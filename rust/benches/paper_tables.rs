//! Bench: chip-level tables — regenerates Table I (power modes), Fig. 7
//! (operating modes over VDD) and Table II (state-of-the-art comparison).

use fulmine::report;

fn main() {
    println!("{}", report::table1());
    println!("{}", report::fig7());
    println!("{}", report::table2());
}
