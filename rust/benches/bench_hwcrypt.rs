//! Bench: HWCRYPT — regenerates §III-B and Fig. 8a, and measures the host
//! throughput of the functional crypto implementations (the L3 hot path of
//! the secure pipelines).

use fulmine::bench_support::{blackbox, measure, report_row};
use fulmine::crypto::modes::{self, XtsKey};
use fulmine::crypto::sponge::{ae_encrypt, SpongeConfig};
use fulmine::report;

fn main() {
    println!("{}", report::sec3b());
    println!("{}", report::fig8a());

    println!("== host throughput of the functional crypto (release build) ==");
    let data = vec![0xA5u8; 1 << 16];
    let key = XtsKey::new(&[1; 16], &[2; 16]);

    let (m, lo, hi) = measure(2, 9, || {
        blackbox(modes::xts_encrypt(&key, 0, &data));
    });
    report_row("xts_encrypt 64 KiB", m, lo, hi, Some((data.len() as f64 / m / 1e6, "MB/s")));

    let (m, lo, hi) = measure(2, 9, || {
        blackbox(modes::ecb_encrypt(&[1; 16], &data));
    });
    report_row("ecb_encrypt 64 KiB", m, lo, hi, Some((data.len() as f64 / m / 1e6, "MB/s")));

    let (m, lo, hi) = measure(2, 9, || {
        blackbox(ae_encrypt(SpongeConfig::MAX_RATE, &[3; 16], &[4; 16], &data));
    });
    report_row("sponge_ae 64 KiB", m, lo, hi, Some((data.len() as f64 / m / 1e6, "MB/s")));

    // decrypt path (sector-addressed, as the use cases drive it)
    let ct = modes::xts_encrypt_region(&key, 0, 512, &data);
    let (m, lo, hi) = measure(2, 9, || {
        blackbox(modes::xts_decrypt_region(&key, 0, 512, &ct));
    });
    report_row("xts_decrypt_region 64 KiB/512B", m, lo, hi, Some((data.len() as f64 / m / 1e6, "MB/s")));
}
