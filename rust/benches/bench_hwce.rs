//! Bench: HWCE — regenerates §III-C and Fig. 8b, and measures the host cost
//! of (a) the detailed streamer-level cycle simulation, (b) the VM software
//! kernels, (c) the golden functional convolution, and (d) a PJRT artifact
//! execution (the runtime hot path).

use fulmine::apps::params::{gen_params, xorshift_i16};
use fulmine::bench_support::{blackbox, measure, report_row};
use fulmine::hwce::golden::{conv_multi, WeightPrec};
use fulmine::hwce::{simulate_tile_cycles, HwceJob};
use fulmine::isa::vm::Machine;
use fulmine::kernels_sw::conv::{run_conv, stage_tile, ConvImpl, ConvJob};
use fulmine::report;
use fulmine::runtime::{default_artifact_dir, Runtime, TensorI16};

fn main() {
    println!("{}", report::sec3c());
    println!("{}", report::fig8b());

    println!("== host cost of the simulation/functional layers ==");

    let job = HwceJob { w: 32, h: 32, k: 5, prec: WeightPrec::W4, qf: 8 };
    let (m, lo, hi) = measure(2, 9, || {
        blackbox(simulate_tile_cycles(job));
    });
    report_row("hwce detailed sim (32x32, w4)", m, lo, hi, None);

    let cjob = ConvJob { w: 36, h: 36, k: 5, qf: 8, x_base: 0, w_base: 0x8000, y_base: 0x9000 };
    let x: Vec<i16> = (0..cjob.w * cjob.h).map(|i| (i % 251) as i16).collect();
    let wts: Vec<i16> = (0..25).map(|i| i as i16).collect();
    let (m, lo, hi) = measure(1, 5, || {
        let mut mach = Machine::new();
        stage_tile(&mut mach, cjob, &x, &wts, ConvImpl::Simd);
        blackbox(run_conv(&mut mach, cjob, ConvImpl::Simd, 4));
    });
    report_row("VM 4-core SIMD conv (36x36)", m, lo, hi, None);

    // golden functional conv (the cross-check reference)
    let gx: Vec<i16> = (0..64 * 64).map(|i| (i % 127) as i16).collect();
    let w4: Vec<Vec<i16>> = (0..4).map(|f| vec![(f as i16) - 2; 25]).collect();
    let wrefs: Vec<&[i16]> = w4.iter().map(|v| v.as_slice()).collect();
    let (m, lo, hi) = measure(2, 9, || {
        let mut y = vec![vec![0i16; 60 * 60]; 4];
        conv_multi(WeightPrec::W4, 5, 64, 64, 8, &gx, &wrefs, &mut y);
        blackbox(y);
    });
    report_row("golden conv_multi w4 (64x64)", m, lo, hi, None);

    // PJRT artifact execution (compile once, execute many)
    match Runtime::open(default_artifact_dir()) {
        Ok(mut rt) => {
            let meta = rt.meta("quickstart_conv_w4").unwrap().clone();
            let xt = TensorI16::new(
                meta.input_shapes[0].clone(),
                xorshift_i16(1, meta.input_shapes[0].iter().product(), -1024, 1023),
            );
            let mut inputs = vec![xt];
            inputs.extend(gen_params(&meta.input_shapes[1..], meta.simd, 1));
            rt.compile("quickstart_conv_w4").unwrap();
            let (m, lo, hi) = measure(3, 15, || {
                blackbox(rt.execute("quickstart_conv_w4", &inputs).unwrap());
            });
            report_row("PJRT execute quickstart_conv_w4", m, lo, hi, None);

            let meta = rt.meta("resnet20_cifar_w4").unwrap().clone();
            let xt = TensorI16::new(
                meta.input_shapes[0].clone(),
                xorshift_i16(2, meta.input_shapes[0].iter().product(), -1024, 1023),
            );
            let mut inputs = vec![xt];
            inputs.extend(gen_params(&meta.input_shapes[1..], 4, 1));
            rt.compile("resnet20_cifar_w4").unwrap();
            let (m, lo, hi) = measure(1, 5, || {
                blackbox(rt.execute("resnet20_cifar_w4", &inputs).unwrap());
            });
            report_row("PJRT execute resnet20_cifar_w4", m, lo, hi, None);
        }
        Err(e) => println!("(PJRT rows skipped: {e})"),
    }
}
