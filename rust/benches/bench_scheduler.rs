//! Bench: the event-driven SoC scheduler — streamed frames/s and pJ/op for
//! every registered workload at increasing stream depths (including the
//! `mixed` multi-tenant stream, which the analytic model could not even
//! express), plus the host cost of scheduling itself (the simulator's own
//! hot path). Workloads resolve through the [`fulmine::workload::Registry`]
//! via the [`SocSystem`] façade.
//!
//! Besides the human-readable report this harness writes
//! **`BENCH_sched.json`**: one row per (workload, rung) with the scheduled
//! and analytic single-frame makespans, their gap, pJ/op and the
//! co-residency statistics, plus a `stream_scaling` section with the
//! *simulator's own* wall-clock throughput (jobs/s) and peak resident job
//! count at `--frames {1, 64, 4096}` for the production streaming path
//! (compiled templates + steady-state fast-forward) against the live
//! windowed path (fast-forward disabled — the PR 4 semantics) and the
//! materialized paths (indexed dispatch and the legacy linear scan), and
//! a `shard_scaling` section with jobs/s at S = {1, 2, 4} simulated SoCs,
//! and a `fleet_scaling` section with the class-deduplicated fleet
//! runner's chips/s and dedup speedup at {1k, 100k, 1M} chips, and a
//! `fleet_hetero_scaling` section repeating those depths with *every*
//! chip perturbed (seeded service-time drift + traffic phase jitter,
//! the parametric-family path — headline key
//! `fleet_hetero_1m_dedup_speedup`), and a
//! `policy` section with energy-per-day and battery-life rows for every
//! workload × sleep policy at a 1 Hz duty cycle (CI guards the
//! oracle ≤ lookahead ≤ greedy energy ordering), and a `fault_overhead`
//! section with the same gap-dominated 512-frame stream run clean and
//! under a seeded mixed fault model (headline key
//! `fault_overhead_jobs_per_s_ratio` — the simulator-side cost of the
//! fault machinery, guarded by CI), and a `session_overhead` section
//! with the 512-frame `secure_link` stream run over a perfect, a
//! retransmission-regime (loss 0.1) and an outage-regime (loss 0.6)
//! seeded channel (headline key `session_overhead_jobs_per_s_ratio` —
//! the steady-state cost of the secure-link session machinery, guarded
//! by CI) — the machine-readable perf trajectory CI tracks across PRs.
//!
//! Uses `fulmine::bench_support` (the offline crate set has no criterion).

use fulmine::bench_support::{blackbox, measure, report_row};
use fulmine::coordinator::{surveillance, ExecConfig};
use fulmine::fault::{FaultModel, Recovery};
use fulmine::hwce::golden::WeightPrec;
use fulmine::json::Json;
use fulmine::report;
use fulmine::session::{SessionModel, SessionRecovery};
use fulmine::soc::pm::{self, PolicyKind};
use fulmine::soc::sched::{Engine, Scheduler, StreamScheduler, DEFAULT_STREAM_WINDOW};
use fulmine::system::{FleetSpec, RunSpec, ShardedStream, SocSystem};
use fulmine::traffic::Traffic;
use fulmine::workload::frame_graph;
use std::time::Instant;

fn main() {
    let sys = SocSystem::new();

    for name in sys.registry().names() {
        println!("== stream throughput: {name} (best rung) ==");
        println!(
            "{:>7} {:>12} {:>12} {:>10} {:>10} {:>10}",
            "frames", "time [s]", "frames/s", "speedup", "mJ/frame", "pJ/op"
        );
        for frames in [1usize, 2, 4, 8] {
            let r = sys.run(&RunSpec::new(name).frames(frames)).unwrap().result;
            println!(
                "{frames:>7} {:>12.4} {:>12.3} {:>9.2}x {:>10.4} {:>10.2}",
                r.time_s,
                r.fps,
                r.speedup,
                r.energy_mj / frames as f64,
                r.pj_per_op
            );
        }
    }

    println!("\n== engine utilization, surveillance x8 ==");
    let r = sys.run(&RunSpec::new("surveillance").frames(8)).unwrap().result;
    for e in Engine::ALL {
        let busy = r.busy_s[e.index()];
        if busy > 0.0 {
            let pct = busy / r.time_s * 100.0;
            println!("{:<14} {pct:>7.1}% busy ({busy:.4} s of {:.4} s)", e.name(), r.time_s);
        }
    }
    println!(
        "overlap {:.4} s | cluster co-residency {:.4} s | scheduled/analytic {:.3}x",
        r.overlap_s,
        r.coresidency_s,
        r.single_frame_s / r.single_frame_analytic_s
    );

    println!("\n== per-tenant attribution, mixed x8 ==");
    let mixed = sys.run(&RunSpec::new("mixed").frames(8)).unwrap();
    print!("{}", mixed.render_text());

    println!("{}", report::stream_report("surveillance", 8, None).unwrap());

    // Machine-readable perf trajectory: pJ/op + makespans per rung.
    let mut rows: Vec<Json> = Vec::new();
    for name in sys.registry().names() {
        let w = sys.registry().resolve(name).unwrap();
        for rung in w.rungs() {
            let g = frame_graph(w, rung.cfg).unwrap();
            let run = Scheduler::run(&g);
            let ana = g.analytic();
            rows.push(Json::obj(vec![
                ("workload", Json::string(name)),
                ("rung", Json::string(rung.label)),
                ("scheduled_s", Json::num(run.makespan_s)),
                ("analytic_s", Json::num(ana.makespan_s)),
                ("gap_vs_analytic", Json::num(run.makespan_s / ana.makespan_s)),
                ("energy_mj", Json::num(run.ledger.total_mj())),
                (
                    "pj_per_op",
                    Json::num(run.ledger.total_mj() * 1e9 / w.eq_ops() as f64),
                ),
                ("mode_switches", Json::num(run.mode_switches as f64)),
                ("overlap_s", Json::num(run.overlap_s)),
                ("coresidency_s", Json::num(run.coresidency_s)),
                ("n_jobs", Json::num(run.n_jobs as f64)),
            ]));
        }
    }
    // The simulator's own hot path, at scale: wall-clock jobs/s and peak
    // resident jobs of the production streaming path (compiled template +
    // steady-state fast-forward) at 1/64/4096 frames, against the live
    // windowed path (fast-forward disabled — the PR 4 baseline) and the
    // materialized paths (indexed dispatch, and the legacy linear scan
    // that rescans the ready set per event) at the depths they can
    // reasonably reach.
    println!("\n== stream scaling: simulator wall-clock and resident jobs ==");
    println!(
        "{:<22} {:>7} {:>10} {:>12} {:>14} {:>6}",
        "path", "frames", "wall [s]", "jobs/s", "peak resident", "ff"
    );
    let best = ExecConfig::with_hwce(WeightPrec::W4);
    let g1 = surveillance::frame_graph(best);
    let mut scaling_rows: Vec<Json> = Vec::new();
    let mut jobs_per_s: Vec<(&'static str, usize, f64)> = Vec::new();
    let mut scale_row = |path: &'static str, frames: usize, wall_s: f64, peak: usize, ff: usize| {
        let jobs = g1.len() * frames;
        let jps = jobs as f64 / wall_s.max(1e-12);
        println!("{path:<22} {frames:>7} {wall_s:>10.4} {jps:>12.0} {peak:>14} {ff:>6}");
        scaling_rows.push(Json::obj(vec![
            ("workload", Json::string("surveillance")),
            ("path", Json::string(path)),
            ("frames", Json::num(frames as f64)),
            ("wall_s", Json::num(wall_s)),
            ("jobs", Json::num(jobs as f64)),
            ("jobs_per_s", Json::num(jps)),
            ("peak_resident_jobs", Json::num(peak as f64)),
            ("fast_forwarded_frames", Json::num(ff as f64)),
        ]));
        jobs_per_s.push((path, frames, jps));
    };
    for frames in [1usize, 64, 4096] {
        let t = Instant::now();
        let r = blackbox(StreamScheduler::run(&g1, frames, DEFAULT_STREAM_WINDOW));
        scale_row(
            "windowed",
            frames,
            t.elapsed().as_secs_f64(),
            r.peak_resident_jobs,
            r.fast_forwarded_frames,
        );
    }
    for frames in [1usize, 64, 4096] {
        let t = Instant::now();
        let r = blackbox(StreamScheduler::run_live(&g1, frames, DEFAULT_STREAM_WINDOW));
        scale_row("windowed-live", frames, t.elapsed().as_secs_f64(), r.peak_resident_jobs, 0);
    }
    for frames in [1usize, 64] {
        let rep = g1.repeat(frames);
        let t = Instant::now();
        let r = blackbox(Scheduler::run(&rep));
        scale_row("materialized", frames, t.elapsed().as_secs_f64(), r.peak_resident_jobs, 0);
        let t = Instant::now();
        let r = blackbox(Scheduler::run_scan(&rep));
        scale_row(
            "materialized-scan",
            frames,
            t.elapsed().as_secs_f64(),
            r.peak_resident_jobs,
            0,
        );
    }
    let jps_of = |path: &str, frames: usize| {
        jobs_per_s
            .iter()
            .find(|(p, f, _)| *p == path && *f == frames)
            .map(|&(_, _, v)| v)
            .unwrap_or(0.0)
    };
    // the headline ratios: the production path vs the legacy scan at the
    // deepest stream the scan can run, vs the PR 4 live windowed path at
    // full depth (the fast-forward win), and the historic scan ratios
    let vs_scan_64 = jps_of("windowed", 64) / jps_of("materialized-scan", 64).max(1e-12);
    let deep_vs_scan = jps_of("windowed", 4096) / jps_of("materialized-scan", 64).max(1e-12);
    let ff_vs_live_4096 = jps_of("windowed", 4096) / jps_of("windowed-live", 4096).max(1e-12);
    println!(
        "windowed vs scan: {vs_scan_64:.1}x at 64 frames, {deep_vs_scan:.1}x at 4096-vs-64 frames"
    );
    println!("fast-forward vs live windowed at 4096 frames: {ff_vs_live_4096:.1}x jobs/s");

    // Multi-SoC sharding: frames split across S simulated chips on
    // parallel host threads; near-linear simulator throughput on top of
    // whatever one chip does.
    println!("\n== shard scaling: 4096 frames across S simulated SoCs ==");
    println!(
        "{:<8} {:>10} {:>12} {:>12} {:>10}",
        "shards", "wall [s]", "jobs/s", "sim fps", "vs S=1"
    );
    let mut shard_rows: Vec<Json> = Vec::new();
    let mut base_jps = 0.0f64;
    for shards in [1usize, 2, 4] {
        let frames = 4096usize;
        let t = Instant::now();
        let parts = blackbox(ShardedStream::run(&g1, frames, DEFAULT_STREAM_WINDOW, shards));
        let wall_s = t.elapsed().as_secs_f64();
        let jobs = g1.len() * frames;
        let jps = jobs as f64 / wall_s.max(1e-12);
        if shards == 1 {
            base_jps = jps;
        }
        let sim_time = parts.iter().map(|(r, _)| r.makespan_s).fold(0.0, f64::max);
        let sim_fps = frames as f64 / sim_time;
        let speedup = jps / base_jps.max(1e-12);
        println!("{shards:<8} {wall_s:>10.4} {jps:>12.0} {sim_fps:>12.3} {speedup:>9.2}x");
        shard_rows.push(Json::obj(vec![
            ("workload", Json::string("surveillance")),
            ("shards", Json::num(shards as f64)),
            ("frames", Json::num(frames as f64)),
            ("wall_s", Json::num(wall_s)),
            ("jobs", Json::num(jobs as f64)),
            ("jobs_per_s", Json::num(jps)),
            ("sim_fps", Json::num(sim_fps)),
            ("speedup_vs_one_shard", Json::num(speedup)),
        ]));
    }

    // Fleet scaling: class-deduplicated simulation of N chips over the
    // standard workload x rung x traffic mix. Wall-clock is dominated by
    // the distinct *classes* (plus K parity samples each), not the chip
    // count, so throughput in chips/s grows with N — the headline row is
    // a million chips, with the dedup speedup vs simulating every chip
    // live (estimated from the measured per-class live cost).
    println!("\n== fleet scaling: class-deduplicated chips/s ==");
    println!(
        "{:>9} {:>8} {:>6} {:>10} {:>14} {:>14} {:>10}",
        "chips", "classes", "live", "wall [s]", "chips/s", "naive est [s]", "speedup"
    );
    let mut fleet_rows: Vec<Json> = Vec::new();
    let mut fleet_1m_speedup = 0.0f64;
    for chips in [1_000usize, 100_000, 1_000_000] {
        let rep = sys.fleet(&FleetSpec::mixed(chips, 32)).unwrap();
        println!(
            "{chips:>9} {:>8} {:>6} {:>10.4} {:>14.0} {:>14.2} {:>9.1}x",
            rep.classes.len(),
            rep.live_chips,
            rep.wall_s,
            rep.chips_per_s,
            rep.naive_est_wall_s,
            rep.dedup_speedup
        );
        fleet_rows.push(Json::obj(vec![
            ("chips", Json::num(chips as f64)),
            ("class_count", Json::num(rep.classes.len() as f64)),
            ("live_chips", Json::num(rep.live_chips as f64)),
            ("parity_checked", Json::num(rep.parity_checked as f64)),
            ("wall_s", Json::num(rep.wall_s)),
            ("chips_per_s", Json::num(rep.chips_per_s)),
            ("naive_est_wall_s", Json::num(rep.naive_est_wall_s)),
            ("dedup_speedup", Json::num(rep.dedup_speedup)),
        ]));
        if chips == 1_000_000 {
            fleet_1m_speedup = rep.dedup_speedup;
        }
    }
    println!("fleet dedup speedup at 1M chips: {fleet_1m_speedup:.1}x vs per-chip simulation");

    // Heterogeneous fleet scaling: the same mix, but *every* chip
    // perturbed — seeded service-time drift of ±1% and up to 10 ms of
    // traffic phase per chip. PR 6's exact dedup would degrade to
    // O(chips) here; parametric families keep the wall clock O(classes)
    // by deriving members through the certified closed-form rescale
    // (live fallback where the certificate refuses). The headline row is
    // again a million chips, all distinct.
    println!("\n== fleet hetero scaling: every chip perturbed (drift 1%, jitter 10ms) ==");
    println!(
        "{:>9} {:>8} {:>9} {:>9} {:>10} {:>14} {:>10}",
        "chips", "classes", "members", "fallback", "wall [s]", "chips/s", "speedup"
    );
    let mut hetero_rows: Vec<Json> = Vec::new();
    let mut hetero_1m_speedup = 0.0f64;
    for chips in [1_000usize, 100_000, 1_000_000] {
        let rep = sys
            .fleet(&FleetSpec::mixed(chips, 32).drift(1.0).phase_jitter(0.01))
            .unwrap();
        assert_eq!(rep.parity_failures, 0, "hetero fleet parity must hold at {chips} chips");
        println!(
            "{chips:>9} {:>8} {:>9} {:>9} {:>10.4} {:>14.0} {:>9.1}x",
            rep.classes.len(),
            rep.members,
            rep.live_fallbacks,
            rep.wall_s,
            rep.chips_per_s,
            rep.dedup_speedup
        );
        hetero_rows.push(Json::obj(vec![
            ("chips", Json::num(chips as f64)),
            ("drift_pct", Json::num(rep.drift_pct)),
            ("phase_jitter_s", Json::num(rep.phase_jitter_s)),
            ("class_count", Json::num(rep.classes.len() as f64)),
            ("members", Json::num(rep.members as f64)),
            ("live_fallbacks", Json::num(rep.live_fallbacks as f64)),
            ("live_chips", Json::num(rep.live_chips as f64)),
            ("parity_checked", Json::num(rep.parity_checked as f64)),
            ("wall_s", Json::num(rep.wall_s)),
            ("chips_per_s", Json::num(rep.chips_per_s)),
            ("naive_est_wall_s", Json::num(rep.naive_est_wall_s)),
            ("dedup_speedup", Json::num(rep.dedup_speedup)),
        ]));
        if chips == 1_000_000 {
            hetero_1m_speedup = rep.dedup_speedup;
        }
    }
    println!(
        "hetero fleet dedup speedup at 1M perturbed chips: {hetero_1m_speedup:.1}x vs per-chip simulation"
    );

    // Power-state policies: every workload duty-cycled at 1 Hz (a gap-
    // dominated sensor cadence) under the three sleep policies. The rows
    // carry the battery extrapolation CI guards: per workload, lookahead
    // must never burn more energy per day than greedy, and the
    // clairvoyant oracle lower-bounds both.
    println!("\n== power policies: energy/day at periodic:1, 64 frames ==");
    println!(
        "{:<14} {:<10} {:>10} {:>11} {:>11} {:>8} {:>7}",
        "workload", "policy", "E [mJ]", "mJ/day", "batt [d]", "sleep%", "wakes"
    );
    let mut policy_rows: Vec<Json> = Vec::new();
    for name in sys.registry().names() {
        for policy in [PolicyKind::Greedy, PolicyKind::Lookahead, PolicyKind::Oracle] {
            let r = sys
                .run(
                    &RunSpec::new(name)
                        .frames(64)
                        .traffic(Traffic::Periodic { rate_hz: 1.0 })
                        .policy(Some(policy)),
                )
                .unwrap()
                .result;
            let epd = pm::energy_per_day_mj(r.energy_mj, r.time_s);
            let batt = pm::battery_days(r.energy_mj, r.time_s);
            let sleep_frac = r.sleep_s / r.time_s;
            println!(
                "{name:<14} {:<10} {:>10.4} {epd:>11.3} {batt:>11.2} {:>7.1}% {:>7}",
                policy.name(),
                r.energy_mj,
                sleep_frac * 100.0,
                r.wake_transitions
            );
            policy_rows.push(Json::obj(vec![
                ("workload", Json::string(name)),
                ("policy", Json::string(policy.name())),
                ("traffic", Json::string("periodic:1")),
                ("frames", Json::num(64.0)),
                ("energy_mj", Json::num(r.energy_mj)),
                ("epd_mj_per_day", Json::num(epd)),
                ("battery_days", Json::num(batt)),
                ("sleep_fraction", Json::num(sleep_frac)),
                ("deep_sleep_s", Json::num(r.deep_sleep_s)),
                ("wake_transitions", Json::num(r.wake_transitions as f64)),
            ]));
        }
    }

    // Fault-injection overhead: the same gap-dominated 512-frame stream
    // run clean and under a seeded low-rate mixed fault model with retry
    // recovery. The jobs/s ratio is the simulator-side cost of the fault
    // machinery (plan build, per-frame variant dispatch, fast-forward
    // suspension around faulted frames); both sides are measured in this
    // run, so the ratio transfers across CI hardware. The reliability
    // counters are deterministic model output — the seed 5 table fires
    // 4 drops, 6 transients and 6 link losses over frames 0..512.
    println!("\n== fault overhead: seizure x512 at periodic:2, clean vs mixed faults ==");
    let fault_model = FaultModel {
        drop_rate: 0.01,
        transient_rate: 0.01,
        brownout_rate: 0.002,
        link_rate: 0.01,
        seed: 5,
    };
    let fault_frames = 512usize;
    let mut fault_rows: Vec<Json> = Vec::new();
    let mut fault_jps = [0.0f64; 2];
    for (i, faults) in [None, Some(fault_model)].into_iter().enumerate() {
        let mode = if i == 0 { "clean" } else { "faulted" };
        let spec = RunSpec::new("seizure")
            .frames(fault_frames)
            .traffic(Traffic::Periodic { rate_hz: 2.0 })
            .faults(faults)
            .recovery(Recovery::default());
        let t = Instant::now();
        let run = blackbox(sys.run(&spec).unwrap());
        let wall_s = t.elapsed().as_secs_f64();
        let r = &run.result;
        let jps = r.total_jobs as f64 / wall_s.max(1e-12);
        fault_jps[i] = jps;
        println!(
            "{mode:<8} wall {wall_s:>8.4} s | {jps:>10.0} jobs/s | avail {:.4} | \
             {} dropped | {} retries | {} resets | ff {} | recovery {:.4} mJ",
            r.availability(),
            r.frames_dropped,
            r.fault_retries,
            r.chip_resets,
            r.fast_forwarded_frames,
            r.recovery_energy_mj
        );
        fault_rows.push(Json::obj(vec![
            ("workload", Json::string("seizure")),
            ("mode", Json::string(mode)),
            ("frames", Json::num(fault_frames as f64)),
            ("wall_s", Json::num(wall_s)),
            ("jobs_per_s", Json::num(jps)),
            ("availability", Json::num(r.availability())),
            ("frames_dropped", Json::num(r.frames_dropped as f64)),
            ("fault_retries", Json::num(r.fault_retries as f64)),
            ("chip_resets", Json::num(r.chip_resets as f64)),
            ("state_loss_frames", Json::num(r.state_loss_frames as f64)),
            ("recovery_energy_mj", Json::num(r.recovery_energy_mj)),
            ("fast_forwarded_frames", Json::num(r.fast_forwarded_frames as f64)),
        ]));
    }
    let fault_overhead_ratio = fault_jps[1] / fault_jps[0].max(1e-12);
    println!("faulted vs clean simulator throughput: {fault_overhead_ratio:.2}x jobs/s");

    // Secure-link session overhead: the same 512-frame secure_link
    // stream run over a perfect channel, a retransmission-regime channel
    // (loss 0.1 — every loss recovered within the timer budget, ~48 of
    // 512 frames carry variants, fast-forward stays engaged between
    // them) and an outage-regime channel (loss 0.6 — frames exhaust the
    // 8-send budget and resumption handshakes fire). The guarded ratio
    // compares the retransmission regime against clean: that is the
    // steady-state cost of the session machinery (plan build, per-frame
    // variant dispatch, fast-forward suspension around handshake and
    // retransmission frames). The outage row is reported for the perf
    // trajectory but not guarded — at loss 0.6 most frames are
    // variants, so its throughput is dominated by variant dispatch, not
    // by a hot-path regression. Session counters are deterministic
    // model output of the seed-7 channel tables.
    println!("\n== session overhead: secure_link x512 at periodic:2, clean vs lossy channel ==");
    let session_frames = 512usize;
    let mut session_rows: Vec<Json> = Vec::new();
    let mut session_jps = [0.0f64; 3];
    for (i, (mode, loss)) in [
        ("clean", None),
        ("lossy-0.1", Some(SessionModel { loss_rate: 0.1, seed: 7 })),
        ("outage-0.6", Some(SessionModel { loss_rate: 0.6, seed: 7 })),
    ]
    .into_iter()
    .enumerate()
    {
        let spec = RunSpec::new("secure_link")
            .frames(session_frames)
            .traffic(Traffic::Periodic { rate_hz: 2.0 })
            .loss(loss)
            .session_recovery(SessionRecovery::default());
        let t = Instant::now();
        let run = blackbox(sys.run(&spec).unwrap());
        let wall_s = t.elapsed().as_secs_f64();
        let r = &run.result;
        let ss = run.session.unwrap_or_default();
        let jps = r.total_jobs as f64 / wall_s.max(1e-12);
        session_jps[i] = jps;
        println!(
            "{mode:<10} wall {wall_s:>8.4} s | {jps:>10.0} jobs/s | avail {:.4} | \
             {} retx | {} resumptions | {} dropped | ff {} | overhead {:.4} mJ",
            r.availability(),
            ss.retransmissions,
            ss.resumptions,
            ss.records_dropped,
            r.fast_forwarded_frames,
            ss.overhead_mj
        );
        session_rows.push(Json::obj(vec![
            ("workload", Json::string("secure_link")),
            ("mode", Json::string(mode)),
            ("frames", Json::num(session_frames as f64)),
            ("wall_s", Json::num(wall_s)),
            ("jobs_per_s", Json::num(jps)),
            ("availability", Json::num(r.availability())),
            ("retransmissions", Json::num(ss.retransmissions as f64)),
            ("resumptions", Json::num(ss.resumptions as f64)),
            ("full_handshakes", Json::num(ss.full_handshakes as f64)),
            ("records_dropped", Json::num(ss.records_dropped as f64)),
            ("handshake_mj", Json::num(ss.handshake_mj)),
            ("overhead_mj", Json::num(ss.overhead_mj)),
            ("goodput_fps", Json::num(ss.goodput_fps(session_frames, r.time_s))),
            ("fast_forwarded_frames", Json::num(r.fast_forwarded_frames as f64)),
        ]));
    }
    let session_overhead_ratio = session_jps[1] / session_jps[0].max(1e-12);
    println!("lossy (0.1) vs clean simulator throughput: {session_overhead_ratio:.2}x jobs/s");

    let doc = Json::obj(vec![
        ("rungs", Json::Arr(rows)),
        ("stream_scaling", Json::Arr(scaling_rows)),
        ("shard_scaling", Json::Arr(shard_rows)),
        ("fleet_scaling", Json::Arr(fleet_rows)),
        ("fleet_hetero_scaling", Json::Arr(hetero_rows)),
        ("policy", Json::Arr(policy_rows)),
        ("fault_overhead", Json::Arr(fault_rows)),
        ("fault_overhead_jobs_per_s_ratio", Json::num(fault_overhead_ratio)),
        ("session_overhead", Json::Arr(session_rows)),
        ("session_overhead_jobs_per_s_ratio", Json::num(session_overhead_ratio)),
        ("fleet_1m_dedup_speedup", Json::num(fleet_1m_speedup)),
        ("fleet_hetero_1m_dedup_speedup", Json::num(hetero_1m_speedup)),
        ("windowed_vs_scan_jobs_per_s", Json::num(vs_scan_64)),
        ("windowed_4096_vs_scan_64_jobs_per_s", Json::num(deep_vs_scan)),
        ("windowed_ff_vs_live_4096_jobs_per_s", Json::num(ff_vs_live_4096)),
    ]);
    std::fs::write("BENCH_sched.json", doc.render() + "\n").expect("write BENCH_sched.json");
    println!("wrote BENCH_sched.json");

    println!("\n== host cost of scheduling ==");
    let g8 = g1.repeat(8);
    let (m, lo, hi) = measure(2, 9, || {
        blackbox(Scheduler::run(&g1));
    });
    report_row(
        "schedule surveillance frame",
        m,
        lo,
        hi,
        Some((g1.len() as f64 / m / 1e3, "kjobs/s")),
    );
    let (m, lo, hi) = measure(2, 9, || {
        blackbox(Scheduler::run(&g8));
    });
    report_row(
        "schedule surveillance x8 stream",
        m,
        lo,
        hi,
        Some((g8.len() as f64 / m / 1e3, "kjobs/s")),
    );
    let (m, lo, hi) = measure(2, 9, || {
        blackbox(StreamScheduler::run(&g1, 8, DEFAULT_STREAM_WINDOW));
    });
    report_row(
        "windowed x8 stream",
        m,
        lo,
        hi,
        Some((g8.len() as f64 / m / 1e3, "kjobs/s")),
    );
    let (m, lo, hi) = measure(2, 9, || {
        blackbox(g1.analytic());
    });
    report_row("analytic replay (reference)", m, lo, hi, None);
}
