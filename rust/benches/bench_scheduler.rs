//! Bench: the event-driven SoC scheduler — streamed frames/s and pJ/op for
//! every registered workload at increasing stream depths (including the
//! `mixed` multi-tenant stream, which the analytic model could not even
//! express), plus the host cost of scheduling itself (the simulator's own
//! hot path). Workloads resolve through the [`fulmine::workload::Registry`]
//! via the [`SocSystem`] façade.
//!
//! Besides the human-readable report this harness writes
//! **`BENCH_sched.json`**: one row per (workload, rung) with the scheduled
//! and analytic single-frame makespans, their gap, pJ/op and the
//! co-residency statistics — the machine-readable trajectory CI tracks
//! across PRs.
//!
//! Uses `fulmine::bench_support` (the offline crate set has no criterion).

use fulmine::bench_support::{blackbox, measure, report_row};
use fulmine::coordinator::{surveillance, ExecConfig};
use fulmine::hwce::golden::WeightPrec;
use fulmine::json::Json;
use fulmine::report;
use fulmine::soc::sched::{Engine, Scheduler};
use fulmine::system::{RunSpec, SocSystem};
use fulmine::workload::frame_graph;

fn main() {
    let sys = SocSystem::new();

    for name in sys.registry().names() {
        println!("== stream throughput: {name} (best rung) ==");
        println!(
            "{:>7} {:>12} {:>12} {:>10} {:>10} {:>10}",
            "frames", "time [s]", "frames/s", "speedup", "mJ/frame", "pJ/op"
        );
        for frames in [1usize, 2, 4, 8] {
            let r = sys.run(&RunSpec::new(name).frames(frames)).unwrap().result;
            println!(
                "{frames:>7} {:>12.4} {:>12.3} {:>9.2}x {:>10.4} {:>10.2}",
                r.time_s,
                r.fps,
                r.speedup,
                r.energy_mj / frames as f64,
                r.pj_per_op
            );
        }
    }

    println!("\n== engine utilization, surveillance x8 ==");
    let r = sys.run(&RunSpec::new("surveillance").frames(8)).unwrap().result;
    for e in Engine::ALL {
        let busy = r.busy_s[e.index()];
        if busy > 0.0 {
            let pct = busy / r.time_s * 100.0;
            println!("{:<14} {pct:>7.1}% busy ({busy:.4} s of {:.4} s)", e.name(), r.time_s);
        }
    }
    println!(
        "overlap {:.4} s | cluster co-residency {:.4} s | scheduled/analytic {:.3}x",
        r.overlap_s,
        r.coresidency_s,
        r.single_frame_s / r.single_frame_analytic_s
    );

    println!("\n== per-tenant attribution, mixed x8 ==");
    let mixed = sys.run(&RunSpec::new("mixed").frames(8)).unwrap();
    print!("{}", mixed.render_text());

    println!("{}", report::stream_report("surveillance", 8, None).unwrap());

    // Machine-readable perf trajectory: pJ/op + makespans per rung.
    let mut rows: Vec<Json> = Vec::new();
    for name in sys.registry().names() {
        let w = sys.registry().resolve(name).unwrap();
        for rung in w.rungs() {
            let g = frame_graph(w, rung.cfg).unwrap();
            let run = Scheduler::run(&g);
            let ana = g.analytic();
            rows.push(Json::obj(vec![
                ("workload", Json::string(name)),
                ("rung", Json::string(rung.label)),
                ("scheduled_s", Json::num(run.makespan_s)),
                ("analytic_s", Json::num(ana.makespan_s)),
                ("gap_vs_analytic", Json::num(run.makespan_s / ana.makespan_s)),
                ("energy_mj", Json::num(run.ledger.total_mj())),
                (
                    "pj_per_op",
                    Json::num(run.ledger.total_mj() * 1e9 / w.eq_ops() as f64),
                ),
                ("mode_switches", Json::num(run.mode_switches as f64)),
                ("overlap_s", Json::num(run.overlap_s)),
                ("coresidency_s", Json::num(run.coresidency_s)),
                ("n_jobs", Json::num(run.n_jobs as f64)),
            ]));
        }
    }
    let doc = Json::obj(vec![("rungs", Json::Arr(rows))]);
    std::fs::write("BENCH_sched.json", doc.render() + "\n").expect("write BENCH_sched.json");
    println!("wrote BENCH_sched.json");

    println!("\n== host cost of scheduling ==");
    let best = ExecConfig::with_hwce(WeightPrec::W4);
    let g1 = surveillance::frame_graph(best);
    let g8 = g1.repeat(8);
    let (m, lo, hi) = measure(2, 9, || {
        blackbox(Scheduler::run(&g1));
    });
    report_row(
        "schedule surveillance frame",
        m,
        lo,
        hi,
        Some((g1.len() as f64 / m / 1e3, "kjobs/s")),
    );
    let (m, lo, hi) = measure(2, 9, || {
        blackbox(Scheduler::run(&g8));
    });
    report_row(
        "schedule surveillance x8 stream",
        m,
        lo,
        hi,
        Some((g8.len() as f64 / m / 1e3, "kjobs/s")),
    );
    let (m, lo, hi) = measure(2, 9, || {
        blackbox(g1.analytic());
    });
    report_row("analytic replay (reference)", m, lo, hi, None);
}
