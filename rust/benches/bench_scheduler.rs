//! Bench: the event-driven SoC scheduler — streamed frames/s and pJ/op for
//! the three §IV use cases at increasing stream depths (the multi-frame
//! throughput the analytic model could not express), plus the host cost of
//! scheduling itself (the simulator's own hot path).
//!
//! Uses `fulmine::bench_support` (the offline crate set has no criterion).

use fulmine::bench_support::{blackbox, measure, report_row};
use fulmine::coordinator::{facedet, seizure, surveillance, ExecConfig, StreamResult};
use fulmine::hwce::golden::WeightPrec;
use fulmine::report;
use fulmine::soc::sched::{Engine, Scheduler};

fn stream_rows(usecase: &str, run: impl Fn(usize) -> StreamResult) {
    println!("== stream throughput: {usecase} (best rung) ==");
    println!(
        "{:>7} {:>12} {:>12} {:>10} {:>10} {:>10}",
        "frames", "time [s]", "frames/s", "speedup", "mJ/frame", "pJ/op"
    );
    for frames in [1usize, 2, 4, 8] {
        let r = run(frames);
        println!(
            "{frames:>7} {:>12.4} {:>12.3} {:>9.2}x {:>10.4} {:>10.2}",
            r.time_s,
            r.fps,
            r.speedup,
            r.energy_mj / frames as f64,
            r.pj_per_op
        );
    }
}

fn main() {
    let best = ExecConfig::with_hwce(WeightPrec::W4);
    let seizure_best = *seizure::rung_configs().last().map(|(_, c)| c).unwrap();

    stream_rows("surveillance", |n| surveillance::run_stream(best, n));
    stream_rows("facedet", |n| facedet::run_stream(best, n));
    stream_rows("seizure", |n| seizure::run_stream(seizure_best, n));

    println!("\n== engine utilization, surveillance x8 ==");
    let r = surveillance::run_stream(best, 8);
    for e in Engine::ALL {
        let busy = r.busy_s[e.index()];
        if busy > 0.0 {
            let pct = busy / r.time_s * 100.0;
            println!("{:<14} {pct:>7.1}% busy ({busy:.4} s of {:.4} s)", e.name(), r.time_s);
        }
    }

    println!("\n{}", report::stream_report("surveillance", 8, None).unwrap());

    println!("== host cost of scheduling ==");
    let g1 = surveillance::frame_graph(best);
    let g8 = g1.repeat(8);
    let (m, lo, hi) = measure(2, 9, || {
        blackbox(Scheduler::run(&g1));
    });
    report_row(
        "schedule surveillance frame",
        m,
        lo,
        hi,
        Some((g1.len() as f64 / m / 1e3, "kjobs/s")),
    );
    let (m, lo, hi) = measure(2, 9, || {
        blackbox(Scheduler::run(&g8));
    });
    report_row(
        "schedule surveillance x8 stream",
        m,
        lo,
        hi,
        Some((g8.len() as f64 / m / 1e3, "kjobs/s")),
    );
    let (m, lo, hi) = measure(2, 9, || {
        blackbox(g1.analytic());
    });
    report_row("analytic replay (reference)", m, lo, hi, None);
}
