//! Bench: the three §IV use cases — regenerates Fig. 10, Fig. 11, Fig. 12
//! (ladders + breakdowns + feasibility numbers) and an ablation sweep over
//! design choices (precision mode, crypto offload, supply voltage), plus
//! host-side cost of the pipeline simulation itself.

use fulmine::bench_support::{blackbox, measure, report_row};
use fulmine::coordinator::surveillance;
use fulmine::coordinator::ExecConfig;
use fulmine::hwce::golden::WeightPrec;
use fulmine::report;

fn main() {
    println!("{}", report::fig10());
    println!("{}", report::fig11());
    println!("{}", report::fig12());

    println!("== ablations (secure surveillance, design-choice sweep) ==");
    for (label, r) in report::surveillance_ablations() {
        println!(
            "{label:<18} time {:>8.4} s  energy {:>8.3} mJ  {:>6.2} pJ/op",
            r.time_s, r.energy_mj, r.pj_per_op
        );
    }
    // voltage sweep: energy/frame vs VDD for the best configuration
    println!("\n== VDD sweep (HWCE 4b + HWCRYPT) ==");
    for i in 0..=4 {
        let vdd = 0.8 + 0.1 * i as f64;
        let cfg = ExecConfig { vdd, ..ExecConfig::with_hwce(WeightPrec::W4) };
        let r = surveillance::run_frame(cfg);
        println!(
            "VDD={vdd:.1}V  time {:>8.4} s  energy {:>8.3} mJ  {:>6.2} pJ/op",
            r.time_s, r.energy_mj, r.pj_per_op
        );
    }

    println!("\n== host cost of one full ladder simulation ==");
    let (m, lo, hi) = measure(1, 5, || {
        blackbox(surveillance::ladder());
    });
    report_row("surveillance ladder (5 configs)", m, lo, hi, None);
}
