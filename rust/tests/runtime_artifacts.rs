//! End-to-end artifact validation: every AOT artifact is loaded through the
//! PJRT runtime and its numerics are cross-checked against the rust golden
//! model — the three-layer bit-exactness contract (Pallas ≡ jnp oracle is
//! checked in pytest; golden ≡ artifact is checked here; transitively all
//! four implementations agree).

use fulmine::apps::params::{gen_params, xorshift_i16};
use fulmine::hwce::golden::{conv_multi, WeightPrec};
use fulmine::runtime::{default_artifact_dir, Runtime, TensorI16};

/// Open the artifact runtime, or `None` when the environment cannot run
/// artifacts (no `artifacts/` directory from `make artifacts`, or a build
/// without the `pjrt` feature) — each test then skips instead of failing,
/// so `cargo test` stays green in offline environments. Any *other*
/// failure still panics: a regression in manifest parsing or artifact
/// loading must not silently drain this file's coverage.
fn runtime() -> Option<Runtime> {
    match Runtime::open(default_artifact_dir()) {
        Ok(rt) => Some(rt),
        Err(e) => {
            let msg = format!("{e:#}");
            assert!(
                msg.contains("manifest.txt") || msg.contains("pjrt"),
                "artifact runtime failed for an unexpected reason: {msg}"
            );
            eprintln!("skipping artifact test: {msg}");
            None
        }
    }
}

/// Golden-model replica of the hwce_raw artifacts: multi-channel layer with
/// per-pass normalize/saturate accumulation.
fn golden_layer(
    prec: WeightPrec,
    k: usize,
    qf: u8,
    x: &TensorI16,   // (B, Cin, H, W)
    w: &TensorI16,   // (Cout, Cin, k, k)
    yin: &TensorI16, // (B, Cout, OH, OW)
) -> TensorI16 {
    let (b, cin, h, ww) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let cout = w.shape[0];
    let (oh, ow) = (h - k + 1, ww - k + 1);
    let mut out = yin.clone();
    let simd = prec.simd();
    for bb in 0..b {
        for cg in 0..cout / simd {
            for ci in 0..cin {
                let xs = &x.data[(bb * cin + ci) * h * ww..][..h * ww];
                let wslices: Vec<&[i16]> = (0..simd)
                    .map(|f| {
                        let co = cg * simd + f;
                        &w.data[(co * cin + ci) * k * k..][..k * k]
                    })
                    .collect();
                let mut ys: Vec<Vec<i16>> = (0..simd)
                    .map(|f| {
                        let co = cg * simd + f;
                        out.data[(bb * cout + co) * oh * ow..][..oh * ow].to_vec()
                    })
                    .collect();
                conv_multi(prec, k, ww, h, qf, xs, &wslices, &mut ys);
                for (f, y) in ys.into_iter().enumerate() {
                    let co = cg * simd + f;
                    out.data[(bb * cout + co) * oh * ow..][..oh * ow].copy_from_slice(&y);
                }
            }
        }
    }
    out
}

fn rnd_tensor(shape: Vec<usize>, seed: u64, lo: i64, hi: i64) -> TensorI16 {
    let n = shape.iter().product();
    TensorI16::new(shape, xorshift_i16(seed, n, lo, hi))
}

#[test]
fn hwce_conv3_w16_matches_golden() {
    let Some(mut rt) = runtime() else { return };
    let meta = rt.meta("hwce_conv3_w16").expect("artifact missing").clone();
    let x = rnd_tensor(meta.input_shapes[0].clone(), 11, -2048, 2047);
    let w = rnd_tensor(meta.input_shapes[1].clone(), 12, -256, 255);
    let yin = rnd_tensor(meta.input_shapes[2].clone(), 13, -1024, 1023);
    let got = rt.execute("hwce_conv3_w16", &[x.clone(), w.clone(), yin.clone()]).unwrap();
    let want = golden_layer(WeightPrec::W16, 3, meta.qf, &x, &w, &yin);
    assert_eq!(got.len(), 1);
    assert_eq!(got[0], want, "artifact != golden for conv3 w16");
}

#[test]
fn hwce_conv5_w4_matches_golden() {
    let Some(mut rt) = runtime() else { return };
    let meta = rt.meta("hwce_conv5_w4").expect("artifact missing").clone();
    let x = rnd_tensor(meta.input_shapes[0].clone(), 21, -2048, 2047);
    let w = rnd_tensor(meta.input_shapes[1].clone(), 22, -8, 7);
    let yin = rnd_tensor(meta.input_shapes[2].clone(), 23, -1024, 1023);
    let got = rt.execute("hwce_conv5_w4", &[x.clone(), w.clone(), yin.clone()]).unwrap();
    let want = golden_layer(WeightPrec::W4, 5, meta.qf, &x, &w, &yin);
    assert_eq!(got[0], want, "artifact != golden for conv5 w4");
}

/// Randomized sweep: several seeds through the w4 artifact vs golden.
#[test]
fn hwce_conv5_w4_randomized_sweep() {
    let Some(mut rt) = runtime() else { return };
    let meta = rt.meta("hwce_conv5_w4").unwrap().clone();
    for seed in 0..5u64 {
        let x = rnd_tensor(meta.input_shapes[0].clone(), 100 + seed, -4096, 4095);
        let w = rnd_tensor(meta.input_shapes[1].clone(), 200 + seed, -8, 7);
        let yin = rnd_tensor(meta.input_shapes[2].clone(), 300 + seed, -8192, 8191);
        let got = rt.execute("hwce_conv5_w4", &[x.clone(), w.clone(), yin.clone()]).unwrap();
        let want = golden_layer(WeightPrec::W4, 5, meta.qf, &x, &w, &yin);
        assert_eq!(got[0], want, "seed {seed}");
    }
}

#[test]
fn quickstart_artifact_runs_and_is_deterministic() {
    let Some(mut rt) = runtime() else { return };
    let meta = rt.meta("quickstart_conv_w4").unwrap().clone();
    let inputs: Vec<TensorI16> = meta
        .input_shapes
        .iter()
        .enumerate()
        .map(|(i, s)| rnd_tensor(s.clone(), 31 + i as u64, -8, 7))
        .collect();
    let a = rt.execute("quickstart_conv_w4", &inputs).unwrap();
    let b = rt.execute("quickstart_conv_w4", &inputs).unwrap();
    assert_eq!(a, b);
    assert_eq!(a[0].shape, vec![1, 8, 16, 16]);
}

#[test]
fn resnet20_artifact_executes_with_generated_params() {
    let Some(mut rt) = runtime() else { return };
    let meta = rt.meta("resnet20_cifar_w4").unwrap().clone();
    let x = rnd_tensor(meta.input_shapes[0].clone(), 9, -2048, 2047);
    let mut inputs = vec![x];
    inputs.extend(gen_params(&meta.input_shapes[1..], 4, 1));
    let out = rt.execute("resnet20_cifar_w4", &inputs).unwrap();
    assert_eq!(out[0].shape, vec![1, 10]);
    assert!(out[0].data.iter().any(|&v| v != 0), "logits all zero");
    let out2 = rt.execute("resnet20_cifar_w4", &inputs).unwrap();
    assert_eq!(out, out2);
}

/// Different inputs produce different logits (the network is not constant).
#[test]
fn resnet20_sensitive_to_input() {
    let Some(mut rt) = runtime() else { return };
    let meta = rt.meta("resnet20_cifar_w4").unwrap().clone();
    let params = gen_params(&meta.input_shapes[1..], 4, 1);
    let mut run = |seed: u64| {
        let x = rnd_tensor(meta.input_shapes[0].clone(), seed, -2048, 2047);
        let mut inputs = vec![x];
        inputs.extend(params.clone());
        rt.execute("resnet20_cifar_w4", &inputs).unwrap()[0].clone()
    };
    assert_ne!(run(1), run(2));
}

#[test]
fn facedet_artifacts_execute() {
    let Some(mut rt) = runtime() else { return };
    for name in ["facedet_12net_w4", "facedet_24net_w4"] {
        let meta = rt.meta(name).unwrap().clone();
        let x = rnd_tensor(meta.input_shapes[0].clone(), 51, -2048, 2047);
        let mut inputs = vec![x];
        inputs.extend(gen_params(&meta.input_shapes[1..], 4, 2));
        let out = rt.execute(name, &inputs).unwrap();
        assert_eq!(out[0].shape, vec![16, 2], "{name}");
    }
}

#[test]
fn shape_validation_rejects_bad_inputs() {
    let Some(mut rt) = runtime() else { return };
    let bad = vec![TensorI16::zeros(vec![1, 1, 4, 4])];
    assert!(rt.execute("hwce_conv3_w16", &bad).is_err());
}

#[test]
fn all_manifest_artifacts_compile() {
    let Some(mut rt) = runtime() else { return };
    let names: Vec<String> = rt.artifact_names().iter().map(|s| s.to_string()).collect();
    assert!(names.len() >= 6, "expected ≥6 artifacts, got {names:?}");
    for n in names {
        rt.compile(&n).unwrap_or_else(|e| panic!("compile {n}: {e}"));
    }
}
