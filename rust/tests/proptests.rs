//! Property-based tests over coordinator/substrate invariants. The offline
//! crate set has no `proptest`, so this uses a seeded-exploration harness:
//! each property is checked over a few hundred pseudo-random cases with
//! shrink-free but reproducible seeds (failures print the seed).

use fulmine::cluster::tcdm::{Tcdm, N_MASTERS};
use fulmine::crypto::keccak::{self, State};
use fulmine::crypto::modes::{self, XtsKey};
use fulmine::crypto::sponge::{ae_decrypt, ae_encrypt, sponge_decrypt, sponge_encrypt, SpongeConfig};
use fulmine::fixedpoint::{clip, norm_round, sat16, writeback};
use fulmine::hwce::golden::{pack_interleaved, unpack_interleaved, WeightPrec};
use fulmine::hwce::timing::simulate_tile_cycles;
use fulmine::hwce::HwceJob;

struct Rng(u64);

impl Rng {
    fn new(seed: u64) -> Self {
        Rng(seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1)
    }
    fn next(&mut self) -> u64 {
        self.0 ^= self.0 << 13;
        self.0 ^= self.0 >> 7;
        self.0 ^= self.0 << 17;
        self.0
    }
    fn range(&mut self, lo: i64, hi: i64) -> i64 {
        lo + (self.next() % (hi - lo + 1) as u64) as i64
    }
    fn bytes(&mut self, n: usize) -> Vec<u8> {
        (0..n).map(|_| self.next() as u8).collect()
    }
    fn key(&mut self) -> [u8; 16] {
        let mut k = [0u8; 16];
        for b in k.iter_mut() {
            *b = self.next() as u8;
        }
        k
    }
}

/// XTS roundtrip holds for every length ≥ 16 (including ciphertext-stealing
/// tails) and every sector.
#[test]
fn prop_xts_roundtrip() {
    for seed in 0..200u64 {
        let mut r = Rng::new(seed);
        let key = XtsKey::new(&r.key(), &r.key());
        let len = r.range(16, 700) as usize;
        let sector = r.next() as u128;
        let pt = r.bytes(len);
        let ct = modes::xts_encrypt(&key, sector, &pt);
        assert_eq!(ct.len(), pt.len(), "seed {seed}");
        assert_ne!(ct, pt, "seed {seed}");
        assert_eq!(modes::xts_decrypt(&key, sector, &ct), pt, "seed {seed}");
    }
}

/// XTS never maps two different plaintexts to the same ciphertext under the
/// same key/sector (injectivity smoke) and different sectors give different
/// ciphertexts for the same plaintext.
#[test]
fn prop_xts_sector_separation() {
    for seed in 0..100u64 {
        let mut r = Rng::new(7000 + seed);
        let key = XtsKey::new(&r.key(), &r.key());
        let pt = r.bytes(64);
        let s1 = r.next() as u128;
        let s2 = s1.wrapping_add(1 + (r.next() % 1000) as u128);
        assert_ne!(
            modes::xts_encrypt(&key, s1, &pt),
            modes::xts_encrypt(&key, s2, &pt),
            "seed {seed}"
        );
    }
}

/// Sponge stream cipher: roundtrip at every byte-aligned rate and length.
#[test]
fn prop_sponge_roundtrip() {
    for seed in 0..100u64 {
        let mut r = Rng::new(100 + seed);
        let rate = [8u32, 16, 32, 64, 128][(r.next() % 5) as usize];
        let rounds = [3usize, 6, 9, 12, 20][(r.next() % 5) as usize];
        let cfg = SpongeConfig { rate_bits: rate, rounds };
        let key = r.key();
        let iv = r.key();
        let n = r.range(0, 500) as usize;
        let pt = r.bytes(n);
        let ct = sponge_encrypt(cfg, &key, &iv, &pt);
        assert_eq!(sponge_decrypt(cfg, &key, &iv, &ct), pt, "seed {seed}");
    }
}

/// Authenticated encryption: any single-bit flip in ciphertext or tag is
/// detected.
#[test]
fn prop_ae_tamper_detection() {
    for seed in 0..60u64 {
        let mut r = Rng::new(500 + seed);
        let key = r.key();
        let iv = r.key();
        let n = r.range(1, 300) as usize;
        let pt = r.bytes(n);
        let (mut ct, mut tag) = ae_encrypt(SpongeConfig::MAX_RATE, &key, &iv, &pt);
        // flip one random bit in ct or tag
        if !ct.is_empty() && r.next() % 2 == 0 {
            let pos = (r.next() as usize) % ct.len();
            ct[pos] ^= 1 << (r.next() % 8);
        } else {
            let pos = (r.next() as usize) % tag.len();
            tag[pos] ^= 1 << (r.next() % 8);
        }
        assert_eq!(
            ae_decrypt(SpongeConfig::MAX_RATE, &key, &iv, &ct, &tag),
            None,
            "seed {seed}: tampering must be detected"
        );
    }
}

/// Keccak-f[400] is a bijection on a sampled subspace: distinct inputs map
/// to distinct outputs (collision would contradict permutation-ness).
#[test]
fn prop_keccak_injective_on_sample() {
    use std::collections::HashSet;
    let mut seen = HashSet::new();
    for seed in 0..300u64 {
        let mut r = Rng::new(900 + seed);
        let mut st = State::zero();
        for l in st.lanes.iter_mut() {
            *l = r.next() as u16;
        }
        keccak::permute(&mut st);
        assert!(seen.insert(st.to_bytes().to_vec()), "collision at seed {seed}");
    }
}

/// TCDM round-robin arbitration: single grant per bank per cycle, and no
/// master starves under arbitrary persistent contention patterns.
#[test]
fn prop_tcdm_fairness() {
    for seed in 0..50u64 {
        let mut r = Rng::new(1300 + seed);
        let mut t = Tcdm::new();
        let n_masters = r.range(2, 6) as usize;
        let bank_of: Vec<u32> = (0..n_masters).map(|_| (r.next() % 8) as u32 * 4).collect();
        let mut grants = vec![0u32; n_masters];
        let rounds = 400;
        for _ in 0..rounds {
            for m in 0..n_masters {
                t.request(m, bank_of[m]);
            }
            let g = t.arbitrate();
            for m in 0..n_masters {
                if g[m] {
                    grants[m] += 1;
                }
            }
        }
        // every master makes progress proportional to contention on its bank
        for m in 0..n_masters {
            let sharers = bank_of.iter().filter(|&&b| b == bank_of[m]).count() as u32;
            let expected = rounds / sharers;
            assert!(
                grants[m] >= expected - 2 && grants[m] <= expected + 2,
                "seed {seed}: master {m} got {} of expected {expected}",
                grants[m]
            );
        }
        assert!(N_MASTERS >= n_masters);
    }
}

/// Fixed-point writeback: equals the reference formula and is monotone.
#[test]
fn prop_writeback_reference_and_monotone() {
    for seed in 0..500u64 {
        let mut r = Rng::new(1700 + seed);
        let acc = r.range(-(1 << 40), 1 << 40);
        let qf = r.range(0, 15) as u8;
        let got = writeback(acc, qf);
        // reference: floor((acc + half) / 2^qf), saturated
        let half = if qf == 0 { 0 } else { 1i64 << (qf - 1) };
        let want = sat16((acc + half) >> qf);
        assert_eq!(got, want, "seed {seed}");
        // monotonicity in acc
        assert!(writeback(acc + 1, qf) >= got, "seed {seed}");
        let _ = (norm_round(acc, qf), clip(acc as i32, 16));
    }
}

/// Interleaved weight-buffer pack/unpack is the identity for in-range
/// weights at every precision.
#[test]
fn prop_weight_interleave_roundtrip() {
    for seed in 0..200u64 {
        let mut r = Rng::new(2100 + seed);
        let prec = [WeightPrec::W16, WeightPrec::W8, WeightPrec::W4][(r.next() % 3) as usize];
        let k = if r.next() % 2 == 0 { 3 } else { 5 };
        let (lo, hi) = prec.range();
        let wts: Vec<Vec<i16>> = (0..prec.simd())
            .map(|_| (0..k * k).map(|_| r.range(lo as i64, hi as i64) as i16).collect())
            .collect();
        let refs: Vec<&[i16]> = wts.iter().map(|v| v.as_slice()).collect();
        let packed = pack_interleaved(prec, k, &refs);
        assert_eq!(unpack_interleaved(prec, k, &packed), wts, "seed {seed} {prec:?}");
    }
}

/// HWCE detailed timing: cycles are monotone in tile size and bounded below
/// by the datapath/bandwidth structural limits.
#[test]
fn prop_hwce_timing_monotone_and_bounded() {
    for seed in 0..40u64 {
        let mut r = Rng::new(2500 + seed);
        let k = if r.next() % 2 == 0 { 3 } else { 5 };
        let prec = [WeightPrec::W16, WeightPrec::W8, WeightPrec::W4][(r.next() % 3) as usize];
        let w = r.range(k as i64 + 3, 40) as usize;
        let h = r.range(k as i64 + 3, 40) as usize;
        let job = HwceJob { w, h, k, prec, qf: 8 };
        let big = HwceJob { w: w + 4, h: h + 4, k, prec, qf: 8 };
        let c1 = simulate_tile_cycles(job);
        let c2 = simulate_tile_cycles(big);
        assert!(c2 > c1, "seed {seed}: {c2} !> {c1}");
        // lower bound: one cycle per datapath position
        assert!(c1 >= job.positions() as u64, "seed {seed}");
    }
}

/// Tile-share arithmetic (the coordinator's TCDM tiling): shares always
/// partition the total exactly, and no two shares differ by more than one
/// byte/op — what keeps per-tile energy attribution lossless.
#[test]
fn prop_tile_shares_partition_exactly() {
    use fulmine::coordinator::{share, share64};
    for seed in 0..300u64 {
        let mut r = Rng::new(4200 + seed);
        let total = r.range(0, 10_000_000) as usize;
        let n = r.range(1, 64) as usize;
        let shares: Vec<usize> = (0..n).map(|t| share(total, n, t)).collect();
        assert_eq!(shares.iter().sum::<usize>(), total, "seed {seed}");
        let (lo, hi) =
            (shares.iter().min().unwrap(), shares.iter().max().unwrap());
        assert!(hi - lo <= 1, "seed {seed}: uneven shares {lo}..{hi}");
        let total64 = r.range(0, 4_000_000_000) as u64;
        let sum64: u64 = (0..n as u64).map(|t| share64(total64, n as u64, t)).sum();
        assert_eq!(sum64, total64, "seed {seed}");
    }
}

/// ECB determinism/pattern-leak property (the §II-B motivation): equal
/// blocks ⇒ equal ciphertext blocks in ECB, never in XTS (same sector,
/// different block index).
#[test]
fn prop_ecb_leaks_xts_hides() {
    for seed in 0..60u64 {
        let mut r = Rng::new(3000 + seed);
        let k = r.key();
        let block = r.bytes(16);
        let pt = [block.clone(), block.clone()].concat();
        let ecb = modes::ecb_encrypt(&k, &pt);
        assert_eq!(ecb[..16], ecb[16..32], "seed {seed}");
        let xts = modes::xts_encrypt(&XtsKey::xex(&k), r.next() as u128, &pt);
        assert_ne!(xts[..16], xts[16..32], "seed {seed}");
    }
}
