//! Cross-module integration tests: VM kernels vs HWCE golden model, crypto
//! through external-memory devices, pipeline composition, report generation,
//! and failure injection across module boundaries.

use fulmine::apps::eeg;
use fulmine::cluster::dma::{Dma, Transfer};
use fulmine::cluster::event_unit::EventUnit;
use fulmine::coordinator::{surveillance, ExecConfig, GraphBuilder};
use fulmine::soc::sched::Scheduler;
use fulmine::crypto::modes::XtsKey;
use fulmine::crypto::sponge::{ae_decrypt, ae_encrypt, SpongeConfig};
use fulmine::energy::Category;
use fulmine::extmem::{Device, ExtMem};
use fulmine::hwce::golden::{conv_multi, WeightPrec};
use fulmine::hwce::{Hwce, HwceJob};
use fulmine::hwcrypt::{CipherOp, Hwcrypt};
use fulmine::isa::vm::Machine;
use fulmine::kernels_sw::conv::{read_output, run_conv, stage_tile, ConvImpl, ConvJob};

fn rnd(n: usize, seed: u64, range: i16) -> Vec<i16> {
    let mut x = seed | 1;
    (0..n)
        .map(|_| {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            ((x % (2 * range as u64 + 1)) as i64 - range as i64) as i16
        })
        .collect()
}

/// The VM software kernels and the HWCE golden model implement the same
/// fixed-point semantics — outputs must be bit-identical.
#[test]
fn vm_conv_matches_hwce_golden() {
    let job = ConvJob { w: 24, h: 16, k: 5, qf: 8, x_base: 0, w_base: 0x8000, y_base: 0x9000 };
    let x = rnd(job.w * job.h, 3, 800);
    let wts = rnd(25, 4, 800);

    for imp in [ConvImpl::Naive, ConvImpl::Simd] {
        let mut m = Machine::new();
        stage_tile(&mut m, job, &x, &wts, imp);
        run_conv(&mut m, job, imp, 4);
        let vm_out = read_output(&m, job);

        let mut y = vec![vec![0i16; job.ow() * job.oh()]];
        conv_multi(WeightPrec::W16, 5, job.w, job.h, job.qf, &x, &[&wts], &mut y);
        assert_eq!(vm_out, y[0], "{imp:?} disagrees with golden");
    }
}

/// Full secure round trip through the external-memory device model:
/// tensor -> XTS sectors in FRAM -> decrypt -> bit-identical tensor; energy
/// is charged for the traffic.
#[test]
fn secure_extmem_roundtrip_with_energy() {
    let key = XtsKey::new(&[7; 16], &[8; 16]);
    let mut fram = ExtMem::new(Device::Fram);
    let mut ledger = fulmine::energy::EnergyLedger::new();
    let tensor: Vec<u8> = (0..8192).map(|i| (i * 13 % 251) as u8).collect();
    fram.store_encrypted(&key, 512, &tensor, Some(&mut ledger));
    let back = fram.load_decrypted(&key, 512, tensor.len(), Some(&mut ledger));
    assert_eq!(back, tensor);
    assert!(ledger.energy_mj(Category::ExtMem) > 0.0);
}

/// Accelerator device models cooperate through the event unit.
#[test]
fn accelerators_post_events() {
    let mut eu = EventUnit::new();
    let mut hwce = Hwce::new();
    let mut hwcrypt = Hwcrypt::new();
    let t1 = hwce.offload(
        0,
        HwceJob { w: 16, h: 16, k: 3, prec: WeightPrec::W4, qf: 8 },
        Some(&mut eu),
    );
    let t2 = hwcrypt.offload(t1, CipherOp::AesXts, 4096, Some(&mut eu));
    assert!(t2 > t1);
    assert!(eu.take(fulmine::cluster::event_unit::Event::HwceDone));
    assert!(eu.take(fulmine::cluster::event_unit::Event::HwcryptDone));
}

/// DMA double-buffering: a staged pipeline where transfers overlap compute
/// finishes sooner than a strictly serial one.
#[test]
fn dma_overlap_beats_serial() {
    let mut dma = Dma::new();
    let tile = Transfer::d2(256, 16);
    let compute_per_tile = 6000u64;
    let mut t_overlap = 0u64;
    let (_, mut ready) = dma.issue(0, tile);
    for _ in 0..8 {
        let start = t_overlap.max(ready);
        let (_, r) = dma.issue(start, tile); // prefetch next
        ready = r;
        t_overlap = start + compute_per_tile;
    }
    let mut dma2 = Dma::new();
    let mut t_serial = 0u64;
    for _ in 0..8 {
        let (_, done) = dma2.issue(t_serial, tile);
        t_serial = done + compute_per_tile;
    }
    assert!(t_overlap < t_serial, "{t_overlap} !< {t_serial}");
}

/// End-to-end EEG: detection plus authenticated collection, with MAC
/// failure injection.
#[test]
fn eeg_detect_and_secure_collect() {
    let win = eeg::synth_window(77, true);
    let (seizure, comps) = eeg::detect(&win, 4);
    assert!(seizure);
    let payload: Vec<u8> = comps
        .iter()
        .flat_map(|c| c.iter().map(|&v| (v.clamp(-32768, 32767) as i16)))
        .flat_map(|v| v.to_le_bytes())
        .collect();
    let (ct, tag) = ae_encrypt(SpongeConfig::MAX_RATE, &[1; 16], &[2; 16], &payload);
    assert_eq!(
        ae_decrypt(SpongeConfig::MAX_RATE, &[1; 16], &[2; 16], &ct, &tag),
        Some(payload)
    );
    let mut bad_tag = tag;
    bad_tag[5] ^= 2;
    assert!(ae_decrypt(SpongeConfig::MAX_RATE, &[1; 16], &[2; 16], &ct, &bad_tag).is_none());
}

/// The scheduler must respect mode capabilities: XTS needs the CRY-CNN-SW
/// point, so alternating long conv (KEC point) and cipher phases pays a
/// relock at each genuine frequency change — while the tiny HWCE control
/// stubs ride inside the CRY windows for free — and the SW config never
/// switches at all.
#[test]
fn scheduler_mode_discipline() {
    let mut hw = GraphBuilder::new(ExecConfig::with_hwce(WeightPrec::W16));
    let c1 = hw.conv(1_000_000, 3, &[]);
    let x1 = hw.xts(1024, &[c1]);
    let c2 = hw.conv(1_000_000, 3, &[x1]);
    hw.xts(1024, &[c2]);
    assert_eq!(Scheduler::run(&hw.build()).mode_switches, 3);

    let mut sw = GraphBuilder::new(ExecConfig::sw_1core());
    let c = sw.conv(1_000_000, 3, &[]);
    let x = sw.xts(1024, &[c]);
    sw.sw(1000.0, 1.0, &[x]);
    assert_eq!(Scheduler::run(&sw.build()).mode_switches, 0);
}

/// Pinning the cluster at the all-capable CRY-CNN-SW point (the §IV-A
/// steady state) makes the same conv/cipher chain relock-free, and the
/// cipher runs co-reside with the convolutions.
#[test]
fn cry_point_coresidency_discipline() {
    use fulmine::soc::opmodes::OperatingMode;
    let mut b = GraphBuilder::new(ExecConfig::with_hwce(WeightPrec::W16));
    b.set_cluster_point(OperatingMode::CryCnnSw);
    let c1 = b.conv(1_000_000, 3, &[]);
    b.xts(1024, &[c1]); // no dep on the next conv: free to overlap
    b.conv(1_000_000, 3, &[]);
    let r = Scheduler::run(&b.build());
    assert_eq!(r.mode_switches, 0, "one shared point, no relocks");
    assert!(r.coresidency_s > 0.0, "cipher must overlap convolution");
}

/// Sanity of the full surveillance ladder at a second voltage: the ordering
/// survives DVFS.
#[test]
fn surveillance_ladder_holds_at_1v0() {
    let mut results = Vec::new();
    for rung in ExecConfig::ladder() {
        let mut cfg = rung.cfg;
        cfg.vdd = 1.0;
        let mut r = surveillance::run_frame(cfg);
        r.label = rung.label.to_string();
        results.push(r);
    }
    for i in 1..results.len() {
        assert!(
            results[i].time_s <= results[i - 1].time_s * 1.02,
            "ordering broken at 1.0V rung {i}"
        );
    }
    // higher VDD must be faster but less efficient than 0.8V
    let best08 = surveillance::ladder().pop().unwrap();
    let best10 = results.pop().unwrap();
    assert!(best10.time_s < best08.time_s);
    assert!(best10.energy_mj > best08.energy_mj);
}

/// Report generation end-to-end (every paper artifact renders).
#[test]
fn all_reports_render() {
    let r = fulmine::report::all_reports();
    assert!(r.len() > 4000);
}

/// The streaming report renders for every use case and shows a ≥1×
/// cross-frame speedup.
#[test]
fn stream_reports_render() {
    for usecase in ["surveillance", "facedet", "seizure"] {
        let s = fulmine::report::stream_report(usecase, 4, None)
            .unwrap_or_else(|e| panic!("{usecase}: {e}"));
        assert!(s.contains("frames"), "{usecase}: {s}");
    }
    assert!(fulmine::report::stream_report("nonsense", 4, None).is_err());
}
