//! Integration tests for the first-class workload API: the registry
//! resolves every built-in workload, the `SocSystem` façade reproduces the
//! legacy coordinator paths exactly, rung lookup fails helpfully, the JSON
//! reports parse back and agree with the text tables, and the mixed
//! multi-tenant stream runs end-to-end with per-workload attribution.

use fulmine::coordinator::{facedet, seizure, surveillance, ModeOverrides, UseCaseResult};
use fulmine::json::Json;
use fulmine::system::{RunSpec, RungSel, SocSystem};
use fulmine::workload::{MixedStream, Registry, SeizureDetection, Workload};

#[test]
fn registry_resolves_all_builtin_workloads() {
    let sys = SocSystem::new();
    let names = sys.registry().names();
    assert_eq!(names, vec!["surveillance", "facedet", "seizure", "mixed"]);
    for name in names {
        let w = sys.registry().resolve(name).unwrap();
        assert_eq!(w.name(), name);
        assert!(!w.describe().is_empty());
        assert!(!w.rungs().is_empty());
    }
    let err = sys.registry().resolve("thermostat").unwrap_err().to_string();
    assert!(err.contains("unknown workload") && err.contains("available"), "{err}");
}

/// The façade's ladders must be numerically identical to the direct
/// coordinator entry points the figures were calibrated on.
#[test]
fn facade_ladders_match_legacy_coordinator_paths() {
    let sys = SocSystem::new();
    let legacy: [(&str, Vec<UseCaseResult>); 3] = [
        ("surveillance", surveillance::ladder()),
        ("facedet", facedet::ladder()),
        ("seizure", seizure::ladder()),
    ];
    for (name, legacy_rows) in legacy {
        let rows = sys.ladder(name).unwrap().rows;
        assert_eq!(rows.len(), legacy_rows.len(), "{name}");
        for (a, b) in rows.iter().zip(&legacy_rows) {
            assert_eq!(a.label, b.label, "{name}");
            assert_eq!(a.time_s.to_bits(), b.time_s.to_bits(), "{name}/{}", a.label);
            assert_eq!(a.energy_mj.to_bits(), b.energy_mj.to_bits(), "{name}/{}", a.label);
            assert_eq!(a.pj_per_op.to_bits(), b.pj_per_op.to_bits(), "{name}/{}", a.label);
            assert_eq!(a.eq_ops, b.eq_ops, "{name}/{}", a.label);
        }
    }
}

#[test]
fn rung_lookup_rejects_unknown_rungs_helpfully() {
    let sys = SocSystem::new();
    let err = sys
        .run(&RunSpec::new("surveillance").rung(RungSel::Label("turbo".into())))
        .unwrap_err()
        .to_string();
    assert!(err.contains("no rung matches \"turbo\""), "{err}");
    assert!(err.contains("SW 1-core"), "error should list the ladder: {err}");
    let err = sys
        .run(&RunSpec::new("seizure").rung(RungSel::Index(7)))
        .unwrap_err()
        .to_string();
    assert!(err.contains("out of range (0..3)"), "{err}");
}

/// `--json` agrees with the text tables: the ladder JSON parses back and
/// its energy/pJ-per-op numbers equal the rows that render the text
/// report, for every built-in workload.
#[test]
fn ladder_json_roundtrips_and_matches_text() {
    let sys = SocSystem::new();
    for name in sys.registry().names() {
        let ladder = sys.ladder(name).unwrap();
        let parsed = Json::parse(&ladder.to_json().render()).unwrap();
        assert_eq!(parsed.get("workload").and_then(Json::as_str), Some(name));
        let rungs = parsed.get("rungs").and_then(Json::as_array).unwrap();
        assert_eq!(rungs.len(), ladder.rows.len(), "{name}");
        let text = ladder.render_text();
        for (j, row) in rungs.iter().zip(&ladder.rows) {
            assert_eq!(j.get("label").and_then(Json::as_str), Some(row.label.as_str()));
            let energy = j.get("energy_mj").and_then(Json::as_f64).unwrap();
            let pj = j.get("pj_per_op").and_then(Json::as_f64).unwrap();
            assert_eq!(energy.to_bits(), row.energy_mj.to_bits(), "{name}/{}", row.label);
            assert_eq!(pj.to_bits(), row.pj_per_op.to_bits(), "{name}/{}", row.label);
            // and the text table shows the same numbers (at its precision)
            assert!(
                text.contains(&format!("{:>10.4}", row.energy_mj)),
                "{name}/{}: {text}",
                row.label
            );
            assert!(
                text.contains(&format!("{:>8.2}", row.pj_per_op)),
                "{name}/{}: {text}",
                row.label
            );
        }
    }
}

#[test]
fn stream_json_roundtrips_and_matches_report() {
    let sys = SocSystem::new();
    for name in ["surveillance", "facedet", "seizure", "mixed"] {
        let run = sys.run(&RunSpec::new(name).frames(3)).unwrap();
        let parsed = Json::parse(&run.to_json().render()).unwrap();
        assert_eq!(parsed.get("workload").and_then(Json::as_str), Some(name));
        assert_eq!(parsed.get("frames").and_then(Json::as_f64), Some(3.0));
        for (key, expect) in [
            ("time_s", run.result.time_s),
            ("fps", run.result.fps),
            ("energy_mj", run.result.energy_mj),
            ("pj_per_op", run.result.pj_per_op),
        ] {
            let got = parsed.get(key).and_then(Json::as_f64).unwrap();
            assert_eq!(got.to_bits(), expect.to_bits(), "{name}.{key}");
        }
        let tenants = parsed.get("tenants").and_then(Json::as_array).unwrap();
        assert_eq!(tenants.len(), run.tenants.len(), "{name}");
        // breakdown totals match the ledger sum
        let breakdown = parsed.get("energy_breakdown_mj").unwrap();
        let total: f64 = ["conv", "crypto", "other-sw", "dma", "ext-mem", "idle"]
            .iter()
            .map(|c| breakdown.get(c).and_then(Json::as_f64).unwrap())
            .sum();
        assert!(
            (total - run.result.energy_mj).abs() < 1e-9 * (1.0 + total),
            "{name}: breakdown {total} vs {}",
            run.result.energy_mj
        );
    }
}

/// Acceptance: the mixed multi-tenant stream runs end-to-end through the
/// scheduler with per-workload pJ/op in its report.
#[test]
fn mixed_stream_runs_with_per_workload_attribution() {
    let sys = SocSystem::new();
    let frames = 4usize;
    let run = sys.run(&RunSpec::new("mixed").frames(frames)).unwrap();
    assert_eq!(run.frames, frames);
    let names: Vec<&str> = run.tenants.iter().map(|t| t.name.as_str()).collect();
    assert_eq!(names, vec!["surveillance", "facedet", "seizure"]);
    for t in &run.tenants {
        assert!(t.eq_ops > 0, "{}", t.name);
        assert!(t.active_mj > 0.0, "{}", t.name);
        assert!(t.energy_mj >= t.active_mj, "{}", t.name);
        assert!(t.pj_per_op.is_finite() && t.pj_per_op > 0.0, "{}", t.name);
    }
    // attributed energy (active + shared idle) adds back up to the total
    let attributed: f64 = run.tenants.iter().map(|t| t.energy_mj).sum();
    assert!(
        (attributed - run.result.energy_mj).abs() < 1e-6 * run.result.energy_mj,
        "attributed {attributed} vs total {}",
        run.result.energy_mj
    );
    // the surveillance tenant dominates (ResNet-20 vs a cascade + a window)
    assert!(run.tenants[0].energy_mj > run.tenants[1].energy_mj);
    assert!(run.tenants[0].energy_mj > run.tenants[2].energy_mj);
    // the text report carries the per-tenant rows
    let text = run.render_text();
    assert!(text.contains("tenant surveillance"), "{text}");
    assert!(text.contains("tenant seizure"), "{text}");
    // streaming a mixed graph is never materially slower than back-to-back
    // rounds (tolerance for the extra FLL relocks at round boundaries)
    assert!(run.result.speedup >= 0.95, "mixed stream speedup {}", run.result.speedup);
}

/// Satellite (fast-forward edge case): per-tenant attribution is
/// window-invariant even when most of the stream is executed by the
/// steady-state replay path — the active rows are identical across
/// windows, the attributed total re-sums to the schedule's energy, and
/// the run report confirms fast-forward actually engaged.
#[test]
fn tenant_attribution_window_invariant_under_fast_forward() {
    let sys = SocSystem::new();
    let frames = 48usize;
    let mut reference: Option<Vec<(String, f64)>> = None;
    let mut engaged = false;
    for window in [2usize, 4, 8] {
        let r = sys.run(&RunSpec::new("mixed").frames(frames).window(window)).unwrap();
        engaged |= r.result.fast_forwarded_frames > 0;
        let attributed: f64 = r.tenants.iter().map(|t| t.energy_mj).sum();
        assert!(
            (attributed - r.result.energy_mj).abs() < 1e-6 * r.result.energy_mj,
            "window {window}: attributed {attributed} vs {}",
            r.result.energy_mj
        );
        let active: Vec<(String, f64)> =
            r.tenants.iter().map(|t| (t.name.clone(), t.active_mj)).collect();
        match &reference {
            None => reference = Some(active),
            Some(base) => {
                for ((n0, a0), (n1, a1)) in base.iter().zip(&active) {
                    assert_eq!(n0, n1);
                    assert_eq!(a0.to_bits(), a1.to_bits(), "{n0}: active energy vs window");
                }
            }
        }
    }
    assert!(engaged, "a 48-frame mixed stream must reach its steady state");
}

/// The registry accepts new workloads: a custom mixed composition streams
/// through the same façade with no other wiring.
#[test]
fn custom_workload_registers_and_runs() {
    let mut registry = Registry::builtin();
    registry.register(Box::new(MixedStream::new(
        "wardroom",
        "two seizure windows per round",
        vec![Box::new(SeizureDetection), Box::new(SeizureDetection)],
    )));
    let sys = SocSystem::with_registry(registry);
    let run = sys.run(&RunSpec::new("wardroom").frames(2)).unwrap();
    assert_eq!(run.workload, "wardroom");
    assert_eq!(run.tenants.len(), 1, "duplicate tenants aggregate by name");
    assert_eq!(run.tenants[0].name, "seizure");
    assert_eq!(run.tenants[0].eq_ops, 2 * SeizureDetection.eq_ops());
    assert!(run.result.energy_mj > 0.0);
}

/// Ablations expressed as mode overrides reproduce the legacy sweep.
#[test]
fn ablation_overrides_reproduce_legacy_configs() {
    use fulmine::coordinator::ExecConfig;
    use fulmine::hwce::golden::WeightPrec;
    let sys = SocSystem::new();
    let spec = RunSpec::new("surveillance")
        .overrides(ModeOverrides { hwcrypt: Some(false), ..Default::default() });
    let via_facade = sys.run_frame(&spec).unwrap();
    let legacy = surveillance::run_frame(ExecConfig {
        hwcrypt: false,
        ..ExecConfig::with_hwce(WeightPrec::W4)
    });
    assert_eq!(via_facade.time_s.to_bits(), legacy.time_s.to_bits());
    assert_eq!(via_facade.energy_mj.to_bits(), legacy.energy_mj.to_bits());
}
