//! Secure-link session properties across the façade (ISSUE 10): a
//! loss-free channel (`--loss 0`) replays bitwise on the live,
//! fast-forwarded and sharded paths; under a seeded lossy channel the
//! retransmission/resumption schedule is deterministic across runs and
//! shard splits; fast-forward suspends around handshake and
//! retransmission frames yet re-engages on the steady record phase,
//! bitwise equal to live dispatch; and the three recovery policies
//! diverge exactly as designed once outages fire.
//!
//! Counts asserted exactly below were pre-computed from the seeded
//! channel tables (each draw depends only on `(model, frame)`), so they
//! are properties of the chosen seeds, not of luck.

use fulmine::coordinator::StreamResult;
use fulmine::energy::Category;
use fulmine::json::Json;
use fulmine::session::{SessionModel, SessionPlan, SessionRecovery};
use fulmine::soc::sched::{SchedResult, StreamScheduler};
use fulmine::system::{RunSpec, SocSystem};
use fulmine::traffic::Traffic;
use fulmine::workload::{frame_graph, Registry};

fn lossy(loss_rate: f64) -> SessionModel {
    SessionModel { loss_rate, seed: 7 }
}

fn assert_stream_bitwise_eq(a: &StreamResult, b: &StreamResult, ctx: &str) {
    for (field, x, y) in [
        ("time_s", a.time_s, b.time_s),
        ("fps", a.fps, b.fps),
        ("energy_mj", a.energy_mj, b.energy_mj),
        ("pj_per_op", a.pj_per_op, b.pj_per_op),
        ("overlap_s", a.overlap_s, b.overlap_s),
        ("recovery_energy_mj", a.recovery_energy_mj, b.recovery_energy_mj),
    ] {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: {field} {x} vs {y}");
    }
    assert_eq!(a.total_jobs, b.total_jobs, "{ctx}");
    assert_eq!(a.fast_forwarded_frames, b.fast_forwarded_frames, "{ctx}");
    assert_eq!(a.frames_dropped, b.frames_dropped, "{ctx}");
    assert_eq!(a.fault_retries, b.fault_retries, "{ctx}");
    for c in Category::all() {
        assert_eq!(
            a.ledger.energy_mj(c).to_bits(),
            b.ledger.energy_mj(c).to_bits(),
            "{ctx}: ledger {c:?}"
        );
    }
}

fn assert_sched_bitwise_eq(a: &SchedResult, b: &SchedResult, ctx: &str) {
    assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits(), "{ctx}: makespan");
    assert_eq!(a.overlap_s.to_bits(), b.overlap_s.to_bits(), "{ctx}: overlap");
    assert_eq!(a.n_jobs, b.n_jobs, "{ctx}: n_jobs");
    assert_eq!(a.mode_switches, b.mode_switches, "{ctx}: mode_switches");
    for (i, (x, y)) in a.busy_s.iter().zip(&b.busy_s).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: busy_s[{i}]");
    }
    for c in Category::all() {
        assert_eq!(
            a.ledger.energy_mj(c).to_bits(),
            b.ledger.energy_mj(c).to_bits(),
            "{ctx}: ledger {c:?}"
        );
    }
}

/// Acceptance (loss-free identity): `--loss 0` routes through the whole
/// session machinery — frame-0 handshake variant, plan stats, report
/// plumbing — yet delivers every record first try, and the live,
/// fast-forwarded and sharded paths each replay the identical spec
/// bitwise with identical session counters.
#[test]
fn lossless_channel_replays_bitwise_on_live_ff_and_sharded_paths() {
    let sys = SocSystem::new();
    let frames = 64usize;
    let spec = |window: usize, shards: usize| {
        let mut s = RunSpec::new("secure_link")
            .frames(frames)
            .shards(shards)
            .loss(Some(SessionModel::lossless()));
        if window > 0 {
            s = s.window(window);
        }
        s
    };
    let mut sessions = Vec::new();
    for (window, shards, label) in [(frames, 1, "live"), (4, 1, "fast-forwarded"), (0, 2, "sharded")]
    {
        let a = sys.run(&spec(window, shards)).unwrap();
        let b = sys.run(&spec(window, shards)).unwrap();
        assert_stream_bitwise_eq(&a.result, &b.result, label);
        let ss = a.session.expect("a channel was configured");
        assert_eq!(ss.full_handshakes, 1, "{label}: exactly the frame-0 negotiation");
        assert_eq!(ss.resumptions, 0, "{label}");
        assert_eq!(ss.retransmissions, 0, "{label}: a perfect channel never re-sends");
        assert_eq!(ss.records_dropped, 0, "{label}");
        assert_eq!(a.result.frames_dropped, 0, "{label}");
        assert_eq!(a.result.availability(), 1.0, "{label}");
        sessions.push(ss);
    }
    // the session stats come from the one global plan: path-invariant
    assert_eq!(sessions[0], sessions[1], "live vs fast-forwarded session stats");
    assert_eq!(sessions[0], sessions[2], "live vs sharded session stats");
    // the small window really exercised the replay machinery: the
    // handshake variant at frame 0 must not wedge fast-forward
    let ff = sys.run(&spec(4, 1)).unwrap();
    assert!(
        ff.result.fast_forwarded_frames > 0,
        "a 64-frame loss-free stream at window 4 must reach steady state"
    );
}

/// Satellite (ff suspend/re-engage): on a gap-dominated lossy stream the
/// fast-forward path suspends on every handshake/retransmission frame,
/// re-engages on the steady record phase between them, and stays bitwise
/// equal to live dispatch — per recovery policy.
#[test]
fn lossy_stream_fast_forward_reengages_bitwise_with_live() {
    let reg = Registry::builtin();
    let w = reg.resolve("secure_link").unwrap();
    let rung = w.rungs().into_iter().last().expect("secure_link has rungs");
    let g = frame_graph(w, rung.cfg).unwrap();
    let frames = 256usize;
    let rel = Traffic::Periodic { rate_hz: 2.0 }.release_times(frames);
    let model = lossy(0.1);
    for recovery in SessionRecovery::all() {
        // seed 7, loss 0.1 over frames 0..256: 20 variant frames
        // (handshake + retransmissions), 19 retransmissions, no outages
        let plan = SessionPlan::build(&model, recovery, &g, 0, frames).unwrap();
        assert_eq!(plan.variants.len(), 20, "{recovery:?}");
        assert_eq!(plan.stats.retransmissions, 19, "{recovery:?}");
        assert_eq!(plan.stats.records_dropped, 0, "{recovery:?}");
        let vats = plan.variant_refs();
        let live =
            StreamScheduler::run_with_variants_traffic_live_pm(&g, frames, 8, &vats, &rel, None);
        let ff = StreamScheduler::run_with_variants_traffic_pm(&g, frames, 8, &vats, &rel, None);
        assert_sched_bitwise_eq(&ff, &live, &format!("{recovery:?}"));
        assert!(
            ff.fast_forwarded_frames > 0,
            "{recovery:?}: replay must re-engage on the steady record phase"
        );
        assert!(
            ff.fast_forwarded_frames <= frames - plan.variants.len(),
            "{recovery:?}: variant frames can never be replayed"
        );
    }
}

/// Under a seeded lossy channel the whole report — retransmission and
/// resumption schedule included — is deterministic across repeated runs
/// and shard splits, and the session counters are exactly
/// shard-invariant.
#[test]
fn seeded_lossy_runs_are_deterministic_across_runs_and_shards() {
    let sys = SocSystem::new();
    let base = sys
        .run(&RunSpec::new("secure_link").frames(128).loss(Some(lossy(0.6))))
        .unwrap();
    let base_ss = base.session.expect("a channel was configured");
    assert!(base_ss.retransmissions > 0);
    for shards in [1usize, 2, 4] {
        let spec = || {
            RunSpec::new("secure_link")
                .frames(128)
                .shards(shards)
                .loss(Some(lossy(0.6)))
        };
        let a = sys.run(&spec()).unwrap();
        let b = sys.run(&spec()).unwrap();
        assert_stream_bitwise_eq(&a.result, &b.result, &format!("shards {shards}"));
        assert_eq!(
            a.to_json().render(),
            b.to_json().render(),
            "shards {shards}: reports must replay bitwise"
        );
        let ss = a.session.expect("a channel was configured");
        assert_eq!(ss, base_ss, "shards {shards}: session schedule is shard-invariant");
        assert_eq!(a.result.frames_dropped, base.result.frames_dropped, "shards {shards}");
        assert_eq!(a.result.fault_retries, base.result.fault_retries, "shards {shards}");
    }
}

/// Acceptance (recovery-policy divergence): at loss 0.6 (seed 7) over
/// 256 frames, outages fire and the policies answer as designed — full
/// renegotiates (5 full handshakes), resume replays abbreviated
/// handshakes (4 resumptions), degrade drops records while the link is
/// down (8 drops, no recovery handshakes) instead of stalling.
#[test]
fn outage_recovery_policies_diverge_as_designed() {
    let sys = SocSystem::new();
    let run = |recovery: SessionRecovery| {
        sys.run(
            &RunSpec::new("secure_link")
                .frames(256)
                .loss(Some(lossy(0.6)))
                .session_recovery(recovery),
        )
        .unwrap()
    };
    let full = run(SessionRecovery::FullHandshake);
    let resume = run(SessionRecovery::Resume);
    let degrade = run(SessionRecovery::Degrade);
    let (fs, rs, ds) = (
        full.session.unwrap(),
        resume.session.unwrap(),
        degrade.session.unwrap(),
    );
    // seed 7, loss 0.6, frames 0..256: 4 outages
    assert_eq!((fs.full_handshakes, fs.resumptions, fs.records_dropped), (5, 0, 4));
    assert_eq!((rs.full_handshakes, rs.resumptions, rs.records_dropped), (1, 4, 4));
    assert_eq!((ds.full_handshakes, ds.resumptions, ds.records_dropped), (1, 0, 8));
    assert_eq!(fs.retransmissions, 404);
    assert_eq!(rs.retransmissions, 379);
    assert_eq!(ds.retransmissions, 370);
    // availability is the records that made it
    assert_eq!(resume.result.availability(), (256.0 - 4.0) / 256.0);
    assert!(degrade.result.availability() < resume.result.availability());
    // renegotiating from scratch pays the ECC flights resume skips
    assert!(
        fs.handshake_mj > rs.handshake_mj,
        "full {} vs resume {}",
        fs.handshake_mj,
        rs.handshake_mj
    );
    // everyone pays retransmission overhead energy
    for (label, r) in [("full", &full), ("resume", &resume), ("degrade", &degrade)] {
        assert!(r.result.recovery_energy_mj > 0.0, "{label}");
        assert!(r.result.availability() < 1.0, "{label}");
    }
    // the session block surfaces in both renderings
    let text = resume.render_text();
    assert!(text.contains("secure link:"), "{text}");
    assert!(text.contains("resumption"), "{text}");
    let json = Json::parse(&resume.to_json().render()).unwrap();
    let sess = json.get("session").expect("session object in JSON");
    let retx = sess.get("retransmissions").and_then(Json::as_f64).unwrap();
    assert_eq!(retx as u64, rs.retransmissions);
    let goodput = sess.get("goodput_fps").and_then(Json::as_f64).unwrap();
    assert!(goodput > 0.0);
}
