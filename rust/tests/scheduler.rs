//! Scheduler invariants (seeded-exploration style — the offline crate set
//! has no `proptest`; failures print the seed):
//!
//! * resource sanity: per-engine busy time never exceeds the makespan, and
//!   total busy time never exceeds makespan × engine count;
//! * calibration: the scheduled use cases stay within 5 % of the analytic
//!   phase-summation model (per energy category and in pJ/op) on every
//!   ladder rung — the contract that keeps the Fig. 10/11/12 reports
//!   faithful;
//! * streaming: N frames through the scheduler are never slower than N
//!   back-to-back single-frame runs, and genuinely faster where the frame
//!   graph leaves engine stalls to fill.

use fulmine::coordinator::{facedet, seizure, surveillance, ExecConfig, GraphBuilder};
use fulmine::energy::Category;
use fulmine::extmem::Device;
use fulmine::soc::sched::{Engine, JobGraph, JobId, Scheduler, N_ENGINES};

struct Rng(u64);

impl Rng {
    fn new(seed: u64) -> Self {
        Rng(seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1)
    }
    fn next(&mut self) -> u64 {
        self.0 ^= self.0 << 13;
        self.0 ^= self.0 >> 7;
        self.0 ^= self.0 << 17;
        self.0
    }
    fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.next() % (hi - lo + 1)
    }
}

/// A random but well-formed job graph: random phase kinds, random
/// dependencies on earlier jobs, a ladder-sampled configuration.
fn random_graph(seed: u64) -> JobGraph {
    let mut r = Rng::new(seed);
    let ladder = ExecConfig::ladder();
    let cfg = ladder[(r.next() % ladder.len() as u64) as usize].cfg;
    let mut b = GraphBuilder::new(cfg);
    // keep ext-mem standby out so scheduled and analytic ledgers may only
    // differ in the Idle category
    b.set_ext_mem_present(false);
    let n_jobs = r.range(3, 40) as usize;
    let mut ids: Vec<JobId> = Vec::new();
    for _ in 0..n_jobs {
        let mut deps: Vec<JobId> = Vec::new();
        for _ in 0..r.range(0, 2) {
            if !ids.is_empty() {
                deps.push(ids[(r.next() % ids.len() as u64) as usize]);
            }
        }
        deps.sort_unstable();
        deps.dedup();
        let id = match r.next() % 6 {
            0 => b.conv(r.range(10_000, 5_000_000), if r.next() % 2 == 0 { 3 } else { 5 }, &deps),
            1 => b.xts(r.range(64, 100_000) as usize, &deps),
            2 => b.sponge_ae(r.range(64, 100_000) as usize, &deps),
            3 => b.sw(r.range(1_000, 2_000_000) as f64, 1.0, &deps),
            4 => b.dma(r.range(64, 200_000) as usize, &deps),
            _ => {
                let dev = if r.next() % 2 == 0 { Device::Flash } else { Device::Fram };
                b.extmem(dev, r.range(64, 200_000) as usize, &deps)
            }
        };
        ids.push(id);
    }
    b.build()
}

const ACTIVE_CATEGORIES: [Category; 5] = [
    Category::Conv,
    Category::Crypto,
    Category::OtherSw,
    Category::Dma,
    Category::ExtMem,
];

/// (a) Engine-busy accounting: each engine's busy time is bounded by the
/// makespan, and the total by makespan × engine count; runs are
/// deterministic.
#[test]
fn prop_engine_busy_bounded() {
    for seed in 0..60u64 {
        let g = random_graph(seed);
        let r = Scheduler::run(&g);
        for e in Engine::ALL {
            assert!(
                r.busy_s[e.index()] <= r.makespan_s + 1e-9,
                "seed {seed}: {} busy {} > makespan {}",
                e.name(),
                r.busy_s[e.index()],
                r.makespan_s
            );
        }
        let total: f64 = r.busy_s.iter().sum();
        assert!(
            total <= r.makespan_s * N_ENGINES as f64 + 1e-9,
            "seed {seed}: total busy {total} > {} x makespan {}",
            N_ENGINES,
            r.makespan_s
        );
        let again = Scheduler::run(&g);
        assert_eq!(r.makespan_s.to_bits(), again.makespan_s.to_bits(), "seed {seed}");
        assert_eq!(r.mode_switches, again.mode_switches, "seed {seed}");
    }
}

/// Active energy is schedule-independent: scheduled and analytic runs of
/// the same graph charge identical Conv/Crypto/OtherSw/Dma/ExtMem energy
/// (only Idle tracks the makespan).
#[test]
fn prop_active_energy_schedule_independent() {
    for seed in 0..60u64 {
        let g = random_graph(1000 + seed);
        let run = Scheduler::run(&g);
        let ana = g.analytic();
        for cat in ACTIVE_CATEGORIES {
            let a = run.ledger.energy_mj(cat);
            let b = ana.ledger.energy_mj(cat);
            assert!(
                (a - b).abs() <= 1e-9 * (1.0 + a.abs().max(b.abs())),
                "seed {seed} {cat:?}: scheduled {a} != analytic {b}"
            );
        }
    }
}

/// (b) Calibration contract: on every ladder rung of every use case the
/// scheduled energy matches the analytic phase-summation model within 5 %
/// per active category and in total, pJ/op within 5 %, and the makespan
/// stays in the band explained by exposed I/O dependencies.
#[test]
fn usecase_energy_within_5pct_of_analytic() {
    let mut cases: Vec<(String, JobGraph)> = Vec::new();
    for rung in ExecConfig::ladder() {
        let (label, cfg) = (rung.label, rung.cfg);
        cases.push((format!("surveillance/{label}"), surveillance::frame_graph(cfg)));
        cases.push((format!("facedet/{label}"), facedet::frame_graph(cfg)));
    }
    for rung in seizure::rung_configs() {
        cases.push((format!("seizure/{}", rung.label), seizure::window_graph(rung.cfg)));
    }
    for (label, g) in cases {
        let run = Scheduler::run(&g);
        let ana = g.analytic();
        for cat in ACTIVE_CATEGORIES {
            let a = run.ledger.energy_mj(cat);
            let b = ana.ledger.energy_mj(cat);
            if b > 1e-9 {
                let rel = (a - b).abs() / b;
                assert!(rel < 0.05, "{label} {cat:?}: {a} vs {b} ({rel:.4})");
            }
        }
        let (ta, tb) = (run.ledger.total_mj(), ana.ledger.total_mj());
        assert!((ta - tb).abs() / tb < 0.05, "{label} total: {ta} vs {tb}");
        let ratio = run.makespan_s / ana.makespan_s;
        assert!(
            (0.9..1.6).contains(&ratio),
            "{label}: scheduled/analytic makespan ratio {ratio:.3}"
        );
        assert_eq!(run.mode_switches, ana.mode_switches, "{label} switch count");
    }
}

/// pJ/op parity between the scheduled and analytic paths, across all use
/// cases and rungs (the headline acceptance number).
#[test]
fn usecase_pj_per_op_within_5pct() {
    for rung in ExecConfig::ladder() {
        let (label, cfg) = (rung.label, rung.cfg);
        for (case, sched, ana) in [
            (
                "surveillance",
                surveillance::run_frame(cfg).pj_per_op,
                surveillance::run_frame_analytic(cfg).pj_per_op,
            ),
            (
                "facedet",
                facedet::run_frame(cfg).pj_per_op,
                facedet::run_frame_analytic(cfg).pj_per_op,
            ),
        ] {
            let rel = (sched - ana).abs() / ana;
            assert!(rel < 0.05, "{case}/{label}: {sched} vs {ana} ({rel:.4})");
        }
    }
    for rung in seizure::rung_configs() {
        let sched = seizure::run_window(rung.cfg).pj_per_op;
        let ana = seizure::run_window_analytic(rung.cfg).pj_per_op;
        let rel = (sched - ana).abs() / ana;
        assert!(rel < 0.05, "seizure/{}: {sched} vs {ana} ({rel:.4})", rung.label);
    }
}

/// (c) Streaming N frames is never slower than N back-to-back single
/// frames (small tolerance for the extra FLL relock at each frame
/// boundary, which back-to-back runs get for free).
#[test]
fn streaming_never_slower_than_serial() {
    let frames = 4usize;
    let mut cases: Vec<(String, JobGraph)> = Vec::new();
    for idx in [0usize, 2, 4] {
        let rung = ExecConfig::ladder()[idx];
        let (label, cfg) = (rung.label, rung.cfg);
        cases.push((format!("surveillance/{label}"), surveillance::frame_graph(cfg)));
        cases.push((format!("facedet/{label}"), facedet::frame_graph(cfg)));
    }
    let rung = *seizure::rung_configs().last().unwrap();
    cases.push((format!("seizure/{}", rung.label), seizure::window_graph(rung.cfg)));
    for (label, g) in cases {
        let single = Scheduler::run(&g).makespan_s;
        let stream = Scheduler::run(&g.repeat(frames)).makespan_s;
        assert!(
            stream <= frames as f64 * single * 1.02 + 1e-6,
            "{label}: {frames} frames streamed {stream} s > serial {} s",
            frames as f64 * single
        );
    }
}

/// Cross-frame overlap is real where the frame graph stalls on I/O: at the
/// best surveillance rung, 8 streamed frames beat 8 serial ones.
#[test]
fn streaming_gain_at_best_surveillance_rung() {
    let cfg = ExecConfig::ladder().last().unwrap().cfg;
    let r = surveillance::run_stream(cfg, 8);
    assert!(r.speedup > 1.02, "stream speedup {:.3}", r.speedup);
    assert!(r.fps > 1.0 / r.single_frame_s, "fps {} vs single {}", r.fps, r.single_frame_s);
}

/// Streamed schedules keep the busy-time invariant too, and report
/// plausible utilization.
#[test]
fn stream_busy_invariant() {
    let cfg = ExecConfig::ladder().last().unwrap().cfg;
    let g = surveillance::frame_graph(cfg);
    let r = Scheduler::run(&g.repeat(4));
    for e in Engine::ALL {
        let u = r.busy_s[e.index()] / r.makespan_s;
        assert!((0.0..=1.0 + 1e-9).contains(&u), "{} utilization {u}", e.name());
    }
    // the convolution engine dominates this use case at the best rung
    assert!(r.busy_s[Engine::Hwce.index()] > 0.0);
}
