//! Scheduler invariants (seeded-exploration style — the offline crate set
//! has no `proptest`; failures print the seed):
//!
//! * resource sanity: per-engine as-run busy time never exceeds the
//!   makespan, runs are deterministic, and no schedule ever exceeds the
//!   full serialization bound (`JobGraph::serialized_bound`) — even with
//!   tiling, per-core engines and mode co-residency in play;
//! * calibration: the scheduled use cases stay within 5 % of the analytic
//!   phase-summation model (per energy category and in pJ/op) on every
//!   ladder rung — the contract that keeps the Fig. 10/11/12 reports
//!   faithful — and per-segment attribution always re-sums to the graph's
//!   schedule-independent active energy;
//! * acceptance: at the best rung of every use case the tiled,
//!   co-resident schedule closes to below 1.15× of the analytic bound
//!   (ROADMAP: the layer-granular schedule sat ≈1.3× above it);
//! * streaming: N frames through the scheduler are never slower than N
//!   back-to-back single-frame runs;
//! * dispatch parity: the indexed dispatcher (`Scheduler::run`) is
//!   bitwise identical to the legacy linear scan (`Scheduler::run_scan`)
//!   on random graphs and on every use-case rung;
//! * windowed streaming: `StreamScheduler` with window K ≥ frames
//!   reproduces the materialized `Scheduler::run(graph.repeat(frames))`
//!   makespan/energy bitwise, bounded windows complete within the
//!   serialization bound, and the peak resident job count depends on the
//!   window — not the stream length;
//! * steady-state fast-forward: the compiled replay path
//!   (`StreamScheduler::run`) is bitwise identical — time, energy per
//!   category, per-engine busy time, overlap, residency — to the live
//!   windowed path (`StreamScheduler::run_live`) on random graphs and on
//!   every rung of every registered workload, and it genuinely engages
//!   (replays most of the stream) on the periodic §IV workloads.

use fulmine::coordinator::{
    facedet, seizure, surveillance, ExecConfig, GraphBuilder, Tiling,
};
use fulmine::energy::Category;
use fulmine::extmem::Device;
use fulmine::soc::sched::{
    Engine, JobGraph, JobId, Scheduler, StreamScheduler, DEFAULT_STREAM_WINDOW, N_ENGINES,
};
use fulmine::workload::{frame_graph, Registry};

struct Rng(u64);

impl Rng {
    fn new(seed: u64) -> Self {
        Rng(seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1)
    }
    fn next(&mut self) -> u64 {
        self.0 ^= self.0 << 13;
        self.0 ^= self.0 >> 7;
        self.0 ^= self.0 << 17;
        self.0
    }
    fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.next() % (hi - lo + 1)
    }
}

/// A random but well-formed job graph: random phase kinds (including
/// tile-style epilogues and ADC bursts), random dependencies on earlier
/// jobs, a ladder-sampled configuration, and — when `segments` — a tenant
/// marker every few jobs.
fn random_graph_with(seed: u64, segments: bool) -> JobGraph {
    let mut r = Rng::new(seed);
    let ladder = ExecConfig::ladder();
    let cfg = ladder[(r.next() % ladder.len() as u64) as usize].cfg;
    let mut b = GraphBuilder::new(cfg);
    // keep ext-mem standby out so scheduled and analytic ledgers may only
    // differ in the Idle category
    b.set_ext_mem_present(false);
    let n_jobs = r.range(3, 40) as usize;
    let mut ids: Vec<JobId> = Vec::new();
    for i in 0..n_jobs {
        if segments && i % 5 == 0 {
            b.begin_segment(if (i / 5) % 2 == 0 { "even" } else { "odd" });
        }
        let mut deps: Vec<JobId> = Vec::new();
        for _ in 0..r.range(0, 2) {
            if !ids.is_empty() {
                deps.push(ids[(r.next() % ids.len() as u64) as usize]);
            }
        }
        deps.sort_unstable();
        deps.dedup();
        let id = match r.next() % 8 {
            0 => b.conv(r.range(10_000, 5_000_000), if r.next() % 2 == 0 { 3 } else { 5 }, &deps),
            1 => b.xts(r.range(64, 100_000) as usize, &deps),
            2 => b.sponge_ae(r.range(64, 100_000) as usize, &deps),
            3 => b.sw(r.range(1_000, 2_000_000) as f64, 1.0, &deps),
            4 => b.dma(r.range(64, 200_000) as usize, &deps),
            5 => b.epilogue(r.range(1_000, 500_000) as f64, &deps),
            6 => b.adc(r.range(64, 50_000) as usize, &deps),
            _ => {
                let dev = if r.next() % 2 == 0 { Device::Flash } else { Device::Fram };
                b.extmem(dev, r.range(64, 200_000) as usize, &deps)
            }
        };
        ids.push(id);
    }
    b.build()
}

fn random_graph(seed: u64) -> JobGraph {
    random_graph_with(seed, false)
}

const ACTIVE_CATEGORIES: [Category; 5] = [
    Category::Conv,
    Category::Crypto,
    Category::OtherSw,
    Category::Dma,
    Category::ExtMem,
];

/// (a) Engine-busy accounting: each engine's as-run busy time is bounded
/// by the makespan, overlap statistics are consistent, and runs are
/// deterministic.
#[test]
fn prop_engine_busy_bounded() {
    for seed in 0..60u64 {
        let g = random_graph(seed);
        let r = Scheduler::run(&g);
        for e in Engine::ALL {
            assert!(
                r.busy_s[e.index()] <= r.makespan_s + 1e-9,
                "seed {seed}: {} busy {} > makespan {}",
                e.name(),
                r.busy_s[e.index()],
                r.makespan_s
            );
        }
        let total: f64 = r.busy_s.iter().sum();
        assert!(
            total <= r.makespan_s * N_ENGINES as f64 + 1e-9,
            "seed {seed}: total busy {total} > {} x makespan {}",
            N_ENGINES,
            r.makespan_s
        );
        assert!(r.overlap_s <= r.makespan_s + 1e-9, "seed {seed}");
        assert!(r.coresidency_s <= r.overlap_s + 1e-9, "seed {seed}");
        let again = Scheduler::run(&g);
        assert_eq!(r.makespan_s.to_bits(), again.makespan_s.to_bits(), "seed {seed}");
        assert_eq!(r.mode_switches, again.mode_switches, "seed {seed}");
    }
}

/// (b) No schedule exceeds the full serialization bound — every job
/// back-to-back at the slowest admissible point plus one relock per
/// cluster job — under tiling, co-residency and per-core contention.
#[test]
fn prop_makespan_within_serialized_bound() {
    for seed in 0..80u64 {
        let g = random_graph(2000 + seed);
        let r = Scheduler::run(&g);
        let bound = g.serialized_bound();
        assert!(
            r.makespan_s <= bound + 1e-9,
            "seed {seed}: makespan {} > serialized bound {bound}",
            r.makespan_s
        );
        let cluster_jobs = g.jobs.iter().filter(|j| j.mode_locked()).count() as u64;
        assert!(r.mode_switches <= cluster_jobs, "seed {seed}");
    }
    // and for the real use-case graphs at every rung
    let reg = Registry::builtin();
    for name in reg.names() {
        let w = reg.resolve(name).unwrap();
        for rung in w.rungs() {
            let g = frame_graph(w, rung.cfg).unwrap();
            let r = Scheduler::run(&g);
            assert!(
                r.makespan_s <= g.serialized_bound() + 1e-9,
                "{name}/{}: {} > {}",
                rung.label,
                r.makespan_s,
                g.serialized_bound()
            );
        }
    }
}

/// Bitwise agreement of two scheduler results (makespan, relocks, energy
/// per category, per-engine busy time; overlap to fp tolerance).
fn assert_results_match(label: &str, a: &fulmine::soc::sched::SchedResult, b: &fulmine::soc::sched::SchedResult) {
    assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits(), "{label}: makespan");
    assert_eq!(a.mode_switches, b.mode_switches, "{label}: relocks");
    assert_eq!(a.n_jobs, b.n_jobs, "{label}: job count");
    for cat in Category::all() {
        assert_eq!(
            a.ledger.energy_mj(cat).to_bits(),
            b.ledger.energy_mj(cat).to_bits(),
            "{label}: {cat:?} energy"
        );
    }
    for e in Engine::ALL {
        assert_eq!(
            a.busy_s[e.index()].to_bits(),
            b.busy_s[e.index()].to_bits(),
            "{label}: {} busy",
            e.name()
        );
    }
    let scale = 1.0 + a.overlap_s.abs();
    assert!((a.overlap_s - b.overlap_s).abs() < 1e-12 * scale, "{label}: overlap");
    assert!((a.coresidency_s - b.coresidency_s).abs() < 1e-12 * scale, "{label}: coresidency");
}

/// Tentpole parity (dispatch indexing): the per-engine-queue dispatcher
/// must reproduce the legacy linear scan bitwise — on random graphs
/// (covering co-residency, switch grants, multi-core phases, clock-scaled
/// movers and segments) and on every rung of every registered workload.
#[test]
fn prop_indexed_dispatch_matches_scan() {
    for seed in 0..60u64 {
        let g = random_graph_with(5000 + seed, seed % 2 == 0);
        let fast = Scheduler::run(&g);
        let scan = Scheduler::run_scan(&g);
        assert_results_match(&format!("seed {seed}"), &fast, &scan);
    }
    let reg = Registry::builtin();
    for name in reg.names() {
        let w = reg.resolve(name).unwrap();
        for rung in w.rungs() {
            let g = frame_graph(w, rung.cfg).unwrap();
            let fast = Scheduler::run(&g);
            let scan = Scheduler::run_scan(&g);
            assert_results_match(&format!("{name}/{}", rung.label), &fast, &scan);
        }
    }
}

/// Tentpole parity (bounded-window streaming): a window covering the
/// whole stream reproduces the materialized repeat bitwise; tighter
/// windows complete every job, never beat the full window, and stay
/// within the serialization bound.
#[test]
fn prop_windowed_stream_parity_and_bounds() {
    for seed in 0..25u64 {
        let g = random_graph_with(7000 + seed, seed % 2 == 0);
        for frames in [1usize, 3, 6] {
            let mat = Scheduler::run(&g.repeat(frames));
            for window in [frames, frames + 5, 64] {
                let win = StreamScheduler::run(&g, frames, window);
                assert_results_match(&format!("seed {seed} f{frames} w{window}"), &win, &mat);
            }
            for window in [1usize, 2] {
                let win = StreamScheduler::run(&g, frames, window);
                assert_eq!(win.n_jobs, g.len() * frames, "seed {seed}");
                assert!(
                    win.makespan_s <= frames as f64 * g.serialized_bound() + 1e-9,
                    "seed {seed}: window {window} exceeded the serialization bound"
                );
                assert!(win.peak_resident_jobs <= window * g.len(), "seed {seed}");
            }
        }
    }
}

/// Tentpole parity (steady-state fast-forward): `StreamScheduler::run`
/// (compiled template + replay) is bitwise identical to the live windowed
/// path on random graphs — including graphs with tenant segments,
/// co-residency, relocks and clock-scaled movers — across stream depths
/// and windows. Random graphs rarely settle into a periodic steady state;
/// when they do, the replayed result must still be indistinguishable.
#[test]
fn prop_fast_forward_matches_live_on_random_graphs() {
    for seed in 0..40u64 {
        let g = random_graph_with(9000 + seed, seed % 2 == 0);
        for (frames, window) in [(1usize, 1usize), (2, 8), (7, 2), (40, 3), (60, 4)] {
            let live = StreamScheduler::run_live(&g, frames, window);
            let ff = StreamScheduler::run(&g, frames, window);
            assert_results_match(&format!("seed {seed} f{frames} w{window}"), &ff, &live);
            assert_eq!(ff.peak_resident_jobs, live.peak_resident_jobs, "seed {seed}");
            assert_eq!(live.fast_forwarded_frames, 0, "live path must never replay");
        }
    }
}

/// Tentpole acceptance: on every rung of every registered workload the
/// fast-forward path reproduces the live windowed scheduler bitwise —
/// and on the periodic §IV streams it genuinely engages, replaying most
/// of the frames (this is where the simulator's order-of-magnitude
/// jobs/s win at `--frames 4096` comes from; `bench_scheduler` records
/// the trajectory).
#[test]
fn fast_forward_bitwise_identical_on_all_workload_rungs() {
    let reg = Registry::builtin();
    for name in reg.names() {
        let w = reg.resolve(name).unwrap();
        for rung in w.rungs() {
            let g = frame_graph(w, rung.cfg).unwrap();
            let (frames, window) = if g.len() > 500 { (12usize, 2usize) } else { (40, 4) };
            let live = StreamScheduler::run_live(&g, frames, window);
            let ff = StreamScheduler::run(&g, frames, window);
            assert_results_match(&format!("{name}/{}", rung.label), &ff, &live);
            assert_eq!(
                ff.peak_resident_jobs, live.peak_resident_jobs,
                "{name}/{}",
                rung.label
            );
        }
        // the periodic best-rung stream must actually fast-forward
        let rung = *w.rungs().last().unwrap();
        let g = frame_graph(w, rung.cfg).unwrap();
        let (frames, window) = if g.len() > 500 { (12usize, 2usize) } else { (40, 4) };
        let ff = StreamScheduler::run(&g, frames, window);
        assert!(
            ff.fast_forwarded_frames > 0,
            "{name}: steady state never engaged over {frames} frames"
        );
        assert!(ff.fast_forwarded_frames < frames, "{name}: warmup cannot be replayed");
    }
}

/// Satellite edge cases: streams shorter than the detection warmup run
/// fully live (and bitwise identically), and the default-window CLI path
/// clamps oversized windows without changing the schedule.
#[test]
fn fast_forward_warmup_and_clamp_edges() {
    let cfg = ExecConfig::ladder().last().unwrap().cfg;
    let g = seizure::window_graph(cfg);
    for frames in [1usize, 2, 4] {
        for window in [1usize, 3, DEFAULT_STREAM_WINDOW] {
            let live = StreamScheduler::run_live(&g, frames, window);
            let ff = StreamScheduler::run(&g, frames, window);
            assert_results_match(&format!("short f{frames} w{window}"), &ff, &live);
            assert_eq!(ff.fast_forwarded_frames, 0, "f{frames} w{window}: nothing to replay");
        }
    }
    // oversized window ≡ clamped window, bitwise
    let wide = StreamScheduler::run(&g, 5, 4096);
    let exact = StreamScheduler::run(&g, 5, 5);
    assert_results_match("window clamp", &wide, &exact);
}

/// Satellite edge case: a mode-override variant mid-stream breaks the
/// period — the scheduler falls back to live execution around it (bitwise
/// equal to the never-fast-forwarded run), then re-engages once the
/// variant retires.
#[test]
fn fast_forward_variant_fallback_on_workload_graph() {
    let cfg = ExecConfig::ladder().last().unwrap().cfg;
    let base = seizure::window_graph(cfg);
    let mut variant = base.clone();
    for j in &mut variant.jobs {
        j.duration_s *= 2.0;
    }
    let frames = 48usize;
    let vats: [(usize, &JobGraph); 1] = [(19, &variant)];
    for window in [2usize, 4] {
        let live = StreamScheduler::run_with_variants_live(&base, frames, window, &vats);
        let ff = StreamScheduler::run_with_variants(&base, frames, window, &vats);
        assert_results_match(&format!("variant w{window}"), &ff, &live);
        assert!(
            ff.fast_forwarded_frames > 0,
            "w{window}: must re-engage after the variant frame retires"
        );
    }
}

/// Acceptance: streaming the surveillance use case holds O(window) live
/// jobs — the peak resident count is identical at 8 and 64 frames and
/// bounded by window × frame jobs, while the materialized path scales
/// with the stream length.
#[test]
fn stream_peak_residency_independent_of_frame_count() {
    let cfg = ExecConfig::ladder().last().unwrap().cfg;
    let g = surveillance::frame_graph(cfg);
    let short = StreamScheduler::run(&g, 8, DEFAULT_STREAM_WINDOW);
    let long = StreamScheduler::run(&g, 64, DEFAULT_STREAM_WINDOW);
    assert_eq!(
        short.peak_resident_jobs, long.peak_resident_jobs,
        "peak residency must not grow with the frame count"
    );
    assert!(short.peak_resident_jobs <= DEFAULT_STREAM_WINDOW * g.len());
    assert_eq!(Scheduler::run(&g.repeat(16)).peak_resident_jobs, 16 * g.len());
    // and the windowed stream still beats 64 back-to-back frames
    let single = Scheduler::run(&g).makespan_s;
    assert!(
        long.makespan_s <= 64.0 * single * 1.02 + 1e-6,
        "windowed stream slower than serial: {} vs {}",
        long.makespan_s,
        64.0 * single
    );
}

/// Active energy is schedule-independent: scheduled and analytic runs of
/// the same graph charge identical Conv/Crypto/OtherSw/Dma/ExtMem energy
/// (only Idle tracks the makespan) — co-resident frequency rescaling
/// included, since cluster dynamic power is frequency-linear.
#[test]
fn prop_active_energy_schedule_independent() {
    for seed in 0..60u64 {
        let g = random_graph(1000 + seed);
        let run = Scheduler::run(&g);
        let ana = g.analytic();
        for cat in ACTIVE_CATEGORIES {
            let a = run.ledger.energy_mj(cat);
            let b = ana.ledger.energy_mj(cat);
            assert!(
                (a - b).abs() <= 1e-9 * (1.0 + a.abs().max(b.abs())),
                "seed {seed} {cat:?}: scheduled {a} != analytic {b}"
            );
        }
    }
}

/// Per-segment attribution re-sums to the graph's active energy — on
/// random segmented graphs, under streaming repetition, and on the real
/// multi-tenant `mixed` frame with tiling and co-residency in play.
#[test]
fn prop_segment_attribution_sums_to_active() {
    for seed in 0..40u64 {
        let g = random_graph_with(3000 + seed, true);
        let seg = g.segment_active_mj();
        let sum: f64 = seg.iter().map(|(_, mj)| mj).sum();
        let active = g.active_mj();
        assert!(
            (sum - active).abs() <= 1e-9 * (1.0 + active),
            "seed {seed}: segments {sum} vs active {active}"
        );
        let g3 = g.repeat(3);
        let sum3: f64 = g3.segment_active_mj().iter().map(|(_, mj)| mj).sum();
        assert!(
            (sum3 - 3.0 * active).abs() <= 1e-9 * (1.0 + 3.0 * active),
            "seed {seed}: streamed segments {sum3} vs {}",
            3.0 * active
        );
    }
    let reg = Registry::builtin();
    let mixed = reg.resolve("mixed").unwrap();
    for rung in mixed.rungs() {
        let g = frame_graph(mixed, rung.cfg).unwrap();
        let sum: f64 = g.segment_active_mj().iter().map(|(_, mj)| mj).sum();
        let active = g.active_mj();
        assert!(
            (sum - active).abs() <= 1e-9 * (1.0 + active),
            "mixed/{}: {sum} vs {active}",
            rung.label
        );
    }
}

/// (c) Calibration contract: on every ladder rung of every use case the
/// scheduled energy matches the analytic phase-summation model within 5 %
/// per active category and in total, and the makespan stays in the band
/// explained by co-residency gains (below 1) and exposed I/O dependencies
/// (slightly above 1 at the software rungs).
#[test]
fn usecase_energy_within_5pct_of_analytic() {
    let mut cases: Vec<(String, JobGraph)> = Vec::new();
    for rung in ExecConfig::ladder() {
        let (label, cfg) = (rung.label, rung.cfg);
        cases.push((format!("surveillance/{label}"), surveillance::frame_graph(cfg)));
        cases.push((format!("facedet/{label}"), facedet::frame_graph(cfg)));
    }
    for rung in seizure::rung_configs() {
        cases.push((format!("seizure/{}", rung.label), seizure::window_graph(rung.cfg)));
    }
    for (label, g) in cases {
        let run = Scheduler::run(&g);
        let ana = g.analytic();
        for cat in ACTIVE_CATEGORIES {
            let a = run.ledger.energy_mj(cat);
            let b = ana.ledger.energy_mj(cat);
            if b > 1e-9 {
                let rel = (a - b).abs() / b;
                assert!(rel < 0.05, "{label} {cat:?}: {a} vs {b} ({rel:.4})");
            }
        }
        let (ta, tb) = (run.ledger.total_mj(), ana.ledger.total_mj());
        assert!((ta - tb).abs() / tb < 0.05, "{label} total: {ta} vs {tb}");
        let ratio = run.makespan_s / ana.makespan_s;
        assert!(
            (0.5..1.25).contains(&ratio),
            "{label}: scheduled/analytic makespan ratio {ratio:.3}"
        );
    }
}

/// pJ/op parity between the scheduled and analytic paths, across all use
/// cases and rungs (the headline acceptance number).
#[test]
fn usecase_pj_per_op_within_5pct() {
    for rung in ExecConfig::ladder() {
        let (label, cfg) = (rung.label, rung.cfg);
        for (case, sched, ana) in [
            (
                "surveillance",
                surveillance::run_frame(cfg).pj_per_op,
                surveillance::run_frame_analytic(cfg).pj_per_op,
            ),
            (
                "facedet",
                facedet::run_frame(cfg).pj_per_op,
                facedet::run_frame_analytic(cfg).pj_per_op,
            ),
        ] {
            let rel = (sched - ana).abs() / ana;
            assert!(rel < 0.05, "{case}/{label}: {sched} vs {ana} ({rel:.4})");
        }
    }
    for rung in seizure::rung_configs() {
        let sched = seizure::run_window(rung.cfg).pj_per_op;
        let ana = seizure::run_window_analytic(rung.cfg).pj_per_op;
        let rel = (sched - ana).abs() / ana;
        assert!(rel < 0.05, "seizure/{}: {sched} vs {ana} ({rel:.4})", rung.label);
    }
}

/// Acceptance: at the best rung of every use case, tile-granular emission
/// plus CRY–CNN–SW co-residency closes the scheduled/analytic gap to
/// below 1.15× (the layer-granular schedule sat ≈1.3× above the bound),
/// and the tiled schedule beats the layer-granular one outright.
#[test]
fn best_rung_gap_below_1p15_and_tiling_wins() {
    let cases: [(&str, ExecConfig); 3] = [
        ("surveillance", ExecConfig::ladder().last().unwrap().cfg),
        ("facedet", ExecConfig::ladder().last().unwrap().cfg),
        ("seizure", seizure::rung_configs().last().unwrap().cfg),
    ];
    for (name, cfg) in cases {
        let g = match name {
            "surveillance" => surveillance::frame_graph(cfg),
            "facedet" => facedet::frame_graph(cfg),
            _ => seizure::window_graph(cfg),
        };
        let run = Scheduler::run(&g);
        let ana = g.analytic();
        let gap = run.makespan_s / ana.makespan_s;
        assert!(gap < 1.15, "{name}: scheduled/analytic gap {gap:.3}");

        let layer_cfg = ExecConfig { tiling: Tiling::Layer, ..cfg };
        let layer = match name {
            "surveillance" => surveillance::frame_graph(layer_cfg),
            "facedet" => facedet::frame_graph(layer_cfg),
            _ => seizure::window_graph(layer_cfg),
        };
        let layer_run = Scheduler::run(&layer);
        assert!(
            run.makespan_s < layer_run.makespan_s,
            "{name}: tiled {} not better than layer-granular {}",
            run.makespan_s,
            layer_run.makespan_s
        );
    }
}

/// (d) Streaming N frames is never slower than N back-to-back single
/// frames (small tolerance for the extra FLL relock at each frame
/// boundary, which back-to-back runs get for free).
#[test]
fn streaming_never_slower_than_serial() {
    let frames = 4usize;
    let mut cases: Vec<(String, JobGraph)> = Vec::new();
    for idx in [0usize, 2, 4] {
        let rung = ExecConfig::ladder()[idx];
        let (label, cfg) = (rung.label, rung.cfg);
        cases.push((format!("surveillance/{label}"), surveillance::frame_graph(cfg)));
        cases.push((format!("facedet/{label}"), facedet::frame_graph(cfg)));
    }
    let rung = *seizure::rung_configs().last().unwrap();
    cases.push((format!("seizure/{}", rung.label), seizure::window_graph(rung.cfg)));
    for (label, g) in cases {
        let single = Scheduler::run(&g).makespan_s;
        let stream = Scheduler::run(&g.repeat(frames)).makespan_s;
        assert!(
            stream <= frames as f64 * single * 1.02 + 1e-6,
            "{label}: {frames} frames streamed {stream} s > serial {} s",
            frames as f64 * single
        );
    }
}

/// Streaming at the best surveillance rung: the tiled frame already keeps
/// the engines busy, so the cross-frame gain is modest — but streaming
/// must never lose throughput, and the pipeline stays co-resident.
#[test]
fn streaming_holds_throughput_at_best_surveillance_rung() {
    let cfg = ExecConfig::ladder().last().unwrap().cfg;
    let r = surveillance::run_stream(cfg, 8);
    assert!(r.speedup >= 0.999, "stream speedup {:.4}", r.speedup);
    // streamed frames amortize the makespan-proportional idle energy, so
    // per-frame pJ/op never exceeds the single-frame number
    let single = surveillance::run_frame(cfg);
    assert!(
        r.pj_per_op <= single.pj_per_op * 1.001,
        "streamed pJ/op {} vs single-frame {}",
        r.pj_per_op,
        single.pj_per_op
    );
    assert!(r.coresidency_s > 0.0, "streamed schedule must co-reside");
    assert!((r.fps - 8.0 / r.time_s).abs() < 1e-9);
}

/// Streamed schedules keep the busy-time invariant, report plausible
/// utilization, and keep the convolution engine hot at the best rung.
#[test]
fn stream_busy_invariant() {
    let cfg = ExecConfig::ladder().last().unwrap().cfg;
    let g = surveillance::frame_graph(cfg);
    let r = Scheduler::run(&g.repeat(4));
    for e in Engine::ALL {
        let u = r.busy_s[e.index()] / r.makespan_s;
        assert!((0.0..=1.0 + 1e-9).contains(&u), "{} utilization {u}", e.name());
    }
    // the convolution engine dominates this use case at the best rung
    let hwce_util = r.busy_s[Engine::Hwce.index()] / r.makespan_s;
    assert!(hwce_util > 0.5, "HWCE utilization {hwce_util} — tiling should keep it hot");
    // per-core engines see work too: the epilogues and control stubs
    let core_busy: f64 = (0..4).map(|i| r.busy_s[Engine::Core(i).index()]).sum();
    assert!(core_busy > 0.0, "cores never busy?");
}
