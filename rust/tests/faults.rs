//! Fault-injection and recovery properties across the façade (ISSUE 9):
//! a fault-free run is *bitwise identical* whether the fault machinery is
//! absent (`faults: None`) or engaged with an all-zero model; seeded fault
//! runs are deterministic across repeated runs, shard splits and host
//! thread counts; fast-forward re-engages around faulted frames on
//! gap-dominated streams; fleets report reliability percentiles; and a
//! forced parity corruption surfaces the structured mismatch error.
//!
//! Fault counts asserted `> 0` below were pre-computed from the seeded
//! fault tables (the per-frame draw depends only on `(model, frame)`), so
//! they are properties of the chosen seeds, not of luck.

use fulmine::coordinator::StreamResult;
use fulmine::energy::Category;
use fulmine::fault::{FaultModel, Recovery};
use fulmine::json::Json;
use fulmine::system::{FleetSpec, RunSpec, SocSystem};
use fulmine::traffic::Traffic;

/// `mixed:0.05:0.05:0.01:0.05:11` — over 64 frames this table holds 2
/// drops, 5 transients, 1 brown-out and 2 link losses.
fn mixed_model() -> FaultModel {
    FaultModel {
        drop_rate: 0.05,
        transient_rate: 0.05,
        brownout_rate: 0.01,
        link_rate: 0.05,
        seed: 11,
    }
}

fn assert_stream_bitwise_eq(a: &StreamResult, b: &StreamResult, ctx: &str) {
    for (field, x, y) in [
        ("time_s", a.time_s, b.time_s),
        ("fps", a.fps, b.fps),
        ("energy_mj", a.energy_mj, b.energy_mj),
        ("pj_per_op", a.pj_per_op, b.pj_per_op),
        ("overlap_s", a.overlap_s, b.overlap_s),
        ("coresidency_s", a.coresidency_s, b.coresidency_s),
        ("sleep_s", a.sleep_s, b.sleep_s),
        ("deep_sleep_s", a.deep_sleep_s, b.deep_sleep_s),
        ("recovery_energy_mj", a.recovery_energy_mj, b.recovery_energy_mj),
    ] {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: {field} {x} vs {y}");
    }
    assert_eq!(a.mode_switches, b.mode_switches, "{ctx}");
    assert_eq!(a.wake_transitions, b.wake_transitions, "{ctx}");
    assert_eq!(a.peak_resident_jobs, b.peak_resident_jobs, "{ctx}");
    assert_eq!(a.total_jobs, b.total_jobs, "{ctx}");
    assert_eq!(a.fast_forwarded_frames, b.fast_forwarded_frames, "{ctx}");
    assert_eq!(a.frames_dropped, b.frames_dropped, "{ctx}");
    assert_eq!(a.fault_retries, b.fault_retries, "{ctx}");
    assert_eq!(a.chip_resets, b.chip_resets, "{ctx}");
    assert_eq!(a.state_loss_frames, b.state_loss_frames, "{ctx}");
    for (i, (x, y)) in a.busy_s.iter().zip(&b.busy_s).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: busy_s[{i}]");
    }
    for c in Category::all() {
        assert_eq!(
            a.ledger.energy_mj(c).to_bits(),
            b.ledger.energy_mj(c).to_bits(),
            "{ctx}: ledger {c:?}"
        );
    }
}

/// Tentpole property: an all-zero fault model routes through the variant
/// machinery (plan built, `apply_stats` applied) yet is bitwise identical
/// to the historical no-fault path — on the live path *and* with
/// fast-forward engaged through a small window.
#[test]
fn zero_rate_fault_model_is_bitwise_identical_live_and_fast_forwarded() {
    let sys = SocSystem::new();
    let frames = 64usize;
    for (window, label) in [(frames, "live"), (4, "fast-forwarded")] {
        let clean = sys
            .run(&RunSpec::new("seizure").frames(frames).window(window))
            .unwrap();
        let faulted = sys
            .run(
                &RunSpec::new("seizure")
                    .frames(frames)
                    .window(window)
                    .faults(Some(FaultModel::none()))
                    .recovery(Recovery::default()),
            )
            .unwrap();
        assert_stream_bitwise_eq(&clean.result, &faulted.result, label);
        assert_eq!(faulted.result.frames_dropped, 0, "{label}");
        assert_eq!(faulted.result.availability(), 1.0, "{label}");
    }
    // the small window actually exercised the replay path
    let ff = sys
        .run(
            &RunSpec::new("seizure")
                .frames(frames)
                .window(4)
                .faults(Some(FaultModel::none())),
        )
        .unwrap();
    assert!(
        ff.result.fast_forwarded_frames > 0,
        "a 64-frame back-to-back stream at window 4 must reach steady state"
    );
}

/// Seeded fault runs are deterministic: repeating the identical spec —
/// unsharded or split across 2 and 4 simulated chips — reproduces the
/// whole report bit for bit (the JSON render is a faithful projection).
#[test]
fn seeded_fault_runs_are_deterministic_across_runs_and_shards() {
    let sys = SocSystem::new();
    for shards in [1usize, 2, 4] {
        let spec = || {
            RunSpec::new("seizure")
                .frames(64)
                .shards(shards)
                .faults(Some(mixed_model()))
                .recovery(Recovery::Retry { max: 2, backoff_s: 0.0005 })
        };
        let a = sys.run(&spec()).unwrap();
        let b = sys.run(&spec()).unwrap();
        assert_stream_bitwise_eq(&a.result, &b.result, &format!("shards {shards}"));
        assert_eq!(
            a.to_json().render(),
            b.to_json().render(),
            "shards {shards}: reports must replay bitwise"
        );
        // the table really fired (2 drops, 5 transients, 1 brown-out,
        // 2 link losses over frames 0..64 of seed 11)
        assert!(a.result.frames_dropped >= 2, "shards {shards}: {}", a.result.frames_dropped);
        assert!(a.result.fault_retries > 0, "shards {shards}");
        assert!(a.result.chip_resets > 0, "shards {shards}");
        assert!(a.result.availability() < 1.0, "shards {shards}");
    }
}

/// Acceptance: on a gap-dominated faulted stream the fast-forward path
/// suspends around faulted frames and re-engages between them — replayed
/// frames and retries coexist in one run, and recovery billed energy.
#[test]
fn gap_dominated_faulted_stream_fast_forwards_and_retries() {
    let sys = SocSystem::new();
    let model = FaultModel {
        drop_rate: 0.01,
        transient_rate: 0.01,
        brownout_rate: 0.002,
        link_rate: 0.01,
        seed: 5,
    };
    let run = sys
        .run(
            &RunSpec::new("seizure")
                .frames(512)
                .traffic(Traffic::Periodic { rate_hz: 2.0 })
                .faults(Some(model))
                .recovery(Recovery::default()),
        )
        .unwrap();
    let r = &run.result;
    assert!(r.fast_forwarded_frames > 0, "fast-forward must re-engage between faults");
    // seed 5 over frames 0..512: 4 drops, 6 transients, 6 link losses
    assert!(r.fault_retries > 0, "retries {}", r.fault_retries);
    assert!(r.frames_dropped >= 4, "dropped {}", r.frames_dropped);
    assert!(r.recovery_energy_mj > 0.0);
    assert!(r.availability() < 1.0 && r.availability() > 0.9, "{}", r.availability());
    // reliability block surfaces in both renderings
    let text = run.render_text();
    assert!(text.contains("reliability:"), "{text}");
    let json = Json::parse(&run.to_json().render()).unwrap();
    let avail = json.get("availability").and_then(Json::as_f64).unwrap();
    assert_eq!(avail.to_bits(), r.availability().to_bits());
}

/// A faulted fleet dedups, scales and reports reliability percentiles —
/// identically for any host thread count — and `--faults`' counters
/// survive the population scaling.
#[test]
fn faulted_fleet_reports_reliability_percentiles_thread_invariant() {
    // mixed:0.25:0.2:0.05:0.1:1 over frames 0..8: 4 drops, 1 transient,
    // 1 link loss — every chip of every class shares the table.
    let model = FaultModel {
        drop_rate: 0.25,
        transient_rate: 0.2,
        brownout_rate: 0.05,
        link_rate: 0.1,
        seed: 1,
    };
    let sys = SocSystem::new();
    let spec = |threads: usize| {
        FleetSpec::mixed(64, 8)
            .sample_k(1)
            .threads(threads)
            .faults(Some(model.clone()))
            .recovery(Recovery::Retry { max: 2, backoff_s: 0.001 })
    };
    let a = sys.fleet(&spec(1)).unwrap();
    let b = sys.fleet(&spec(4)).unwrap();
    assert_eq!(
        a.to_json().render(),
        b.to_json().render(),
        "fleet reliability must not depend on host threads"
    );
    assert!(a.frames_dropped >= 4 * a.chips as u64, "dropped {}", a.frames_dropped);
    assert!(a.fault_retries > 0);
    assert!(a.recovery_energy_j > 0.0);
    assert!(a.availability.p50 < 1.0 && a.availability.p50 > 0.0, "{}", a.availability.p50);
    assert!(a.recovery_mj_per_chip.p99 >= a.recovery_mj_per_chip.p50);
    for c in &a.classes {
        assert!(c.availability < 1.0, "{}: every class shares the fault table", c.key);
        assert!(c.frames_dropped >= 4, "{}", c.key);
    }
    // the reliability block renders, and the fault model joins the key
    let text = a.render_text();
    assert!(text.contains("reliability:"), "{text}");
    assert!(a.classes.iter().all(|c| c.key.contains("flt:")), "fault model must key classes");
    // and a fault-free fleet keeps the historical clean rendering
    let clean = sys.fleet(&FleetSpec::mixed(64, 8).sample_k(1)).unwrap();
    assert_eq!(clean.frames_dropped, 0);
    assert!(!clean.render_text().contains("reliability:"));
}

/// Satellite (structured parity error): a forced bit-flip on every
/// sampled parity run's makespan makes `Fleet::run` fail with the class
/// key, the mismatching field and both bit patterns — not a blanket
/// count.
#[test]
fn corrupted_parity_reports_class_field_and_bits() {
    let mut fleet = FleetSpec::mixed(8, 2).sample_k(1);
    fleet.corrupt_parity = true;
    let e = SocSystem::new().fleet(&fleet).unwrap_err().to_string();
    assert!(e.contains("parity failed"), "{e}");
    assert!(e.contains("first mismatch in class '"), "{e}");
    assert!(e.contains("`makespan_s`"), "{e}");
    assert!(e.contains("expected 0x"), "{e}");
    assert!(e.contains("live run produced 0x"), "{e}");
}

/// The fault-sweep grid runs end-to-end: the baseline row is fault-free,
/// every faulted row loses availability or pays recovery energy, and
/// within a rate the policies rank as designed (degrade drops the most
/// frames; retry/reset pay recovery energy).
#[test]
fn fault_sweep_rows_are_consistent() {
    let sweep = SocSystem::new().fault_sweep("seizure", 64).unwrap();
    assert_eq!(sweep.rows.len(), 7, "baseline + 2 rates x 3 policies");
    let base = &sweep.rows[0];
    assert_eq!(base.faults, "none");
    assert_eq!(base.availability, 1.0);
    assert_eq!(base.recovery_energy_mj, 0.0);
    for r in &sweep.rows[1..] {
        assert!(
            r.availability < 1.0 || r.recovery_energy_mj > 0.0,
            "{}/{}: faults must cost something",
            r.faults,
            r.recovery
        );
        assert!(r.energy_mj > 0.0);
    }
    let text = sweep.render_text();
    assert!(text.contains("faultsweep: seizure"), "{text}");
    let json = Json::parse(&sweep.to_json().render()).unwrap();
    assert_eq!(json.get("rows").and_then(Json::as_array).unwrap().len(), 7);
}
