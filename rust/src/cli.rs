//! Command-line parsing and dispatch for the `fulmine` binary.
//!
//! Parsing is a pure function ([`parse`]) from argument slices to a typed
//! [`Command`] — it returns `Err` instead of exiting, so every flag path
//! is unit-testable — and [`dispatch`] executes the command against the
//! [`SocSystem`] façade. `main.rs` is the thin shell gluing the two to
//! the process boundary (usage on stderr, exit codes).

use crate::apps::params::{gen_params, xorshift_i16};
use crate::fault::{FaultModel, Recovery};
use crate::report::{self, PAPER_ARTIFACTS};
use crate::runtime::{default_artifact_dir, Runtime, TensorI16};
use crate::session::{BackendKind, SessionModel, SessionRecovery};
use crate::soc::pm::PolicyKind;
use crate::system::{FleetSpec, RunSpec, RungSel, SocSystem};
use crate::traffic::Traffic;
use anyhow::{anyhow, bail, Result};

pub const USAGE: &str = "usage: fulmine <command>

commands:
  table1|fig7|sec3b|fig8a|sec3c|fig8b|fig10|fig11|fig12|table2
                print the corresponding paper table/figure from the model
  all           print every paper artifact in order
  workloads     list the registered workloads
  ladder <workload> [--json]
                run every ladder rung of a workload (one frame each)
  stream <workload> [--frames N] [--window K] [--shards S] [--config RUNG]
         [--traffic MODEL] [--policy P] [--faults FM] [--recovery R]
         [--loss RATE[:SEED]] [--session-recovery SR] [--crypto-backend CB]
         [--json]
                pipeline N frames through the bounded-window streaming
                scheduler: at most K frames in flight (default 8, clamped
                to N), so memory stays O(K) however large N is; with
                --shards S the frames split across S simulated SoCs on
                parallel host threads (near-linear throughput scaling)
                (RUNG: ladder index or label substring, default best;
                MODEL: backtoback | periodic:RATE_HZ | bursty:BURST:RATE_HZ
                | poisson:RATE_HZ[:SEED] — when frames arrive at the chip;
                P: greedy | lookahead | oracle — duty-cycle idle gaps
                through the Table I sleep ladder and report battery life;
                oracle reads future arrivals, so it needs a --traffic model;
                FM: none | drop:RATE[:SEED] | transient:RATE[:SEED]
                | brownout:RATE[:SEED] | link:RATE[:SEED]
                | mixed:DR:TR:BR:LR[:SEED] — seeded deterministic per-frame
                faults, identical across runs, shards and threads;
                R: retry[:MAX[:BACKOFF_S]] | degrade | reset — how the chip
                answers a fault (default retry:3; needs --faults); faulted
                runs add an availability/retry/reset reliability report;
                --loss models a lossy secure-link channel (session
                workloads only, exclusive with --faults): seeded per-frame
                delivery draws, DTLS-style doubling retransmission backoff,
                SR: full | resume | degrade — how the session re-enters
                after an outage (default resume; needs --loss);
                CB: hwcrypt | sw | insram — which crypto cost model prices
                the record traffic, overriding the rung's native engine)
  fleet [--chips N] [--frames F] [--sample K] [--threads T] [--policy P]
        [--drift PCT] [--phase-jitter S] [--faults FM] [--recovery R]
        [--loss RATE[:SEED]] [--session-recovery SR] [--crypto-backend CB]
        [--json]
                simulate a fleet of N endpoints (default 1000) spread over
                every workload x rung x traffic model: chips dedup into
                simulation-identical classes, each class runs once and
                scales to its population (K random members per class
                re-run live and must match bitwise; default K=3), with
                energy/latency/utilization percentiles across the fleet —
                --chips 1000000 completes in seconds; --policy P manages
                every chip's idle gaps and adds battery-life percentiles;
                --drift PCT perturbs every chip's service times by a
                seeded factor in ±PCT% and --phase-jitter S offsets each
                chip's release table by a seeded phase in [0, S) seconds:
                perturbed chips stay O(classes) — each family simulates
                one representative and derives members by a certified
                closed-form rescale (live fallback when the certificate
                refuses, so results stay exact either way); --faults FM
                with --recovery R subjects every chip to the seeded fault
                process and adds fleet-wide availability and
                recovery-energy percentiles to the report; --loss switches
                the fleet to the secure_link mix and subjects every chip
                to the seeded lossy channel, adding handshake/record
                energy split and availability/goodput percentiles
  ablations [--json]
                run the surveillance design-choice sweep
  faultsweep <workload> [--frames N] [--json]
                stream N frames (default 256) once per fault-rate x
                recovery-policy grid point and tabulate availability,
                drops/retries/resets and recovery energy against the
                fault-free baseline
  sessionsweep [--frames N] [--json]
                stream N secure_link frames (default 256) once per
                crypto-backend x loss-rate x recovery-policy grid point
                (shared channel seed) and tabulate availability, goodput,
                retransmissions/resumptions and the handshake-vs-record
                energy split
  artifacts     list and compile the AOT artifacts (PJRT smoke test)
  infer <name>  execute one artifact with generated inputs, print a digest";

/// A parsed `fulmine` invocation.
#[derive(Debug, Clone, PartialEq)]
pub enum Command {
    /// One of the paper tables/figures (or `all`).
    Paper(&'static str),
    /// List the registered workloads.
    Workloads,
    /// Run a workload's full ladder.
    Ladder { workload: String, json: bool },
    /// Stream frames through the bounded-window scheduler.
    Stream {
        workload: String,
        frames: usize,
        window: Option<usize>,
        shards: usize,
        rung: Option<String>,
        traffic: Traffic,
        policy: Option<PolicyKind>,
        faults: Option<FaultModel>,
        recovery: Option<Recovery>,
        loss: Option<SessionModel>,
        session_recovery: Option<SessionRecovery>,
        crypto_backend: Option<BackendKind>,
        json: bool,
    },
    /// Class-deduplicated fleet simulation over the standard mix (or,
    /// under `--loss`, the secure_link mix).
    Fleet {
        chips: usize,
        frames: usize,
        sample: usize,
        threads: usize,
        policy: Option<PolicyKind>,
        drift: f64,
        phase_jitter: f64,
        faults: Option<FaultModel>,
        recovery: Option<Recovery>,
        loss: Option<SessionModel>,
        session_recovery: Option<SessionRecovery>,
        crypto_backend: Option<BackendKind>,
        json: bool,
    },
    /// The surveillance ablation sweep.
    Ablations { json: bool },
    /// The fault-rate x recovery-policy reliability sweep.
    FaultSweep { workload: String, frames: usize, json: bool },
    /// The crypto-backend x loss-rate x recovery-policy session sweep.
    SessionSweep { frames: usize, json: bool },
    /// PJRT artifact listing/compilation.
    Artifacts,
    /// Execute one AOT artifact.
    Infer { name: String },
}

/// Parse the argument list (without the program name).
pub fn parse(args: &[String]) -> Result<Command> {
    let cmd = args.first().map(String::as_str).ok_or_else(|| anyhow!("missing command"))?;
    let rest = &args[1..];
    if let Some(name) = PAPER_ARTIFACTS.iter().copied().find(|&n| n == cmd) {
        expect_no_args(cmd, rest)?;
        return Ok(Command::Paper(name));
    }
    match cmd {
        "workloads" => {
            expect_no_args(cmd, rest)?;
            Ok(Command::Workloads)
        }
        "ladder" => parse_ladder(rest),
        "stream" => parse_stream(rest),
        "fleet" => parse_fleet(rest),
        "faultsweep" => parse_faultsweep(rest),
        "sessionsweep" => parse_sessionsweep(rest),
        "ablations" => {
            let json = parse_json_flag(cmd, rest)?;
            Ok(Command::Ablations { json })
        }
        "artifacts" => {
            expect_no_args(cmd, rest)?;
            Ok(Command::Artifacts)
        }
        "infer" => {
            let name =
                rest.first().cloned().ok_or_else(|| anyhow!("infer needs an artifact name"))?;
            expect_no_args(cmd, &rest[1..])?;
            Ok(Command::Infer { name })
        }
        other => bail!("unknown command {other:?}"),
    }
}

fn expect_no_args(cmd: &str, rest: &[String]) -> Result<()> {
    if let Some(extra) = rest.first() {
        bail!("{cmd} takes no further arguments (got {extra:?})");
    }
    Ok(())
}

/// Accept an optional trailing `--json`, nothing else.
fn parse_json_flag(cmd: &str, rest: &[String]) -> Result<bool> {
    match rest {
        [] => Ok(false),
        [flag] if flag == "--json" => Ok(true),
        [other, ..] => bail!("unknown {cmd} flag {other:?}"),
    }
}

fn parse_ladder(args: &[String]) -> Result<Command> {
    let workload = args
        .first()
        .cloned()
        .ok_or_else(|| anyhow!("ladder needs a workload; try `fulmine workloads`"))?;
    let json = parse_json_flag("ladder", &args[1..])?;
    Ok(Command::Ladder { workload, json })
}

/// Parse the `stream` subcommand's flags: `<workload> [--frames N]
/// [--window K] [--shards S] [--config RUNG] [--json]`.
fn parse_stream(args: &[String]) -> Result<Command> {
    let workload = args
        .first()
        .cloned()
        .ok_or_else(|| anyhow!("stream needs a workload; try `fulmine workloads`"))?;
    let mut frames = 8usize;
    let mut window: Option<usize> = None;
    let mut shards = 1usize;
    let mut rung: Option<String> = None;
    let mut traffic = Traffic::BackToBack;
    let mut policy: Option<PolicyKind> = None;
    let mut faults: Option<FaultModel> = None;
    let mut recovery: Option<Recovery> = None;
    let mut loss: Option<SessionModel> = None;
    let mut session_recovery: Option<SessionRecovery> = None;
    let mut crypto_backend: Option<BackendKind> = None;
    let mut json = false;
    let mut it = args[1..].iter();
    while let Some(flag) = it.next() {
        match flag.as_str() {
            "--frames" => {
                let v = it.next().ok_or_else(|| anyhow!("--frames needs a value"))?;
                frames = v.parse().map_err(|_| anyhow!("bad --frames value {v:?}"))?;
                if frames == 0 {
                    bail!("--frames must be at least 1 (a stream of 0 frames schedules nothing)");
                }
            }
            "--window" => {
                let v = it.next().ok_or_else(|| anyhow!("--window needs a value"))?;
                let w: usize = v.parse().map_err(|_| anyhow!("bad --window value {v:?}"))?;
                if w == 0 {
                    bail!("--window must be at least 1 (zero in-flight frames schedule nothing)");
                }
                window = Some(w);
            }
            "--shards" => {
                let v = it.next().ok_or_else(|| anyhow!("--shards needs a value"))?;
                let s: usize = v.parse().map_err(|_| anyhow!("bad --shards value {v:?}"))?;
                if s == 0 {
                    bail!("--shards must be at least 1 (no chips schedule no frames)");
                }
                shards = s;
            }
            "--config" => {
                let v = it.next().ok_or_else(|| anyhow!("--config needs a value"))?;
                rung = Some(v.clone());
            }
            "--traffic" => {
                let v = it.next().ok_or_else(|| anyhow!("--traffic needs a value"))?;
                traffic = Traffic::parse(v)?;
            }
            "--policy" => {
                let v = it.next().ok_or_else(|| anyhow!("--policy needs a value"))?;
                policy = Some(PolicyKind::parse(v)?);
            }
            "--faults" => {
                let v = it.next().ok_or_else(|| anyhow!("--faults needs a value"))?;
                faults = Some(FaultModel::parse(v)?);
            }
            "--recovery" => {
                let v = it.next().ok_or_else(|| anyhow!("--recovery needs a value"))?;
                recovery = Some(Recovery::parse(v)?);
            }
            "--loss" => {
                let v = it.next().ok_or_else(|| anyhow!("--loss needs a value"))?;
                loss = Some(SessionModel::parse(v)?);
            }
            "--session-recovery" => {
                let v =
                    it.next().ok_or_else(|| anyhow!("--session-recovery needs a value"))?;
                session_recovery = Some(SessionRecovery::parse(v)?);
            }
            "--crypto-backend" => {
                let v = it.next().ok_or_else(|| anyhow!("--crypto-backend needs a value"))?;
                crypto_backend = Some(BackendKind::parse(v)?);
            }
            "--json" => json = true,
            other => bail!("unknown stream flag {other:?}"),
        }
    }
    if policy == Some(PolicyKind::Oracle) && matches!(traffic, Traffic::BackToBack) {
        bail!(
            "--policy oracle reads the future release table, which a back-to-back \
             stream does not have — pick a --traffic model (or use greedy/lookahead)"
        );
    }
    let (faults, recovery) = check_fault_flags(faults, recovery)?;
    check_session_flags(&loss, session_recovery, &faults)?;
    Ok(Command::Stream {
        workload,
        frames,
        window,
        shards,
        rung,
        traffic,
        policy,
        faults,
        recovery,
        loss,
        session_recovery,
        crypto_backend,
        json,
    })
}

/// Cross-validate `--faults`/`--recovery`: a recovery policy without a
/// fault model is a spec error, and `--faults none` is *exactly* an
/// unfaulted run (it normalizes to no model at all, so the simulation
/// takes the historical bitwise-identical path).
fn check_fault_flags(
    faults: Option<FaultModel>,
    recovery: Option<Recovery>,
) -> Result<(Option<FaultModel>, Option<Recovery>)> {
    if recovery.is_some() && faults.is_none() {
        bail!(
            "--recovery without --faults has nothing to recover from — \
             add a --faults model (or drop --recovery)"
        );
    }
    Ok((faults.filter(|m| !m.is_none()), recovery))
}

/// Cross-validate the secure-link flags: a session recovery policy
/// without a channel is a spec error, and `--loss` with `--faults`
/// would stack two failure processes on the same frames — rejected at
/// parse time with the same message [`crate::system`] uses at run time.
/// (`--loss 0` is *not* normalized away: a perfect channel still
/// performs its frame-0 handshake, which the loss-free identity tests
/// rely on.)
fn check_session_flags(
    loss: &Option<SessionModel>,
    session_recovery: Option<SessionRecovery>,
    faults: &Option<FaultModel>,
) -> Result<()> {
    if session_recovery.is_some() && loss.is_none() {
        bail!(
            "--session-recovery without --loss has no outage to recover from — \
             add a --loss channel (or drop --session-recovery)"
        );
    }
    if loss.is_some() && faults.is_some() {
        bail!("--loss and --faults are mutually exclusive (one failure model per run)");
    }
    Ok(())
}

/// Parse the `fleet` subcommand's flags: `[--chips N] [--frames F]
/// [--sample K] [--threads T] [--drift PCT] [--phase-jitter S] [--json]`.
fn parse_fleet(args: &[String]) -> Result<Command> {
    let mut chips = 1000usize;
    let mut frames = 32usize;
    let mut sample = 3usize;
    let mut threads = 0usize;
    let mut policy: Option<PolicyKind> = None;
    let mut drift = 0.0f64;
    let mut phase_jitter = 0.0f64;
    let mut faults: Option<FaultModel> = None;
    let mut recovery: Option<Recovery> = None;
    let mut loss: Option<SessionModel> = None;
    let mut session_recovery: Option<SessionRecovery> = None;
    let mut crypto_backend: Option<BackendKind> = None;
    let mut json = false;
    let mut it = args.iter();
    while let Some(flag) = it.next() {
        match flag.as_str() {
            "--chips" => {
                let v = it.next().ok_or_else(|| anyhow!("--chips needs a value"))?;
                chips = v.parse().map_err(|_| anyhow!("bad --chips value {v:?}"))?;
                if chips == 0 {
                    bail!("--chips must be at least 1 (an empty fleet simulates nothing)");
                }
            }
            "--frames" => {
                let v = it.next().ok_or_else(|| anyhow!("--frames needs a value"))?;
                frames = v.parse().map_err(|_| anyhow!("bad --frames value {v:?}"))?;
                if frames == 0 {
                    bail!("--frames must be at least 1 (a stream of 0 frames schedules nothing)");
                }
            }
            "--sample" => {
                let v = it.next().ok_or_else(|| anyhow!("--sample needs a value"))?;
                sample = v.parse().map_err(|_| anyhow!("bad --sample value {v:?}"))?;
                if sample == 0 {
                    bail!("--sample must be at least 1 (the class representative)");
                }
            }
            "--threads" => {
                let v = it.next().ok_or_else(|| anyhow!("--threads needs a value"))?;
                threads = v.parse().map_err(|_| anyhow!("bad --threads value {v:?}"))?;
            }
            "--policy" => {
                let v = it.next().ok_or_else(|| anyhow!("--policy needs a value"))?;
                policy = Some(PolicyKind::parse(v)?);
            }
            "--drift" => {
                let v = it.next().ok_or_else(|| anyhow!("--drift needs a value"))?;
                drift = v.parse().map_err(|_| anyhow!("bad --drift value {v:?}"))?;
                if !(drift.is_finite() && (0.0..100.0).contains(&drift)) {
                    bail!("--drift must be a percentage in [0, 100) (got {v:?})");
                }
            }
            "--phase-jitter" => {
                let v = it.next().ok_or_else(|| anyhow!("--phase-jitter needs a value"))?;
                phase_jitter =
                    v.parse().map_err(|_| anyhow!("bad --phase-jitter value {v:?}"))?;
                if !(phase_jitter.is_finite() && phase_jitter >= 0.0) {
                    bail!("--phase-jitter must be a non-negative seconds value (got {v:?})");
                }
            }
            "--faults" => {
                let v = it.next().ok_or_else(|| anyhow!("--faults needs a value"))?;
                faults = Some(FaultModel::parse(v)?);
            }
            "--recovery" => {
                let v = it.next().ok_or_else(|| anyhow!("--recovery needs a value"))?;
                recovery = Some(Recovery::parse(v)?);
            }
            "--loss" => {
                let v = it.next().ok_or_else(|| anyhow!("--loss needs a value"))?;
                loss = Some(SessionModel::parse(v)?);
            }
            "--session-recovery" => {
                let v =
                    it.next().ok_or_else(|| anyhow!("--session-recovery needs a value"))?;
                session_recovery = Some(SessionRecovery::parse(v)?);
            }
            "--crypto-backend" => {
                let v = it.next().ok_or_else(|| anyhow!("--crypto-backend needs a value"))?;
                crypto_backend = Some(BackendKind::parse(v)?);
            }
            "--json" => json = true,
            other => bail!("unknown fleet flag {other:?}"),
        }
    }
    let (faults, recovery) = check_fault_flags(faults, recovery)?;
    check_session_flags(&loss, session_recovery, &faults)?;
    Ok(Command::Fleet {
        chips,
        frames,
        sample,
        threads,
        policy,
        drift,
        phase_jitter,
        faults,
        recovery,
        loss,
        session_recovery,
        crypto_backend,
        json,
    })
}

/// Parse the `faultsweep` subcommand: `<workload> [--frames N] [--json]`.
fn parse_faultsweep(args: &[String]) -> Result<Command> {
    let workload = args
        .first()
        .cloned()
        .ok_or_else(|| anyhow!("faultsweep needs a workload; try `fulmine workloads`"))?;
    let mut frames = 256usize;
    let mut json = false;
    let mut it = args[1..].iter();
    while let Some(flag) = it.next() {
        match flag.as_str() {
            "--frames" => {
                let v = it.next().ok_or_else(|| anyhow!("--frames needs a value"))?;
                frames = v.parse().map_err(|_| anyhow!("bad --frames value {v:?}"))?;
                if frames == 0 {
                    bail!("--frames must be at least 1 (a stream of 0 frames schedules nothing)");
                }
            }
            "--json" => json = true,
            other => bail!("unknown faultsweep flag {other:?}"),
        }
    }
    Ok(Command::FaultSweep { workload, frames, json })
}

/// Parse the `sessionsweep` subcommand: `[--frames N] [--json]`. The
/// workload is always secure_link — the only registered session
/// workload — so it takes no positional argument.
fn parse_sessionsweep(args: &[String]) -> Result<Command> {
    let mut frames = 256usize;
    let mut json = false;
    let mut it = args.iter();
    while let Some(flag) = it.next() {
        match flag.as_str() {
            "--frames" => {
                let v = it.next().ok_or_else(|| anyhow!("--frames needs a value"))?;
                frames = v.parse().map_err(|_| anyhow!("bad --frames value {v:?}"))?;
                if frames == 0 {
                    bail!("--frames must be at least 1 (a stream of 0 frames schedules nothing)");
                }
            }
            "--json" => json = true,
            other => bail!("unknown sessionsweep flag {other:?}"),
        }
    }
    Ok(Command::SessionSweep { frames, json })
}

/// Execute a parsed command, printing its output to stdout.
pub fn dispatch(cmd: &Command) -> Result<()> {
    match cmd {
        Command::Paper(name) => {
            let text = report::paper_artifact(name)
                .ok_or_else(|| anyhow!("unknown paper artifact {name:?}"))?;
            print!("{text}");
        }
        Command::Workloads => {
            let sys = SocSystem::new();
            for w in sys.registry().iter() {
                println!("{:<14} {}", w.name(), w.describe());
            }
        }
        Command::Ladder { workload, json } => {
            let ladder = SocSystem::new().ladder(workload)?;
            if *json {
                println!("{}", ladder.to_json().render());
            } else {
                print!("{}", ladder.render_text());
            }
        }
        Command::Stream {
            workload,
            frames,
            window,
            shards,
            rung,
            traffic,
            policy,
            faults,
            recovery,
            loss,
            session_recovery,
            crypto_backend,
            json,
        } => {
            let mut spec = RunSpec::new(workload)
                .frames(*frames)
                .shards(*shards)
                .rung(RungSel::parse(rung.as_deref()))
                .traffic(traffic.clone())
                .policy(*policy)
                .faults(faults.clone())
                .recovery(recovery.unwrap_or_default())
                .loss(loss.clone())
                .session_recovery(session_recovery.unwrap_or_default())
                .crypto_backend(*crypto_backend);
            if let Some(w) = window {
                spec = spec.window(*w);
            }
            let run = SocSystem::new().run(&spec)?;
            if *json {
                println!("{}", run.to_json().render());
            } else {
                print!("{}", run.render_text());
            }
        }
        Command::Fleet {
            chips,
            frames,
            sample,
            threads,
            policy,
            drift,
            phase_jitter,
            faults,
            recovery,
            loss,
            session_recovery,
            crypto_backend,
            json,
        } => {
            // A lossy channel only makes sense over session workloads, so
            // `--loss` switches the population from the standard mix to
            // the secure_link rung x traffic mix.
            let base = if loss.is_some() {
                FleetSpec::secure_link(*chips, *frames)
            } else {
                FleetSpec::mixed(*chips, *frames)
            };
            let fleet = base
                .sample_k(*sample)
                .threads(*threads)
                .policy(*policy)
                .drift(*drift)
                .phase_jitter(*phase_jitter)
                .faults(faults.clone())
                .recovery(recovery.unwrap_or_default())
                .loss(loss.clone())
                .session_recovery(session_recovery.unwrap_or_default())
                .crypto_backend(*crypto_backend);
            let report = SocSystem::new().fleet(&fleet)?;
            if *json {
                println!("{}", report.to_json().render());
            } else {
                print!("{}", report.render_text());
            }
        }
        Command::Ablations { json } => {
            let ablations = SocSystem::new().surveillance_ablations()?;
            if *json {
                println!("{}", ablations.to_json().render());
            } else {
                print!("{}", ablations.render_text());
            }
        }
        Command::FaultSweep { workload, frames, json } => {
            let sweep = SocSystem::new().fault_sweep(workload, *frames)?;
            if *json {
                println!("{}", sweep.to_json().render());
            } else {
                print!("{}", sweep.render_text());
            }
        }
        Command::SessionSweep { frames, json } => {
            let sweep = SocSystem::new().session_sweep(*frames)?;
            if *json {
                println!("{}", sweep.to_json().render());
            } else {
                print!("{}", sweep.render_text());
            }
        }
        Command::Artifacts => {
            let mut rt = Runtime::open(default_artifact_dir())?;
            let names: Vec<String> = rt.artifact_names().iter().map(|s| s.to_string()).collect();
            for n in names {
                let t = std::time::Instant::now();
                rt.compile(&n)?;
                let meta = rt.meta(&n).ok_or_else(|| {
                    anyhow!("artifact {n} compiled but has no manifest metadata")
                })?;
                println!(
                    "{n:<22} compiled in {:>7.1} ms   kind={} k={} simd={} inputs={}",
                    t.elapsed().as_secs_f64() * 1e3,
                    meta.kind,
                    meta.k,
                    meta.simd,
                    meta.input_shapes.len()
                );
            }
        }
        Command::Infer { name } => {
            let mut rt = Runtime::open(default_artifact_dir())?;
            let Some(meta) = rt.meta(name).cloned() else {
                bail!("unknown artifact {name}; try `fulmine artifacts`");
            };
            let Some(x_shape) = meta.input_shapes.first() else {
                bail!(
                    "artifact {name} declares no input shapes in its manifest; \
                     cannot generate inputs (regenerate it with `make artifacts`)"
                );
            };
            let x = TensorI16::new(
                x_shape.clone(),
                xorshift_i16(7, x_shape.iter().product(), -2048, 2047),
            );
            let mut inputs = vec![x];
            inputs.extend(gen_params(&meta.input_shapes[1..], meta.simd, 1));
            let t = std::time::Instant::now();
            let out = rt.execute(name, &inputs)?;
            println!(
                "{name}: executed in {:.2} ms; output shape {:?}, first values {:?}",
                t.elapsed().as_secs_f64() * 1e3,
                out[0].shape,
                &out[0].data[..out[0].data.len().min(10)]
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_paper_artifacts_and_all() {
        assert_eq!(parse(&argv(&["fig10"])).unwrap(), Command::Paper("fig10"));
        assert_eq!(parse(&argv(&["all"])).unwrap(), Command::Paper("all"));
        assert!(parse(&argv(&["fig10", "extra"])).is_err());
    }

    #[test]
    fn parses_stream_flags() {
        assert_eq!(
            parse(&argv(&["stream", "surveillance"])).unwrap(),
            Command::Stream {
                workload: "surveillance".into(),
                frames: 8,
                window: None,
                shards: 1,
                rung: None,
                traffic: Traffic::BackToBack,
                policy: None,
                faults: None,
                recovery: None,
                loss: None,
                session_recovery: None,
                crypto_backend: None,
                json: false
            }
        );
        assert_eq!(
            parse(&argv(&["stream", "mixed", "--frames", "4", "--config", "hwce", "--json"]))
                .unwrap(),
            Command::Stream {
                workload: "mixed".into(),
                frames: 4,
                window: None,
                shards: 1,
                rung: Some("hwce".into()),
                traffic: Traffic::BackToBack,
                policy: None,
                faults: None,
                recovery: None,
                loss: None,
                session_recovery: None,
                crypto_backend: None,
                json: true
            }
        );
        assert_eq!(
            parse(&argv(&["stream", "surveillance", "--frames", "4096", "--window", "16"]))
                .unwrap(),
            Command::Stream {
                workload: "surveillance".into(),
                frames: 4096,
                window: Some(16),
                shards: 1,
                rung: None,
                traffic: Traffic::BackToBack,
                policy: None,
                faults: None,
                recovery: None,
                loss: None,
                session_recovery: None,
                crypto_backend: None,
                json: false
            }
        );
        assert_eq!(
            parse(&argv(&["stream", "surveillance", "--frames", "4096", "--shards", "4"]))
                .unwrap(),
            Command::Stream {
                workload: "surveillance".into(),
                frames: 4096,
                window: None,
                shards: 4,
                rung: None,
                traffic: Traffic::BackToBack,
                policy: None,
                faults: None,
                recovery: None,
                loss: None,
                session_recovery: None,
                crypto_backend: None,
                json: false
            }
        );
    }

    /// `--shards 0` (and garbage values) are rejected at parse time.
    #[test]
    fn degenerate_shards_rejected() {
        let e = parse(&argv(&["stream", "surveillance", "--shards", "0"]))
            .unwrap_err()
            .to_string();
        assert!(e.contains("--shards must be at least 1"), "{e}");
        assert!(parse(&argv(&["stream", "surveillance", "--shards"])).is_err());
        assert!(parse(&argv(&["stream", "surveillance", "--shards", "two"])).is_err());
    }

    /// Satellite (window clamp): a `--window` far wider than `--frames`
    /// parses fine and dispatches end-to-end through the real CLI path —
    /// `--shards` wiring included. (The clamped window *value* is pinned
    /// by the façade tests in `system.rs` and the scheduler tests; this
    /// exercises the `dispatch` plumbing those tests bypass.)
    #[test]
    fn oversized_window_dispatches_end_to_end() {
        let cmd = parse(&argv(&[
            "stream", "seizure", "--frames", "2", "--window", "512", "--shards", "2",
        ]))
        .unwrap();
        assert_eq!(
            cmd,
            Command::Stream {
                workload: "seizure".into(),
                frames: 2,
                window: Some(512),
                shards: 2,
                rung: None,
                traffic: Traffic::BackToBack,
                policy: None,
                faults: None,
                recovery: None,
                loss: None,
                session_recovery: None,
                crypto_backend: None,
                json: false
            }
        );
        assert!(dispatch(&cmd).is_ok(), "oversized window must clamp, not fail");
    }

    /// `--window 0` (and garbage values) are rejected at parse time with a
    /// clear message — the window is the memory bound of the stream.
    #[test]
    fn degenerate_window_rejected() {
        let e = parse(&argv(&["stream", "surveillance", "--window", "0"]))
            .unwrap_err()
            .to_string();
        assert!(e.contains("--window must be at least 1"), "{e}");
        assert!(parse(&argv(&["stream", "surveillance", "--window"])).is_err());
        assert!(parse(&argv(&["stream", "surveillance", "--window", "abc"])).is_err());
    }

    /// The former `parse_stream_args` called `usage()` (process exit) on a
    /// missing workload; parsing now returns `Err` on every bad input.
    #[test]
    fn stream_parse_errors_instead_of_exiting() {
        assert!(parse(&argv(&["stream"])).is_err());
        assert!(parse(&argv(&["stream", "surveillance", "--frames"])).is_err());
        assert!(parse(&argv(&["stream", "surveillance", "--frames", "abc"])).is_err());
        assert!(parse(&argv(&["stream", "surveillance", "--bogus"])).is_err());
    }

    /// `--frames 0` would schedule an empty graph; it must be rejected at
    /// parse time with a clear message, as must a bare `stream`.
    #[test]
    fn degenerate_stream_requests_rejected_clearly() {
        let e = parse(&argv(&["stream", "surveillance", "--frames", "0"]))
            .unwrap_err()
            .to_string();
        assert!(e.contains("--frames must be at least 1"), "{e}");
        let e = parse(&argv(&["stream"])).unwrap_err().to_string();
        assert!(e.contains("stream needs a workload"), "{e}");
        // negative values are not a valid usize either
        assert!(parse(&argv(&["stream", "surveillance", "--frames", "-3"])).is_err());
    }

    #[test]
    fn parses_workload_commands() {
        assert_eq!(parse(&argv(&["workloads"])).unwrap(), Command::Workloads);
        assert_eq!(
            parse(&argv(&["ladder", "seizure", "--json"])).unwrap(),
            Command::Ladder { workload: "seizure".into(), json: true }
        );
        assert_eq!(
            parse(&argv(&["ablations"])).unwrap(),
            Command::Ablations { json: false }
        );
        assert!(parse(&argv(&["ladder"])).is_err());
        assert!(parse(&argv(&["ablations", "--verbose"])).is_err());
    }

    /// `--traffic` accepts every model grammar [`Traffic::parse`] knows and
    /// rejects garbage at parse time, before any simulation starts.
    #[test]
    fn parses_traffic_models() {
        assert_eq!(
            parse(&argv(&["stream", "seizure", "--traffic", "periodic:30"])).unwrap(),
            Command::Stream {
                workload: "seizure".into(),
                frames: 8,
                window: None,
                shards: 1,
                rung: None,
                traffic: Traffic::Periodic { rate_hz: 30.0 },
                policy: None,
                faults: None,
                recovery: None,
                loss: None,
                session_recovery: None,
                crypto_backend: None,
                json: false
            }
        );
        assert_eq!(
            parse(&argv(&["stream", "seizure", "--traffic", "poisson:20:7"])).unwrap(),
            Command::Stream {
                workload: "seizure".into(),
                frames: 8,
                window: None,
                shards: 1,
                rung: None,
                traffic: Traffic::Poisson { rate_hz: 20.0, seed: 7 },
                policy: None,
                faults: None,
                recovery: None,
                loss: None,
                session_recovery: None,
                crypto_backend: None,
                json: false
            }
        );
        assert!(parse(&argv(&["stream", "seizure", "--traffic"])).is_err());
        assert!(parse(&argv(&["stream", "seizure", "--traffic", "warp:9"])).is_err());
        assert!(parse(&argv(&["stream", "seizure", "--traffic", "periodic:0"])).is_err());
    }

    /// Satellite (policy flag): `--policy` parses the three policy names
    /// on both subcommands, rejects unknown names with the expected list,
    /// and refuses `--policy oracle` on a back-to-back stream (no release
    /// table to read the future from).
    #[test]
    fn parses_policy_flags_and_rejects_bad_ones() {
        let cmd =
            parse(&argv(&["stream", "seizure", "--traffic", "periodic:2", "--policy", "lookahead"]))
                .unwrap();
        match cmd {
            Command::Stream { policy, .. } => assert_eq!(policy, Some(PolicyKind::Lookahead)),
            other => panic!("expected stream, got {other:?}"),
        }
        let cmd = parse(&argv(&["fleet", "--chips", "4", "--policy", "oracle"])).unwrap();
        match cmd {
            Command::Fleet { policy, .. } => assert_eq!(policy, Some(PolicyKind::Oracle)),
            other => panic!("expected fleet, got {other:?}"),
        }
        // unknown policy names name the accepted set
        for args in [
            vec!["stream", "seizure", "--policy", "eager"],
            vec!["fleet", "--policy", "eager"],
        ] {
            let e = parse(&argv(&args)).unwrap_err().to_string();
            assert!(e.contains("greedy|lookahead|oracle"), "{e}");
        }
        assert!(parse(&argv(&["stream", "seizure", "--policy"])).is_err());
        // oracle needs future arrivals: back-to-back streams are rejected
        let e = parse(&argv(&["stream", "seizure", "--policy", "oracle"]))
            .unwrap_err()
            .to_string();
        assert!(e.contains("release table"), "{e}");
        assert!(
            parse(&argv(&["stream", "seizure", "--policy", "oracle", "--traffic", "poisson:2"]))
                .is_ok(),
            "oracle with a traffic model is fine (flag order must not matter)"
        );
        // greedy/lookahead work on back-to-back streams (stall spans only)
        assert!(parse(&argv(&["stream", "seizure", "--policy", "greedy"])).is_ok());
    }

    /// Satellite (seed grammar): the CLI accepts `poisson:RATE:SEED` and
    /// the seedless `poisson:RATE` (seed defaults to 1), and rejects a
    /// malformed seed before any simulation starts.
    #[test]
    fn poisson_seed_grammar_round_trips_through_cli() {
        let cmd = parse(&argv(&["stream", "seizure", "--traffic", "poisson:3"])).unwrap();
        match cmd {
            Command::Stream { traffic, .. } => {
                assert_eq!(traffic, Traffic::Poisson { rate_hz: 3.0, seed: 1 });
            }
            other => panic!("expected stream, got {other:?}"),
        }
        let e = parse(&argv(&["stream", "seizure", "--traffic", "poisson:3:nope"]))
            .unwrap_err()
            .to_string();
        assert!(e.contains("seed"), "{e}");
        assert!(parse(&argv(&["stream", "seizure", "--traffic", "poisson:"])).is_err());
    }

    /// A managed stream dispatches end-to-end through the real CLI path
    /// (policy plumbed into the spec, battery line rendered).
    #[test]
    fn policy_stream_dispatches_end_to_end() {
        let cmd = parse(&argv(&[
            "stream", "seizure", "--frames", "4", "--traffic", "periodic:2", "--policy",
            "lookahead",
        ]))
        .unwrap();
        assert!(dispatch(&cmd).is_ok(), "managed stream must simulate cleanly");
    }

    /// Bare `fleet` gets the documented defaults; every flag overrides its
    /// field; zero-valued knobs are rejected with actionable messages.
    #[test]
    fn parses_fleet_flags() {
        assert_eq!(
            parse(&argv(&["fleet"])).unwrap(),
            Command::Fleet {
                chips: 1000,
                frames: 32,
                sample: 3,
                threads: 0,
                policy: None,
                drift: 0.0,
                phase_jitter: 0.0,
                faults: None,
                recovery: None,
                loss: None,
                session_recovery: None,
                crypto_backend: None,
                json: false
            }
        );
        assert_eq!(
            parse(&argv(&[
                "fleet", "--chips", "1000000", "--frames", "16", "--sample", "2", "--threads",
                "4", "--json",
            ]))
            .unwrap(),
            Command::Fleet {
                chips: 1_000_000,
                frames: 16,
                sample: 2,
                threads: 4,
                policy: None,
                drift: 0.0,
                phase_jitter: 0.0,
                faults: None,
                recovery: None,
                loss: None,
                session_recovery: None,
                crypto_backend: None,
                json: true
            }
        );
        let e = parse(&argv(&["fleet", "--chips", "0"])).unwrap_err().to_string();
        assert!(e.contains("--chips must be at least 1"), "{e}");
        let e = parse(&argv(&["fleet", "--sample", "0"])).unwrap_err().to_string();
        assert!(e.contains("--sample must be at least 1"), "{e}");
        assert!(parse(&argv(&["fleet", "--frames", "0"])).is_err());
        assert!(parse(&argv(&["fleet", "--bogus"])).is_err());
    }

    /// Satellite (heterogeneity flags): `--drift` and `--phase-jitter`
    /// parse into the spec, and out-of-domain values are rejected at parse
    /// time — the same domains [`FleetSpec`] re-checks at run time.
    #[test]
    fn parses_fleet_heterogeneity_flags() {
        let cmd = parse(&argv(&[
            "fleet", "--chips", "100", "--drift", "2.5", "--phase-jitter", "0.01",
        ]))
        .unwrap();
        match cmd {
            Command::Fleet { drift, phase_jitter, .. } => {
                assert_eq!(drift, 2.5);
                assert_eq!(phase_jitter, 0.01);
            }
            other => panic!("expected fleet, got {other:?}"),
        }
        let e = parse(&argv(&["fleet", "--drift", "-1"])).unwrap_err().to_string();
        assert!(e.contains("--drift must be a percentage in [0, 100)"), "{e}");
        let e = parse(&argv(&["fleet", "--drift", "100"])).unwrap_err().to_string();
        assert!(e.contains("--drift must be a percentage in [0, 100)"), "{e}");
        let e = parse(&argv(&["fleet", "--phase-jitter", "-0.5"])).unwrap_err().to_string();
        assert!(e.contains("--phase-jitter must be a non-negative"), "{e}");
        assert!(parse(&argv(&["fleet", "--drift"])).is_err());
        assert!(parse(&argv(&["fleet", "--drift", "abc"])).is_err());
        assert!(parse(&argv(&["fleet", "--phase-jitter", "nan"])).is_err());
    }

    /// A small heterogeneous fleet dispatches end-to-end through the real
    /// CLI path — parametric families, member derivation, and the
    /// "parametric:" report line included.
    #[test]
    fn heterogeneous_fleet_dispatches_end_to_end() {
        let cmd = parse(&argv(&[
            "fleet", "--chips", "8", "--frames", "2", "--sample", "1", "--drift", "1.5",
            "--phase-jitter", "0.02",
        ]))
        .unwrap();
        assert!(dispatch(&cmd).is_ok(), "heterogeneous fleet must simulate cleanly");
    }

    /// A tiny fleet dispatches end-to-end through the real CLI path —
    /// class dedup, parity sampling, and report rendering included.
    #[test]
    fn small_fleet_dispatches_end_to_end() {
        let cmd = parse(&argv(&["fleet", "--chips", "8", "--frames", "2", "--sample", "1"]))
            .unwrap();
        assert_eq!(
            cmd,
            Command::Fleet {
                chips: 8,
                frames: 2,
                sample: 1,
                threads: 0,
                policy: None,
                drift: 0.0,
                phase_jitter: 0.0,
                faults: None,
                recovery: None,
                loss: None,
                session_recovery: None,
                crypto_backend: None,
                json: false
            }
        );
        assert!(dispatch(&cmd).is_ok(), "small fleet must simulate cleanly");
    }

    /// Satellite (fault flags): `--faults` accepts every model grammar
    /// [`FaultModel::parse`] knows on both subcommands, `--recovery`
    /// parses the three policies, and `--faults none` normalizes to *no
    /// model at all* — bit-for-bit the same command as omitting the flag.
    #[test]
    fn parses_fault_and_recovery_flags() {
        let cmd = parse(&argv(&[
            "stream", "seizure", "--faults", "drop:0.05:7", "--recovery", "retry:5:0.001",
        ]))
        .unwrap();
        match cmd {
            Command::Stream { faults, recovery, .. } => {
                let m = faults.expect("fault model parsed");
                assert_eq!(m.drop_rate, 0.05);
                assert_eq!(m.seed, 7);
                assert_eq!(recovery, Some(Recovery::Retry { max: 5, backoff_s: 0.001 }));
            }
            other => panic!("expected stream, got {other:?}"),
        }
        let cmd = parse(&argv(&[
            "fleet", "--chips", "16", "--faults", "mixed:0.01:0.02:0.001:0.005:3",
            "--recovery", "degrade",
        ]))
        .unwrap();
        match cmd {
            Command::Fleet { faults, recovery, .. } => {
                let m = faults.expect("fault model parsed");
                assert_eq!(m.transient_rate, 0.02);
                assert_eq!(m.seed, 3);
                assert_eq!(recovery, Some(Recovery::Degrade));
            }
            other => panic!("expected fleet, got {other:?}"),
        }
        // `--faults none` IS the unfaulted command, not a third state
        assert_eq!(
            parse(&argv(&["stream", "seizure", "--faults", "none"])).unwrap(),
            parse(&argv(&["stream", "seizure"])).unwrap()
        );
    }

    /// Negative paths of the fault flags: missing values, malformed
    /// models/policies, out-of-domain rates, and `--recovery` without a
    /// fault model are all rejected at parse time with clear messages.
    #[test]
    fn rejects_bad_fault_and_recovery_flags() {
        assert!(parse(&argv(&["stream", "seizure", "--faults"])).is_err());
        assert!(parse(&argv(&["stream", "seizure", "--recovery"])).is_err());
        assert!(parse(&argv(&["fleet", "--faults"])).is_err());
        let e = parse(&argv(&["stream", "seizure", "--faults", "cosmic:0.1"]))
            .unwrap_err()
            .to_string();
        assert!(e.contains("unknown fault model"), "{e}");
        let e = parse(&argv(&["stream", "seizure", "--faults", "drop:1.5"]))
            .unwrap_err()
            .to_string();
        assert!(e.contains("must be in [0, 1]"), "{e}");
        let e = parse(&argv(&["stream", "seizure", "--faults", "drop:0.1", "--recovery", "pray"]))
            .unwrap_err()
            .to_string();
        assert!(e.contains("unknown recovery policy"), "{e}");
        let e = parse(&argv(&["stream", "seizure", "--faults", "drop:0.1", "--recovery",
            "retry:0"]))
            .unwrap_err()
            .to_string();
        assert!(e.contains("retry budget"), "{e}");
        // a recovery policy with nothing to recover from is a spec error
        for args in [
            vec!["stream", "seizure", "--recovery", "retry"],
            vec!["fleet", "--recovery", "reset"],
        ] {
            let e = parse(&argv(&args)).unwrap_err().to_string();
            assert!(e.contains("--recovery without --faults"), "{e}");
        }
    }

    /// A faulted stream dispatches end-to-end through the real CLI path —
    /// fault plan built, recovery billed, reliability line rendered.
    #[test]
    fn faulted_stream_dispatches_end_to_end() {
        let cmd = parse(&argv(&[
            "stream", "seizure", "--frames", "16", "--faults", "mixed:0.1:0.1:0.02:0.05:5",
            "--recovery", "retry:2:0.001",
        ]))
        .unwrap();
        assert!(dispatch(&cmd).is_ok(), "faulted stream must simulate cleanly");
    }

    /// `faultsweep` parses its grammar, rejects garbage, and a small
    /// sweep dispatches end-to-end.
    #[test]
    fn parses_and_dispatches_faultsweep() {
        assert_eq!(
            parse(&argv(&["faultsweep", "seizure", "--frames", "16", "--json"])).unwrap(),
            Command::FaultSweep { workload: "seizure".into(), frames: 16, json: true }
        );
        let e = parse(&argv(&["faultsweep"])).unwrap_err().to_string();
        assert!(e.contains("faultsweep needs a workload"), "{e}");
        assert!(parse(&argv(&["faultsweep", "seizure", "--frames", "0"])).is_err());
        assert!(parse(&argv(&["faultsweep", "seizure", "--bogus"])).is_err());
        let cmd = parse(&argv(&["faultsweep", "seizure", "--frames", "16"])).unwrap();
        assert!(dispatch(&cmd).is_ok(), "small fault sweep must simulate cleanly");
    }

    /// Satellite (session flags): `--loss` accepts the `RATE[:SEED]`
    /// grammar on both subcommands, `--session-recovery` parses the
    /// three policies, `--crypto-backend` the three cost models — and
    /// `--loss 0` is *kept* (a perfect channel still handshakes at
    /// frame 0), unlike `--faults none` which normalizes away.
    #[test]
    fn parses_session_flags() {
        let cmd = parse(&argv(&[
            "stream", "secure_link", "--loss", "0.1:7", "--session-recovery", "degrade",
            "--crypto-backend", "insram",
        ]))
        .unwrap();
        match cmd {
            Command::Stream { loss, session_recovery, crypto_backend, .. } => {
                let m = loss.expect("channel model parsed");
                assert_eq!(m.loss_rate, 0.1);
                assert_eq!(m.seed, 7);
                assert_eq!(session_recovery, Some(SessionRecovery::Degrade));
                assert_eq!(crypto_backend, Some(BackendKind::InSram));
            }
            other => panic!("expected stream, got {other:?}"),
        }
        let cmd = parse(&argv(&[
            "fleet", "--chips", "16", "--loss", "0.2", "--crypto-backend", "sw",
        ]))
        .unwrap();
        match cmd {
            Command::Fleet { loss, session_recovery, crypto_backend, .. } => {
                let m = loss.expect("channel model parsed");
                assert_eq!(m.loss_rate, 0.2);
                assert_eq!(m.seed, 1, "seed defaults to 1");
                assert_eq!(session_recovery, None, "recovery defaults at dispatch time");
                assert_eq!(crypto_backend, Some(BackendKind::Software));
            }
            other => panic!("expected fleet, got {other:?}"),
        }
        // `--loss 0` is a real (perfect) channel, not the absent one
        let cmd = parse(&argv(&["stream", "secure_link", "--loss", "0"])).unwrap();
        match cmd {
            Command::Stream { loss, .. } => {
                assert_eq!(loss, Some(SessionModel::lossless()));
            }
            other => panic!("expected stream, got {other:?}"),
        }
        // `--crypto-backend` stands alone: no channel required
        assert!(parse(&argv(&["stream", "seizure", "--crypto-backend", "sw"])).is_ok());
    }

    /// Negative paths of the session flags: missing values, out-of-domain
    /// rates, unknown policies/backends, `--session-recovery` without a
    /// channel, and `--loss` stacked on `--faults` are all rejected at
    /// parse time with clear messages.
    #[test]
    fn rejects_bad_session_flags() {
        assert!(parse(&argv(&["stream", "secure_link", "--loss"])).is_err());
        assert!(parse(&argv(&["stream", "secure_link", "--session-recovery"])).is_err());
        assert!(parse(&argv(&["stream", "secure_link", "--crypto-backend"])).is_err());
        assert!(parse(&argv(&["fleet", "--loss"])).is_err());
        let e = parse(&argv(&["stream", "secure_link", "--loss", "1.5"]))
            .unwrap_err()
            .to_string();
        assert!(e.contains("must be in [0, 1)"), "{e}");
        let e = parse(&argv(&["stream", "secure_link", "--loss", "1"]))
            .unwrap_err()
            .to_string();
        assert!(e.contains("must be in [0, 1)"), "{e}");
        assert!(parse(&argv(&["stream", "secure_link", "--loss", "abc"])).is_err());
        assert!(parse(&argv(&["stream", "secure_link", "--loss", "0.1:nope"])).is_err());
        let e = parse(&argv(&[
            "stream", "secure_link", "--loss", "0.1", "--session-recovery", "pray",
        ]))
        .unwrap_err()
        .to_string();
        assert!(e.contains("unknown session recovery"), "{e}");
        let e = parse(&argv(&["stream", "secure_link", "--crypto-backend", "quantum"]))
            .unwrap_err()
            .to_string();
        assert!(e.contains("unknown crypto backend"), "{e}");
        // a recovery policy with no channel to recover is a spec error
        for args in [
            vec!["stream", "secure_link", "--session-recovery", "resume"],
            vec!["fleet", "--session-recovery", "full"],
        ] {
            let e = parse(&argv(&args)).unwrap_err().to_string();
            assert!(e.contains("--session-recovery without --loss"), "{e}");
        }
        // one failure model per run: a lossy channel excludes frame faults
        for args in [
            vec!["stream", "secure_link", "--loss", "0.1", "--faults", "drop:0.1"],
            vec!["fleet", "--faults", "drop:0.1", "--loss", "0.1"],
        ] {
            let e = parse(&argv(&args)).unwrap_err().to_string();
            assert!(e.contains("mutually exclusive"), "{e}");
        }
    }

    /// A lossy secure-link stream and a secure-link fleet both dispatch
    /// end-to-end through the real CLI path — session plan built,
    /// retransmissions billed, session lines rendered.
    #[test]
    fn secure_link_dispatches_end_to_end() {
        let cmd = parse(&argv(&[
            "stream", "secure_link", "--frames", "16", "--loss", "0.3:7",
            "--session-recovery", "resume", "--crypto-backend", "sw",
        ]))
        .unwrap();
        assert!(dispatch(&cmd).is_ok(), "lossy secure-link stream must simulate cleanly");
        let cmd = parse(&argv(&[
            "fleet", "--chips", "8", "--frames", "2", "--sample", "1", "--loss", "0.3:7",
        ]))
        .unwrap();
        assert!(dispatch(&cmd).is_ok(), "secure-link fleet must simulate cleanly");
    }

    /// `sessionsweep` parses its grammar, rejects garbage, and a small
    /// sweep dispatches end-to-end.
    #[test]
    fn parses_and_dispatches_sessionsweep() {
        assert_eq!(
            parse(&argv(&["sessionsweep"])).unwrap(),
            Command::SessionSweep { frames: 256, json: false }
        );
        assert_eq!(
            parse(&argv(&["sessionsweep", "--frames", "8", "--json"])).unwrap(),
            Command::SessionSweep { frames: 8, json: true }
        );
        assert!(parse(&argv(&["sessionsweep", "--frames", "0"])).is_err());
        assert!(parse(&argv(&["sessionsweep", "--bogus"])).is_err());
        let cmd = parse(&argv(&["sessionsweep", "--frames", "4"])).unwrap();
        assert!(dispatch(&cmd).is_ok(), "small session sweep must simulate cleanly");
    }

    #[test]
    fn parses_runtime_commands_and_rejects_unknown() {
        assert_eq!(parse(&argv(&["artifacts"])).unwrap(), Command::Artifacts);
        assert_eq!(
            parse(&argv(&["infer", "quickstart_conv_w4"])).unwrap(),
            Command::Infer { name: "quickstart_conv_w4".into() }
        );
        assert!(parse(&argv(&["infer"])).is_err());
        assert!(parse(&argv(&[])).is_err());
        assert!(parse(&argv(&["frobnicate"])).is_err());
    }
}
