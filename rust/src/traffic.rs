//! Per-chip traffic models: when do frames *arrive* at an endpoint?
//!
//! The §IV use cases stream back-to-back — each frame's input is assumed
//! resident the moment the window has room. A deployed endpoint is paced
//! by its sensor instead: a camera delivers frames at a fixed rate, an
//! EEG front-end in windowed bursts, an event-driven trigger at random
//! (Poisson) instants. A [`Traffic`] model turns those arrival processes
//! into a deterministic *release table* — `release[f]` is the earliest
//! simulated time frame `f` may start — which
//! [`crate::soc::sched::StreamScheduler::run_traffic`] enforces as
//! admission gates and [`crate::system::Fleet`] uses as part of the chip
//! class key (two chips with the same workload, rung *and* traffic phase
//! are simulation-identical).
//!
//! Everything is seeded and wall-clock free: a [`Traffic::Poisson`] model
//! carries its own xorshift64* seed, so the same spec replays bitwise on
//! any host, any thread count, any run.

use anyhow::{bail, Result};

/// A deterministic frame-arrival process. Times are simulated seconds;
/// frame 0 always releases at `t = 0` (the stream starts when the first
/// sample is in).
#[derive(Debug, Clone, PartialEq)]
pub enum Traffic {
    /// Every frame is ready immediately (the PR 5 semantics): the window,
    /// not the sensor, is the only admission limit.
    BackToBack,
    /// Fixed-rate sensor: frame `f` releases at `f / rate_hz`. A rate the
    /// pipeline cannot sustain leaves the scheduler input-starved
    /// (gap-dominated); a rate faster than the frame makespan degrades to
    /// back-to-back — releases in the past gate nothing.
    Periodic { rate_hz: f64 },
    /// Windowed acquisition: frames arrive `burst` at a time, bursts at
    /// `rate_hz` (frame `f` releases at `⌊f / burst⌋ / rate_hz`). Models
    /// e.g. an EEG front-end handing over one multi-channel window per
    /// acquisition period.
    Bursty { burst: usize, rate_hz: f64 },
    /// Event-driven trigger: exponential inter-arrival gaps with mean
    /// `1 / rate_hz`, drawn from a seeded xorshift64* stream. Fully
    /// deterministic — the same `(rate_hz, seed)` yields the same release
    /// table everywhere.
    Poisson { rate_hz: f64, seed: u64 },
}

impl Traffic {
    /// Validate the model parameters (finite positive rates, non-zero
    /// burst).
    pub fn validate(&self) -> Result<()> {
        let rate = match *self {
            Traffic::BackToBack => return Ok(()),
            Traffic::Periodic { rate_hz } => rate_hz,
            Traffic::Bursty { burst, rate_hz } => {
                if burst == 0 {
                    bail!("bursty traffic needs a burst of at least 1 frame");
                }
                rate_hz
            }
            Traffic::Poisson { rate_hz, .. } => rate_hz,
        };
        if !(rate.is_finite() && rate > 0.0) {
            bail!("traffic rate must be finite and > 0 Hz, got {rate}");
        }
        Ok(())
    }

    /// The release table for a `frames`-long stream: non-decreasing,
    /// `release[0] == 0`. [`Traffic::BackToBack`] returns an empty table
    /// (the scheduler's no-gating fast path).
    pub fn release_times(&self, frames: usize) -> Vec<f64> {
        match *self {
            Traffic::BackToBack => Vec::new(),
            Traffic::Periodic { rate_hz } => {
                (0..frames).map(|f| f as f64 / rate_hz).collect()
            }
            Traffic::Bursty { burst, rate_hz } => {
                (0..frames).map(|f| (f / burst) as f64 / rate_hz).collect()
            }
            Traffic::Poisson { rate_hz, seed } => {
                let mut rng = Xorshift64Star::new(seed);
                let mut t = 0.0f64;
                (0..frames)
                    .map(|f| {
                        if f > 0 {
                            t += -rng.next_unit().ln() / rate_hz;
                        }
                        t
                    })
                    .collect()
            }
        }
    }

    /// True for the ungated model (callers may skip release-table work).
    pub fn is_back_to_back(&self) -> bool {
        matches!(self, Traffic::BackToBack)
    }

    /// Canonical class-key fragment: distinct models (including distinct
    /// Poisson seeds — different phase, different schedule) map to
    /// distinct keys, bit-exactly (`f64::to_bits`, not display rounding).
    pub fn key(&self) -> String {
        match *self {
            Traffic::BackToBack => "b2b".into(),
            Traffic::Periodic { rate_hz } => format!("per:{:016x}", rate_hz.to_bits()),
            Traffic::Bursty { burst, rate_hz } => {
                format!("bur:{burst}:{:016x}", rate_hz.to_bits())
            }
            Traffic::Poisson { rate_hz, seed } => {
                format!("poi:{:016x}:{seed:016x}", rate_hz.to_bits())
            }
        }
    }

    /// Human-readable description for reports.
    pub fn describe(&self) -> String {
        match *self {
            Traffic::BackToBack => "back-to-back".into(),
            Traffic::Periodic { rate_hz } => format!("periodic {rate_hz} Hz"),
            Traffic::Bursty { burst, rate_hz } => {
                format!("bursty {burst}x @ {rate_hz} Hz")
            }
            Traffic::Poisson { rate_hz, seed } => {
                format!("poisson {rate_hz} Hz (seed {seed})")
            }
        }
    }

    /// Parse a CLI spec: `backtoback`/`b2b`, `periodic:RATE`,
    /// `bursty:BURST:RATE`, `poisson:RATE[:SEED]` (seed defaults to 1).
    pub fn parse(s: &str) -> Result<Traffic> {
        let parts: Vec<&str> = s.split(':').collect();
        let t = match parts[0] {
            "backtoback" | "b2b" => {
                if parts.len() != 1 {
                    bail!("back-to-back traffic takes no parameters: {s}");
                }
                Traffic::BackToBack
            }
            "periodic" => {
                if parts.len() != 2 {
                    bail!("expected periodic:RATE_HZ, got {s}");
                }
                Traffic::Periodic { rate_hz: parse_rate(parts[1])? }
            }
            "bursty" => {
                if parts.len() != 3 {
                    bail!("expected bursty:BURST:RATE_HZ, got {s}");
                }
                let burst: usize = parts[1]
                    .parse()
                    .map_err(|_| anyhow::anyhow!("bad burst count {}", parts[1]))?;
                Traffic::Bursty { burst, rate_hz: parse_rate(parts[2])? }
            }
            "poisson" => {
                if parts.len() < 2 || parts.len() > 3 {
                    bail!("expected poisson:RATE_HZ[:SEED], got {s}");
                }
                let seed = match parts.get(2) {
                    Some(p) => p
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad poisson seed {p}"))?,
                    None => 1,
                };
                Traffic::Poisson { rate_hz: parse_rate(parts[1])?, seed }
            }
            other => bail!(
                "unknown traffic model '{other}' (expected backtoback, periodic, bursty or poisson)"
            ),
        };
        t.validate()?;
        Ok(t)
    }
}

fn parse_rate(s: &str) -> Result<f64> {
    s.parse::<f64>()
        .map_err(|_| anyhow::anyhow!("bad rate '{s}' (Hz)"))
}

/// Per-chip numeric perturbation of a shared traffic + frame template: a
/// service-time scale `alpha` (process/temperature drift of the chip's
/// clock tree — the whole chip-local time base, FLL relock included,
/// stretches by `alpha`) and a sensor phase offset `phase_s` (start-up
/// skew of the acquisition front-end, in pre-drift seconds). A member
/// chip's release table is `(r + phase_s) * alpha` — drift also stretches
/// the sensor schedule because the sampling clock derives from the same
/// drifted crystal.
///
/// Both parameters are quantized to dyadic grids (`alpha` to 2⁻¹²,
/// `phase_s` to 2⁻²⁰ s) so that perturbed chips dedup onto a bounded
/// member-key space and so that test arithmetic can stay exactly
/// representable. Two chips with equal [`Perturb::key`] are
/// simulation-identical members of the same parametric family.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Perturb {
    /// Service-time scale factor (1.0 = nominal silicon).
    pub alpha: f64,
    /// Sensor phase offset in pre-drift seconds (≥ 0).
    pub phase_s: f64,
}

/// Quantization grid for the drift scale: multiples of 2⁻¹².
const ALPHA_GRID: f64 = 4096.0;
/// Quantization grid for the phase offset: multiples of 2⁻²⁰ s (~1 µs).
const PHASE_GRID: f64 = 1048576.0;

/// Mix a base seed with a per-item index into an independent RNG seed —
/// the shared discipline for everything that derives one deterministic
/// draw stream per chip or per frame ([`Perturb::derive`], the
/// [`crate::fault::FaultModel`] per-frame fault draws): the golden-ratio
/// multiply decorrelates adjacent indices, and because the result depends
/// only on `(seed, index)` the derived stream is invariant across shard
/// splits, thread counts and hosts.
pub(crate) fn mix_seed(seed: u64, index: u64) -> u64 {
    seed ^ index.wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

impl Perturb {
    /// The nominal chip: no drift, no phase skew.
    pub const IDENTITY: Perturb = Perturb { alpha: 1.0, phase_s: 0.0 };

    pub fn is_identity(&self) -> bool {
        self.alpha == 1.0 && self.phase_s == 0.0
    }

    /// Deterministically derive chip `chip`'s perturbation from the fleet
    /// seed: `alpha` uniform in `1 ± drift_pct/100`, `phase_s` uniform in
    /// `[0, jitter_s]`, both snapped to their dyadic grids. The same
    /// `(seed, chip)` pair yields the same perturbation on any host.
    pub fn derive(seed: u64, chip: u64, drift_pct: f64, jitter_s: f64) -> Perturb {
        if drift_pct == 0.0 && jitter_s == 0.0 {
            return Perturb::IDENTITY;
        }
        let mut rng = Xorshift64Star::new(mix_seed(seed ^ 0x5EED_D81F, chip));
        let u1 = rng.next_unit();
        let u2 = rng.next_unit();
        let alpha = if drift_pct > 0.0 {
            let raw = 1.0 + drift_pct / 100.0 * (2.0 * u1 - 1.0);
            ((raw * ALPHA_GRID).round() / ALPHA_GRID).max(1.0 / ALPHA_GRID)
        } else {
            1.0
        };
        let phase_s = if jitter_s > 0.0 {
            (jitter_s * u2 * PHASE_GRID).round() / PHASE_GRID
        } else {
            0.0
        };
        Perturb { alpha, phase_s }
    }

    /// Canonical member-key fragment inside a parametric family — bit-exact
    /// (`f64::to_bits`), injective over distinct quantized perturbations.
    pub fn key(&self) -> String {
        format!("a{:016x}:p{:016x}", self.alpha.to_bits(), self.phase_s.to_bits())
    }

    /// Apply the perturbation to a release table in place:
    /// `r ← (r + phase_s) · alpha`. An empty table (back-to-back) stays
    /// empty — phase skew is meaningless without a sensor schedule.
    pub fn apply(&self, release: &mut [f64]) {
        for r in release.iter_mut() {
            *r = (*r + self.phase_s) * self.alpha;
        }
    }

    pub fn describe(&self) -> String {
        format!("alpha {:.6}, phase {:.6e} s", self.alpha, self.phase_s)
    }
}

/// xorshift64* — tiny, seeded, statistically adequate for inter-arrival
/// draws, and (unlike `rand`) dependency-free. Zero seeds are remapped so
/// the state never sticks. Crate-internal: the fleet runner reuses it for
/// parity-sample member selection.
pub(crate) struct Xorshift64Star {
    state: u64,
}

impl Xorshift64Star {
    pub(crate) fn new(seed: u64) -> Self {
        Xorshift64Star {
            state: if seed == 0 { 0x9E37_79B9_7F4A_7C15 } else { seed },
        }
    }

    pub(crate) fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform in (0, 1] — the `+1` keeps `ln` off zero.
    pub(crate) fn next_unit(&mut self) -> f64 {
        ((self.next_u64() >> 11) + 1) as f64 * (1.0 / (1u64 << 53) as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn back_to_back_is_empty() {
        assert!(Traffic::BackToBack.release_times(64).is_empty());
        assert!(Traffic::BackToBack.is_back_to_back());
    }

    #[test]
    fn periodic_release_times() {
        let r = Traffic::Periodic { rate_hz: 4.0 }.release_times(4);
        assert_eq!(r, vec![0.0, 0.25, 0.5, 0.75]);
    }

    #[test]
    fn bursty_release_times_group_frames() {
        let r = Traffic::Bursty { burst: 3, rate_hz: 2.0 }.release_times(7);
        assert_eq!(r, vec![0.0, 0.0, 0.0, 0.5, 0.5, 0.5, 1.0]);
    }

    #[test]
    fn release_tables_start_at_zero_and_never_decrease() {
        let models = [
            Traffic::Periodic { rate_hz: 7.3 },
            Traffic::Bursty { burst: 5, rate_hz: 0.9 },
            Traffic::Poisson { rate_hz: 3.0, seed: 42 },
        ];
        for m in models {
            let r = m.release_times(257);
            assert_eq!(r[0], 0.0, "{m:?}");
            for w in r.windows(2) {
                assert!(w[1] >= w[0], "{m:?} decreased: {w:?}");
            }
            assert!(r.iter().all(|x| x.is_finite()));
        }
    }

    #[test]
    fn poisson_is_reproducible_and_seed_sensitive() {
        let a = Traffic::Poisson { rate_hz: 5.0, seed: 7 }.release_times(100);
        let b = Traffic::Poisson { rate_hz: 5.0, seed: 7 }.release_times(100);
        assert_eq!(
            a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            b.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "same seed must replay bitwise"
        );
        let c = Traffic::Poisson { rate_hz: 5.0, seed: 8 }.release_times(100);
        assert_ne!(a, c, "different seeds must differ");
        // A prefix is a prefix: the table for fewer frames is the head of
        // the longer table (shard splits rely on per-chip regeneration,
        // not table slicing, but prefix stability keeps the two equal).
        let d = Traffic::Poisson { rate_hz: 5.0, seed: 7 }.release_times(40);
        assert_eq!(&a[..40], &d[..]);
    }

    #[test]
    fn poisson_zero_seed_is_remapped() {
        let r = Traffic::Poisson { rate_hz: 1.0, seed: 0 }.release_times(10);
        assert!(r[9] > 0.0, "zero seed must still draw gaps");
    }

    #[test]
    fn parse_round_trips() {
        assert_eq!(Traffic::parse("b2b").unwrap(), Traffic::BackToBack);
        assert_eq!(Traffic::parse("backtoback").unwrap(), Traffic::BackToBack);
        assert_eq!(
            Traffic::parse("periodic:2.5").unwrap(),
            Traffic::Periodic { rate_hz: 2.5 }
        );
        assert_eq!(
            Traffic::parse("bursty:4:0.5").unwrap(),
            Traffic::Bursty { burst: 4, rate_hz: 0.5 }
        );
        assert_eq!(
            Traffic::parse("poisson:3:99").unwrap(),
            Traffic::Poisson { rate_hz: 3.0, seed: 99 }
        );
        assert_eq!(
            Traffic::parse("poisson:3").unwrap(),
            Traffic::Poisson { rate_hz: 3.0, seed: 1 }
        );
        assert!(Traffic::parse("periodic:-1").is_err());
        assert!(Traffic::parse("periodic:0").is_err());
        assert!(Traffic::parse("bursty:0:1").is_err());
        assert!(Traffic::parse("warp:9").is_err());
        assert!(Traffic::parse("b2b:1").is_err());
    }

    #[test]
    fn perturb_derivation_is_deterministic_and_quantized() {
        let a = Perturb::derive(0xF1EE7, 42, 6.25, 0.0156_25);
        let b = Perturb::derive(0xF1EE7, 42, 6.25, 0.0156_25);
        assert_eq!(a.alpha.to_bits(), b.alpha.to_bits());
        assert_eq!(a.phase_s.to_bits(), b.phase_s.to_bits());
        // dyadic grids: alpha on 2⁻¹², phase on 2⁻²⁰ s
        assert_eq!((a.alpha * 4096.0).fract(), 0.0);
        assert_eq!((a.phase_s * 1048576.0).fract(), 0.0);
        assert!(a.alpha >= 1.0 - 0.0625 && a.alpha <= 1.0 + 0.0625, "{}", a.alpha);
        assert!(a.phase_s >= 0.0 && a.phase_s <= 0.015_625 + 1e-12);
        // different chips draw different perturbations (w.h.p. — pinned)
        let c = Perturb::derive(0xF1EE7, 43, 6.25, 0.015_625);
        assert!(a != c, "adjacent chips should perturb differently");
        // zero specs collapse to the identity member
        assert_eq!(Perturb::derive(1, 2, 0.0, 0.0), Perturb::IDENTITY);
        assert!(Perturb::IDENTITY.is_identity());
        assert!(!a.is_identity());
    }

    #[test]
    fn perturb_keys_are_injective_and_apply_shifts_then_scales() {
        let mut keys = std::collections::BTreeSet::new();
        for chip in 0..256u64 {
            keys.insert(Perturb::derive(7, chip, 3.125, 0.01).key());
        }
        assert!(keys.len() > 64, "quantized members should still spread: {}", keys.len());
        assert!(keys.insert(Perturb::IDENTITY.key()), "identity key must be distinct");

        let p = Perturb { alpha: 0.5, phase_s: 0.25 };
        let mut r = vec![0.0, 1.0, 2.0];
        p.apply(&mut r);
        assert_eq!(r, vec![0.125, 0.625, 1.125]);
        let mut empty: Vec<f64> = Vec::new();
        p.apply(&mut empty);
        assert!(empty.is_empty());
    }

    #[test]
    fn keys_distinguish_models_and_seeds() {
        let models = [
            Traffic::BackToBack,
            Traffic::Periodic { rate_hz: 2.0 },
            Traffic::Periodic { rate_hz: 2.5 },
            Traffic::Bursty { burst: 4, rate_hz: 2.0 },
            Traffic::Poisson { rate_hz: 2.0, seed: 1 },
            Traffic::Poisson { rate_hz: 2.0, seed: 2 },
        ];
        let keys: std::collections::BTreeSet<String> =
            models.iter().map(|m| m.key()).collect();
        assert_eq!(keys.len(), models.len(), "class keys must be injective");
    }
}
