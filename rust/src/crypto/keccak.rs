//! The KECCAK-f[400] permutation (16-bit lanes, 5×5 state, 20 rounds) used by
//! the HWCRYPT sponge engine — "a smaller version of the SHA-3 permutation"
//! (§II-B). Round count is configurable as the hardware allows: any multiple
//! of three (the datapath executes three rounds per clock) or the full 20
//! rounds of the KECCAK-f[400] specification.

/// Lane width in bits (w = 16 for KECCAK-f[400]; b = 25·w = 400).
pub const LANE_BITS: u32 = 16;
/// Specified number of rounds: 12 + 2·log2(w) = 20.
pub const FULL_ROUNDS: usize = 20;
/// State size in bytes (400 bits / 8 = 50).
pub const STATE_BYTES: usize = 50;

/// Round constants: the standard KECCAK RC table truncated to the 16-bit lane
/// width (the RC generation LFSR only sets bits at positions 2^j − 1, so for
/// w = 16 the bits at 0, 1, 3, 7, 15 survive).
pub const RC: [u16; FULL_ROUNDS] = [
    0x0001, 0x8082, 0x808a, 0x8000, 0x808b, 0x0001, 0x8081, 0x8009, 0x008a, 0x0088, 0x8009, 0x000a,
    0x808b, 0x008b, 0x8089, 0x8003, 0x8002, 0x0080, 0x800a, 0x000a,
];

/// Rho rotation offsets (mod 16), indexed `[x][y]` as in the KECCAK spec.
const RHO: [[u32; 5]; 5] = [
    [0, 36 % 16, 3, 41 % 16, 18 % 16],
    [1, 44 % 16, 10, 45 % 16, 2],
    [62 % 16, 6, 43 % 16, 15, 61 % 16],
    [28 % 16, 55 % 16, 25 % 16, 21 % 16, 56 % 16],
    [27 % 16, 20 % 16, 39 % 16, 8, 14],
];

/// The 5×5 lane state. Lane `(x, y)` is `lanes[x + 5*y]`, matching the
/// spec's A[x, y] indexing; byte serialization is lane-ordered little-endian
/// (lane (0,0) first), as in the Keccak reference code.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct State {
    pub lanes: [u16; 25],
}

impl State {
    pub fn zero() -> Self {
        State { lanes: [0; 25] }
    }

    pub fn from_bytes(bytes: &[u8; STATE_BYTES]) -> Self {
        let mut lanes = [0u16; 25];
        for (i, lane) in lanes.iter_mut().enumerate() {
            *lane = u16::from_le_bytes([bytes[2 * i], bytes[2 * i + 1]]);
        }
        State { lanes }
    }

    pub fn to_bytes(&self) -> [u8; STATE_BYTES] {
        let mut out = [0u8; STATE_BYTES];
        for (i, lane) in self.lanes.iter().enumerate() {
            out[2 * i..2 * i + 2].copy_from_slice(&lane.to_le_bytes());
        }
        out
    }

    /// XOR `data` into the first `data.len()` bytes of the state (absorb).
    /// Lane-wise (no full-state serialization) — hot in the sponge AE path.
    pub fn xor_bytes(&mut self, data: &[u8]) {
        assert!(data.len() <= STATE_BYTES);
        for (i, d) in data.iter().enumerate() {
            self.lanes[i / 2] ^= (*d as u16) << (8 * (i % 2));
        }
    }

    /// Read the first `n` bytes of the state (squeeze), lane-wise.
    pub fn extract(&self, n: usize) -> Vec<u8> {
        (0..n)
            .map(|i| (self.lanes[i / 2] >> (8 * (i % 2))) as u8)
            .collect()
    }
}

#[inline]
fn theta(a: &mut [u16; 25]) {
    let mut c = [0u16; 5];
    for x in 0..5 {
        c[x] = a[x] ^ a[x + 5] ^ a[x + 10] ^ a[x + 15] ^ a[x + 20];
    }
    for x in 0..5 {
        let d = c[(x + 4) % 5] ^ c[(x + 1) % 5].rotate_left(1);
        for y in 0..5 {
            a[x + 5 * y] ^= d;
        }
    }
}

#[inline]
fn rho_pi(a: &[u16; 25]) -> [u16; 25] {
    let mut b = [0u16; 25];
    for x in 0..5 {
        for y in 0..5 {
            // pi: B[y, 2x+3y] = rot(A[x, y], rho[x][y])
            let nx = y;
            let ny = (2 * x + 3 * y) % 5;
            b[nx + 5 * ny] = a[x + 5 * y].rotate_left(RHO[x][y]);
        }
    }
    b
}

#[inline]
fn chi(b: &[u16; 25]) -> [u16; 25] {
    let mut a = [0u16; 25];
    for y in 0..5 {
        for x in 0..5 {
            a[x + 5 * y] =
                b[x + 5 * y] ^ ((!b[(x + 1) % 5 + 5 * y]) & b[(x + 2) % 5 + 5 * y]);
        }
    }
    a
}

/// One KECCAK-f[400] round with round constant index `ir`.
pub fn round(state: &mut State, ir: usize) {
    theta(&mut state.lanes);
    let b = rho_pi(&state.lanes);
    state.lanes = chi(&b);
    state.lanes[0] ^= RC[ir];
}

/// Apply `nrounds` rounds of KECCAK-f[400] starting from round index 0.
/// The HWCRYPT permits `nrounds` as any multiple of 3, or 20 (the full
/// permutation, which is the security-relevant configuration used by all
/// benchmarks in §III-B).
pub fn permute_rounds(state: &mut State, nrounds: usize) {
    assert!(
        nrounds == FULL_ROUNDS || (nrounds > 0 && nrounds % 3 == 0 && nrounds <= FULL_ROUNDS),
        "HWCRYPT supports multiples of 3 rounds or the full 20"
    );
    for ir in 0..nrounds {
        round(state, ir);
    }
}

/// The full 20-round KECCAK-f[400] permutation.
pub fn permute(state: &mut State) {
    for ir in 0..FULL_ROUNDS {
        round(state, ir);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_state_changes_and_is_deterministic() {
        let mut s1 = State::zero();
        let mut s2 = State::zero();
        permute(&mut s1);
        permute(&mut s2);
        assert_ne!(s1, State::zero());
        assert_eq!(s1, s2);
    }

    #[test]
    fn iota_only_touches_lane00() {
        // With a zero state, the first round's theta/rho/pi/chi are all zero
        // preserving, so only iota contributes: state = RC[0] in lane (0,0).
        let mut s = State::zero();
        round(&mut s, 0);
        assert_eq!(s.lanes[0], RC[0]);
        assert!(s.lanes[1..].iter().all(|&l| l == 0));
    }

    #[test]
    fn rho_preserves_lane_popcount() {
        let mut lanes = [0u16; 25];
        for (i, l) in lanes.iter_mut().enumerate() {
            *l = (i as u16).wrapping_mul(0x9e37) ^ 0x5a5a;
        }
        let before: u32 = lanes.iter().map(|l| l.count_ones()).sum();
        let b = rho_pi(&lanes);
        let after: u32 = b.iter().map(|l| l.count_ones()).sum();
        assert_eq!(before, after);
    }

    #[test]
    fn pi_is_a_lane_permutation() {
        // With all rotations applied, the multiset of lane popcounts must be
        // preserved (rho rotates, pi permutes).
        let mut lanes = [0u16; 25];
        for (i, l) in lanes.iter_mut().enumerate() {
            *l = 1u16 << (i % 16);
        }
        let b = rho_pi(&lanes);
        let mut pb: Vec<u32> = b.iter().map(|l| l.count_ones()).collect();
        let mut pa: Vec<u32> = lanes.iter().map(|l| l.count_ones()).collect();
        pa.sort_unstable();
        pb.sort_unstable();
        assert_eq!(pa, pb);
    }

    #[test]
    fn byte_roundtrip() {
        let mut s = State::zero();
        s.lanes[3] = 0xbeef;
        s.lanes[24] = 0x1234;
        assert_eq!(State::from_bytes(&s.to_bytes()), s);
    }

    #[test]
    fn permutation_diffuses() {
        // single-bit input difference should diffuse to ~half the state
        let mut a = State::zero();
        let mut b = State::zero();
        b.lanes[7] = 1;
        permute(&mut a);
        permute(&mut b);
        let diff: u32 = a
            .lanes
            .iter()
            .zip(&b.lanes)
            .map(|(x, y)| (x ^ y).count_ones())
            .sum();
        assert!(diff > 120 && diff < 280, "diffusion out of range: {diff}");
    }

    #[test]
    fn partial_rounds_supported() {
        let mut s = State::zero();
        permute_rounds(&mut s, 3);
        let mut t = State::zero();
        for ir in 0..3 {
            round(&mut t, ir);
        }
        assert_eq!(s, t);
    }

    #[test]
    #[should_panic]
    fn invalid_round_count_rejected() {
        permute_rounds(&mut State::zero(), 4);
    }
}
