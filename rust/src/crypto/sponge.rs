//! The HWCRYPT sponge engine (§II-B, Fig. 4b): KECCAK-f[400]-based stream
//! encryption and authenticated encryption with a prefix message
//! authentication code.
//!
//! The state is initialized with the key `K` and initial vector `IV`; after
//! each permutation call an `rate`-bit encryption pad is squeezed and XORed
//! with the plaintext. The hardware runs *two* permutation instances in
//! parallel: one producing the keystream, the other absorbing ciphertext for
//! the MAC — which is why authenticated encryption reaches the same 0.51 cpb
//! as plain sponge encryption (§III-B). Functionally we model the two
//! instances as two [`keccak::State`]s advanced in lockstep.

use super::keccak::{self, State, STATE_BYTES};

/// Sponge configuration: rate in bits (1..=128, power of two per §II-B; we
/// require byte-aligned rates ≥ 8 for byte-stream processing) and round count.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpongeConfig {
    /// Rate in bits per permutation call (8, 16, 32, 64, or 128).
    pub rate_bits: u32,
    /// Rounds per permutation call (multiple of 3, or the full 20).
    pub rounds: usize,
}

impl SpongeConfig {
    /// The maximum-rate, full-security configuration used by the paper's
    /// benchmarks: 128-bit rate, 20 rounds.
    pub const MAX_RATE: SpongeConfig = SpongeConfig { rate_bits: 128, rounds: 20 };

    pub fn rate_bytes(&self) -> usize {
        assert!(
            matches!(self.rate_bits, 8 | 16 | 32 | 64 | 128),
            "byte-aligned power-of-two rate required"
        );
        (self.rate_bits / 8) as usize
    }
}

/// MAC tag length in bytes (128-bit prefix MAC).
pub const TAG_BYTES: usize = 16;

fn init_state(key: &[u8; 16], iv: &[u8; 16], domain: u8) -> State {
    // Fill the 50-byte state with K ‖ IV ‖ domain-separation padding.
    let mut bytes = [0u8; STATE_BYTES];
    bytes[..16].copy_from_slice(key);
    bytes[16..32].copy_from_slice(iv);
    bytes[32] = domain;
    bytes[STATE_BYTES - 1] = 0x80;
    let mut st = State::from_bytes(&bytes);
    keccak::permute_rounds(&mut st, 20);
    st
}

/// Sponge stream encryption *without* authentication (§II-B: "the sponge
/// engine also provides encryption without authentication").
pub fn sponge_encrypt(cfg: SpongeConfig, key: &[u8; 16], iv: &[u8; 16], data: &[u8]) -> Vec<u8> {
    let rate = cfg.rate_bytes();
    let mut st = init_state(key, iv, 0x01);
    let mut out = Vec::with_capacity(data.len());
    for chunk in data.chunks(rate) {
        let pad = st.extract(chunk.len());
        out.extend(chunk.iter().zip(&pad).map(|(p, k)| p ^ k));
        keccak::permute_rounds(&mut st, cfg.rounds);
    }
    out
}

/// Stream decryption (identical keystream).
pub fn sponge_decrypt(cfg: SpongeConfig, key: &[u8; 16], iv: &[u8; 16], data: &[u8]) -> Vec<u8> {
    sponge_encrypt(cfg, key, iv, data)
}

/// Authenticated encryption: returns ciphertext and a 128-bit tag.
///
/// Keystream instance and MAC instance run in parallel as in the hardware;
/// the MAC instance absorbs each ciphertext block before permuting, and the
/// tag is squeezed after a final permutation.
pub fn ae_encrypt(
    cfg: SpongeConfig,
    key: &[u8; 16],
    iv: &[u8; 16],
    plaintext: &[u8],
) -> (Vec<u8>, [u8; TAG_BYTES]) {
    let rate = cfg.rate_bytes();
    let mut enc = init_state(key, iv, 0x01);
    let mut mac = init_state(key, iv, 0x02);
    let mut ct = Vec::with_capacity(plaintext.len());
    for chunk in plaintext.chunks(rate) {
        let pad = enc.extract(chunk.len());
        let cblock: Vec<u8> = chunk.iter().zip(&pad).map(|(p, k)| p ^ k).collect();
        mac.xor_bytes(&cblock);
        ct.extend_from_slice(&cblock);
        keccak::permute_rounds(&mut enc, cfg.rounds);
        keccak::permute_rounds(&mut mac, cfg.rounds);
    }
    // length + domain padding, then squeeze the tag
    mac.xor_bytes(&(plaintext.len() as u64).to_le_bytes());
    keccak::permute_rounds(&mut mac, cfg.rounds);
    let mut tag = [0u8; TAG_BYTES];
    tag.copy_from_slice(&mac.extract(TAG_BYTES));
    (ct, tag)
}

/// Authenticated decryption; returns `None` if the tag does not verify
/// (integrity/authenticity failure).
pub fn ae_decrypt(
    cfg: SpongeConfig,
    key: &[u8; 16],
    iv: &[u8; 16],
    ciphertext: &[u8],
    tag: &[u8; TAG_BYTES],
) -> Option<Vec<u8>> {
    let rate = cfg.rate_bytes();
    let mut enc = init_state(key, iv, 0x01);
    let mut mac = init_state(key, iv, 0x02);
    let mut pt = Vec::with_capacity(ciphertext.len());
    for chunk in ciphertext.chunks(rate) {
        let pad = enc.extract(chunk.len());
        pt.extend(chunk.iter().zip(&pad).map(|(c, k)| c ^ k));
        mac.xor_bytes(chunk);
        keccak::permute_rounds(&mut enc, cfg.rounds);
        keccak::permute_rounds(&mut mac, cfg.rounds);
    }
    mac.xor_bytes(&(ciphertext.len() as u64).to_le_bytes());
    keccak::permute_rounds(&mut mac, cfg.rounds);
    // constant-time-ish comparison
    let computed = mac.extract(TAG_BYTES);
    let mut diff = 0u8;
    for (a, b) in computed.iter().zip(tag) {
        diff |= a ^ b;
    }
    if diff == 0 {
        Some(pt)
    } else {
        None
    }
}

/// Direct permutation access (§II-B: "direct access to the permutations to
/// allow the software to accelerate any KECCAK-f[400]-based algorithm").
pub fn raw_permute(state: &mut [u8; STATE_BYTES], rounds: usize) {
    let mut st = State::from_bytes(state);
    keccak::permute_rounds(&mut st, rounds);
    *state = st.to_bytes();
}

#[cfg(test)]
mod tests {
    use super::*;

    const KEY: [u8; 16] = [0x0f; 16];
    const IV: [u8; 16] = [0xf0; 16];

    #[test]
    fn stream_roundtrip_all_rates() {
        let data: Vec<u8> = (0..1000).map(|i| (i * 7 + 3) as u8).collect();
        for rate in [8, 16, 32, 64, 128] {
            let cfg = SpongeConfig { rate_bits: rate, rounds: 20 };
            let ct = sponge_encrypt(cfg, &KEY, &IV, &data);
            assert_ne!(ct, data);
            assert_eq!(sponge_decrypt(cfg, &KEY, &IV, &ct), data, "rate={rate}");
        }
    }

    #[test]
    fn ae_roundtrip_and_tag_verifies() {
        let cfg = SpongeConfig::MAX_RATE;
        let data = b"near-sensor analytics payload".to_vec();
        let (ct, tag) = ae_encrypt(cfg, &KEY, &IV, &data);
        assert_eq!(ae_decrypt(cfg, &KEY, &IV, &ct, &tag), Some(data));
    }

    #[test]
    fn ae_detects_ciphertext_tamper() {
        let cfg = SpongeConfig::MAX_RATE;
        let data = vec![0x11u8; 333];
        let (mut ct, tag) = ae_encrypt(cfg, &KEY, &IV, &data);
        ct[100] ^= 0x40;
        assert_eq!(ae_decrypt(cfg, &KEY, &IV, &ct, &tag), None);
    }

    #[test]
    fn ae_detects_tag_tamper() {
        let cfg = SpongeConfig::MAX_RATE;
        let data = vec![0x22u8; 64];
        let (ct, mut tag) = ae_encrypt(cfg, &KEY, &IV, &data);
        tag[0] ^= 1;
        assert_eq!(ae_decrypt(cfg, &KEY, &IV, &ct, &tag), None);
    }

    #[test]
    fn ae_detects_truncation() {
        let cfg = SpongeConfig::MAX_RATE;
        let data = vec![0x33u8; 160];
        let (ct, tag) = ae_encrypt(cfg, &KEY, &IV, &data);
        assert_eq!(ae_decrypt(cfg, &KEY, &IV, &ct[..144], &tag), None);
    }

    #[test]
    fn different_iv_different_keystream() {
        let cfg = SpongeConfig::MAX_RATE;
        let data = vec![0u8; 64];
        let c1 = sponge_encrypt(cfg, &KEY, &[1u8; 16], &data);
        let c2 = sponge_encrypt(cfg, &KEY, &[2u8; 16], &data);
        assert_ne!(c1, c2);
    }

    #[test]
    fn reduced_rounds_still_roundtrip() {
        let cfg = SpongeConfig { rate_bits: 128, rounds: 6 };
        let data = vec![0xabu8; 200];
        let ct = sponge_encrypt(cfg, &KEY, &IV, &data);
        assert_eq!(sponge_decrypt(cfg, &KEY, &IV, &ct), data);
    }

    #[test]
    fn raw_permutation_exposed() {
        let mut s = [0u8; STATE_BYTES];
        raw_permute(&mut s, 20);
        assert_ne!(s, [0u8; STATE_BYTES]);
    }
}
