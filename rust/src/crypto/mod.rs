//! Functional models of the HWCRYPT cryptographic primitives (§II-B).
//!
//! Everything is implemented from scratch (no external crypto crates), as the
//! paper's HWCRYPT engine is itself a from-scratch silicon datapath:
//!
//! * [`aes`] — AES-128 block cipher (FIPS-197): S-boxes, key expansion,
//!   encryption and decryption rounds. The HWCRYPT round-key generator
//!   "keeps track of the last round-key during encryption" to seed
//!   decryption; we model the same by deriving the decryption schedule from
//!   the final round key.
//! * [`modes`] — ECB and XTS (IEEE P1619 / NIST SP 800-38E) with the
//!   sequential ⊗2 tweak chain of Eq. (2) and ciphertext stealing; XEX as the
//!   single-key degenerate case.
//! * [`keccak`] — the KECCAK-f[400] permutation (16-bit lanes, 20 rounds,
//!   configurable round count as the HWCRYPT datapath allows multiples of 3
//!   or the full 20).
//! * [`sponge`] — the sponge-based encryption pad and the dual-permutation
//!   authenticated-encryption scheme of Fig. 4b (configurable rate 8..128
//!   bits in powers of two).
//!
//! The *timing* of the hardware engine lives in [`crate::hwcrypt`]; this
//! module is pure data transformation and is shared by the device model, the
//! software-implementation cost models and the use-case pipelines.

pub mod aes;
pub mod keccak;
pub mod modes;
pub mod sponge;
