//! Block-cipher modes of operation implemented by the HWCRYPT AES engine:
//! ECB and XTS (with the sequential ⊗2 tweak chain of Eq. (2) in the paper,
//! and ciphertext stealing for non-block-aligned tails). Using the same key
//! for the tweak and data instances degrades XTS to XEX "without implications
//! to the overall security" (§II-B).

use super::aes::{decrypt_block_fast as decrypt_block, encrypt_block_fast as encrypt_block, KeySchedule};

/// Encrypt data in ECB mode. `data.len()` must be a multiple of 16.
///
/// The paper notes ECB "is not recommended to encrypt larger blocks of data"
/// (equal plaintext blocks leak); it is provided because the silicon
/// implements it and §III-B benchmarks it.
pub fn ecb_encrypt(key: &[u8; 16], data: &[u8]) -> Vec<u8> {
    assert!(data.len() % 16 == 0, "ECB requires whole blocks");
    let ks = KeySchedule::expand(key);
    let mut out = Vec::with_capacity(data.len());
    for chunk in data.chunks_exact(16) {
        let mut b = [0u8; 16];
        b.copy_from_slice(chunk);
        out.extend_from_slice(&encrypt_block(&ks, &b));
    }
    out
}

/// Decrypt data in ECB mode.
pub fn ecb_decrypt(key: &[u8; 16], data: &[u8]) -> Vec<u8> {
    assert!(data.len() % 16 == 0, "ECB requires whole blocks");
    let ks = KeySchedule::expand(key);
    let mut out = Vec::with_capacity(data.len());
    for chunk in data.chunks_exact(16) {
        let mut b = [0u8; 16];
        b.copy_from_slice(chunk);
        out.extend_from_slice(&decrypt_block(&ks, &b));
    }
    out
}

/// Multiply a 128-bit value by α=2 in GF(2^128) mod x^128 + x^7 + x^2 + x + 1
/// — Eq. (2): a left shift with a conditional XOR of the reduction
/// polynomial. XTS convention: the 16 bytes are little-endian, i.e. bit 0 of
/// byte 0 is the least significant coefficient.
#[inline]
pub fn gf128_mul_alpha(t: &[u8; 16]) -> [u8; 16] {
    let mut out = [0u8; 16];
    let mut carry = 0u8;
    for i in 0..16 {
        let b = t[i];
        out[i] = (b << 1) | carry;
        carry = b >> 7;
    }
    if carry != 0 {
        out[0] ^= 0x87; // x^7 + x^2 + x + 1
    }
    out
}

/// XTS dual-key pair. `k1` derives the tweak (encrypts the sector number),
/// `k2` encrypts the data — the paper's Eq. (1) naming (note: IEEE P1619
/// swaps the roles of key1/key2 relative to the paper; we follow P1619's
/// convention key1 = data key, key2 = tweak key so standard test vectors
/// apply, and expose the paper's naming through [`XtsKey::new`]).
#[derive(Clone)]
pub struct XtsKey {
    data_ks: KeySchedule,
    tweak_ks: KeySchedule,
}

impl XtsKey {
    /// `data_key` encrypts blocks, `tweak_key` encrypts the sector number.
    pub fn new(data_key: &[u8; 16], tweak_key: &[u8; 16]) -> Self {
        XtsKey {
            data_ks: KeySchedule::expand(data_key),
            tweak_ks: KeySchedule::expand(tweak_key),
        }
    }

    /// XEX degenerate case: same key for tweak and data (§II-B).
    pub fn xex(key: &[u8; 16]) -> Self {
        Self::new(key, key)
    }

    /// Initial tweak T0 = E_tweak(sector_number), sector number encoded
    /// little-endian as in IEEE P1619.
    pub fn initial_tweak(&self, sector: u128) -> [u8; 16] {
        let sn = sector.to_le_bytes();
        encrypt_block(&self.tweak_ks, &sn)
    }
}

fn xor16(a: &[u8; 16], b: &[u8; 16]) -> [u8; 16] {
    let mut out = [0u8; 16];
    for i in 0..16 {
        out[i] = a[i] ^ b[i];
    }
    out
}

/// XTS-AES-128 encryption of one sector (IEEE P1619). `data.len() >= 16`;
/// a non-multiple-of-16 tail is handled with ciphertext stealing.
pub fn xts_encrypt(key: &XtsKey, sector: u128, data: &[u8]) -> Vec<u8> {
    assert!(data.len() >= 16, "XTS requires at least one block");
    let mut t = key.initial_tweak(sector);
    let nfull = data.len() / 16;
    let tail = data.len() % 16;
    let mut out = vec![0u8; data.len()];

    let whole = if tail == 0 { nfull } else { nfull - 1 };
    for i in 0..whole {
        let mut b = [0u8; 16];
        b.copy_from_slice(&data[16 * i..16 * i + 16]);
        let c = xor16(&encrypt_block(&key.data_ks, &xor16(&b, &t)), &t);
        out[16 * i..16 * i + 16].copy_from_slice(&c);
        t = gf128_mul_alpha(&t);
    }
    if tail != 0 {
        // ciphertext stealing over the last full block + partial block
        let m = whole; // index of last full block
        let mut pm = [0u8; 16];
        pm.copy_from_slice(&data[16 * m..16 * m + 16]);
        let cm = xor16(&encrypt_block(&key.data_ks, &xor16(&pm, &t)), &t);
        let t_next = gf128_mul_alpha(&t);
        // last partial plaintext padded with tail of cm
        let mut plast = [0u8; 16];
        plast[..tail].copy_from_slice(&data[16 * (m + 1)..]);
        plast[tail..].copy_from_slice(&cm[tail..]);
        let clast = xor16(&encrypt_block(&key.data_ks, &xor16(&plast, &t_next)), &t_next);
        out[16 * m..16 * m + 16].copy_from_slice(&clast);
        out[16 * (m + 1)..].copy_from_slice(&cm[..tail]);
    }
    out
}

/// XTS-AES-128 decryption of one sector.
pub fn xts_decrypt(key: &XtsKey, sector: u128, data: &[u8]) -> Vec<u8> {
    assert!(data.len() >= 16, "XTS requires at least one block");
    let mut t = key.initial_tweak(sector);
    let nfull = data.len() / 16;
    let tail = data.len() % 16;
    let mut out = vec![0u8; data.len()];

    let whole = if tail == 0 { nfull } else { nfull - 1 };
    for i in 0..whole {
        let mut b = [0u8; 16];
        b.copy_from_slice(&data[16 * i..16 * i + 16]);
        let p = xor16(&decrypt_block(&key.data_ks, &xor16(&b, &t)), &t);
        out[16 * i..16 * i + 16].copy_from_slice(&p);
        t = gf128_mul_alpha(&t);
    }
    if tail != 0 {
        let m = whole;
        let t_next = gf128_mul_alpha(&t);
        // Ciphertext block m holds E(P_last‖stolen) under t_next; the partial
        // tail holds the head of E(P_m) under t.
        let mut clast = [0u8; 16];
        clast.copy_from_slice(&data[16 * m..16 * m + 16]);
        let plast_full = xor16(&decrypt_block(&key.data_ks, &xor16(&clast, &t_next)), &t_next);
        let mut cfull = [0u8; 16];
        cfull[..tail].copy_from_slice(&data[16 * (m + 1)..]);
        cfull[tail..].copy_from_slice(&plast_full[tail..]);
        let pm = xor16(&decrypt_block(&key.data_ks, &xor16(&cfull, &t)), &t);
        out[16 * m..16 * m + 16].copy_from_slice(&pm);
        out[16 * (m + 1)..].copy_from_slice(&plast_full[..tail]);
    }
    out
}

/// Encrypt a large buffer as a sequence of sectors of `sector_size` bytes
/// (the paper derives the XTS sector number "from the address of the data").
/// This is how the use cases protect weights / partial results in external
/// memory: each `sector_size`-byte chunk at byte offset `off` uses sector
/// number `base_sector + off / sector_size`.
pub fn xts_encrypt_region(key: &XtsKey, base_sector: u128, sector_size: usize, data: &[u8]) -> Vec<u8> {
    assert!(sector_size % 16 == 0 && sector_size > 0);
    let mut out = Vec::with_capacity(data.len());
    for (i, chunk) in data.chunks(sector_size).enumerate() {
        out.extend_from_slice(&xts_encrypt(key, base_sector + i as u128, chunk));
    }
    out
}

/// Inverse of [`xts_encrypt_region`].
pub fn xts_decrypt_region(key: &XtsKey, base_sector: u128, sector_size: usize, data: &[u8]) -> Vec<u8> {
    assert!(sector_size % 16 == 0 && sector_size > 0);
    let mut out = Vec::with_capacity(data.len());
    for (i, chunk) in data.chunks(sector_size).enumerate() {
        out.extend_from_slice(&xts_decrypt(key, base_sector + i as u128, chunk));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hex(s: &str) -> Vec<u8> {
        (0..s.len() / 2)
            .map(|i| u8::from_str_radix(&s[2 * i..2 * i + 2], 16).unwrap())
            .collect()
    }

    /// IEEE P1619 XTS-AES-128 Vector 1: all-zero keys, sector 0, 32 zero bytes.
    #[test]
    fn p1619_vector1() {
        let key = XtsKey::new(&[0u8; 16], &[0u8; 16]);
        let pt = vec![0u8; 32];
        let ct = xts_encrypt(&key, 0, &pt);
        assert_eq!(
            ct,
            hex("917cf69ebd68b2ec9b9fe9a3eadda692cd43d2f59598ed858c02c2652fbf922e")
        );
        assert_eq!(xts_decrypt(&key, 0, &ct), pt);
    }

    /// IEEE P1619 Vector 2: key1=11.., key2=22.., sector 0x3333333333,
    /// plaintext 44*32.
    #[test]
    fn p1619_vector2() {
        let key = XtsKey::new(&[0x11u8; 16], &[0x22u8; 16]);
        let pt = vec![0x44u8; 32];
        let ct = xts_encrypt(&key, 0x3333333333, &pt);
        assert_eq!(
            ct,
            hex("c454185e6a16936e39334038acef838bfb186fff7480adc4289382ecd6d394f0")
        );
        assert_eq!(xts_decrypt(&key, 0x3333333333, &ct), pt);
    }

    #[test]
    fn xts_roundtrip_with_ciphertext_stealing() {
        let key = XtsKey::new(&[7u8; 16], &[9u8; 16]);
        for len in [16, 17, 31, 32, 33, 48, 100, 255, 256, 8192] {
            let pt: Vec<u8> = (0..len).map(|i| (i * 31 + 7) as u8).collect();
            let ct = xts_encrypt(&key, 42, &pt);
            assert_eq!(ct.len(), pt.len());
            assert_eq!(xts_decrypt(&key, 42, &ct), pt, "len={len}");
        }
    }

    #[test]
    fn xts_different_sectors_differ() {
        let key = XtsKey::new(&[7u8; 16], &[9u8; 16]);
        let pt = vec![0xabu8; 64];
        assert_ne!(xts_encrypt(&key, 0, &pt), xts_encrypt(&key, 1, &pt));
    }

    #[test]
    fn xex_is_xts_with_equal_keys() {
        let key = XtsKey::xex(&[5u8; 16]);
        let key2 = XtsKey::new(&[5u8; 16], &[5u8; 16]);
        let pt = vec![1u8; 48];
        assert_eq!(xts_encrypt(&key, 3, &pt), xts_encrypt(&key2, 3, &pt));
    }

    #[test]
    fn ecb_leaks_patterns_xts_does_not() {
        // The §II-B motivation for XTS: equal plaintext blocks map to equal
        // ciphertext blocks in ECB but not in XTS.
        let k = [3u8; 16];
        let pt = [[0x5au8; 16], [0x5au8; 16]].concat();
        let ecb = ecb_encrypt(&k, &pt);
        assert_eq!(ecb[..16], ecb[16..32]);
        let xts = xts_encrypt(&XtsKey::xex(&k), 0, &pt);
        assert_ne!(xts[..16], xts[16..32]);
    }

    #[test]
    fn ecb_roundtrip() {
        let k = [0x42u8; 16];
        let pt: Vec<u8> = (0..256u32).map(|i| i as u8).collect();
        assert_eq!(ecb_decrypt(&k, &ecb_encrypt(&k, &pt)), pt);
    }

    #[test]
    fn gf128_known_doubling() {
        // 1 << 1 == 2 (no reduction)
        let mut one = [0u8; 16];
        one[0] = 1;
        let two = gf128_mul_alpha(&one);
        assert_eq!(two[0], 2);
        // value with MSB set reduces with 0x87
        let mut hi = [0u8; 16];
        hi[15] = 0x80;
        let red = gf128_mul_alpha(&hi);
        assert_eq!(red[0], 0x87);
        assert_eq!(red[15], 0);
    }

    #[test]
    fn region_roundtrip() {
        let key = XtsKey::new(&[1u8; 16], &[2u8; 16]);
        let data: Vec<u8> = (0..10_000).map(|i| (i % 251) as u8).collect();
        let ct = xts_encrypt_region(&key, 100, 512, &data);
        assert_eq!(xts_decrypt_region(&key, 100, 512, &ct), data);
    }
}
