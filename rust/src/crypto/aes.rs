//! AES-128 block cipher (FIPS-197), from scratch.
//!
//! The HWCRYPT AES engine is round-based: two cipher instances, each
//! implementing two rounds per clock, with a shared on-the-fly round-key
//! module. This module provides the *functional* cipher; the per-cycle
//! behaviour (2 rounds/cycle × 2 instances) is modelled in
//! [`crate::hwcrypt`].

/// AES S-box (FIPS-197 Fig. 7).
pub const SBOX: [u8; 256] = [
    0x63, 0x7c, 0x77, 0x7b, 0xf2, 0x6b, 0x6f, 0xc5, 0x30, 0x01, 0x67, 0x2b, 0xfe, 0xd7, 0xab, 0x76,
    0xca, 0x82, 0xc9, 0x7d, 0xfa, 0x59, 0x47, 0xf0, 0xad, 0xd4, 0xa2, 0xaf, 0x9c, 0xa4, 0x72, 0xc0,
    0xb7, 0xfd, 0x93, 0x26, 0x36, 0x3f, 0xf7, 0xcc, 0x34, 0xa5, 0xe5, 0xf1, 0x71, 0xd8, 0x31, 0x15,
    0x04, 0xc7, 0x23, 0xc3, 0x18, 0x96, 0x05, 0x9a, 0x07, 0x12, 0x80, 0xe2, 0xeb, 0x27, 0xb2, 0x75,
    0x09, 0x83, 0x2c, 0x1a, 0x1b, 0x6e, 0x5a, 0xa0, 0x52, 0x3b, 0xd6, 0xb3, 0x29, 0xe3, 0x2f, 0x84,
    0x53, 0xd1, 0x00, 0xed, 0x20, 0xfc, 0xb1, 0x5b, 0x6a, 0xcb, 0xbe, 0x39, 0x4a, 0x4c, 0x58, 0xcf,
    0xd0, 0xef, 0xaa, 0xfb, 0x43, 0x4d, 0x33, 0x85, 0x45, 0xf9, 0x02, 0x7f, 0x50, 0x3c, 0x9f, 0xa8,
    0x51, 0xa3, 0x40, 0x8f, 0x92, 0x9d, 0x38, 0xf5, 0xbc, 0xb6, 0xda, 0x21, 0x10, 0xff, 0xf3, 0xd2,
    0xcd, 0x0c, 0x13, 0xec, 0x5f, 0x97, 0x44, 0x17, 0xc4, 0xa7, 0x7e, 0x3d, 0x64, 0x5d, 0x19, 0x73,
    0x60, 0x81, 0x4f, 0xdc, 0x22, 0x2a, 0x90, 0x88, 0x46, 0xee, 0xb8, 0x14, 0xde, 0x5e, 0x0b, 0xdb,
    0xe0, 0x32, 0x3a, 0x0a, 0x49, 0x06, 0x24, 0x5c, 0xc2, 0xd3, 0xac, 0x62, 0x91, 0x95, 0xe4, 0x79,
    0xe7, 0xc8, 0x37, 0x6d, 0x8d, 0xd5, 0x4e, 0xa9, 0x6c, 0x56, 0xf4, 0xea, 0x65, 0x7a, 0xae, 0x08,
    0xba, 0x78, 0x25, 0x2e, 0x1c, 0xa6, 0xb4, 0xc6, 0xe8, 0xdd, 0x74, 0x1f, 0x4b, 0xbd, 0x8b, 0x8a,
    0x70, 0x3e, 0xb5, 0x66, 0x48, 0x03, 0xf6, 0x0e, 0x61, 0x35, 0x57, 0xb9, 0x86, 0xc1, 0x1d, 0x9e,
    0xe1, 0xf8, 0x98, 0x11, 0x69, 0xd9, 0x8e, 0x94, 0x9b, 0x1e, 0x87, 0xe9, 0xce, 0x55, 0x28, 0xdf,
    0x8c, 0xa1, 0x89, 0x0d, 0xbf, 0xe6, 0x42, 0x68, 0x41, 0x99, 0x2d, 0x0f, 0xb0, 0x54, 0xbb, 0x16,
];

/// Inverse S-box, derived from [`SBOX`] at first use.
fn inv_sbox() -> &'static [u8; 256] {
    use std::sync::OnceLock;
    static INV: OnceLock<[u8; 256]> = OnceLock::new();
    INV.get_or_init(|| {
        let mut inv = [0u8; 256];
        for (i, &s) in SBOX.iter().enumerate() {
            inv[s as usize] = i as u8;
        }
        inv
    })
}

/// Multiply in GF(2^8) modulo x^8 + x^4 + x^3 + x + 1 (0x11b).
#[inline]
pub fn gf_mul(mut a: u8, mut b: u8) -> u8 {
    let mut p = 0u8;
    for _ in 0..8 {
        if b & 1 != 0 {
            p ^= a;
        }
        let hi = a & 0x80;
        a <<= 1;
        if hi != 0 {
            a ^= 0x1b;
        }
        b >>= 1;
    }
    p
}

/// Number of AES-128 rounds.
pub const ROUNDS: usize = 10;

/// Expanded key schedule: 11 round keys of 16 bytes each.
#[derive(Clone)]
pub struct KeySchedule {
    pub round_keys: [[u8; 16]; ROUNDS + 1],
}

impl KeySchedule {
    /// FIPS-197 §5.2 key expansion for a 128-bit key.
    pub fn expand(key: &[u8; 16]) -> Self {
        let mut w = [[0u8; 4]; 4 * (ROUNDS + 1)];
        for i in 0..4 {
            w[i].copy_from_slice(&key[4 * i..4 * i + 4]);
        }
        let mut rcon: u8 = 1;
        for i in 4..4 * (ROUNDS + 1) {
            let mut t = w[i - 1];
            if i % 4 == 0 {
                // RotWord + SubWord + Rcon
                t = [
                    SBOX[t[1] as usize] ^ rcon,
                    SBOX[t[2] as usize],
                    SBOX[t[3] as usize],
                    SBOX[t[0] as usize],
                ];
                rcon = gf_mul(rcon, 2);
            }
            for j in 0..4 {
                w[i][j] = w[i - 4][j] ^ t[j];
            }
        }
        let mut round_keys = [[0u8; 16]; ROUNDS + 1];
        for (r, rk) in round_keys.iter_mut().enumerate() {
            for c in 0..4 {
                rk[4 * c..4 * c + 4].copy_from_slice(&w[4 * r + c]);
            }
        }
        KeySchedule { round_keys }
    }

    /// The last round key — the HWCRYPT round-key generator starts decryption
    /// from here (§II-B: "keeps track of the last round-key during encryption,
    /// which acts as the starting point to generate round-keys for a
    /// decryption operation").
    pub fn last_round_key(&self) -> [u8; 16] {
        self.round_keys[ROUNDS]
    }
}

#[inline]
fn add_round_key(state: &mut [u8; 16], rk: &[u8; 16]) {
    for i in 0..16 {
        state[i] ^= rk[i];
    }
}

#[inline]
fn sub_bytes(state: &mut [u8; 16]) {
    for b in state.iter_mut() {
        *b = SBOX[*b as usize];
    }
}

#[inline]
fn inv_sub_bytes(state: &mut [u8; 16]) {
    let inv = inv_sbox();
    for b in state.iter_mut() {
        *b = inv[*b as usize];
    }
}

/// State layout: column-major as in FIPS-197 — `state[r + 4c]`.
#[inline]
fn shift_rows(s: &mut [u8; 16]) {
    // row r shifted left by r
    let t = *s;
    for r in 1..4 {
        for c in 0..4 {
            s[r + 4 * c] = t[r + 4 * ((c + r) % 4)];
        }
    }
}

#[inline]
fn inv_shift_rows(s: &mut [u8; 16]) {
    let t = *s;
    for r in 1..4 {
        for c in 0..4 {
            s[r + 4 * ((c + r) % 4)] = t[r + 4 * c];
        }
    }
}

#[inline]
fn mix_columns(s: &mut [u8; 16]) {
    for c in 0..4 {
        let col = [s[4 * c], s[4 * c + 1], s[4 * c + 2], s[4 * c + 3]];
        s[4 * c] = gf_mul(col[0], 2) ^ gf_mul(col[1], 3) ^ col[2] ^ col[3];
        s[4 * c + 1] = col[0] ^ gf_mul(col[1], 2) ^ gf_mul(col[2], 3) ^ col[3];
        s[4 * c + 2] = col[0] ^ col[1] ^ gf_mul(col[2], 2) ^ gf_mul(col[3], 3);
        s[4 * c + 3] = gf_mul(col[0], 3) ^ col[1] ^ col[2] ^ gf_mul(col[3], 2);
    }
}

#[inline]
fn inv_mix_columns(s: &mut [u8; 16]) {
    for c in 0..4 {
        let col = [s[4 * c], s[4 * c + 1], s[4 * c + 2], s[4 * c + 3]];
        s[4 * c] = gf_mul(col[0], 14) ^ gf_mul(col[1], 11) ^ gf_mul(col[2], 13) ^ gf_mul(col[3], 9);
        s[4 * c + 1] =
            gf_mul(col[0], 9) ^ gf_mul(col[1], 14) ^ gf_mul(col[2], 11) ^ gf_mul(col[3], 13);
        s[4 * c + 2] =
            gf_mul(col[0], 13) ^ gf_mul(col[1], 9) ^ gf_mul(col[2], 14) ^ gf_mul(col[3], 11);
        s[4 * c + 3] =
            gf_mul(col[0], 11) ^ gf_mul(col[1], 13) ^ gf_mul(col[2], 9) ^ gf_mul(col[3], 14);
    }
}

/// One encryption round (SubBytes, ShiftRows, MixColumns, AddRoundKey) — the
/// primitive the HWCRYPT exposes individually "similar to the Intel AES-NI
/// instructions" for round-based algorithms like AEGIS/AEZ.
pub fn encrypt_round(state: &mut [u8; 16], rk: &[u8; 16]) {
    sub_bytes(state);
    shift_rows(state);
    mix_columns(state);
    add_round_key(state, rk);
}

// --- T-table fast path (§Perf) -------------------------------------------
//
// The straightforward byte-wise rounds above are kept as the readable
// reference (and for the exposed single-round primitive); the block
// en/decryption hot path below uses the classic 4×256 u32 table formulation
// (SubBytes+ShiftRows+MixColumns folded into four lookups per column).
// Equivalence with the reference path is asserted in tests.

struct Tables {
    te: [[u32; 256]; 4],
    /// InvMixColumns-only tables (for the equivalent inverse cipher).
    um: [[u32; 256]; 4],
}

fn tables() -> &'static Tables {
    use std::sync::OnceLock;
    static T: OnceLock<Box<Tables>> = OnceLock::new();
    T.get_or_init(|| {
        let mut te = [[0u32; 256]; 4];
        let mut um = [[0u32; 256]; 4];
        for x in 0..256 {
            let s = SBOX[x];
            let s2 = gf_mul(s, 2);
            let s3 = s2 ^ s;
            // contribution of row-r input byte to the output column
            te[0][x] = u32::from_le_bytes([s2, s, s, s3]);
            te[1][x] = u32::from_le_bytes([s3, s2, s, s]);
            te[2][x] = u32::from_le_bytes([s, s3, s2, s]);
            te[3][x] = u32::from_le_bytes([s, s, s3, s2]);
            let b = x as u8;
            let (e, n, d, nn) = (gf_mul(b, 14), gf_mul(b, 9), gf_mul(b, 13), gf_mul(b, 11));
            um[0][x] = u32::from_le_bytes([e, n, d, nn]);
            um[1][x] = u32::from_le_bytes([nn, e, n, d]);
            um[2][x] = u32::from_le_bytes([d, nn, e, n]);
            um[3][x] = u32::from_le_bytes([n, d, nn, e]);
        }
        Box::new(Tables { te, um })
    })
}

#[inline]
fn col(s: &[u8; 16], c: usize) -> u32 {
    u32::from_le_bytes([s[4 * c], s[4 * c + 1], s[4 * c + 2], s[4 * c + 3]])
}

/// Fast block encryption (T-tables). Bit-identical to [`encrypt_block`].
pub fn encrypt_block_fast(ks: &KeySchedule, block: &[u8; 16]) -> [u8; 16] {
    let t = tables();
    let mut s = *block;
    add_round_key(&mut s, &ks.round_keys[0]);
    for r in 1..ROUNDS {
        let rk = &ks.round_keys[r];
        let mut out = [0u8; 16];
        for c in 0..4 {
            let v = t.te[0][s[4 * c] as usize]
                ^ t.te[1][s[(4 * (c + 1) + 1) % 16] as usize]
                ^ t.te[2][s[(4 * (c + 2) + 2) % 16] as usize]
                ^ t.te[3][s[(4 * (c + 3) + 3) % 16] as usize]
                ^ col(rk, c);
            out[4 * c..4 * c + 4].copy_from_slice(&v.to_le_bytes());
        }
        s = out;
    }
    encrypt_round_last(&mut s, &ks.round_keys[ROUNDS]);
    s
}

/// Fast block decryption. Bit-identical to [`decrypt_block`].
pub fn decrypt_block_fast(ks: &KeySchedule, block: &[u8; 16]) -> [u8; 16] {
    let t = tables();
    let mut s = *block;
    add_round_key(&mut s, &ks.round_keys[ROUNDS]);
    for r in (1..ROUNDS).rev() {
        inv_shift_rows(&mut s);
        inv_sub_bytes(&mut s);
        add_round_key(&mut s, &ks.round_keys[r]);
        // InvMixColumns via tables
        let mut out = [0u8; 16];
        for c in 0..4 {
            let v = t.um[0][s[4 * c] as usize]
                ^ t.um[1][s[4 * c + 1] as usize]
                ^ t.um[2][s[4 * c + 2] as usize]
                ^ t.um[3][s[4 * c + 3] as usize];
            out[4 * c..4 * c + 4].copy_from_slice(&v.to_le_bytes());
        }
        s = out;
    }
    inv_shift_rows(&mut s);
    inv_sub_bytes(&mut s);
    add_round_key(&mut s, &ks.round_keys[0]);
    s
}

/// Final encryption round (no MixColumns).
pub fn encrypt_round_last(state: &mut [u8; 16], rk: &[u8; 16]) {
    sub_bytes(state);
    shift_rows(state);
    add_round_key(state, rk);
}

/// Encrypt one 16-byte block.
pub fn encrypt_block(ks: &KeySchedule, block: &[u8; 16]) -> [u8; 16] {
    let mut s = *block;
    add_round_key(&mut s, &ks.round_keys[0]);
    for r in 1..ROUNDS {
        encrypt_round(&mut s, &ks.round_keys[r]);
    }
    encrypt_round_last(&mut s, &ks.round_keys[ROUNDS]);
    s
}

/// Decrypt one 16-byte block (equivalent inverse cipher).
pub fn decrypt_block(ks: &KeySchedule, block: &[u8; 16]) -> [u8; 16] {
    let mut s = *block;
    add_round_key(&mut s, &ks.round_keys[ROUNDS]);
    for r in (1..ROUNDS).rev() {
        inv_shift_rows(&mut s);
        inv_sub_bytes(&mut s);
        add_round_key(&mut s, &ks.round_keys[r]);
        inv_mix_columns(&mut s);
    }
    inv_shift_rows(&mut s);
    inv_sub_bytes(&mut s);
    add_round_key(&mut s, &ks.round_keys[0]);
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hex16(s: &str) -> [u8; 16] {
        let mut out = [0u8; 16];
        for i in 0..16 {
            out[i] = u8::from_str_radix(&s[2 * i..2 * i + 2], 16).unwrap();
        }
        out
    }

    /// FIPS-197 Appendix C.1 known-answer test.
    #[test]
    fn fips197_appendix_c1() {
        let key = hex16("000102030405060708090a0b0c0d0e0f");
        let pt = hex16("00112233445566778899aabbccddeeff");
        let ct = hex16("69c4e0d86a7b0430d8cdb78070b4c55a");
        let ks = KeySchedule::expand(&key);
        assert_eq!(encrypt_block(&ks, &pt), ct);
        assert_eq!(decrypt_block(&ks, &ct), pt);
    }

    /// FIPS-197 Appendix B example vector.
    #[test]
    fn fips197_appendix_b() {
        let key = hex16("2b7e151628aed2a6abf7158809cf4f3c");
        let pt = hex16("3243f6a8885a308d313198a2e0370734");
        let ct = hex16("3925841d02dc09fbdc118597196a0b32");
        let ks = KeySchedule::expand(&key);
        assert_eq!(encrypt_block(&ks, &pt), ct);
        assert_eq!(decrypt_block(&ks, &ct), pt);
    }

    /// Key expansion first/last round keys from FIPS-197 Appendix A.1.
    #[test]
    fn fips197_key_expansion() {
        let key = hex16("2b7e151628aed2a6abf7158809cf4f3c");
        let ks = KeySchedule::expand(&key);
        assert_eq!(ks.round_keys[0], key);
        // w[40..43] = d014f9a8 c9ee2589 e13f0cc8 b6630ca6
        assert_eq!(ks.round_keys[10], hex16("d014f9a8c9ee2589e13f0cc8b6630ca6"));
        assert_eq!(ks.last_round_key(), ks.round_keys[10]);
    }

    #[test]
    fn roundtrip_random() {
        // deterministic xorshift "random" data
        let mut x: u64 = 0x123456789abcdef;
        let mut next = || {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            x
        };
        for _ in 0..50 {
            let mut key = [0u8; 16];
            let mut pt = [0u8; 16];
            for b in key.iter_mut().chain(pt.iter_mut()) {
                *b = next() as u8;
            }
            let ks = KeySchedule::expand(&key);
            assert_eq!(decrypt_block(&ks, &encrypt_block(&ks, &pt)), pt);
        }
    }

    /// The T-table fast path must be bit-identical to the reference rounds
    /// over random keys/blocks.
    #[test]
    fn fast_path_equivalent_to_reference() {
        let mut x: u64 = 0xfeedface;
        let mut next = || {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            x
        };
        for _ in 0..200 {
            let mut key = [0u8; 16];
            let mut pt = [0u8; 16];
            for b in key.iter_mut().chain(pt.iter_mut()) {
                *b = next() as u8;
            }
            let ks = KeySchedule::expand(&key);
            let ct_ref = encrypt_block(&ks, &pt);
            assert_eq!(encrypt_block_fast(&ks, &pt), ct_ref);
            assert_eq!(decrypt_block_fast(&ks, &ct_ref), pt);
            assert_eq!(decrypt_block_fast(&ks, &ct_ref), decrypt_block(&ks, &ct_ref));
        }
    }

    #[test]
    fn gf_mul_basics() {
        assert_eq!(gf_mul(0x57, 0x83), 0xc1); // FIPS-197 §4.2 example
        assert_eq!(gf_mul(0x57, 0x13), 0xfe);
        assert_eq!(gf_mul(1, 0xab), 0xab);
        assert_eq!(gf_mul(0, 0xff), 0);
    }
}
