//! `fulmine` CLI — the leader entrypoint: regenerate any paper artifact,
//! run the secure-analytics use cases, or execute AOT artifacts through the
//! PJRT runtime.
//!
//! Usage:
//!   fulmine <command>
//!
//! Commands:
//!   table1 | fig7 | sec3b | fig8a | sec3c | fig8b | fig10 | fig11 | fig12 | table2
//!                 — print the corresponding paper table/figure from the model
//!   all           — print every paper artifact in order
//!   artifacts     — list and compile the AOT artifacts (PJRT smoke test)
//!   infer <name>  — execute one artifact with generated inputs, print a digest
//!   ablations     — run the surveillance ablation sweep
//!   stream <usecase> [--frames N] [--config RUNG]
//!                 — pipeline N frames through the event-driven SoC
//!                   scheduler (usecase: surveillance|facedet|seizure;
//!                   RUNG: ladder index or label substring, default best)

use anyhow::{bail, Result};
use fulmine::apps::params::{gen_params, xorshift_i16};
use fulmine::report;
use fulmine::runtime::{default_artifact_dir, Runtime, TensorI16};

fn usage() -> ! {
    eprintln!(
        "usage: fulmine <table1|fig7|sec3b|fig8a|sec3c|fig8b|fig10|fig11|fig12|table2|all|artifacts|infer <name>|ablations|stream <usecase> [--frames N] [--config RUNG]>"
    );
    std::process::exit(2);
}

/// Parse the `stream` subcommand's flags: `<usecase> [--frames N]
/// [--config RUNG]`.
fn parse_stream_args(args: &[String]) -> Result<(String, usize, Option<String>)> {
    let usecase = args.first().cloned().unwrap_or_else(|| usage());
    let mut frames = 8usize;
    let mut config: Option<String> = None;
    let mut it = args[1..].iter();
    while let Some(flag) = it.next() {
        match flag.as_str() {
            "--frames" => {
                let v = it.next().ok_or_else(|| anyhow::anyhow!("--frames needs a value"))?;
                frames = v.parse().map_err(|_| anyhow::anyhow!("bad --frames value {v:?}"))?;
            }
            "--config" => {
                let v = it.next().ok_or_else(|| anyhow::anyhow!("--config needs a value"))?;
                config = Some(v.clone());
            }
            other => bail!("unknown stream flag {other:?}"),
        }
    }
    Ok((usecase, frames, config))
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or_else(|| usage());
    match cmd {
        "table1" => print!("{}", report::table1()),
        "fig7" => print!("{}", report::fig7()),
        "sec3b" => print!("{}", report::sec3b()),
        "fig8a" => print!("{}", report::fig8a()),
        "sec3c" => print!("{}", report::sec3c()),
        "fig8b" => print!("{}", report::fig8b()),
        "fig10" => print!("{}", report::fig10()),
        "fig11" => print!("{}", report::fig11()),
        "fig12" => print!("{}", report::fig12()),
        "table2" => print!("{}", report::table2()),
        "all" => print!("{}", report::all_reports()),
        "stream" => {
            let (usecase, frames, config) = parse_stream_args(&args[1..])?;
            match report::stream_report(&usecase, frames, config.as_deref()) {
                Ok(s) => print!("{s}"),
                Err(e) => bail!("{e}"),
            }
        }
        "ablations" => {
            for (label, r) in report::surveillance_ablations() {
                println!(
                    "{label:<18} time {:>8.4} s  energy {:>8.3} mJ  {:>6.2} pJ/op",
                    r.time_s, r.energy_mj, r.pj_per_op
                );
            }
        }
        "artifacts" => {
            let mut rt = Runtime::open(default_artifact_dir())?;
            let names: Vec<String> = rt.artifact_names().iter().map(|s| s.to_string()).collect();
            for n in names {
                let t = std::time::Instant::now();
                rt.compile(&n)?;
                let meta = rt.meta(&n).unwrap();
                println!(
                    "{n:<22} compiled in {:>7.1} ms   kind={} k={} simd={} inputs={}",
                    t.elapsed().as_secs_f64() * 1e3,
                    meta.kind,
                    meta.k,
                    meta.simd,
                    meta.input_shapes.len()
                );
            }
        }
        "infer" => {
            let name = args.get(1).map(String::as_str).unwrap_or_else(|| usage());
            let mut rt = Runtime::open(default_artifact_dir())?;
            let Some(meta) = rt.meta(name).cloned() else {
                bail!("unknown artifact {name}; try `fulmine artifacts`");
            };
            let Some(x_shape) = meta.input_shapes.first() else {
                bail!(
                    "artifact {name} declares no input shapes in its manifest; \
                     cannot generate inputs (regenerate it with `make artifacts`)"
                );
            };
            let x = TensorI16::new(
                x_shape.clone(),
                xorshift_i16(7, x_shape.iter().product(), -2048, 2047),
            );
            let mut inputs = vec![x];
            inputs.extend(gen_params(&meta.input_shapes[1..], meta.simd, 1));
            let t = std::time::Instant::now();
            let out = rt.execute(name, &inputs)?;
            println!(
                "{name}: executed in {:.2} ms; output shape {:?}, first values {:?}",
                t.elapsed().as_secs_f64() * 1e3,
                out[0].shape,
                &out[0].data[..out[0].data.len().min(10)]
            );
        }
        _ => usage(),
    }
    Ok(())
}
