//! `fulmine` CLI — a thin shell over [`fulmine::cli`]: parse the argument
//! list into a typed [`fulmine::cli::Command`], dispatch it against the
//! [`fulmine::system::SocSystem`] façade, and map errors to the process
//! boundary (usage + exit 2 for bad invocations, exit 1 for runtime
//! failures). Run `fulmine` with no arguments for the command list.

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = match fulmine::cli::parse(&args) {
        Ok(cmd) => cmd,
        Err(e) => {
            eprintln!("error: {e}\n\n{}", fulmine::cli::USAGE);
            std::process::exit(2);
        }
    };
    if let Err(e) = fulmine::cli::dispatch(&cmd) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
