//! Energy bookkeeping: integrates per-component power over simulated activity
//! windows and produces the breakdown reports of Fig. 10/11/12.

use crate::soc::power::{Component, PowerModel};
use crate::soc::OperatingPoint;
use std::collections::BTreeMap;

/// Breakdown categories used by the paper's use-case figures.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Category {
    /// Convolution kernels (SW or HWCE).
    Conv,
    /// Encryption/decryption (SW or HWCRYPT).
    Crypto,
    /// Other CNN / algorithm components run in software (pooling, activation,
    /// dense layers, PCA, DWT, SVM, ...).
    OtherSw,
    /// Cluster DMA transfers (L2 ↔ TCDM).
    Dma,
    /// External memories (flash + FRAM traffic) and uDMA I/O.
    ExtMem,
    /// Idle/leakage and power-management overheads.
    Idle,
}

impl Category {
    pub fn name(self) -> &'static str {
        match self {
            Category::Conv => "conv",
            Category::Crypto => "crypto",
            Category::OtherSw => "other-sw",
            Category::Dma => "dma",
            Category::ExtMem => "ext-mem",
            Category::Idle => "idle",
        }
    }

    pub fn all() -> [Category; 6] {
        [
            Category::Conv,
            Category::Crypto,
            Category::OtherSw,
            Category::Dma,
            Category::ExtMem,
            Category::Idle,
        ]
    }
}

/// Accumulates energy (mJ) per category and wall-clock time (s) per phase.
#[derive(Debug, Default, Clone)]
pub struct EnergyLedger {
    energy_mj: BTreeMap<Category, f64>,
    /// Total pipeline time in seconds (phases may overlap; the coordinator
    /// adds only the critical path).
    pub elapsed_s: f64,
}

impl EnergyLedger {
    pub fn new() -> Self {
        Self::default()
    }

    /// Charge `seconds` of `component` activity at `op` to `category`.
    pub fn charge(&mut self, category: Category, component: Component, op: OperatingPoint, seconds: f64) {
        let mw = PowerModel::active_mw(component, op);
        *self.energy_mj.entry(category).or_insert(0.0) += mw * seconds;
    }

    /// Charge a raw energy amount in mJ.
    pub fn charge_mj(&mut self, category: Category, mj: f64) {
        *self.energy_mj.entry(category).or_insert(0.0) += mj;
    }

    /// Advance wall-clock time by `seconds` (critical path only).
    pub fn advance(&mut self, seconds: f64) {
        self.elapsed_s += seconds;
    }

    pub fn energy_mj(&self, category: Category) -> f64 {
        *self.energy_mj.get(&category).unwrap_or(&0.0)
    }

    pub fn total_mj(&self) -> f64 {
        self.energy_mj.values().sum()
    }

    /// Merge another ledger (e.g. per-layer ledgers into a pipeline total).
    pub fn merge(&mut self, other: &EnergyLedger) {
        for (k, v) in &other.energy_mj {
            *self.energy_mj.entry(*k).or_insert(0.0) += v;
        }
        self.elapsed_s += other.elapsed_s;
    }

    /// Scale all energies and time by a constant (used when a measured tile
    /// is replicated `n` times across a layer, as the paper's own evaluation
    /// does when composing kernels).
    pub fn scaled(&self, factor: f64) -> EnergyLedger {
        let mut out = self.clone();
        for v in out.energy_mj.values_mut() {
            *v *= factor;
        }
        out.elapsed_s *= factor;
        out
    }

    /// Render the Fig. 10/11/12-style breakdown as table rows.
    pub fn breakdown(&self) -> Vec<(Category, f64)> {
        Category::all()
            .iter()
            .map(|&c| (c, self.energy_mj(c)))
            .collect()
    }

    pub fn report(&self, label: &str) -> String {
        let mut s = format!(
            "{label:<28} time {:>9.4} s   energy {:>9.4} mJ  | ",
            self.elapsed_s,
            self.total_mj()
        );
        for (c, e) in self.breakdown() {
            if e > 0.0 {
                s.push_str(&format!("{}={:.3}mJ ", c.name(), e));
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::opmodes::OperatingMode;

    #[test]
    fn charge_integrates_power_over_time() {
        let mut l = EnergyLedger::new();
        let op = OperatingPoint::nominal(OperatingMode::Sw);
        // one core for one second
        l.charge(Category::OtherSw, Component::Core, op, 1.0);
        let expected = PowerModel::active_mw(Component::Core, op);
        assert!((l.energy_mj(Category::OtherSw) - expected).abs() < 1e-12);
    }

    #[test]
    fn merge_and_scale() {
        let mut a = EnergyLedger::new();
        a.charge_mj(Category::Conv, 2.0);
        a.advance(0.5);
        let mut b = EnergyLedger::new();
        b.charge_mj(Category::Crypto, 1.0);
        b.advance(0.25);
        a.merge(&b);
        assert!((a.total_mj() - 3.0).abs() < 1e-12);
        assert!((a.elapsed_s - 0.75).abs() < 1e-12);
        let s = a.scaled(4.0);
        assert!((s.total_mj() - 12.0).abs() < 1e-12);
        assert!((s.elapsed_s - 3.0).abs() < 1e-12);
    }

    #[test]
    fn breakdown_covers_all_categories() {
        let l = EnergyLedger::new();
        assert_eq!(l.breakdown().len(), 6);
        assert_eq!(l.total_mj(), 0.0);
    }
}
