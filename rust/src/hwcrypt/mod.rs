//! The Hardware Encryption Engine (HWCRYPT) device model (§II-B, Fig. 3).
//!
//! Functional behaviour comes from [`crate::crypto`]; this module adds the
//! device-level cycle/throughput model, the four-deep command queue, and the
//! event interface.
//!
//! ## Throughput derivation (§II-B/§III-B)
//!
//! * **AES-128**: two instances × two rounds per cycle with a shared
//!   on-the-fly key schedule. A block takes 5 datapath cycles; with two
//!   instances and the 2×32-bit TCDM ports feeding 8 bytes/cycle, the
//!   engine sustains the measured **0.38 cycles/byte** (≈3100 cycles for
//!   8 kB including configuration). XTS matches ECB because the ⊗2 tweak
//!   chain is computed in parallel with encryption.
//! * **KECCAK-f[400] sponge**: two permutation instances × three rounds per
//!   cycle ⇒ ⌈20/3⌉ = 7 cycles per permutation call. At the maximum rate of
//!   128 bits, one instance encrypts 16 bytes per call while the second
//!   computes the MAC in parallel ⇒ ≈0.44 cpb datapath, **0.51 cpb**
//!   measured with state (re)initialization and port sharing.
//! * Round/rate reconfiguration scales cost linearly: `rounds/3` datapath
//!   cycles per call over `rate/8` bytes.

use crate::cluster::event_unit::{Event, EventUnit};
use crate::crypto::sponge::SpongeConfig;

/// Measured engine throughputs, cycles per byte (§III-B).
pub const AES_ECB_CPB: f64 = 0.38;
pub const AES_XTS_CPB: f64 = 0.38;
pub const SPONGE_AE_CPB: f64 = 0.51;

/// Configuration cycles per job (register writes through the peripheral
/// interconnect; part of the ~3100-cycle 8 kB ECB figure).
pub const JOB_CONFIG_CYCLES: u64 = 24;

/// Command-queue depth ("a command queue that supports up to four pending
/// operations").
pub const QUEUE_DEPTH: usize = 4;

/// Cipher selection for a job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CipherOp {
    AesEcb,
    AesXts,
    /// Sponge authenticated encryption at the given configuration.
    SpongeAe(SpongeConfig),
    /// Sponge keystream-only encryption.
    SpongeEnc(SpongeConfig),
    /// Raw permutation calls (software acceleration of KECCAK-based
    /// algorithms), `n` invocations.
    RawPermute(usize),
}

impl CipherOp {
    /// Engine cycles to process `bytes` (excluding configuration).
    pub fn cycles(&self, bytes: usize) -> u64 {
        match self {
            CipherOp::AesEcb => (AES_ECB_CPB * bytes as f64).ceil() as u64,
            CipherOp::AesXts => (AES_XTS_CPB * bytes as f64).ceil() as u64,
            CipherOp::SpongeAe(cfg) => Self::sponge_cycles(*cfg, bytes, true),
            CipherOp::SpongeEnc(cfg) => Self::sponge_cycles(*cfg, bytes, false),
            CipherOp::RawPermute(n) => (*n as u64) * 7,
        }
    }

    /// Structural sponge cost: ⌈rounds/3⌉ cycles per permutation call plus
    /// rate-sized I/O on the shared ports; the dual instance hides the MAC
    /// permutation entirely. Calibrated so the max-rate 20-round AE
    /// configuration hits the measured 0.51 cpb.
    fn sponge_cycles(cfg: SpongeConfig, bytes: usize, _auth: bool) -> u64 {
        let calls = bytes.div_ceil(cfg.rate_bytes()) as u64 + 1; // +1 init permute
        let perm = (cfg.rounds as u64).div_ceil(3);
        // I/O: rate bytes over 8 B/cycle, overlapped with the permutation.
        let io = (cfg.rate_bytes() as u64).div_ceil(8);
        calls * perm.max(io) + (0.06 * bytes as f64) as u64 // port-sharing overhead
    }

    /// Whether this op needs the full CRY-CNN-SW mode (AES datapath).
    pub fn needs_aes_mode(&self) -> bool {
        matches!(self, CipherOp::AesEcb | CipherOp::AesXts)
    }
}

/// The HWCRYPT device: busy-tracking with a four-deep command queue.
#[derive(Debug, Default)]
pub struct Hwcrypt {
    busy_until: u64,
    queue: Vec<u64>, // completion times of queued ops
    pub active_cycles: u64,
    pub bytes_processed: u64,
    pub jobs_done: u64,
}

impl Hwcrypt {
    pub fn new() -> Self {
        Self::default()
    }

    /// Enqueue an operation over `bytes` at time `now`; returns completion
    /// cycle. If the queue is full, the issuing core blocks until a slot
    /// frees (reflected in the returned completion time).
    pub fn offload(
        &mut self,
        now: u64,
        op: CipherOp,
        bytes: usize,
        eu: Option<&mut EventUnit>,
    ) -> u64 {
        let queue_ready = crate::cluster::accel_queue_issue_at(&mut self.queue, QUEUE_DEPTH, now);
        let cycles = op.cycles(bytes);
        let start = self.busy_until.max(queue_ready).max(now);
        let done = start + JOB_CONFIG_CYCLES + cycles;
        self.busy_until = done;
        self.queue.push(done);
        self.active_cycles += cycles;
        self.bytes_processed += bytes as u64;
        self.jobs_done += 1;
        if let Some(eu) = eu {
            eu.post(Event::HwcryptDone);
        }
        done
    }

    pub fn idle_at(&self) -> u64 {
        self.busy_until
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// §III-B: "To encrypt one 8 kB block of data using the AES-128-ECB
    /// mode, HWCRYPT requires ∼3100 clock cycles including the initial
    /// configuration".
    #[test]
    fn ecb_8kb_about_3100_cycles() {
        let mut hw = Hwcrypt::new();
        let done = hw.offload(0, CipherOp::AesEcb, 8192, None);
        assert!((done as f64 - 3100.0).abs() < 120.0, "8 kB ECB = {done} cycles");
    }

    /// §III-B: XTS performance equals ECB (parallel tweak computation).
    #[test]
    fn xts_matches_ecb() {
        assert_eq!(
            CipherOp::AesXts.cycles(4096),
            CipherOp::AesEcb.cycles(4096)
        );
    }

    /// §III-B: sponge AE at max rate = 0.51 cpb.
    #[test]
    fn sponge_ae_max_rate_cpb() {
        let bytes = 65536;
        let c = CipherOp::SpongeAe(SpongeConfig::MAX_RATE).cycles(bytes);
        let cpb = c as f64 / bytes as f64;
        assert!((cpb - 0.51).abs() < 0.03, "sponge cpb {cpb}");
    }

    /// Reducing the rate decreases throughput (increases cpb).
    #[test]
    fn lower_rate_costs_more() {
        let full = CipherOp::SpongeAe(SpongeConfig { rate_bits: 128, rounds: 20 }).cycles(4096);
        let half = CipherOp::SpongeAe(SpongeConfig { rate_bits: 64, rounds: 20 }).cycles(4096);
        assert!(half > full);
    }

    /// More rounds per call cost proportionally (multiples of 3).
    #[test]
    fn more_rounds_cost_more() {
        let r20 = CipherOp::SpongeAe(SpongeConfig { rate_bits: 128, rounds: 20 }).cycles(4096);
        let r6 = CipherOp::SpongeAe(SpongeConfig { rate_bits: 128, rounds: 6 }).cycles(4096);
        assert!(r6 < r20);
    }

    #[test]
    fn queue_serializes_and_blocks_at_depth() {
        let mut hw = Hwcrypt::new();
        let mut last = 0;
        for _ in 0..6 {
            last = hw.offload(0, CipherOp::AesEcb, 1024, None);
        }
        // six jobs of ~390+24 cycles must serialize
        assert!(last >= 6 * (CipherOp::AesEcb.cycles(1024) + JOB_CONFIG_CYCLES) - 1);
        assert_eq!(hw.jobs_done, 6);
    }

    #[test]
    fn event_posted_on_offload() {
        let mut hw = Hwcrypt::new();
        let mut eu = EventUnit::new();
        hw.offload(0, CipherOp::AesXts, 512, Some(&mut eu));
        assert!(eu.take(Event::HwcryptDone));
    }

    /// Speedup ladder of §III-B: HW vs SW 1-core / 4-core.
    #[test]
    fn speedups_vs_software_match_paper() {
        use crate::kernels_sw::crypto_cost::*;
        let hw_cpb = AES_ECB_CPB;
        let s1 = SW_AES_ECB_CPB_1CORE / hw_cpb;
        let s4 = SW_AES_ECB_CPB_4CORE / hw_cpb;
        assert!((s1 - 450.0).abs() < 1.0);
        assert!((s4 - 120.0).abs() < 1.0);
        let x1 = SW_AES_XTS_CPB_1CORE / AES_XTS_CPB;
        let x4 = sw_xts_cpb(4) / AES_XTS_CPB;
        assert!((x1 - 495.0).abs() < 1.0);
        assert!((x4 - 287.0).abs() < 1.0);
    }
}
