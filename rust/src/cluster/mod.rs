//! Cycle-approximate model of the Fulmine CLUSTER domain (§II, Fig. 1).
//!
//! The cluster couples four OR10N cores (modelled by [`crate::isa`]), the
//! HWCRYPT ([`crate::hwcrypt`]) and HWCE ([`crate::hwce`]) accelerators, 64 kB
//! of TCDM in eight word-interleaved banks behind a single-cycle logarithmic
//! interconnect ([`tcdm`]), a lightweight multi-channel DMA ([`dma`]) and the
//! event unit ([`event_unit`]).
//!
//! Simulation strategy: *detailed* where contention matters (per-cycle bank
//! arbitration for core/accelerator memory traffic on representative tiles),
//! *analytic* where the paper itself composes measured kernels into full
//! workloads (DMA bandwidth equations, per-phase cycle scaling). This mirrors
//! how the paper's own evaluation is constructed (§III: "we measured average
//! throughput by running a full-platform benchmark"; §IV composes kernels).

pub mod dma;
pub mod event_unit;
pub mod tcdm;

/// Number of general-purpose cores in the cluster.
pub const N_CORES: usize = 4;
/// TCDM size in bytes (64 kB).
pub const TCDM_BYTES: usize = 64 * 1024;
/// Number of word-interleaved TCDM banks.
pub const TCDM_BANKS: usize = 8;
/// L2 memory size in bytes (192 kB, SOC domain).
pub const L2_BYTES: usize = 192 * 1024;
/// Shared accelerator ports on the TCDM interconnect (§II: "the two
/// accelerators share the same set of four physical ports").
pub const ACCEL_PORTS: usize = 4;

/// Shared command-queue semantics of the cluster accelerators (HWCE and
/// HWCRYPT both front a fixed-depth queue of job descriptors): drain
/// completed entries at `now`, then return the cycle at which a queue slot
/// is free for a new job — `now` when below capacity, otherwise the
/// completion of the job whose retirement brings occupancy under `depth`.
/// `queue` holds completion cycles in ascending order (each accelerator's
/// completions are monotone) and is drained in place.
pub fn accel_queue_issue_at(queue: &mut Vec<u64>, depth: usize, now: u64) -> u64 {
    queue.retain(|&d| d > now);
    if queue.len() >= depth {
        queue[queue.len() - depth]
    } else {
        now
    }
}
