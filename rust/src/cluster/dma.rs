//! The cluster DMA (§II, evolution of [18]): per-core command FIFOs behind
//! private DEMUX ports, up to 16 outstanding 1D/2D transfers between TCDM and
//! L2, 256-byte bursts on a 64-bit AXI4 interface, <10-cycle programming
//! overhead, completion events to the event unit.
//!
//! The timing model is analytic (the DMA moves long contiguous bursts, so
//! per-beat bank arbitration is well-approximated by its steady-state):
//!
//! * programming: [`PROGRAM_CYCLES`] cycles on the issuing core;
//! * data movement: 8 bytes/cycle on the AXI side (64-bit), 16 bytes/cycle
//!   peak on the TCDM side (4 ports × 32 bit), so AXI is the bottleneck;
//! * per-burst overhead: [`BURST_SETUP_CYCLES`] cycles of L2/AXI latency per
//!   256-byte burst (pipelined across the up-to-16 outstanding transfers, so
//!   it is charged only when the queue drains);
//! * 2D transfers: one burst sequence per row (stride jumps break bursts).

/// Max outstanding transfers (paper: "up to 16 outstanding 1D or 2D
/// transfers to hide L2 memory latency").
pub const MAX_OUTSTANDING: usize = 16;
/// AXI burst length in bytes ("256 byte bursts on the 64-bit AXI4 interface").
pub const BURST_BYTES: usize = 256;
/// AXI data width in bytes per cycle.
pub const AXI_BYTES_PER_CYCLE: usize = 8;
/// Programming overhead ("less than 10 cycles to initiate a transfer").
pub const PROGRAM_CYCLES: u64 = 9;
/// L2-side latency charged per non-pipelined burst.
pub const BURST_SETUP_CYCLES: u64 = 8;

/// A 1D or 2D transfer descriptor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Transfer {
    /// Bytes per row.
    pub row_bytes: usize,
    /// Number of rows (1 for a 1D transfer).
    pub rows: usize,
}

impl Transfer {
    pub fn d1(bytes: usize) -> Self {
        Transfer { row_bytes: bytes, rows: 1 }
    }

    pub fn d2(row_bytes: usize, rows: usize) -> Self {
        Transfer { row_bytes, rows }
    }

    pub fn total_bytes(&self) -> usize {
        self.row_bytes * self.rows
    }

    /// Pure data-movement cycles for this transfer once issued (steady-state,
    /// outstanding queue full enough to hide per-burst latency).
    pub fn stream_cycles(&self) -> u64 {
        let mut cycles = 0u64;
        for _ in 0..self.rows {
            // each row is an independent burst sequence
            let bursts = self.row_bytes.div_ceil(BURST_BYTES).max(1);
            let beats = self.row_bytes.div_ceil(AXI_BYTES_PER_CYCLE) as u64;
            // first burst of a row pays setup; subsequent bursts pipeline
            cycles += beats + BURST_SETUP_CYCLES.min(bursts as u64 * 2);
        }
        cycles
    }
}

/// Aggregate DMA engine state: models the command queue occupancy and total
/// busy time so the coordinator can overlap transfers with computation
/// (double buffering, §II-D).
#[derive(Debug, Default)]
pub struct Dma {
    /// Cycle at which the engine becomes idle.
    busy_until: u64,
    /// Completion times of in-flight transfers (bounded by MAX_OUTSTANDING).
    inflight: Vec<u64>,
    /// Total bytes moved (stats).
    pub bytes_moved: u64,
    /// Total transfers issued (stats).
    pub transfers: u64,
}

impl Dma {
    pub fn new() -> Self {
        Self::default()
    }

    /// Issue a transfer at `now` (core-side cycle count). Returns
    /// `(program_done, transfer_done)`: the issuing core is busy until
    /// `program_done`; the data is in place at `transfer_done`.
    pub fn issue(&mut self, now: u64, t: Transfer) -> (u64, u64) {
        let program_done = now + PROGRAM_CYCLES;
        // The engine serializes transfers on the AXI port; if the queue is
        // full the issue stalls until a slot frees.
        self.inflight.retain(|&d| d > now);
        let queue_ready = if self.inflight.len() >= MAX_OUTSTANDING {
            // wait for the earliest in-flight transfer to complete
            let mut v: Vec<u64> = self.inflight.clone();
            v.sort_unstable();
            v[self.inflight.len() - MAX_OUTSTANDING]
        } else {
            program_done
        };
        let start = self.busy_until.max(queue_ready);
        let done = start + t.stream_cycles();
        self.busy_until = done;
        self.inflight.push(done);
        self.bytes_moved += t.total_bytes() as u64;
        self.transfers += 1;
        (program_done, done)
    }

    /// Cycle at which all issued transfers have completed.
    pub fn idle_at(&self) -> u64 {
        self.busy_until
    }

    /// Effective bandwidth in bytes/cycle for a large 1D transfer — used by
    /// analytic pipeline models.
    pub fn effective_bw_1d(bytes: usize) -> f64 {
        let t = Transfer::d1(bytes);
        bytes as f64 / t.stream_cycles() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn large_1d_approaches_8_bytes_per_cycle() {
        let bw = Dma::effective_bw_1d(1 << 20);
        assert!(bw > 7.9 && bw <= 8.0, "bw={bw}");
    }

    #[test]
    fn small_transfer_pays_setup() {
        let t = Transfer::d1(32);
        // 4 beats + setup
        assert!(t.stream_cycles() >= 4 + 2);
    }

    #[test]
    fn d2_rows_pay_per_row() {
        let one_row = Transfer::d1(256).stream_cycles();
        let four_rows = Transfer::d2(256, 4).stream_cycles();
        assert_eq!(four_rows, 4 * one_row);
    }

    #[test]
    fn issue_serializes_on_engine() {
        let mut dma = Dma::new();
        let (_, d1) = dma.issue(0, Transfer::d1(1024));
        let (_, d2) = dma.issue(0, Transfer::d1(1024));
        assert!(d2 >= d1 + Transfer::d1(1024).stream_cycles());
    }

    #[test]
    fn programming_overhead_under_10_cycles() {
        let mut dma = Dma::new();
        let (pd, _) = dma.issue(100, Transfer::d1(64));
        assert!(pd - 100 < 10);
    }

    #[test]
    fn outstanding_queue_bounds_inflight() {
        let mut dma = Dma::new();
        let mut last = 0;
        for _ in 0..64 {
            let (_, d) = dma.issue(0, Transfer::d1(256));
            last = d;
        }
        assert_eq!(dma.transfers, 64);
        assert_eq!(dma.bytes_moved, 64 * 256);
        assert!(last >= 64 * Transfer::d1(256).stream_cycles() - 64);
    }

    #[test]
    fn overlap_with_compute_is_possible() {
        // double buffering: a transfer issued at t=0 completes while the
        // "core" computes; the done time is independent of core activity.
        let mut dma = Dma::new();
        let (pd, done) = dma.issue(0, Transfer::d1(4096));
        assert!(pd < done);
        let compute_end = 10_000u64;
        assert!(done < compute_end, "4 kB must stream well before 10k cycles");
    }
}
