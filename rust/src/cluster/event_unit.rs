//! The cluster event unit (§II, §II-A): hardware-assisted synchronization and
//! automatic clock-gating of idle cores.
//!
//! Cores execute an explicit *Wait For Event* and are clock-gated by the
//! event unit until the awaited event (DMA completion, accelerator done,
//! barrier release) arrives; the event unit also accelerates the OpenMP
//! parallelization patterns: 2 cycles for a barrier, 8 cycles to open a
//! critical section, 70 cycles to open a parallel section.

use super::N_CORES;

/// Synchronization primitive costs measured in cluster cycles (§II).
pub const BARRIER_CYCLES: u64 = 2;
pub const CRITICAL_OPEN_CYCLES: u64 = 8;
pub const PARALLEL_OPEN_CYCLES: u64 = 70;

/// Event lines routed by the event unit.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Event {
    DmaDone(u32),
    HwceDone,
    HwcryptDone,
    Timer,
    SwEvent(u32),
}

/// Core activity state tracked for clock-gating (idle cores consume only
/// leakage — see [`crate::soc::power`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CoreState {
    Active,
    /// Clock-gated, waiting for an event; wakes at the recorded cycle.
    Gated { since: u64 },
}

/// Tracks per-core busy/idle windows so the energy ledger can integrate
/// active vs. clock-gated power, and provides barrier semantics.
#[derive(Debug)]
pub struct EventUnit {
    state: [CoreState; N_CORES],
    /// Accumulated active cycles per core.
    active_cycles: [u64; N_CORES],
    /// Accumulated gated cycles per core.
    gated_cycles: [u64; N_CORES],
    /// Pending events.
    pending: Vec<Event>,
}

impl Default for EventUnit {
    fn default() -> Self {
        Self::new()
    }
}

impl EventUnit {
    pub fn new() -> Self {
        EventUnit {
            state: [CoreState::Active; N_CORES],
            active_cycles: [0; N_CORES],
            gated_cycles: [0; N_CORES],
            pending: Vec::new(),
        }
    }

    /// Core `c` runs until cycle `until` (charged as active time).
    pub fn run_until(&mut self, c: usize, from: u64, until: u64) {
        debug_assert!(until >= from);
        self.active_cycles[c] += until - from;
        self.state[c] = CoreState::Active;
    }

    /// Core `c` executes WFE at `now`; it is clock-gated until `wake`.
    /// Returns the wake cycle (== `wake`), charging gated time.
    pub fn wait_for_event(&mut self, c: usize, now: u64, wake: u64) -> u64 {
        debug_assert!(wake >= now);
        self.state[c] = CoreState::Gated { since: now };
        self.gated_cycles[c] += wake - now;
        self.state[c] = CoreState::Active;
        wake
    }

    /// Barrier across `n` cores whose local times are `t`: all cores align to
    /// max(t) + BARRIER_CYCLES; early arrivals are clock-gated while waiting.
    pub fn barrier(&mut self, t: &[u64]) -> u64 {
        let n = t.len().min(N_CORES);
        let release = t[..n].iter().copied().max().unwrap_or(0) + BARRIER_CYCLES;
        for (c, &tc) in t[..n].iter().enumerate() {
            self.gated_cycles[c] += release - BARRIER_CYCLES - tc;
            self.active_cycles[c] += BARRIER_CYCLES;
        }
        release
    }

    pub fn post(&mut self, e: Event) {
        self.pending.push(e);
    }

    pub fn take(&mut self, e: Event) -> bool {
        if let Some(pos) = self.pending.iter().position(|&p| p == e) {
            self.pending.remove(pos);
            true
        } else {
            false
        }
    }

    pub fn active_cycles(&self) -> &[u64; N_CORES] {
        &self.active_cycles
    }

    pub fn gated_cycles(&self) -> &[u64; N_CORES] {
        &self.gated_cycles
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn barrier_aligns_to_slowest_plus_two() {
        let mut eu = EventUnit::new();
        let release = eu.barrier(&[100, 250, 90, 180]);
        assert_eq!(release, 252);
        // core 2 (arrived at 90) waited 160 cycles gated
        assert_eq!(eu.gated_cycles()[2], 160);
        assert_eq!(eu.gated_cycles()[1], 0);
    }

    #[test]
    fn wfe_charges_gated_time() {
        let mut eu = EventUnit::new();
        let wake = eu.wait_for_event(0, 1000, 5000);
        assert_eq!(wake, 5000);
        assert_eq!(eu.gated_cycles()[0], 4000);
        assert_eq!(eu.active_cycles()[0], 0);
    }

    #[test]
    fn events_post_and_take() {
        let mut eu = EventUnit::new();
        eu.post(Event::DmaDone(3));
        eu.post(Event::HwceDone);
        assert!(eu.take(Event::HwceDone));
        assert!(!eu.take(Event::HwceDone));
        assert!(eu.take(Event::DmaDone(3)));
    }

    #[test]
    fn run_until_accumulates_active() {
        let mut eu = EventUnit::new();
        eu.run_until(1, 0, 500);
        eu.run_until(1, 500, 700);
        assert_eq!(eu.active_cycles()[1], 700);
    }
}
