//! The 64 kB Tightly-Coupled Data Memory: eight word-interleaved SRAM banks
//! behind a single-cycle logarithmic interconnect (§II, [13]).
//!
//! Bank selection is word-interleaved: bank = (addr >> 2) % 8. If two masters
//! address the same bank in the same cycle, one is granted and the others are
//! stalled by a *starvation-free round-robin* arbiter (per bank). This module
//! provides both the functional storage (shared by cores, DMA and
//! accelerators — the zero-copy property of the architecture) and the
//! per-cycle arbitration used by the detailed simulations.

use super::{TCDM_BANKS, TCDM_BYTES};

/// Identifies a master on the TCDM interconnect for arbitration and stats.
/// Cores use 0..=3, DMA ports 4..=7, the shared accelerator ports 8..=11.
pub type MasterId = usize;

/// Number of master ports modelled on the interconnect:
/// 4 cores + 4 DMA + 4 shared accelerator ports.
pub const N_MASTERS: usize = 12;

/// Per-access contention statistics.
#[derive(Debug, Default, Clone, Copy)]
pub struct TcdmStats {
    /// Total accesses granted.
    pub accesses: u64,
    /// Total stall cycles inserted by bank conflicts.
    pub conflict_stalls: u64,
}

/// Functional + timing model of the TCDM.
pub struct Tcdm {
    mem: Vec<u8>,
    /// Round-robin pointer per bank: the master id with current priority.
    rr_ptr: [usize; TCDM_BANKS],
    /// Pending requests in the current arbitration cycle: bank -> masters.
    pending: Vec<Vec<MasterId>>,
    stats: TcdmStats,
}

impl Default for Tcdm {
    fn default() -> Self {
        Self::new()
    }
}

impl Tcdm {
    pub fn new() -> Self {
        Tcdm {
            mem: vec![0; TCDM_BYTES],
            rr_ptr: [0; TCDM_BANKS],
            pending: vec![Vec::new(); TCDM_BANKS],
            stats: TcdmStats::default(),
        }
    }

    #[inline]
    pub fn bank_of(addr: u32) -> usize {
        ((addr >> 2) as usize) % TCDM_BANKS
    }

    // ---- functional access (zero-copy shared storage) ----

    pub fn read_u32(&self, addr: u32) -> u32 {
        let a = addr as usize;
        assert!(a + 4 <= TCDM_BYTES, "TCDM read OOB at {addr:#x}");
        u32::from_le_bytes(self.mem[a..a + 4].try_into().unwrap())
    }

    pub fn write_u32(&mut self, addr: u32, v: u32) {
        let a = addr as usize;
        assert!(a + 4 <= TCDM_BYTES, "TCDM write OOB at {addr:#x}");
        self.mem[a..a + 4].copy_from_slice(&v.to_le_bytes());
    }

    pub fn read_u16(&self, addr: u32) -> u16 {
        let a = addr as usize;
        assert!(a + 2 <= TCDM_BYTES, "TCDM read OOB at {addr:#x}");
        u16::from_le_bytes(self.mem[a..a + 2].try_into().unwrap())
    }

    pub fn write_u16(&mut self, addr: u32, v: u16) {
        let a = addr as usize;
        assert!(a + 2 <= TCDM_BYTES, "TCDM write OOB at {addr:#x}");
        self.mem[a..a + 2].copy_from_slice(&v.to_le_bytes());
    }

    pub fn read_u8(&self, addr: u32) -> u8 {
        assert!((addr as usize) < TCDM_BYTES, "TCDM read OOB at {addr:#x}");
        self.mem[addr as usize]
    }

    pub fn write_u8(&mut self, addr: u32, v: u8) {
        assert!((addr as usize) < TCDM_BYTES, "TCDM write OOB at {addr:#x}");
        self.mem[addr as usize] = v;
    }

    pub fn slice(&self, addr: u32, len: usize) -> &[u8] {
        &self.mem[addr as usize..addr as usize + len]
    }

    pub fn slice_mut(&mut self, addr: u32, len: usize) -> &mut [u8] {
        &mut self.mem[addr as usize..addr as usize + len]
    }

    // ---- per-cycle arbitration ----

    /// Register that `master` wants to access `addr` this cycle.
    pub fn request(&mut self, master: MasterId, addr: u32) {
        debug_assert!(master < N_MASTERS);
        self.pending[Self::bank_of(addr)].push(master);
    }

    /// Arbitrate the current cycle. Returns, per master, whether its request
    /// was granted (`true`) or stalled (`false`). Masters without a request
    /// get `true`. The round-robin pointer of each bank advances past the
    /// winner, making the policy starvation-free.
    pub fn arbitrate(&mut self) -> [bool; N_MASTERS] {
        let mut granted = [true; N_MASTERS];
        for bank in 0..TCDM_BANKS {
            let reqs = &mut self.pending[bank];
            if reqs.is_empty() {
                continue;
            }
            // Winner: requesting master closest (cyclically) to rr_ptr.
            let ptr = self.rr_ptr[bank];
            let winner = *reqs
                .iter()
                .min_by_key(|&&m| (m + N_MASTERS - ptr) % N_MASTERS)
                .unwrap();
            for &m in reqs.iter() {
                if m != winner {
                    granted[m] = false;
                    self.stats.conflict_stalls += 1;
                }
            }
            self.stats.accesses += 1;
            self.rr_ptr[bank] = (winner + 1) % N_MASTERS;
            reqs.clear();
        }
        granted
    }

    pub fn stats(&self) -> TcdmStats {
        self.stats
    }

    pub fn reset_stats(&mut self) {
        self.stats = TcdmStats::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn word_interleaving() {
        assert_eq!(Tcdm::bank_of(0x0), 0);
        assert_eq!(Tcdm::bank_of(0x4), 1);
        assert_eq!(Tcdm::bank_of(0x1c), 7);
        assert_eq!(Tcdm::bank_of(0x20), 0);
        // sub-word addresses hit the same bank as their word
        assert_eq!(Tcdm::bank_of(0x6), 1);
    }

    #[test]
    fn functional_rw() {
        let mut t = Tcdm::new();
        t.write_u32(0x100, 0xdeadbeef);
        assert_eq!(t.read_u32(0x100), 0xdeadbeef);
        assert_eq!(t.read_u16(0x100), 0xbeef);
        assert_eq!(t.read_u8(0x103), 0xde);
        t.write_u16(0x200, 0x1234);
        assert_eq!(t.read_u16(0x200), 0x1234);
    }

    #[test]
    fn no_conflict_same_cycle_different_banks() {
        let mut t = Tcdm::new();
        t.request(0, 0x0); // bank 0
        t.request(1, 0x4); // bank 1
        let g = t.arbitrate();
        assert!(g[0] && g[1]);
        assert_eq!(t.stats().conflict_stalls, 0);
    }

    #[test]
    fn conflict_stalls_loser() {
        let mut t = Tcdm::new();
        t.request(0, 0x0);
        t.request(1, 0x20); // same bank 0
        let g = t.arbitrate();
        assert!(g[0] ^ g[1], "exactly one granted");
        assert_eq!(t.stats().conflict_stalls, 1);
    }

    #[test]
    fn round_robin_is_starvation_free() {
        let mut t = Tcdm::new();
        let mut grants = [0u32; 2];
        // Masters 0 and 1 fight for bank 0 for many cycles; both must make
        // progress with alternating grants.
        for _ in 0..100 {
            t.request(0, 0x0);
            t.request(1, 0x20);
            let g = t.arbitrate();
            if g[0] {
                grants[0] += 1;
            }
            if g[1] {
                grants[1] += 1;
            }
        }
        assert_eq!(grants[0], 50);
        assert_eq!(grants[1], 50);
    }

    #[test]
    fn three_way_conflict_all_progress() {
        let mut t = Tcdm::new();
        let mut grants = [0u32; 3];
        for _ in 0..99 {
            for m in 0..3 {
                t.request(m, 0x40); // bank 0
            }
            let g = t.arbitrate();
            for m in 0..3 {
                if g[m] {
                    grants[m] += 1;
                }
            }
        }
        assert_eq!(grants.iter().sum::<u32>(), 99);
        for m in 0..3 {
            assert_eq!(grants[m], 33, "master {m} starved: {grants:?}");
        }
    }
}
