//! Bit-exact functional model of the HWCE datapath (§II-C, Fig. 5).
//!
//! Semantics contract (shared with the Pallas kernel and jnp oracle):
//!
//! * pixels `x` and partial sums `y_in` are i16 in Q-format with `qf`
//!   fractional bits;
//! * weights are i16 values constrained to the mode's range (full i16 for
//!   16-bit; [-128,127] for 8-bit; [-8,7] for 4-bit);
//! * one pass computes, for each of the `simd()` concurrent output maps `f`:
//!   `y_out[f] = sat16( y_in[f] + round(Σ_window x·w[f] >> qf) )`
//!   — the sum-of-products is exact in 32+ bits, normalization is
//!   round-to-nearest (add half LSB, arithmetic shift), then the normalized
//!   contribution accumulates onto the memory-resident partial sum with
//!   i16 saturation (the "fractional part normalization and saturation"
//!   stage of the second-level reduction tree).
//!
//! Multi-channel convolutional layers chain passes: the `y` array stays in
//! TCDM and each input channel's pass accumulates onto it ("the accelerator
//! needs no internal memory to perform the feature map accumulation ... but
//! uses directly the shared memory of the cluster").

use crate::fixedpoint::{norm_round, sat16};

/// Weight precision modes (§II-C): scaling weights to 8/4 bits computes 2/4
/// output feature maps per pass from interleaved weight-buffer entries.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum WeightPrec {
    W16,
    W8,
    W4,
}

impl WeightPrec {
    /// Concurrent output feature maps per pass.
    pub fn simd(self) -> usize {
        match self {
            WeightPrec::W16 => 1,
            WeightPrec::W8 => 2,
            WeightPrec::W4 => 4,
        }
    }

    /// Weight bits.
    pub fn bits(self) -> u8 {
        match self {
            WeightPrec::W16 => 16,
            WeightPrec::W8 => 8,
            WeightPrec::W4 => 4,
        }
    }

    /// Inclusive weight range for this mode.
    pub fn range(self) -> (i16, i16) {
        match self {
            WeightPrec::W16 => (i16::MIN, i16::MAX),
            WeightPrec::W8 => (-128, 127),
            WeightPrec::W4 => (-8, 7),
        }
    }

    /// Quantize an f32 weight into this mode's range at `qf` fractional bits.
    pub fn quantize(self, v: f32, qf: u8) -> i16 {
        let scaled = (v * (1i32 << qf) as f32).round() as i64;
        let (lo, hi) = self.range();
        scaled.clamp(lo as i64, hi as i64) as i16
    }
}

/// One HWCE pass: convolve `x` (w×h) with `simd` filters (each k×k), and
/// accumulate onto the corresponding `y` maps ((w-k+1)×(h-k+1), updated in
/// place). Weight values must lie within the precision mode's range.
pub fn conv_multi(
    prec: WeightPrec,
    k: usize,
    w: usize,
    h: usize,
    qf: u8,
    x: &[i16],
    weights: &[&[i16]],
    y: &mut [Vec<i16>],
) {
    assert!(k == 3 || k == 5, "HWCE supports 3x3 and 5x5 natively");
    assert_eq!(x.len(), w * h);
    assert_eq!(weights.len(), prec.simd());
    assert_eq!(y.len(), prec.simd());
    let (lo, hi) = prec.range();
    for wf in weights {
        assert_eq!(wf.len(), k * k);
        assert!(
            wf.iter().all(|&v| v >= lo && v <= hi),
            "weight out of range for {prec:?}"
        );
    }
    let (ow, oh) = (w - k + 1, h - k + 1);
    for (f, wf) in weights.iter().enumerate() {
        assert_eq!(y[f].len(), ow * oh);
        for oy in 0..oh {
            for ox in 0..ow {
                let mut acc: i64 = 0;
                for ky in 0..k {
                    for kx in 0..k {
                        acc += x[(oy + ky) * w + ox + kx] as i64 * wf[ky * k + kx] as i64;
                    }
                }
                let idx = oy * ow + ox;
                let contrib = norm_round(acc, qf);
                y[f][idx] = sat16(y[f][idx] as i64 + contrib);
            }
        }
    }
}

/// Interleaved weight-buffer encoding (§II-C): in scaled-precision modes a
/// 16-bit weight-buffer location holds 2×8-bit or 4×4-bit weights of the
/// *same tap* across the concurrent filters. Returns the packed buffer
/// (k×k u16 words); used to model the storage footprint and by tests of the
/// encode/decode roundtrip.
pub fn pack_interleaved(prec: WeightPrec, k: usize, weights: &[&[i16]]) -> Vec<u16> {
    assert_eq!(weights.len(), prec.simd());
    let mut out = vec![0u16; k * k];
    for (tap, slot) in out.iter_mut().enumerate() {
        match prec {
            WeightPrec::W16 => *slot = weights[0][tap] as u16,
            WeightPrec::W8 => {
                let a = (weights[0][tap] as i8) as u8 as u16;
                let b = (weights[1][tap] as i8) as u8 as u16;
                *slot = a | (b << 8);
            }
            WeightPrec::W4 => {
                let mut v = 0u16;
                for (f, wf) in weights.iter().enumerate() {
                    v |= ((wf[tap] as u16) & 0xf) << (4 * f);
                }
                *slot = v;
            }
        }
    }
    out
}

/// Decode an interleaved weight buffer back to per-filter taps.
pub fn unpack_interleaved(prec: WeightPrec, k: usize, packed: &[u16]) -> Vec<Vec<i16>> {
    assert_eq!(packed.len(), k * k);
    let mut out = vec![vec![0i16; k * k]; prec.simd()];
    for (tap, &v) in packed.iter().enumerate() {
        match prec {
            WeightPrec::W16 => out[0][tap] = v as i16,
            WeightPrec::W8 => {
                out[0][tap] = (v as u8) as i8 as i16;
                out[1][tap] = ((v >> 8) as u8) as i8 as i16;
            }
            WeightPrec::W4 => {
                for f in 0..4 {
                    let nib = ((v >> (4 * f)) & 0xf) as i16;
                    out[f][tap] = if nib >= 8 { nib - 16 } else { nib };
                }
            }
        }
    }
    out
}

/// Weight storage bytes for a layer of `n_if × n_of` k×k filters in this
/// precision (drives the flash footprint of §IV-A: 8.9 MB at 16 bit for
/// ResNet-20 shrinks proportionally at 8/4 bit).
pub fn weight_bytes(prec: WeightPrec, k: usize, n_if: usize, n_of: usize) -> usize {
    n_if * n_of * k * k * prec.bits() as usize / 8
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rnd(n: usize, seed: u64, range: i16) -> Vec<i16> {
        let mut x = seed | 1;
        (0..n)
            .map(|_| {
                x ^= x << 13;
                x ^= x >> 7;
                x ^= x << 17;
                ((x % (2 * range as u64 + 1)) as i64 - range as i64) as i16
            })
            .collect()
    }

    #[test]
    fn single_pass_matches_direct_computation() {
        let (w, h, k, qf) = (8, 8, 3, 4);
        let x = rnd(w * h, 11, 1000);
        let wt = rnd(k * k, 22, 1000);
        let mut y = vec![vec![0i16; (w - k + 1) * (h - k + 1)]];
        conv_multi(WeightPrec::W16, k, w, h, qf, &x, &[&wt], &mut y);
        // spot check one pixel
        let mut acc = 0i64;
        for ky in 0..k {
            for kx in 0..k {
                acc += x[(2 + ky) * w + 3 + kx] as i64 * wt[ky * k + kx] as i64;
            }
        }
        assert_eq!(y[0][2 * (w - k + 1) + 3], sat16(norm_round(acc, qf)));
    }

    #[test]
    fn accumulation_chains_passes() {
        // two input channels accumulated = one pass on sum of contributions
        let (w, h, k, qf) = (7, 7, 3, 0);
        let x1 = rnd(w * h, 1, 100);
        let x2 = rnd(w * h, 2, 100);
        let wt = rnd(k * k, 3, 50);
        let n_out = (w - k + 1) * (h - k + 1);

        let mut y = vec![vec![0i16; n_out]];
        conv_multi(WeightPrec::W16, k, w, h, qf, &x1, &[&wt], &mut y);
        conv_multi(WeightPrec::W16, k, w, h, qf, &x2, &[&wt], &mut y);

        let xsum: Vec<i16> = x1.iter().zip(&x2).map(|(a, b)| a + b).collect();
        let mut y2 = vec![vec![0i16; n_out]];
        conv_multi(WeightPrec::W16, k, w, h, qf, &xsum, &[&wt], &mut y2);
        // with qf = 0 no rounding error: distributivity holds exactly
        assert_eq!(y, y2);
    }

    #[test]
    fn w4_mode_computes_four_maps() {
        let (w, h, k, qf) = (9, 9, 5, 2);
        let x = rnd(w * h, 5, 500);
        let wts: Vec<Vec<i16>> = (0..4).map(|i| rnd(k * k, 100 + i, 7)).collect();
        let refs: Vec<&[i16]> = wts.iter().map(|v| v.as_slice()).collect();
        let n_out = (w - k + 1) * (h - k + 1);
        let mut y = vec![vec![0i16; n_out]; 4];
        conv_multi(WeightPrec::W4, k, w, h, qf, &x, &refs, &mut y);
        // each map equals an independent W16 pass with the same weights
        for f in 0..4 {
            let mut yref = vec![vec![0i16; n_out]];
            conv_multi(WeightPrec::W16, k, w, h, qf, &x, &[&wts[f]], &mut yref);
            assert_eq!(y[f], yref[0], "map {f}");
        }
    }

    #[test]
    #[should_panic(expected = "weight out of range")]
    fn w4_rejects_out_of_range_weights() {
        let x = vec![0i16; 25];
        let wt = vec![8i16; 9]; // 8 > max 7
        let mut y = vec![vec![0i16; 9]; 4];
        let w4 = vec![0i16; 9];
        conv_multi(WeightPrec::W4, 3, 5, 5, 0, &x, &[&wt, &w4, &w4, &w4], &mut y);
    }

    #[test]
    fn interleaved_pack_roundtrip() {
        for prec in [WeightPrec::W16, WeightPrec::W8, WeightPrec::W4] {
            let k = 5;
            let (lo, hi) = prec.range();
            let wts: Vec<Vec<i16>> = (0..prec.simd())
                .map(|i| {
                    rnd(k * k, 7 + i as u64, 1000)
                        .into_iter()
                        .map(|v| v.clamp(lo, hi))
                        .collect()
                })
                .collect();
            let refs: Vec<&[i16]> = wts.iter().map(|v| v.as_slice()).collect();
            let packed = pack_interleaved(prec, k, &refs);
            assert_eq!(unpack_interleaved(prec, k, &packed), wts, "{prec:?}");
        }
    }

    #[test]
    fn quantize_respects_ranges() {
        assert_eq!(WeightPrec::W4.quantize(100.0, 0), 7);
        assert_eq!(WeightPrec::W4.quantize(-100.0, 0), -8);
        assert_eq!(WeightPrec::W8.quantize(0.5, 2), 2);
        assert_eq!(WeightPrec::W16.quantize(1.0, 8), 256);
    }

    #[test]
    fn weight_footprint_scales_with_precision() {
        // ResNet-20-ish check: 4-bit weights are 4× smaller than 16-bit
        let b16 = weight_bytes(WeightPrec::W16, 3, 64, 64);
        let b4 = weight_bytes(WeightPrec::W4, 3, 64, 64);
        assert_eq!(b16, 4 * b4);
    }

    #[test]
    fn saturation_on_accumulate() {
        let (w, h, k) = (5, 5, 3);
        let x = vec![i16::MAX; w * h];
        let wt = vec![7i16; k * k];
        let n_out = (w - k + 1) * (h - k + 1);
        let mut y = vec![vec![i16::MAX - 1; n_out]];
        conv_multi(WeightPrec::W16, k, w, h, 0, &x, &[&wt], &mut y);
        assert!(y[0].iter().all(|&v| v == i16::MAX));
    }
}
