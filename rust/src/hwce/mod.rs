//! The Hardware Convolution Engine (HWCE) model (§II-C, Fig. 5).
//!
//! Three views of the same device:
//!
//! * [`golden`] — the bit-exact functional model: 5×5/3×3 sum-of-products
//!   over 16-bit pixels with 16/8/4-bit weights, accumulation with the
//!   memory-resident `y_in` stream, rounded normalization and saturation.
//!   This is the semantics contract shared with the Pallas kernel
//!   (`python/compile/kernels/hwce.py`) and the jnp oracle (`ref.py`); the
//!   AOT artifact is validated against this model in
//!   `rust/tests/runtime_artifacts.rs`.
//! * [`timing`] — the cycle model. A *detailed* mode replays the wrapper's
//!   streamer address traces (x fetch, per-fmap y_in/y_out) through the
//!   shared 4-port interface and the TCDM bank arbiter, reproducing the
//!   self-contention the paper measures; analytic per-pixel constants
//!   calibrated to §III-C are used when scaling tiles to full layers.
//! * [`Hwce`] — the device: a two-entry job queue (the controller register
//!   file "can host a queue of two jobs"), completion events, busy tracking.

pub mod golden;
pub mod timing;

pub use golden::{conv_multi, WeightPrec};
pub use timing::{analytic_cycles_per_px, simulate_tile_cycles};

use crate::cluster::event_unit::{Event, EventUnit};

/// A HWCE job descriptor (mirrors the controller register file: pointers to
/// x, W, y, strides, fractional bits, precision mode).
#[derive(Debug, Clone, Copy)]
pub struct HwceJob {
    /// Input feature-map width/height (pixels).
    pub w: usize,
    pub h: usize,
    /// Filter size: 3 or 5.
    pub k: usize,
    /// Weight precision mode.
    pub prec: WeightPrec,
    /// Fractional bits for normalization.
    pub qf: u8,
}

impl HwceJob {
    pub fn ow(&self) -> usize {
        self.w - self.k + 1
    }
    pub fn oh(&self) -> usize {
        self.h - self.k + 1
    }
    /// Output positions per pass (each yields `prec.simd()` output pixels on
    /// different feature maps).
    pub fn positions(&self) -> usize {
        self.ow() * self.oh()
    }
}

/// Cycles to program one job through the peripheral interconnect (register
/// writes for pointers/strides/config; §II: accelerators are on the
/// lower-priority peripheral path).
pub const JOB_CONFIG_CYCLES: u64 = 16;

/// Job-queue depth (the controller register file "can host a queue of two
/// jobs").
pub const QUEUE_DEPTH: usize = 2;

/// The HWCE device model: job queue of two, busy-until tracking, and
/// issuing-core stall accounting.
#[derive(Debug, Default)]
pub struct Hwce {
    busy_until: u64,
    /// Completion times of queued jobs, ascending by construction; entries
    /// drain as `now` passes them, so queue pressure only exists while both
    /// slots genuinely hold unfinished jobs. `now` is the issuing core's
    /// clock and is expected to be non-decreasing across offloads.
    queue: Vec<u64>,
    /// Total cycles spent active (for energy integration).
    pub active_cycles: u64,
    pub jobs_done: u64,
    /// Cycles the issuing core spent blocked on a full job queue (it must
    /// hold the descriptor until a register-file slot frees).
    pub stall_cycles: u64,
}

impl Hwce {
    pub fn new() -> Self {
        Self::default()
    }

    /// Offload `job` at time `now`; returns the completion cycle. If both
    /// queue slots hold unfinished jobs the issuing core blocks until one
    /// frees — accounted in [`Hwce::stall_cycles`]. (The engine itself
    /// serializes on `busy_until` regardless; the queue models when the
    /// *core* is released, which the saturating counter this replaces
    /// never did, as it counted completed jobs as occupants forever.)
    pub fn offload(&mut self, now: u64, job: HwceJob, eu: Option<&mut EventUnit>) -> u64 {
        let cycles = simulate_tile_cycles(job);
        let issue_at = crate::cluster::accel_queue_issue_at(&mut self.queue, QUEUE_DEPTH, now);
        self.stall_cycles += issue_at - now;
        let start = self.busy_until.max(issue_at).max(now);
        let done = start + JOB_CONFIG_CYCLES + cycles;
        self.busy_until = done;
        self.queue.push(done);
        self.active_cycles += cycles;
        self.jobs_done += 1;
        if let Some(eu) = eu {
            eu.post(Event::HwceDone);
        }
        done
    }

    pub fn idle_at(&self) -> u64 {
        self.busy_until
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn offload_accumulates_active_cycles() {
        let mut hwce = Hwce::new();
        let job = HwceJob { w: 32, h: 32, k: 5, prec: WeightPrec::W16, qf: 8 };
        let done = hwce.offload(100, job, None);
        assert!(done > 100 + JOB_CONFIG_CYCLES);
        assert_eq!(hwce.jobs_done, 1);
        assert!(hwce.active_cycles > 0);
    }

    #[test]
    fn jobs_serialize() {
        let mut hwce = Hwce::new();
        let job = HwceJob { w: 16, h: 16, k: 3, prec: WeightPrec::W16, qf: 8 };
        let d1 = hwce.offload(0, job, None);
        let d2 = hwce.offload(0, job, None);
        assert!(d2 > d1);
    }

    /// Regression for the saturating-counter bug: the queue must drain as
    /// jobs complete. After two offloads have long finished, a third must
    /// issue immediately at `now` with no core stall (the old counter
    /// stayed at 2 forever, claiming permanent queue pressure).
    #[test]
    fn queue_drains_after_jobs_complete() {
        let mut hwce = Hwce::new();
        let job = HwceJob { w: 16, h: 16, k: 3, prec: WeightPrec::W16, qf: 8 };
        let d1 = hwce.offload(0, job, None);
        let d2 = hwce.offload(0, job, None);
        assert!(d2 > d1);
        let stall_after_two = hwce.stall_cycles;
        // Far in the future, both queue slots are free again.
        let now = d2 + 1_000_000;
        let d3 = hwce.offload(now, job, None);
        assert_eq!(
            d3,
            now + JOB_CONFIG_CYCLES + simulate_tile_cycles(job),
            "a free queue must not delay the job"
        );
        assert_eq!(hwce.stall_cycles, stall_after_two, "no stall on a drained queue");
    }

    /// With more than two back-to-back offloads at the same `now`, the
    /// third and later block the issuing core on queue slots (depth 2):
    /// completions serialize and the core-stall time is accounted.
    #[test]
    fn queue_depth_two_blocks_third_job() {
        let mut hwce = Hwce::new();
        let job = HwceJob { w: 16, h: 16, k: 3, prec: WeightPrec::W16, qf: 8 };
        let per_job = JOB_CONFIG_CYCLES + simulate_tile_cycles(job);
        let mut last = 0;
        for _ in 0..4 {
            last = hwce.offload(0, job, None);
        }
        assert_eq!(last, 4 * per_job);
        assert_eq!(hwce.jobs_done, 4);
        // jobs 1+2 issue at 0; job 3 waits for job 1 (1·per_job), job 4
        // waits for job 2 (2·per_job).
        assert_eq!(hwce.stall_cycles, 3 * per_job, "core must stall on full queue");
    }

    #[test]
    fn completion_event_posted() {
        let mut hwce = Hwce::new();
        let mut eu = EventUnit::new();
        let job = HwceJob { w: 16, h: 16, k: 5, prec: WeightPrec::W4, qf: 8 };
        hwce.offload(0, job, Some(&mut eu));
        assert!(eu.take(Event::HwceDone));
    }
}
