//! The Hardware Convolution Engine (HWCE) model (§II-C, Fig. 5).
//!
//! Three views of the same device:
//!
//! * [`golden`] — the bit-exact functional model: 5×5/3×3 sum-of-products
//!   over 16-bit pixels with 16/8/4-bit weights, accumulation with the
//!   memory-resident `y_in` stream, rounded normalization and saturation.
//!   This is the semantics contract shared with the Pallas kernel
//!   (`python/compile/kernels/hwce.py`) and the jnp oracle (`ref.py`); the
//!   AOT artifact is validated against this model in
//!   `rust/tests/runtime_artifacts.rs`.
//! * [`timing`] — the cycle model. A *detailed* mode replays the wrapper's
//!   streamer address traces (x fetch, per-fmap y_in/y_out) through the
//!   shared 4-port interface and the TCDM bank arbiter, reproducing the
//!   self-contention the paper measures; analytic per-pixel constants
//!   calibrated to §III-C are used when scaling tiles to full layers.
//! * [`Hwce`] — the device: a two-entry job queue (the controller register
//!   file "can host a queue of two jobs"), completion events, busy tracking.

pub mod golden;
pub mod timing;

pub use golden::{conv_multi, WeightPrec};
pub use timing::{analytic_cycles_per_px, simulate_tile_cycles};

use crate::cluster::event_unit::{Event, EventUnit};

/// A HWCE job descriptor (mirrors the controller register file: pointers to
/// x, W, y, strides, fractional bits, precision mode).
#[derive(Debug, Clone, Copy)]
pub struct HwceJob {
    /// Input feature-map width/height (pixels).
    pub w: usize,
    pub h: usize,
    /// Filter size: 3 or 5.
    pub k: usize,
    /// Weight precision mode.
    pub prec: WeightPrec,
    /// Fractional bits for normalization.
    pub qf: u8,
}

impl HwceJob {
    pub fn ow(&self) -> usize {
        self.w - self.k + 1
    }
    pub fn oh(&self) -> usize {
        self.h - self.k + 1
    }
    /// Output positions per pass (each yields `prec.simd()` output pixels on
    /// different feature maps).
    pub fn positions(&self) -> usize {
        self.ow() * self.oh()
    }
}

/// Cycles to program one job through the peripheral interconnect (register
/// writes for pointers/strides/config; §II: accelerators are on the
/// lower-priority peripheral path).
pub const JOB_CONFIG_CYCLES: u64 = 16;

/// The HWCE device model: job queue of two, busy-until tracking.
#[derive(Debug, Default)]
pub struct Hwce {
    busy_until: u64,
    queued: usize,
    /// Total cycles spent active (for energy integration).
    pub active_cycles: u64,
    pub jobs_done: u64,
}

impl Hwce {
    pub fn new() -> Self {
        Self::default()
    }

    /// Offload `job` at time `now`; returns the completion cycle. If both
    /// queue slots are full the caller (controller core) blocks until one
    /// frees — reflected in the returned start time.
    pub fn offload(&mut self, now: u64, job: HwceJob, eu: Option<&mut EventUnit>) -> u64 {
        let cycles = simulate_tile_cycles(job);
        let start = if self.queued >= 2 { self.busy_until } else { now.max(self.busy_until) };
        let done = start.max(now) + JOB_CONFIG_CYCLES + cycles;
        self.busy_until = done;
        self.queued = (self.queued + 1).min(2);
        self.active_cycles += cycles;
        self.jobs_done += 1;
        if let Some(eu) = eu {
            eu.post(Event::HwceDone);
        }
        done
    }

    pub fn idle_at(&self) -> u64 {
        self.busy_until
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn offload_accumulates_active_cycles() {
        let mut hwce = Hwce::new();
        let job = HwceJob { w: 32, h: 32, k: 5, prec: WeightPrec::W16, qf: 8 };
        let done = hwce.offload(100, job, None);
        assert!(done > 100 + JOB_CONFIG_CYCLES);
        assert_eq!(hwce.jobs_done, 1);
        assert!(hwce.active_cycles > 0);
    }

    #[test]
    fn jobs_serialize() {
        let mut hwce = Hwce::new();
        let job = HwceJob { w: 16, h: 16, k: 3, prec: WeightPrec::W16, qf: 8 };
        let d1 = hwce.offload(0, job, None);
        let d2 = hwce.offload(0, job, None);
        assert!(d2 > d1);
    }

    #[test]
    fn completion_event_posted() {
        let mut hwce = Hwce::new();
        let mut eu = EventUnit::new();
        let job = HwceJob { w: 16, h: 16, k: 5, prec: WeightPrec::W4, qf: 8 };
        hwce.offload(0, job, Some(&mut eu));
        assert!(eu.take(Event::HwceDone));
    }
}
