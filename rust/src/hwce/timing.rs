//! HWCE cycle model (§II-C wrapper + §III-C measurements).
//!
//! ## Detailed mode
//!
//! [`simulate_tile_cycles`] replays the wrapper's streamer traffic cycle by
//! cycle through the shared 4-port interface and the TCDM bank arbiter:
//!
//! * an **x fetcher** streams the input tile row-major as 32-bit words into
//!   the line buffer (which must stay ahead of the window being computed);
//! * the **sum-of-products datapath** produces one window position per cycle
//!   at most (two 5×5 sums-of-products per cycle would need a second
//!   multiplier array);
//! * per position, `simd()` partial sums are read (`y_in`) and written back
//!   (`y_out`) by replicated streamers, coalescing two adjacent positions
//!   into one 32-bit access per feature map;
//! * all streamers contend for 4 ports and 8 banks — the "self-contention by
//!   HWCE inputs/outputs trying to access the same TCDM bank in a given
//!   cycle" the paper includes in its full-platform measurement.
//!
//! The detailed model lands on the same cycles/px ladder the paper measures
//! (§III-C: 1.14/1.07 at 16 bit, 0.61/0.58 at 8 bit, 0.45/0.43 at 4 bit;
//! asserted within tolerance in the tests). For composing full layers the
//! coordinator uses [`analytic_cycles_per_px`], the paper's own measured
//! constants, so that use-case results are calibrated to silicon rather
//! than to our approximation of it.

use super::golden::WeightPrec;
use super::HwceJob;
use crate::cluster::tcdm::Tcdm;

/// §III-C measured average inverse throughput (cycles per output pixel),
/// full-platform (line-buffer fill, memory contention included).
pub fn analytic_cycles_per_px(k: usize, prec: WeightPrec) -> f64 {
    match (k, prec) {
        (5, WeightPrec::W16) => 1.14,
        (3, WeightPrec::W16) => 1.07,
        (5, WeightPrec::W8) => 0.61,
        (3, WeightPrec::W8) => 0.58,
        (5, WeightPrec::W4) => 0.45,
        (3, WeightPrec::W4) => 0.43,
        _ => panic!("unsupported filter size {k}"),
    }
}

/// Base TCDM addresses used by the trace generator (arbitrary but bank-
/// realistic: x, then per-fmap y regions).
const X_BASE: u32 = 0x0000;
const Y_BASE: u32 = 0x8000;
/// Per-fmap y region stride, staggered by one word so the four replicated
/// y streamers start on different banks (the HWCE wrapper's address
/// generators apply the same stagger to avoid systematic self-conflicts).
const Y_STRIDE: u32 = 0x1804;

/// Detailed streamer-level simulation; returns total cycles for one tile
/// pass (excluding job configuration).
pub fn simulate_tile_cycles(job: HwceJob) -> u64 {
    let simd = job.prec.simd();
    let (w, h, k) = (job.w, job.h, job.k);
    let (ow, oh) = (job.ow(), job.oh());
    let n_positions = ow * oh;
    let x_words_total = (w * h).div_ceil(2);

    let mut tcdm = Tcdm::new();

    // Streamer state.
    let mut x_fetched_words = 0usize; // words of x loaded so far
    let mut yin_fetched = vec![0usize; simd]; // positions worth of y_in available
    let mut produced = 0usize; // window positions computed by the datapath
    let mut yout_written = vec![0usize; simd]; // positions written back

    // Line buffer capacity: k rows + prefetch margin (latch-based SCM FIFOs).
    let lb_capacity_words = ((k + 1) * w).div_ceil(2);

    let mut cycle: u64 = 0;
    let max_cycles = (n_positions as u64 + x_words_total as u64) * 16 + 1024;

    while yout_written.iter().any(|&n| n < n_positions) {
        assert!(cycle < max_cycles, "HWCE sim did not converge");
        // Build the candidate request list (x prefetch, per-fmap y_in/y_out)
        // and grant up to 4 ports with a rotating start so no stream class
        // convoys the others. Each request: (master 8..=11, address).
        let mut candidates: Vec<(u32, StreamKind, usize)> = Vec::with_capacity(2 * simd + 1);
        // Words retire from the line buffer as the window advances by rows.
        let retired_words = (produced / ow) * w / 2;
        if x_fetched_words < x_words_total
            && x_fetched_words < lb_capacity_words + retired_words
        {
            candidates.push((X_BASE + x_fetched_words as u32 * 4, StreamKind::X, 0));
        }
        for f in 0..simd {
            // y_in: stay ahead of the datapath by up to 8 positions.
            if yin_fetched[f] < n_positions && yin_fetched[f] < produced + 8 {
                let addr = Y_BASE + f as u32 * Y_STRIDE + (yin_fetched[f] as u32 / 2) * 4;
                candidates.push((addr, StreamKind::YIn, f));
            }
            // y_out: one word (2 positions) per fmap whose data is ready.
            if yout_written[f] + 2 <= produced
                || (yout_written[f] < produced && produced == n_positions)
            {
                let addr = Y_BASE + f as u32 * Y_STRIDE + (yout_written[f] as u32 / 2) * 4;
                candidates.push((addr, StreamKind::YOut, f));
            }
        }
        let rot = if candidates.is_empty() { 0 } else { cycle as usize % candidates.len() };
        let mut reqs: Vec<(usize, u32, StreamKind, usize)> = Vec::with_capacity(4);
        for i in 0..candidates.len().min(4) {
            let (addr, kind, f) = candidates[(rot + i) % candidates.len()];
            reqs.push((8 + reqs.len(), addr, kind, f));
        }

        for &(m, addr, _, _) in &reqs {
            tcdm.request(m, addr);
        }
        let granted = tcdm.arbitrate();
        for &(m, _, kind, f) in &reqs {
            if granted[m] {
                match kind {
                    StreamKind::X => x_fetched_words += 1,
                    StreamKind::YIn => yin_fetched[f] = (yin_fetched[f] + 2).min(n_positions),
                    StreamKind::YOut => yout_written[f] = (yout_written[f] + 2).min(produced),
                }
            }
        }

        // Datapath: produce one position if the window and partial sums are in.
        if produced < n_positions {
            let pos = produced;
            let (oy, ox) = (pos / ow, pos % ow);
            // last x element of the window in row-major order:
            let last_elem = (oy + k - 1) * w + (ox + k - 1);
            let window_ready = x_fetched_words * 2 > last_elem;
            let yin_ready = (0..simd).all(|f| yin_fetched[f] > pos);
            if window_ready && yin_ready {
                produced += 1;
            }
        }
        cycle += 1;
    }
    cycle
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum StreamKind {
    X,
    YIn,
    YOut,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cyc_per_px(w: usize, h: usize, k: usize, prec: WeightPrec) -> f64 {
        let job = HwceJob { w, h, k, prec, qf: 8 };
        let c = simulate_tile_cycles(job);
        // each position yields simd() output pixels
        c as f64 / (job.positions() * prec.simd()) as f64
    }

    /// The detailed model must land on the §III-C ladder within tolerance.
    #[test]
    fn detailed_matches_paper_w16_5x5() {
        let c = cyc_per_px(32, 32, 5, WeightPrec::W16);
        let paper = 1.14;
        assert!((c - paper).abs() / paper < 0.25, "5x5 W16: {c} vs {paper}");
    }

    #[test]
    fn detailed_matches_paper_w8_5x5() {
        let c = cyc_per_px(32, 32, 5, WeightPrec::W8);
        let paper = 0.61;
        assert!((c - paper).abs() / paper < 0.30, "5x5 W8: {c} vs {paper}");
    }

    #[test]
    fn detailed_matches_paper_w4_5x5() {
        let c = cyc_per_px(32, 32, 5, WeightPrec::W4);
        let paper = 0.45;
        assert!((c - paper).abs() / paper < 0.35, "5x5 W4: {c} vs {paper}");
    }

    #[test]
    fn detailed_matches_paper_w16_3x3() {
        let c = cyc_per_px(32, 32, 3, WeightPrec::W16);
        let paper = 1.07;
        assert!((c - paper).abs() / paper < 0.25, "3x3 W16: {c} vs {paper}");
    }

    #[test]
    fn precision_scaling_monotone() {
        let c16 = cyc_per_px(32, 32, 5, WeightPrec::W16);
        let c8 = cyc_per_px(32, 32, 5, WeightPrec::W8);
        let c4 = cyc_per_px(32, 32, 5, WeightPrec::W4);
        assert!(c16 > c8 && c8 > c4, "{c16} > {c8} > {c4} violated");
        // 4-bit mode is memory-bound, not 4× faster than 16-bit (§III-C:
        // "further performance scaling would require an increase in memory
        // bandwidth")
        assert!(c16 / c4 < 4.0);
        assert!(c16 / c4 > 2.0);
    }

    #[test]
    fn analytic_constants_are_the_paper_table() {
        assert_eq!(analytic_cycles_per_px(5, WeightPrec::W16), 1.14);
        assert_eq!(analytic_cycles_per_px(3, WeightPrec::W4), 0.43);
    }

    #[test]
    fn small_tiles_pay_relatively_more_fill() {
        let small = cyc_per_px(12, 12, 5, WeightPrec::W16);
        let large = cyc_per_px(48, 48, 5, WeightPrec::W16);
        assert!(small > large, "fill overhead must show on small tiles: {small} vs {large}");
    }
}
