//! The first-class workload API: anything the Fulmine SoC can run is a
//! [`Workload`] — a named scenario that emits its frame as a job graph —
//! and the CLI, reports, benches and tests all resolve workloads through
//! one [`Registry`].
//!
//! The three §IV use cases ([`Surveillance`], [`FaceDetection`],
//! [`SeizureDetection`]) are registered implementations of the same trait
//! any embedder can implement; nothing about them is special. The paper's
//! own argument — one SoC flexibly serving many secure-analytics
//! scenarios — is what this seam encodes: a new scenario is a new
//! `impl Workload` plus one [`Registry::register`] call, and every
//! entry point (ladders, streaming, ablation sweeps, JSON reports) picks
//! it up unchanged.
//!
//! [`MixedStream`] is the proof: a multi-tenant workload that interleaves
//! frames of *different* scenarios on one SoC — inexpressible under the
//! old one-function-per-use-case API. Each tenant's jobs are tagged with
//! a graph segment ([`crate::soc::sched::JobGraph::mark_segment`]), so the
//! scheduler's result can be attributed back per tenant (active energy,
//! pJ/op) even though the engines interleave all tenants' phases freely.
//!
//! The façade that runs workloads (typed run specs, structured reports,
//! text + JSON rendering) lives in [`crate::system`].

use crate::coordinator::{facedet, seizure, surveillance, ExecConfig, GraphBuilder, Rung};
use crate::soc::sched::JobGraph;
use anyhow::{anyhow, bail, Result};

/// A schedulable scenario: one "frame" (or window) of work, emitted as a
/// job graph over the SoC's engines.
pub trait Workload {
    /// Registry key, CLI name and report label.
    fn name(&self) -> &'static str;

    /// One-line human description (shown by `fulmine workloads`).
    fn describe(&self) -> &'static str;

    /// Emit one frame of the workload into `b` (whose
    /// [`GraphBuilder::cfg`] carries the selected execution
    /// configuration). Streaming repeats the emitted graph.
    fn emit(&self, b: &mut GraphBuilder) -> Result<()>;

    /// OpenRISC-1200-equivalent operations of one frame (footnote 4 of the
    /// paper; configuration-invariant — the denominator of pJ/op).
    fn eq_ops(&self) -> u64;

    /// The workload's configuration ladder, worst to best. Defaults to the
    /// full Fig. 10-style ladder.
    fn rungs(&self) -> Vec<Rung> {
        ExecConfig::ladder()
    }

    /// Per-tenant `(name, eq_ops-per-frame)` rows for multi-tenant
    /// workloads; single-tenant workloads are their own only tenant.
    fn tenants(&self) -> Vec<(String, u64)> {
        vec![(self.name().to_string(), self.eq_ops())]
    }

    /// The workload's natural sensor frame rate (Hz): the arrival rate a
    /// deployed endpoint sees — what paces the [`crate::traffic::Traffic`]
    /// models [`crate::system::FleetSpec::mixed`] builds. Defaults to
    /// 1 Hz for workloads without a natural cadence.
    fn native_rate_hz(&self) -> f64 {
        1.0
    }
}

/// Build one frame of `w` at `cfg` as a standalone job graph.
pub fn frame_graph(w: &dyn Workload, cfg: ExecConfig) -> Result<JobGraph> {
    frame_graph_with(w, cfg, None)
}

/// [`frame_graph`] with an explicit crypto backend override — the
/// CryptoSRAM-style ablation axis. `None` keeps the configuration's
/// native backend, bitwise.
pub fn frame_graph_with(
    w: &dyn Workload,
    cfg: ExecConfig,
    backend: Option<crate::session::BackendKind>,
) -> Result<JobGraph> {
    let mut b = GraphBuilder::new(cfg);
    if let Some(kind) = backend {
        b.set_backend(kind);
    }
    w.emit(&mut b)?;
    Ok(b.build())
}

/// The secure-link session workload: one AEAD record per frame on an
/// established DTLS-style session. The steady template is the record
/// pipeline (sensor readout → framing on the cores → sponge AE on the
/// crypto backend) plus two zero-duration handshake placeholder jobs;
/// under a lossy channel ([`crate::session::SessionModel`]) a
/// [`crate::session::SessionPlan`] inflates the placeholders on
/// handshake frames and re-bills retransmitted records.
pub struct SecureLink;

/// SW cycles to frame/serialize one record before encryption (header,
/// sequence numbers, padding — ~12 cycles/byte over the record).
const RECORD_PACK_CYCLES: f64 = 12.0 * crate::session::RECORD_BYTES as f64;

impl Workload for SecureLink {
    fn name(&self) -> &'static str {
        "secure_link"
    }
    fn describe(&self) -> &'static str {
        "DTLS-style secure session: SW handshake flights + AEAD record stream over a lossy channel"
    }
    fn emit(&self, b: &mut GraphBuilder) -> Result<()> {
        // A bare radio endpoint: records stream off the sensor, no
        // external flash/FRAM in the loop.
        b.set_ext_mem_present(false);
        let (_cookie, flight) = b.session_handshake();
        let adc = b.adc(crate::session::RECORD_BYTES, &[]);
        let pack = b.sw(RECORD_PACK_CYCLES, 0.8, &[adc]);
        // The record rides the session: it depends on the (normally
        // zero-duration) flight placeholder, so handshake frames
        // serialize handshake-then-record.
        b.sponge_ae(crate::session::RECORD_BYTES, &[pack, flight]);
        Ok(())
    }
    fn eq_ops(&self) -> u64 {
        // Framing + AEAD of one 2 kB record in OpenRISC-equivalent ops.
        60_000
    }
    fn rungs(&self) -> Vec<Rung> {
        // No convolutions: the HWCE rungs collapse onto +HWCRYPT.
        ExecConfig::ladder().into_iter().filter(|r| r.cfg.hwce.is_none()).collect()
    }
    fn native_rate_hz(&self) -> f64 {
        // One record batch every 100 ms — radio cadence, not sensor
        // cadence.
        10.0
    }
}

/// §IV-A: secure autonomous aerial surveillance (Fig. 10).
pub struct Surveillance;

impl Workload for Surveillance {
    fn name(&self) -> &'static str {
        "surveillance"
    }
    fn describe(&self) -> &'static str {
        "secure aerial surveillance: ResNet-20 on 224x224 frames, XTS on all external data (§IV-A)"
    }
    fn emit(&self, b: &mut GraphBuilder) -> Result<()> {
        surveillance::emit(b);
        Ok(())
    }
    fn eq_ops(&self) -> u64 {
        surveillance::eq_ops()
    }
    fn native_rate_hz(&self) -> f64 {
        // §IV-A: one secured inference every ~2 s of the 7-min flight.
        0.5
    }
}

/// §IV-B: local face detection with secured remote recognition (Fig. 11).
pub struct FaceDetection;

impl Workload for FaceDetection {
    fn name(&self) -> &'static str {
        "facedet"
    }
    fn describe(&self) -> &'static str {
        "local face detection + secured remote recognition: 12/24-net cascade in L2 (§IV-B)"
    }
    fn emit(&self, b: &mut GraphBuilder) -> Result<()> {
        facedet::emit(b);
        Ok(())
    }
    fn eq_ops(&self) -> u64 {
        facedet::eq_ops()
    }
    fn native_rate_hz(&self) -> f64 {
        // §IV-B: always-on camera trigger, a few frames per second.
        2.0
    }
}

/// §IV-C: EEG seizure detection with secure long-term monitoring (Fig. 12).
pub struct SeizureDetection;

impl Workload for SeizureDetection {
    fn name(&self) -> &'static str {
        "seizure"
    }
    fn describe(&self) -> &'static str {
        "EEG seizure detection + secure collection: PCA/DWT/SVM every 0.5 s window (§IV-C)"
    }
    fn emit(&self, b: &mut GraphBuilder) -> Result<()> {
        seizure::emit(b);
        Ok(())
    }
    fn eq_ops(&self) -> u64 {
        seizure::eq_ops()
    }
    fn rungs(&self) -> Vec<Rung> {
        seizure::rung_configs()
    }
    fn native_rate_hz(&self) -> f64 {
        // §IV-C: one 23-channel EEG window every 0.5 s.
        2.0
    }
}

/// A multi-tenant stream: one "frame" interleaves one frame of each tenant
/// workload on the same SoC. The scheduler is free to overlap tenants'
/// phases across engines and cores (a seizure window's analytics run under
/// the surveillance frame's FRAM round trips, and mode-compatible tenants
/// co-reside on the cluster point); per-tenant attribution comes from
/// graph segments.
///
/// All tenants share the selected rung's [`ExecConfig`] — one cluster, one
/// supply voltage, one mode sequence (the §II-D discipline). They also
/// share one [`GraphBuilder`], so a tenant that pins the cluster at the
/// all-capable CRY-CNN-SW point (e.g. surveillance at the accelerated
/// rungs) pins it for the tenants emitted after it too: on a shared chip
/// the cluster point is a chip-wide choice, and staying at the
/// all-capable point is what lets tenants co-reside without relock churn.
pub struct MixedStream {
    name: &'static str,
    describe: &'static str,
    tenants: Vec<Box<dyn Workload>>,
}

impl MixedStream {
    pub fn new(
        name: &'static str,
        describe: &'static str,
        tenants: Vec<Box<dyn Workload>>,
    ) -> Self {
        MixedStream { name, describe, tenants }
    }
}

impl Workload for MixedStream {
    fn name(&self) -> &'static str {
        self.name
    }
    fn describe(&self) -> &'static str {
        self.describe
    }
    fn emit(&self, b: &mut GraphBuilder) -> Result<()> {
        if self.tenants.is_empty() {
            bail!("mixed workload {:?} has no tenants", self.name);
        }
        // The external memories are attached iff any tenant needs them
        // (a tenant's emit may detach them for its own platform — §IV-C).
        let mut ext_mem = false;
        for t in &self.tenants {
            b.set_ext_mem_present(true);
            b.begin_segment(t.name());
            t.emit(b)?;
            ext_mem |= b.ext_mem_present();
        }
        b.set_ext_mem_present(ext_mem);
        Ok(())
    }
    fn eq_ops(&self) -> u64 {
        self.tenants.iter().map(|t| t.eq_ops()).sum()
    }
    fn native_rate_hz(&self) -> f64 {
        // A shared chip is paced by its slowest sensor: a mixed frame
        // carries one frame of every tenant.
        let slowest = self
            .tenants
            .iter()
            .map(|t| t.native_rate_hz())
            .fold(f64::INFINITY, f64::min);
        if slowest.is_finite() { slowest } else { 1.0 }
    }
    fn tenants(&self) -> Vec<(String, u64)> {
        // Aggregate by name: segments of repeated tenants merge the same way.
        let mut out: Vec<(String, u64)> = Vec::new();
        for t in &self.tenants {
            match out.iter_mut().find(|(n, _)| n == t.name()) {
                Some((_, ops)) => *ops += t.eq_ops(),
                None => out.push((t.name().to_string(), t.eq_ops())),
            }
        }
        out
    }
}

/// The workload registry: the single place every entry point (CLI,
/// reports, benches, tests) resolves scenario names through.
pub struct Registry {
    entries: Vec<Box<dyn Workload>>,
}

impl Registry {
    /// An empty registry (embedders composing their own scenario set).
    pub fn empty() -> Self {
        Registry { entries: Vec::new() }
    }

    /// The built-in set: the three §IV use cases plus the `mixed`
    /// multi-tenant stream over all three.
    pub fn builtin() -> Self {
        let mut r = Self::empty();
        r.register(Box::new(Surveillance));
        r.register(Box::new(FaceDetection));
        r.register(Box::new(SeizureDetection));
        r.register(Box::new(MixedStream::new(
            "mixed",
            "multi-tenant stream: one surveillance + facedet + seizure frame per round on one SoC",
            vec![Box::new(Surveillance), Box::new(FaceDetection), Box::new(SeizureDetection)],
        )));
        r.register(Box::new(SecureLink));
        r
    }

    /// Register a workload; a same-named entry is replaced (latest wins).
    pub fn register(&mut self, w: Box<dyn Workload>) {
        match self.entries.iter_mut().find(|e| e.name() == w.name()) {
            Some(slot) => *slot = w,
            None => self.entries.push(w),
        }
    }

    pub fn get(&self, name: &str) -> Option<&dyn Workload> {
        self.entries.iter().find(|e| e.name() == name).map(|b| b.as_ref())
    }

    /// Resolve a name or fail with the available set.
    pub fn resolve(&self, name: &str) -> Result<&dyn Workload> {
        self.get(name).ok_or_else(|| {
            anyhow!("unknown workload {name:?}; available: {:?}", self.names())
        })
    }

    /// Registered names, in registration order.
    pub fn names(&self) -> Vec<&'static str> {
        self.entries.iter().map(|e| e.name()).collect()
    }

    pub fn iter(&self) -> impl Iterator<Item = &dyn Workload> {
        self.entries.iter().map(|b| b.as_ref())
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

impl Default for Registry {
    fn default() -> Self {
        Self::builtin()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::sched::Scheduler;

    #[test]
    fn builtin_registry_resolves_paper_usecases() {
        let r = Registry::builtin();
        assert_eq!(r.names(), vec!["surveillance", "facedet", "seizure", "mixed", "secure_link"]);
        for name in r.names() {
            let w = r.resolve(name).unwrap();
            assert!(!w.describe().is_empty());
            assert!(w.eq_ops() > 0, "{name} eq_ops");
            assert!(!w.rungs().is_empty(), "{name} rungs");
        }
        let err = r.resolve("bogus").unwrap_err().to_string();
        assert!(err.contains("available"), "{err}");
    }

    #[test]
    fn register_replaces_same_name() {
        let mut r = Registry::builtin();
        let before = r.len();
        r.register(Box::new(MixedStream::new(
            "mixed",
            "replacement",
            vec![Box::new(SeizureDetection)],
        )));
        assert_eq!(r.len(), before);
        assert_eq!(r.get("mixed").unwrap().describe(), "replacement");
    }

    #[test]
    fn workload_graphs_match_direct_coordinator_graphs() {
        let cfg = ExecConfig::ladder().last().unwrap().cfg;
        let via_trait = frame_graph(&Surveillance, cfg).unwrap();
        let direct = surveillance::frame_graph(cfg);
        assert_eq!(via_trait.len(), direct.len());
        let a = Scheduler::run(&via_trait);
        let b = Scheduler::run(&direct);
        assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits());
        assert_eq!(a.ledger.total_mj().to_bits(), b.ledger.total_mj().to_bits());
    }

    #[test]
    fn mixed_stream_emits_all_tenants_with_segments() {
        let r = Registry::builtin();
        let mixed = r.resolve("mixed").unwrap();
        let cfg = mixed.rungs().last().unwrap().cfg;
        let g = frame_graph(mixed, cfg).unwrap();
        let expect: usize = [
            surveillance::frame_graph(cfg).len(),
            facedet::frame_graph(cfg).len(),
            seizure::window_graph(cfg).len(),
        ]
        .iter()
        .sum();
        assert_eq!(g.len(), expect, "mixed frame = one frame of each tenant");
        assert_eq!(g.segments.len(), 3);
        assert_eq!(g.segment_labels.len(), 3, "tenant labels intern once");
        // streaming repeats markers but never duplicates the label table
        let g16 = g.repeat(16);
        assert_eq!(g16.segments.len(), 48);
        assert_eq!(g16.segment_labels.len(), 3);
        assert!(g.ext_mem_present, "surveillance needs the external memories");
        let seg = g.segment_active_mj();
        assert_eq!(seg.len(), 3);
        for (name, mj) in &seg {
            assert!(*mj > 0.0, "tenant {name} has zero active energy");
        }
        // the schedule completes (no deadlock across tenant mode demands)
        let res = Scheduler::run(&g);
        assert!(res.makespan_s > 0.0);
    }

    #[test]
    fn secure_link_template_and_backend_ablation() {
        let w = SecureLink;
        let rungs = w.rungs();
        assert_eq!(rungs.len(), 3, "HWCE rungs collapse for a conv-free workload");
        for rung in &rungs {
            let g = frame_graph(&w, rung.cfg).unwrap();
            assert!(crate::session::has_session_jobs(&g), "{}", rung.label);
            assert!(!g.ext_mem_present, "{}: a bare radio endpoint", rung.label);
            // placeholders are free in the steady template
            for j in g.jobs.iter().filter(|j| j.label.starts_with("hs-")) {
                assert_eq!(j.duration_s, 0.0, "{}", rung.label);
            }
            let res = Scheduler::run(&g);
            assert!(res.makespan_s > 0.0, "{}", rung.label);
        }
        // the native backend override reproduces the default bitwise
        let cfg = ExecConfig::with_hwcrypt();
        let native = Scheduler::run(&frame_graph(&w, cfg).unwrap());
        let forced = Scheduler::run(
            &frame_graph_with(&w, cfg, Some(crate::session::BackendKind::Hwcrypt)).unwrap(),
        );
        assert_eq!(native.makespan_s.to_bits(), forced.makespan_s.to_bits());
        assert_eq!(
            native.ledger.total_mj().to_bits(),
            forced.ledger.total_mj().to_bits()
        );
        // every backend builds and schedules on every rung — the sweep
        // the session ablation iterates
        for rung in &rungs {
            for kind in crate::session::BackendKind::all() {
                let g = frame_graph_with(&w, rung.cfg, Some(kind)).unwrap();
                let r = Scheduler::run(&g);
                assert!(r.makespan_s > 0.0, "{} × {}", rung.label, kind.name());
            }
        }
    }

    #[test]
    fn mixed_eq_ops_sum_and_tenant_rows() {
        let mixed = MixedStream::new(
            "m2",
            "two seizure windows + one facedet frame",
            vec![Box::new(SeizureDetection), Box::new(SeizureDetection), Box::new(FaceDetection)],
        );
        assert_eq!(mixed.eq_ops(), 2 * SeizureDetection.eq_ops() + FaceDetection.eq_ops());
        let t = mixed.tenants();
        assert_eq!(t.len(), 2, "duplicate tenants aggregate by name");
        assert_eq!(t[0], ("seizure".to_string(), 2 * SeizureDetection.eq_ops()));
    }
}
