//! EEG seizure-detection pipeline (§IV-C): functional fixed-point
//! implementation (PCA → DWT → energy coefficients → SVM) plus a synthetic
//! 23-channel EEG source with injected ictal (seizure) segments.
//!
//! The paper's substrate is the CHB-MIT-style recordings of [30]; we have no
//! access to clinical data, so the generator synthesizes background EEG
//! (mixed-frequency oscillations + noise) and seizure windows (large-
//! amplitude rhythmic 3–5 Hz activity) — exercising the identical code path
//! with a discriminable signal, per the substitution rule (DESIGN.md §1).

use crate::kernels_sw::eeg_cost::{N_CHANNELS, N_COMPONENTS, N_SAMPLES};

/// Fixed-point EEG sample type (the ADC delivers 32-bit words; we keep i32
/// through PCA to preserve precision, as the paper's pipeline does).
pub type Sample = i32;

/// Deterministic sine table (Q15) to avoid libm in the signal generator.
fn sin_q15(phase: u32) -> i32 {
    // 1024-entry quarter-wave table built once.
    use std::sync::OnceLock;
    static TABLE: OnceLock<Vec<i32>> = OnceLock::new();
    let t = TABLE.get_or_init(|| {
        (0..1024)
            .map(|i| {
                let x = (i as f64 + 0.5) * std::f64::consts::FRAC_PI_2 / 1024.0;
                (x.sin() * 32767.0) as i32
            })
            .collect()
    });
    let p = (phase >> 6) & 0xfff; // 4096 positions per period
    match p >> 10 {
        0 => t[(p & 1023) as usize],
        1 => t[(1023 - (p & 1023)) as usize],
        2 => -t[(p & 1023) as usize],
        _ => -t[(1023 - (p & 1023)) as usize],
    }
}

/// Generate one 23×256 window. `seizure` injects rhythmic high-amplitude
/// activity across channels.
pub fn synth_window(seed: u64, seizure: bool) -> Vec<Vec<Sample>> {
    let mut x = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1;
    let mut rnd = move || {
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        x
    };
    (0..N_CHANNELS)
        .map(|ch| {
            let f1 = 8 + (ch % 5) as u32; // alpha-ish background
            let f2 = 20 + (ch % 7) as u32; // beta-ish background
            let phase0 = (rnd() & 0xffff) as u32;
            (0..N_SAMPLES)
                .map(|t| {
                    let t = t as u32;
                    let mut v = sin_q15(phase0 + t * f1 * 1024) / 8
                        + sin_q15(phase0 / 3 + t * f2 * 1024) / 16
                        + ((rnd() & 0xfff) as i32 - 2048);
                    if seizure {
                        // 4 Hz rhythmic discharge, 6× background amplitude
                        v += sin_q15(t * 4 * 1024) / 2 * 3;
                    }
                    v
                })
                .collect()
        })
        .collect()
}

/// Covariance matrix (upper triangle mirrored), means removed, >> 8 to keep
/// dynamic range.
pub fn covariance(win: &[Vec<Sample>]) -> Vec<Vec<i64>> {
    let ch = win.len();
    let n = win[0].len() as i64;
    let means: Vec<i64> = win
        .iter()
        .map(|c| c.iter().map(|&v| v as i64).sum::<i64>() / n)
        .collect();
    let mut cov = vec![vec![0i64; ch]; ch];
    for i in 0..ch {
        for j in i..ch {
            let mut acc = 0i64;
            for t in 0..win[0].len() {
                acc += (win[i][t] as i64 - means[i]) * (win[j][t] as i64 - means[j]);
            }
            let v = acc / n;
            cov[i][j] = v;
            cov[j][i] = v;
        }
    }
    cov
}

/// Jacobi eigen-decomposition (cyclic sweeps) returning eigenvalues and
/// eigenvectors, sorted by descending eigenvalue. Integer-scaled float-free
/// Jacobi is numerically fragile; the silicon runs this in software too, so
/// we use f64 internally and quantize the projection — the *cycle* cost is
/// modelled separately in [`crate::kernels_sw::eeg_cost`].
pub fn jacobi_eigen(cov: &[Vec<i64>]) -> (Vec<f64>, Vec<Vec<f64>>) {
    let n = cov.len();
    let mut a: Vec<Vec<f64>> = cov.iter().map(|r| r.iter().map(|&v| v as f64).collect()).collect();
    let mut v = vec![vec![0.0; n]; n];
    for (i, row) in v.iter_mut().enumerate() {
        row[i] = 1.0;
    }
    for _sweep in 0..8 {
        let mut off = 0.0;
        for p in 0..n {
            for q in p + 1..n {
                off += a[p][q] * a[p][q];
            }
        }
        if off < 1e-3 {
            break;
        }
        for p in 0..n {
            for q in p + 1..n {
                if a[p][q].abs() < 1e-12 {
                    continue;
                }
                let theta = (a[q][q] - a[p][p]) / (2.0 * a[p][q]);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                for k in 0..n {
                    let (akp, akq) = (a[k][p], a[k][q]);
                    a[k][p] = c * akp - s * akq;
                    a[k][q] = s * akp + c * akq;
                }
                for k in 0..n {
                    let (apk, aqk) = (a[p][k], a[q][k]);
                    a[p][k] = c * apk - s * aqk;
                    a[q][k] = s * apk + c * aqk;
                }
                for k in 0..n {
                    let (vkp, vkq) = (v[k][p], v[k][q]);
                    v[k][p] = c * vkp - s * vkq;
                    v[k][q] = s * vkp + c * vkq;
                }
            }
        }
    }
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&i, &j| a[j][j].partial_cmp(&a[i][i]).unwrap());
    let evals: Vec<f64> = idx.iter().map(|&i| a[i][i]).collect();
    let evecs: Vec<Vec<f64>> = idx
        .iter()
        .map(|&i| (0..n).map(|k| v[k][i]).collect())
        .collect();
    (evals, evecs)
}

/// Project the window onto the top [`N_COMPONENTS`] principal components
/// (i32 output, scaled).
pub fn pca_project(win: &[Vec<Sample>], evecs: &[Vec<f64>]) -> Vec<Vec<i32>> {
    (0..N_COMPONENTS)
        .map(|c| {
            (0..win[0].len())
                .map(|t| {
                    let mut acc = 0.0;
                    for (ch, w) in win.iter().enumerate() {
                        acc += evecs[c][ch] * w[t] as f64;
                    }
                    (acc / 16.0) as i32
                })
                .collect()
        })
        .collect()
}

/// Haar DWT (the paper uses a 4-tap filter bank; Haar keeps the fixed-point
/// path exact): returns per-level detail energies + final approx energy.
pub fn dwt_energies(signal: &[i32], levels: usize) -> Vec<i64> {
    let mut cur: Vec<i64> = signal.iter().map(|&v| v as i64).collect();
    let mut feats = Vec::with_capacity(levels + 1);
    for _ in 0..levels {
        let half = cur.len() / 2;
        let mut approx = Vec::with_capacity(half);
        let mut energy = 0i64;
        for i in 0..half {
            let a = (cur[2 * i] + cur[2 * i + 1]) >> 1;
            let d = (cur[2 * i] - cur[2 * i + 1]) >> 1;
            energy += d * d >> 8;
            approx.push(a);
        }
        feats.push(energy);
        cur = approx;
    }
    feats.push(cur.iter().map(|&v| (v * v) >> 8).sum());
    feats
}

/// Feature vector: DWT energies of each principal component.
pub fn features(components: &[Vec<i32>], levels: usize) -> Vec<i64> {
    components
        .iter()
        .flat_map(|c| dwt_energies(c, levels))
        .collect()
}

/// A trivial linear SVM: sign(w·f + b). Weights are trained offline (here:
/// fixed to detect the energy signature of the injected seizures — total
/// energy in the low-frequency bands above a threshold).
pub struct LinearSvm {
    pub w: Vec<i64>,
    pub b: i64,
}

impl LinearSvm {
    /// Decision threshold calibrated on the synthetic generator: seizure
    /// windows carry ≫ energy in the deepest approximation/detail bands.
    pub fn synthetic_detector(levels: usize) -> Self {
        let feats_per_comp = levels + 1;
        let mut w = vec![0i64; N_COMPONENTS * feats_per_comp];
        for c in 0..N_COMPONENTS {
            // weight the low-frequency (deep) bands positively
            w[c * feats_per_comp + levels] = 1;
            w[c * feats_per_comp + levels - 1] = 1;
        }
        // Calibrated on the synthetic generator: background windows score
        // ≈3–8×10⁴ on these features, seizure windows ≈6×10⁶.
        LinearSvm { w, b: -500_000 }
    }

    pub fn classify(&self, f: &[i64]) -> bool {
        let score: i64 = self.w.iter().zip(f).map(|(w, x)| w * x).sum::<i64>() + self.b;
        score > 0
    }
}

/// Full pipeline on one window: returns (seizure?, pca components).
pub fn detect(win: &[Vec<Sample>], levels: usize) -> (bool, Vec<Vec<i32>>) {
    let cov = covariance(win);
    let (_evals, evecs) = jacobi_eigen(&cov);
    let comps = pca_project(win, &evecs);
    let f = features(&comps, levels);
    let svm = LinearSvm::synthetic_detector(levels);
    (svm.classify(&f), comps)
}

/// Bytes of PCA components encrypted per window for secure long-term
/// collection (9 components × 256 samples × 2 B, quantized to i16).
pub fn collected_bytes() -> usize {
    N_COMPONENTS * N_SAMPLES * 2
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels_sw::eeg_cost::DWT_LEVELS;

    #[test]
    fn generator_is_deterministic() {
        assert_eq!(synth_window(5, false), synth_window(5, false));
        assert_ne!(synth_window(5, false), synth_window(6, false));
    }

    #[test]
    fn seizure_windows_have_higher_energy() {
        let bg = synth_window(1, false);
        let sz = synth_window(1, true);
        let e = |w: &Vec<Vec<i32>>| -> i64 {
            w.iter().flat_map(|c| c.iter().map(|&v| (v as i64).pow(2) >> 8)).sum()
        };
        assert!(e(&sz) > 2 * e(&bg));
    }

    #[test]
    fn detector_separates_seizure_from_background() {
        let mut tp = 0;
        let mut fp = 0;
        for seed in 0..10 {
            let (d_sz, _) = detect(&synth_window(100 + seed, true), DWT_LEVELS);
            let (d_bg, _) = detect(&synth_window(200 + seed, false), DWT_LEVELS);
            tp += d_sz as u32;
            fp += d_bg as u32;
        }
        assert!(tp >= 9, "missed seizures: {tp}/10");
        assert!(fp <= 1, "false alarms: {fp}/10");
    }

    #[test]
    fn jacobi_diagonalizes() {
        let win = synth_window(3, false);
        let cov = covariance(&win);
        let (evals, evecs) = jacobi_eigen(&cov);
        // eigenvalues sorted descending, eigenvectors ~unit norm
        for i in 1..evals.len() {
            assert!(evals[i - 1] >= evals[i] - 1e-6);
        }
        for v in &evecs {
            let n: f64 = v.iter().map(|x| x * x).sum::<f64>().sqrt();
            assert!((n - 1.0).abs() < 1e-6, "norm {n}");
        }
        // trace preserved
        let tr: f64 = cov.iter().enumerate().map(|(i, r)| r[i] as f64).sum();
        let se: f64 = evals.iter().sum();
        assert!((tr - se).abs() / tr.abs().max(1.0) < 1e-6);
    }

    #[test]
    fn covariance_symmetric_psd_diag() {
        let win = synth_window(9, false);
        let cov = covariance(&win);
        for i in 0..cov.len() {
            assert!(cov[i][i] >= 0);
            for j in 0..cov.len() {
                assert_eq!(cov[i][j], cov[j][i]);
            }
        }
    }

    #[test]
    fn dwt_preserves_energy_order() {
        let flat = vec![100i32; 256];
        let e = dwt_energies(&flat, 4);
        // constant signal: all detail energies zero, approx carries all
        assert!(e[..4].iter().all(|&x| x == 0));
        assert!(e[4] > 0);
    }

    #[test]
    fn collected_bytes_value() {
        assert_eq!(collected_bytes(), 4608);
    }
}
