//! Face-detection workload (§IV-B): the 12-net/24-net cascade of Li et
//! al. [29] scanned over a 224×224 frame, with full-image AES-128-XTS
//! encryption when a candidate face is found.

use super::resnet::ConvLayer;

/// Frame dims.
pub const FRAME: usize = 224;
/// Fraction of the image area the 12-net classifies as containing faces
/// (§IV-B: "the first stage 12-net classifies 10% of the input image as
/// containing faces, and ... the second stage 24-net is applied only to
/// that fraction").
pub const STAGE2_FRACTION: f64 = 0.10;

/// Number of 12×12 windows scanned. §IV-B: "the networks are applied to
/// small *separate* 24×24 windows extracted from the input image" — i.e.
/// a non-overlapping tiling, not a dense sliding scan (this is also the
/// only reading consistent with the published 0.57 mJ / 5.74 pJ/op ⇒
/// ≈10⁸ equivalent-op workload).
pub fn n_windows_12() -> usize {
    let n = FRAME / 12; // 18 full tiles
    n * n
}

/// Number of 24×24 windows evaluated by the 24-net: 10 % of the image area.
pub fn n_windows_24() -> usize {
    let tiles = (FRAME / 24) * (FRAME / 24);
    (tiles as f64 * STAGE2_FRACTION).round() as usize
}

/// The 12-net convolution (per window batch of 1): 1→16 3×3 on 12².
pub fn conv_12net() -> ConvLayer {
    ConvLayer { name: "12net.conv", cin: 1, cout: 16, h: 12, w: 12, k: 3, stride: 1, pool: 2 }
}

/// The 24-net convolution: 1→64 5×5 on 24², pooled twice to 5×5 (the
/// parameter set must fit L2 — see the python model's shape comment).
pub fn conv_24net() -> ConvLayer {
    ConvLayer { name: "24net.conv", cin: 1, cout: 64, h: 24, w: 24, k: 5, stride: 1, pool: 4 }
}

/// Dense-layer MACs per 12-net window: fc1 (16·5·5 → 16) + fc2 (16 → 2).
pub fn dense_macs_12() -> u64 {
    (16 * 5 * 5 * 16 + 16 * 2) as u64
}

/// Dense-layer MACs per 24-net window: fc1 (64·5·5 → 32) + fc2 (32 → 2).
pub fn dense_macs_24() -> u64 {
    (64 * 5 * 5 * 32 + 32 * 2) as u64
}

/// Total conv MACs for a frame.
pub fn total_conv_macs() -> u64 {
    // 12-net convs are computed per window (windows overlap; the cascade
    // recomputes per candidate as in [29])
    n_windows_12() as u64 * conv_12net().macs() + n_windows_24() as u64 * conv_24net().macs()
}

/// Total dense MACs for a frame.
pub fn total_dense_macs() -> u64 {
    n_windows_12() as u64 * dense_macs_12() + n_windows_24() as u64 * dense_macs_24()
}

/// Bytes encrypted when a face is detected: the full 8-bit camera frame.
pub fn encrypted_image_bytes() -> usize {
    FRAME * FRAME
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_counts() {
        assert_eq!(n_windows_12(), 18 * 18);
        assert_eq!(n_windows_24(), 8); // 10% of the 81 24×24 tiles
    }

    /// The workload must land near the paper's ≈10⁸ equivalent-op scale
    /// (0.57 mJ ÷ 5.74 pJ/op ≈ 99 M ops) — the consistency check that pins
    /// the window-tiling interpretation.
    #[test]
    fn total_workload_scale() {
        let eq = crate::coordinator::facedet::eq_ops() as f64;
        assert!((4e7..2.5e8).contains(&eq), "eq_ops = {eq:.3e} (paper ≈ 9.9e7)");
    }

    #[test]
    fn workload_balance_matches_paper_narrative() {
        // §IV-B: baseline energy "almost evenly spent between convolutions,
        // AES-128-XTS encryption, and densely connected CNN layers" — the
        // conv and dense MAC pools must be the same order of magnitude.
        let conv = total_conv_macs() as f64;
        let dense = total_dense_macs() as f64;
        let ratio = conv / dense;
        assert!((0.2..8.0).contains(&ratio), "conv/dense = {ratio}");
    }

    #[test]
    fn per_window_macs() {
        // 12-net conv: 1·16·9·144 = 20736 dense-computed MACs
        assert_eq!(conv_12net().macs(), 20736);
        assert_eq!(dense_macs_12(), 6432);
        assert_eq!(dense_macs_24(), 51264);
    }
}
