//! ResNet-20 workload tables (§IV-A).
//!
//! Two variants:
//!
//! * [`resnet20_cifar`] — the native 32×32 CIFAR topology (16/32/64
//!   channels), used by the functional end-to-end example through the
//!   `resnet20_cifar_w4` AOT artifact.
//! * [`resnet20_224`] — the surveillance workload on 224×224 frames. The
//!   paper gives three hard facts about its variant: >1.35×10⁹ operations,
//!   8.9 MB of 16-bit weights, and a 1.5 MB maximum partial result (the
//!   16-channel first-layer output at 224² is exactly 1.6 MB). We
//!   reconstruct a ResNet-20 (19 convolutions + fc) meeting those
//!   footprints: conv1 3→16 @224², 4×4 pool to 56², then three stages of
//!   six 3×3 convolutions at 64/128/256 channels on 28²/14²/7² grids.
//!   The reconstruction lands at ≈4.2 M weights (≈8.5 MB) and ≈0.5 G MACs
//!   (≈1.0 G arithmetic ops) — within 10 % of the published footprints;
//!   the deviation is recorded in EXPERIMENTS.md.

use crate::hwce::golden::{weight_bytes, WeightPrec};

/// One convolutional layer of the workload.
#[derive(Debug, Clone)]
pub struct ConvLayer {
    pub name: &'static str,
    pub cin: usize,
    pub cout: usize,
    /// Input spatial dims (pre-padding).
    pub h: usize,
    pub w: usize,
    pub k: usize,
    /// Output subsampling (HWCE computes densely; stride discards).
    pub stride: usize,
    /// 2×2 max pool after activation.
    pub pool: usize,
}

impl ConvLayer {
    /// Output dims after stride and pooling.
    pub fn out_dims(&self) -> (usize, usize) {
        (self.h / self.stride / self.pool, self.w / self.stride / self.pool)
    }

    /// Dense output positions per pass ('same' conv at input resolution —
    /// what the HWCE actually computes before stride subsampling).
    pub fn positions(&self) -> usize {
        self.h * self.w
    }

    /// HWCE passes for the full layer at the given precision.
    pub fn passes(&self, prec: WeightPrec) -> usize {
        self.cin * self.cout.div_ceil(prec.simd())
    }

    /// Multiply-accumulates (dense compute, as executed).
    pub fn macs(&self) -> u64 {
        (self.cin * self.cout * self.k * self.k) as u64 * self.positions() as u64
    }

    /// Weight bytes at a given precision.
    pub fn weight_bytes(&self, prec: WeightPrec) -> usize {
        weight_bytes(prec, self.k, self.cin, self.cout)
    }

    /// Output feature-map bytes (i16), after stride/pool.
    pub fn out_bytes(&self) -> usize {
        let (oh, ow) = self.out_dims();
        self.cout * oh * ow * 2
    }

    /// Input feature-map bytes (i16).
    pub fn in_bytes(&self) -> usize {
        self.cin * self.h * self.w * 2
    }

    /// Dense (pre-stride) output bytes — the partial results the HWCE
    /// streams to memory during accumulation.
    pub fn dense_out_bytes(&self) -> usize {
        self.cout * self.h * self.w * 2
    }
}

/// The CIFAR-native ResNet-20 (matches `resnet20_param_shapes()` on the
/// python side: conv1 + 9 blocks × 2 convs + fc).
pub fn resnet20_cifar() -> Vec<ConvLayer> {
    let mut layers = vec![ConvLayer {
        name: "conv1", cin: 3, cout: 16, h: 32, w: 32, k: 3, stride: 1, pool: 1,
    }];
    let stages: [(usize, usize, usize); 3] = [(16, 32, 1), (32, 16, 2), (64, 8, 2)];
    let mut cin = 16;
    for (si, &(cout, hw, first_stride)) in stages.iter().enumerate() {
        for blk in 0..3 {
            let stride = if blk == 0 { first_stride } else { 1 };
            let h_in = if stride == 2 { hw * 2 } else { hw };
            layers.push(ConvLayer {
                name: stage_name(si, blk, 1), cin, cout, h: h_in, w: h_in, k: 3, stride, pool: 1,
            });
            layers.push(ConvLayer {
                name: stage_name(si, blk, 2), cin: cout, cout, h: hw, w: hw, k: 3, stride: 1, pool: 1,
            });
            cin = cout;
        }
    }
    layers
}

fn stage_name(stage: usize, blk: usize, conv: usize) -> &'static str {
    // static names for the 18 block convs
    const NAMES: [[&str; 2]; 9] = [
        ["s0b0.c1", "s0b0.c2"], ["s0b1.c1", "s0b1.c2"], ["s0b2.c1", "s0b2.c2"],
        ["s1b0.c1", "s1b0.c2"], ["s1b1.c1", "s1b1.c2"], ["s1b2.c1", "s1b2.c2"],
        ["s2b0.c1", "s2b0.c2"], ["s2b1.c1", "s2b1.c2"], ["s2b2.c1", "s2b2.c2"],
    ];
    NAMES[stage * 3 + blk][conv - 1]
}

/// The 224×224 surveillance ResNet-20 reconstruction (see module docs).
pub fn resnet20_224() -> Vec<ConvLayer> {
    let mut layers = vec![
        // conv1 at full resolution: 16 × 224² × 2 B = 1.6 MB partial (the
        // paper's 1.5 MB max), then 4×4 pooled to 56².
        ConvLayer { name: "conv1", cin: 3, cout: 16, h: 224, w: 224, k: 3, stride: 1, pool: 4 },
        // transition into stage 1 at 28²
        ConvLayer { name: "t1", cin: 16, cout: 64, h: 56, w: 56, k: 3, stride: 2, pool: 1 },
    ];
    for i in 0..5 {
        layers.push(ConvLayer {
            name: S1[i], cin: 64, cout: 64, h: 28, w: 28, k: 3, stride: 1, pool: 1,
        });
    }
    layers.push(ConvLayer { name: "t2", cin: 64, cout: 128, h: 28, w: 28, k: 3, stride: 2, pool: 1 });
    for i in 0..5 {
        layers.push(ConvLayer {
            name: S2[i], cin: 128, cout: 128, h: 14, w: 14, k: 3, stride: 1, pool: 1,
        });
    }
    layers.push(ConvLayer { name: "t3", cin: 128, cout: 256, h: 14, w: 14, k: 3, stride: 2, pool: 1 });
    for i in 0..5 {
        layers.push(ConvLayer {
            name: S3[i], cin: 256, cout: 256, h: 7, w: 7, k: 3, stride: 1, pool: 1,
        });
    }
    layers
}

const S1: [&str; 5] = ["s1.c1", "s1.c2", "s1.c3", "s1.c4", "s1.c5"];
const S2: [&str; 5] = ["s2.c1", "s2.c2", "s2.c3", "s2.c4", "s2.c5"];
const S3: [&str; 5] = ["s3.c1", "s3.c2", "s3.c3", "s3.c4", "s3.c5"];

/// Total MACs across a layer table.
pub fn total_macs(layers: &[ConvLayer]) -> u64 {
    layers.iter().map(|l| l.macs()).sum()
}

/// Total weight bytes at a precision.
pub fn total_weight_bytes(layers: &[ConvLayer], prec: WeightPrec) -> usize {
    layers.iter().map(|l| l.weight_bytes(prec)).sum()
}

/// Maximum partial-result footprint (dense layer output).
pub fn max_partial_bytes(layers: &[ConvLayer]) -> usize {
    layers.iter().map(|l| l.dense_out_bytes()).max().unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cifar_table_matches_python_contract() {
        let layers = resnet20_cifar();
        assert_eq!(layers.len(), 19); // conv1 + 18 block convs
        let params: usize = layers.iter().map(|l| l.cin * l.cout * 9).sum();
        // + fc (10×64) ≈ python test band 250k..300k
        assert!((250_000..300_000).contains(&(params + 640)), "{params}");
        // CIFAR ResNet-20 ≈ 41 M MACs (dense-computed strided layers add a bit)
        let m = total_macs(&layers);
        assert!((40_000_000..60_000_000).contains(&(m as usize)), "{m}");
    }

    /// The §IV-A published footprints: >1.35e9 ops, 8.9 MB weights @16 bit,
    /// 1.5 MB max partial.
    #[test]
    fn surveillance_workload_footprints() {
        let layers = resnet20_224();
        assert_eq!(layers.len(), 19);
        let wb = total_weight_bytes(&layers, WeightPrec::W16) as f64 / 1e6;
        assert!((7.5..10.0).contains(&wb), "weight MB = {wb} (paper: 8.9)");
        let part = max_partial_bytes(&layers) as f64 / 1e6;
        assert!((1.4..1.7).contains(&part), "max partial MB = {part} (paper: 1.5)");
        let ops = 2 * total_macs(&layers);
        assert!(
            (0.9e9..1.6e9).contains(&(ops as f64)),
            "arith ops = {ops} (paper: >1.35e9)"
        );
    }

    #[test]
    fn w4_weights_quarter_footprint() {
        let layers = resnet20_224();
        let w16 = total_weight_bytes(&layers, WeightPrec::W16);
        let w4 = total_weight_bytes(&layers, WeightPrec::W4);
        assert_eq!(w16, 4 * w4);
    }

    #[test]
    fn passes_scale_with_precision() {
        let l = &resnet20_224()[2];
        assert_eq!(l.passes(WeightPrec::W16), 64 * 64);
        assert_eq!(l.passes(WeightPrec::W4), 64 * 16);
    }

    #[test]
    fn dims_consistent() {
        for l in resnet20_224().iter().chain(resnet20_cifar().iter()) {
            let (oh, ow) = l.out_dims();
            assert!(oh > 0 && ow > 0, "{}", l.name);
            assert!(l.h % (l.stride * l.pool) == 0, "{}", l.name);
        }
    }
}
