//! Workload definitions for the three §IV use cases: layer tables, op
//! counts, parameter generation, and the functional EEG pipeline.

pub mod eeg;
pub mod facedet;
pub mod params;
pub mod resnet;

/// One OpenRISC-equivalent operation count, the normalization unit of the
/// paper's `pJ/op` metric (footnote 4: "the number of OpenRISC instructions
/// that are necessary to execute a given task, using only instructions of
/// the original OpenRISC 1200 ISA").
pub type EqOps = u64;
