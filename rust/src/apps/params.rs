//! Deterministic parameter generation — the exact xorshift64 stream of
//! `python/compile/model.py::xorshift_i16` (pinned there by
//! `test_xorshift_contract_values`; the runtime_artifacts integration test
//! feeds these to the AOT graphs).

use crate::runtime::TensorI16;

/// xorshift64 stream mapped into [lo, hi], identical to the python side.
pub fn xorshift_i16(seed: u64, n: usize, lo: i64, hi: i64) -> Vec<i16> {
    let mut x = seed | 1;
    let span = (hi - lo + 1) as u64;
    (0..n)
        .map(|_| {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            ((x % span) as i64 + lo) as i16
        })
        .collect()
}

/// Mirror of `model.gen_params`: per-tensor seeds/ranges depend on position
/// and role. Because the python side keys ranges off parameter *names*
/// (bias / fc / conv weight), we reproduce the same classification from the
/// shapes: rank-1 tensors are biases, rank-2 are fc weights, rank-4 are conv
/// weights (this matches every registry artifact's parameter list).
pub fn gen_params(shapes: &[Vec<usize>], simd: usize, seed: u64) -> Vec<TensorI16> {
    let (lo_w, hi_w) = match simd {
        1 => (-256, 255),
        2 => (-128, 127),
        4 => (-8, 7),
        _ => panic!("bad simd {simd}"),
    };
    shapes
        .iter()
        .enumerate()
        .map(|(i, shape)| {
            let n: usize = shape.iter().product();
            let data = match shape.len() {
                1 => xorshift_i16(seed + 1000 + i as u64, n, -64, 64),
                2 => xorshift_i16(seed + 1000 + i as u64, n, -16, 16),
                _ => xorshift_i16(seed + 1000 + i as u64, n, lo_w, hi_w),
            };
            TensorI16::new(shape.clone(), data)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Pin the same first values as the python contract test.
    #[test]
    fn xorshift_contract_values() {
        let v = xorshift_i16(1, 4, -8, 7);
        let mut x: u64 = 1;
        let expect: Vec<i16> = (0..4)
            .map(|_| {
                x ^= x << 13;
                x ^= x >> 7;
                x ^= x << 17;
                (x % 16) as i16 - 8
            })
            .collect();
        assert_eq!(v, expect);
    }

    #[test]
    fn ranges_respected() {
        let v = xorshift_i16(7, 1000, -8, 7);
        assert!(v.iter().all(|&x| (-8..=7).contains(&x)));
        assert!(v.iter().any(|&x| x < 0) && v.iter().any(|&x| x > 0));
    }

    #[test]
    fn gen_params_shapes_and_classification() {
        let shapes = vec![vec![8, 2, 3, 3], vec![8], vec![4, 16]];
        let p = gen_params(&shapes, 4, 1);
        assert!(p[0].data.iter().all(|&x| (-8..=7).contains(&x)), "conv w4 range");
        assert!(p[1].data.iter().all(|&x| (-64..=64).contains(&x)), "bias range");
        assert!(p[2].data.iter().all(|&x| (-16..=16).contains(&x)), "fc range");
    }
}
