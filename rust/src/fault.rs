//! Deterministic fault injection and recovery for streamed endpoints.
//!
//! A deployed Fulmine endpoint fails in ways the fault-free simulator
//! never sees: the sensor glitches and a frame simply never arrives, a
//! soft error in TCDM or an engine corrupts a frame that must then
//! re-execute, the battery browns out and the whole chip resets through
//! the deep-sleep wake path, or the radio link at the encryption
//! boundary drops and the CRY tail of a frame retries. A [`FaultModel`]
//! turns per-class fault *rates* into a fully deterministic per-frame
//! fault table — the same xorshift64* discipline as
//! [`crate::traffic::Traffic`]: the draw for frame `f` depends only on
//! `(model, f)`, so the same spec replays bitwise on any host, any
//! shard split, any thread count.
//!
//! ## Integration: faults are per-frame variants
//!
//! No scheduler-core changes: a faulted frame compiles to a per-frame
//! template *variant* ([`crate::soc::sched::StreamScheduler`]'s PR 5
//! machinery) whose service times and prefolded energy rows carry the
//! recovery cost — re-execution scales both duration and active energy
//! (honest re-billing), recovery dead time (retry backoff, brown-out
//! wake) stretches the frame's root jobs *without* scaling their active
//! energy (the chip idles through it; only the makespan-proportional
//! leakage grows), and a skipped frame is a zero-duration, zero-energy
//! variant that flows through the window without scheduling work.
//! Fast-forward suspends around faulted frames and re-engages after
//! they retire, exactly as for any other variant; a run with
//! `faults: None` never touches this module and stays bitwise identical
//! to the pre-fault simulator (property-tested).
//!
//! Counters and the brown-out wake energy are computed here, in pure
//! closed form over the fault table ([`FaultPlan::build`]), and
//! attached to the finished [`SchedResult`] by [`apply_stats`] — the
//! scheduler's cycle proof and replay machinery never see them.
//!
//! ## Recovery policies
//!
//! * [`Recovery::Retry`] — re-execute the faulted work, up to `max`
//!   attempts; the wait before each retry starts at `backoff_s` and
//!   doubles per prior attempt, saturating at [`BACKOFF_CAP_FACTOR`]×
//!   (RFC 6347-style timers — overflow-free even at the [`MAX_RETRIES`]
//!   budget). Each retry may fail again (drawn from the same per-frame
//!   stream), and a frame that exhausts its retries is dropped *after*
//!   paying for every attempt.
//! * [`Recovery::Degrade`] — skip the frame, count it, keep streaming
//!   (the right answer when freshness beats completeness).
//! * [`Recovery::Reset`] — watchdog flush + restart: the frame
//!   re-executes once after a full-chip reset (deep-sleep wake dead
//!   time + wake energy via [`crate::soc::pm`]), and the in-flight
//!   window's state is counted lost.
//!
//! A brown-out is a reset whatever the policy asks for — retrying
//! cannot un-collapse a supply rail — though `degrade` declines the
//! re-execution and drops the frame. A sensor dropout is always a skip:
//! there is no data to retry.

use crate::energy::Category;
use crate::soc::pm;
use crate::soc::sched::{Engine, JobGraph, SchedResult};
use crate::traffic::{mix_seed, Xorshift64Star};
use anyhow::{anyhow, bail, Result};

/// Salt folded into the fault seed so the per-frame fault stream is
/// independent of every other consumer of [`mix_seed`] (traffic phase,
/// chip perturbations) even under equal user-facing seeds.
const FAULT_SALT: u64 = 0xFA01_7D0C_ED5E_ED11;

/// Hard cap on retry attempts — a watchdog bound, and it keeps the
/// per-frame draw count O(1).
pub const MAX_RETRIES: u32 = 64;

/// Saturation ceiling of the doubling backoff ladder: the wait before a
/// retry doubles per prior attempt (RFC 6347-style timers) but never
/// exceeds `64×` the initial backoff. The factor is computed in `f64`
/// from a capped shift, so a retry budget as large as [`MAX_RETRIES`]
/// can never overflow the `1 << k` arithmetic (`1u64 << 64` would).
pub const BACKOFF_CAP_FACTOR: f64 = 64.0;

/// Backoff multiplier before the `step`-th retry (0-based): `2^step`,
/// saturating at [`BACKOFF_CAP_FACTOR`].
pub fn backoff_factor(step: u32) -> f64 {
    if step >= 6 {
        BACKOFF_CAP_FACTOR
    } else {
        (1u64 << step) as f64
    }
}

/// Total dead time spent waiting across `execs` executions of a frame
/// (the first execution waits nothing; retry `k` waits
/// `backoff_s × backoff_factor(k-1)`). Saturating and overflow-free for
/// any `execs ≤ MAX_RETRIES + 1`.
pub fn backoff_dead_s(backoff_s: f64, execs: u32) -> f64 {
    (1..execs).map(|k| backoff_s * backoff_factor(k - 1)).sum()
}

/// Which fault struck a frame.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameFault {
    /// Sensor dropout: the frame's data never arrives.
    Drop,
    /// Transient soft error (TCDM/engine): the frame completed but its
    /// output is corrupt and the work must re-execute.
    Transient,
    /// Brown-out: full-chip reset through the deep-sleep wake path.
    Brownout,
    /// Link loss at the offload/encryption boundary: the CRY tail of
    /// the frame retries.
    Link,
}

impl FrameFault {
    pub fn name(self) -> &'static str {
        match self {
            FrameFault::Drop => "drop",
            FrameFault::Transient => "transient",
            FrameFault::Brownout => "brownout",
            FrameFault::Link => "link",
        }
    }
}

/// A seeded, per-frame-deterministic fault process over four fault
/// classes. Rates are per-frame probabilities; the per-frame draw
/// depends only on `(rates, seed, frame index)`, so fault tables are
/// invariant across runs, shard splits and thread counts.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultModel {
    /// P(sensor dropout) per frame.
    pub drop_rate: f64,
    /// P(transient soft error) per frame.
    pub transient_rate: f64,
    /// P(brown-out reset) per frame.
    pub brownout_rate: f64,
    /// P(link loss on the CRY tail) per frame.
    pub link_rate: f64,
    /// xorshift64* seed of the fault stream.
    pub seed: u64,
}

impl FaultModel {
    /// The fault-free model (`--faults none`): every rate zero. Running
    /// with this model is bitwise identical to running without one.
    pub fn none() -> FaultModel {
        FaultModel {
            drop_rate: 0.0,
            transient_rate: 0.0,
            brownout_rate: 0.0,
            link_rate: 0.0,
            seed: 1,
        }
    }

    /// Whether no fault class can ever fire.
    pub fn is_none(&self) -> bool {
        self.total_rate() == 0.0
    }

    /// Sum of the class rates — the per-frame fault probability.
    pub fn total_rate(&self) -> f64 {
        self.drop_rate + self.transient_rate + self.brownout_rate + self.link_rate
    }

    /// Validate the rates: each finite and in `[0, 1]`, sum < 1 (the
    /// no-fault bucket must keep positive measure — a fleet where every
    /// frame faults is a spec error, not a simulation).
    pub fn validate(&self) -> Result<()> {
        for (name, r) in [
            ("drop", self.drop_rate),
            ("transient", self.transient_rate),
            ("brownout", self.brownout_rate),
            ("link", self.link_rate),
        ] {
            if !(r.is_finite() && (0.0..=1.0).contains(&r)) {
                bail!("fault rate {name} must be in [0, 1], got {r}");
            }
        }
        if self.total_rate() >= 1.0 {
            bail!(
                "fault rates sum to {} — every frame would fault; keep the sum below 1",
                self.total_rate()
            );
        }
        Ok(())
    }

    /// Canonical class-key fragment: distinct models (rates bit-exact
    /// via `f64::to_bits`, distinct seeds) map to distinct keys.
    pub fn key(&self) -> String {
        format!(
            "flt:{:016x}:{:016x}:{:016x}:{:016x}:{:016x}",
            self.drop_rate.to_bits(),
            self.transient_rate.to_bits(),
            self.brownout_rate.to_bits(),
            self.link_rate.to_bits(),
            self.seed
        )
    }

    /// Human-readable description for reports.
    pub fn describe(&self) -> String {
        if self.is_none() {
            return "none".into();
        }
        format!(
            "drop {} / transient {} / brownout {} / link {} (seed {})",
            self.drop_rate, self.transient_rate, self.brownout_rate, self.link_rate, self.seed
        )
    }

    /// Parse a CLI spec: `none`, `drop:RATE[:SEED]`,
    /// `transient:RATE[:SEED]`, `brownout:RATE[:SEED]`,
    /// `link:RATE[:SEED]`, or `mixed:DROP:TRANSIENT:BROWNOUT:LINK[:SEED]`
    /// (seed defaults to 1).
    pub fn parse(s: &str) -> Result<FaultModel> {
        let parts: Vec<&str> = s.split(':').collect();
        let seed_at = |idx: usize| -> Result<u64> {
            match parts.get(idx) {
                Some(p) => p.parse().map_err(|_| anyhow!("bad fault seed {p:?}")),
                None => Ok(1),
            }
        };
        let mut m = FaultModel::none();
        match parts[0] {
            "none" => {
                if parts.len() != 1 {
                    bail!("fault model 'none' takes no parameters: {s}");
                }
            }
            kind @ ("drop" | "transient" | "brownout" | "link") => {
                if parts.len() < 2 || parts.len() > 3 {
                    bail!("expected {kind}:RATE[:SEED], got {s}");
                }
                let rate = parse_rate(parts[1])?;
                match kind {
                    "drop" => m.drop_rate = rate,
                    "transient" => m.transient_rate = rate,
                    "brownout" => m.brownout_rate = rate,
                    _ => m.link_rate = rate,
                }
                m.seed = seed_at(2)?;
            }
            "mixed" => {
                if parts.len() < 5 || parts.len() > 6 {
                    bail!("expected mixed:DROP:TRANSIENT:BROWNOUT:LINK[:SEED], got {s}");
                }
                m.drop_rate = parse_rate(parts[1])?;
                m.transient_rate = parse_rate(parts[2])?;
                m.brownout_rate = parse_rate(parts[3])?;
                m.link_rate = parse_rate(parts[4])?;
                m.seed = seed_at(5)?;
            }
            other => bail!(
                "unknown fault model '{other}' (expected none, drop, transient, brownout, link or mixed)"
            ),
        }
        m.validate()?;
        Ok(m)
    }

    /// The per-frame draw stream for global frame `frame` — depends only
    /// on `(seed, frame)`, never on how the stream is sharded.
    fn frame_rng(&self, frame: u64) -> Xorshift64Star {
        Xorshift64Star::new(mix_seed(self.seed ^ FAULT_SALT, frame))
    }

    /// One fault draw from an already-positioned per-frame stream:
    /// cumulative bucketing of a single uniform draw, so the four class
    /// rates partition the unit interval. `next_unit` is in `(0, 1]`,
    /// so a zero-rate class can never fire.
    fn draw(&self, rng: &mut Xorshift64Star) -> Option<FrameFault> {
        let u = rng.next_unit();
        let mut acc = self.drop_rate;
        if u <= acc {
            return Some(FrameFault::Drop);
        }
        acc += self.transient_rate;
        if u <= acc {
            return Some(FrameFault::Transient);
        }
        acc += self.brownout_rate;
        if u <= acc {
            return Some(FrameFault::Brownout);
        }
        acc += self.link_rate;
        if u <= acc {
            return Some(FrameFault::Link);
        }
        None
    }

    /// The fault (if any) striking global frame `frame`.
    pub fn fault_at(&self, frame: usize) -> Option<FrameFault> {
        if self.is_none() {
            return None;
        }
        self.draw(&mut self.frame_rng(frame as u64))
    }

    /// Sparse fault table for global frames `[start, start + frames)`,
    /// indexed *locally* (`0..frames`) — the form a shard consumes. The
    /// union of shard tables over a partition of the global range equals
    /// the unsharded table, re-indexed.
    pub fn table(&self, start: usize, frames: usize) -> Vec<(usize, FrameFault)> {
        if self.is_none() {
            return Vec::new();
        }
        (0..frames)
            .filter_map(|f| self.fault_at(start + f).map(|c| (f, c)))
            .collect()
    }
}

fn parse_rate(s: &str) -> Result<f64> {
    s.parse::<f64>().map_err(|_| anyhow!("bad fault rate '{s}' (per-frame probability)"))
}

/// How the endpoint answers a fault.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Recovery {
    /// Re-execute the faulted work, at most `max` attempts; the wait
    /// before each retry starts at `backoff_s` and doubles per prior
    /// attempt, saturating at [`BACKOFF_CAP_FACTOR`]×. Exhausting the
    /// budget drops the frame (after paying for every attempt).
    Retry { max: u32, backoff_s: f64 },
    /// Skip the faulted frame, count it, keep streaming.
    Degrade,
    /// Watchdog flush + restart: pay a full chip reset and re-execute
    /// the frame once.
    Reset,
}

impl Default for Recovery {
    /// The policy assumed when `--faults` is given without `--recovery`.
    fn default() -> Self {
        Recovery::Retry { max: 3, backoff_s: 0.0 }
    }
}

impl Recovery {
    pub fn validate(&self) -> Result<()> {
        if let Recovery::Retry { max, backoff_s } = *self {
            if max == 0 || max > MAX_RETRIES {
                bail!("retry budget must be in 1..={MAX_RETRIES}, got {max}");
            }
            if !(backoff_s.is_finite() && backoff_s >= 0.0) {
                bail!("retry backoff must be finite and >= 0 s, got {backoff_s}");
            }
        }
        Ok(())
    }

    /// Canonical class-key fragment (bit-exact backoff).
    pub fn key(&self) -> String {
        match *self {
            Recovery::Retry { max, backoff_s } => {
                format!("retry:{max}:{:016x}", backoff_s.to_bits())
            }
            Recovery::Degrade => "degrade".into(),
            Recovery::Reset => "reset".into(),
        }
    }

    pub fn describe(&self) -> String {
        match *self {
            Recovery::Retry { max, backoff_s } => format!("retry (max {max}, backoff {backoff_s} s)"),
            Recovery::Degrade => "degrade".into(),
            Recovery::Reset => "reset".into(),
        }
    }

    /// Parse a CLI spec: `retry[:MAX[:BACKOFF_S]]` (defaults 3, 0),
    /// `degrade`, or `reset`.
    pub fn parse(s: &str) -> Result<Recovery> {
        let parts: Vec<&str> = s.split(':').collect();
        let r = match parts[0] {
            "retry" => {
                if parts.len() > 3 {
                    bail!("expected retry[:MAX[:BACKOFF_S]], got {s}");
                }
                let max = match parts.get(1) {
                    Some(p) => p.parse().map_err(|_| anyhow!("bad retry budget {p:?}"))?,
                    None => 3,
                };
                let backoff_s = match parts.get(2) {
                    Some(p) => p
                        .parse()
                        .map_err(|_| anyhow!("bad retry backoff '{p}' (seconds)"))?,
                    None => 0.0,
                };
                Recovery::Retry { max, backoff_s }
            }
            "degrade" => {
                if parts.len() != 1 {
                    bail!("recovery 'degrade' takes no parameters: {s}");
                }
                Recovery::Degrade
            }
            "reset" => {
                if parts.len() != 1 {
                    bail!("recovery 'reset' takes no parameters: {s}");
                }
                Recovery::Reset
            }
            other => bail!("unknown recovery policy '{other}' (expected retry, degrade or reset)"),
        };
        r.validate()?;
        Ok(r)
    }
}

/// Reliability counters of one faulted stream, computed in closed form
/// over the fault table and attached to the finished [`SchedResult`]
/// by [`apply_stats`]. Counters are per-stream (per-chip in a fleet);
/// energies are in the stream's nominal time base and scale with a
/// member chip's drift factor exactly like every other energy.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct FaultStats {
    /// Frames struck by any fault class.
    pub faulted_frames: u64,
    /// Frames whose output was lost (sensor dropouts, degraded frames,
    /// exhausted retry budgets) — the numerator of unavailability.
    pub frames_dropped: u64,
    /// Retry executions performed beyond each frame's first attempt.
    pub fault_retries: u64,
    /// Full-chip resets (brown-outs plus watchdog resets).
    pub chip_resets: u64,
    /// Frames whose in-flight state a chip reset flushed (bounded by
    /// the streaming window per event).
    pub state_loss_frames: u64,
    /// Energy overhead of recovery (mJ): re-executed active energy plus
    /// the brown-out wake transitions.
    pub recovery_energy_mj: f64,
    /// Portion of `recovery_energy_mj` that is wake-transition energy —
    /// charged into the ledger's `Idle` category post-run (re-executed
    /// active energy reaches the ledger through the variants).
    pub wake_mj: f64,
}

impl FaultStats {
    /// Fraction of frames whose output survived.
    pub fn availability(&self, frames: usize) -> f64 {
        if frames == 0 {
            return 1.0;
        }
        (frames as f64 - self.frames_dropped as f64) / frames as f64
    }
}

/// A faulted stream's compiled recovery plan: one variant [`JobGraph`]
/// per faulted frame (local indices, ascending — the order
/// [`crate::soc::sched::StreamScheduler::run_with_variants`] wants) and
/// the closed-form reliability counters.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    pub variants: Vec<(usize, JobGraph)>,
    pub stats: FaultStats,
}

impl FaultPlan {
    /// Build the plan for global frames `[start, start + frames)` of a
    /// stream of `frame`-template frames admitted through a
    /// `window`-deep in-flight window. Pure: depends only on the
    /// arguments, so shards and threads agree by construction.
    pub fn build(
        model: &FaultModel,
        recovery: Recovery,
        frame: &JobGraph,
        start: usize,
        frames: usize,
        window: usize,
    ) -> FaultPlan {
        let mut plan = FaultPlan { variants: Vec::new(), stats: FaultStats::default() };
        if model.is_none() {
            return plan;
        }
        let base_mj = frame.active_mj();
        for f in 0..frames {
            let mut rng = model.frame_rng((start + f) as u64);
            let Some(fault) = model.draw(&mut rng) else { continue };
            plan.stats.faulted_frames += 1;
            let in_flight = window.min(frames - f);
            let variant = match (fault, recovery) {
                // No data arrived; nothing to retry, reset or degrade to.
                (FrameFault::Drop, _) => {
                    plan.stats.frames_dropped += 1;
                    skip_variant(frame)
                }
                (FrameFault::Transient, Recovery::Retry { max, backoff_s }) => {
                    let (execs, ok) = retry_attempts(&mut rng, model.transient_rate, max);
                    plan.stats.fault_retries += (execs - 1) as u64;
                    if !ok {
                        plan.stats.frames_dropped += 1;
                    }
                    rework_variant(frame, execs as f64, backoff_dead_s(backoff_s, execs), false)
                }
                (FrameFault::Link, Recovery::Retry { max, backoff_s }) => {
                    let (execs, ok) = retry_attempts(&mut rng, model.link_rate, max);
                    plan.stats.fault_retries += (execs - 1) as u64;
                    if !ok {
                        plan.stats.frames_dropped += 1;
                    }
                    cry_rework_variant(frame, execs as f64, backoff_dead_s(backoff_s, execs))
                }
                (FrameFault::Transient | FrameFault::Link, Recovery::Degrade) => {
                    plan.stats.frames_dropped += 1;
                    skip_variant(frame)
                }
                // A watchdog reset answers transient/link faults under
                // the reset policy; a brown-out *is* a reset whatever
                // the policy (a supply collapse cannot be retried),
                // except that degrade declines the re-execution.
                (FrameFault::Brownout, Recovery::Degrade) => {
                    plan.stats.frames_dropped += 1;
                    plan.stats.chip_resets += 1;
                    plan.stats.state_loss_frames += in_flight as u64;
                    plan.stats.wake_mj += pm::brownout_wake_mj();
                    dead_variant(frame, pm::brownout_dead_s())
                }
                (FrameFault::Transient | FrameFault::Link, Recovery::Reset)
                | (FrameFault::Brownout, _) => {
                    plan.stats.chip_resets += 1;
                    plan.stats.state_loss_frames += in_flight as u64;
                    plan.stats.wake_mj += pm::brownout_wake_mj();
                    rework_variant(frame, 2.0, pm::brownout_dead_s(), false)
                }
            };
            // Recovery overhead = the variant's extra active energy
            // (never credit skipped frames' savings as overhead).
            plan.stats.recovery_energy_mj += (variant.active_mj() - base_mj).max(0.0);
            plan.variants.push((f, variant));
        }
        plan.stats.recovery_energy_mj += plan.stats.wake_mj;
        plan
    }

    /// The variants as the borrow slice the scheduler entry points take.
    pub fn variant_refs(&self) -> Vec<(usize, &JobGraph)> {
        self.variants.iter().map(|(f, g)| (*f, g)).collect()
    }
}

/// Attach a plan's counters to a finished result, with the wake energy
/// charged into the ledger's `Idle` category and every energy scaled by
/// the chip's time-base factor (`1.0` for a nominal chip; a drifted
/// member's watchdog and wake intervals stretch with its crystal, the
/// same convention as the FLL relock). Called identically on live runs
/// and closed-form derived members, so fleet parity stays bitwise.
pub fn apply_stats(r: &mut SchedResult, stats: &FaultStats, scale: f64) {
    r.frames_dropped += stats.frames_dropped;
    r.fault_retries += stats.fault_retries;
    r.chip_resets += stats.chip_resets;
    r.state_loss_frames += stats.state_loss_frames;
    r.recovery_energy_mj += stats.recovery_energy_mj * scale;
    if stats.wake_mj != 0.0 {
        r.ledger.charge_mj(Category::Idle, stats.wake_mj * scale);
    }
}

/// Retry loop over an already-positioned per-frame draw stream: the
/// first execution has failed; each retry fails again with the class's
/// rate. Returns (total executions, whether the frame finally
/// succeeded). Deterministic: the draws continue the same per-frame
/// stream the fault came from.
fn retry_attempts(rng: &mut Xorshift64Star, rate: f64, max: u32) -> (u32, bool) {
    let mut execs = 1u32;
    for _ in 0..max.min(MAX_RETRIES) {
        execs += 1;
        if rng.next_unit() > rate {
            return (execs, true);
        }
    }
    (execs, false)
}

/// Whether a job runs on a HWCRYPT datapath — the CRY tail a link-loss
/// retry re-executes.
fn is_cry(engines: &[Engine]) -> bool {
    engines.iter().any(|e| matches!(e, Engine::HwcryptAes | Engine::HwcryptKec))
}

/// The skipped frame: zero service time, zero active energy. It flows
/// through the window (admission, retirement) without scheduling work.
fn skip_variant(frame: &JobGraph) -> JobGraph {
    let mut v = frame.clone();
    for job in &mut v.jobs {
        job.duration_s = 0.0;
        for c in &mut job.charges {
            c.2 = 0.0;
        }
    }
    v
}

/// A dropped frame that still pays `dead_s` of recovery dead time (the
/// brown-out wake under degrade): roots stretch by the dead time with
/// their active energy zeroed like every other job's.
fn dead_variant(frame: &JobGraph, dead_s: f64) -> JobGraph {
    let mut v = skip_variant(frame);
    for job in &mut v.jobs {
        if job.deps.is_empty() {
            job.duration_s = dead_s;
        }
    }
    v
}

/// The re-executed frame: every job's service time and active energy
/// scale by `factor` (`cry_only` restricts the scaling to HWCRYPT
/// jobs), and `dead_s` of recovery dead time stretches the root jobs
/// with their charge multiplicities compensated so the dead interval
/// bills *no* active energy — the chip idles through a backoff or a
/// wake, and only the makespan-proportional leakage grows.
fn stretch_variant(frame: &JobGraph, factor: f64, dead_s: f64, cry_only: bool) -> JobGraph {
    let mut v = frame.clone();
    for job in &mut v.jobs {
        if !cry_only || is_cry(&job.engines) {
            job.duration_s *= factor;
        }
        if dead_s > 0.0 && job.deps.is_empty() {
            let work = job.duration_s;
            job.duration_s = work + dead_s;
            let ratio = if work + dead_s > 0.0 { work / (work + dead_s) } else { 0.0 };
            for c in &mut job.charges {
                c.2 *= ratio;
            }
        }
    }
    v
}

fn rework_variant(frame: &JobGraph, factor: f64, dead_s: f64, cry_only: bool) -> JobGraph {
    stretch_variant(frame, factor, dead_s, cry_only)
}

fn cry_rework_variant(frame: &JobGraph, factor: f64, dead_s: f64) -> JobGraph {
    stretch_variant(frame, factor, dead_s, true)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::Category;
    use crate::soc::opmodes::{OperatingMode, OperatingPoint};
    use crate::soc::power::Component;
    use crate::soc::sched::Job;

    fn graph() -> JobGraph {
        let mut g = JobGraph::new();
        let a = g.push(Job {
            label: "sw",
            engines: vec![Engine::Core(0)],
            op: OperatingPoint::new(OperatingMode::Sw, 0.8),
            duration_s: 0.25,
            deps: vec![],
            charges: vec![(Category::OtherSw, Component::Core, 1.0)],
        });
        g.push(Job {
            label: "cry",
            engines: vec![Engine::HwcryptAes],
            op: OperatingPoint::new(OperatingMode::Sw, 0.8),
            duration_s: 0.125,
            deps: vec![a],
            charges: vec![(Category::Crypto, Component::HwcryptAes, 1.0)],
        });
        g
    }

    fn model(rate: f64) -> FaultModel {
        FaultModel { transient_rate: rate, seed: 7, ..FaultModel::none() }
    }

    #[test]
    fn parse_round_trips_and_rejects() {
        assert!(FaultModel::parse("none").unwrap().is_none());
        let m = FaultModel::parse("drop:0.01:9").unwrap();
        assert_eq!(m.drop_rate, 0.01);
        assert_eq!(m.seed, 9);
        let m = FaultModel::parse("transient:0.05").unwrap();
        assert_eq!(m.transient_rate, 0.05);
        assert_eq!(m.seed, 1);
        let m = FaultModel::parse("mixed:0.01:0.02:0.003:0.04:5").unwrap();
        assert_eq!(
            (m.drop_rate, m.transient_rate, m.brownout_rate, m.link_rate, m.seed),
            (0.01, 0.02, 0.003, 0.04, 5)
        );
        for bad in [
            "none:1",
            "drop",
            "drop:x",
            "drop:1.5",
            "drop:-0.1",
            "mixed:0.5:0.5:0.1:0.1",
            "mixed:0.1:0.1",
            "transient:0.1:badseed",
            "gamma:0.1",
        ] {
            assert!(FaultModel::parse(bad).is_err(), "{bad} should be rejected");
        }
    }

    #[test]
    fn recovery_parse_round_trips_and_rejects() {
        assert_eq!(Recovery::parse("retry").unwrap(), Recovery::Retry { max: 3, backoff_s: 0.0 });
        assert_eq!(
            Recovery::parse("retry:5:0.01").unwrap(),
            Recovery::Retry { max: 5, backoff_s: 0.01 }
        );
        assert_eq!(Recovery::parse("degrade").unwrap(), Recovery::Degrade);
        assert_eq!(Recovery::parse("reset").unwrap(), Recovery::Reset);
        for bad in ["retry:0", "retry:999", "retry:2:-1", "retry:2:x", "retry:x", "degrade:1", "reset:x", "panic"] {
            assert!(Recovery::parse(bad).is_err(), "{bad} should be rejected");
        }
    }

    #[test]
    fn fault_table_is_deterministic_and_seed_sensitive() {
        let m = model(0.1);
        assert_eq!(m.table(0, 512), m.table(0, 512), "same model must replay");
        let other = FaultModel { seed: 8, ..m.clone() };
        assert_ne!(m.table(0, 512), other.table(0, 512), "seeds must matter");
        let t = m.table(0, 4096);
        assert!(!t.is_empty(), "a 10% rate over 4096 frames must fire");
        // roughly the expected count — the draw is one uniform per frame
        assert!(t.len() > 256 && t.len() < 640, "{} faults at 10%", t.len());
    }

    #[test]
    fn shard_tables_partition_the_global_table() {
        let m = FaultModel {
            drop_rate: 0.02,
            transient_rate: 0.03,
            brownout_rate: 0.01,
            link_rate: 0.02,
            seed: 42,
        };
        let whole = m.table(0, 300);
        for splits in [2usize, 3, 4] {
            let per = 300 / splits;
            let mut joined = Vec::new();
            for s in 0..splits {
                for (f, c) in m.table(s * per, per) {
                    joined.push((s * per + f, c));
                }
            }
            assert_eq!(whole, joined, "{splits}-way shard split must agree");
        }
    }

    #[test]
    fn zero_rates_never_fire_and_keys_are_injective() {
        assert!(FaultModel::none().table(0, 10_000).is_empty());
        let keys: std::collections::BTreeSet<String> = [
            FaultModel::none(),
            model(0.1),
            model(0.2),
            FaultModel { seed: 9, ..model(0.1) },
            FaultModel { link_rate: 0.1, ..FaultModel::none() },
        ]
        .iter()
        .map(|m| m.key())
        .collect();
        assert_eq!(keys.len(), 5);
        assert_ne!(Recovery::parse("retry:3:0").unwrap().key(), Recovery::Degrade.key());
    }

    #[test]
    fn plan_counts_and_energies_are_consistent() {
        let g = graph();
        let m = model(0.1);
        let plan = FaultPlan::build(&m, Recovery::default(), &g, 0, 1024, 8);
        assert_eq!(plan.stats.faulted_frames as usize, plan.variants.len());
        assert_eq!(plan.stats.faulted_frames as usize, m.table(0, 1024).len());
        assert!(plan.stats.fault_retries >= plan.stats.faulted_frames, "each fault retries");
        assert!(plan.stats.recovery_energy_mj > 0.0);
        assert_eq!(plan.stats.chip_resets, 0, "transients under retry never reset");
        // variants arrive sorted by frame, the order the scheduler wants
        assert!(plan.variants.windows(2).all(|w| w[0].0 < w[1].0));
        // a retried frame bills at least twice the base active energy
        let base = g.active_mj();
        let (_, v) = &plan.variants[0];
        assert!(v.active_mj() >= 2.0 * base - 1e-12, "{} vs {base}", v.active_mj());
    }

    #[test]
    fn degrade_skips_and_reset_bills_the_wake() {
        let g = graph();
        let m = model(0.1);
        let degrade = FaultPlan::build(&m, Recovery::Degrade, &g, 0, 512, 8);
        assert_eq!(degrade.stats.frames_dropped, degrade.stats.faulted_frames);
        assert_eq!(degrade.stats.recovery_energy_mj, 0.0, "skips cost no recovery energy");
        for (_, v) in &degrade.variants {
            assert_eq!(v.active_mj(), 0.0);
            assert!(v.jobs.iter().all(|j| j.duration_s == 0.0));
        }
        let reset = FaultPlan::build(&m, Recovery::Reset, &g, 0, 512, 8);
        assert_eq!(reset.stats.chip_resets, reset.stats.faulted_frames);
        assert!(reset.stats.wake_mj > 0.0);
        assert!(reset.stats.state_loss_frames >= reset.stats.chip_resets);
        // dead time stretches the roots but bills no extra active energy
        let base = g.active_mj();
        for (_, v) in &reset.variants {
            assert!((v.active_mj() - 2.0 * base).abs() < 1e-9, "{} vs {}", v.active_mj(), 2.0 * base);
            assert!(v.jobs[0].duration_s > 2.0 * g.jobs[0].duration_s);
        }
    }

    #[test]
    fn link_faults_rework_only_the_cry_tail() {
        let g = graph();
        let m = FaultModel { link_rate: 0.1, seed: 3, ..FaultModel::none() };
        let plan = FaultPlan::build(&m, Recovery::default(), &g, 0, 512, 8);
        assert!(!plan.variants.is_empty());
        for (_, v) in &plan.variants {
            assert_eq!(v.jobs[0].duration_s, g.jobs[0].duration_s, "SW phase untouched");
            assert!(v.jobs[1].duration_s >= 2.0 * g.jobs[1].duration_s, "CRY tail retried");
        }
    }

    #[test]
    fn brownout_is_a_reset_under_every_policy() {
        let g = graph();
        let m = FaultModel { brownout_rate: 0.05, seed: 11, ..FaultModel::none() };
        for rec in [Recovery::default(), Recovery::Reset, Recovery::Degrade] {
            let plan = FaultPlan::build(&m, rec, &g, 0, 512, 8);
            assert_eq!(plan.stats.chip_resets, plan.stats.faulted_frames, "{rec:?}");
            assert!(plan.stats.wake_mj > 0.0, "{rec:?}");
        }
    }

    #[test]
    fn apply_stats_attaches_counters_and_wake_energy() {
        let g = graph();
        let mut r = crate::soc::sched::Scheduler::run(&g);
        let before = r.ledger.total_mj();
        let stats = FaultStats {
            faulted_frames: 3,
            frames_dropped: 1,
            fault_retries: 2,
            chip_resets: 1,
            state_loss_frames: 4,
            recovery_energy_mj: 0.5,
            wake_mj: 0.125,
        };
        apply_stats(&mut r, &stats, 1.0);
        assert_eq!(r.frames_dropped, 1);
        assert_eq!(r.fault_retries, 2);
        assert_eq!(r.chip_resets, 1);
        assert_eq!(r.state_loss_frames, 4);
        assert_eq!(r.recovery_energy_mj, 0.5);
        assert!((r.ledger.total_mj() - before - 0.125).abs() < 1e-12);
        assert!((stats.availability(4) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn backoff_doubles_then_saturates_without_overflow() {
        // doubling: 1, 2, 4, 8, 16, 32 then pinned at the cap
        for (step, want) in [(0, 1.0), (1, 2.0), (5, 32.0), (6, 64.0), (7, 64.0), (63, 64.0)] {
            assert_eq!(backoff_factor(step), want, "step {step}");
        }
        assert_eq!(backoff_factor(u32::MAX), BACKOFF_CAP_FACTOR);
        // execs = 10 → nine waits: 1+2+4+8+16+32+64+64+64 = 255
        let b = 0.05;
        assert!((backoff_dead_s(b, 10) - 255.0 * b).abs() < 1e-12);
        // one past the retry budget: finite, monotone, no shift overflow
        let budget = backoff_dead_s(b, MAX_RETRIES + 1);
        assert!(budget.is_finite());
        assert!(budget > backoff_dead_s(b, MAX_RETRIES));
        // zero or one execution waits for nothing
        assert_eq!(backoff_dead_s(b, 0), 0.0);
        assert_eq!(backoff_dead_s(b, 1), 0.0);
    }

    #[test]
    fn retry_exhaustion_is_counted_as_a_drop() {
        let g = graph();
        // near-certain transients against a one-retry budget: most faulted
        // frames exhaust and must land in the availability accounting
        let m = model(0.95);
        let plan = FaultPlan::build(&m, Recovery::Retry { max: 1, backoff_s: 0.01 }, &g, 0, 512, 8);
        assert!(plan.stats.faulted_frames > 0);
        assert!(plan.stats.frames_dropped > 0, "exhausted retries must count as drops");
        assert!(plan.stats.frames_dropped <= plan.stats.faulted_frames);
        assert!(plan.stats.availability(512) < 1.0);
        let kept = 512 - plan.stats.frames_dropped;
        assert!((plan.stats.availability(512) - kept as f64 / 512.0).abs() < 1e-12);
    }
}
