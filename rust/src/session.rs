//! Secure-link sessions over a deterministic lossy channel.
//!
//! The paper encrypts everything that crosses the analytics boundary,
//! but every §IV workload models only the steady record phase — as if
//! the radio never dropped a datagram. A deployed endpoint speaks a
//! DTLS-style protocol: a cookie exchange and ECC-heavy handshake
//! flights on the SW cores (the reconfigurable-DTLS-engine op
//! breakdown: the handshake is public-key-bound, the record phase is
//! AES-bound with opposite engine affinity), then AEAD record traffic
//! on HWCRYPT. This module models that session layer over a *lossy*
//! channel with RFC 6347-style retransmission timers — the wait doubles
//! per retransmission and saturates via
//! [`crate::fault::backoff_factor`] — and a [`SessionRecovery`] policy
//! for what happens when the link goes down mid-stream:
//!
//! * [`SessionRecovery::FullHandshake`] — renegotiate from the cookie
//!   exchange up (4 flights, ECC both ways).
//! * [`SessionRecovery::Resume`] — abbreviated resumption handshake
//!   (one flight, no ECC): the session ticket survives the outage.
//! * [`SessionRecovery::Degrade`] — drop records while the link is
//!   down instead of stalling the pipeline; freshness beats
//!   completeness for near-sensor analytics.
//!
//! ## Determinism
//!
//! Loss is drawn per flight from the salted xorshift64* discipline of
//! [`crate::fault`]: frame `f`'s record deliveries come from a stream
//! seeded by `(seed ^ SESSION_SALT, f)` and its handshake flights from
//! `(seed ^ HS_SALT, f)`, so the retransmission/resumption schedule
//! depends only on `(model, f)` — bitwise identical across runs, shard
//! splits and thread counts, with O(1) lookback (a shard starting at
//! `s` decides "was the link down?" from frame `s-1`'s draw alone).
//!
//! ## Integration: handshakes are per-frame variants
//!
//! The `secure_link` template carries two zero-duration placeholder
//! jobs ([`HS_COOKIE_LABEL`], [`HS_FLIGHT_LABEL`]) on the SW cores; a
//! handshake frame is a template *variant* (PR 5/PR 9 machinery in
//! [`crate::soc::sched::StreamScheduler`]) whose placeholders inflate
//! to the flight compute, whose crypto-charged record jobs scale by
//! the retransmission count (honest re-billing, the
//! [`crate::fault`] link-loss convention), and whose root jobs stretch
//! by the backoff dead time without billing active energy. Steady
//! delivered frames stay the unmodified template, so fast-forward
//! suspends exactly around handshake/retransmission frames and
//! re-engages on the steady record phase.
//!
//! ## Pluggable crypto backends
//!
//! The record-phase cost model sits behind [`CryptoBackend`]
//! (CryptoSRAM's motivation): the HWCRYPT engines, software AES/KECCAK
//! via [`crate::kernels_sw::crypto_cost`], or an in-SRAM compute model.
//! [`crate::coordinator::GraphBuilder`] routes every `xts`/`sponge_ae`
//! phase through the selected backend, so one ablation sweeps backends
//! across `secure_link` *and* the existing §IV workloads.

use crate::coordinator::ExecConfig;
use crate::energy::Category;
use crate::fault::backoff_factor;
use crate::hwcrypt;
use crate::kernels_sw::crypto_cost;
use crate::soc::opmodes::{OperatingMode, OperatingPoint};
use crate::soc::power::Component;
use crate::soc::sched::{Engine, JobGraph, SchedResult};
use crate::traffic::{mix_seed, Xorshift64Star};
use anyhow::{anyhow, bail, Result};

/// Salt folded into the session seed for the per-frame *record* loss
/// stream — independent of traffic phase, fault draws and the handshake
/// stream even under equal user-facing seeds.
const SESSION_SALT: u64 = 0x5E55_10D0_CADE_0D1E;

/// Salt of the per-frame *handshake flight* loss stream.
const HS_SALT: u64 = 0x4A5D_54A8_F119_075E;

/// Maximum retransmissions of one flight or record before the sender
/// gives up (RFC 6347 suggests bounding the timer ladder; 7 retries
/// with doubling backoff spans the usual 1 s → 64 s window scaled to
/// the sensor cadence).
pub const MAX_RETX: u32 = 7;

/// Initial retransmission timer (seconds). Doubles per retransmission,
/// saturating at [`crate::fault::BACKOFF_CAP_FACTOR`]× — the same
/// capped ladder the fault layer's retry policy uses.
pub const RETX_INIT_S: f64 = 0.05;

/// SW cycles of one cookie-exchange flight (HelloVerify round: parse,
/// stateless cookie MAC, re-serialize — cheap by design).
pub const COOKIE_CYCLES: f64 = 40_000.0;

/// SW cycles of one ECC handshake flight (P-256 scalar multiplications
/// dominate — the DTLS-engine breakdown puts the asymmetric flights
/// orders of magnitude above the record phase).
pub const ECC_FLIGHT_CYCLES: f64 = 2_600_000.0;

/// SW cycles of the abbreviated resumption flight (PSK-style: key
/// derivation and finished MACs, no public-key work).
pub const RESUME_FLIGHT_CYCLES: f64 = 120_000.0;

/// Payload bytes of one AEAD record (one sensor readout batch).
pub const RECORD_BYTES: usize = 2048;

/// Template label of the cookie-exchange placeholder job.
pub const HS_COOKIE_LABEL: &str = "hs-cookie";

/// Template label of the handshake-flight placeholder job.
pub const HS_FLIGHT_LABEL: &str = "hs-flight";

/// A seeded, per-frame-deterministic lossy channel. `loss_rate` is the
/// per-transmission loss probability; every flight and record draws
/// its delivery attempts from a per-frame stream, so the schedule is
/// invariant across runs, shard splits and thread counts.
#[derive(Debug, Clone, PartialEq)]
pub struct SessionModel {
    /// P(loss) per transmission attempt.
    pub loss_rate: f64,
    /// xorshift64* seed of the channel streams.
    pub seed: u64,
}

/// One transmission's outcome: how many sends it took, whether it ever
/// arrived, and the retransmission-timer dead time paid waiting.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Delivery {
    /// Transmission attempts performed (1 = delivered first try).
    pub execs: u32,
    /// Whether any attempt arrived before the budget ran out.
    pub delivered: bool,
    /// Total timer dead time across the attempts (seconds).
    pub dead_s: f64,
}

/// Run one transmission against the channel: attempt, wait the doubling
/// backoff, retransmit — up to [`MAX_RETX`] retransmissions. `rng` is
/// an already-positioned per-frame stream; `next_unit` is in `(0, 1]`,
/// so a zero loss rate delivers every attempt first try with no draws
/// wasted.
pub fn deliver(rng: &mut Xorshift64Star, loss_rate: f64) -> Delivery {
    let mut dead_s = 0.0;
    for attempt in 0..=MAX_RETX {
        if rng.next_unit() > loss_rate {
            return Delivery { execs: attempt + 1, delivered: true, dead_s };
        }
        if attempt < MAX_RETX {
            dead_s += RETX_INIT_S * backoff_factor(attempt);
        }
    }
    Delivery { execs: MAX_RETX + 1, delivered: false, dead_s }
}

impl SessionModel {
    /// The lossless channel (`--loss 0`): every transmission delivers
    /// first try. The stream still performs its frame-0 handshake.
    pub fn lossless() -> SessionModel {
        SessionModel { loss_rate: 0.0, seed: 1 }
    }

    /// Validate: finite, in `[0, 1)` (a channel that loses *every*
    /// transmission never completes a handshake — a spec error).
    pub fn validate(&self) -> Result<()> {
        if !(self.loss_rate.is_finite() && (0.0..1.0).contains(&self.loss_rate)) {
            bail!("channel loss rate must be in [0, 1), got {}", self.loss_rate);
        }
        Ok(())
    }

    /// Canonical class-key fragment (bit-exact rate, seed).
    pub fn key(&self) -> String {
        format!("ses:{:016x}:{:016x}", self.loss_rate.to_bits(), self.seed)
    }

    /// Human-readable description for reports.
    pub fn describe(&self) -> String {
        format!("loss {} (seed {})", self.loss_rate, self.seed)
    }

    /// Parse a CLI spec: `RATE[:SEED]` (seed defaults to 1).
    pub fn parse(s: &str) -> Result<SessionModel> {
        let parts: Vec<&str> = s.split(':').collect();
        if parts.is_empty() || parts.len() > 2 {
            bail!("expected RATE[:SEED], got {s:?}");
        }
        let loss_rate = parts[0]
            .parse::<f64>()
            .map_err(|_| anyhow!("bad channel loss rate '{}' (per-transmission probability)", parts[0]))?;
        let seed = match parts.get(1) {
            Some(p) => p.parse().map_err(|_| anyhow!("bad channel seed {p:?}"))?,
            None => 1,
        };
        let m = SessionModel { loss_rate, seed };
        m.validate()?;
        Ok(m)
    }

    /// The record-delivery draw stream for global frame `frame`.
    fn record_rng(&self, frame: u64) -> Xorshift64Star {
        Xorshift64Star::new(mix_seed(self.seed ^ SESSION_SALT, frame))
    }

    /// The handshake-flight draw stream for global frame `frame`.
    fn hs_rng(&self, frame: u64) -> Xorshift64Star {
        Xorshift64Star::new(mix_seed(self.seed ^ HS_SALT, frame))
    }

    /// Frame `frame`'s record transmission outcome — depends only on
    /// `(model, frame)`, never on how the stream is sharded.
    pub fn record_delivery(&self, frame: usize) -> Delivery {
        deliver(&mut self.record_rng(frame as u64), self.loss_rate)
    }

    /// Whether the link is down *entering* global frame `frame`: the
    /// previous frame's record exhausted its retransmission budget.
    /// O(1) — a shard starting anywhere answers this from one draw.
    pub fn link_down_at(&self, frame: usize) -> bool {
        frame > 0 && !self.record_delivery(frame - 1).delivered
    }
}

/// How a stream re-establishes its session after a link outage.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SessionRecovery {
    /// Renegotiate from scratch: cookie exchange + both ECC flights.
    FullHandshake,
    /// Abbreviated resumption handshake (session ticket): one cheap
    /// flight, no public-key work.
    Resume,
    /// Graceful degradation: drop records while the link is down and
    /// keep the pipeline streaming; re-enter on the next delivery.
    Degrade,
}

impl Default for SessionRecovery {
    /// The policy assumed when `--loss` is given without
    /// `--session-recovery` — resumption is the DTLS-native answer.
    fn default() -> Self {
        SessionRecovery::Resume
    }
}

impl SessionRecovery {
    /// Canonical class-key fragment.
    pub fn key(self) -> &'static str {
        match self {
            SessionRecovery::FullHandshake => "full",
            SessionRecovery::Resume => "resume",
            SessionRecovery::Degrade => "degrade",
        }
    }

    pub fn describe(self) -> &'static str {
        match self {
            SessionRecovery::FullHandshake => "full handshake",
            SessionRecovery::Resume => "resumption",
            SessionRecovery::Degrade => "degrade (drop while down)",
        }
    }

    /// Parse a CLI spec: `full`, `resume` or `degrade`.
    pub fn parse(s: &str) -> Result<SessionRecovery> {
        match s {
            "full" => Ok(SessionRecovery::FullHandshake),
            "resume" => Ok(SessionRecovery::Resume),
            "degrade" => Ok(SessionRecovery::Degrade),
            other => bail!("unknown session recovery '{other}' (expected full, resume or degrade)"),
        }
    }

    pub fn all() -> [SessionRecovery; 3] {
        [SessionRecovery::FullHandshake, SessionRecovery::Resume, SessionRecovery::Degrade]
    }
}

/// Session counters of one stream, computed in closed form over the
/// channel draws ([`SessionPlan::build`]) and attached to the finished
/// [`SchedResult`] by [`apply_stats`]. Counters are per-stream
/// (per-chip in a fleet); energies are in the stream's nominal time
/// base and scale with a member chip's drift factor.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SessionStats {
    /// Full handshakes performed (the frame-0 negotiation plus every
    /// outage answered under [`SessionRecovery::FullHandshake`]).
    pub full_handshakes: u64,
    /// Abbreviated resumption handshakes performed.
    pub resumptions: u64,
    /// Retransmissions: flight and record sends beyond each first
    /// attempt.
    pub retransmissions: u64,
    /// Records that never reached the collector (retransmission budget
    /// exhausted, handshake failures, degraded outage frames) — the
    /// numerator of unavailability and the goodput deficit.
    pub records_dropped: u64,
    /// Active energy of the handshake side (cookie + flight jobs), mJ.
    pub handshake_mj: f64,
    /// Active energy of the record side (everything else), mJ.
    pub record_mj: f64,
    /// Extra active energy versus the loss-free stream (re-sent flights
    /// and records), mJ — the session's recovery overhead.
    pub overhead_mj: f64,
    /// Total retransmission-timer dead time paid (seconds).
    pub backoff_dead_s: f64,
}

impl SessionStats {
    /// Fraction of records that reached the collector.
    pub fn availability(&self, frames: usize) -> f64 {
        if frames == 0 {
            return 1.0;
        }
        (frames as f64 - self.records_dropped as f64) / frames as f64
    }

    /// Delivered records per second of stream time — the goodput the
    /// collector observes (fps × availability).
    pub fn goodput_fps(&self, frames: usize, time_s: f64) -> f64 {
        if time_s <= 0.0 {
            return 0.0;
        }
        (frames as f64 - self.records_dropped as f64) / time_s
    }
}

/// Attach a plan's counters to a finished result. The mapping reuses
/// the fault-layer columns — dropped records are dropped frames
/// (availability), retransmissions are retries, and the re-sent energy
/// is recovery energy — with every energy scaled by the chip's
/// time-base factor. The handshake/record split stays in
/// [`SessionStats`] for the session sections of the reports.
pub fn apply_stats(r: &mut SchedResult, stats: &SessionStats, scale: f64) {
    r.frames_dropped += stats.records_dropped;
    r.fault_retries += stats.retransmissions;
    r.recovery_energy_mj += stats.overhead_mj * scale;
}

/// Whether `frame` is a `secure_link` template: carries both handshake
/// placeholder jobs a [`SessionPlan`] inflates.
pub fn has_session_jobs(frame: &JobGraph) -> bool {
    frame.jobs.iter().any(|j| j.label == HS_COOKIE_LABEL)
        && frame.jobs.iter().any(|j| j.label == HS_FLIGHT_LABEL)
}

/// What a frame is, given the channel and the recovery policy. Pure in
/// `(model, recovery, global frame)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum FrameKind {
    /// (Re)negotiate the session before sending the record.
    Handshake { resume: bool },
    /// Link down under [`SessionRecovery::Degrade`]: drop the record,
    /// keep streaming.
    Skip,
    /// Steady record traffic on the established session.
    Steady,
}

fn frame_kind(model: &SessionModel, recovery: SessionRecovery, frame: usize) -> FrameKind {
    if frame == 0 {
        return FrameKind::Handshake { resume: false };
    }
    if model.link_down_at(frame) {
        return match recovery {
            SessionRecovery::FullHandshake => FrameKind::Handshake { resume: false },
            SessionRecovery::Resume => FrameKind::Handshake { resume: true },
            SessionRecovery::Degrade => FrameKind::Skip,
        };
    }
    FrameKind::Steady
}

/// A secure-link stream's compiled session plan: one variant
/// [`JobGraph`] per handshake/retransmission/outage frame (local
/// indices, ascending) and the closed-form session counters. Steady
/// delivered frames stay the unmodified template — the fast-forward
/// machinery skips them wholesale.
#[derive(Debug, Clone)]
pub struct SessionPlan {
    pub variants: Vec<(usize, JobGraph)>,
    pub stats: SessionStats,
}

impl SessionPlan {
    /// Build the plan for global frames `[start, start + frames)` of a
    /// stream of `frame`-template frames. Pure over the arguments, so
    /// shards and threads agree by construction; the union of shard
    /// plans over a partition of the global range equals the unsharded
    /// plan, re-indexed.
    pub fn build(
        model: &SessionModel,
        recovery: SessionRecovery,
        frame: &JobGraph,
        start: usize,
        frames: usize,
    ) -> Result<SessionPlan> {
        model.validate()?;
        if !has_session_jobs(frame) {
            bail!(
                "secure-link channel on a template without handshake jobs \
                 ({HS_COOKIE_LABEL}/{HS_FLIGHT_LABEL}) — only session workloads take --loss"
            );
        }
        let base_mj = frame.active_mj();
        let mut plan = SessionPlan { variants: Vec::new(), stats: SessionStats::default() };
        for f in 0..frames {
            let g = start + f;
            // Drawn unconditionally every frame: the outage chain and
            // the shard lookback both key off this one record draw.
            let record = model.record_delivery(g);
            let variant = match frame_kind(model, recovery, g) {
                FrameKind::Steady => {
                    if !record.delivered {
                        plan.stats.records_dropped += 1;
                    }
                    plan.stats.retransmissions += (record.execs - 1) as u64;
                    plan.stats.backoff_dead_s += record.dead_s;
                    if record.execs == 1 {
                        // The unmodified template: no variant, and the
                        // fast-forward machinery stays engaged.
                        plan.stats.record_mj += base_mj;
                        continue;
                    }
                    retx_variant(frame, record.execs as f64, record.dead_s)
                }
                FrameKind::Skip => {
                    plan.stats.records_dropped += 1;
                    skip_variant(frame)
                }
                FrameKind::Handshake { resume } => {
                    if resume {
                        plan.stats.resumptions += 1;
                    } else {
                        plan.stats.full_handshakes += 1;
                    }
                    let hs = run_handshake(model, resume, g, &mut plan.stats);
                    let (rec_execs, rec_dead_s) = if hs.completed {
                        // The record rides the fresh session.
                        plan.stats.retransmissions += (record.execs - 1) as u64;
                        plan.stats.backoff_dead_s += record.dead_s;
                        if !record.delivered {
                            plan.stats.records_dropped += 1;
                        }
                        (record.execs as f64, record.dead_s)
                    } else {
                        // The handshake itself timed out: the record is
                        // encrypted once but never sent.
                        plan.stats.records_dropped += 1;
                        (1.0, 0.0)
                    };
                    handshake_variant(
                        frame,
                        hs.cookie_cycles,
                        hs.flight_cycles,
                        rec_execs,
                        hs.dead_s + rec_dead_s,
                    )
                }
            };
            let (hs_mj, rec_mj) = split_mj(&variant);
            plan.stats.handshake_mj += hs_mj;
            plan.stats.record_mj += rec_mj;
            plan.stats.overhead_mj += (variant.active_mj() - base_mj).max(0.0);
            plan.variants.push((f, variant));
        }
        Ok(plan)
    }

    /// The variants as the borrow slice the scheduler entry points take.
    pub fn variant_refs(&self) -> Vec<(usize, &JobGraph)> {
        self.variants.iter().map(|(f, g)| (*f, g)).collect()
    }
}

/// One handshake's aggregate outcome over its flights.
struct HandshakeRun {
    cookie_cycles: f64,
    flight_cycles: f64,
    dead_s: f64,
    completed: bool,
}

/// Fly the handshake flights against the channel, charging every send
/// (a retransmitted flight re-executes its compute — the fault layer's
/// honest-re-billing convention) and aborting on the first flight that
/// exhausts its budget.
fn run_handshake(
    model: &SessionModel,
    resume: bool,
    frame: usize,
    stats: &mut SessionStats,
) -> HandshakeRun {
    // (is_cookie, SW cycles) per flight: the full handshake is the
    // cookie round trip then the two ECC-bound key-exchange flights;
    // resumption is one cheap flight.
    let flights: &[(bool, f64)] = if resume {
        &[(false, RESUME_FLIGHT_CYCLES)]
    } else {
        &[
            (true, COOKIE_CYCLES),
            (true, COOKIE_CYCLES),
            (false, ECC_FLIGHT_CYCLES),
            (false, ECC_FLIGHT_CYCLES),
        ]
    };
    let mut run =
        HandshakeRun { cookie_cycles: 0.0, flight_cycles: 0.0, dead_s: 0.0, completed: true };
    let mut rng = model.hs_rng(frame as u64);
    for &(is_cookie, cycles) in flights {
        let d = deliver(&mut rng, model.loss_rate);
        stats.retransmissions += (d.execs - 1) as u64;
        stats.backoff_dead_s += d.dead_s;
        run.dead_s += d.dead_s;
        let sent = cycles * d.execs as f64;
        if is_cookie {
            run.cookie_cycles += sent;
        } else {
            run.flight_cycles += sent;
        }
        if !d.delivered {
            run.completed = false;
            break;
        }
    }
    run
}

/// Active energy of a variant, split into (handshake jobs, the rest).
fn split_mj(v: &JobGraph) -> (f64, f64) {
    let mut hs = 0.0;
    let mut rec = 0.0;
    for job in &v.jobs {
        let e = JobGraph::job_active_mj(job);
        if job.label == HS_COOKIE_LABEL || job.label == HS_FLIGHT_LABEL {
            hs += e;
        } else {
            rec += e;
        }
    }
    (hs, rec)
}

/// The degraded frame: zero service time, zero active energy — it
/// flows through the window without scheduling work, so the pipeline
/// never stalls on a dead link.
fn skip_variant(frame: &JobGraph) -> JobGraph {
    let mut v = frame.clone();
    for job in &mut v.jobs {
        job.duration_s = 0.0;
        for c in &mut job.charges {
            c.2 = 0.0;
        }
    }
    v
}

/// Scale the record-side crypto jobs by the retransmission count and
/// stretch the roots by the timer dead time. Crypto jobs are selected
/// by their energy category (not engine), so the scaling is backend-
/// independent: HWCRYPT, SW-core and in-SRAM records all re-bill their
/// sends. Dead time bills no active energy — the chip idles out the
/// timers and only makespan-proportional leakage grows.
fn retx_variant(frame: &JobGraph, execs: f64, dead_s: f64) -> JobGraph {
    let mut v = frame.clone();
    for job in &mut v.jobs {
        if execs != 1.0 && job.charges.iter().any(|c| c.0 == Category::Crypto) {
            job.duration_s *= execs;
        }
    }
    stretch_roots(&mut v, dead_s);
    v
}

/// The handshake frame: the placeholder jobs inflate to the flight
/// compute (SW cycles at each job's own operating point), the record's
/// crypto jobs scale by its sends, and the roots stretch by the total
/// dead time. Label-preserving — durations and charge multiplicities
/// are the only edits, so the variant stays `structurally_eq` to the
/// template.
fn handshake_variant(
    frame: &JobGraph,
    cookie_cycles: f64,
    flight_cycles: f64,
    rec_execs: f64,
    dead_s: f64,
) -> JobGraph {
    let mut v = frame.clone();
    for job in &mut v.jobs {
        if job.label == HS_COOKIE_LABEL {
            job.duration_s = cookie_cycles / job.op.freq_hz();
        } else if job.label == HS_FLIGHT_LABEL {
            job.duration_s = flight_cycles / job.op.freq_hz();
        } else if rec_execs != 1.0 && job.charges.iter().any(|c| c.0 == Category::Crypto) {
            job.duration_s *= rec_execs;
        }
    }
    stretch_roots(&mut v, dead_s);
    v
}

/// Stretch root jobs by `dead_s` with their charge multiplicities
/// compensated so the dead interval bills no active energy (the
/// fault layer's convention).
fn stretch_roots(v: &mut JobGraph, dead_s: f64) {
    if dead_s <= 0.0 {
        return;
    }
    for job in &mut v.jobs {
        if job.deps.is_empty() {
            let work = job.duration_s;
            job.duration_s = work + dead_s;
            let ratio = if work + dead_s > 0.0 { work / (work + dead_s) } else { 0.0 };
            for c in &mut job.charges {
                c.2 *= ratio;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Pluggable crypto cost models (CryptoSRAM-style ablation axis).
// ---------------------------------------------------------------------------

/// In-SRAM AES-XTS cycles per byte: wide in-memory XOR/SBOX operations
/// amortize the datapath over an SRAM row (CryptoSRAM-class designs
/// report 20–30× over scalar software; modeled, not measured).
pub const IN_SRAM_XTS_CPB: f64 = 6.0;

/// In-SRAM sponge-AE cycles per byte (KECCAK permutes map less cleanly
/// onto in-memory bitlines than AES rounds).
pub const IN_SRAM_AE_CPB: f64 = 9.0;

/// Which crypto cost model prices the record phases.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// The HWCRYPT engines (AES + KECCAK datapaths) — the paper's SoC.
    Hwcrypt,
    /// Software kernels on the OR10N cores
    /// ([`crate::kernels_sw::crypto_cost`]).
    Software,
    /// In-SRAM compute model à la CryptoSRAM.
    InSram,
}

impl BackendKind {
    /// The backend a configuration natively implies — what every run
    /// uses unless `--crypto-backend` overrides it. Matching the native
    /// backend is bitwise identical to the pre-backend builder.
    pub fn native(cfg: &ExecConfig) -> BackendKind {
        if cfg.hwcrypt {
            BackendKind::Hwcrypt
        } else {
            BackendKind::Software
        }
    }

    /// CLI name, report label and class-key fragment.
    pub fn name(self) -> &'static str {
        match self {
            BackendKind::Hwcrypt => "hwcrypt",
            BackendKind::Software => "sw",
            BackendKind::InSram => "insram",
        }
    }

    /// Parse a CLI spec: `hwcrypt`, `sw` or `insram`.
    pub fn parse(s: &str) -> Result<BackendKind> {
        match s {
            "hwcrypt" => Ok(BackendKind::Hwcrypt),
            "sw" => Ok(BackendKind::Software),
            "insram" => Ok(BackendKind::InSram),
            other => bail!("unknown crypto backend '{other}' (expected hwcrypt, sw or insram)"),
        }
    }

    pub fn all() -> [BackendKind; 3] {
        [BackendKind::Hwcrypt, BackendKind::Software, BackendKind::InSram]
    }

    /// The backend's cost model.
    pub fn model(self) -> &'static dyn CryptoBackend {
        match self {
            BackendKind::Hwcrypt => &HwcryptBackend,
            BackendKind::Software => &SoftwareBackend,
            BackendKind::InSram => &InSramBackend,
        }
    }
}

/// One crypto phase, priced: cycles at `mode`, on an accelerator (with
/// its control stub) or on `cores` SW cores, with the energy rows the
/// phase charges.
pub struct CryptoCost {
    pub cycles: f64,
    pub mode: OperatingMode,
    /// `Some(engine)` runs on that accelerator behind a control stub;
    /// `None` runs on the first `cores` cluster cores.
    pub accel: Option<Engine>,
    pub cores: usize,
    pub charges: Vec<(Category, Component, f64)>,
}

impl CryptoCost {
    /// Operating point of the phase at the configuration's rail.
    pub fn op(&self, cfg: &ExecConfig) -> OperatingPoint {
        OperatingPoint::new(self.mode, cfg.vdd)
    }
}

/// A crypto cost model: prices the builder's `xts` and `sponge_ae`
/// phases. `cluster_point` is the workload's pinned cluster mode — the
/// HWCRYPT backend hosts KECCAK there when the point covers it, the
/// convention the pre-backend builder used.
pub trait CryptoBackend {
    fn xts(&self, cfg: &ExecConfig, cluster_point: OperatingMode, bytes: usize) -> CryptoCost;
    fn sponge_ae(&self, cfg: &ExecConfig, cluster_point: OperatingMode, bytes: usize) -> CryptoCost;
}

/// The HWCRYPT engines: AES-XTS on the AES datapath at the all-capable
/// CRY-CNN-SW point, sponge AE on the KECCAK datapath.
pub struct HwcryptBackend;

impl CryptoBackend for HwcryptBackend {
    fn xts(&self, _cfg: &ExecConfig, _cluster_point: OperatingMode, bytes: usize) -> CryptoCost {
        CryptoCost {
            cycles: hwcrypt::CipherOp::AesXts.cycles(bytes) as f64
                + hwcrypt::JOB_CONFIG_CYCLES as f64,
            mode: OperatingMode::CryCnnSw,
            accel: Some(Engine::HwcryptAes),
            cores: 1,
            charges: vec![
                (Category::Crypto, Component::Core, 1.0), // controller core
                (Category::Crypto, Component::ClusterInfra, 1.0),
                (Category::Crypto, Component::HwcryptAes, 1.0),
            ],
        }
    }

    fn sponge_ae(&self, _cfg: &ExecConfig, cluster_point: OperatingMode, bytes: usize) -> CryptoCost {
        let mode = if cluster_point.keccak_available() {
            cluster_point
        } else {
            OperatingMode::KecCnnSw
        };
        CryptoCost {
            cycles: hwcrypt::CipherOp::SpongeAe(crate::crypto::sponge::SpongeConfig::MAX_RATE)
                .cycles(bytes) as f64,
            mode,
            accel: Some(Engine::HwcryptKec),
            cores: 1,
            charges: vec![
                (Category::Crypto, Component::Core, 1.0),
                (Category::Crypto, Component::ClusterInfra, 1.0),
                (Category::Crypto, Component::HwcryptKec, 1.0),
            ],
        }
    }
}

/// Software crypto on the OR10N cores: the §III-calibrated
/// cycles-per-byte models, XTS Amdahl-split over the configured cores,
/// KECCAK single-core.
pub struct SoftwareBackend;

impl CryptoBackend for SoftwareBackend {
    fn xts(&self, cfg: &ExecConfig, _cluster_point: OperatingMode, bytes: usize) -> CryptoCost {
        CryptoCost {
            cycles: crypto_cost::sw_xts_cpb(cfg.n_cores) * bytes as f64,
            mode: OperatingMode::Sw,
            accel: None,
            cores: cfg.n_cores,
            charges: vec![
                (Category::Crypto, Component::Core, cfg.n_cores as f64),
                (Category::Crypto, Component::ClusterInfra, 1.0),
            ],
        }
    }

    fn sponge_ae(&self, _cfg: &ExecConfig, _cluster_point: OperatingMode, bytes: usize) -> CryptoCost {
        CryptoCost {
            cycles: crypto_cost::SW_KECCAK_CPB_1CORE * bytes as f64,
            mode: OperatingMode::Sw,
            accel: None,
            cores: 1,
            charges: vec![
                (Category::Crypto, Component::Core, 1.0),
                (Category::Crypto, Component::ClusterInfra, 1.0),
            ],
        }
    }
}

/// In-SRAM compute model: one core issues wide in-memory operations;
/// the work stays in the SRAM macros, so only the issuing core and the
/// cluster infrastructure charge.
pub struct InSramBackend;

impl CryptoBackend for InSramBackend {
    fn xts(&self, _cfg: &ExecConfig, _cluster_point: OperatingMode, bytes: usize) -> CryptoCost {
        CryptoCost {
            cycles: IN_SRAM_XTS_CPB * bytes as f64,
            mode: OperatingMode::Sw,
            accel: None,
            cores: 1,
            charges: vec![
                (Category::Crypto, Component::Core, 1.0),
                (Category::Crypto, Component::ClusterInfra, 1.0),
            ],
        }
    }

    fn sponge_ae(&self, _cfg: &ExecConfig, _cluster_point: OperatingMode, bytes: usize) -> CryptoCost {
        CryptoCost {
            cycles: IN_SRAM_AE_CPB * bytes as f64,
            mode: OperatingMode::Sw,
            accel: None,
            cores: 1,
            charges: vec![
                (Category::Crypto, Component::Core, 1.0),
                (Category::Crypto, Component::ClusterInfra, 1.0),
            ],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::sched::Job;

    /// A minimal secure-link template: the two zero-duration handshake
    /// placeholders, a sensor root and a crypto record tail.
    fn template() -> JobGraph {
        let sw = OperatingPoint::new(OperatingMode::Sw, 0.8);
        let kec = OperatingPoint::new(OperatingMode::KecCnnSw, 0.8);
        let mut g = JobGraph::new();
        let cookie = g.push(Job {
            label: HS_COOKIE_LABEL,
            engines: vec![Engine::Core(0)],
            op: sw,
            duration_s: 0.0,
            deps: vec![],
            charges: vec![
                (Category::OtherSw, Component::Core, 1.0),
                (Category::OtherSw, Component::ClusterInfra, 1.0),
            ],
        });
        let flight = g.push(Job {
            label: HS_FLIGHT_LABEL,
            engines: vec![Engine::Core(0)],
            op: sw,
            duration_s: 0.0,
            deps: vec![cookie],
            charges: vec![
                (Category::OtherSw, Component::Core, 1.0),
                (Category::OtherSw, Component::ClusterInfra, 1.0),
            ],
        });
        let adc = g.push(Job {
            label: "adc",
            engines: vec![Engine::Core(0)],
            op: sw,
            duration_s: 0.001,
            deps: vec![],
            charges: vec![(Category::OtherSw, Component::Core, 1.0)],
        });
        g.push(Job {
            label: "sponge-ae",
            engines: vec![Engine::HwcryptKec],
            op: kec,
            duration_s: 0.002,
            deps: vec![flight, adc],
            charges: vec![
                (Category::Crypto, Component::Core, 1.0),
                (Category::Crypto, Component::ClusterInfra, 1.0),
                (Category::Crypto, Component::HwcryptKec, 1.0),
            ],
        });
        g
    }

    fn lossy(rate: f64) -> SessionModel {
        SessionModel { loss_rate: rate, seed: 5 }
    }

    #[test]
    fn parse_round_trips_and_rejects() {
        let m = SessionModel::parse("0.2:9").unwrap();
        assert_eq!(m, SessionModel { loss_rate: 0.2, seed: 9 });
        assert_eq!(SessionModel::parse("0.1").unwrap().seed, 1);
        assert!(SessionModel::parse("1.0").is_err(), "certain loss never completes");
        assert!(SessionModel::parse("-0.1").is_err());
        assert!(SessionModel::parse("x").is_err());
        assert!(SessionModel::parse("0.1:y").is_err());
        assert!(SessionModel::parse("0.1:2:3").is_err());
        for r in SessionRecovery::all() {
            assert_eq!(SessionRecovery::parse(r.key()).unwrap(), r);
        }
        assert!(SessionRecovery::parse("retry").is_err());
        for b in BackendKind::all() {
            assert_eq!(BackendKind::parse(b.name()).unwrap(), b);
        }
        assert!(BackendKind::parse("fpga").is_err());
        // distinct models map to distinct class-key fragments
        assert_ne!(lossy(0.1).key(), lossy(0.2).key());
        assert_ne!(lossy(0.1).key(), SessionModel { loss_rate: 0.1, seed: 6 }.key());
    }

    #[test]
    fn delivery_is_deterministic_and_bounded() {
        let m = lossy(0.4);
        for f in 0..256 {
            let a = m.record_delivery(f);
            let b = m.record_delivery(f);
            assert_eq!(a, b, "frame {f} must replay bitwise");
            assert!(a.execs >= 1 && a.execs <= MAX_RETX + 1);
            if a.execs <= MAX_RETX {
                assert!(a.delivered, "giving up takes the whole budget");
            }
        }
        // a lossless channel delivers everything first try, no waiting
        let l = SessionModel::lossless();
        for f in 0..64 {
            assert_eq!(l.record_delivery(f), Delivery { execs: 1, delivered: true, dead_s: 0.0 });
            assert!(!l.link_down_at(f));
        }
    }

    #[test]
    fn retx_timers_double_and_saturate() {
        // force total loss through the free function: all MAX_RETX+1
        // sends fail, and the dead time is the full saturating ladder
        let mut rng = Xorshift64Star::new(42);
        let d = deliver(&mut rng, 1.0);
        assert_eq!(d.execs, MAX_RETX + 1);
        assert!(!d.delivered);
        // 0.05 × (1+2+4+8+16+32+64) = 0.05 × 127
        assert!((d.dead_s - RETX_INIT_S * 127.0).abs() < 1e-12);
    }

    #[test]
    fn frame_zero_is_always_a_full_handshake() {
        let m = lossy(0.0);
        let plan = SessionPlan::build(&m, SessionRecovery::Resume, &template(), 0, 64).unwrap();
        assert_eq!(plan.stats.full_handshakes, 1);
        assert_eq!(plan.stats.resumptions, 0);
        assert_eq!(plan.stats.retransmissions, 0);
        assert_eq!(plan.stats.records_dropped, 0);
        assert_eq!(plan.variants.len(), 1, "lossless: only the frame-0 handshake varies");
        assert_eq!(plan.variants[0].0, 0);
        assert!(plan.stats.handshake_mj > 0.0);
        assert!((plan.stats.availability(64) - 1.0).abs() < 1e-12);
        // the handshake placeholders inflated: cookie + ECC flights
        let v = &plan.variants[0].1;
        assert!(v.jobs[0].duration_s > 0.0 && v.jobs[1].duration_s > 0.0);
        assert!(v.jobs[1].duration_s > v.jobs[0].duration_s, "ECC flights dwarf the cookie");
        // ... and a shard that starts past frame 0 never handshakes
        let tail = SessionPlan::build(&m, SessionRecovery::Resume, &template(), 1, 63).unwrap();
        assert!(tail.variants.is_empty());
        assert_eq!(tail.stats.full_handshakes, 0);
    }

    #[test]
    fn plans_union_over_shards() {
        let g = template();
        for rec in SessionRecovery::all() {
            let m = lossy(0.3);
            let whole = SessionPlan::build(&m, rec, &g, 0, 512).unwrap();
            let a = SessionPlan::build(&m, rec, &g, 0, 200).unwrap();
            let b = SessionPlan::build(&m, rec, &g, 200, 312).unwrap();
            assert_eq!(
                whole.stats.retransmissions,
                a.stats.retransmissions + b.stats.retransmissions,
                "{rec:?}"
            );
            assert_eq!(
                whole.stats.records_dropped,
                a.stats.records_dropped + b.stats.records_dropped
            );
            assert_eq!(whole.stats.full_handshakes, a.stats.full_handshakes + b.stats.full_handshakes);
            assert_eq!(whole.stats.resumptions, a.stats.resumptions + b.stats.resumptions);
            assert!(
                (whole.stats.handshake_mj - a.stats.handshake_mj - b.stats.handshake_mj).abs()
                    < 1e-9
            );
            let mut frames: Vec<usize> = a.variants.iter().map(|(f, _)| *f).collect();
            frames.extend(b.variants.iter().map(|(f, _)| f + 200));
            assert_eq!(frames, whole.variants.iter().map(|(f, _)| *f).collect::<Vec<_>>());
        }
    }

    #[test]
    fn recovery_policies_shape_the_outage() {
        let g = template();
        let m = lossy(0.45);
        let full = SessionPlan::build(&m, SessionRecovery::FullHandshake, &g, 0, 512).unwrap();
        let resume = SessionPlan::build(&m, SessionRecovery::Resume, &g, 0, 512).unwrap();
        let degrade = SessionPlan::build(&m, SessionRecovery::Degrade, &g, 0, 512).unwrap();
        // outages exist at this rate, and each policy answers them its way
        assert!(full.stats.full_handshakes > 1);
        assert!(resume.stats.resumptions > 0);
        assert_eq!(resume.stats.full_handshakes, 1, "only frame 0 negotiates from scratch");
        assert_eq!(degrade.stats.full_handshakes, 1);
        assert_eq!(degrade.stats.resumptions, 0);
        // resumption replays a far cheaper handshake
        assert!(resume.stats.handshake_mj < full.stats.handshake_mj);
        // degrade drops every outage frame and pays nothing to recover
        assert!(degrade.stats.records_dropped > resume.stats.records_dropped);
        assert!(degrade.stats.handshake_mj < resume.stats.handshake_mj);
        // degraded outage frames are true skips: zero duration, no stall
        let skip = degrade
            .variants
            .iter()
            .find(|(f, _)| m.link_down_at(*f))
            .map(|(_, v)| v)
            .expect("an outage frame exists");
        assert!(skip.jobs.iter().all(|j| j.duration_s == 0.0));
        assert_eq!(skip.active_mj(), 0.0);
        // retransmissions happened and were billed as overhead
        assert!(resume.stats.retransmissions > 0);
        assert!(resume.stats.overhead_mj > 0.0);
        assert!(resume.stats.backoff_dead_s > 0.0);
        assert!(resume.stats.availability(512) < 1.0);
    }

    #[test]
    fn variants_preserve_structure_and_bill_dead_time_free() {
        let g = template();
        let m = lossy(0.4);
        let plan = SessionPlan::build(&m, SessionRecovery::Resume, &g, 0, 512).unwrap();
        assert!(plan.variants.windows(2).all(|w| w[0].0 < w[1].0));
        for (f, v) in &plan.variants {
            // the scheduler's check_variants demands identical structure:
            // labels, engines and dependency edges never change
            assert_eq!(v.jobs.len(), g.jobs.len(), "variant at {f}");
            for (a, b) in v.jobs.iter().zip(&g.jobs) {
                assert_eq!(a.label, b.label);
                assert_eq!(a.engines, b.engines);
                assert_eq!(a.deps, b.deps);
            }
        }
        // a pure-retransmission steady frame: crypto tail scaled, sw row
        // untouched, roots stretched with their charges compensated
        let (f, v) = plan
            .variants
            .iter()
            .find(|(f, _)| {
                *f > 0 && !m.link_down_at(*f) && m.record_delivery(*f).execs > 1
            })
            .expect("a retransmitted steady frame exists");
        let d = m.record_delivery(*f);
        assert!((v.jobs[3].duration_s - g.jobs[3].duration_s * d.execs as f64).abs() < 1e-12);
        assert_eq!(v.jobs[1].duration_s, 0.0, "hs placeholder stays empty on steady frames");
        let root = &v.jobs[2];
        assert!((root.duration_s - (g.jobs[2].duration_s + d.dead_s)).abs() < 1e-12);
        assert!(root.charges[0].2 < 1.0, "dead time must not bill active energy");
        assert!(
            (JobGraph::job_active_mj(root) - JobGraph::job_active_mj(&g.jobs[2])).abs() < 1e-12
        );
    }

    #[test]
    fn non_session_templates_are_rejected() {
        let mut g = template();
        g.jobs.retain(|j| j.label != HS_FLIGHT_LABEL);
        for j in &mut g.jobs {
            j.deps.clear();
        }
        let err = SessionPlan::build(&lossy(0.1), SessionRecovery::Resume, &g, 0, 8);
        assert!(err.is_err());
        assert!(!has_session_jobs(&g));
        assert!(has_session_jobs(&template()));
    }

    #[test]
    fn apply_stats_maps_onto_the_reliability_columns() {
        let g = template();
        let mut r = crate::soc::sched::Scheduler::run(&g);
        let stats = SessionStats {
            full_handshakes: 1,
            resumptions: 2,
            retransmissions: 7,
            records_dropped: 3,
            handshake_mj: 0.25,
            record_mj: 1.0,
            overhead_mj: 0.5,
            backoff_dead_s: 0.4,
        };
        apply_stats(&mut r, &stats, 2.0);
        assert_eq!(r.frames_dropped, 3);
        assert_eq!(r.fault_retries, 7);
        assert!((r.recovery_energy_mj - 1.0).abs() < 1e-12);
        assert!((stats.availability(12) - 0.75).abs() < 1e-12);
        assert!((stats.goodput_fps(12, 3.0) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn backends_price_the_record_phases() {
        let cfg = ExecConfig::sw_1core();
        let hw = BackendKind::Hwcrypt.model();
        let sw = BackendKind::Software.model();
        let sram = BackendKind::InSram.model();
        let bytes = RECORD_BYTES;
        // HWCRYPT runs on its engines at the capable points whatever
        // the rung says — that's what makes the ablation a sweep
        let x = hw.xts(&cfg, OperatingMode::Sw, bytes);
        assert_eq!(x.accel, Some(Engine::HwcryptAes));
        assert_eq!(x.mode, OperatingMode::CryCnnSw);
        let s = hw.sponge_ae(&cfg, OperatingMode::Sw, bytes);
        assert_eq!(s.accel, Some(Engine::HwcryptKec));
        assert_eq!(s.mode, OperatingMode::KecCnnSw);
        // ... and hosts the sponge at a keccak-capable cluster point
        assert_eq!(hw.sponge_ae(&cfg, OperatingMode::CryCnnSw, bytes).mode, OperatingMode::CryCnnSw);
        // software prices by the §III cycles-per-byte anchors
        let xs = sw.xts(&cfg, OperatingMode::Sw, bytes);
        assert!(xs.accel.is_none());
        assert!((xs.cycles - crypto_cost::sw_xts_cpb(1) * bytes as f64).abs() < 1e-9);
        // in-SRAM sits far under software and needs no accelerator
        let xi = sram.xts(&cfg, OperatingMode::Sw, bytes);
        assert!(xi.accel.is_none() && xi.cycles < xs.cycles / 10.0);
        assert!(sram.sponge_ae(&cfg, OperatingMode::Sw, bytes).cycles < s.cycles * 100.0);
        // native backend mirrors the configuration's hwcrypt bit
        assert_eq!(BackendKind::native(&cfg), BackendKind::Software);
        let mut hwcfg = cfg;
        hwcfg.hwcrypt = true;
        assert_eq!(BackendKind::native(&hwcfg), BackendKind::Hwcrypt);
    }
}
