//! Minimal hand-rolled JSON: a value tree, an emitter and a parser.
//!
//! The crate's dependency policy is anyhow-only (the offline registry
//! carries no `serde`), so the machine-readable output of the
//! [`crate::system`] reports is emitted by this module instead. The
//! emitter produces canonical, round-trippable JSON — `f64` values are
//! printed with Rust's shortest-representation `Display`, which parses
//! back bit-exactly — and the parser exists so tests (and embedders) can
//! verify that contract without external tooling.

use anyhow::{bail, Result};

/// A JSON value. Object fields keep insertion order (no map — reports are
/// ordered documents, not dictionaries).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn string(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    /// Build an object from `(key, value)` pairs.
    pub fn obj(fields: Vec<(&str, Json)>) -> Json {
        Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Field lookup on an object.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    /// Serialize (compact, no trailing newline).
    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, s: &mut String) {
        match self {
            Json::Null => s.push_str("null"),
            Json::Bool(b) => s.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    // Rust's Display for f64 is the shortest decimal that
                    // round-trips, and never uses exponent notation — valid
                    // JSON as-is.
                    s.push_str(&format!("{x}"));
                } else {
                    // JSON has no Inf/NaN; null is the least-bad encoding.
                    s.push_str("null");
                }
            }
            Json::Str(v) => write_escaped(s, v),
            Json::Arr(items) => {
                s.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        s.push(',');
                    }
                    item.write(s);
                }
                s.push(']');
            }
            Json::Obj(fields) => {
                s.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        s.push(',');
                    }
                    write_escaped(s, k);
                    s.push(':');
                    v.write(s);
                }
                s.push('}');
            }
        }
    }

    /// Parse a complete JSON document (trailing whitespace allowed).
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing garbage at byte {}", p.pos);
        }
        Ok(v)
    }
}

fn write_escaped(s: &mut String, v: &str) {
    s.push('"');
    for c in v.chars() {
        match c {
            '"' => s.push_str("\\\""),
            '\\' => s.push_str("\\\\"),
            '\n' => s.push_str("\\n"),
            '\t' => s.push_str("\\t"),
            '\r' => s.push_str("\\r"),
            c if (c as u32) < 0x20 => s.push_str(&format!("\\u{:04x}", c as u32)),
            c => s.push(c),
        }
    }
    s.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected {:?} at byte {}", b as char, self.pos)
        }
    }

    fn literal(&mut self, lit: &str, value: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(value)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|b| b as char), self.pos),
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).expect("ascii digits");
        match text.parse::<f64>() {
            Ok(x) => Ok(Json::Num(x)),
            Err(_) => bail!("bad number {text:?} at byte {start}"),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let Some(c) = self.peek() else { bail!("unterminated string") };
            self.pos += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let Some(e) = self.peek() else { bail!("unterminated escape") };
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let cp = self.hex4()?;
                            // Surrogate pairs: a high surrogate must be
                            // followed by an escaped low surrogate.
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                self.expect(b'\\')?;
                                self.expect(b'u')?;
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    bail!("bad low surrogate at byte {}", self.pos);
                                }
                                0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00)
                            } else {
                                cp
                            };
                            match char::from_u32(c) {
                                Some(c) => out.push(c),
                                None => bail!("bad codepoint {c:#x} at byte {}", self.pos),
                            }
                        }
                        other => bail!("bad escape {:?} at byte {}", other as char, self.pos),
                    }
                }
                c if c < 0x80 => out.push(c as char),
                _ => {
                    // multi-byte UTF-8: copy the full sequence
                    let start = self.pos - 1;
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    if start + len > self.bytes.len() {
                        bail!("truncated UTF-8 at byte {start}");
                    }
                    match std::str::from_utf8(&self.bytes[start..start + len]) {
                        Ok(s) => out.push_str(s),
                        Err(_) => bail!("invalid UTF-8 at byte {start}"),
                    }
                    self.pos = start + len;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        if self.pos + 4 > self.bytes.len() {
            bail!("truncated \\u escape at byte {}", self.pos);
        }
        let text = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| anyhow::anyhow!("bad \\u escape at byte {}", self.pos))?;
        let cp = u32::from_str_radix(text, 16)
            .map_err(|_| anyhow::anyhow!("bad \\u escape {text:?} at byte {}", self.pos))?;
        self.pos += 4;
        Ok(cp)
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => bail!("expected ',' or ']' at byte {}", self.pos),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let value = self.value()?;
            fields.push((key, value));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => bail!("expected ',' or '}}' at byte {}", self.pos),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_document() {
        let doc = Json::obj(vec![
            ("name", Json::string("fulmine \"soc\"\n")),
            ("pi", Json::num(3.141592653589793)),
            ("tiny", Json::num(1.25e-7)),
            ("neg", Json::num(-42.0)),
            ("flags", Json::Arr(vec![Json::Bool(true), Json::Bool(false), Json::Null])),
            ("nested", Json::obj(vec![("empty_arr", Json::Arr(vec![])), ("empty_obj", Json::obj(vec![]))])),
        ]);
        let text = doc.render();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back, doc);
    }

    #[test]
    fn f64_roundtrips_bit_exactly() {
        for x in [0.0, 1.0, -1.0, 0.1, 1.0 / 3.0, 27.345678901234, 2.5e-9, 1e15 + 0.5] {
            let text = Json::Num(x).render();
            let back = Json::parse(&text).unwrap().as_f64().unwrap();
            assert_eq!(back.to_bits(), x.to_bits(), "{x} -> {text} -> {back}");
        }
    }

    #[test]
    fn accessors_navigate() {
        let v = Json::parse(r#"{"a": [1, {"b": "c"}], "d": 2.5}"#).unwrap();
        assert_eq!(v.get("d").and_then(Json::as_f64), Some(2.5));
        let arr = v.get("a").and_then(Json::as_array).unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[1].get("b").and_then(Json::as_str), Some("c"));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn escapes_and_unicode() {
        let s = "tab\there \"q\" \\ back\nnew μJ/ΣΔ";
        let text = Json::Str(s.to_string()).render();
        assert_eq!(Json::parse(&text).unwrap().as_str(), Some(s));
        // external escape forms parse too
        assert_eq!(Json::parse(r#""µJ 😀""#).unwrap().as_str(), Some("µJ 😀"));
    }

    #[test]
    fn nonfinite_numbers_encode_as_null() {
        assert_eq!(Json::Num(f64::NAN).render(), "null");
        assert_eq!(Json::Num(f64::INFINITY).render(), "null");
    }
}
