//! Event-driven SoC scheduler: the whole chip as a set of [`Engine`]
//! resources consuming typed [`Job`]s from a dependency graph.
//!
//! The coordinator use cases (§IV) *emit* a [`JobGraph`] — convolutions,
//! cipher runs, software phases, DMA and external-memory transfers with
//! their data dependencies — and [`Scheduler::run`] advances simulated time
//! through a binary-heap event queue, dispatching each job as soon as its
//! dependencies have completed, its engines are free, and the cluster
//! operating point admits it. Cross-engine concurrency (double-buffered
//! DMA, uDMA I/O under compute, HWCRYPT decrypting the next tile's weights
//! while the HWCE convolves the current one, SW epilogues on the cores
//! under both) falls out of the schedule instead of being approximated by
//! an analytic overlap term.
//!
//! ## Engines
//!
//! One entry per serially-busy resource of the Fulmine SoC: the four OR10N
//! cluster cores — *individually*, [`Engine::Core`]`(0..4)`, so software
//! phases, accelerator-control stubs and epilogues contend per core the
//! way the TCDM masters do — the HWCE, the two HWCRYPT datapaths, the
//! cluster DMA, and one uDMA channel per external interface (flash, FRAM,
//! and the ADC front end; the uDMA serves its peripherals on independent
//! channels, §II). A job may span several engines at once (a 4-core
//! software phase occupies four `Core` engines for one interval).
//!
//! ## Operating modes and co-residency
//!
//! The cluster-domain engines (cores + accelerators) share one clock and
//! one operating mode (§III-A). Jobs carry the [`OperatingPoint`] they
//! were *emitted* for; at dispatch the cluster is at some current mode and
//! the co-residency rule applies:
//!
//! * a job whose mode equals the current mode dispatches immediately —
//!   same clock, no cost;
//! * a job whose mode is *subsumed* by the current mode
//!   ([`OperatingMode::supports`]: the CRY-CNN-SW point is all-capable,
//!   KEC-CNN-SW hosts KEC/CNN/SW work, SW only SW) may co-reside: it runs
//!   at the current — lower — clock, its service time rescaled by the
//!   frequency ratio. The scheduler accepts this only when the slowdown
//!   costs less than the 10 µs FLL relock a private mode window would
//!   (tiny epilogue slivers and cipher-control stubs ride along free;
//!   long software phases get their own window);
//! * otherwise the job waits for the cluster to drain, and the relock
//!   ([`MODE_SWITCH_S`]) is charged only on a *genuine* frequency change.
//!   A switch is granted to the lowest-id ready cluster job, keeping the
//!   mode sequence in program order.
//!
//! SOC-domain engines (cluster DMA, uDMA channels) run in any mode — the
//! uDMA works "even when the cluster is in sleep mode" (§II).
//!
//! ## Energy
//!
//! Each job lists per-component charges; the busy interval is integrated
//! on the [`EnergyLedger`] at the job's *emission* operating point.
//! Because cluster dynamic power is linear in frequency at fixed VDD
//! ([`PowerModel`]), a rescaled co-resident job consumes exactly the same
//! active energy as at its own point (P·t = pJ/cycle × cycles), so active
//! energy stays schedule-independent; only the makespan-proportional
//! Idle/standby terms (≈1.5 mW) vary with the schedule — which keeps
//! scheduled results within a few percent of [`JobGraph::analytic`], the
//! phase-summation model the figures of the paper were calibrated against.
//!
//! ## Streaming
//!
//! [`JobGraph::repeat`] concatenates N copies of a frame graph (dependency
//! edges stay within each frame). Scheduling the combined graph pipelines
//! successive frames through the engines: frame *f+1*'s I/O and
//! accelerator phases fill the stalls of frame *f*, which is where the
//! multi-frame throughput of `fulmine stream` comes from.

use crate::energy::{Category, EnergyLedger};
use crate::soc::opmodes::{OperatingMode, OperatingPoint, MODE_SWITCH_S, V_NOM};
use crate::soc::power::{Component, PowerModel, FLASH_STANDBY_MW, FRAM_STANDBY_MW};
use std::cmp::Ordering;
use std::collections::{BTreeSet, BinaryHeap};

/// Cluster cores (OR10N complex).
pub const N_CORES: usize = 4;

/// A serially-busy hardware resource of the SoC.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Engine {
    /// One OR10N cluster core (0..[`N_CORES`]). Modeling the cores as
    /// separate masters lets accelerator control and SW epilogues share
    /// the complex instead of folding into one aggregate resource.
    Core(u8),
    /// HWCE convolution engine.
    Hwce,
    /// HWCRYPT AES datapath.
    HwcryptAes,
    /// HWCRYPT KECCAK sponge datapath.
    HwcryptKec,
    /// Cluster DMA (L2 ↔ TCDM).
    ClusterDma,
    /// uDMA channel serving the quad-SPI flash.
    UdmaFlash,
    /// uDMA channel serving the FRAM.
    UdmaFram,
    /// uDMA channel serving the sensor/ADC front end (§IV-C acquisition).
    UdmaAdc,
}

/// Number of scheduled engines.
pub const N_ENGINES: usize = Engine::ALL.len();

impl Engine {
    /// Every engine, in [`Engine::index`] order.
    pub const ALL: [Engine; 11] = [
        Engine::Core(0),
        Engine::Core(1),
        Engine::Core(2),
        Engine::Core(3),
        Engine::Hwce,
        Engine::HwcryptAes,
        Engine::HwcryptKec,
        Engine::ClusterDma,
        Engine::UdmaFlash,
        Engine::UdmaFram,
        Engine::UdmaAdc,
    ];

    /// Dense index for per-engine arrays (matches the position in
    /// [`Engine::ALL`]).
    pub fn index(self) -> usize {
        match self {
            Engine::Core(i) => {
                // unconditional: an out-of-range core would alias another
                // engine's dense index and silently corrupt its accounting
                assert!((i as usize) < N_CORES, "core index {i} out of range");
                i as usize
            }
            Engine::Hwce => N_CORES,
            Engine::HwcryptAes => N_CORES + 1,
            Engine::HwcryptKec => N_CORES + 2,
            Engine::ClusterDma => N_CORES + 3,
            Engine::UdmaFlash => N_CORES + 4,
            Engine::UdmaFram => N_CORES + 5,
            Engine::UdmaAdc => N_CORES + 6,
        }
    }

    /// Cluster-domain engines share the cluster clock and therefore the
    /// operating mode; SOC-domain movers do not.
    pub fn mode_locked(self) -> bool {
        matches!(
            self,
            Engine::Core(_) | Engine::Hwce | Engine::HwcryptAes | Engine::HwcryptKec
        )
    }

    pub fn name(self) -> &'static str {
        match self {
            Engine::Core(0) => "core0",
            Engine::Core(1) => "core1",
            Engine::Core(2) => "core2",
            Engine::Core(3) => "core3",
            Engine::Core(_) => "core?",
            Engine::Hwce => "hwce",
            Engine::HwcryptAes => "hwcrypt-aes",
            Engine::HwcryptKec => "hwcrypt-kec",
            Engine::ClusterDma => "cluster-dma",
            Engine::UdmaFlash => "udma-flash",
            Engine::UdmaFram => "udma-fram",
            Engine::UdmaAdc => "udma-adc",
        }
    }
}

/// Identifier of a job within its [`JobGraph`] (its insertion index).
pub type JobId = usize;

/// One unit of work bound to one or more engines: a service time at an
/// operating point, dependencies on earlier jobs, and the energy charges
/// to integrate over the busy interval (`(category, component,
/// multiplicity)` — e.g. a 4-core software phase occupies
/// `Core(0)..Core(3)` and charges `Component::Core` with multiplicity 4).
#[derive(Debug, Clone)]
pub struct Job {
    pub label: &'static str,
    /// Engines this job occupies for its whole busy interval (≥ 1,
    /// distinct). Multi-engine jobs model phases that hold several cores
    /// at once.
    pub engines: Vec<Engine>,
    pub op: OperatingPoint,
    /// Service time at `op`; a co-resident dispatch at a slower compatible
    /// point rescales it by the frequency ratio.
    pub duration_s: f64,
    pub deps: Vec<JobId>,
    pub charges: Vec<(Category, Component, f64)>,
}

impl Job {
    /// Whether this job runs in the cluster clock domain (any of its
    /// engines is mode-locked).
    pub fn mode_locked(&self) -> bool {
        self.engines.iter().any(|e| e.mode_locked())
    }

    /// Service time when hosted at cluster mode `at` (its own time at its
    /// own mode; stretched by the frequency ratio under a slower
    /// compatible point).
    fn duration_at(&self, at: OperatingMode) -> f64 {
        if at == self.op.mode {
            self.duration_s
        } else {
            self.duration_s * self.op.freq_hz() / OperatingPoint::new(at, self.op.vdd).freq_hz()
        }
    }
}

/// A dependency graph of jobs. Acyclic by construction: dependencies must
/// point at already-pushed jobs.
#[derive(Debug, Clone)]
pub struct JobGraph {
    pub jobs: Vec<Job>,
    /// Whether external flash/FRAM are attached (their standby power is
    /// charged over the whole run); the pacemaker-class seizure platform
    /// has none (§IV-C).
    pub ext_mem_present: bool,
    /// Named segment markers `(label, first job id)` — see
    /// [`JobGraph::mark_segment`]. Empty for single-tenant graphs.
    pub segments: Vec<(String, JobId)>,
}

impl Default for JobGraph {
    fn default() -> Self {
        Self::new()
    }
}

impl JobGraph {
    pub fn new() -> Self {
        JobGraph { jobs: Vec::new(), ext_mem_present: true, segments: Vec::new() }
    }

    /// Open a named segment at the current end of the graph: jobs pushed
    /// from here until the next marker belong to `label`. Multi-tenant
    /// workloads use this to attribute active energy per tenant
    /// ([`JobGraph::segment_active_mj`]); repeating the same label
    /// aggregates (each streamed frame re-marks its tenants).
    pub fn mark_segment(&mut self, label: &str) {
        self.segments.push((label.to_string(), self.jobs.len()));
    }

    /// Append a job; its dependencies must reference earlier jobs, its
    /// engine set must be non-empty and duplicate-free, and all jobs of a
    /// graph must share one supply voltage (leakage is charged graph-wide
    /// at the first job's VDD).
    pub fn push(&mut self, job: Job) -> JobId {
        let id = self.jobs.len();
        assert!(!job.engines.is_empty(), "job {id} occupies no engine");
        debug_assert!(
            {
                let mut seen = [false; N_ENGINES];
                job.engines.iter().all(|e| !std::mem::replace(&mut seen[e.index()], true))
            },
            "job {id} lists an engine twice"
        );
        for &d in &job.deps {
            assert!(d < id, "job {id} depends on not-yet-pushed job {d}");
        }
        if let Some(first) = self.jobs.first() {
            debug_assert!(
                job.op.vdd == first.op.vdd,
                "job {id} at {} V in a {} V graph — one graph, one supply",
                job.op.vdd,
                first.op.vdd
            );
        }
        self.jobs.push(job);
        id
    }

    pub fn len(&self) -> usize {
        self.jobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }

    /// Concatenate `frames` copies of this graph (streaming): dependency
    /// edges stay within each copy; pipelining across copies comes from the
    /// shared engines at schedule time.
    pub fn repeat(&self, frames: usize) -> JobGraph {
        let n = self.jobs.len();
        let mut out = JobGraph {
            jobs: Vec::with_capacity(n * frames),
            ext_mem_present: self.ext_mem_present,
            segments: Vec::with_capacity(self.segments.len() * frames),
        };
        for f in 0..frames {
            let off = f * n;
            for job in &self.jobs {
                let mut j = job.clone();
                for d in &mut j.deps {
                    *d += off;
                }
                out.jobs.push(j);
            }
            for (label, start) in &self.segments {
                out.segments.push((label.clone(), start + off));
            }
        }
        out
    }

    /// Active energy (mJ) of one job: its per-component charges integrated
    /// over its busy interval at its operating point — the same arithmetic
    /// [`JobGraph::finish_ledger`] feeds the [`EnergyLedger`], without the
    /// makespan-proportional leakage/standby terms. Cluster dynamic power
    /// is frequency-linear, so this is also exactly the energy of a
    /// co-resident (rescaled) execution of the job.
    fn job_active_mj(job: &Job) -> f64 {
        job.charges
            .iter()
            .map(|&(_, comp, mult)| PowerModel::active_mw(comp, job.op) * job.duration_s * mult)
            .sum()
    }

    /// Total active energy of the graph (mJ), schedule-independent.
    pub fn active_mj(&self) -> f64 {
        self.jobs.iter().map(Self::job_active_mj).sum()
    }

    /// Active energy per segment label, in first-appearance order; jobs
    /// pushed before the first marker are unattributed. Labels repeated
    /// across markers (e.g. one per streamed frame) aggregate into one row,
    /// and a segment whose marker is followed by no jobs still reports a
    /// zero row (its tenant must not vanish from attribution).
    pub fn segment_active_mj(&self) -> Vec<(String, f64)> {
        let mut out: Vec<(String, f64)> = Vec::new();
        let row_of = |out: &mut Vec<(String, f64)>, label: &str| -> usize {
            match out.iter().position(|(l, _)| l == label) {
                Some(i) => i,
                None => {
                    out.push((label.to_string(), 0.0));
                    out.len() - 1
                }
            }
        };
        let mut next = 0usize; // next marker to cross
        let mut current: Option<usize> = None; // index into `out`
        for (id, job) in self.jobs.iter().enumerate() {
            while next < self.segments.len() && self.segments[next].1 <= id {
                current = Some(row_of(&mut out, self.segments[next].0.as_str()));
                next += 1;
            }
            if let Some(cur) = current {
                out[cur].1 += Self::job_active_mj(job);
            }
        }
        // trailing markers past the last job
        for (label, _) in &self.segments[next..] {
            row_of(&mut out, label);
        }
        out
    }

    /// The supply voltage the graph runs at (jobs all share the builder's
    /// `ExecConfig`); nominal when the graph is empty.
    fn vdd(&self) -> f64 {
        self.jobs.first().map(|j| j.op.vdd).unwrap_or(V_NOM)
    }

    /// Integrate every job's charges plus makespan-proportional leakage and
    /// external-memory standby into a ledger whose elapsed time is
    /// `makespan_s`.
    fn finish_ledger(&self, makespan_s: f64) -> EnergyLedger {
        let mut ledger = EnergyLedger::new();
        for job in &self.jobs {
            for &(cat, comp, mult) in &job.charges {
                ledger.charge(cat, comp, job.op, job.duration_s * mult);
            }
        }
        // Leakage is mode-independent (it scales only with VDD), so one
        // charge over the makespan equals the per-phase charges of the
        // analytic model.
        let leak_op = OperatingPoint::new(OperatingMode::Sw, self.vdd());
        ledger.charge(Category::Idle, Component::ClusterLeak, leak_op, makespan_s);
        ledger.charge(Category::Idle, Component::SocLeak, leak_op, makespan_s);
        if self.ext_mem_present {
            ledger.charge_mj(Category::ExtMem, (FLASH_STANDBY_MW + FRAM_STANDBY_MW) * makespan_s);
        }
        ledger.advance(makespan_s);
        ledger
    }

    /// Per-engine total service time at the emission operating points
    /// (what the analytic replay uses; the scheduler reports *as-run*
    /// occupancy instead).
    fn busy_totals(&self) -> [f64; N_ENGINES] {
        let mut busy = [0.0; N_ENGINES];
        for job in &self.jobs {
            for &e in &job.engines {
                busy[e.index()] += job.duration_s;
            }
        }
        busy
    }

    /// The phase-summation reference model (the pre-scheduler coordinator):
    /// cluster jobs serialize in emission order with FLL relock on every
    /// mode change, while DMA/uDMA time accumulates in an I/O backlog that
    /// the cluster phases drain (double buffering); whatever backlog
    /// survives lands on the critical path at the end. This reproduces the
    /// analytic `Pipeline` numbers the Fig. 10/11/12 bands were calibrated
    /// against, and serves as the correctness reference for
    /// [`Scheduler::run`] (see `rust/tests/scheduler.rs`): the scheduled
    /// energy is pinned to it, and at the accelerated rungs the scheduled
    /// makespan must beat it via tile pipelining and co-residency.
    pub fn analytic(&self) -> SchedResult {
        let mut elapsed = 0.0f64;
        let mut backlog = 0.0f64;
        let mut last_mode: Option<OperatingMode> = None;
        let mut switches = 0u64;
        for job in &self.jobs {
            if job.mode_locked() {
                if last_mode != Some(job.op.mode) {
                    if last_mode.is_some() {
                        switches += 1;
                        elapsed += MODE_SWITCH_S;
                        backlog = (backlog - MODE_SWITCH_S).max(0.0);
                    }
                    last_mode = Some(job.op.mode);
                }
                elapsed += job.duration_s;
                backlog = (backlog - job.duration_s).max(0.0);
            } else {
                backlog += job.duration_s;
            }
        }
        elapsed += backlog;
        SchedResult {
            ledger: self.finish_ledger(elapsed),
            makespan_s: elapsed,
            mode_switches: switches,
            busy_s: self.busy_totals(),
            n_jobs: self.jobs.len(),
            overlap_s: 0.0,
            coresidency_s: 0.0,
        }
    }

    /// A true serialization upper bound on any schedule of this graph:
    /// every job back-to-back at the slowest point it could be hosted at
    /// (the all-capable CRY-CNN-SW clock for cluster jobs), plus one FLL
    /// relock per cluster job. The greedy scheduler never idles all
    /// engines outside a relock window, so [`Scheduler::run`] can never
    /// exceed this — the property `rust/tests/scheduler.rs` checks on
    /// random graphs.
    pub fn serialized_bound(&self) -> f64 {
        let mut total = 0.0f64;
        let mut cluster_jobs = 0u64;
        for job in &self.jobs {
            if job.mode_locked() {
                cluster_jobs += 1;
                total += job.duration_at(OperatingMode::CryCnnSw).max(job.duration_s);
            } else {
                total += job.duration_s;
            }
        }
        total + cluster_jobs as f64 * MODE_SWITCH_S
    }
}

/// Outcome of scheduling a [`JobGraph`].
#[derive(Debug, Clone)]
pub struct SchedResult {
    pub ledger: EnergyLedger,
    /// Completion time of the last job (simulated seconds).
    pub makespan_s: f64,
    /// FLL relocks performed.
    pub mode_switches: u64,
    /// Total busy time per engine, indexed by [`Engine::index`] — as-run
    /// occupancy for scheduled results, emission service time for the
    /// analytic replay.
    pub busy_s: [f64; N_ENGINES],
    pub n_jobs: usize,
    /// Simulated time during which ≥ 2 jobs were in flight at once (any
    /// engines) — the schedule's total overlap.
    pub overlap_s: f64,
    /// Simulated time during which ≥ 2 *cluster* jobs were in flight at
    /// once: CRY–CNN–SW co-residency made visible (0 for the analytic
    /// replay, which serializes the cluster by construction).
    pub coresidency_s: f64,
}

impl SchedResult {
    /// Busy fraction of an engine over the makespan (0 when empty).
    pub fn utilization(&self, e: Engine) -> f64 {
        if self.makespan_s > 0.0 {
            self.busy_s[e.index()] / self.makespan_s
        } else {
            0.0
        }
    }
}

/// Completion event: min-heap by time (ties broken by job id) on top of
/// `std`'s max-heap.
struct Ev {
    t: f64,
    job: JobId,
}

impl PartialEq for Ev {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t && self.job == other.job
    }
}

impl Eq for Ev {}

impl Ord for Ev {
    fn cmp(&self, other: &Self) -> Ordering {
        other.t.total_cmp(&self.t).then_with(|| other.job.cmp(&self.job))
    }
}

impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Busy interval of one dispatched job, for the overlap statistics.
struct Span {
    start: f64,
    end: f64,
    cluster: bool,
}

/// Sweep the job spans and integrate the time with ≥ 2 concurrent jobs
/// (overall, and restricted to cluster jobs).
fn overlap_stats(spans: &[Span]) -> (f64, f64) {
    let mut events: Vec<(f64, i32, i32)> = Vec::with_capacity(spans.len() * 2);
    for s in spans {
        if s.end > s.start {
            let c = s.cluster as i32;
            events.push((s.start, 1, c));
            events.push((s.end, -1, -c));
        }
    }
    events.sort_by(|a, b| a.0.total_cmp(&b.0));
    let (mut overlap, mut cores) = (0.0f64, 0.0f64);
    let (mut n_all, mut n_cluster) = (0i32, 0i32);
    let mut last_t = 0.0f64;
    for (t, d_all, d_cluster) in events {
        let dt = t - last_t;
        if dt > 0.0 {
            if n_all >= 2 {
                overlap += dt;
            }
            if n_cluster >= 2 {
                cores += dt;
            }
        }
        n_all += d_all;
        n_cluster += d_cluster;
        last_t = t;
    }
    (overlap, cores)
}

/// The event-driven scheduler. Stateless: all state lives on the run.
pub struct Scheduler;

impl Scheduler {
    /// Schedule `graph` to completion and return makespan, energy and
    /// per-engine statistics. Deterministic: dispatch prefers the
    /// lowest-id ready job, completion ties resolve by job id.
    pub fn run(graph: &JobGraph) -> SchedResult {
        let n = graph.jobs.len();
        let mut indeg: Vec<usize> = Vec::with_capacity(n);
        let mut children: Vec<Vec<JobId>> = vec![Vec::new(); n];
        for (id, job) in graph.jobs.iter().enumerate() {
            indeg.push(job.deps.len());
            for &d in &job.deps {
                children[d].push(id);
            }
        }
        let mut ready: BTreeSet<JobId> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut heap: BinaryHeap<Ev> = BinaryHeap::new();
        let mut engine_busy = [false; N_ENGINES];
        let mut busy = [0.0f64; N_ENGINES];
        let mut spans: Vec<Span> = Vec::with_capacity(n);
        let mut current_mode: Option<OperatingMode> = None;
        let mut mode_ready_at = 0.0f64;
        let mut mode_locked_running = 0usize;
        let mut switches = 0u64;
        let mut n_done = 0usize;
        let mut t = 0.0f64;
        let mut makespan = 0.0f64;

        loop {
            // Dispatch everything startable at time t, lowest job id first.
            loop {
                let lowest_ml_ready =
                    ready.iter().copied().find(|&j| graph.jobs[j].mode_locked());
                let mut pick: Option<(JobId, bool)> = None; // (job, switches mode)
                for &j in ready.iter() {
                    let job = &graph.jobs[j];
                    if job.engines.iter().any(|&e| engine_busy[e.index()]) {
                        continue;
                    }
                    if job.mode_locked() {
                        if let Some(c) = current_mode {
                            if Self::co_resident(c, job) {
                                pick = Some((j, false));
                                break;
                            }
                        }
                        // A mode switch is granted only to the lowest-id
                        // ready cluster job, and only once the cluster
                        // engines have drained.
                        if mode_locked_running == 0 && Some(j) == lowest_ml_ready {
                            pick = Some((j, true));
                            break;
                        }
                        continue;
                    }
                    pick = Some((j, false));
                    break;
                }
                let Some((j, switch)) = pick else { break };
                ready.remove(&j);
                let job = &graph.jobs[j];
                let mut start = t;
                let mut dur = job.duration_s;
                if job.mode_locked() {
                    if switch {
                        // Relock only on a genuine frequency change (the
                        // first mode entry is free).
                        if current_mode.is_some() && current_mode != Some(job.op.mode) {
                            switches += 1;
                            mode_ready_at = t + MODE_SWITCH_S;
                        }
                        current_mode = Some(job.op.mode);
                    } else {
                        // Co-resident dispatch: hosted at the cluster's
                        // current point, service time rescaled.
                        let c = current_mode.expect("co-resident dispatch without a mode");
                        dur = job.duration_at(c);
                    }
                    // The cluster sleeps while the FLL relocks.
                    start = start.max(mode_ready_at);
                    mode_locked_running += 1;
                }
                for &e in &job.engines {
                    engine_busy[e.index()] = true;
                    busy[e.index()] += dur;
                }
                spans.push(Span { start, end: start + dur, cluster: job.mode_locked() });
                heap.push(Ev { t: start + dur, job: j });
            }

            // Advance simulated time to the next completion.
            let Some(ev) = heap.pop() else { break };
            t = ev.t;
            makespan = makespan.max(t);
            let job = &graph.jobs[ev.job];
            for &e in &job.engines {
                engine_busy[e.index()] = false;
            }
            if job.mode_locked() {
                mode_locked_running -= 1;
            }
            n_done += 1;
            for &c in &children[ev.job] {
                indeg[c] -= 1;
                if indeg[c] == 0 {
                    ready.insert(c);
                }
            }
        }
        assert_eq!(n_done, n, "scheduler stalled: {n_done} of {n} jobs completed");

        let (overlap_s, coresidency_s) = overlap_stats(&spans);
        SchedResult {
            ledger: graph.finish_ledger(makespan),
            makespan_s: makespan,
            mode_switches: switches,
            busy_s: busy,
            n_jobs: n,
            overlap_s,
            coresidency_s,
        }
    }

    /// The co-residency rule: may `job` be hosted at current mode `c`
    /// without a mode switch? Equal modes always; a subsumed mode only
    /// when the frequency-rescale penalty is cheaper than the FLL relock
    /// a private mode window would cost.
    fn co_resident(c: OperatingMode, job: &Job) -> bool {
        if c == job.op.mode {
            return true;
        }
        if !c.supports(job.op.mode) {
            return false;
        }
        job.duration_at(c) - job.duration_s <= MODE_SWITCH_S
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(engine: Engine, mode: OperatingMode, duration_s: f64, deps: &[JobId]) -> Job {
        multi(vec![engine], mode, duration_s, deps)
    }

    fn multi(engines: Vec<Engine>, mode: OperatingMode, duration_s: f64, deps: &[JobId]) -> Job {
        Job {
            label: "test",
            engines,
            op: OperatingPoint::new(mode, 0.8),
            duration_s,
            deps: deps.to_vec(),
            charges: vec![(Category::OtherSw, Component::Core, 1.0)],
        }
    }

    #[test]
    fn engine_indices_are_dense_and_ordered() {
        for (i, e) in Engine::ALL.iter().enumerate() {
            assert_eq!(e.index(), i, "{}", e.name());
        }
        assert_eq!(N_ENGINES, 11);
        assert!(Engine::Core(3).mode_locked() && Engine::Hwce.mode_locked());
        assert!(!Engine::UdmaAdc.mode_locked() && !Engine::ClusterDma.mode_locked());
    }

    #[test]
    fn serial_chain_sums_durations() {
        let mut g = JobGraph::new();
        let a = g.push(job(Engine::Core(0), OperatingMode::Sw, 1.0, &[]));
        let b = g.push(job(Engine::Core(0), OperatingMode::Sw, 2.0, &[a]));
        g.push(job(Engine::Core(0), OperatingMode::Sw, 3.0, &[b]));
        let r = Scheduler::run(&g);
        assert!((r.makespan_s - 6.0).abs() < 1e-12);
        assert_eq!(r.mode_switches, 0);
        assert!((r.busy_s[Engine::Core(0).index()] - 6.0).abs() < 1e-12);
        assert_eq!(r.overlap_s, 0.0);
    }

    #[test]
    fn independent_engines_overlap() {
        let mut g = JobGraph::new();
        g.push(job(Engine::Core(0), OperatingMode::Sw, 2.0, &[]));
        g.push(job(Engine::UdmaFlash, OperatingMode::Sw, 1.5, &[]));
        g.push(job(Engine::UdmaFram, OperatingMode::Sw, 1.0, &[]));
        let r = Scheduler::run(&g);
        assert!((r.makespan_s - 2.0).abs() < 1e-12, "I/O must hide under compute");
        assert!((r.overlap_s - 1.5).abs() < 1e-12, "overlap {}", r.overlap_s);
    }

    #[test]
    fn same_engine_serializes() {
        let mut g = JobGraph::new();
        g.push(job(Engine::UdmaFram, OperatingMode::Sw, 1.0, &[]));
        g.push(job(Engine::UdmaFram, OperatingMode::Sw, 1.0, &[]));
        let r = Scheduler::run(&g);
        assert!((r.makespan_s - 2.0).abs() < 1e-12);
    }

    #[test]
    fn multi_engine_job_occupies_all_its_cores() {
        // a 2-core phase on {0,1} blocks a core-1 job but not a core-2 job
        let mut g = JobGraph::new();
        g.push(multi(
            vec![Engine::Core(0), Engine::Core(1)],
            OperatingMode::Sw,
            2.0,
            &[],
        ));
        g.push(job(Engine::Core(1), OperatingMode::Sw, 1.0, &[]));
        g.push(job(Engine::Core(2), OperatingMode::Sw, 1.0, &[]));
        let r = Scheduler::run(&g);
        assert!((r.makespan_s - 3.0).abs() < 1e-12, "core1 job must wait: {}", r.makespan_s);
        assert!((r.busy_s[Engine::Core(1).index()] - 3.0).abs() < 1e-12);
        assert!((r.busy_s[Engine::Core(2).index()] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn mode_switch_costs_relock() {
        let mut g = JobGraph::new();
        let a = g.push(job(Engine::Hwce, OperatingMode::KecCnnSw, 1.0, &[]));
        let b = g.push(job(Engine::HwcryptAes, OperatingMode::CryCnnSw, 1.0, &[a]));
        g.push(job(Engine::Hwce, OperatingMode::KecCnnSw, 1.0, &[b]));
        let r = Scheduler::run(&g);
        // a 1 s KEC job under the CRY clock would cost ≈0.22 s — far more
        // than the relock, so both boundaries pay the genuine switch
        assert_eq!(r.mode_switches, 2);
        assert!((r.makespan_s - (3.0 + 2.0 * MODE_SWITCH_S)).abs() < 1e-9);
    }

    #[test]
    fn long_incompatible_jobs_serialize_without_deps() {
        // No dependency between them, and hosting a 1 s KEC job at the CRY
        // clock would cost more than a relock — the shared cluster clock
        // serializes them.
        let mut g = JobGraph::new();
        g.push(job(Engine::Hwce, OperatingMode::KecCnnSw, 1.0, &[]));
        g.push(job(Engine::HwcryptAes, OperatingMode::CryCnnSw, 1.0, &[]));
        let r = Scheduler::run(&g);
        assert!(r.makespan_s >= 2.0, "mode exclusivity violated: {}", r.makespan_s);
        assert_eq!(r.mode_switches, 1);
        assert_eq!(r.coresidency_s, 0.0);
    }

    /// The co-residency rule: a short lower-capability job rides inside
    /// the current all-capable window instead of forcing a relock.
    #[test]
    fn short_subsumed_job_co_resides_free() {
        let mut g = JobGraph::new();
        g.push(job(Engine::HwcryptAes, OperatingMode::CryCnnSw, 1.0, &[]));
        let tiny = 1e-6; // rescale penalty ≈ 0.22 µs < 10 µs relock
        g.push(job(Engine::Hwce, OperatingMode::KecCnnSw, tiny, &[]));
        g.push(job(Engine::Core(2), OperatingMode::Sw, tiny, &[]));
        let r = Scheduler::run(&g);
        assert_eq!(r.mode_switches, 0, "subsumed jobs must not relock");
        assert!((r.makespan_s - 1.0).abs() < 1e-9, "makespan {}", r.makespan_s);
        assert!(r.coresidency_s > 0.0, "cluster co-residency must be visible");
        // hosted at the slower CRY clock, the KEC job's as-run busy time
        // stretches by the frequency ratio
        let hosted = tiny * OperatingMode::KecCnnSw.fmax_nominal_mhz()
            / OperatingMode::CryCnnSw.fmax_nominal_mhz();
        assert!((r.busy_s[Engine::Hwce.index()] - hosted).abs() < 1e-12);
    }

    /// A long subsumed job prefers its own mode window: the rescale
    /// penalty exceeds the relock, so it waits and switches.
    #[test]
    fn long_subsumed_job_takes_its_own_window() {
        let mut g = JobGraph::new();
        g.push(job(Engine::HwcryptAes, OperatingMode::CryCnnSw, 1.0, &[]));
        g.push(job(Engine::Core(0), OperatingMode::Sw, 1.0, &[]));
        let r = Scheduler::run(&g);
        assert_eq!(r.mode_switches, 1);
        assert!((r.makespan_s - (2.0 + MODE_SWITCH_S)).abs() < 1e-9);
    }

    #[test]
    fn same_mode_engines_do_overlap() {
        let mut g = JobGraph::new();
        g.push(job(Engine::Hwce, OperatingMode::KecCnnSw, 2.0, &[]));
        g.push(job(Engine::HwcryptKec, OperatingMode::KecCnnSw, 2.0, &[]));
        let r = Scheduler::run(&g);
        assert!((r.makespan_s - 2.0).abs() < 1e-12);
        assert_eq!(r.mode_switches, 0);
        assert!((r.coresidency_s - 2.0).abs() < 1e-12);
    }

    #[test]
    fn first_mode_entry_is_free() {
        let mut g = JobGraph::new();
        g.push(job(Engine::Hwce, OperatingMode::KecCnnSw, 1.0, &[]));
        let r = Scheduler::run(&g);
        assert_eq!(r.mode_switches, 0);
        assert!((r.makespan_s - 1.0).abs() < 1e-12);
    }

    #[test]
    fn analytic_matches_run_on_serial_cluster_graph() {
        let mut g = JobGraph::new();
        let mut prev: Option<JobId> = None;
        for i in 0..6 {
            let mode = if i % 2 == 0 { OperatingMode::KecCnnSw } else { OperatingMode::CryCnnSw };
            let engine = if i % 2 == 0 { Engine::Hwce } else { Engine::HwcryptAes };
            let deps: Vec<JobId> = prev.into_iter().collect();
            prev = Some(g.push(job(engine, mode, 0.5, &deps)));
        }
        let run = Scheduler::run(&g);
        let ana = g.analytic();
        assert!((run.makespan_s - ana.makespan_s).abs() < 1e-9);
        assert_eq!(run.mode_switches, ana.mode_switches);
        assert!((run.ledger.total_mj() - ana.ledger.total_mj()).abs() < 1e-9);
    }

    #[test]
    fn analytic_hides_io_behind_compute() {
        let mut g = JobGraph::new();
        g.push(job(Engine::UdmaFram, OperatingMode::Sw, 1.0, &[]));
        g.push(job(Engine::Core(0), OperatingMode::Sw, 3.0, &[]));
        let ana = g.analytic();
        assert!((ana.makespan_s - 3.0).abs() < 1e-12);
        // I/O-dominated: the surplus lands on the critical path.
        let mut g2 = JobGraph::new();
        g2.push(job(Engine::UdmaFram, OperatingMode::Sw, 5.0, &[]));
        g2.push(job(Engine::Core(0), OperatingMode::Sw, 3.0, &[]));
        let ana2 = g2.analytic();
        assert!((ana2.makespan_s - 5.0).abs() < 1e-12);
    }

    #[test]
    fn repeat_streams_through_shared_engines() {
        // frame: long compute + short store that depends on it
        let mut g = JobGraph::new();
        let c = g.push(job(Engine::Core(0), OperatingMode::Sw, 2.0, &[]));
        g.push(job(Engine::UdmaFram, OperatingMode::Sw, 1.0, &[c]));
        let single = Scheduler::run(&g);
        assert!((single.makespan_s - 3.0).abs() < 1e-12);
        let four = Scheduler::run(&g.repeat(4));
        // stores of frame f overlap compute of frame f+1: 4×2 + trailing 1
        assert!((four.makespan_s - 9.0).abs() < 1e-12, "stream {}", four.makespan_s);
        assert!(four.makespan_s < 4.0 * single.makespan_s);
    }

    #[test]
    fn streaming_never_slower_than_serial_frames() {
        let mut g = JobGraph::new();
        let a = g.push(job(Engine::Hwce, OperatingMode::KecCnnSw, 0.3, &[]));
        let b = g.push(job(Engine::HwcryptAes, OperatingMode::CryCnnSw, 0.2, &[a]));
        g.push(job(Engine::UdmaFram, OperatingMode::Sw, 0.4, &[b]));
        let single = Scheduler::run(&g).makespan_s;
        for frames in [2usize, 5] {
            let stream = Scheduler::run(&g.repeat(frames)).makespan_s;
            assert!(
                stream <= frames as f64 * single + 1e-9,
                "{frames} frames: {stream} > {}",
                frames as f64 * single
            );
        }
    }

    #[test]
    fn busy_never_exceeds_makespan() {
        let mut g = JobGraph::new();
        let mut prev = Vec::new();
        for i in 0..22 {
            let e = Engine::ALL[i % N_ENGINES];
            let deps: Vec<JobId> = prev.clone();
            prev = vec![g.push(job(e, OperatingMode::Sw, 0.01 * (i + 1) as f64, &deps))];
        }
        let r = Scheduler::run(&g);
        for e in Engine::ALL {
            assert!(r.busy_s[e.index()] <= r.makespan_s + 1e-9, "{}", e.name());
        }
        let total: f64 = r.busy_s.iter().sum();
        assert!(total <= r.makespan_s * N_ENGINES as f64 + 1e-9);
        assert!(r.makespan_s <= g.serialized_bound() + 1e-9);
    }

    #[test]
    fn serialized_bound_holds_with_coresidency_and_switches() {
        let mut g = JobGraph::new();
        g.push(job(Engine::HwcryptAes, OperatingMode::CryCnnSw, 0.5, &[]));
        g.push(job(Engine::Hwce, OperatingMode::KecCnnSw, 1e-6, &[]));
        g.push(job(Engine::Core(0), OperatingMode::Sw, 0.4, &[]));
        g.push(job(Engine::Hwce, OperatingMode::KecCnnSw, 0.3, &[]));
        g.push(job(Engine::UdmaFram, OperatingMode::Sw, 0.2, &[]));
        let r = Scheduler::run(&g);
        assert!(r.makespan_s <= g.serialized_bound() + 1e-9);
    }

    #[test]
    fn segments_attribute_active_energy() {
        let mut g = JobGraph::new();
        g.mark_segment("a");
        g.push(job(Engine::Core(0), OperatingMode::Sw, 2.0, &[]));
        g.mark_segment("b");
        g.push(job(Engine::Core(0), OperatingMode::Sw, 1.0, &[]));
        g.mark_segment("empty"); // trailing marker with no jobs
        let seg = g.segment_active_mj();
        assert_eq!(seg.len(), 3);
        assert_eq!(seg[0].0, "a");
        assert_eq!(seg[1].0, "b");
        assert_eq!(seg[2], ("empty".to_string(), 0.0), "empty tenants keep a zero row");
        assert!((seg[0].1 - 2.0 * seg[1].1).abs() < 1e-12, "a charges 2x b's interval");
        let total: f64 = seg.iter().map(|(_, mj)| mj).sum();
        assert!((total - g.active_mj()).abs() < 1e-12);
        // streaming re-marks each frame's segments and aggregates by label
        let g4 = g.repeat(4);
        assert_eq!(g4.segments.len(), 12);
        let seg4 = g4.segment_active_mj();
        assert_eq!(seg4.len(), 3, "labels aggregate across frames");
        assert!((seg4[0].1 - 4.0 * seg[0].1).abs() < 1e-12);
    }

    #[test]
    fn empty_graph_is_trivial() {
        let g = JobGraph::new();
        let r = Scheduler::run(&g);
        assert_eq!(r.makespan_s, 0.0);
        assert_eq!(r.n_jobs, 0);
        assert_eq!(r.ledger.total_mj(), 0.0);
        assert_eq!(r.overlap_s, 0.0);
    }

    #[test]
    #[should_panic(expected = "not-yet-pushed")]
    fn forward_dependency_rejected() {
        let mut g = JobGraph::new();
        g.push(job(Engine::Core(0), OperatingMode::Sw, 1.0, &[3]));
    }

    #[test]
    #[should_panic(expected = "occupies no engine")]
    fn engineless_job_rejected() {
        let mut g = JobGraph::new();
        g.push(multi(vec![], OperatingMode::Sw, 1.0, &[]));
    }

    #[test]
    fn energy_charges_integrate_at_op() {
        use crate::soc::power::PowerModel;
        let mut g = JobGraph::new();
        g.push(job(Engine::Core(0), OperatingMode::Sw, 2.0, &[]));
        let r = Scheduler::run(&g);
        let op = OperatingPoint::new(OperatingMode::Sw, 0.8);
        let expect = PowerModel::active_mw(Component::Core, op) * 2.0;
        assert!((r.ledger.energy_mj(Category::OtherSw) - expect).abs() < 1e-9);
        // leakage charged over the makespan
        assert!(r.ledger.energy_mj(Category::Idle) > 0.0);
    }

    /// Rescaled co-resident execution leaves active energy untouched:
    /// cluster dynamic power is frequency-linear, so P·t is invariant.
    #[test]
    fn coresident_rescale_preserves_active_energy() {
        let mut g = JobGraph::new();
        g.push(job(Engine::HwcryptAes, OperatingMode::CryCnnSw, 1.0, &[]));
        g.push(job(Engine::Hwce, OperatingMode::KecCnnSw, 1e-6, &[]));
        let run = Scheduler::run(&g);
        let ana = g.analytic();
        let a = run.ledger.energy_mj(Category::OtherSw);
        let b = ana.ledger.energy_mj(Category::OtherSw);
        assert!((a - b).abs() < 1e-12 * (1.0 + a.abs()), "{a} vs {b}");
    }
}
