//! Event-driven SoC scheduler: the whole chip as a set of [`Engine`]
//! resources consuming typed [`Job`]s from a dependency graph.
//!
//! The coordinator use cases (§IV) *emit* a [`JobGraph`] — convolutions,
//! cipher runs, software phases, DMA and external-memory transfers with
//! their data dependencies — and [`Scheduler::run`] advances simulated time
//! through a binary-heap event queue, dispatching each job as soon as its
//! dependencies have completed, its engines are free, and the cluster
//! operating point admits it. Cross-engine concurrency (double-buffered
//! DMA, uDMA I/O under compute, HWCRYPT decrypting the next tile's weights
//! while the HWCE convolves the current one, SW epilogues on the cores
//! under both) falls out of the schedule instead of being approximated by
//! an analytic overlap term.
//!
//! ## Engines
//!
//! One entry per serially-busy resource of the Fulmine SoC: the four OR10N
//! cluster cores — *individually*, [`Engine::Core`]`(0..4)`, so software
//! phases, accelerator-control stubs and epilogues contend per core the
//! way the TCDM masters do — the HWCE, the two HWCRYPT datapaths, the
//! cluster DMA, and one uDMA channel per external interface (flash, FRAM,
//! and the ADC front end; the uDMA serves its peripherals on independent
//! channels, §II). A job may span several engines at once (a 4-core
//! software phase occupies four `Core` engines for one interval).
//!
//! ## Operating modes and co-residency
//!
//! The cluster-domain engines (cores + accelerators) share one clock and
//! one operating mode (§III-A). Jobs carry the [`OperatingPoint`] they
//! were *emitted* for; at dispatch the cluster is at some current mode and
//! the co-residency rule applies:
//!
//! * a job whose mode equals the current mode dispatches immediately —
//!   same clock, no cost;
//! * a job whose mode is *subsumed* by the current mode
//!   ([`OperatingMode::supports`]: the CRY-CNN-SW point is all-capable,
//!   KEC-CNN-SW hosts KEC/CNN/SW work, SW only SW) may co-reside: it runs
//!   at the current — lower — clock, its service time rescaled by the
//!   frequency ratio. The scheduler accepts this only when the slowdown
//!   costs less than the 10 µs FLL relock a private mode window would
//!   (tiny epilogue slivers and cipher-control stubs ride along free;
//!   long software phases get their own window);
//! * otherwise the job waits for the cluster to drain, and the relock
//!   ([`MODE_SWITCH_S`]) is charged only on a *genuine* frequency change.
//!   A switch is granted to the lowest-id ready cluster job, keeping the
//!   mode sequence in program order.
//!
//! SOC-domain engines (cluster DMA, uDMA channels) run in any mode — the
//! uDMA works "even when the cluster is in sleep mode" (§II). The movers
//! whose service time is *clock-derived* (cluster DMA, the ADC burst
//! channel — bytes per AXI cycle; [`Engine::clock_scaled`]) follow the
//! cluster point live at dispatch: hosted under a slower co-resident point
//! they rescale by the frequency ratio exactly like cluster jobs do,
//! instead of being pinned at their emission-mode clock. The flash/FRAM
//! channels stay bound by the external device's bandwidth.
//!
//! ## Dispatch (indexed)
//!
//! The ready set is partitioned: non-cluster jobs wait in **per-engine
//! ready queues** (ordered by job id; only the queues of *free* engines
//! are consulted, and in the single-engine common case only their heads),
//! and mode-locked cluster jobs in a separate ordered set that is scanned
//! under the co-residency rules — with the pick pruned by the best
//! I/O candidate's id. Dispatch cost therefore tracks the number of
//! *startable* jobs (bounded by the engines and the in-flight window),
//! not the total pending backlog: a 4096-frame stream keeps thousands of
//! prefetchable uDMA transfers queued without the scheduler rescanning
//! them on every event. The pick rule is unchanged — the lowest-id
//! startable job wins — and [`Scheduler::run_scan`] keeps the original
//! linear-scan dispatcher as a bitwise parity reference (asserted on
//! random graphs and every use-case rung in `rust/tests/scheduler.rs`).
//!
//! ## Energy
//!
//! Each job lists per-component charges; the busy interval is integrated
//! on the [`EnergyLedger`] at the job's *emission* operating point.
//! Because cluster dynamic power is linear in frequency at fixed VDD
//! ([`PowerModel`]), a rescaled co-resident job consumes exactly the same
//! active energy as at its own point (P·t = pJ/cycle × cycles), so active
//! energy stays schedule-independent; only the makespan-proportional
//! Idle/standby terms (≈1.5 mW) vary with the schedule — which keeps
//! scheduled results within a few percent of [`JobGraph::analytic`], the
//! phase-summation model the figures of the paper were calibrated against.
//!
//! ## Streaming
//!
//! [`JobGraph::repeat`] concatenates N copies of a frame graph (dependency
//! edges stay within each frame) — the *materialized* path, kept for
//! small-N parity tests. The production streaming path is the
//! [`StreamScheduler`]: it admits frame instances of the template graph
//! into a rolling window of at most K in-flight frames (K is clamped to
//! the stream length — a window wider than the stream cannot fill),
//! retiring completed frames and recycling their dependency-tracking
//! slots — O(window × jobs) live state instead of O(frames × jobs), with
//! per-frame energy accumulated incrementally and the overlap statistics
//! swept online. With K ≥ frames the windowed schedule reproduces the
//! materialized one *bitwise* (same admission order, same dispatch
//! decisions — a property test pins this); smaller windows bound memory at
//! a possible makespan cost once the window is tighter than the pipeline
//! depth. Either way frame *f+1*'s I/O and accelerator phases fill the
//! stalls of frame *f*, which is where the multi-frame throughput of
//! `fulmine stream` comes from.
//!
//! ## Compiled frame templates
//!
//! The execution core does not chase `Vec<Engine>`/`Vec<JobId>` pointers
//! per job: a [`CompiledFrame`] lowers the template once into flat
//! struct-of-arrays form — an engine *bitmask* per job (conflict check =
//! one `AND` against the busy mask), CSR successor arrays, per-job
//! mode/duration tables, and the per-frame energy charges prefolded to
//! `(category, mJ)` rows so admission is a tight add loop. Compilation
//! changes no arithmetic: every float the core produces is the same
//! expression the job-structure path evaluated, so results stay bitwise
//! identical to the [`Scheduler::run_scan`] reference.
//!
//! ## Steady-state fast-forward
//!
//! A long stream of identical frames settles into a periodic schedule.
//! While streaming, the core records each *admission cycle* (the dispatch/
//! completion/retire/admit decisions between consecutive admissions) in
//! frame-relative form and watches for a period-*k* repeat (k ≤ 4): when
//! the last cycles repeat and the frame-relative scheduler state is a
//! verified fixpoint across one period, the core switches to **replay** —
//! it executes the recorded decision sequence directly, with no ready
//! queues, no dependency counting and no dispatch search, verifying at
//! every completion that the event order still matches (the ≤ #engines
//! in-flight jobs make that a trivial scan). Replay performs *the same
//! float operations in the same order* as live execution would, so the
//! result is bitwise identical — this is re-derived, not assumed: any
//! mismatch rolls the cycle back and falls back to live execution, and
//! [`SchedResult::fast_forwarded_frames`] reports how much of the stream
//! was replayed. Per-frame template *variants*
//! ([`StreamScheduler::run_with_variants`]) suspend fast-forward around
//! the divergent frames and re-engage after they retire.

use crate::energy::{Category, EnergyLedger};
use crate::soc::opmodes::{OperatingMode, OperatingPoint, MODE_SWITCH_S, V_NOM};
use crate::soc::pm::{self, PolicyKind};
use crate::soc::power::{Component, PowerModel, FLASH_STANDBY_MW, FRAM_STANDBY_MW};
use crate::traffic::Perturb;
use std::cmp::Ordering;
use std::collections::{BTreeSet, BinaryHeap, VecDeque};

/// Cluster cores (OR10N complex).
pub const N_CORES: usize = 4;

/// Default in-flight frame window of the streaming path (see
/// [`StreamScheduler`]): deep enough that adjacent-frame pipelining is
/// never clipped for the §IV use cases, small enough that a 100 000-frame
/// stream holds only a few thousand live jobs.
pub const DEFAULT_STREAM_WINDOW: usize = 8;

/// A serially-busy hardware resource of the SoC.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Engine {
    /// One OR10N cluster core (0..[`N_CORES`]). Modeling the cores as
    /// separate masters lets accelerator control and SW epilogues share
    /// the complex instead of folding into one aggregate resource.
    Core(u8),
    /// HWCE convolution engine.
    Hwce,
    /// HWCRYPT AES datapath.
    HwcryptAes,
    /// HWCRYPT KECCAK sponge datapath.
    HwcryptKec,
    /// Cluster DMA (L2 ↔ TCDM).
    ClusterDma,
    /// uDMA channel serving the quad-SPI flash.
    UdmaFlash,
    /// uDMA channel serving the FRAM.
    UdmaFram,
    /// uDMA channel serving the sensor/ADC front end (§IV-C acquisition).
    UdmaAdc,
}

/// Number of scheduled engines.
pub const N_ENGINES: usize = Engine::ALL.len();

impl Engine {
    /// Every engine, in [`Engine::index`] order.
    pub const ALL: [Engine; 11] = [
        Engine::Core(0),
        Engine::Core(1),
        Engine::Core(2),
        Engine::Core(3),
        Engine::Hwce,
        Engine::HwcryptAes,
        Engine::HwcryptKec,
        Engine::ClusterDma,
        Engine::UdmaFlash,
        Engine::UdmaFram,
        Engine::UdmaAdc,
    ];

    /// Dense index for per-engine arrays (matches the position in
    /// [`Engine::ALL`]).
    pub fn index(self) -> usize {
        match self {
            Engine::Core(i) => {
                // unconditional: an out-of-range core would alias another
                // engine's dense index and silently corrupt its accounting
                assert!((i as usize) < N_CORES, "core index {i} out of range");
                i as usize
            }
            Engine::Hwce => N_CORES,
            Engine::HwcryptAes => N_CORES + 1,
            Engine::HwcryptKec => N_CORES + 2,
            Engine::ClusterDma => N_CORES + 3,
            Engine::UdmaFlash => N_CORES + 4,
            Engine::UdmaFram => N_CORES + 5,
            Engine::UdmaAdc => N_CORES + 6,
        }
    }

    /// Cluster-domain engines share the cluster clock and therefore the
    /// operating mode; SOC-domain movers do not.
    pub fn mode_locked(self) -> bool {
        matches!(
            self,
            Engine::Core(_) | Engine::Hwce | Engine::HwcryptAes | Engine::HwcryptKec
        )
    }

    /// SOC-domain movers whose service time is derived from the cluster/AXI
    /// clock (bytes per cycle): they follow the *hosting* cluster point at
    /// dispatch instead of staying pinned at their emission-mode clock.
    /// The flash/FRAM uDMA channels are external-device-bandwidth bound and
    /// do not rescale.
    pub fn clock_scaled(self) -> bool {
        matches!(self, Engine::ClusterDma | Engine::UdmaAdc)
    }

    pub fn name(self) -> &'static str {
        match self {
            Engine::Core(0) => "core0",
            Engine::Core(1) => "core1",
            Engine::Core(2) => "core2",
            Engine::Core(3) => "core3",
            Engine::Core(_) => "core?",
            Engine::Hwce => "hwce",
            Engine::HwcryptAes => "hwcrypt-aes",
            Engine::HwcryptKec => "hwcrypt-kec",
            Engine::ClusterDma => "cluster-dma",
            Engine::UdmaFlash => "udma-flash",
            Engine::UdmaFram => "udma-fram",
            Engine::UdmaAdc => "udma-adc",
        }
    }
}

/// Identifier of a job within its [`JobGraph`] (its insertion index).
pub type JobId = usize;

/// One unit of work bound to one or more engines: a service time at an
/// operating point, dependencies on earlier jobs, and the energy charges
/// to integrate over the busy interval (`(category, component,
/// multiplicity)` — e.g. a 4-core software phase occupies
/// `Core(0)..Core(3)` and charges `Component::Core` with multiplicity 4).
#[derive(Debug, Clone)]
pub struct Job {
    pub label: &'static str,
    /// Engines this job occupies for its whole busy interval (≥ 1,
    /// distinct). Multi-engine jobs model phases that hold several cores
    /// at once.
    pub engines: Vec<Engine>,
    pub op: OperatingPoint,
    /// Service time at `op`; a co-resident dispatch at a slower compatible
    /// point rescales it by the frequency ratio.
    pub duration_s: f64,
    pub deps: Vec<JobId>,
    pub charges: Vec<(Category, Component, f64)>,
}

impl Job {
    /// Whether this job runs in the cluster clock domain (any of its
    /// engines is mode-locked).
    pub fn mode_locked(&self) -> bool {
        self.engines.iter().any(|e| e.mode_locked())
    }

    /// Whether this job's service time follows the cluster clock live at
    /// dispatch even though it is not mode-locked (the clock-derived SOC
    /// movers — see [`Engine::clock_scaled`]).
    pub fn clock_scaled(&self) -> bool {
        !self.mode_locked() && self.engines.iter().all(|e| e.clock_scaled())
    }

    /// Service time when hosted at cluster mode `at` (its own time at its
    /// own mode; stretched by the frequency ratio under a slower
    /// compatible point).
    fn duration_at(&self, at: OperatingMode) -> f64 {
        hosted_duration(self.duration_s, self.op, at)
    }
}

/// A dependency graph of jobs. Acyclic by construction: dependencies must
/// point at already-pushed jobs.
#[derive(Debug, Clone)]
pub struct JobGraph {
    pub jobs: Vec<Job>,
    /// Whether external flash/FRAM are attached (their standby power is
    /// charged over the whole run); the pacemaker-class seizure platform
    /// has none (§IV-C).
    pub ext_mem_present: bool,
    /// Interned segment label table, in first-marker order — markers
    /// reference labels by index so streaming repetition copies no
    /// strings (see [`JobGraph::mark_segment`]).
    pub segment_labels: Vec<String>,
    /// Named segment markers `(label index, first job id)`. Empty for
    /// single-tenant graphs.
    pub segments: Vec<(u32, JobId)>,
}

impl Default for JobGraph {
    fn default() -> Self {
        Self::new()
    }
}

impl JobGraph {
    pub fn new() -> Self {
        JobGraph {
            jobs: Vec::new(),
            ext_mem_present: true,
            segment_labels: Vec::new(),
            segments: Vec::new(),
        }
    }

    /// Open a named segment at the current end of the graph: jobs pushed
    /// from here until the next marker belong to `label`. Multi-tenant
    /// workloads use this to attribute active energy per tenant
    /// ([`JobGraph::segment_active_mj`]); repeating the same label
    /// aggregates (each streamed frame re-marks its tenants) and interns
    /// it — the marker list holds indices, never cloned strings.
    pub fn mark_segment(&mut self, label: &str) {
        let idx = match self.segment_labels.iter().position(|l| l == label) {
            Some(i) => i,
            None => {
                self.segment_labels.push(label.to_string());
                self.segment_labels.len() - 1
            }
        };
        self.segments.push((idx as u32, self.jobs.len()));
    }

    /// Append a job; its dependencies must reference earlier jobs, its
    /// engine set must be non-empty and duplicate-free, and all jobs of a
    /// graph must share one supply voltage (leakage is charged graph-wide
    /// at the first job's VDD).
    pub fn push(&mut self, job: Job) -> JobId {
        let id = self.jobs.len();
        assert!(!job.engines.is_empty(), "job {id} occupies no engine");
        debug_assert!(
            {
                let mut seen = [false; N_ENGINES];
                job.engines.iter().all(|e| !std::mem::replace(&mut seen[e.index()], true))
            },
            "job {id} lists an engine twice"
        );
        for &d in &job.deps {
            assert!(d < id, "job {id} depends on not-yet-pushed job {d}");
        }
        if let Some(first) = self.jobs.first() {
            debug_assert!(
                job.op.vdd == first.op.vdd,
                "job {id} at {} V in a {} V graph — one graph, one supply",
                job.op.vdd,
                first.op.vdd
            );
        }
        self.jobs.push(job);
        id
    }

    pub fn len(&self) -> usize {
        self.jobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }

    /// Concatenate `frames` copies of this graph (streaming): dependency
    /// edges stay within each copy; pipelining across copies comes from the
    /// shared engines at schedule time. This materializes O(frames × jobs)
    /// state — the bounded-memory path is [`StreamScheduler::run`] on the
    /// single-frame template; `repeat` survives as its small-N parity
    /// reference.
    pub fn repeat(&self, frames: usize) -> JobGraph {
        let n = self.jobs.len();
        let mut out = JobGraph {
            jobs: Vec::with_capacity(n * frames),
            ext_mem_present: self.ext_mem_present,
            segment_labels: self.segment_labels.clone(),
            segments: Vec::with_capacity(self.segments.len() * frames),
        };
        for f in 0..frames {
            let off = f * n;
            for job in &self.jobs {
                let mut j = job.clone();
                for d in &mut j.deps {
                    *d += off;
                }
                out.jobs.push(j);
            }
            for &(label, start) in &self.segments {
                out.segments.push((label, start + off));
            }
        }
        out
    }

    /// Active energy (mJ) of one job: its per-component charges integrated
    /// over its busy interval at its operating point — the same arithmetic
    /// [`JobGraph::charge_active_into`] feeds the [`EnergyLedger`], without
    /// the makespan-proportional leakage/standby terms. Cluster dynamic
    /// power is frequency-linear, so this is also exactly the energy of a
    /// co-resident (rescaled) execution of the job. `pub(crate)` so the
    /// session layer can split a variant's energy into handshake vs
    /// record portions by job label ([`crate::session`]).
    pub(crate) fn job_active_mj(job: &Job) -> f64 {
        job.charges
            .iter()
            .map(|&(_, comp, mult)| PowerModel::active_mw(comp, job.op) * job.duration_s * mult)
            .sum()
    }

    /// Total active energy of the graph (mJ), schedule-independent.
    pub fn active_mj(&self) -> f64 {
        self.jobs.iter().map(Self::job_active_mj).sum()
    }

    /// Active energy per segment label, in first-marker order; jobs pushed
    /// before the first marker are unattributed. Labels repeated across
    /// markers (e.g. one per streamed frame) aggregate into one row via
    /// the interned label index — O(jobs + markers), no per-marker label
    /// search — and a segment whose marker is followed by no jobs still
    /// reports a zero row (its tenant must not vanish from attribution).
    pub fn segment_active_mj(&self) -> Vec<(String, f64)> {
        let mut rows = vec![0.0f64; self.segment_labels.len()];
        let mut next = 0usize; // next marker to cross
        let mut current: Option<usize> = None; // index into `rows`
        for (id, job) in self.jobs.iter().enumerate() {
            while next < self.segments.len() && self.segments[next].1 <= id {
                current = Some(self.segments[next].0 as usize);
                next += 1;
            }
            if let Some(cur) = current {
                rows[cur] += Self::job_active_mj(job);
            }
        }
        // trailing markers past the last job already have their zero rows
        self.segment_labels.iter().cloned().zip(rows).collect()
    }

    /// The supply voltage the graph runs at (jobs all share the builder's
    /// `ExecConfig`); nominal when the graph is empty.
    fn vdd(&self) -> f64 {
        self.jobs.first().map(|j| j.op.vdd).unwrap_or(V_NOM)
    }

    /// Integrate every job's per-component charges at its emission
    /// operating point into `ledger` — the schedule-independent active
    /// energy. The streaming path calls this once per admitted frame, so
    /// the accumulation order (frame-major, job order) is identical to
    /// [`JobGraph::finish_ledger`] over a [`JobGraph::repeat`] graph and
    /// the sums match bitwise.
    fn charge_active_into(&self, ledger: &mut EnergyLedger) {
        for job in &self.jobs {
            for &(cat, comp, mult) in &job.charges {
                ledger.charge(cat, comp, job.op, job.duration_s * mult);
            }
        }
    }

    /// The makespan-proportional terms: leakage and external-memory
    /// standby over `makespan_s`, plus the elapsed-time advance.
    fn charge_overheads_into(&self, ledger: &mut EnergyLedger, makespan_s: f64) {
        charge_overheads(ledger, self.vdd(), self.ext_mem_present, makespan_s);
    }

    /// Integrate every job's charges plus makespan-proportional leakage and
    /// external-memory standby into a ledger whose elapsed time is
    /// `makespan_s`.
    fn finish_ledger(&self, makespan_s: f64) -> EnergyLedger {
        let mut ledger = EnergyLedger::new();
        self.charge_active_into(&mut ledger);
        self.charge_overheads_into(&mut ledger, makespan_s);
        ledger
    }

    /// Per-engine total service time at the emission operating points
    /// (what the analytic replay uses; the scheduler reports *as-run*
    /// occupancy instead).
    fn busy_totals(&self) -> [f64; N_ENGINES] {
        let mut busy = [0.0; N_ENGINES];
        for job in &self.jobs {
            for &e in &job.engines {
                busy[e.index()] += job.duration_s;
            }
        }
        busy
    }

    /// The phase-summation reference model (the pre-scheduler coordinator):
    /// cluster jobs serialize in emission order with FLL relock on every
    /// mode change, while DMA/uDMA time accumulates in an I/O backlog that
    /// the cluster phases drain (double buffering); whatever backlog
    /// survives lands on the critical path at the end. This reproduces the
    /// analytic `Pipeline` numbers the Fig. 10/11/12 bands were calibrated
    /// against, and serves as the correctness reference for
    /// [`Scheduler::run`] (see `rust/tests/scheduler.rs`): the scheduled
    /// energy is pinned to it, and at the accelerated rungs the scheduled
    /// makespan must beat it via tile pipelining and co-residency.
    pub fn analytic(&self) -> SchedResult {
        let mut elapsed = 0.0f64;
        let mut backlog = 0.0f64;
        let mut last_mode: Option<OperatingMode> = None;
        let mut switches = 0u64;
        for job in &self.jobs {
            if job.mode_locked() {
                if last_mode != Some(job.op.mode) {
                    if last_mode.is_some() {
                        switches += 1;
                        elapsed += MODE_SWITCH_S;
                        backlog = (backlog - MODE_SWITCH_S).max(0.0);
                    }
                    last_mode = Some(job.op.mode);
                }
                elapsed += job.duration_s;
                backlog = (backlog - job.duration_s).max(0.0);
            } else {
                backlog += job.duration_s;
            }
        }
        elapsed += backlog;
        SchedResult {
            ledger: self.finish_ledger(elapsed),
            makespan_s: elapsed,
            mode_switches: switches,
            busy_s: self.busy_totals(),
            n_jobs: self.jobs.len(),
            overlap_s: 0.0,
            coresidency_s: 0.0,
            peak_resident_jobs: self.jobs.len(),
            fast_forwarded_frames: 0,
            sleep_s: 0.0,
            deep_sleep_s: 0.0,
            wake_transitions: 0,
            frames_dropped: 0,
            fault_retries: 0,
            chip_resets: 0,
            state_loss_frames: 0,
            recovery_energy_mj: 0.0,
        }
    }

    /// A true serialization upper bound on any schedule of this graph:
    /// every job back-to-back at the slowest point it could be hosted at
    /// (the all-capable CRY-CNN-SW clock for cluster jobs *and* for the
    /// clock-scaled SOC movers, which may be hosted there too), plus one
    /// FLL relock per cluster job. The greedy scheduler never idles all
    /// engines outside a relock window — windowed admission included,
    /// since retirement and admission happen eagerly at completion events
    /// — so neither [`Scheduler::run`] nor [`StreamScheduler::run`] can
    /// exceed this; the property `rust/tests/scheduler.rs` checks on
    /// random graphs.
    pub fn serialized_bound(&self) -> f64 {
        let mut total = 0.0f64;
        let mut cluster_jobs = 0u64;
        for job in &self.jobs {
            if job.mode_locked() {
                cluster_jobs += 1;
                total += job.duration_at(OperatingMode::CryCnnSw).max(job.duration_s);
            } else if job.clock_scaled() {
                total += job.duration_at(OperatingMode::CryCnnSw).max(job.duration_s);
            } else {
                total += job.duration_s;
            }
        }
        total + cluster_jobs as f64 * MODE_SWITCH_S
    }
}

/// The makespan-proportional ledger terms shared by the job-structure and
/// compiled paths: leakage and external-memory standby over `makespan_s`,
/// plus the elapsed-time advance. Leakage is mode-independent (it scales
/// only with VDD), so one charge over the makespan equals the per-phase
/// charges of the analytic model.
fn charge_overheads(ledger: &mut EnergyLedger, vdd: f64, ext_mem_present: bool, makespan_s: f64) {
    let leak_op = OperatingPoint::new(OperatingMode::Sw, vdd);
    ledger.charge(Category::Idle, Component::ClusterLeak, leak_op, makespan_s);
    ledger.charge(Category::Idle, Component::SocLeak, leak_op, makespan_s);
    if ext_mem_present {
        ledger.charge_mj(Category::ExtMem, (FLASH_STANDBY_MW + FRAM_STANDBY_MW) * makespan_s);
    }
    ledger.advance(makespan_s);
}

/// Service time of a job emitted for `op` when hosted at cluster mode `at`
/// (its own time at its own mode; stretched by the frequency ratio under a
/// slower compatible point). The single expression both the job-structure
/// and the compiled paths evaluate — bitwise-identical by construction.
fn hosted_duration(duration_s: f64, op: OperatingPoint, at: OperatingMode) -> f64 {
    if at == op.mode {
        duration_s
    } else {
        duration_s * op.freq_hz() / OperatingPoint::new(at, op.vdd).freq_hz()
    }
}

/// Dense index of a breakdown category in [`Category::all`] order — the
/// compiled path accumulates active energy in a flat array and transfers
/// it to the [`EnergyLedger`] once at the end of the run.
fn cat_index(c: Category) -> usize {
    match c {
        Category::Conv => 0,
        Category::Crypto => 1,
        Category::OtherSw => 2,
        Category::Dma => 3,
        Category::ExtMem => 4,
        Category::Idle => 5,
    }
}

/// Number of breakdown categories (length of [`Category::all`]).
const N_CATS: usize = 6;

/// Outcome of scheduling a [`JobGraph`].
#[derive(Debug, Clone)]
pub struct SchedResult {
    pub ledger: EnergyLedger,
    /// Completion time of the last job (simulated seconds).
    pub makespan_s: f64,
    /// FLL relocks performed.
    pub mode_switches: u64,
    /// Total busy time per engine, indexed by [`Engine::index`] — as-run
    /// occupancy for scheduled results, emission service time for the
    /// analytic replay.
    pub busy_s: [f64; N_ENGINES],
    pub n_jobs: usize,
    /// Simulated time during which ≥ 2 jobs were in flight at once (any
    /// engines) — the schedule's total overlap.
    pub overlap_s: f64,
    /// Simulated time during which ≥ 2 *cluster* jobs were in flight at
    /// once: CRY–CNN–SW co-residency made visible (0 for the analytic
    /// replay, which serializes the cluster by construction).
    pub coresidency_s: f64,
    /// Peak number of jobs resident in the scheduler at once (admitted
    /// into the window, not yet completed). The materialized paths hold
    /// the whole graph (`= n_jobs`); [`StreamScheduler::run`] is bounded
    /// by `window × frame jobs` independent of the stream length.
    pub peak_resident_jobs: usize,
    /// Frames executed by steady-state replay instead of live dispatch
    /// (0 for the materialized/analytic paths and for streams that never
    /// reach a periodic steady state). Replayed frames are bitwise
    /// identical to live execution — this is a performance statistic, not
    /// an accuracy knob.
    pub fast_forwarded_frames: usize,
    /// Simulated time spent in policy-managed idle spans (full-chip
    /// inter-frame gaps plus cluster stalls) — 0 without a `--policy`.
    /// The managed energy replaces the active-idle leakage floor in the
    /// ledger's `Idle` category (see [`crate::soc::pm`]).
    pub sleep_s: f64,
    /// Portion of [`SchedResult::sleep_s`] resting in the deep-sleep
    /// rung; for full-chip gaps it also gates the external-memory
    /// standby rails out of the `ExtMem` category.
    pub deep_sleep_s: f64,
    /// Wake-up transitions charged by the policy (spans that descended
    /// below the FLL-on idle rung).
    pub wake_transitions: u64,
    /// Frames whose output was lost to a fault (sensor dropouts,
    /// degraded frames, exhausted retry budgets) — see [`crate::fault`].
    /// Always 0 without a fault model; the scheduler core never writes
    /// these five fields, [`crate::fault::apply_stats`] attaches them
    /// post-run.
    pub frames_dropped: u64,
    /// Retry executions performed beyond faulted frames' first attempts.
    pub fault_retries: u64,
    /// Full-chip resets (brown-outs plus watchdog resets).
    pub chip_resets: u64,
    /// Frames whose in-flight state a chip reset flushed.
    pub state_loss_frames: u64,
    /// Energy overhead of fault recovery (mJ): re-executed active energy
    /// plus brown-out wake transitions.
    pub recovery_energy_mj: f64,
}

impl SchedResult {
    /// Busy fraction of an engine over the makespan (0 when empty).
    pub fn utilization(&self, e: Engine) -> f64 {
        if self.makespan_s > 0.0 {
            self.busy_s[e.index()] / self.makespan_s
        } else {
            0.0
        }
    }

    /// The result with every time- and energy-valued field scaled by
    /// `scale` and every count (jobs, switches, wakes, peaks, replayed
    /// frames) unchanged — the closed form for a chip whose time base runs
    /// `scale` times slower but makes the identical decisions. Used by
    /// [`crate::report::Merged::absorb_scaled`]; the policy-managed
    /// members of a parametric fleet class go through the richer
    /// [`ParamRep::member`] instead (sleep billing is not homogeneous in
    /// the span length).
    pub fn rescaled(&self, scale: f64) -> SchedResult {
        assert!(scale.is_finite() && scale > 0.0, "rescale factor must be positive and finite");
        let mut busy = self.busy_s;
        for b in &mut busy {
            *b *= scale;
        }
        SchedResult {
            ledger: self.ledger.scaled(scale),
            makespan_s: self.makespan_s * scale,
            mode_switches: self.mode_switches,
            busy_s: busy,
            n_jobs: self.n_jobs,
            overlap_s: self.overlap_s * scale,
            coresidency_s: self.coresidency_s * scale,
            peak_resident_jobs: self.peak_resident_jobs,
            fast_forwarded_frames: self.fast_forwarded_frames,
            sleep_s: self.sleep_s * scale,
            deep_sleep_s: self.deep_sleep_s * scale,
            wake_transitions: self.wake_transitions,
            frames_dropped: self.frames_dropped,
            fault_retries: self.fault_retries,
            chip_resets: self.chip_resets,
            state_loss_frames: self.state_loss_frames,
            recovery_energy_mj: self.recovery_energy_mj * scale,
        }
    }
}

/// Completion event: min-heap by time (ties broken by job id) on top of
/// `std`'s max-heap.
struct Ev {
    t: f64,
    job: JobId,
}

impl PartialEq for Ev {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t && self.job == other.job
    }
}

impl Eq for Ev {}

impl Ord for Ev {
    fn cmp(&self, other: &Self) -> Ordering {
        other.t.total_cmp(&self.t).then_with(|| other.job.cmp(&self.job))
    }
}

impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Busy interval of one dispatched job, for the overlap statistics of the
/// legacy scan dispatcher ([`Scheduler::run_scan`]).
struct Span {
    start: f64,
    end: f64,
    cluster: bool,
}

/// Sweep the job spans and integrate the time with ≥ 2 concurrent jobs
/// (overall, and restricted to cluster jobs).
fn overlap_stats(spans: &[Span]) -> (f64, f64) {
    let mut events: Vec<(f64, i32, i32)> = Vec::with_capacity(spans.len() * 2);
    for s in spans {
        if s.end > s.start {
            let c = s.cluster as i32;
            events.push((s.start, 1, c));
            events.push((s.end, -1, -c));
        }
    }
    events.sort_by(|a, b| a.0.total_cmp(&b.0));
    let (mut overlap, mut cores) = (0.0f64, 0.0f64);
    let (mut n_all, mut n_cluster) = (0i32, 0i32);
    let mut last_t = 0.0f64;
    for (t, d_all, d_cluster) in events {
        let dt = t - last_t;
        if dt > 0.0 {
            if n_all >= 2 {
                overlap += dt;
            }
            if n_cluster >= 2 {
                cores += dt;
            }
        }
        n_all += d_all;
        n_cluster += d_cluster;
        last_t = t;
    }
    (overlap, cores)
}

/// One boundary of a busy interval in the online overlap sweep: min-heap
/// by (time, insertion sequence) so ties integrate in the same order the
/// batch sweep's stable sort produced.
#[derive(Clone)]
struct SweepEv {
    t: f64,
    seq: u64,
    d_all: i32,
    d_cluster: i32,
}

impl PartialEq for SweepEv {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t && self.seq == other.seq
    }
}

impl Eq for SweepEv {}

impl Ord for SweepEv {
    fn cmp(&self, other: &Self) -> Ordering {
        other.t.total_cmp(&self.t).then_with(|| other.seq.cmp(&self.seq))
    }
}

impl PartialOrd for SweepEv {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Online version of [`overlap_stats`]: span boundaries are pushed at
/// dispatch time and integrated as simulated time advances past them, so
/// the streaming path never materializes the O(frames × jobs) span list.
/// All pending boundaries lie within the in-flight window (+ one relock),
/// keeping the heap O(window). `Clone` lets the fast-forward replay keep a
/// per-cycle undo copy (the pending set is tiny — bounded by the in-flight
/// spans).
#[derive(Clone)]
struct OverlapSweep {
    events: BinaryHeap<SweepEv>,
    seq: u64,
    overlap: f64,
    cluster: f64,
    n_all: i32,
    n_cluster: i32,
    last_t: f64,
}

impl OverlapSweep {
    fn new() -> Self {
        OverlapSweep {
            events: BinaryHeap::new(),
            seq: 0,
            overlap: 0.0,
            cluster: 0.0,
            n_all: 0,
            n_cluster: 0,
            last_t: 0.0,
        }
    }

    fn push_span(&mut self, start: f64, end: f64, cluster: bool) {
        if end > start {
            let c = cluster as i32;
            self.events.push(SweepEv { t: start, seq: self.seq, d_all: 1, d_cluster: c });
            self.seq += 1;
            self.events.push(SweepEv { t: end, seq: self.seq, d_all: -1, d_cluster: -c });
            self.seq += 1;
        }
    }

    fn step(&mut self, ev: SweepEv) {
        let dt = ev.t - self.last_t;
        if dt > 0.0 {
            if self.n_all >= 2 {
                self.overlap += dt;
            }
            if self.n_cluster >= 2 {
                self.cluster += dt;
            }
        }
        self.n_all += ev.d_all;
        self.n_cluster += ev.d_cluster;
        self.last_t = ev.t;
    }

    /// Integrate every boundary at or before `horizon`. Safe because no
    /// later dispatch can introduce a boundary earlier than the current
    /// simulated time.
    fn drain_until(&mut self, horizon: f64) {
        while self.events.peek().is_some_and(|e| e.t <= horizon) {
            let ev = self.events.pop().expect("peeked");
            self.step(ev);
        }
    }

    fn finish(mut self) -> (f64, f64) {
        while let Some(ev) = self.events.pop() {
            self.step(ev);
        }
        (self.overlap, self.cluster)
    }
}

/// Per-frame dependency-tracking slot of the windowed core; retired slots
/// are recycled so a long stream allocates O(window) of them total.
struct FrameSlot {
    indeg: Vec<u32>,
    remaining: usize,
}

/// The co-residency predicate on raw job parameters (shared by the
/// job-structure and compiled paths): may a job emitted for `op` with
/// service time `duration_s` be hosted at current mode `c` without a mode
/// switch? Equal modes always; a subsumed mode only when the
/// frequency-rescale penalty is cheaper than the FLL relock (`relock_s`,
/// [`MODE_SWITCH_S`] on an undrifted chip) a private mode window would
/// cost. Taking the relock as a parameter keeps the predicate invariant
/// under a uniform time-base scale: a drifted chip stretches service
/// times *and* its FLL relock by the same factor, so the comparison —
/// and with it every dispatch decision — is unchanged.
fn co_resident_at(c: OperatingMode, op: OperatingPoint, duration_s: f64, relock_s: f64) -> bool {
    if c == op.mode {
        return true;
    }
    if !c.supports(op.mode) {
        return false;
    }
    hosted_duration(duration_s, op, c) - duration_s <= relock_s
}

/// A frame template lowered to flat struct-of-arrays form: the hot-path
/// representation the execution core actually runs. Per job: an engine
/// occupancy *bitmask* (startability = one `AND` against the core's busy
/// mask), the ready-queue key, mode/clock flags, operating point and
/// service time; plus CSR successor arrays replacing the per-job
/// `Vec<JobId>` children, and the frame's active-energy charges prefolded
/// to `(category, mJ)` rows — exactly the values `EnergyLedger::charge`
/// would compute, so per-frame admission is a tight add loop with zero
/// heap traffic and bitwise-identical sums.
#[derive(Debug, Clone)]
pub struct CompiledFrame {
    n: usize,
    ext_mem_present: bool,
    vdd: f64,
    /// Engine occupancy bitmask per job (bit = [`Engine::index`]).
    engine_mask: Vec<u16>,
    /// `engines[0]` index per job — the ready-queue key of non-cluster jobs.
    first_engine: Vec<u8>,
    mode_locked: Vec<bool>,
    clock_scaled: Vec<bool>,
    op: Vec<OperatingPoint>,
    duration_s: Vec<f64>,
    /// FLL relock interval of the hosting chip ([`MODE_SWITCH_S`] when
    /// compiled; scaled together with `duration_s` by [`CompiledFrame::
    /// rescaled`], since a drifted crystal stretches the relock too).
    relock_s: f64,
    indeg0: Vec<u32>,
    roots: Vec<u32>,
    /// CSR successors: job `j`'s dependents are `succ[succ_off[j]..succ_off[j+1]]`.
    succ_off: Vec<u32>,
    succ: Vec<u32>,
    /// Active-energy rows of one frame in job-then-charge order (parallel
    /// arrays: breakdown-category index, energy in mJ).
    charge_cat: Vec<u8>,
    charge_mj: Vec<f64>,
}

impl CompiledFrame {
    /// Lower a frame graph into the struct-of-arrays template. Pure
    /// repackaging: no float is computed differently from the
    /// job-structure path, so compiled execution is bitwise identical.
    pub fn compile(g: &JobGraph) -> CompiledFrame {
        let n = g.jobs.len();
        let mut cf = CompiledFrame {
            n,
            ext_mem_present: g.ext_mem_present,
            vdd: g.vdd(),
            engine_mask: Vec::with_capacity(n),
            first_engine: Vec::with_capacity(n),
            mode_locked: Vec::with_capacity(n),
            clock_scaled: Vec::with_capacity(n),
            op: Vec::with_capacity(n),
            duration_s: Vec::with_capacity(n),
            relock_s: MODE_SWITCH_S,
            indeg0: Vec::with_capacity(n),
            roots: Vec::new(),
            succ_off: vec![0u32; n + 1],
            succ: Vec::new(),
            charge_cat: Vec::new(),
            charge_mj: Vec::new(),
        };
        for (id, job) in g.jobs.iter().enumerate() {
            let mut mask = 0u16;
            for &e in &job.engines {
                mask |= 1 << e.index();
            }
            cf.engine_mask.push(mask);
            cf.first_engine.push(job.engines[0].index() as u8);
            cf.mode_locked.push(job.mode_locked());
            cf.clock_scaled.push(job.clock_scaled());
            cf.op.push(job.op);
            cf.duration_s.push(job.duration_s);
            cf.indeg0.push(job.deps.len() as u32);
            if job.deps.is_empty() {
                cf.roots.push(id as u32);
            }
            for &d in &job.deps {
                cf.succ_off[d + 1] += 1;
            }
            for &(cat, comp, mult) in &job.charges {
                cf.charge_cat.push(cat_index(cat) as u8);
                // the exact expression `charge_active_into` feeds the
                // ledger: active_mw(comp, op) x (duration x multiplicity)
                cf.charge_mj
                    .push(PowerModel::active_mw(comp, job.op) * (job.duration_s * mult));
            }
        }
        for i in 0..n {
            let upto = cf.succ_off[i];
            cf.succ_off[i + 1] += upto;
        }
        let mut cursor: Vec<u32> = cf.succ_off[..n].to_vec();
        cf.succ = vec![0u32; cf.succ_off[n] as usize];
        for (id, job) in g.jobs.iter().enumerate() {
            for &d in &job.deps {
                cf.succ[cursor[d] as usize] = id as u32;
                cursor[d] += 1;
            }
        }
        cf
    }

    /// Jobs in the template.
    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    fn succ_of(&self, local: usize) -> &[u32] {
        &self.succ[self.succ_off[local] as usize..self.succ_off[local + 1] as usize]
    }

    fn duration_at(&self, local: usize, at: OperatingMode) -> f64 {
        hosted_duration(self.duration_s[local], self.op[local], at)
    }

    /// Whether `other` may stand in for `self` as a per-frame variant: the
    /// job *structure* (engine sets, dependencies) must match; operating
    /// points, service times and charges may differ (a mode override).
    fn structurally_eq(&self, other: &CompiledFrame) -> bool {
        self.n == other.n
            && self.engine_mask == other.engine_mask
            && self.first_engine == other.first_engine
            && self.succ_off == other.succ_off
            && self.succ == other.succ
            && self.indeg0 == other.indeg0
    }

    /// The template as hosted by a chip whose time base runs `alpha` times
    /// slower than nominal (process/temperature drift): every service time,
    /// every prefolded energy row (energy = power x duration, linear in
    /// time) *and* the FLL relock interval scale by `alpha`. Because each
    /// event time of a run is built from sums, maxima and comparisons of
    /// exactly these inputs, scaling all of them uniformly scales every
    /// event time by `alpha` in real arithmetic and leaves the decision
    /// schedule untouched — the theorem the parametric fleet classes lean
    /// on (see [`ParamRep`]).
    pub fn rescaled(&self, alpha: f64) -> CompiledFrame {
        assert!(
            alpha.is_finite() && alpha > 0.0,
            "rescale factor must be positive and finite"
        );
        let mut cf = self.clone();
        for d in &mut cf.duration_s {
            *d *= alpha;
        }
        for c in &mut cf.charge_mj {
            *c *= alpha;
        }
        cf.relock_s = self.relock_s * alpha;
        cf
    }
}

/// Longest steady-state period the detector searches for (frames). The
/// back-to-back §IV streams settle at period 1 and small multiples cover
/// beat patterns between engines; traffic-gated streams settle on longer
/// beats — a k-frame burst repeats with period k — so the detector
/// searches up to 16 (a period-6 burst pattern provably escapes a k ≤ 4
/// detector; see the `bursty_period6_*` test).
const FF_MAX_PERIOD: usize = 16;

/// Detector horizon of the stride/beat extension: periods in
/// `FF_MAX_PERIOD+1 ..= FF_LONG_PERIOD` are tracked by O(1) per-cycle hash
/// signatures instead of full op-log comparisons (rate-controlled streams
/// settle on e.g. 30-frame GOP beats — far past the exact window, far too
/// long for 64 deep `Vec<OpRec>` compares per cycle). A hash collision can
/// at worst promote a false candidate: the confirm phase still checks the
/// frame-relative snapshot fixpoint and every replayed cycle re-verifies
/// op-for-op against live arithmetic, so collisions cost one bail, never
/// correctness.
const FF_LONG_PERIOD: usize = 64;

/// Event-heap tag marking a frame-release (traffic arrival) event: the
/// event's `job` is `RELEASE_TAG + frame`. Far above any real global job
/// id, so at equal times completions (smaller ids) pop first.
const RELEASE_TAG: usize = usize::MAX / 2;

/// Identical periods required before a candidate fixpoint is captured.
const FF_STEADY_PERIODS: usize = 2;

/// Extra identical cycles demanded per prior replay bail-out, so a
/// near-periodic stream cannot thrash between engage and bail.
const FF_BAIL_PENALTY: usize = 4;

/// One recorded scheduling decision of an admission cycle, in
/// frame-relative form (`delta` = frames admitted at the time of the op,
/// minus the job's frame index). Cycle equality compares these sequences —
/// times are deliberately absent: detection is about *decisions*, and the
/// replay recomputes every float with the exact live arithmetic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum OpRec {
    Dispatch { delta: u32, local: u32, switch: bool },
    Pop { delta: u32, local: u32 },
    Retire,
    Admit,
    /// A traffic release event fired: the gated frame's roots became
    /// eligible. `delta` is frame-relative like every other op, so a
    /// steady traffic beat (periodic, repeating burst) records a
    /// shift-invariant cycle and fast-forward still engages.
    Release { delta: u32 },
}

/// Order-sensitive 64-bit FNV-1a signature of a closed cycle's op log —
/// the streak currency of the long-period detector (periods past
/// [`FF_MAX_PERIOD`] compare one `u64` per candidate instead of a full
/// `Vec<OpRec>`). Collisions are tolerated: see [`FF_LONG_PERIOD`].
fn cycle_sig(ops: &[OpRec]) -> u64 {
    fn mix(mut h: u64, v: u64) -> u64 {
        for b in v.to_le_bytes() {
            h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3);
        }
        h
    }
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for op in ops {
        h = match *op {
            OpRec::Dispatch { delta, local, switch } => {
                mix(mix(h, 1 | ((switch as u64) << 8)), ((delta as u64) << 32) | local as u64)
            }
            OpRec::Pop { delta, local } => mix(mix(h, 2), ((delta as u64) << 32) | local as u64),
            OpRec::Retire => mix(h, 3),
            OpRec::Admit => mix(h, 4),
            OpRec::Release { delta } => mix(mix(h, 5), delta as u64),
        };
    }
    h
}

/// Frame-relative snapshot of the discrete scheduler state at an
/// admission boundary. Captured twice, one period apart: equality
/// certifies the steady state is a genuine fixpoint (a repeating op log
/// alone is not sufficient — the state must map onto itself under the
/// one-period shift), and the snapshot doubles as the rebuild recipe when
/// replay hands back to live execution.
#[derive(PartialEq)]
struct RelSnapshot {
    slots: Vec<(Vec<u32>, usize)>,
    io: Vec<Vec<(u32, u32)>>,
    ml: Vec<(u32, u32)>,
    running: Vec<(u32, u32)>,
    /// Admitted frames whose release event has not fired yet
    /// (frame-relative deltas, sorted).
    pending_release: Vec<u32>,
    current_mode: Option<OperatingMode>,
    mode_locked_running: usize,
    busy_mask: u16,
}

/// A job in flight during fast-forward replay. The live path keeps these
/// in the event heap; replay scans them directly — engines are serially
/// busy, so there are at most [`N_ENGINES`] entries and the min scan is
/// cheaper than heap maintenance.
#[derive(Clone, Copy)]
struct RunEntry {
    end: f64,
    gid: JobId,
    mask: u16,
    cluster: bool,
}

/// Undo copy of the accumulator state, taken before each replayed cycle so
/// a verification failure can roll back to the cycle boundary (where the
/// frame-relative snapshot is valid) and resume live execution.
struct FfUndo {
    t: f64,
    makespan: f64,
    mode_ready_at: f64,
    current_mode: Option<OperatingMode>,
    mode_locked_running: usize,
    switches: u64,
    busy: [f64; N_ENGINES],
    busy_mask: u16,
    cats: [f64; N_CATS],
    live: usize,
    peak_live: usize,
    done: usize,
    admitted: usize,
    first_frame: usize,
    sweep: OverlapSweep,
    running: Vec<RunEntry>,
    pending_release: Vec<usize>,
    pm_gap_s: f64,
    pm_gap_mj: f64,
    pm_stall_s: f64,
    pm_stall_mj: f64,
    pm_deep_s: f64,
    pm_wakes: u64,
    /// Span-profile rollback: `(len, copy of last record)` of the profile's
    /// span list at the cycle boundary (`None` when no profile is being
    /// recorded). Replayed cycles append/merge spans like live execution,
    /// so a verification bail must un-record them too.
    profile_spans: Option<(usize, Option<SpanRec>)>,
}

/// One run-length-compressed entry of a [`ProfileRec`]'s chronological
/// idle-span log: `count` consecutive billed spans of identical kind and
/// bit-identical length. Merging only *adjacent* equal spans preserves the
/// chronological float-accumulation order, so a member derivation that
/// walks the log re-billing each record `count` times reproduces the live
/// accumulator sums bitwise (for exactly representable scales).
#[derive(Debug, Clone, Copy, PartialEq)]
struct SpanRec {
    /// Full-chip gap (`true`) vs cluster stall (`false`).
    gap: bool,
    /// Span length in rep-chip seconds.
    len_s: f64,
    /// Consecutive repetitions.
    count: u32,
}

/// Everything a parametric-class representative run records beyond its
/// [`SchedResult`], so family members can be derived in closed form (see
/// [`ParamRep`]): the chronological idle-span profile (policy billing is
/// piecewise in span length — wake thresholds — so spans must be re-billed
/// at the member's time base, not scaled), the leading `[0, r_0)` gap kept
/// separate (a phase offset stretches exactly this span), and the
/// schedule-invariance evidence for the certificate.
#[derive(Debug, Clone)]
struct ProfileRec {
    /// Billed idle spans in chronological order, run-length compressed;
    /// excludes the lead gap.
    spans: Vec<SpanRec>,
    /// Length of the pre-first-release full-chip gap `[0, r_0)` when one
    /// was billed (`None`: the stream started busy at t = 0).
    lead_gap_s: Option<f64>,
    /// True while every traffic release observed live fired into a fully
    /// idle chip (`busy_mask == 0`, FLL settled). A diagnostic, not a
    /// precondition: the φ closed form rests on the uniform-shift theorem
    /// (all releases shift together, and every event is downstream of a
    /// release), which holds whether or not releases land on a busy chip.
    release_anchored: bool,
    /// Smallest relative gap between successive distinct event times seen
    /// on the live heap — the certificate's headroom against f64 rounding
    /// reordering events under a non-dyadic scale.
    min_rel_margin: f64,
    /// Smallest absolute gap (seconds) between successive distinct event
    /// times — the extra headroom a phase offset needs: member events sit
    /// at `α·(t + φ)`, so rounding there is proportional to `t + φ`, and
    /// for early events (`t ≪ φ`) the flip risk is governed by `Δ/φ`, not
    /// `Δ/t`.
    min_abs_margin_s: f64,
}

impl ProfileRec {
    fn new() -> ProfileRec {
        ProfileRec {
            spans: Vec::new(),
            lead_gap_s: None,
            release_anchored: true,
            min_rel_margin: f64::INFINITY,
            min_abs_margin_s: f64::INFINITY,
        }
    }
}

/// Relative event-spacing headroom the invariance certificate demands
/// before deriving a member at a scale whose f64 arithmetic is *not*
/// exact. A uniform scale perturbs each rounded event time by ~2⁻⁵²
/// relative; 1e-9 is ≈ 4·10⁶ ulps of slack, so no comparison the
/// scheduler makes can flip. Scales that are exact in f64 (power-of-two
/// α with φ = 0) skip the margin check — their arithmetic distributes
/// bitwise.
const PARAM_MIN_MARGIN: f64 = 1e-9;

/// Whether `x` is a positive power of two (mantissa bits all zero, normal
/// range) — the scales for which `α·(a+b) == α·a + α·b` holds bitwise in
/// f64, making member derivation exact rather than ~1e-9-accurate.
pub(crate) fn exact_pow2(x: f64) -> bool {
    x >= f64::MIN_POSITIVE && x.is_finite() && x.to_bits() & ((1u64 << 52) - 1) == 0
}

/// Certified outcome of a parametric-class representative run
/// ([`StreamScheduler::run_param_rep`]): the representative's
/// [`SchedResult`] plus the recorded evidence and raw accumulators needed
/// to derive any family member — a chip with service-time drift `α` and
/// traffic phase offset `φ` ([`Perturb`]) — in closed form, without
/// re-simulating it.
///
/// **The scaling theorem.** [`CompiledFrame::rescaled`] multiplies every
/// service time, every prefolded energy row and the FLL relock interval
/// by α, and the member's release table is `(r + φ)·α` (the sensor
/// sampling clock derives from the same drifted crystal). Every event
/// time of a run is built from sums, maxima and order comparisons of
/// exactly these inputs, so in real arithmetic every event time scales by
/// α. The φ shift is rigid by the **uniform-shift theorem**: every
/// release moves by the same αφ, every other event (completions, relock
/// deadlines, admissions) is transitively downstream of a release —
/// frame 0 releases at t = 0, nothing dispatches earlier, and
/// `mode_ready_at` only ever advances from event times — so by induction
/// every sum shifts, every `max` shifts, and every comparison between
/// two shifted times is unchanged. This holds whether releases land on
/// an idle or a busy chip ([`ProfileRec::release_anchored`] records the
/// idle-landing diagnostic, but it is not a precondition). Hence no
/// decision flips: the member makes bit-for-bit the same
/// dispatch/pop/retire/admit decisions at event times `α·(t + φ)`, and
/// all time- and energy-valued outputs follow in closed form. Idle-span
/// *billing* is the one non-homogeneous piece (wake thresholds are
/// absolute times — [`crate::soc::pm`]), so the rep records its
/// chronological span profile and [`ParamRep::member`] re-bills each
/// span at the member's time base; span lengths are shift-invariant, and
/// the pre-first-release lead gap (the only interval pinned to t = 0)
/// stretches to `α·(lead + φ)`.
///
/// **The certificate.** f64 rounds the scaled products, so
/// [`ParamRep::certify`] demands observed event-spacing headroom before
/// deriving at a scale whose arithmetic is not exact. Member events sit
/// at `α·(t + φ)`, so rounding perturbs each by ~ε·(t + φ) and a pair of
/// rep events Δ apart can flip only if `Δ/(t + φ)` falls to ~ε. With
/// φ = 0 the recorded relative margin (min Δ/t) bounds that directly;
/// with φ > 0 the certificate additionally needs the recorded *absolute*
/// margin over φ (min Δ)/φ — for early events `t ≪ φ` the shift, not the
/// event time, sets the rounding magnitude. `min(Δ/t, Δ/φ)/2 ≤
/// Δ/(t + φ)` makes the pair of recorded minima a sound bound
/// ([`PARAM_MIN_MARGIN`], demanded with the factor 2). Power-of-two α
/// with φ = 0 (or a release-free stream, where φ is inert) skips the
/// check — its arithmetic distributes bitwise. Rejected members are
/// re-simulated live on the rescaled template — exact, just not O(1).
/// Bit-equal event-time *ties* are taken to come from identical float
/// computations on both sides of the scale (the symmetric parallel
/// structure that produces every tie in these frame graphs); the fleet
/// layer's sampled live re-runs cross-check that assumption per class.
pub struct ParamRep {
    result: SchedResult,
    /// Flat per-category active-energy accumulators of the rep run
    /// ([`cat_index`] order) — scaled and re-folded into a member ledger
    /// with the exact tail arithmetic of [`ExecCore::run_full`].
    cats: [f64; N_CATS],
    vdd: f64,
    ext_mem_present: bool,
    policy: Option<PolicyKind>,
    has_release: bool,
    spans: Vec<SpanRec>,
    lead_gap_s: Option<f64>,
    release_anchored: bool,
    min_rel_margin: f64,
    min_abs_margin_s: f64,
}

impl ParamRep {
    /// The representative's own result (the α = 1, φ = 0 member).
    pub fn result(&self) -> &SchedResult {
        &self.result
    }

    /// Worst relative event-spacing headroom observed by the rep run
    /// (`∞` when no two live events were distinct-but-adjacent).
    pub fn min_rel_margin(&self) -> f64 {
        self.min_rel_margin
    }

    /// Smallest absolute gap (seconds) between successive distinct event
    /// times of the rep run (`∞` when no two live events were
    /// distinct-but-adjacent) — the headroom the φ > 0 certificate regime
    /// measures against the phase offset.
    pub fn min_abs_margin_s(&self) -> f64 {
        self.min_abs_margin_s
    }

    /// Whether every live traffic release fired into a fully idle chip.
    /// A diagnostic, not a precondition: the uniform-shift theorem makes
    /// the φ closed form valid either way (see the type docs).
    pub fn release_anchored(&self) -> bool {
        self.release_anchored
    }

    /// The schedule-invariance certificate: may member `p` be derived in
    /// closed form? Cheap (a handful of compares) — the expensive evidence
    /// was gathered during the rep run.
    pub fn certify(&self, p: &Perturb) -> bool {
        if !(p.alpha.is_finite() && p.alpha > 0.0 && p.phase_s.is_finite() && p.phase_s >= 0.0) {
            return false;
        }
        if p.is_identity() {
            return true;
        }
        // φ only enters the arithmetic through the release table — a
        // release-free stream ignores it entirely.
        if exact_pow2(p.alpha) && (p.phase_s == 0.0 || !self.has_release) {
            return true;
        }
        if p.phase_s > 0.0 && self.has_release {
            // Shift regime: member events sit at α·(t + φ), so rounding
            // there is ∝ (t + φ) and a rep pair Δ apart flips only when
            // Δ/(t + φ) reaches ~ε. Bound it by the two recorded minima:
            // min(Δ/t, Δ/φ)/2 ≤ Δ/(t + φ), hence the factor 2.
            self.min_rel_margin.min(self.min_abs_margin_s / p.phase_s)
                >= 2.0 * PARAM_MIN_MARGIN
        } else {
            self.min_rel_margin >= PARAM_MIN_MARGIN
        }
    }

    /// Derive member `p`'s full [`SchedResult`] in closed form, or `None`
    /// when the certificate refuses (caller falls back to a live run on
    /// the rescaled template). Exact to the last bit for power-of-two α
    /// with φ = 0; within ~[`PARAM_MIN_MARGIN`] relative otherwise.
    pub fn member(&self, p: &Perturb) -> Option<SchedResult> {
        if !self.certify(p) {
            return None;
        }
        if p.is_identity() {
            return Some(self.result.clone());
        }
        let a = p.alpha;
        let phase = if self.has_release { p.phase_s } else { 0.0 };
        let makespan = a * (self.result.makespan_s + phase);
        let mut busy = self.result.busy_s;
        for b in &mut busy {
            *b *= a;
        }
        // Re-bill the idle-span profile at the member's time base: span
        // lengths scale by α (and the lead gap stretches by the phase
        // offset — or appears, when the rep started busy at t = 0 and the
        // member's offset gates its first frame), but the *bill* of each
        // span is the policy's piecewise function of the scaled length,
        // re-evaluated per span in chronological accumulation order.
        let (mut gap_s, mut gap_mj) = (0.0f64, 0.0f64);
        let (mut stall_s, mut stall_mj) = (0.0f64, 0.0f64);
        let mut deep_s = 0.0f64;
        let mut wakes = 0u64;
        if let Some(kind) = self.policy {
            let lead = match self.lead_gap_s {
                Some(l) => a * (l + phase),
                None if phase > 0.0 => a * phase,
                None => 0.0,
            };
            if lead > 0.0 {
                let b = pm::gap_bill(kind, lead);
                gap_s += lead;
                gap_mj += b.energy_mj;
                deep_s += b.deep_s;
                wakes += b.woke as u64;
            }
            for s in &self.spans {
                let len = a * s.len_s;
                for _ in 0..s.count {
                    if s.gap {
                        let b = pm::gap_bill(kind, len);
                        gap_s += len;
                        gap_mj += b.energy_mj;
                        deep_s += b.deep_s;
                        wakes += b.woke as u64;
                    } else {
                        let b = pm::stall_bill(kind, len);
                        stall_s += len;
                        stall_mj += b.energy_mj;
                        wakes += b.woke as u64;
                    }
                }
            }
        }
        // Rebuild the ledger with the exact tail arithmetic of
        // `ExecCore::run_full`, at the member's accumulators.
        let mut ledger = EnergyLedger::new();
        for (i, cat) in Category::all().into_iter().enumerate() {
            ledger.charge_mj(cat, a * self.cats[i]);
        }
        charge_overheads(&mut ledger, self.vdd, self.ext_mem_present, makespan);
        if self.policy.is_some() {
            let leak_op = OperatingPoint::new(OperatingMode::Sw, self.vdd);
            let cl_mw = PowerModel::active_mw(Component::ClusterLeak, leak_op);
            let soc_mw = PowerModel::active_mw(Component::SocLeak, leak_op);
            let delta =
                (gap_mj - (cl_mw + soc_mw) * gap_s) + (stall_mj - cl_mw * stall_s);
            ledger.charge_mj(Category::Idle, delta);
            if self.ext_mem_present {
                ledger.charge_mj(
                    Category::ExtMem,
                    -((FLASH_STANDBY_MW + FRAM_STANDBY_MW) * deep_s),
                );
            }
        }
        Some(SchedResult {
            ledger,
            makespan_s: makespan,
            mode_switches: self.result.mode_switches,
            busy_s: busy,
            n_jobs: self.result.n_jobs,
            overlap_s: a * self.result.overlap_s,
            coresidency_s: a * self.result.coresidency_s,
            peak_resident_jobs: self.result.peak_resident_jobs,
            // replay engagement can shift by a cycle under a φ lead-in;
            // this is a performance statistic, not a semantic output, and
            // member parity checks deliberately exclude it.
            fast_forwarded_frames: self.result.fast_forwarded_frames,
            sleep_s: gap_s + stall_s,
            deep_sleep_s: deep_s,
            wake_transitions: wakes,
            // Fault counters are attached *after* member derivation
            // ([`crate::fault::apply_stats`] runs the same arithmetic on
            // the rep, the derived members, and the live fallbacks), so
            // the rep's fields here are zero; carry them with
            // [`SchedResult::rescaled`]'s convention regardless.
            frames_dropped: self.result.frames_dropped,
            fault_retries: self.result.fault_retries,
            chip_resets: self.result.chip_resets,
            state_loss_frames: self.result.state_loss_frames,
            recovery_energy_mj: a * self.result.recovery_energy_mj,
        })
    }
}

/// The shared event-driven execution core: schedules `frames` instances of
/// a [`CompiledFrame`] template admitted through a rolling window of at
/// most `window` in-flight frames, with indexed dispatch over the
/// compiled bitmask/CSR arrays. [`Scheduler::run`] is the `frames == 1`
/// case; [`StreamScheduler::run`] streams with a bounded window and
/// steady-state fast-forward. Global job ids are `frame × n + local`, so
/// the admission and dispatch order with `window ≥ frames` is identical to
/// running the materialized [`JobGraph::repeat`] graph.
struct ExecCore<'c> {
    base: &'c CompiledFrame,
    /// Per-frame template overrides, sorted by frame index (empty for
    /// homogeneous streams). Variants are structurally identical to the
    /// base — see [`StreamScheduler::run_with_variants`].
    variants: &'c [(usize, CompiledFrame)],
    n: usize,
    frames: usize,
    window: usize,
    ff_enabled: bool,
    /// Traffic release times, one per frame (empty = back-to-back). A
    /// frame whose release time lies in the future when its window slot
    /// opens is admitted (slot, energy, live count) but its roots stay
    /// gated behind a [`RELEASE_TAG`] heap event.
    release: &'c [f64],
    /// Runtime cap on the detector period (≤ [`FF_LONG_PERIOD`]); a test
    /// hook proving that a short detector misses longer traffic beats
    /// (k ≤ 4 vs period 6; k ≤ 16 vs a 30-frame GOP).
    ff_max_period: usize,
    /// Admitted frames whose release event has not fired yet. Live
    /// execution keeps these in the event heap; replay scans this list
    /// (like [`ExecCore::running`] for completions).
    pending_release: Vec<usize>,
    slots: VecDeque<FrameSlot>,
    spare: Vec<FrameSlot>,
    first_frame: usize,
    admitted: usize,
    /// Ready non-cluster jobs, queued under their (single, in practice)
    /// engine — only free engines' queues are consulted at dispatch.
    io_ready: Vec<BTreeSet<JobId>>,
    /// Ready mode-locked cluster jobs.
    ml_ready: BTreeSet<JobId>,
    /// Busy engines as a bitmask (bit = [`Engine::index`]).
    busy_mask: u16,
    busy: [f64; N_ENGINES],
    current_mode: Option<OperatingMode>,
    mode_ready_at: f64,
    mode_locked_running: usize,
    switches: u64,
    heap: BinaryHeap<Ev>,
    sweep: OverlapSweep,
    /// Active energy per breakdown category ([`cat_index`] order) — the
    /// flat accumulator the final [`EnergyLedger`] is built from.
    cats: [f64; N_CATS],
    live: usize,
    peak_live: usize,
    t: f64,
    makespan: f64,
    done: usize,
    // --- steady-state detection + replay ---
    cur_ops: Vec<OpRec>,
    ring: VecDeque<Vec<OpRec>>,
    streak: [usize; FF_MAX_PERIOD + 1],
    /// Cycle signatures parallel to `ring` ([`cycle_sig`]), bounded the
    /// same way — the long-period detector's comparison ring.
    sig_ring: VecDeque<u64>,
    /// Hash-signature streaks for periods `FF_MAX_PERIOD+1 ..=
    /// FF_LONG_PERIOD` (index = period; slots ≤ FF_MAX_PERIOD unused).
    long_streak: [usize; FF_LONG_PERIOD + 1],
    confirm: Option<(usize, usize, RelSnapshot)>,
    engage: Option<(usize, Vec<OpRec>, RelSnapshot)>,
    bails: usize,
    ff_frames: usize,
    running: Vec<RunEntry>,
    // --- power-state management (accounting only — never timing) ---
    /// Sleep/DVFS policy managing idle spans (`None` = unmanaged: the
    /// pre-PM billing, active-idle leakage across the whole makespan).
    policy: Option<PolicyKind>,
    /// Total full-chip gap time under management (s).
    pm_gap_s: f64,
    /// Policy-billed energy across those gaps (mJ).
    pm_gap_mj: f64,
    /// Total cluster-stall time under management (s).
    pm_stall_s: f64,
    /// Policy-billed cluster energy across those stalls (mJ).
    pm_stall_mj: f64,
    /// Deep-sleep residency within full-chip gaps (s) — gates the
    /// external-memory standby rails.
    pm_deep_s: f64,
    /// Wake-up transitions charged.
    pm_wakes: u64,
    /// Parametric-class recording (`Some` only under
    /// [`StreamScheduler::run_param_rep`]): span profile + invariance
    /// evidence for closed-form member derivation.
    profile: Option<ProfileRec>,
}

impl<'c> ExecCore<'c> {
    fn new(
        base: &'c CompiledFrame,
        variants: &'c [(usize, CompiledFrame)],
        frames: usize,
        window: usize,
        ff_enabled: bool,
    ) -> Self {
        // Clamp the window to the stream length: slots beyond `frames`
        // could never fill (satellite fix — a 1024-frame window over a
        // 3-frame stream is a 3-frame window).
        let window = window.max(1).min(frames.max(1));
        ExecCore {
            base,
            variants,
            n: base.n,
            frames,
            window,
            ff_enabled,
            release: &[],
            ff_max_period: FF_LONG_PERIOD,
            pending_release: Vec::new(),
            slots: VecDeque::new(),
            spare: Vec::new(),
            first_frame: 0,
            admitted: 0,
            io_ready: vec![BTreeSet::new(); N_ENGINES],
            ml_ready: BTreeSet::new(),
            busy_mask: 0,
            busy: [0.0; N_ENGINES],
            current_mode: None,
            mode_ready_at: 0.0,
            mode_locked_running: 0,
            switches: 0,
            heap: BinaryHeap::new(),
            sweep: OverlapSweep::new(),
            cats: [0.0; N_CATS],
            live: 0,
            peak_live: 0,
            t: 0.0,
            makespan: 0.0,
            done: 0,
            cur_ops: Vec::new(),
            ring: VecDeque::new(),
            streak: [0; FF_MAX_PERIOD + 1],
            sig_ring: VecDeque::new(),
            long_streak: [0; FF_LONG_PERIOD + 1],
            confirm: None,
            engage: None,
            bails: 0,
            ff_frames: 0,
            running: Vec::new(),
            policy: None,
            pm_gap_s: 0.0,
            pm_gap_mj: 0.0,
            pm_stall_s: 0.0,
            pm_stall_mj: 0.0,
            pm_deep_s: 0.0,
            pm_wakes: 0,
            profile: None,
        }
    }

    /// The template frame `frame` executes from (its variant when one is
    /// registered, the base otherwise). Returns the `'c` lifetime, not a
    /// reborrow of `self`, so callers may keep mutating the core while
    /// holding template rows.
    fn tpl(&self, frame: usize) -> &'c CompiledFrame {
        if self.variants.is_empty() {
            self.base
        } else {
            match self.variants.binary_search_by_key(&frame, |v| v.0) {
                Ok(i) => &self.variants[i].1,
                Err(_) => self.base,
            }
        }
    }

    /// Whether `frame` runs the base template (fast-forward replays base
    /// frames only).
    fn variant_free(&self, frame: usize) -> bool {
        self.variants.is_empty() || self.variants.binary_search_by_key(&frame, |v| v.0).is_err()
    }

    /// Cycle recording is on while admissions remain (once the last frame
    /// is admitted no cycle can close, so recording would only accumulate
    /// garbage for the drain tail).
    fn recording(&self) -> bool {
        self.ff_enabled && self.admitted < self.frames
    }

    fn enqueue_ready(&mut self, gid: JobId) {
        let tpl = self.tpl(gid / self.n);
        let local = gid % self.n;
        if tpl.mode_locked[local] {
            self.ml_ready.insert(gid);
        } else {
            self.io_ready[tpl.first_engine[local] as usize].insert(gid);
        }
    }

    /// Retire completed frames off the front of the window and admit new
    /// ones while there is both headroom and frames left. Admission
    /// charges the frame's active energy (frame-major order — the same
    /// accumulation sequence `finish_ledger` uses on a materialized
    /// repeat) and enqueues its dependency-free jobs at the current time.
    fn fill(&mut self) {
        loop {
            while self.slots.front().is_some_and(|s| s.remaining == 0) {
                let slot = self.slots.pop_front().expect("checked front");
                self.spare.push(slot);
                self.first_frame += 1;
                if self.recording() {
                    self.cur_ops.push(OpRec::Retire);
                }
            }
            if self.admitted < self.frames && self.slots.len() < self.window {
                self.admit();
            } else {
                break;
            }
        }
    }

    /// The traffic release time of `frame` (0.0 for back-to-back streams).
    fn release_of(&self, frame: usize) -> f64 {
        if self.release.is_empty() {
            0.0
        } else {
            self.release[frame]
        }
    }

    fn admit(&mut self) {
        let frame = self.admitted;
        let base_id = frame * self.n;
        let tpl = self.tpl(frame);
        let rec = self.recording();
        let mut slot = self
            .spare
            .pop()
            .unwrap_or_else(|| FrameSlot { indeg: Vec::new(), remaining: 0 });
        slot.indeg.clear();
        slot.indeg.extend_from_slice(&tpl.indeg0);
        slot.remaining = self.n;
        self.slots.push_back(slot);
        self.admitted += 1;
        self.live += self.n;
        self.peak_live = self.peak_live.max(self.live);
        for (&c, &v) in tpl.charge_cat.iter().zip(&tpl.charge_mj) {
            self.cats[c as usize] += v;
        }
        let rel_t = self.release_of(frame);
        if rel_t > self.t {
            // The frame's sensor data has not arrived yet: hold its roots
            // behind a release event instead of enqueueing them now.
            self.heap.push(Ev { t: rel_t, job: RELEASE_TAG + frame });
        } else {
            for &r in &tpl.roots {
                self.enqueue_ready(base_id + r as usize);
            }
        }
        if rec {
            self.cur_ops.push(OpRec::Admit);
            self.close_cycle();
        }
    }

    /// Close the admission cycle that just ended: update the lag-k repeat
    /// streaks, drive the two-phase fixpoint confirmation, and arm
    /// `engage` once a period is certified. The run loop fast-forwards at
    /// the next loop head — exactly the recorded cycle boundary.
    fn close_cycle(&mut self) {
        let closed = std::mem::take(&mut self.cur_ops);
        // Exact op-log streaks up to FF_MAX_PERIOD; hash-signature streaks
        // beyond (one u64 compare per candidate period instead of a deep
        // Vec compare — the stride/beat extension for long GOP-style
        // patterns). The signature ring is maintained strictly parallel
        // to the op-log ring.
        let sig = cycle_sig(&closed);
        let short_max = self.ff_max_period.min(FF_MAX_PERIOD);
        for k in 1..=short_max {
            if self.ring.len() >= k && closed == self.ring[self.ring.len() - k] {
                self.streak[k] += 1;
            } else {
                self.streak[k] = 0;
            }
        }
        for k in (FF_MAX_PERIOD + 1)..=self.ff_max_period {
            if self.sig_ring.len() >= k && sig == self.sig_ring[self.sig_ring.len() - k] {
                self.long_streak[k] += 1;
            } else {
                self.long_streak[k] = 0;
            }
        }
        self.ring.push_back(closed);
        self.sig_ring.push_back(sig);
        if self.ring.len() > self.ff_max_period + 1 {
            self.ring.pop_front();
            self.sig_ring.pop_front();
        }
        if self.engage.is_some() {
            return;
        }
        if let Some((k, left, snap)) = self.confirm.take() {
            if self.streak_of(k) > 0 {
                if left > 1 {
                    self.confirm = Some((k, left - 1, snap));
                } else {
                    // One full period after the candidate: the relative
                    // state must have mapped onto itself.
                    let now = self.capture_rel();
                    if now == snap && self.guards_ok(k) {
                        let mut pattern = Vec::new();
                        for cycle in self.ring.iter().skip(self.ring.len() - k) {
                            pattern.extend_from_slice(cycle);
                        }
                        self.engage = Some((k, pattern, now));
                    }
                }
            }
            return;
        }
        let need_extra = FF_BAIL_PENALTY * self.bails;
        for k in 1..=self.ff_max_period {
            if self.streak_of(k) >= FF_STEADY_PERIODS * k + need_extra && self.guards_ok(k) {
                self.confirm = Some((k, k, self.capture_rel()));
                break;
            }
        }
    }

    /// Current repeat streak of period `k`: exact op-log streak inside the
    /// short window, hash-signature streak beyond it. A long-period streak
    /// can be inflated by a hash collision — harmless, because engagement
    /// still requires the snapshot fixpoint and replay re-verifies every
    /// op (a collision costs one bail, never correctness).
    fn streak_of(&self, k: usize) -> usize {
        if k <= FF_MAX_PERIOD {
            self.streak[k]
        } else {
            self.long_streak[k]
        }
    }

    /// Sanity guards on a candidate period `k`: a full window, enough
    /// frames left to replay at least once (plus the confirm period), a
    /// block that completes exactly k frames (k retires, k·n pops), and no
    /// per-frame variant from the window onwards.
    fn guards_ok(&self, k: usize) -> bool {
        if self.slots.len() != self.window || self.n == 0 || self.ring.len() < k {
            return false;
        }
        if self.admitted + 2 * k > self.frames {
            return false;
        }
        let (mut pops, mut retires) = (0usize, 0usize);
        for cycle in self.ring.iter().skip(self.ring.len() - k) {
            for op in cycle {
                match op {
                    OpRec::Pop { .. } => pops += 1,
                    OpRec::Retire => retires += 1,
                    _ => {}
                }
            }
        }
        if pops != k * self.n || retires != k {
            return false;
        }
        match self.variants.last() {
            None => true,
            Some(v) => v.0 < self.first_frame,
        }
    }

    /// Snapshot the discrete scheduler state in frame-relative form
    /// (`delta` = admitted − frame) at an admission boundary.
    fn capture_rel(&self) -> RelSnapshot {
        let n = self.n;
        let admitted = self.admitted;
        let rel = move |gid: usize| ((admitted - gid / n) as u32, (gid % n) as u32);
        let mut running: Vec<(u32, u32)> = Vec::new();
        let mut pending_release: Vec<u32> = Vec::new();
        for ev in self.heap.iter() {
            if ev.job >= RELEASE_TAG {
                pending_release.push((admitted - (ev.job - RELEASE_TAG)) as u32);
            } else {
                running.push(rel(ev.job));
            }
        }
        running.sort_unstable();
        pending_release.sort_unstable();
        RelSnapshot {
            slots: self.slots.iter().map(|s| (s.indeg.clone(), s.remaining)).collect(),
            io: self
                .io_ready
                .iter()
                .map(|q| q.iter().map(|&g| rel(g)).collect())
                .collect(),
            ml: self.ml_ready.iter().map(|&g| rel(g)).collect(),
            running,
            pending_release,
            current_mode: self.current_mode,
            mode_locked_running: self.mode_locked_running,
            busy_mask: self.busy_mask,
        }
    }

    /// The lowest-id startable job under the same predicates the linear
    /// scan used: non-cluster jobs via the free engines' queue heads,
    /// cluster jobs via the ordered mode-locked set (co-residency first,
    /// then a mode-switch grant for the overall-lowest cluster job once
    /// the cluster has drained), each scan pruned by the other partition's
    /// best candidate.
    fn find_pick(&self) -> Option<(JobId, bool)> {
        let mut best_io: Option<JobId> = None;
        for e in Engine::ALL {
            if e.mode_locked() {
                continue;
            }
            if self.busy_mask & (1 << e.index()) != 0 {
                continue; // every job queued here needs this engine
            }
            for &id in &self.io_ready[e.index()] {
                if best_io.is_some_and(|b| id >= b) {
                    break;
                }
                if self.tpl(id / self.n).engine_mask[id % self.n] & self.busy_mask == 0 {
                    best_io = Some(id);
                    break;
                }
            }
        }
        let mut best_ml: Option<(JobId, bool)> = None;
        let lowest_ml = self.ml_ready.first().copied();
        for &id in &self.ml_ready {
            if best_io.is_some_and(|b| id >= b) {
                break;
            }
            let tpl = self.tpl(id / self.n);
            let local = id % self.n;
            if tpl.engine_mask[local] & self.busy_mask != 0 {
                continue;
            }
            if let Some(c) = self.current_mode {
                if co_resident_at(c, tpl.op[local], tpl.duration_s[local], tpl.relock_s) {
                    best_ml = Some((id, false));
                    break;
                }
            }
            // A mode switch is granted only to the lowest-id ready
            // cluster job, and only once the cluster engines have drained.
            if self.mode_locked_running == 0 && Some(id) == lowest_ml {
                best_ml = Some((id, true));
                break;
            }
        }
        match (best_io, best_ml) {
            (Some(a), Some((b, sw))) => {
                if a < b {
                    Some((a, false))
                } else {
                    Some((b, sw))
                }
            }
            (Some(a), None) => Some((a, false)),
            (None, b) => b,
        }
    }

    fn dispatch(&mut self, id: JobId, switch: bool) {
        let frame = id / self.n;
        let local = id % self.n;
        let tpl = self.tpl(frame);
        if tpl.mode_locked[local] {
            self.ml_ready.remove(&id);
        } else {
            self.io_ready[tpl.first_engine[local] as usize].remove(&id);
        }
        if self.recording() {
            self.cur_ops.push(OpRec::Dispatch {
                delta: (self.admitted - frame) as u32,
                local: local as u32,
                switch,
            });
        }
        let mut start = self.t;
        let mut dur = tpl.duration_s[local];
        if tpl.mode_locked[local] {
            if switch {
                // Relock only on a genuine frequency change (the first
                // mode entry is free).
                if self.current_mode.is_some() && self.current_mode != Some(tpl.op[local].mode) {
                    self.switches += 1;
                    self.mode_ready_at = self.t + self.base.relock_s;
                }
                self.current_mode = Some(tpl.op[local].mode);
            } else {
                // Co-resident dispatch: hosted at the cluster's current
                // point, service time rescaled.
                let c = self.current_mode.expect("co-resident dispatch without a mode");
                dur = tpl.duration_at(local, c);
            }
            // The cluster sleeps while the FLL relocks.
            start = start.max(self.mode_ready_at);
            self.mode_locked_running += 1;
        } else if tpl.clock_scaled[local] {
            // Clock-derived SOC movers follow the live cluster point
            // (emission clock only while no cluster point is set).
            if let Some(c) = self.current_mode {
                dur = tpl.duration_at(local, c);
            }
        }
        let mask = tpl.engine_mask[local];
        let mut m = mask;
        while m != 0 {
            let e = m.trailing_zeros() as usize;
            self.busy[e] += dur;
            m &= m - 1;
        }
        self.busy_mask |= mask;
        self.sweep.push_span(start, start + dur, tpl.mode_locked[local]);
        self.heap.push(Ev { t: start + dur, job: id });
    }

    fn complete(&mut self, gid: JobId) {
        let frame = gid / self.n;
        let local = gid % self.n;
        let tpl = self.tpl(frame);
        self.busy_mask &= !tpl.engine_mask[local];
        if tpl.mode_locked[local] {
            self.mode_locked_running -= 1;
        }
        self.done += 1;
        self.live -= 1;
        let si = frame - self.first_frame;
        self.slots[si].remaining -= 1;
        for &c in tpl.succ_of(local) {
            let slot = &mut self.slots[si];
            slot.indeg[c as usize] -= 1;
            if slot.indeg[c as usize] == 0 {
                self.enqueue_ready(frame * self.n + c as usize);
            }
        }
    }

    // ---- power-state management ----------------------------------------

    /// Bill the idle span `[self.t, t_next)` before simulated time
    /// advances to the next event. Classification reads the *pre-event*
    /// engine state (events mutate it only after time advances):
    /// `busy_mask == 0` means nothing ran anywhere — a full-chip
    /// inter-frame gap, necessarily terminated by a traffic release —
    /// while `mode_locked_running == 0` with busy SOC movers is a
    /// cluster stall (only the cluster domain can rest). Called at the
    /// same structural point in live execution and in fast-forward
    /// replay with identical float operations, so sleep accounting
    /// stays inside the cycle proof and replay remains bitwise
    /// identical to live.
    #[inline]
    fn pm_account(&mut self, t_next: f64) {
        let Some(kind) = self.policy else { return };
        let dt = t_next - self.t;
        if dt <= 0.0 {
            return;
        }
        if self.busy_mask == 0 {
            let b = pm::gap_bill(kind, dt);
            self.pm_gap_s += dt;
            self.pm_gap_mj += b.energy_mj;
            self.pm_deep_s += b.deep_s;
            self.pm_wakes += b.woke as u64;
            self.record_span(true, dt);
        } else if self.mode_locked_running == 0 {
            let b = pm::stall_bill(kind, dt);
            self.pm_stall_s += dt;
            self.pm_stall_mj += b.energy_mj;
            self.pm_wakes += b.woke as u64;
            self.record_span(false, dt);
        }
    }

    /// Append a billed idle span to the parametric-class profile (no-op
    /// without one). The pre-first-release gap `[0, r_0)` is kept out of
    /// the run-length-compressed log under its own field: a member's phase
    /// offset stretches exactly that span, so it must never merge with
    /// later gaps of coincidentally equal length. Called from live
    /// execution and fast-forward replay alike — the profile stays valid
    /// across replayed cycles (bails are rolled back via [`FfUndo`]).
    #[inline]
    fn record_span(&mut self, gap: bool, dt: f64) {
        let at_origin = self.t == 0.0;
        if let Some(p) = &mut self.profile {
            if gap && at_origin && p.spans.is_empty() && p.lead_gap_s.is_none() {
                p.lead_gap_s = Some(dt);
                return;
            }
            match p.spans.last_mut() {
                Some(s) if s.gap == gap && s.len_s.to_bits() == dt.to_bits() => s.count += 1,
                _ => p.spans.push(SpanRec { gap, len_s: dt, count: 1 }),
            }
        }
    }

    // ---- steady-state replay -------------------------------------------

    fn save_floats(&self) -> FfUndo {
        FfUndo {
            t: self.t,
            makespan: self.makespan,
            mode_ready_at: self.mode_ready_at,
            current_mode: self.current_mode,
            mode_locked_running: self.mode_locked_running,
            switches: self.switches,
            busy: self.busy,
            busy_mask: self.busy_mask,
            cats: self.cats,
            live: self.live,
            peak_live: self.peak_live,
            done: self.done,
            admitted: self.admitted,
            first_frame: self.first_frame,
            sweep: self.sweep.clone(),
            running: self.running.clone(),
            pending_release: self.pending_release.clone(),
            pm_gap_s: self.pm_gap_s,
            pm_gap_mj: self.pm_gap_mj,
            pm_stall_s: self.pm_stall_s,
            pm_stall_mj: self.pm_stall_mj,
            pm_deep_s: self.pm_deep_s,
            pm_wakes: self.pm_wakes,
            profile_spans: self
                .profile
                .as_ref()
                .map(|p| (p.spans.len(), p.spans.last().copied())),
        }
    }

    fn restore_floats(&mut self, u: FfUndo) {
        self.t = u.t;
        self.makespan = u.makespan;
        self.mode_ready_at = u.mode_ready_at;
        self.current_mode = u.current_mode;
        self.mode_locked_running = u.mode_locked_running;
        self.switches = u.switches;
        self.busy = u.busy;
        self.busy_mask = u.busy_mask;
        self.cats = u.cats;
        self.live = u.live;
        self.peak_live = u.peak_live;
        self.done = u.done;
        self.admitted = u.admitted;
        self.first_frame = u.first_frame;
        self.sweep = u.sweep;
        self.running = u.running;
        self.pending_release = u.pending_release;
        self.pm_gap_s = u.pm_gap_s;
        self.pm_gap_mj = u.pm_gap_mj;
        self.pm_stall_s = u.pm_stall_s;
        self.pm_stall_mj = u.pm_stall_mj;
        self.pm_deep_s = u.pm_deep_s;
        self.pm_wakes = u.pm_wakes;
        if let Some((len, last)) = u.profile_spans {
            let p = self.profile.as_mut().expect("profile vanished during replay");
            p.spans.truncate(len);
            if let (Some(slot), Some(saved)) = (p.spans.last_mut(), last) {
                // the bailed cycle may have merged into the boundary record
                *slot = saved;
            }
        }
    }

    /// The next completion among the in-flight jobs, under exactly the
    /// event heap's order: earliest end time ([`f64::total_cmp`]), ties by
    /// job id.
    fn min_running(&self) -> Option<usize> {
        let mut best: Option<usize> = None;
        for (i, r) in self.running.iter().enumerate() {
            let better = match best {
                None => true,
                Some(b) => {
                    let rb = &self.running[b];
                    r.end.total_cmp(&rb.end).then_with(|| r.gid.cmp(&rb.gid)) == Ordering::Less
                }
            };
            if better {
                best = Some(i);
            }
        }
        best
    }

    /// Execute one recorded steady-state period without the ready queues,
    /// dependency counters or dispatch search: pure accumulator arithmetic
    /// plus an order check at every completion. Every float op is the
    /// exact op live execution would perform, in the same order, so a
    /// completed cycle is bitwise identical to having run it live. Returns
    /// false on any divergence (the caller rolls back to the cycle
    /// boundary and resumes live execution).
    fn replay_cycle(&mut self, pattern: &[OpRec]) -> bool {
        let base = self.base;
        for &op in pattern {
            match op {
                OpRec::Dispatch { delta, local, switch } => {
                    let local = local as usize;
                    let Some(frame) = self.admitted.checked_sub(delta as usize) else {
                        return false;
                    };
                    let gid = frame * self.n + local;
                    if self.pending_release.contains(&frame) {
                        // The frame's traffic release has not fired yet —
                        // live execution could not have dispatched it.
                        return false;
                    }
                    let mask = base.engine_mask[local];
                    if mask & self.busy_mask != 0 {
                        return false;
                    }
                    let mut start = self.t;
                    let mut dur = base.duration_s[local];
                    if base.mode_locked[local] {
                        if switch {
                            if self.current_mode.is_some()
                                && self.current_mode != Some(base.op[local].mode)
                            {
                                self.switches += 1;
                                self.mode_ready_at = self.t + self.base.relock_s;
                            }
                            self.current_mode = Some(base.op[local].mode);
                        } else {
                            let Some(c) = self.current_mode else {
                                return false;
                            };
                            dur = base.duration_at(local, c);
                        }
                        start = start.max(self.mode_ready_at);
                        self.mode_locked_running += 1;
                    } else if base.clock_scaled[local] {
                        if let Some(c) = self.current_mode {
                            dur = base.duration_at(local, c);
                        }
                    }
                    let mut m = mask;
                    while m != 0 {
                        let e = m.trailing_zeros() as usize;
                        self.busy[e] += dur;
                        m &= m - 1;
                    }
                    self.busy_mask |= mask;
                    self.sweep.push_span(start, start + dur, base.mode_locked[local]);
                    self.running.push(RunEntry {
                        end: start + dur,
                        gid,
                        mask,
                        cluster: base.mode_locked[local],
                    });
                }
                OpRec::Pop { delta, local } => {
                    let Some(frame) = self.admitted.checked_sub(delta as usize) else {
                        return false;
                    };
                    let expect = frame * self.n + local as usize;
                    let Some(bi) = self.min_running() else {
                        return false;
                    };
                    if self.running[bi].gid != expect {
                        return false;
                    }
                    // A pending release strictly before this completion
                    // would pop first live (equal times resolve to the
                    // completion — release tags sort above all job ids).
                    let end = self.running[bi].end;
                    for &f2 in &self.pending_release {
                        if self.release_of(f2).total_cmp(&end) == Ordering::Less {
                            return false;
                        }
                    }
                    let r = self.running.swap_remove(bi);
                    self.pm_account(r.end);
                    self.t = r.end;
                    self.makespan = self.makespan.max(r.end);
                    self.sweep.drain_until(r.end);
                    self.busy_mask &= !r.mask;
                    if r.cluster {
                        self.mode_locked_running -= 1;
                    }
                    self.done += 1;
                    self.live -= 1;
                }
                OpRec::Retire => self.first_frame += 1,
                OpRec::Admit => {
                    if self.admitted >= self.frames || !self.variant_free(self.admitted) {
                        return false;
                    }
                    for (&c, &v) in base.charge_cat.iter().zip(&base.charge_mj) {
                        self.cats[c as usize] += v;
                    }
                    let frame = self.admitted;
                    self.admitted += 1;
                    self.live += self.n;
                    self.peak_live = self.peak_live.max(self.live);
                    // Mirror the live admission gate: a future release
                    // time holds the frame's roots behind a release event.
                    if self.release_of(frame) > self.t {
                        self.pending_release.push(frame);
                    }
                }
                OpRec::Release { delta } => {
                    let Some(frame) = self.admitted.checked_sub(delta as usize) else {
                        return false;
                    };
                    let Some(pi) = self.pending_release.iter().position(|&f| f == frame) else {
                        return false;
                    };
                    let r = self.release_of(frame);
                    // The release must be the next heap event: time may
                    // not run backwards, no in-flight completion at or
                    // before it (ties go to completions), and no earlier
                    // pending release (ties by frame id).
                    if r < self.t {
                        return false;
                    }
                    if let Some(bi) = self.min_running() {
                        if self.running[bi].end.total_cmp(&r) != Ordering::Greater {
                            return false;
                        }
                    }
                    for &f2 in &self.pending_release {
                        if f2 != frame {
                            let r2 = self.release_of(f2);
                            if r2.total_cmp(&r).then_with(|| f2.cmp(&frame)) == Ordering::Less {
                                return false;
                            }
                        }
                    }
                    self.pending_release.swap_remove(pi);
                    self.pm_account(r);
                    self.t = r;
                    self.makespan = self.makespan.max(r);
                    self.sweep.drain_until(r);
                }
            }
        }
        true
    }

    /// Replay the certified steady-state pattern until the stream's
    /// admissions are exhausted (or a verification check fails), then
    /// rebuild the live structures from the frame-relative fixpoint and
    /// hand back to event-driven execution for the drain tail.
    fn fast_forward(&mut self) {
        let (k, pattern, snap) = self.engage.take().expect("fast_forward without engage");
        // In-flight jobs move from the event heap to the flat running set
        // (all in-window frames are base-template — the variant guard).
        self.running.clear();
        self.pending_release.clear();
        while let Some(ev) = self.heap.pop() {
            if ev.job >= RELEASE_TAG {
                self.pending_release.push(ev.job - RELEASE_TAG);
                continue;
            }
            let local = ev.job % self.n;
            self.running.push(RunEntry {
                end: ev.t,
                gid: ev.job,
                mask: self.base.engine_mask[local],
                cluster: self.base.mode_locked[local],
            });
        }
        while self.admitted + k <= self.frames {
            let undo = self.save_floats();
            if self.replay_cycle(&pattern) {
                self.ff_frames += k;
            } else {
                self.restore_floats(undo);
                self.bails += 1;
                break;
            }
        }
        self.rebuild(&snap);
        self.running.clear();
        self.pending_release.clear();
        self.ring.clear();
        self.streak = [0; FF_MAX_PERIOD + 1];
        self.sig_ring.clear();
        self.long_streak = [0; FF_LONG_PERIOD + 1];
        self.confirm = None;
        self.cur_ops.clear();
    }

    /// Reconstruct the discrete scheduler structures from the
    /// frame-relative fixpoint, shifted to the current admission boundary.
    fn rebuild(&mut self, snap: &RelSnapshot) {
        debug_assert_eq!(self.admitted - self.first_frame, snap.slots.len());
        debug_assert_eq!(self.busy_mask, snap.busy_mask);
        debug_assert_eq!(self.current_mode, snap.current_mode);
        debug_assert_eq!(self.mode_locked_running, snap.mode_locked_running);
        let n = self.n;
        let admitted = self.admitted;
        let gid = move |&(delta, local): &(u32, u32)| (admitted - delta as usize) * n + local as usize;
        self.slots.clear();
        for (indeg, remaining) in &snap.slots {
            self.slots.push_back(FrameSlot { indeg: indeg.clone(), remaining: *remaining });
        }
        for (e, q) in self.io_ready.iter_mut().enumerate() {
            q.clear();
            for r in &snap.io[e] {
                q.insert(gid(r));
            }
        }
        self.ml_ready.clear();
        for r in &snap.ml {
            self.ml_ready.insert(gid(r));
        }
        self.heap.clear();
        for r in &self.running {
            self.heap.push(Ev { t: r.end, job: r.gid });
        }
        debug_assert_eq!(
            {
                let mut d: Vec<u32> = self
                    .pending_release
                    .iter()
                    .map(|&f| (self.admitted - f) as u32)
                    .collect();
                d.sort_unstable();
                d
            },
            snap.pending_release,
            "pending releases diverged from the fixpoint"
        );
        for &f in &self.pending_release {
            self.heap.push(Ev { t: self.release_of(f), job: RELEASE_TAG + f });
        }
    }

    fn run(mut self) -> SchedResult {
        self.run_full().0
    }

    /// [`ExecCore::run`] returning, in addition to the result, the raw
    /// flat category accumulators and the recorded parametric profile —
    /// the material [`StreamScheduler::run_param_rep`] packages into a
    /// [`ParamRep`] so family members can rebuild their ledgers with the
    /// exact tail arithmetic below at a scaled time base.
    fn run_full(mut self) -> (SchedResult, [f64; N_CATS], Option<ProfileRec>) {
        self.fill();
        loop {
            // A certified steady state replays here — exactly the
            // admission boundary the pattern was recorded at.
            if self.engage.is_some() {
                self.fast_forward();
            }
            // Dispatch everything startable at time t, lowest job id first.
            while let Some((id, switch)) = self.find_pick() {
                self.dispatch(id, switch);
            }
            // Advance simulated time to the next completion or release.
            let Some(ev) = self.heap.pop() else { break };
            if let Some(p) = &mut self.profile {
                // Certificate evidence: the relative headroom to the next
                // distinct event time. A uniform time-base scale perturbs
                // each f64 event time by ~1 ulp, so reordering would need
                // two events closer than that — the certificate demands
                // margins orders of magnitude wider (PARAM_MIN_MARGIN).
                if let Some(next) = self.heap.peek() {
                    if next.t > ev.t && next.t > 0.0 {
                        let gap = next.t - ev.t;
                        let m = gap / next.t;
                        if m < p.min_rel_margin {
                            p.min_rel_margin = m;
                        }
                        if gap < p.min_abs_margin_s {
                            p.min_abs_margin_s = gap;
                        }
                    }
                }
            }
            self.pm_account(ev.t);
            self.t = ev.t;
            self.makespan = self.makespan.max(ev.t);
            self.sweep.drain_until(ev.t);
            if ev.job >= RELEASE_TAG {
                // Traffic release: the gated frame's sensor data arrived;
                // its roots become dispatchable now.
                let frame = ev.job - RELEASE_TAG;
                if self.busy_mask != 0 || self.mode_ready_at > ev.t {
                    // Diagnostic only: record that this release landed on
                    // a busy (or still-relocking) chip. The φ closed form
                    // does not care — the uniform-shift theorem moves the
                    // in-flight work and the release together (see
                    // [`ProfileRec`] / [`ParamRep`]).
                    if let Some(p) = &mut self.profile {
                        p.release_anchored = false;
                    }
                }
                if self.recording() {
                    self.cur_ops
                        .push(OpRec::Release { delta: (self.admitted - frame) as u32 });
                }
                let tpl = self.tpl(frame);
                let base_id = frame * self.n;
                for &r in &tpl.roots {
                    self.enqueue_ready(base_id + r as usize);
                }
                continue;
            }
            if self.recording() {
                self.cur_ops.push(OpRec::Pop {
                    delta: (self.admitted - ev.job / self.n) as u32,
                    local: (ev.job % self.n) as u32,
                });
            }
            self.complete(ev.job);
            self.fill();
        }
        assert_eq!(
            self.done,
            self.n * self.frames,
            "scheduler stalled: {} of {} jobs completed",
            self.done,
            self.n * self.frames
        );
        let makespan = self.makespan;
        let (overlap_s, coresidency_s) = self.sweep.finish();
        // Transfer the flat accumulators into a ledger (category order),
        // then the makespan-proportional overheads — the same order the
        // job-structure `finish_ledger` charges, so sums match bitwise.
        let mut ledger = EnergyLedger::new();
        for (i, cat) in Category::all().into_iter().enumerate() {
            ledger.charge_mj(cat, self.cats[i]);
        }
        charge_overheads(&mut ledger, self.base.vdd, self.base.ext_mem_present, makespan);
        if self.policy.is_some() {
            // Replace the active-idle leakage floor `charge_overheads`
            // billed across the managed spans with the policy's bill
            // (both domains across full-chip gaps, cluster only across
            // stalls), and gate the external-memory standby rails for
            // the deep-sleep portion of the gaps. Pure accumulator
            // arithmetic at run end — identical on the live and
            // fast-forward paths because the accumulators are.
            let leak_op = OperatingPoint::new(OperatingMode::Sw, self.base.vdd);
            let cl_mw = PowerModel::active_mw(Component::ClusterLeak, leak_op);
            let soc_mw = PowerModel::active_mw(Component::SocLeak, leak_op);
            let delta = (self.pm_gap_mj - (cl_mw + soc_mw) * self.pm_gap_s)
                + (self.pm_stall_mj - cl_mw * self.pm_stall_s);
            ledger.charge_mj(Category::Idle, delta);
            if self.base.ext_mem_present {
                ledger.charge_mj(
                    Category::ExtMem,
                    -((FLASH_STANDBY_MW + FRAM_STANDBY_MW) * self.pm_deep_s),
                );
            }
        }
        let result = SchedResult {
            ledger,
            makespan_s: makespan,
            mode_switches: self.switches,
            busy_s: self.busy,
            n_jobs: self.n * self.frames,
            overlap_s,
            coresidency_s,
            peak_resident_jobs: self.peak_live,
            fast_forwarded_frames: self.ff_frames,
            sleep_s: self.pm_gap_s + self.pm_stall_s,
            deep_sleep_s: self.pm_deep_s,
            wake_transitions: self.pm_wakes,
            frames_dropped: 0,
            fault_retries: 0,
            chip_resets: 0,
            state_loss_frames: 0,
            recovery_energy_mj: 0.0,
        };
        (result, self.cats, self.profile)
    }
}

/// The event-driven scheduler. Stateless: all state lives on the run.
pub struct Scheduler;

impl Scheduler {
    /// Schedule `graph` to completion and return makespan, energy and
    /// per-engine statistics. Deterministic: dispatch prefers the
    /// lowest-id ready job, completion ties resolve by job id. The graph
    /// is lowered to a [`CompiledFrame`] and dispatch is indexed
    /// (per-engine ready queues + a mode-locked partition), with
    /// [`Scheduler::run_scan`] as the linear-scan parity reference.
    pub fn run(graph: &JobGraph) -> SchedResult {
        let cf = CompiledFrame::compile(graph);
        ExecCore::new(&cf, &[], 1, 1, false).run()
    }

    /// The original linear-scan dispatcher: rescans the whole ready set on
    /// every dispatch — O(pending) per event, O(n²) over a long stream.
    /// Kept as the bitwise correctness reference for [`Scheduler::run`]
    /// (property-tested on random graphs and every use-case rung) and as
    /// the materialized-path baseline `bench_scheduler` measures the
    /// indexed and windowed paths against.
    pub fn run_scan(graph: &JobGraph) -> SchedResult {
        let n = graph.jobs.len();
        let mut indeg: Vec<usize> = Vec::with_capacity(n);
        let mut children: Vec<Vec<JobId>> = vec![Vec::new(); n];
        for (id, job) in graph.jobs.iter().enumerate() {
            indeg.push(job.deps.len());
            for &d in &job.deps {
                children[d].push(id);
            }
        }
        let mut ready: BTreeSet<JobId> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut heap: BinaryHeap<Ev> = BinaryHeap::new();
        let mut engine_busy = [false; N_ENGINES];
        let mut busy = [0.0f64; N_ENGINES];
        let mut spans: Vec<Span> = Vec::with_capacity(n);
        let mut current_mode: Option<OperatingMode> = None;
        let mut mode_ready_at = 0.0f64;
        let mut mode_locked_running = 0usize;
        let mut switches = 0u64;
        let mut n_done = 0usize;
        let mut t = 0.0f64;
        let mut makespan = 0.0f64;

        loop {
            // Dispatch everything startable at time t, lowest job id first.
            loop {
                let lowest_ml_ready =
                    ready.iter().copied().find(|&j| graph.jobs[j].mode_locked());
                let mut pick: Option<(JobId, bool)> = None; // (job, switches mode)
                for &j in ready.iter() {
                    let job = &graph.jobs[j];
                    if job.engines.iter().any(|&e| engine_busy[e.index()]) {
                        continue;
                    }
                    if job.mode_locked() {
                        if let Some(c) = current_mode {
                            if Self::co_resident(c, job) {
                                pick = Some((j, false));
                                break;
                            }
                        }
                        // A mode switch is granted only to the lowest-id
                        // ready cluster job, and only once the cluster
                        // engines have drained.
                        if mode_locked_running == 0 && Some(j) == lowest_ml_ready {
                            pick = Some((j, true));
                            break;
                        }
                        continue;
                    }
                    pick = Some((j, false));
                    break;
                }
                let Some((j, switch)) = pick else { break };
                ready.remove(&j);
                let job = &graph.jobs[j];
                let mut start = t;
                let mut dur = job.duration_s;
                if job.mode_locked() {
                    if switch {
                        // Relock only on a genuine frequency change (the
                        // first mode entry is free).
                        if current_mode.is_some() && current_mode != Some(job.op.mode) {
                            switches += 1;
                            mode_ready_at = t + MODE_SWITCH_S;
                        }
                        current_mode = Some(job.op.mode);
                    } else {
                        // Co-resident dispatch: hosted at the cluster's
                        // current point, service time rescaled.
                        let c = current_mode.expect("co-resident dispatch without a mode");
                        dur = job.duration_at(c);
                    }
                    // The cluster sleeps while the FLL relocks.
                    start = start.max(mode_ready_at);
                    mode_locked_running += 1;
                } else if job.clock_scaled() {
                    if let Some(c) = current_mode {
                        dur = job.duration_at(c);
                    }
                }
                for &e in &job.engines {
                    engine_busy[e.index()] = true;
                    busy[e.index()] += dur;
                }
                spans.push(Span { start, end: start + dur, cluster: job.mode_locked() });
                heap.push(Ev { t: start + dur, job: j });
            }

            // Advance simulated time to the next completion.
            let Some(ev) = heap.pop() else { break };
            t = ev.t;
            makespan = makespan.max(t);
            let job = &graph.jobs[ev.job];
            for &e in &job.engines {
                engine_busy[e.index()] = false;
            }
            if job.mode_locked() {
                mode_locked_running -= 1;
            }
            n_done += 1;
            for &c in &children[ev.job] {
                indeg[c] -= 1;
                if indeg[c] == 0 {
                    ready.insert(c);
                }
            }
        }
        assert_eq!(n_done, n, "scheduler stalled: {n_done} of {n} jobs completed");

        let (overlap_s, coresidency_s) = overlap_stats(&spans);
        SchedResult {
            ledger: graph.finish_ledger(makespan),
            makespan_s: makespan,
            mode_switches: switches,
            busy_s: busy,
            n_jobs: n,
            overlap_s,
            coresidency_s,
            peak_resident_jobs: n,
            fast_forwarded_frames: 0,
            sleep_s: 0.0,
            deep_sleep_s: 0.0,
            wake_transitions: 0,
            frames_dropped: 0,
            fault_retries: 0,
            chip_resets: 0,
            state_loss_frames: 0,
            recovery_energy_mj: 0.0,
        }
    }

    /// The co-residency rule: may `job` be hosted at current mode `c`
    /// without a mode switch? Equal modes always; a subsumed mode only
    /// when the frequency-rescale penalty is cheaper than the FLL relock
    /// a private mode window would cost.
    fn co_resident(c: OperatingMode, job: &Job) -> bool {
        co_resident_at(c, job.op, job.duration_s, MODE_SWITCH_S)
    }
}

/// Bounded-window streaming: schedules `frames` instances of a frame
/// template through the shared execution core, admitting at most `window`
/// frames at a time (clamped to the stream length) and recycling the
/// dependency state of retired frames. Memory and dispatch cost are
/// O(window × frame jobs) regardless of the stream length; with
/// `window ≥ frames` the result is bitwise identical to
/// `Scheduler::run(&frame.repeat(frames))`. The production entry points
/// compile the template and fast-forward through the periodic steady
/// state — bitwise identical to the live path (see the module docs),
/// which survives as [`StreamScheduler::run_live`] for parity testing.
pub struct StreamScheduler;

impl StreamScheduler {
    /// Stream `frames` instances of `frame`: compiled template +
    /// steady-state fast-forward.
    pub fn run(frame: &JobGraph, frames: usize, window: usize) -> SchedResult {
        Self::run_compiled(&CompiledFrame::compile(frame), frames, window)
    }

    /// [`StreamScheduler::run`] over a pre-compiled template — compile
    /// once, stream many (e.g. one template shared by every shard of a
    /// [`crate::system::ShardedStream`]).
    pub fn run_compiled(frame: &CompiledFrame, frames: usize, window: usize) -> SchedResult {
        assert!(frames >= 1, "streaming needs at least one frame");
        assert!(window >= 1, "streaming needs at least one in-flight frame of window");
        ExecCore::new(frame, &[], frames, window, true).run()
    }

    /// The live windowed path with fast-forward disabled — the bitwise
    /// parity reference for [`StreamScheduler::run`] (the PR 4 semantics),
    /// and the baseline `bench_scheduler` measures the replay win against.
    pub fn run_live(frame: &JobGraph, frames: usize, window: usize) -> SchedResult {
        assert!(frames >= 1, "streaming needs at least one frame");
        assert!(window >= 1, "streaming needs at least one in-flight frame of window");
        let cf = CompiledFrame::compile(frame);
        ExecCore::new(&cf, &[], frames, window, false).run()
    }

    /// Stream under a traffic model: `release[f]` is the earliest
    /// simulated time frame `f`'s roots may dispatch (its sensor data
    /// arrival). An empty slice means back-to-back; `release` filled with
    /// zeros (or any schedule the stream outruns) is bitwise identical to
    /// the back-to-back path on serial pipelines. Gaps participate in
    /// steady-state detection frame-relatively, so periodic and repeating
    /// burst traffic still fast-forwards (see [`FF_MAX_PERIOD`]).
    pub fn run_traffic(
        frame: &JobGraph,
        frames: usize,
        window: usize,
        release: &[f64],
    ) -> SchedResult {
        Self::run_compiled_traffic(&CompiledFrame::compile(frame), frames, window, release)
    }

    /// [`StreamScheduler::run_traffic`] over a pre-compiled template — the
    /// fleet runner's per-class entry point.
    pub fn run_compiled_traffic(
        frame: &CompiledFrame,
        frames: usize,
        window: usize,
        release: &[f64],
    ) -> SchedResult {
        Self::run_compiled_traffic_pm(frame, frames, window, release, None)
    }

    /// [`StreamScheduler::run_compiled_traffic`] with idle spans managed
    /// by a sleep/DVFS policy ([`crate::soc::pm`]). The policy is
    /// accounting-only — dispatch order, makespan and every busy interval
    /// are bitwise identical to the unmanaged run; only the idle-span
    /// energy (and the sleep statistics of [`SchedResult`]) change.
    /// `None` is exactly [`StreamScheduler::run_compiled_traffic`].
    pub fn run_compiled_traffic_pm(
        frame: &CompiledFrame,
        frames: usize,
        window: usize,
        release: &[f64],
        policy: Option<PolicyKind>,
    ) -> SchedResult {
        assert!(frames >= 1, "streaming needs at least one frame");
        assert!(window >= 1, "streaming needs at least one in-flight frame of window");
        Self::check_release(release, frames);
        let mut core = ExecCore::new(frame, &[], frames, window, true);
        core.release = release;
        core.policy = policy;
        core.run()
    }

    /// The live traffic path with fast-forward disabled — the bitwise
    /// parity reference for [`StreamScheduler::run_traffic`].
    pub fn run_traffic_live(
        frame: &JobGraph,
        frames: usize,
        window: usize,
        release: &[f64],
    ) -> SchedResult {
        Self::run_traffic_live_pm(frame, frames, window, release, None)
    }

    /// [`StreamScheduler::run_traffic_live`] under a sleep/DVFS policy —
    /// the bitwise parity reference for
    /// [`StreamScheduler::run_compiled_traffic_pm`] (sleep accounting
    /// must survive fast-forward unchanged; the fleet parity samples run
    /// through here).
    pub fn run_traffic_live_pm(
        frame: &JobGraph,
        frames: usize,
        window: usize,
        release: &[f64],
        policy: Option<PolicyKind>,
    ) -> SchedResult {
        assert!(frames >= 1, "streaming needs at least one frame");
        assert!(window >= 1, "streaming needs at least one in-flight frame of window");
        Self::check_release(release, frames);
        let cf = CompiledFrame::compile(frame);
        let mut core = ExecCore::new(&cf, &[], frames, window, false);
        core.release = release;
        core.policy = policy;
        core.run()
    }

    /// [`StreamScheduler::run_traffic_live_pm`] over a pre-compiled
    /// template — the live (fast-forward-disabled) parity reference the
    /// fleet layer runs against *rescaled* templates when it samples
    /// parametric family members.
    pub fn run_compiled_traffic_live_pm(
        frame: &CompiledFrame,
        frames: usize,
        window: usize,
        release: &[f64],
        policy: Option<PolicyKind>,
    ) -> SchedResult {
        assert!(frames >= 1, "streaming needs at least one frame");
        assert!(window >= 1, "streaming needs at least one in-flight frame of window");
        Self::check_release(release, frames);
        let mut core = ExecCore::new(frame, &[], frames, window, false);
        core.release = release;
        core.policy = policy;
        core.run()
    }

    /// [`StreamScheduler::run_compiled_traffic_pm`] as a parametric-class
    /// *representative*: the identical simulation (fast-forward enabled,
    /// bitwise-identical result), additionally recording the idle-span
    /// profile and schedule-invariance evidence that let [`ParamRep`]
    /// derive drift/phase family members in closed form.
    pub fn run_param_rep(
        frame: &CompiledFrame,
        frames: usize,
        window: usize,
        release: &[f64],
        policy: Option<PolicyKind>,
    ) -> ParamRep {
        assert!(frames >= 1, "streaming needs at least one frame");
        assert!(window >= 1, "streaming needs at least one in-flight frame of window");
        Self::check_release(release, frames);
        let mut core = ExecCore::new(frame, &[], frames, window, true);
        core.release = release;
        core.policy = policy;
        core.profile = Some(ProfileRec::new());
        let (result, cats, profile) = core.run_full();
        let p = profile.expect("representative run records a profile");
        ParamRep {
            result,
            cats,
            vdd: frame.vdd,
            ext_mem_present: frame.ext_mem_present,
            policy,
            has_release: !release.is_empty(),
            spans: p.spans,
            lead_gap_s: p.lead_gap_s,
            release_anchored: p.release_anchored,
            min_rel_margin: p.min_rel_margin,
            min_abs_margin_s: p.min_abs_margin_s,
        }
    }

    /// Test hook: [`StreamScheduler::run_traffic`] with the limit-cycle
    /// detector capped at `max_period` (≤ [`FF_LONG_PERIOD`]) — proves a
    /// short detector misses longer traffic beats (k ≤ 4 vs period 6,
    /// k ≤ 16 vs a 30-frame GOP; see the `*_needs_extended_detector`
    /// tests).
    #[doc(hidden)]
    pub fn run_traffic_capped(
        frame: &JobGraph,
        frames: usize,
        window: usize,
        release: &[f64],
        max_period: usize,
    ) -> SchedResult {
        assert!(frames >= 1, "streaming needs at least one frame");
        assert!(window >= 1, "streaming needs at least one in-flight frame of window");
        assert!(max_period >= 1, "detector needs at least period 1");
        Self::check_release(release, frames);
        let cf = CompiledFrame::compile(frame);
        let mut core = ExecCore::new(&cf, &[], frames, window, true);
        core.release = release;
        core.ff_max_period = max_period.min(FF_LONG_PERIOD);
        core.run()
    }

    fn check_release(release: &[f64], frames: usize) {
        if release.is_empty() {
            return;
        }
        assert!(
            release.len() >= frames,
            "release table covers {} frames of a {frames}-frame stream",
            release.len()
        );
        let mut prev = 0.0f64;
        for (f, &r) in release.iter().take(frames).enumerate() {
            assert!(r.is_finite() && r >= 0.0, "release[{f}] = {r} must be finite and ≥ 0");
            assert!(r >= prev, "release times must be non-decreasing (frame {f})");
            prev = r;
        }
    }

    /// Stream with per-frame template overrides: a frame listed in
    /// `variants` executes its own graph instead of the base template
    /// (e.g. a mode override on one frame of a long stream). Variants must
    /// be *structurally* identical to the base — same job count, engine
    /// sets and dependencies; operating points, service times and energy
    /// charges may differ. Fast-forward suspends while a variant is in (or
    /// ahead of) the window and re-engages after it retires.
    pub fn run_with_variants(
        frame: &JobGraph,
        frames: usize,
        window: usize,
        variants: &[(usize, &JobGraph)],
    ) -> SchedResult {
        Self::run_variants_inner(frame, frames, window, variants, &[], None, true)
    }

    /// [`StreamScheduler::run_with_variants`] with fast-forward disabled —
    /// the parity reference for the variant fallback path.
    pub fn run_with_variants_live(
        frame: &JobGraph,
        frames: usize,
        window: usize,
        variants: &[(usize, &JobGraph)],
    ) -> SchedResult {
        Self::run_variants_inner(frame, frames, window, variants, &[], None, false)
    }

    /// [`StreamScheduler::run_with_variants`] under a traffic model and an
    /// optional sleep/DVFS policy — the faulted-stream entry point
    /// ([`crate::fault::FaultPlan`] compiles each faulted frame into a
    /// variant; empty `variants` is exactly
    /// [`StreamScheduler::run_compiled_traffic_pm`] on the compiled
    /// template).
    pub fn run_with_variants_traffic_pm(
        frame: &JobGraph,
        frames: usize,
        window: usize,
        variants: &[(usize, &JobGraph)],
        release: &[f64],
        policy: Option<PolicyKind>,
    ) -> SchedResult {
        Self::run_variants_inner(frame, frames, window, variants, release, policy, true)
    }

    /// [`StreamScheduler::run_with_variants_traffic_pm`] with fast-forward
    /// disabled — the bitwise parity reference for faulted streams.
    pub fn run_with_variants_traffic_live_pm(
        frame: &JobGraph,
        frames: usize,
        window: usize,
        variants: &[(usize, &JobGraph)],
        release: &[f64],
        policy: Option<PolicyKind>,
    ) -> SchedResult {
        Self::run_variants_inner(frame, frames, window, variants, release, policy, false)
    }

    #[allow(clippy::too_many_arguments)]
    fn run_variants_inner(
        frame: &JobGraph,
        frames: usize,
        window: usize,
        variants: &[(usize, &JobGraph)],
        release: &[f64],
        policy: Option<PolicyKind>,
        ff: bool,
    ) -> SchedResult {
        let base = CompiledFrame::compile(frame);
        let mut compiled: Vec<(usize, CompiledFrame)> =
            variants.iter().map(|&(f, g)| (f, CompiledFrame::compile(g))).collect();
        compiled.sort_by_key(|v| v.0);
        Self::run_compiled_variants_traffic_pm(&base, &compiled, frames, window, release, policy, ff)
    }

    /// The compiled variant path the fleet runner drives directly: the
    /// base template and the variants arrive pre-compiled (and possibly
    /// uniformly rescaled for a drifted family member), already sorted by
    /// frame. `ff` selects replay vs the live parity reference.
    #[allow(clippy::too_many_arguments)]
    pub fn run_compiled_variants_traffic_pm(
        base: &CompiledFrame,
        variants: &[(usize, CompiledFrame)],
        frames: usize,
        window: usize,
        release: &[f64],
        policy: Option<PolicyKind>,
        ff: bool,
    ) -> SchedResult {
        assert!(frames >= 1, "streaming needs at least one frame");
        assert!(window >= 1, "streaming needs at least one in-flight frame of window");
        Self::check_release(release, frames);
        Self::check_variants(base, variants, frames);
        let mut core = ExecCore::new(base, variants, frames, window, ff);
        core.release = release;
        core.policy = policy;
        core.run()
    }

    /// [`StreamScheduler::run_param_rep`] with per-frame variants — the
    /// parametric-class representative of a *faulted* stream. Variants
    /// scale uniformly with the member drift factor exactly like the base
    /// template (they are part of the scaled input set), so the
    /// closed-form member derivation is unchanged.
    pub fn run_param_rep_variants(
        frame: &CompiledFrame,
        variants: &[(usize, CompiledFrame)],
        frames: usize,
        window: usize,
        release: &[f64],
        policy: Option<PolicyKind>,
    ) -> ParamRep {
        assert!(frames >= 1, "streaming needs at least one frame");
        assert!(window >= 1, "streaming needs at least one in-flight frame of window");
        Self::check_release(release, frames);
        Self::check_variants(frame, variants, frames);
        let mut core = ExecCore::new(frame, variants, frames, window, true);
        core.release = release;
        core.policy = policy;
        core.profile = Some(ProfileRec::new());
        let (result, cats, profile) = core.run_full();
        let p = profile.expect("representative run records a profile");
        ParamRep {
            result,
            cats,
            vdd: frame.vdd,
            ext_mem_present: frame.ext_mem_present,
            policy,
            has_release: !release.is_empty(),
            spans: p.spans,
            lead_gap_s: p.lead_gap_s,
            release_anchored: p.release_anchored,
            min_rel_margin: p.min_rel_margin,
            min_abs_margin_s: p.min_abs_margin_s,
        }
    }

    fn check_variants(base: &CompiledFrame, variants: &[(usize, CompiledFrame)], frames: usize) {
        for w in variants.windows(2) {
            assert!(w[0].0 < w[1].0, "variants must be sorted by frame, without duplicates");
        }
        for (f, v) in variants {
            assert!(*f < frames, "variant frame {f} beyond the {frames}-frame stream");
            assert!(
                base.structurally_eq(v),
                "variant for frame {f} must match the template's job structure"
            );
            assert!(
                v.vdd == base.vdd && v.ext_mem_present == base.ext_mem_present,
                "variant for frame {f} must share the template's supply and external memories"
            );
            assert!(
                v.relock_s == base.relock_s,
                "variant for frame {f} must share the template's FLL relock (time base)"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::traffic::Traffic;

    fn job(engine: Engine, mode: OperatingMode, duration_s: f64, deps: &[JobId]) -> Job {
        multi(vec![engine], mode, duration_s, deps)
    }

    fn multi(engines: Vec<Engine>, mode: OperatingMode, duration_s: f64, deps: &[JobId]) -> Job {
        Job {
            label: "test",
            engines,
            op: OperatingPoint::new(mode, 0.8),
            duration_s,
            deps: deps.to_vec(),
            charges: vec![(Category::OtherSw, Component::Core, 1.0)],
        }
    }

    /// The flat category accumulator's index map must agree with
    /// [`Category::all`] — the transfer loop in `ExecCore::run` pairs the
    /// two by position, so a drift would silently mis-bucket the energy
    /// breakdown on every path at once.
    #[test]
    fn cat_index_matches_category_all_order() {
        let all = Category::all();
        assert_eq!(all.len(), N_CATS);
        for (i, c) in all.into_iter().enumerate() {
            assert_eq!(cat_index(c), i, "{c:?}");
        }
    }

    #[test]
    fn engine_indices_are_dense_and_ordered() {
        for (i, e) in Engine::ALL.iter().enumerate() {
            assert_eq!(e.index(), i, "{}", e.name());
        }
        assert_eq!(N_ENGINES, 11);
        assert!(Engine::Core(3).mode_locked() && Engine::Hwce.mode_locked());
        assert!(!Engine::UdmaAdc.mode_locked() && !Engine::ClusterDma.mode_locked());
        // clock-scaled movers: AXI-clock-derived service only
        assert!(Engine::ClusterDma.clock_scaled() && Engine::UdmaAdc.clock_scaled());
        assert!(!Engine::UdmaFlash.clock_scaled() && !Engine::UdmaFram.clock_scaled());
        assert!(!Engine::Hwce.clock_scaled());
    }

    #[test]
    fn serial_chain_sums_durations() {
        let mut g = JobGraph::new();
        let a = g.push(job(Engine::Core(0), OperatingMode::Sw, 1.0, &[]));
        let b = g.push(job(Engine::Core(0), OperatingMode::Sw, 2.0, &[a]));
        g.push(job(Engine::Core(0), OperatingMode::Sw, 3.0, &[b]));
        let r = Scheduler::run(&g);
        assert!((r.makespan_s - 6.0).abs() < 1e-12);
        assert_eq!(r.mode_switches, 0);
        assert!((r.busy_s[Engine::Core(0).index()] - 6.0).abs() < 1e-12);
        assert_eq!(r.overlap_s, 0.0);
        assert_eq!(r.peak_resident_jobs, 3);
    }

    #[test]
    fn independent_engines_overlap() {
        let mut g = JobGraph::new();
        g.push(job(Engine::Core(0), OperatingMode::Sw, 2.0, &[]));
        g.push(job(Engine::UdmaFlash, OperatingMode::Sw, 1.5, &[]));
        g.push(job(Engine::UdmaFram, OperatingMode::Sw, 1.0, &[]));
        let r = Scheduler::run(&g);
        assert!((r.makespan_s - 2.0).abs() < 1e-12, "I/O must hide under compute");
        assert!((r.overlap_s - 1.5).abs() < 1e-12, "overlap {}", r.overlap_s);
    }

    #[test]
    fn same_engine_serializes() {
        let mut g = JobGraph::new();
        g.push(job(Engine::UdmaFram, OperatingMode::Sw, 1.0, &[]));
        g.push(job(Engine::UdmaFram, OperatingMode::Sw, 1.0, &[]));
        let r = Scheduler::run(&g);
        assert!((r.makespan_s - 2.0).abs() < 1e-12);
    }

    #[test]
    fn multi_engine_job_occupies_all_its_cores() {
        // a 2-core phase on {0,1} blocks a core-1 job but not a core-2 job
        let mut g = JobGraph::new();
        g.push(multi(
            vec![Engine::Core(0), Engine::Core(1)],
            OperatingMode::Sw,
            2.0,
            &[],
        ));
        g.push(job(Engine::Core(1), OperatingMode::Sw, 1.0, &[]));
        g.push(job(Engine::Core(2), OperatingMode::Sw, 1.0, &[]));
        let r = Scheduler::run(&g);
        assert!((r.makespan_s - 3.0).abs() < 1e-12, "core1 job must wait: {}", r.makespan_s);
        assert!((r.busy_s[Engine::Core(1).index()] - 3.0).abs() < 1e-12);
        assert!((r.busy_s[Engine::Core(2).index()] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn mode_switch_costs_relock() {
        let mut g = JobGraph::new();
        let a = g.push(job(Engine::Hwce, OperatingMode::KecCnnSw, 1.0, &[]));
        let b = g.push(job(Engine::HwcryptAes, OperatingMode::CryCnnSw, 1.0, &[a]));
        g.push(job(Engine::Hwce, OperatingMode::KecCnnSw, 1.0, &[b]));
        let r = Scheduler::run(&g);
        // a 1 s KEC job under the CRY clock would cost ≈0.22 s — far more
        // than the relock, so both boundaries pay the genuine switch
        assert_eq!(r.mode_switches, 2);
        assert!((r.makespan_s - (3.0 + 2.0 * MODE_SWITCH_S)).abs() < 1e-9);
    }

    #[test]
    fn long_incompatible_jobs_serialize_without_deps() {
        // No dependency between them, and hosting a 1 s KEC job at the CRY
        // clock would cost more than a relock — the shared cluster clock
        // serializes them.
        let mut g = JobGraph::new();
        g.push(job(Engine::Hwce, OperatingMode::KecCnnSw, 1.0, &[]));
        g.push(job(Engine::HwcryptAes, OperatingMode::CryCnnSw, 1.0, &[]));
        let r = Scheduler::run(&g);
        assert!(r.makespan_s >= 2.0, "mode exclusivity violated: {}", r.makespan_s);
        assert_eq!(r.mode_switches, 1);
        assert_eq!(r.coresidency_s, 0.0);
    }

    /// The co-residency rule: a short lower-capability job rides inside
    /// the current all-capable window instead of forcing a relock.
    #[test]
    fn short_subsumed_job_co_resides_free() {
        let mut g = JobGraph::new();
        g.push(job(Engine::HwcryptAes, OperatingMode::CryCnnSw, 1.0, &[]));
        let tiny = 1e-6; // rescale penalty ≈ 0.22 µs < 10 µs relock
        g.push(job(Engine::Hwce, OperatingMode::KecCnnSw, tiny, &[]));
        g.push(job(Engine::Core(2), OperatingMode::Sw, tiny, &[]));
        let r = Scheduler::run(&g);
        assert_eq!(r.mode_switches, 0, "subsumed jobs must not relock");
        assert!((r.makespan_s - 1.0).abs() < 1e-9, "makespan {}", r.makespan_s);
        assert!(r.coresidency_s > 0.0, "cluster co-residency must be visible");
        // hosted at the slower CRY clock, the KEC job's as-run busy time
        // stretches by the frequency ratio
        let hosted = tiny * OperatingMode::KecCnnSw.fmax_nominal_mhz()
            / OperatingMode::CryCnnSw.fmax_nominal_mhz();
        assert!((r.busy_s[Engine::Hwce.index()] - hosted).abs() < 1e-12);
    }

    /// A long subsumed job prefers its own mode window: the rescale
    /// penalty exceeds the relock, so it waits and switches.
    #[test]
    fn long_subsumed_job_takes_its_own_window() {
        let mut g = JobGraph::new();
        g.push(job(Engine::HwcryptAes, OperatingMode::CryCnnSw, 1.0, &[]));
        g.push(job(Engine::Core(0), OperatingMode::Sw, 1.0, &[]));
        let r = Scheduler::run(&g);
        assert_eq!(r.mode_switches, 1);
        assert!((r.makespan_s - (2.0 + MODE_SWITCH_S)).abs() < 1e-9);
    }

    #[test]
    fn same_mode_engines_do_overlap() {
        let mut g = JobGraph::new();
        g.push(job(Engine::Hwce, OperatingMode::KecCnnSw, 2.0, &[]));
        g.push(job(Engine::HwcryptKec, OperatingMode::KecCnnSw, 2.0, &[]));
        let r = Scheduler::run(&g);
        assert!((r.makespan_s - 2.0).abs() < 1e-12);
        assert_eq!(r.mode_switches, 0);
        assert!((r.coresidency_s - 2.0).abs() < 1e-12);
    }

    #[test]
    fn first_mode_entry_is_free() {
        let mut g = JobGraph::new();
        g.push(job(Engine::Hwce, OperatingMode::KecCnnSw, 1.0, &[]));
        let r = Scheduler::run(&g);
        assert_eq!(r.mode_switches, 0);
        assert!((r.makespan_s - 1.0).abs() < 1e-12);
    }

    /// Satellite fix (ROADMAP): clock-derived SOC movers rescale with the
    /// hosting cluster point at dispatch instead of staying pinned at
    /// their emission-mode clock; the device-bandwidth-bound flash/FRAM
    /// channels do not.
    #[test]
    fn dma_service_rescales_with_hosting_point() {
        // A long CRY cluster job establishes the hosting point; the
        // cluster DMA and ADC burst were emitted at the KEC clock and must
        // stretch by f_KEC / f_CRY, the FRAM transfer must not.
        let d = 0.01;
        let mut g = JobGraph::new();
        g.push(job(Engine::HwcryptAes, OperatingMode::CryCnnSw, 1.0, &[]));
        g.push(job(Engine::ClusterDma, OperatingMode::KecCnnSw, d, &[]));
        g.push(job(Engine::UdmaAdc, OperatingMode::KecCnnSw, d, &[]));
        g.push(job(Engine::UdmaFram, OperatingMode::KecCnnSw, d, &[]));
        let r = Scheduler::run(&g);
        let hosted = d * OperatingMode::KecCnnSw.fmax_nominal_mhz()
            / OperatingMode::CryCnnSw.fmax_nominal_mhz();
        assert!(
            (r.busy_s[Engine::ClusterDma.index()] - hosted).abs() < 1e-15,
            "DMA busy {} vs hosted {hosted}",
            r.busy_s[Engine::ClusterDma.index()]
        );
        assert!((r.busy_s[Engine::UdmaAdc.index()] - hosted).abs() < 1e-15);
        assert!(
            (r.busy_s[Engine::UdmaFram.index()] - d).abs() < 1e-15,
            "FRAM is device-bandwidth bound, not clock-scaled"
        );
        // same-mode hosting is a no-op: emitted at CRY, hosted at CRY
        let mut g2 = JobGraph::new();
        g2.push(job(Engine::HwcryptAes, OperatingMode::CryCnnSw, 1.0, &[]));
        g2.push(job(Engine::ClusterDma, OperatingMode::CryCnnSw, d, &[]));
        let r2 = Scheduler::run(&g2);
        assert_eq!(r2.busy_s[Engine::ClusterDma.index()].to_bits(), d.to_bits());
        // with no cluster point set, the emission clock stands
        let mut g3 = JobGraph::new();
        g3.push(job(Engine::ClusterDma, OperatingMode::KecCnnSw, d, &[]));
        let r3 = Scheduler::run(&g3);
        assert_eq!(r3.busy_s[Engine::ClusterDma.index()].to_bits(), d.to_bits());
    }

    #[test]
    fn analytic_matches_run_on_serial_cluster_graph() {
        let mut g = JobGraph::new();
        let mut prev: Option<JobId> = None;
        for i in 0..6 {
            let mode = if i % 2 == 0 { OperatingMode::KecCnnSw } else { OperatingMode::CryCnnSw };
            let engine = if i % 2 == 0 { Engine::Hwce } else { Engine::HwcryptAes };
            let deps: Vec<JobId> = prev.into_iter().collect();
            prev = Some(g.push(job(engine, mode, 0.5, &deps)));
        }
        let run = Scheduler::run(&g);
        let ana = g.analytic();
        assert!((run.makespan_s - ana.makespan_s).abs() < 1e-9);
        assert_eq!(run.mode_switches, ana.mode_switches);
        assert!((run.ledger.total_mj() - ana.ledger.total_mj()).abs() < 1e-9);
    }

    #[test]
    fn analytic_hides_io_behind_compute() {
        let mut g = JobGraph::new();
        g.push(job(Engine::UdmaFram, OperatingMode::Sw, 1.0, &[]));
        g.push(job(Engine::Core(0), OperatingMode::Sw, 3.0, &[]));
        let ana = g.analytic();
        assert!((ana.makespan_s - 3.0).abs() < 1e-12);
        // I/O-dominated: the surplus lands on the critical path.
        let mut g2 = JobGraph::new();
        g2.push(job(Engine::UdmaFram, OperatingMode::Sw, 5.0, &[]));
        g2.push(job(Engine::Core(0), OperatingMode::Sw, 3.0, &[]));
        let ana2 = g2.analytic();
        assert!((ana2.makespan_s - 5.0).abs() < 1e-12);
    }

    #[test]
    fn repeat_streams_through_shared_engines() {
        // frame: long compute + short store that depends on it
        let mut g = JobGraph::new();
        let c = g.push(job(Engine::Core(0), OperatingMode::Sw, 2.0, &[]));
        g.push(job(Engine::UdmaFram, OperatingMode::Sw, 1.0, &[c]));
        let single = Scheduler::run(&g);
        assert!((single.makespan_s - 3.0).abs() < 1e-12);
        let four = Scheduler::run(&g.repeat(4));
        // stores of frame f overlap compute of frame f+1: 4×2 + trailing 1
        assert!((four.makespan_s - 9.0).abs() < 1e-12, "stream {}", four.makespan_s);
        assert!(four.makespan_s < 4.0 * single.makespan_s);
    }

    #[test]
    fn streaming_never_slower_than_serial_frames() {
        let mut g = JobGraph::new();
        let a = g.push(job(Engine::Hwce, OperatingMode::KecCnnSw, 0.3, &[]));
        let b = g.push(job(Engine::HwcryptAes, OperatingMode::CryCnnSw, 0.2, &[a]));
        g.push(job(Engine::UdmaFram, OperatingMode::Sw, 0.4, &[b]));
        let single = Scheduler::run(&g).makespan_s;
        for frames in [2usize, 5] {
            let stream = Scheduler::run(&g.repeat(frames)).makespan_s;
            assert!(
                stream <= frames as f64 * single + 1e-9,
                "{frames} frames: {stream} > {}",
                frames as f64 * single
            );
        }
    }

    /// The indexed dispatcher must reproduce the legacy linear scan
    /// bitwise on graphs exercising every dispatch rule: per-engine
    /// queues, co-residency, switch grants and clock-scaled movers.
    #[test]
    fn indexed_dispatch_matches_scan_reference() {
        let mut g = JobGraph::new();
        let a = g.push(job(Engine::Hwce, OperatingMode::KecCnnSw, 0.4, &[]));
        g.push(job(Engine::HwcryptAes, OperatingMode::CryCnnSw, 0.5, &[]));
        g.push(job(Engine::Hwce, OperatingMode::KecCnnSw, 1e-6, &[]));
        let s = g.push(multi(
            vec![Engine::Core(0), Engine::Core(1)],
            OperatingMode::Sw,
            0.3,
            &[a],
        ));
        g.push(job(Engine::ClusterDma, OperatingMode::KecCnnSw, 0.05, &[]));
        g.push(job(Engine::UdmaAdc, OperatingMode::Sw, 0.02, &[s]));
        g.push(job(Engine::UdmaFram, OperatingMode::Sw, 0.2, &[]));
        g.push(job(Engine::Core(2), OperatingMode::Sw, 1e-6, &[]));
        for graph in [g.clone(), g.repeat(3)] {
            let fast = Scheduler::run(&graph);
            let scan = Scheduler::run_scan(&graph);
            assert_eq!(fast.makespan_s.to_bits(), scan.makespan_s.to_bits());
            assert_eq!(fast.mode_switches, scan.mode_switches);
            assert_eq!(fast.ledger.total_mj().to_bits(), scan.ledger.total_mj().to_bits());
            for e in Engine::ALL {
                assert_eq!(
                    fast.busy_s[e.index()].to_bits(),
                    scan.busy_s[e.index()].to_bits(),
                    "{}",
                    e.name()
                );
            }
            assert!((fast.overlap_s - scan.overlap_s).abs() < 1e-12);
            assert!((fast.coresidency_s - scan.coresidency_s).abs() < 1e-12);
        }
    }

    /// Tentpole contract: a window covering the whole stream reproduces
    /// the materialized repeat bitwise; tighter windows still complete,
    /// stay within the serialization bound, and hold only O(window) jobs.
    #[test]
    fn windowed_stream_matches_materialized_when_window_covers() {
        let mut g = JobGraph::new();
        let c = g.push(job(Engine::Hwce, OperatingMode::KecCnnSw, 0.3, &[]));
        let x = g.push(job(Engine::HwcryptAes, OperatingMode::CryCnnSw, 0.1, &[c]));
        let d = g.push(job(Engine::ClusterDma, OperatingMode::CryCnnSw, 0.05, &[x]));
        g.push(job(Engine::UdmaFram, OperatingMode::Sw, 0.2, &[d]));
        let frames = 5usize;
        let mat = Scheduler::run(&g.repeat(frames));
        for window in [frames, frames + 3, 64] {
            let win = StreamScheduler::run(&g, frames, window);
            assert_eq!(win.makespan_s.to_bits(), mat.makespan_s.to_bits(), "window {window}");
            assert_eq!(win.mode_switches, mat.mode_switches);
            assert_eq!(win.ledger.total_mj().to_bits(), mat.ledger.total_mj().to_bits());
            for cat in Category::all() {
                assert_eq!(
                    win.ledger.energy_mj(cat).to_bits(),
                    mat.ledger.energy_mj(cat).to_bits(),
                    "{cat:?}"
                );
            }
            for e in Engine::ALL {
                assert_eq!(win.busy_s[e.index()].to_bits(), mat.busy_s[e.index()].to_bits());
            }
            assert!((win.overlap_s - mat.overlap_s).abs() < 1e-12);
            assert_eq!(win.peak_resident_jobs, g.len() * frames);
        }
        for window in [1usize, 2] {
            let win = StreamScheduler::run(&g, frames, window);
            assert_eq!(win.n_jobs, g.len() * frames);
            assert!(win.makespan_s <= frames as f64 * g.serialized_bound() + 1e-9);
            assert!(win.peak_resident_jobs <= window * g.len(), "window {window}");
            // a bounded window can only delay admissions, never break the
            // per-frame pipeline: it is no faster than the full window
            assert!(win.makespan_s >= mat.makespan_s - 1e-12);
        }
    }

    /// O(window) residency: the peak live-job count of the windowed path
    /// depends on the window, not the stream length.
    #[test]
    fn windowed_stream_peak_residency_is_frame_count_independent() {
        let mut g = JobGraph::new();
        let c = g.push(job(Engine::Core(0), OperatingMode::Sw, 0.1, &[]));
        g.push(job(Engine::UdmaFram, OperatingMode::Sw, 0.05, &[c]));
        let w = 3usize;
        let a = StreamScheduler::run(&g, 8, w);
        let b = StreamScheduler::run(&g, 64, w);
        assert_eq!(a.peak_resident_jobs, b.peak_resident_jobs);
        assert!(a.peak_resident_jobs <= w * g.len());
        // while the materialized path scales with the stream length
        assert_eq!(Scheduler::run(&g.repeat(64)).peak_resident_jobs, 64 * g.len());
    }

    #[test]
    fn busy_never_exceeds_makespan() {
        let mut g = JobGraph::new();
        let mut prev = Vec::new();
        for i in 0..22 {
            let e = Engine::ALL[i % N_ENGINES];
            let deps: Vec<JobId> = prev.clone();
            prev = vec![g.push(job(e, OperatingMode::Sw, 0.01 * (i + 1) as f64, &deps))];
        }
        let r = Scheduler::run(&g);
        for e in Engine::ALL {
            assert!(r.busy_s[e.index()] <= r.makespan_s + 1e-9, "{}", e.name());
        }
        let total: f64 = r.busy_s.iter().sum();
        assert!(total <= r.makespan_s * N_ENGINES as f64 + 1e-9);
        assert!(r.makespan_s <= g.serialized_bound() + 1e-9);
    }

    #[test]
    fn serialized_bound_holds_with_coresidency_and_switches() {
        let mut g = JobGraph::new();
        g.push(job(Engine::HwcryptAes, OperatingMode::CryCnnSw, 0.5, &[]));
        g.push(job(Engine::Hwce, OperatingMode::KecCnnSw, 1e-6, &[]));
        g.push(job(Engine::Core(0), OperatingMode::Sw, 0.4, &[]));
        g.push(job(Engine::Hwce, OperatingMode::KecCnnSw, 0.3, &[]));
        g.push(job(Engine::UdmaFram, OperatingMode::Sw, 0.2, &[]));
        g.push(job(Engine::ClusterDma, OperatingMode::Sw, 0.1, &[]));
        let r = Scheduler::run(&g);
        assert!(r.makespan_s <= g.serialized_bound() + 1e-9);
    }

    #[test]
    fn segments_attribute_active_energy() {
        let mut g = JobGraph::new();
        g.mark_segment("a");
        g.push(job(Engine::Core(0), OperatingMode::Sw, 2.0, &[]));
        g.mark_segment("b");
        g.push(job(Engine::Core(0), OperatingMode::Sw, 1.0, &[]));
        g.mark_segment("empty"); // trailing marker with no jobs
        let seg = g.segment_active_mj();
        assert_eq!(seg.len(), 3);
        assert_eq!(seg[0].0, "a");
        assert_eq!(seg[1].0, "b");
        assert_eq!(seg[2], ("empty".to_string(), 0.0), "empty tenants keep a zero row");
        assert!((seg[0].1 - 2.0 * seg[1].1).abs() < 1e-12, "a charges 2x b's interval");
        let total: f64 = seg.iter().map(|(_, mj)| mj).sum();
        assert!((total - g.active_mj()).abs() < 1e-12);
        // streaming re-marks each frame's segments and aggregates by label
        let g4 = g.repeat(4);
        assert_eq!(g4.segments.len(), 12);
        let seg4 = g4.segment_active_mj();
        assert_eq!(seg4.len(), 3, "labels aggregate across frames");
        assert!((seg4[0].1 - 4.0 * seg[0].1).abs() < 1e-12);
    }

    /// Regression for the quadratic per-marker label scan: labels are
    /// interned once, markers carry indices, and heavy repetition (many
    /// streamed frames × few tenants) neither clones strings per frame
    /// nor rescans rows per marker.
    #[test]
    fn segment_labels_interned_across_heavy_repetition() {
        let mut g = JobGraph::new();
        for i in 0..30 {
            g.mark_segment(if i % 3 == 0 { "alpha" } else if i % 3 == 1 { "beta" } else { "gamma" });
            g.push(job(Engine::Core(0), OperatingMode::Sw, 0.001 * (i + 1) as f64, &[]));
        }
        assert_eq!(g.segment_labels.len(), 3, "three distinct tenants");
        assert_eq!(g.segments.len(), 30);
        let base = g.segment_active_mj();
        assert_eq!(base.len(), 3);
        let frames = 500usize;
        let big = g.repeat(frames);
        assert_eq!(big.segment_labels.len(), 3, "repeat must not duplicate labels");
        assert_eq!(big.segments.len(), 30 * frames);
        let seg = big.segment_active_mj();
        assert_eq!(seg.len(), 3);
        for ((l0, v0), (l1, v1)) in base.iter().zip(&seg) {
            assert_eq!(l0, l1);
            assert!(
                (v1 - frames as f64 * v0).abs() < 1e-9 * (1.0 + v1.abs()),
                "{l0}: {v1} vs {frames}x{v0}"
            );
        }
        let total: f64 = seg.iter().map(|(_, mj)| mj).sum();
        assert!((total - big.active_mj()).abs() < 1e-9 * (1.0 + total));
    }

    #[test]
    fn empty_graph_is_trivial() {
        let g = JobGraph::new();
        let r = Scheduler::run(&g);
        assert_eq!(r.makespan_s, 0.0);
        assert_eq!(r.n_jobs, 0);
        assert_eq!(r.ledger.total_mj(), 0.0);
        assert_eq!(r.overlap_s, 0.0);
    }

    #[test]
    #[should_panic(expected = "not-yet-pushed")]
    fn forward_dependency_rejected() {
        let mut g = JobGraph::new();
        g.push(job(Engine::Core(0), OperatingMode::Sw, 1.0, &[3]));
    }

    #[test]
    #[should_panic(expected = "occupies no engine")]
    fn engineless_job_rejected() {
        let mut g = JobGraph::new();
        g.push(multi(vec![], OperatingMode::Sw, 1.0, &[]));
    }

    #[test]
    #[should_panic(expected = "at least one in-flight frame")]
    fn zero_window_stream_rejected() {
        let mut g = JobGraph::new();
        g.push(job(Engine::Core(0), OperatingMode::Sw, 1.0, &[]));
        StreamScheduler::run(&g, 4, 0);
    }

    #[test]
    fn energy_charges_integrate_at_op() {
        use crate::soc::power::PowerModel;
        let mut g = JobGraph::new();
        g.push(job(Engine::Core(0), OperatingMode::Sw, 2.0, &[]));
        let r = Scheduler::run(&g);
        let op = OperatingPoint::new(OperatingMode::Sw, 0.8);
        let expect = PowerModel::active_mw(Component::Core, op) * 2.0;
        assert!((r.ledger.energy_mj(Category::OtherSw) - expect).abs() < 1e-9);
        // leakage charged over the makespan
        assert!(r.ledger.energy_mj(Category::Idle) > 0.0);
    }

    /// Rescaled co-resident execution leaves active energy untouched:
    /// cluster dynamic power is frequency-linear, so P·t is invariant.
    #[test]
    fn coresident_rescale_preserves_active_energy() {
        let mut g = JobGraph::new();
        g.push(job(Engine::HwcryptAes, OperatingMode::CryCnnSw, 1.0, &[]));
        g.push(job(Engine::Hwce, OperatingMode::KecCnnSw, 1e-6, &[]));
        let run = Scheduler::run(&g);
        let ana = g.analytic();
        let a = run.ledger.energy_mj(Category::OtherSw);
        let b = ana.ledger.energy_mj(Category::OtherSw);
        assert!((a - b).abs() < 1e-12 * (1.0 + a.abs()), "{a} vs {b}");
    }

    /// Bitwise agreement of two scheduler results — the fast-forward
    /// acceptance bar (time, energy, busy, overlap, residency).
    fn assert_bitwise(a: &SchedResult, b: &SchedResult, label: &str) {
        assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits(), "{label}: makespan");
        assert_eq!(a.mode_switches, b.mode_switches, "{label}: relocks");
        assert_eq!(a.n_jobs, b.n_jobs, "{label}: job count");
        assert_eq!(a.peak_resident_jobs, b.peak_resident_jobs, "{label}: peak residency");
        for cat in Category::all() {
            assert_eq!(
                a.ledger.energy_mj(cat).to_bits(),
                b.ledger.energy_mj(cat).to_bits(),
                "{label}: {cat:?} energy"
            );
        }
        for e in Engine::ALL {
            assert_eq!(
                a.busy_s[e.index()].to_bits(),
                b.busy_s[e.index()].to_bits(),
                "{label}: {} busy",
                e.name()
            );
        }
        assert_eq!(a.overlap_s.to_bits(), b.overlap_s.to_bits(), "{label}: overlap");
        assert_eq!(a.coresidency_s.to_bits(), b.coresidency_s.to_bits(), "{label}: coresidency");
        assert_eq!(a.sleep_s.to_bits(), b.sleep_s.to_bits(), "{label}: sleep");
        assert_eq!(a.deep_sleep_s.to_bits(), b.deep_sleep_s.to_bits(), "{label}: deep sleep");
        assert_eq!(a.wake_transitions, b.wake_transitions, "{label}: wake transitions");
        assert_eq!(a.frames_dropped, b.frames_dropped, "{label}: dropped frames");
        assert_eq!(a.fault_retries, b.fault_retries, "{label}: fault retries");
        assert_eq!(a.chip_resets, b.chip_resets, "{label}: chip resets");
        assert_eq!(a.state_loss_frames, b.state_loss_frames, "{label}: state-loss frames");
        assert_eq!(
            a.recovery_energy_mj.to_bits(),
            b.recovery_energy_mj.to_bits(),
            "{label}: recovery energy"
        );
    }

    /// A tiled-pipeline-shaped frame (fetch → decrypt → conv → epilogue →
    /// DMA per tile) that settles into a periodic steady state when
    /// streamed.
    fn pipeline_frame() -> JobGraph {
        let mut g = JobGraph::new();
        for t in 0..3usize {
            let f = g.push(job(Engine::UdmaFram, OperatingMode::Sw, 0.01 * (t + 1) as f64, &[]));
            let x = g.push(job(Engine::HwcryptAes, OperatingMode::CryCnnSw, 0.004, &[f]));
            let c = g.push(job(Engine::Hwce, OperatingMode::CryCnnSw, 0.02, &[x]));
            let e = g.push(multi(
                vec![Engine::Core(0), Engine::Core(1)],
                OperatingMode::CryCnnSw,
                0.003,
                &[c],
            ));
            g.push(job(Engine::ClusterDma, OperatingMode::CryCnnSw, 0.002, &[e]));
        }
        g
    }

    /// Tentpole contract: steady-state fast-forward is bitwise identical
    /// to the live windowed path, and it actually engages on a periodic
    /// stream (replaying most of the frames).
    #[test]
    fn fast_forward_matches_live_and_engages() {
        // simple serial chain: compute + store, strictly periodic
        let mut chain = JobGraph::new();
        let c = chain.push(job(Engine::Core(0), OperatingMode::Sw, 2.0, &[]));
        chain.push(job(Engine::UdmaFram, OperatingMode::Sw, 1.0, &[c]));
        let live = StreamScheduler::run_live(&chain, 64, 2);
        let ff = StreamScheduler::run(&chain, 64, 2);
        assert_bitwise(&ff, &live, "serial chain");
        assert_eq!(live.fast_forwarded_frames, 0, "live path must not replay");
        assert!(
            ff.fast_forwarded_frames >= 40,
            "only {} of 64 frames fast-forwarded",
            ff.fast_forwarded_frames
        );
        // pipeline-shaped frame across several windows
        let g = pipeline_frame();
        for window in [2usize, 4, 8] {
            let live = StreamScheduler::run_live(&g, 48, window);
            let ff = StreamScheduler::run(&g, 48, window);
            assert_bitwise(&ff, &live, &format!("pipeline w{window}"));
            assert!(ff.fast_forwarded_frames > 0, "window {window} never engaged");
        }
    }

    /// Below the detection warmup there is nothing to replay: short
    /// streams run fully live and stay bitwise identical.
    #[test]
    fn short_streams_never_fast_forward() {
        let g = pipeline_frame();
        for frames in [1usize, 2, 3] {
            for window in [1usize, 2, 8] {
                let live = StreamScheduler::run_live(&g, frames, window);
                let ff = StreamScheduler::run(&g, frames, window);
                assert_bitwise(&ff, &live, &format!("f{frames} w{window}"));
                assert_eq!(ff.fast_forwarded_frames, 0, "f{frames} w{window}");
            }
        }
    }

    /// Satellite fix: a window wider than the stream clamps to the stream
    /// length — identical schedule, and no phantom slots to account for.
    #[test]
    fn oversized_window_clamps_to_stream() {
        let g = pipeline_frame();
        let wide = StreamScheduler::run(&g, 3, 1024);
        let exact = StreamScheduler::run(&g, 3, 3);
        assert_bitwise(&wide, &exact, "clamped window");
        assert_eq!(wide.peak_resident_jobs, 3 * g.len());
    }

    /// Concatenate per-frame templates into one materialized graph (the
    /// reference for the variant streaming path).
    fn concat_frames(tpls: &[&JobGraph]) -> JobGraph {
        let mut out = JobGraph::new();
        out.ext_mem_present = tpls[0].ext_mem_present;
        let mut off = 0usize;
        for t in tpls {
            for jb in &t.jobs {
                let mut j = jb.clone();
                for d in &mut j.deps {
                    *d += off;
                }
                out.jobs.push(j);
            }
            off += t.jobs.len();
        }
        out
    }

    /// Satellite edge case: a mode-override variant mid-stream breaks
    /// periodicity — the scheduler must fall back to live execution around
    /// it (bitwise identical to the no-fast-forward path and to the
    /// materialized concatenation) and re-engage afterwards.
    #[test]
    fn mid_stream_variant_falls_back_to_live() {
        let base = pipeline_frame();
        // same structure, slower service times (e.g. hosted at a derated
        // point) — breaks the period at frame 17
        let mut variant = base.clone();
        for j in &mut variant.jobs {
            j.duration_s *= 3.0;
        }
        let frames = 40usize;
        let vats: [(usize, &JobGraph); 1] = [(17, &variant)];
        for window in [2usize, 4] {
            let live = StreamScheduler::run_with_variants_live(&base, frames, window, &vats);
            let ff = StreamScheduler::run_with_variants(&base, frames, window, &vats);
            assert_bitwise(&ff, &live, &format!("variant w{window}"));
            assert!(
                ff.fast_forwarded_frames > 0,
                "window {window}: must re-engage after the variant retires"
            );
            // the variant frame itself is never replayed
            assert!(ff.fast_forwarded_frames <= frames - 1);
        }
        // window >= frames: the whole stream materializes — compare against
        // the concatenated graph run through the single-shot scheduler
        let mut tpls: Vec<&JobGraph> = vec![&base; frames];
        tpls[17] = &variant;
        let mat = Scheduler::run(&concat_frames(&tpls));
        let full = StreamScheduler::run_with_variants(&base, frames, frames, &vats);
        assert_bitwise(&full, &mat, "variant materialized");
    }

    /// The compiled template records the same structure the job graph
    /// described (masks, roots, CSR successors, charge rows).
    #[test]
    fn compiled_frame_mirrors_graph_structure() {
        let g = pipeline_frame();
        let cf = CompiledFrame::compile(&g);
        assert_eq!(cf.len(), g.len());
        assert!(!cf.is_empty());
        for (i, jb) in g.jobs.iter().enumerate() {
            let mut mask = 0u16;
            for &e in &jb.engines {
                mask |= 1 << e.index();
            }
            assert_eq!(cf.engine_mask[i], mask, "job {i} mask");
            assert_eq!(cf.mode_locked[i], jb.mode_locked(), "job {i} ml");
            assert_eq!(cf.indeg0[i] as usize, jb.deps.len(), "job {i} indeg");
            for &d in &jb.deps {
                assert!(cf.succ_of(d).contains(&(i as u32)), "edge {d}->{i} lost");
            }
        }
        let total_rows: usize = g.jobs.iter().map(|j| j.charges.len()).sum();
        assert_eq!(cf.charge_mj.len(), total_rows);
        let sum: f64 = cf.charge_mj.iter().sum();
        assert!((sum - g.active_mj()).abs() < 1e-12 * (1.0 + sum), "charge rows vs active_mj");
    }

    #[test]
    #[should_panic(expected = "job structure")]
    fn structurally_different_variant_rejected() {
        let base = pipeline_frame();
        let mut other = JobGraph::new();
        other.push(job(Engine::Core(0), OperatingMode::Sw, 1.0, &[]));
        StreamScheduler::run_with_variants(&base, 8, 2, &[(3, &other)]);
    }

    // ---- traffic-gated admission ---------------------------------------

    /// A frame of `jobs` serial flash transfers, each an exact dyadic
    /// 2⁻¹⁰ s — all release/makespan arithmetic in the traffic tests below
    /// is exact, so equality asserts are bitwise, not toleranced.
    fn flash_frame(jobs: usize) -> JobGraph {
        let d = 1.0 / 1024.0;
        let mut g = JobGraph::new();
        let mut prev: Vec<JobId> = Vec::new();
        for _ in 0..jobs {
            let id = g.push(job(Engine::UdmaFlash, OperatingMode::Sw, d, &prev));
            prev = vec![id];
        }
        g
    }

    /// An all-zeros release table gates nothing: it must be bitwise the
    /// empty (back-to-back) table, including the fast-forward share — no
    /// release events exist, so even the recorded op logs are identical.
    #[test]
    fn zero_release_table_is_bitwise_back_to_back() {
        let g = flash_frame(1);
        let b2b = StreamScheduler::run(&g, 64, 8);
        let zeros = StreamScheduler::run_traffic(&g, 64, 8, &vec![0.0; 64]);
        assert_bitwise(&zeros, &b2b, "zeros vs b2b");
        assert_eq!(zeros.fast_forwarded_frames, b2b.fast_forwarded_frames);
        assert!(b2b.fast_forwarded_frames > 0, "baseline must engage");
    }

    /// Gap-dominated periodic traffic (sensor period 2× the frame
    /// makespan): the stream is input-starved, the release gaps become
    /// part of the frame-relative period proof, fast-forward still
    /// engages, and replay stays bitwise identical to live execution.
    #[test]
    fn gap_dominated_periodic_stream_engages_and_matches_live() {
        let g = flash_frame(1);
        let rel = Traffic::Periodic { rate_hz: 512.0 }.release_times(64);
        let live = StreamScheduler::run_traffic_live(&g, 64, 8, &rel);
        let ff = StreamScheduler::run_traffic(&g, 64, 8, &rel);
        assert_bitwise(&ff, &live, "periodic 512 Hz");
        assert_eq!(live.fast_forwarded_frames, 0);
        assert!(
            ff.fast_forwarded_frames >= 40,
            "only {} of 64 gap-dominated frames replayed",
            ff.fast_forwarded_frames
        );
        // frame f starts exactly at its release: makespan is the last
        // release plus one frame of service, bit-exactly.
        assert_eq!(ff.makespan_s.to_bits(), (63.0 / 512.0 + 1.0 / 1024.0).to_bits());
        // multi-job frames under the same starvation
        let g3 = flash_frame(3);
        let rel3 = Traffic::Periodic { rate_hz: 256.0 }.release_times(64);
        let live3 = StreamScheduler::run_traffic_live(&g3, 64, 8, &rel3);
        let ff3 = StreamScheduler::run_traffic(&g3, 64, 8, &rel3);
        assert_bitwise(&ff3, &live3, "periodic 256 Hz, 3 jobs");
        assert!(ff3.fast_forwarded_frames > 0);
    }

    /// Satellite: a sensor faster than the pipeline degrades to
    /// back-to-back — past releases gate nothing, there are no negative
    /// gaps, and the schedule is bitwise the ungated one.
    #[test]
    fn rate_limited_faster_than_makespan_degrades_to_back_to_back() {
        let g = flash_frame(1);
        // service d = 2⁻¹⁰ s; releases every d/2 — frame f's release is
        // in the past from frame 1 on.
        let rel = Traffic::Periodic { rate_hz: 2048.0 }.release_times(64);
        let fast = StreamScheduler::run_traffic(&g, 64, 8, &rel);
        let b2b = StreamScheduler::run(&g, 64, 8);
        assert_bitwise(&fast, &b2b, "fast periodic vs b2b");
        assert!(fast.fast_forwarded_frames > 0, "saturated stream must still engage");
        assert_bitwise(
            &fast,
            &StreamScheduler::run_traffic_live(&g, 64, 8, &rel),
            "fast periodic vs live",
        );
    }

    /// Satellite: a 6-frame burst beat is a period-6 steady state — the
    /// k ≤ 16 detector certifies and replays it, while a k ≤ 4 detector
    /// (the PR 5 cap, via the capped test hook) provably never engages.
    /// Both stay bitwise correct; the small cap just runs everything live.
    #[test]
    fn period_six_burst_needs_extended_detector() {
        let g = flash_frame(1);
        let traffic = Traffic::Bursty { burst: 6, rate_hz: 16.0 };
        let rel = traffic.release_times(126);
        let live = StreamScheduler::run_traffic_live(&g, 126, 8, &rel);
        let k16 = StreamScheduler::run_traffic(&g, 126, 8, &rel);
        assert_bitwise(&k16, &live, "burst k16");
        assert!(
            k16.fast_forwarded_frames >= 60,
            "period-6 beat must replay in 6-frame blocks, got {}",
            k16.fast_forwarded_frames
        );
        assert_eq!(k16.fast_forwarded_frames % 6, 0, "replay advances whole periods");
        let k4 = StreamScheduler::run_traffic_capped(&g, 126, 8, &rel, 4);
        assert_bitwise(&k4, &live, "burst k4");
        assert_eq!(
            k4.fast_forwarded_frames, 0,
            "a k ≤ 4 detector cannot certify a period-6 traffic beat"
        );
        // last burst releases at 20/16 s and drains serially, bit-exactly
        assert_eq!(k16.makespan_s.to_bits(), (20.0 / 16.0 + 6.0 / 1024.0).to_bits());
    }

    /// Satellite pin: a 30-frame GOP-style burst beat (ROADMAP's
    /// rate-control pattern) has period 30 — past the exact op-log window
    /// (k ≤ [`FF_MAX_PERIOD`]) — and is certified by the hash-signature
    /// stride detector (k ≤ [`FF_LONG_PERIOD`]), replaying whole periods
    /// bitwise; a k ≤ 16 detector provably never engages. The window is
    /// set wider than the burst so the admitted window always spans a
    /// burst boundary and no shorter pseudo-period can certify.
    #[test]
    fn period_thirty_gop_needs_stride_detector() {
        let g = flash_frame(1);
        let traffic = Traffic::Bursty { burst: 30, rate_hz: 16.0 };
        let rel = traffic.release_times(300);
        let live = StreamScheduler::run_traffic_live(&g, 300, 32, &rel);
        let k64 = StreamScheduler::run_traffic(&g, 300, 32, &rel);
        assert_bitwise(&k64, &live, "gop k64");
        assert!(
            k64.fast_forwarded_frames >= 30,
            "period-30 beat must replay in 30-frame blocks, got {}",
            k64.fast_forwarded_frames
        );
        assert_eq!(k64.fast_forwarded_frames % 30, 0, "replay advances whole periods");
        let k16 = StreamScheduler::run_traffic_capped(&g, 300, 32, &rel, 16);
        assert_bitwise(&k16, &live, "gop k16");
        assert_eq!(
            k16.fast_forwarded_frames, 0,
            "a k ≤ 16 detector cannot certify a period-30 GOP beat"
        );
        // last burst releases at 9/16 s and drains serially, bit-exactly
        assert_eq!(k64.makespan_s.to_bits(), (9.0 / 16.0 + 30.0 / 1024.0).to_bits());
    }

    // ---- parametric-class representatives ------------------------------

    /// Relative-tolerance comparison for members whose scale arithmetic
    /// is not exact in f64 (non-power-of-two α or φ > 0 with non-dyadic
    /// inputs): counts must match exactly, times and energies within
    /// `tol` relative.
    fn assert_close(a: &SchedResult, b: &SchedResult, tol: f64, label: &str) {
        let close = |x: f64, y: f64| (x - y).abs() <= tol * x.abs().max(y.abs()).max(1e-12);
        assert!(close(a.makespan_s, b.makespan_s), "{label}: makespan {} vs {}", a.makespan_s, b.makespan_s);
        assert_eq!(a.mode_switches, b.mode_switches, "{label}: relocks");
        assert_eq!(a.n_jobs, b.n_jobs, "{label}: job count");
        assert_eq!(a.wake_transitions, b.wake_transitions, "{label}: wake transitions");
        assert_eq!(a.peak_resident_jobs, b.peak_resident_jobs, "{label}: peak residency");
        assert_eq!(a.frames_dropped, b.frames_dropped, "{label}: dropped frames");
        assert_eq!(a.fault_retries, b.fault_retries, "{label}: fault retries");
        assert_eq!(a.chip_resets, b.chip_resets, "{label}: chip resets");
        assert_eq!(a.state_loss_frames, b.state_loss_frames, "{label}: state-loss frames");
        assert!(
            close(a.recovery_energy_mj, b.recovery_energy_mj),
            "{label}: recovery energy {} vs {}",
            a.recovery_energy_mj,
            b.recovery_energy_mj
        );
        for cat in Category::all() {
            assert!(
                close(a.ledger.energy_mj(cat), b.ledger.energy_mj(cat)),
                "{label}: {cat:?} energy {} vs {}",
                a.ledger.energy_mj(cat),
                b.ledger.energy_mj(cat)
            );
        }
        for e in Engine::ALL {
            assert!(close(a.busy_s[e.index()], b.busy_s[e.index()]), "{label}: {} busy", e.name());
        }
        assert!(close(a.sleep_s, b.sleep_s), "{label}: sleep");
        assert!(close(a.deep_sleep_s, b.deep_sleep_s), "{label}: deep sleep");
    }

    /// Tentpole contract, exact half: a power-of-two drift (φ = 0) makes
    /// the closed-form member derivation bitwise identical to live
    /// execution on the rescaled template — for every policy, with the
    /// representative running fast-forward and the reference running
    /// live, so the span profile is proven correct through replay too.
    #[test]
    fn param_member_pow2_drift_is_bitwise_exact() {
        let g = flash_frame(3);
        let rel = Traffic::Periodic { rate_hz: 256.0 }.release_times(64);
        let cf = CompiledFrame::compile(&g);
        for policy in [None, Some(PolicyKind::Greedy), Some(PolicyKind::Lookahead), Some(PolicyKind::Oracle)] {
            let rep = StreamScheduler::run_param_rep(&cf, 64, 8, &rel, policy);
            assert!(rep.release_anchored(), "gap-dominated periodic traffic is anchored");
            let ident = rep.member(&Perturb::IDENTITY).expect("identity always certifies");
            assert_bitwise(&ident, rep.result(), "identity member");
            for alpha in [0.5f64, 2.0] {
                let p = Perturb { alpha, phase_s: 0.0 };
                let derived = rep.member(&p).expect("power-of-two drift certifies");
                let mut shifted = rel.clone();
                p.apply(&mut shifted);
                let live = StreamScheduler::run_compiled_traffic_live_pm(
                    &cf.rescaled(alpha),
                    64,
                    8,
                    &shifted,
                    policy,
                );
                assert_bitwise(&derived, &live, &format!("alpha {alpha} policy {policy:?}"));
            }
        }
    }

    /// Tentpole contract, phase half: a release phase offset shifts the
    /// whole schedule rigidly (uniform-shift theorem) — the closed form
    /// matches live execution on the shifted table within the documented
    /// tolerance, for a pure offset and for a general drift + phase
    /// combination. (Not bitwise even for dyadic φ: the member folds φ in
    /// after the event chain, live folds it in before, and f64 addition
    /// is not associative — which is exactly why [`ParamRep::member`]
    /// only claims bit-exactness at φ = 0.)
    #[test]
    fn param_member_phase_offset_matches_live() {
        let g = flash_frame(3);
        let rel = Traffic::Periodic { rate_hz: 256.0 }.release_times(64);
        let cf = CompiledFrame::compile(&g);
        for policy in [None, Some(PolicyKind::Lookahead)] {
            let rep = StreamScheduler::run_param_rep(&cf, 64, 8, &rel, policy);
            // dyadic pure phase: counts exact, numerics within tolerance
            let p = Perturb { alpha: 1.0, phase_s: 1.0 / 1024.0 };
            let derived = rep.member(&p).expect("margin-backed phase certifies");
            let mut shifted = rel.clone();
            p.apply(&mut shifted);
            let live =
                StreamScheduler::run_compiled_traffic_live_pm(&cf, 64, 8, &shifted, policy);
            assert_close(&derived, &live, 1e-9, &format!("pure phase, policy {policy:?}"));
            // general drift + phase: 1e-9 relative, counts exact
            let p = Perturb { alpha: 1.5 + 1.0 / 4096.0, phase_s: 3.0 / 1048576.0 };
            let derived = rep.member(&p).expect("wide margins certify");
            let mut shifted = rel.clone();
            p.apply(&mut shifted);
            let live = StreamScheduler::run_compiled_traffic_live_pm(
                &cf.rescaled(p.alpha),
                64,
                8,
                &shifted,
                policy,
            );
            assert_close(&derived, &live, 1e-9, &format!("drift+phase, policy {policy:?}"));
        }
    }

    /// Satellite: the invariance certificate accepts what the
    /// uniform-shift theorem covers and *rejects* what it cannot bound —
    /// a phase offset into a busy chip still derives (and matches live),
    /// but a phase offset dwarfing the absolute event margins is refused,
    /// as is a non-exact drift when two events ran closer than the safety
    /// margin — and the live fallback on the rescaled template stays
    /// exact.
    #[test]
    fn param_certificate_rejects_unsafe_scales_and_falls_back() {
        // saturated traffic: releases land while the chip is busy — the
        // uniform-shift theorem still applies, so a modest phase offset
        // certifies and the closed form matches a live run on the
        // shifted table
        let g = flash_frame(1);
        let rel = Traffic::Periodic { rate_hz: 2048.0 }.release_times(32);
        let cf = CompiledFrame::compile(&g);
        let rep = StreamScheduler::run_param_rep(&cf, 32, 8, &rel, None);
        assert!(!rep.release_anchored(), "saturated releases land on a busy chip");
        let phased = Perturb { alpha: 1.0, phase_s: 1.0 / 4096.0 };
        assert!(rep.certify(&phased), "busy-landing releases still shift rigidly");
        let derived = rep.member(&phased).expect("certified phase derives");
        let mut shifted = rel.clone();
        phased.apply(&mut shifted);
        let live =
            StreamScheduler::run_compiled_traffic_live_pm(&cf, 32, 8, &shifted, None);
        assert_close(&derived, &live, 1e-9, "phase into busy chip");
        // ...but a phase offset so large it dwarfs the absolute event
        // margins (Δ/φ below the bar) must be refused
        let huge = Perturb { alpha: 1.0, phase_s: (1u64 << 30) as f64 };
        assert!(
            rep.min_abs_margin_s() / huge.phase_s < 2.0 * PARAM_MIN_MARGIN,
            "test premise: the offset must dominate the margins"
        );
        assert!(!rep.certify(&huge), "margin-dwarfing phase must be refused");
        assert!(rep.member(&huge).is_none(), "refused phase must fall back");
        // pure power-of-two drift stays certifiable on the same rep
        let halved = Perturb { alpha: 0.5, phase_s: 0.0 };
        let derived = rep.member(&halved).expect("pure pow2 drift is exact");
        let mut shifted = rel.clone();
        halved.apply(&mut shifted);
        let live =
            StreamScheduler::run_compiled_traffic_live_pm(&cf.rescaled(0.5), 32, 8, &shifted, None);
        assert_bitwise(&derived, &live, "drift on saturated traffic");

        // two engines completing 1e-12 apart: margin below the safety bar
        let mut tight = JobGraph::new();
        tight.push(job(Engine::UdmaFram, OperatingMode::Sw, 1.0, &[]));
        tight.push(job(Engine::UdmaFlash, OperatingMode::Sw, 1.0 + 1e-12, &[]));
        let tcf = CompiledFrame::compile(&tight);
        let trep = StreamScheduler::run_param_rep(&tcf, 16, 4, &[], None);
        assert!(
            trep.min_rel_margin() < PARAM_MIN_MARGIN,
            "margin {} must flag the near-tie",
            trep.min_rel_margin()
        );
        let drift = Perturb { alpha: 1.0 + 1.0 / 4096.0, phase_s: 0.0 };
        assert!(!trep.certify(&drift), "non-exact drift over a near-tie must be refused");
        assert!(trep.member(&drift).is_none());
        // the fallback — a live run on the rescaled template — is exact
        // and deterministic
        let a = StreamScheduler::run_compiled_traffic_live_pm(&tcf.rescaled(drift.alpha), 16, 4, &[], None);
        let b = StreamScheduler::run_compiled_traffic_live_pm(&tcf.rescaled(drift.alpha), 16, 4, &[], None);
        assert_bitwise(&a, &b, "fallback determinism");
        // while exact power-of-two scaling is exempt from the margin bar
        let exact = trep.member(&halved).expect("pow2 is exact regardless of margin");
        let lhalf = StreamScheduler::run_compiled_traffic_live_pm(&tcf.rescaled(0.5), 16, 4, &[], None);
        assert_bitwise(&exact, &lhalf, "pow2 under near-tie margins");
    }

    /// Poisson traffic is aperiodic, so engagement is seed-dependent —
    /// but replay must stay bitwise-safe for every seed, and a saturated
    /// trigger rate (gaps almost always in the past) converges to the
    /// back-to-back beat and engages for every seed tried.
    #[test]
    fn poisson_traffic_replays_bitwise_for_any_seed() {
        let g = flash_frame(1);
        for seed in 1..=20u64 {
            let rel = Traffic::Poisson { rate_hz: 682.0, seed }.release_times(64);
            let live = StreamScheduler::run_traffic_live(&g, 64, 8, &rel);
            let ff = StreamScheduler::run_traffic(&g, 64, 8, &rel);
            assert_bitwise(&ff, &live, &format!("poisson seed {seed}"));
        }
        let mut engaged = 0usize;
        for seed in 1..=10u64 {
            let rel = Traffic::Poisson { rate_hz: 8192.0, seed }.release_times(256);
            let live = StreamScheduler::run_traffic_live(&g, 256, 8, &rel);
            let ff = StreamScheduler::run_traffic(&g, 256, 8, &rel);
            assert_bitwise(&ff, &live, &format!("saturated poisson seed {seed}"));
            if ff.fast_forwarded_frames > 0 {
                engaged += 1;
            }
        }
        assert_eq!(engaged, 10, "saturated Poisson streams must all engage");
    }

    // ---- power-state management ----------------------------------------

    const POLICIES: [PolicyKind; 3] =
        [PolicyKind::Greedy, PolicyKind::Lookahead, PolicyKind::Oracle];

    /// Acceptance bar: sleep/wake accounting is bitwise identical
    /// between the live and fast-forward paths, per policy × traffic
    /// shape — the managed spans are part of the frame-relative cycle
    /// proof, so the fleet dedup parity guarantee survives `--policy`.
    #[test]
    fn policy_accounting_matches_live_per_policy_and_traffic() {
        let g = flash_frame(1);
        let cf = CompiledFrame::compile(&g);
        let tables: Vec<(String, Vec<f64>)> = [
            Traffic::Periodic { rate_hz: 512.0 },
            Traffic::Periodic { rate_hz: 64.0 },
            Traffic::Bursty { burst: 6, rate_hz: 16.0 },
            Traffic::Poisson { rate_hz: 200.0, seed: 3 },
            Traffic::Poisson { rate_hz: 2048.0, seed: 9 },
        ]
        .into_iter()
        .map(|t| (t.describe(), t.release_times(64)))
        .collect();
        for policy in POLICIES {
            for (name, rel) in &tables {
                let live =
                    StreamScheduler::run_traffic_live_pm(&g, 64, 8, rel, Some(policy));
                let ff = StreamScheduler::run_compiled_traffic_pm(
                    &cf, 64, 8, rel, Some(policy),
                );
                assert_bitwise(&ff, &live, &format!("{policy:?} over {name}"));
                assert_eq!(live.fast_forwarded_frames, 0);
                assert!(live.sleep_s > 0.0, "{policy:?} over {name} never slept");
            }
        }
        // The gap-dominated periodic stream must still engage under
        // management (the accounting rides the existing cycle proof).
        let rel = Traffic::Periodic { rate_hz: 512.0 }.release_times(64);
        for policy in POLICIES {
            let ff =
                StreamScheduler::run_compiled_traffic_pm(&cf, 64, 8, &rel, Some(policy));
            assert!(
                ff.fast_forwarded_frames >= 40,
                "{policy:?}: only {} frames replayed",
                ff.fast_forwarded_frames
            );
        }
    }

    /// A policy is accounting-only: the schedule (makespan, busy time,
    /// relocks, overlap) is bitwise the unmanaged one — only the idle
    /// billing and the sleep statistics differ.
    #[test]
    fn policy_never_changes_the_schedule() {
        let g = flash_frame(3);
        let rel = Traffic::Periodic { rate_hz: 128.0 }.release_times(48);
        let cf = CompiledFrame::compile(&g);
        let base = StreamScheduler::run_compiled_traffic(&cf, 48, 8, &rel);
        assert_eq!(base.sleep_s, 0.0);
        assert_eq!(base.wake_transitions, 0);
        for policy in POLICIES {
            let run =
                StreamScheduler::run_compiled_traffic_pm(&cf, 48, 8, &rel, Some(policy));
            assert_eq!(run.makespan_s.to_bits(), base.makespan_s.to_bits(), "{policy:?}");
            assert_eq!(run.mode_switches, base.mode_switches);
            assert_eq!(run.overlap_s.to_bits(), base.overlap_s.to_bits());
            for e in Engine::ALL {
                assert_eq!(run.busy_s[e.index()].to_bits(), base.busy_s[e.index()].to_bits());
            }
            assert!(run.sleep_s > 0.0, "{policy:?} never slept");
        }
    }

    /// The policy energy ordering on a gap-dominated stream: the oracle
    /// bounds lookahead from below, greedy from above, and every policy
    /// beats the unmanaged active-idle billing.
    #[test]
    fn policy_energy_ordering_on_gapped_stream() {
        let g = flash_frame(1);
        // 8 Hz sensor on a ~1 ms frame: ≈99 % of the makespan is gap,
        // far past every rung's baseline break-even.
        let rel = Traffic::Periodic { rate_hz: 8.0 }.release_times(64);
        let cf = CompiledFrame::compile(&g);
        let unmanaged = StreamScheduler::run_compiled_traffic(&cf, 64, 8, &rel);
        let e = |p| {
            StreamScheduler::run_compiled_traffic_pm(&cf, 64, 8, &rel, Some(p))
                .ledger
                .total_mj()
        };
        let (greedy, lookahead, oracle) =
            (e(PolicyKind::Greedy), e(PolicyKind::Lookahead), e(PolicyKind::Oracle));
        assert!(
            oracle <= lookahead && lookahead <= greedy,
            "oracle {oracle} lookahead {lookahead} greedy {greedy}"
        );
        assert!(
            greedy < unmanaged.ledger.total_mj(),
            "gap-dominated duty cycling must beat active idle: greedy {greedy} vs {}",
            unmanaged.ledger.total_mj()
        );
        // Sleep statistics: nearly the whole makespan rests, mostly deep.
        let run = StreamScheduler::run_compiled_traffic_pm(
            &cf, 64, 8, &rel, Some(PolicyKind::Lookahead),
        );
        assert!(run.sleep_s > 0.9 * run.makespan_s, "slept {} of {}", run.sleep_s, run.makespan_s);
        assert!(run.deep_sleep_s > 0.8 * run.sleep_s);
        // One wake per inter-frame gap (63) plus one FLL relock per
        // cluster-stall span (64 serial flash transfers).
        assert_eq!(run.wake_transitions, 127);
    }

    /// Back-to-back streams have no full-chip gaps: policies may only
    /// re-bill cluster stalls (the serial flash chain stalls the cluster
    /// for its whole runtime), and the totals stay ordered.
    #[test]
    fn policy_on_back_to_back_bills_stalls_only() {
        let g = flash_frame(1);
        let cf = CompiledFrame::compile(&g);
        for policy in POLICIES {
            let run = StreamScheduler::run_compiled_traffic_pm(&cf, 64, 8, &[], Some(policy));
            let live = StreamScheduler::run_traffic_live_pm(&g, 64, 8, &[], Some(policy));
            assert_bitwise(&run, &live, &format!("{policy:?} b2b"));
            assert_eq!(run.deep_sleep_s, 0.0, "{policy:?}: no full-chip gap exists");
        }
    }

    #[test]
    #[should_panic(expected = "non-decreasing")]
    fn decreasing_release_table_rejected() {
        let g = flash_frame(1);
        StreamScheduler::run_traffic(&g, 3, 2, &[0.0, 2.0, 1.0]);
    }

    #[test]
    #[should_panic(expected = "covers")]
    fn short_release_table_rejected() {
        let g = flash_frame(1);
        StreamScheduler::run_traffic(&g, 4, 2, &[0.0, 1.0]);
    }

    // ---- fault injection (crate::fault through the variant path) -------

    /// Empty variants through the traffic entry point are exactly the
    /// plain traffic path — the `faults: None` guarantee at the
    /// scheduler boundary, per policy.
    #[test]
    fn empty_variants_traffic_is_the_plain_traffic_path() {
        let g = flash_frame(2);
        let rel = Traffic::Periodic { rate_hz: 256.0 }.release_times(48);
        let cf = CompiledFrame::compile(&g);
        for policy in [None, Some(PolicyKind::Lookahead)] {
            let plain = StreamScheduler::run_compiled_traffic_pm(&cf, 48, 8, &rel, policy);
            let faulted =
                StreamScheduler::run_with_variants_traffic_pm(&g, 48, 8, &[], &rel, policy);
            assert_bitwise(&faulted, &plain, &format!("no variants, policy {policy:?}"));
            assert_eq!(faulted.fast_forwarded_frames, plain.fast_forwarded_frames);
        }
    }

    /// A seeded faulted gap-dominated stream: fast-forward suspends
    /// around every faulted frame, re-engages between them (ff share > 0
    /// — the ISSUE 9 acceptance bar), and the result is bitwise the live
    /// path's, per recovery policy.
    #[test]
    fn faulted_stream_replays_bitwise_and_reengages() {
        use crate::fault::{FaultModel, FaultPlan, Recovery};
        let g = flash_frame(1);
        let frames = 256usize;
        let rel = Traffic::Periodic { rate_hz: 512.0 }.release_times(frames);
        let model = FaultModel::parse("mixed:0.005:0.02:0.002:0.01:7").unwrap();
        for recovery in [Recovery::default(), Recovery::Degrade, Recovery::Reset] {
            let plan = FaultPlan::build(&model, recovery, &g, 0, frames, 8);
            assert!(!plan.variants.is_empty(), "the seeded table must fire");
            let vats = plan.variant_refs();
            for policy in [None, Some(PolicyKind::Lookahead)] {
                let live = StreamScheduler::run_with_variants_traffic_live_pm(
                    &g, frames, 8, &vats, &rel, policy,
                );
                let ff = StreamScheduler::run_with_variants_traffic_pm(
                    &g, frames, 8, &vats, &rel, policy,
                );
                assert_bitwise(&ff, &live, &format!("{recovery:?} under {policy:?}"));
                assert!(
                    ff.fast_forwarded_frames > 0,
                    "{recovery:?} under {policy:?}: replay must re-engage between faults"
                );
                assert!(ff.fast_forwarded_frames <= frames - plan.variants.len());
            }
        }
    }

    /// Faulted parametric representatives: a power-of-two drift member
    /// derives bitwise even when the class stream carries fault variants
    /// (the variants scale with the member like every other input), and
    /// the identity member is the representative itself.
    #[test]
    fn param_rep_with_fault_variants_derives_members_bitwise() {
        use crate::fault::{FaultModel, FaultPlan, Recovery};
        let g = flash_frame(3);
        let frames = 64usize;
        let rel = Traffic::Periodic { rate_hz: 256.0 }.release_times(frames);
        let cf = CompiledFrame::compile(&g);
        let model = FaultModel::parse("transient:0.05:11").unwrap();
        let plan = FaultPlan::build(&model, Recovery::default(), &g, 0, frames, 8);
        assert!(!plan.variants.is_empty());
        let compiled: Vec<(usize, CompiledFrame)> =
            plan.variants.iter().map(|(f, v)| (*f, CompiledFrame::compile(v))).collect();
        for policy in [None, Some(PolicyKind::Lookahead)] {
            let rep = StreamScheduler::run_param_rep_variants(
                &cf, &compiled, frames, 8, &rel, policy,
            );
            let ident = rep.member(&Perturb::IDENTITY).expect("identity always certifies");
            assert_bitwise(&ident, rep.result(), "faulted identity member");
            for alpha in [0.5f64, 2.0] {
                let p = Perturb { alpha, phase_s: 0.0 };
                let derived = rep.member(&p).expect("power-of-two drift certifies");
                let scaled: Vec<(usize, CompiledFrame)> =
                    compiled.iter().map(|(f, v)| (*f, v.rescaled(alpha))).collect();
                let mut shifted = rel.clone();
                p.apply(&mut shifted);
                let live = StreamScheduler::run_compiled_variants_traffic_pm(
                    &cf.rescaled(alpha),
                    &scaled,
                    frames,
                    8,
                    &shifted,
                    policy,
                    false,
                );
                assert_bitwise(&derived, &live, &format!("faulted alpha {alpha} {policy:?}"));
            }
        }
    }
}
