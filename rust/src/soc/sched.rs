//! Event-driven SoC scheduler: the whole chip as a set of [`Engine`]
//! resources consuming typed [`Job`]s from a dependency graph.
//!
//! The coordinator use cases (§IV) *emit* a [`JobGraph`] — convolutions,
//! cipher runs, software phases, DMA and external-memory transfers with
//! their data dependencies — and [`Scheduler::run`] advances simulated time
//! through a binary-heap event queue, dispatching each job as soon as its
//! dependencies have completed, its engine is free, and the cluster
//! operating mode allows it. Cross-engine concurrency (double-buffered DMA,
//! uDMA I/O under compute, HWCRYPT decrypting the next layer's weights
//! while the HWCE convolves the current one) falls out of the schedule
//! instead of being approximated by an analytic overlap term.
//!
//! ## Engines
//!
//! One entry per serially-busy resource of the Fulmine SoC: the core
//! complex (software jobs run on all configured cores at once, so the
//! complex is one resource), the HWCE, the two HWCRYPT datapaths, the
//! cluster DMA, and one uDMA channel per external interface (the uDMA
//! serves its peripherals on independent channels, §II).
//!
//! ## Operating modes
//!
//! The cluster-domain engines (cores + accelerators) share one clock and
//! one operating mode (§III-A). Jobs carry the [`OperatingPoint`] they run
//! at; the scheduler serializes cluster jobs of *different* modes and
//! charges the 10 µs FLL relock ([`MODE_SWITCH_S`]) on every switch. A
//! switch is only granted to the lowest-id ready cluster job, which keeps
//! the mode sequence faithful to program order and prevents later frames
//! of a stream from starving earlier ones. SOC-domain engines (cluster
//! DMA, uDMA) run in any mode — the uDMA works "even when the cluster is
//! in sleep mode" (§II).
//!
//! ## Energy
//!
//! Each job lists per-component charges; the busy interval is integrated
//! on the [`EnergyLedger`] at the job's operating point. Leakage and
//! external-memory standby are charged over the makespan. Active energy is
//! therefore schedule-independent; only the Idle/standby terms (≈1.5 mW)
//! vary with the schedule — which keeps scheduled results within a few
//! percent of [`JobGraph::analytic`], the phase-summation model the
//! figures of the paper were calibrated against.
//!
//! ## Streaming
//!
//! [`JobGraph::repeat`] concatenates N copies of a frame graph (dependency
//! edges stay within each frame). Scheduling the combined graph pipelines
//! successive frames through the engines: frame *f+1*'s I/O and
//! accelerator phases fill the stalls of frame *f*, which is where the
//! multi-frame throughput of `fulmine stream` comes from.

use crate::energy::{Category, EnergyLedger};
use crate::soc::opmodes::{OperatingMode, OperatingPoint, MODE_SWITCH_S, V_NOM};
use crate::soc::power::{Component, PowerModel, FLASH_STANDBY_MW, FRAM_STANDBY_MW};
use std::cmp::Ordering;
use std::collections::{BTreeSet, BinaryHeap};

/// A serially-busy hardware resource of the SoC.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Engine {
    /// The OR10N core complex (a software job occupies all its cores).
    Cores,
    /// HWCE convolution engine.
    Hwce,
    /// HWCRYPT AES datapath.
    HwcryptAes,
    /// HWCRYPT KECCAK sponge datapath.
    HwcryptKec,
    /// Cluster DMA (L2 ↔ TCDM).
    ClusterDma,
    /// uDMA channel serving the quad-SPI flash.
    UdmaFlash,
    /// uDMA channel serving the FRAM.
    UdmaFram,
}

/// Number of scheduled engines.
pub const N_ENGINES: usize = Engine::ALL.len();

impl Engine {
    /// Every engine, in declaration (= discriminant) order.
    pub const ALL: [Engine; 7] = [
        Engine::Cores,
        Engine::Hwce,
        Engine::HwcryptAes,
        Engine::HwcryptKec,
        Engine::ClusterDma,
        Engine::UdmaFlash,
        Engine::UdmaFram,
    ];

    /// Dense index for per-engine arrays (the enum discriminant, which by
    /// construction matches the position in [`Engine::ALL`]).
    pub fn index(self) -> usize {
        self as usize
    }

    /// Cluster-domain engines share the cluster clock and therefore the
    /// operating mode; SOC-domain movers do not.
    pub fn mode_locked(self) -> bool {
        matches!(self, Engine::Cores | Engine::Hwce | Engine::HwcryptAes | Engine::HwcryptKec)
    }

    pub fn name(self) -> &'static str {
        match self {
            Engine::Cores => "cores",
            Engine::Hwce => "hwce",
            Engine::HwcryptAes => "hwcrypt-aes",
            Engine::HwcryptKec => "hwcrypt-kec",
            Engine::ClusterDma => "cluster-dma",
            Engine::UdmaFlash => "udma-flash",
            Engine::UdmaFram => "udma-fram",
        }
    }
}

/// Identifier of a job within its [`JobGraph`] (its insertion index).
pub type JobId = usize;

/// One unit of work bound to an engine: a service time at an operating
/// point, dependencies on earlier jobs, and the energy charges to integrate
/// over the busy interval (`(category, component, multiplicity)` — e.g. a
/// 4-core software phase charges `Component::Core` with multiplicity 4).
#[derive(Debug, Clone)]
pub struct Job {
    pub label: &'static str,
    pub engine: Engine,
    pub op: OperatingPoint,
    pub duration_s: f64,
    pub deps: Vec<JobId>,
    pub charges: Vec<(Category, Component, f64)>,
}

/// A dependency graph of jobs. Acyclic by construction: dependencies must
/// point at already-pushed jobs.
#[derive(Debug, Clone)]
pub struct JobGraph {
    pub jobs: Vec<Job>,
    /// Whether external flash/FRAM are attached (their standby power is
    /// charged over the whole run); the pacemaker-class seizure platform
    /// has none (§IV-C).
    pub ext_mem_present: bool,
    /// Named segment markers `(label, first job id)` — see
    /// [`JobGraph::mark_segment`]. Empty for single-tenant graphs.
    pub segments: Vec<(String, JobId)>,
}

impl Default for JobGraph {
    fn default() -> Self {
        Self::new()
    }
}

impl JobGraph {
    pub fn new() -> Self {
        JobGraph { jobs: Vec::new(), ext_mem_present: true, segments: Vec::new() }
    }

    /// Open a named segment at the current end of the graph: jobs pushed
    /// from here until the next marker belong to `label`. Multi-tenant
    /// workloads use this to attribute active energy per tenant
    /// ([`JobGraph::segment_active_mj`]); repeating the same label
    /// aggregates (each streamed frame re-marks its tenants).
    pub fn mark_segment(&mut self, label: &str) {
        self.segments.push((label.to_string(), self.jobs.len()));
    }

    /// Append a job; its dependencies must reference earlier jobs, and all
    /// jobs of a graph must share one supply voltage (leakage is charged
    /// graph-wide at the first job's VDD).
    pub fn push(&mut self, job: Job) -> JobId {
        let id = self.jobs.len();
        for &d in &job.deps {
            assert!(d < id, "job {id} depends on not-yet-pushed job {d}");
        }
        if let Some(first) = self.jobs.first() {
            debug_assert!(
                job.op.vdd == first.op.vdd,
                "job {id} at {} V in a {} V graph — one graph, one supply",
                job.op.vdd,
                first.op.vdd
            );
        }
        self.jobs.push(job);
        id
    }

    pub fn len(&self) -> usize {
        self.jobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }

    /// Concatenate `frames` copies of this graph (streaming): dependency
    /// edges stay within each copy; pipelining across copies comes from the
    /// shared engines at schedule time.
    pub fn repeat(&self, frames: usize) -> JobGraph {
        let n = self.jobs.len();
        let mut out = JobGraph {
            jobs: Vec::with_capacity(n * frames),
            ext_mem_present: self.ext_mem_present,
            segments: Vec::with_capacity(self.segments.len() * frames),
        };
        for f in 0..frames {
            let off = f * n;
            for job in &self.jobs {
                let mut j = job.clone();
                for d in &mut j.deps {
                    *d += off;
                }
                out.jobs.push(j);
            }
            for (label, start) in &self.segments {
                out.segments.push((label.clone(), start + off));
            }
        }
        out
    }

    /// Active energy (mJ) of one job: its per-component charges integrated
    /// over its busy interval at its operating point — the same arithmetic
    /// [`JobGraph::finish_ledger`] feeds the [`EnergyLedger`], without the
    /// makespan-proportional leakage/standby terms.
    fn job_active_mj(job: &Job) -> f64 {
        job.charges
            .iter()
            .map(|&(_, comp, mult)| PowerModel::active_mw(comp, job.op) * job.duration_s * mult)
            .sum()
    }

    /// Total active energy of the graph (mJ), schedule-independent.
    pub fn active_mj(&self) -> f64 {
        self.jobs.iter().map(Self::job_active_mj).sum()
    }

    /// Active energy per segment label, in first-appearance order; jobs
    /// pushed before the first marker are unattributed. Labels repeated
    /// across markers (e.g. one per streamed frame) aggregate into one row,
    /// and a segment whose marker is followed by no jobs still reports a
    /// zero row (its tenant must not vanish from attribution).
    pub fn segment_active_mj(&self) -> Vec<(String, f64)> {
        let mut out: Vec<(String, f64)> = Vec::new();
        let row_of = |out: &mut Vec<(String, f64)>, label: &str| -> usize {
            match out.iter().position(|(l, _)| l == label) {
                Some(i) => i,
                None => {
                    out.push((label.to_string(), 0.0));
                    out.len() - 1
                }
            }
        };
        let mut next = 0usize; // next marker to cross
        let mut current: Option<usize> = None; // index into `out`
        for (id, job) in self.jobs.iter().enumerate() {
            while next < self.segments.len() && self.segments[next].1 <= id {
                current = Some(row_of(&mut out, self.segments[next].0.as_str()));
                next += 1;
            }
            if let Some(cur) = current {
                out[cur].1 += Self::job_active_mj(job);
            }
        }
        // trailing markers past the last job
        for (label, _) in &self.segments[next..] {
            row_of(&mut out, label);
        }
        out
    }

    /// The supply voltage the graph runs at (jobs all share the builder's
    /// `ExecConfig`); nominal when the graph is empty.
    fn vdd(&self) -> f64 {
        self.jobs.first().map(|j| j.op.vdd).unwrap_or(V_NOM)
    }

    /// Integrate every job's charges plus makespan-proportional leakage and
    /// external-memory standby into a ledger whose elapsed time is
    /// `makespan_s`.
    fn finish_ledger(&self, makespan_s: f64) -> EnergyLedger {
        let mut ledger = EnergyLedger::new();
        for job in &self.jobs {
            for &(cat, comp, mult) in &job.charges {
                ledger.charge(cat, comp, job.op, job.duration_s * mult);
            }
        }
        // Leakage is mode-independent (it scales only with VDD), so one
        // charge over the makespan equals the per-phase charges of the
        // analytic model.
        let leak_op = OperatingPoint::new(OperatingMode::Sw, self.vdd());
        ledger.charge(Category::Idle, Component::ClusterLeak, leak_op, makespan_s);
        ledger.charge(Category::Idle, Component::SocLeak, leak_op, makespan_s);
        if self.ext_mem_present {
            ledger.charge_mj(Category::ExtMem, (FLASH_STANDBY_MW + FRAM_STANDBY_MW) * makespan_s);
        }
        ledger.advance(makespan_s);
        ledger
    }

    /// Per-engine total service time (schedule-independent).
    fn busy_totals(&self) -> [f64; N_ENGINES] {
        let mut busy = [0.0; N_ENGINES];
        for job in &self.jobs {
            busy[job.engine.index()] += job.duration_s;
        }
        busy
    }

    /// The phase-summation reference model (the pre-scheduler coordinator):
    /// cluster jobs serialize in emission order with FLL relock on every
    /// mode change, while DMA/uDMA time accumulates in an I/O backlog that
    /// the cluster phases drain (double buffering); whatever backlog
    /// survives lands on the critical path at the end. This reproduces the
    /// analytic `Pipeline` numbers the Fig. 10/11/12 bands were calibrated
    /// against, and serves as the correctness reference for
    /// [`Scheduler::run`] (see `rust/tests/scheduler.rs`).
    pub fn analytic(&self) -> SchedResult {
        let mut elapsed = 0.0f64;
        let mut backlog = 0.0f64;
        let mut last_mode: Option<OperatingMode> = None;
        let mut switches = 0u64;
        for job in &self.jobs {
            if job.engine.mode_locked() {
                if last_mode != Some(job.op.mode) {
                    if last_mode.is_some() {
                        switches += 1;
                        elapsed += MODE_SWITCH_S;
                        backlog = (backlog - MODE_SWITCH_S).max(0.0);
                    }
                    last_mode = Some(job.op.mode);
                }
                elapsed += job.duration_s;
                backlog = (backlog - job.duration_s).max(0.0);
            } else {
                backlog += job.duration_s;
            }
        }
        elapsed += backlog;
        SchedResult {
            ledger: self.finish_ledger(elapsed),
            makespan_s: elapsed,
            mode_switches: switches,
            busy_s: self.busy_totals(),
            n_jobs: self.jobs.len(),
        }
    }
}

/// Outcome of scheduling a [`JobGraph`].
#[derive(Debug, Clone)]
pub struct SchedResult {
    pub ledger: EnergyLedger,
    /// Completion time of the last job (simulated seconds).
    pub makespan_s: f64,
    /// FLL relocks performed.
    pub mode_switches: u64,
    /// Total busy time per engine, indexed by [`Engine::index`].
    pub busy_s: [f64; N_ENGINES],
    pub n_jobs: usize,
}

/// Completion event: min-heap by time (ties broken by job id) on top of
/// `std`'s max-heap.
struct Ev {
    t: f64,
    job: JobId,
}

impl PartialEq for Ev {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t && self.job == other.job
    }
}

impl Eq for Ev {}

impl Ord for Ev {
    fn cmp(&self, other: &Self) -> Ordering {
        other.t.total_cmp(&self.t).then_with(|| other.job.cmp(&self.job))
    }
}

impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// The event-driven scheduler. Stateless: all state lives on the run.
pub struct Scheduler;

impl Scheduler {
    /// Schedule `graph` to completion and return makespan, energy and
    /// per-engine statistics. Deterministic: dispatch prefers the
    /// lowest-id ready job, completion ties resolve by job id.
    pub fn run(graph: &JobGraph) -> SchedResult {
        let n = graph.jobs.len();
        let mut indeg: Vec<usize> = Vec::with_capacity(n);
        let mut children: Vec<Vec<JobId>> = vec![Vec::new(); n];
        for (id, job) in graph.jobs.iter().enumerate() {
            indeg.push(job.deps.len());
            for &d in &job.deps {
                children[d].push(id);
            }
        }
        let mut ready: BTreeSet<JobId> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut heap: BinaryHeap<Ev> = BinaryHeap::new();
        let mut engine_busy = [false; N_ENGINES];
        let mut current_mode: Option<OperatingMode> = None;
        let mut mode_ready_at = 0.0f64;
        let mut mode_locked_running = 0usize;
        let mut switches = 0u64;
        let mut n_done = 0usize;
        let mut t = 0.0f64;
        let mut makespan = 0.0f64;

        loop {
            // Dispatch everything startable at time t, lowest job id first.
            loop {
                let lowest_ml_ready =
                    ready.iter().copied().find(|&j| graph.jobs[j].engine.mode_locked());
                let mut pick: Option<(JobId, bool)> = None; // (job, switches mode)
                for &j in ready.iter() {
                    let job = &graph.jobs[j];
                    if engine_busy[job.engine.index()] {
                        continue;
                    }
                    if job.engine.mode_locked() {
                        if current_mode == Some(job.op.mode) {
                            pick = Some((j, false));
                            break;
                        }
                        // A mode switch is granted only to the lowest-id
                        // ready cluster job, and only once the cluster
                        // engines have drained.
                        if mode_locked_running == 0 && Some(j) == lowest_ml_ready {
                            pick = Some((j, true));
                            break;
                        }
                        continue;
                    }
                    pick = Some((j, false));
                    break;
                }
                let Some((j, switch)) = pick else { break };
                ready.remove(&j);
                let job = &graph.jobs[j];
                let mut start = t;
                if job.engine.mode_locked() {
                    if switch {
                        if current_mode.is_some() {
                            switches += 1;
                            mode_ready_at = t + MODE_SWITCH_S;
                        }
                        current_mode = Some(job.op.mode);
                    }
                    // The cluster sleeps while the FLL relocks.
                    start = start.max(mode_ready_at);
                    mode_locked_running += 1;
                }
                engine_busy[job.engine.index()] = true;
                heap.push(Ev { t: start + job.duration_s, job: j });
            }

            // Advance simulated time to the next completion.
            let Some(ev) = heap.pop() else { break };
            t = ev.t;
            makespan = makespan.max(t);
            let job = &graph.jobs[ev.job];
            engine_busy[job.engine.index()] = false;
            if job.engine.mode_locked() {
                mode_locked_running -= 1;
            }
            n_done += 1;
            for &c in &children[ev.job] {
                indeg[c] -= 1;
                if indeg[c] == 0 {
                    ready.insert(c);
                }
            }
        }
        assert_eq!(n_done, n, "scheduler stalled: {n_done} of {n} jobs completed");

        SchedResult {
            ledger: graph.finish_ledger(makespan),
            makespan_s: makespan,
            mode_switches: switches,
            busy_s: graph.busy_totals(),
            n_jobs: n,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(engine: Engine, mode: OperatingMode, duration_s: f64, deps: &[JobId]) -> Job {
        Job {
            label: "test",
            engine,
            op: OperatingPoint::new(mode, 0.8),
            duration_s,
            deps: deps.to_vec(),
            charges: vec![(Category::OtherSw, Component::Core, 1.0)],
        }
    }

    #[test]
    fn serial_chain_sums_durations() {
        let mut g = JobGraph::new();
        let a = g.push(job(Engine::Cores, OperatingMode::Sw, 1.0, &[]));
        let b = g.push(job(Engine::Cores, OperatingMode::Sw, 2.0, &[a]));
        g.push(job(Engine::Cores, OperatingMode::Sw, 3.0, &[b]));
        let r = Scheduler::run(&g);
        assert!((r.makespan_s - 6.0).abs() < 1e-12);
        assert_eq!(r.mode_switches, 0);
        assert!((r.busy_s[Engine::Cores.index()] - 6.0).abs() < 1e-12);
    }

    #[test]
    fn independent_engines_overlap() {
        let mut g = JobGraph::new();
        g.push(job(Engine::Cores, OperatingMode::Sw, 2.0, &[]));
        g.push(job(Engine::UdmaFlash, OperatingMode::Sw, 1.5, &[]));
        g.push(job(Engine::UdmaFram, OperatingMode::Sw, 1.0, &[]));
        let r = Scheduler::run(&g);
        assert!((r.makespan_s - 2.0).abs() < 1e-12, "I/O must hide under compute");
    }

    #[test]
    fn same_engine_serializes() {
        let mut g = JobGraph::new();
        g.push(job(Engine::UdmaFram, OperatingMode::Sw, 1.0, &[]));
        g.push(job(Engine::UdmaFram, OperatingMode::Sw, 1.0, &[]));
        let r = Scheduler::run(&g);
        assert!((r.makespan_s - 2.0).abs() < 1e-12);
    }

    #[test]
    fn mode_switch_costs_relock() {
        let mut g = JobGraph::new();
        let a = g.push(job(Engine::Hwce, OperatingMode::KecCnnSw, 1.0, &[]));
        let b = g.push(job(Engine::HwcryptAes, OperatingMode::CryCnnSw, 1.0, &[a]));
        g.push(job(Engine::Hwce, OperatingMode::KecCnnSw, 1.0, &[b]));
        let r = Scheduler::run(&g);
        assert_eq!(r.mode_switches, 2);
        assert!((r.makespan_s - (3.0 + 2.0 * MODE_SWITCH_S)).abs() < 1e-9);
    }

    #[test]
    fn different_mode_jobs_serialize_without_deps() {
        // No dependency between them, but the shared cluster clock
        // serializes a KEC-mode and a CRY-mode job.
        let mut g = JobGraph::new();
        g.push(job(Engine::Hwce, OperatingMode::KecCnnSw, 1.0, &[]));
        g.push(job(Engine::HwcryptAes, OperatingMode::CryCnnSw, 1.0, &[]));
        let r = Scheduler::run(&g);
        assert!(r.makespan_s >= 2.0, "mode exclusivity violated: {}", r.makespan_s);
        assert_eq!(r.mode_switches, 1);
    }

    #[test]
    fn same_mode_engines_do_overlap() {
        let mut g = JobGraph::new();
        g.push(job(Engine::Hwce, OperatingMode::KecCnnSw, 2.0, &[]));
        g.push(job(Engine::HwcryptKec, OperatingMode::KecCnnSw, 2.0, &[]));
        let r = Scheduler::run(&g);
        assert!((r.makespan_s - 2.0).abs() < 1e-12);
        assert_eq!(r.mode_switches, 0);
    }

    #[test]
    fn first_mode_entry_is_free() {
        let mut g = JobGraph::new();
        g.push(job(Engine::Hwce, OperatingMode::KecCnnSw, 1.0, &[]));
        let r = Scheduler::run(&g);
        assert_eq!(r.mode_switches, 0);
        assert!((r.makespan_s - 1.0).abs() < 1e-12);
    }

    #[test]
    fn analytic_matches_run_on_serial_cluster_graph() {
        let mut g = JobGraph::new();
        let mut prev: Option<JobId> = None;
        for i in 0..6 {
            let mode = if i % 2 == 0 { OperatingMode::KecCnnSw } else { OperatingMode::CryCnnSw };
            let engine = if i % 2 == 0 { Engine::Hwce } else { Engine::HwcryptAes };
            let deps: Vec<JobId> = prev.into_iter().collect();
            prev = Some(g.push(job(engine, mode, 0.5, &deps)));
        }
        let run = Scheduler::run(&g);
        let ana = g.analytic();
        assert!((run.makespan_s - ana.makespan_s).abs() < 1e-9);
        assert_eq!(run.mode_switches, ana.mode_switches);
        assert!((run.ledger.total_mj() - ana.ledger.total_mj()).abs() < 1e-9);
    }

    #[test]
    fn analytic_hides_io_behind_compute() {
        let mut g = JobGraph::new();
        g.push(job(Engine::UdmaFram, OperatingMode::Sw, 1.0, &[]));
        g.push(job(Engine::Cores, OperatingMode::Sw, 3.0, &[]));
        let ana = g.analytic();
        assert!((ana.makespan_s - 3.0).abs() < 1e-12);
        // I/O-dominated: the surplus lands on the critical path.
        let mut g2 = JobGraph::new();
        g2.push(job(Engine::UdmaFram, OperatingMode::Sw, 5.0, &[]));
        g2.push(job(Engine::Cores, OperatingMode::Sw, 3.0, &[]));
        let ana2 = g2.analytic();
        assert!((ana2.makespan_s - 5.0).abs() < 1e-12);
    }

    #[test]
    fn repeat_streams_through_shared_engines() {
        // frame: long compute + short store that depends on it
        let mut g = JobGraph::new();
        let c = g.push(job(Engine::Cores, OperatingMode::Sw, 2.0, &[]));
        g.push(job(Engine::UdmaFram, OperatingMode::Sw, 1.0, &[c]));
        let single = Scheduler::run(&g);
        assert!((single.makespan_s - 3.0).abs() < 1e-12);
        let four = Scheduler::run(&g.repeat(4));
        // stores of frame f overlap compute of frame f+1: 4×2 + trailing 1
        assert!((four.makespan_s - 9.0).abs() < 1e-12, "stream {}", four.makespan_s);
        assert!(four.makespan_s < 4.0 * single.makespan_s);
    }

    #[test]
    fn streaming_never_slower_than_serial_frames() {
        let mut g = JobGraph::new();
        let a = g.push(job(Engine::Hwce, OperatingMode::KecCnnSw, 0.3, &[]));
        let b = g.push(job(Engine::HwcryptAes, OperatingMode::CryCnnSw, 0.2, &[a]));
        g.push(job(Engine::UdmaFram, OperatingMode::Sw, 0.4, &[b]));
        let single = Scheduler::run(&g).makespan_s;
        for frames in [2usize, 5] {
            let stream = Scheduler::run(&g.repeat(frames)).makespan_s;
            assert!(
                stream <= frames as f64 * single + 1e-9,
                "{frames} frames: {stream} > {}",
                frames as f64 * single
            );
        }
    }

    #[test]
    fn busy_never_exceeds_makespan() {
        let mut g = JobGraph::new();
        let mut prev = Vec::new();
        for i in 0..20 {
            let e = Engine::ALL[i % N_ENGINES];
            let deps: Vec<JobId> = prev.clone();
            prev = vec![g.push(job(e, OperatingMode::Sw, 0.01 * (i + 1) as f64, &deps))];
        }
        let r = Scheduler::run(&g);
        for e in Engine::ALL {
            assert!(r.busy_s[e.index()] <= r.makespan_s + 1e-9, "{}", e.name());
        }
        let total: f64 = r.busy_s.iter().sum();
        assert!(total <= r.makespan_s * N_ENGINES as f64 + 1e-9);
    }

    #[test]
    fn segments_attribute_active_energy() {
        let mut g = JobGraph::new();
        g.mark_segment("a");
        g.push(job(Engine::Cores, OperatingMode::Sw, 2.0, &[]));
        g.mark_segment("b");
        g.push(job(Engine::Cores, OperatingMode::Sw, 1.0, &[]));
        g.mark_segment("empty"); // trailing marker with no jobs
        let seg = g.segment_active_mj();
        assert_eq!(seg.len(), 3);
        assert_eq!(seg[0].0, "a");
        assert_eq!(seg[1].0, "b");
        assert_eq!(seg[2], ("empty".to_string(), 0.0), "empty tenants keep a zero row");
        assert!((seg[0].1 - 2.0 * seg[1].1).abs() < 1e-12, "a charges 2x b's interval");
        let total: f64 = seg.iter().map(|(_, mj)| mj).sum();
        assert!((total - g.active_mj()).abs() < 1e-12);
        // streaming re-marks each frame's segments and aggregates by label
        let g4 = g.repeat(4);
        assert_eq!(g4.segments.len(), 12);
        let seg4 = g4.segment_active_mj();
        assert_eq!(seg4.len(), 3, "labels aggregate across frames");
        assert!((seg4[0].1 - 4.0 * seg[0].1).abs() < 1e-12);
    }

    #[test]
    fn empty_graph_is_trivial() {
        let g = JobGraph::new();
        let r = Scheduler::run(&g);
        assert_eq!(r.makespan_s, 0.0);
        assert_eq!(r.n_jobs, 0);
        assert_eq!(r.ledger.total_mj(), 0.0);
    }

    #[test]
    #[should_panic(expected = "not-yet-pushed")]
    fn forward_dependency_rejected() {
        let mut g = JobGraph::new();
        g.push(job(Engine::Cores, OperatingMode::Sw, 1.0, &[3]));
    }

    #[test]
    fn energy_charges_integrate_at_op() {
        use crate::soc::power::PowerModel;
        let mut g = JobGraph::new();
        g.push(job(Engine::Cores, OperatingMode::Sw, 2.0, &[]));
        let r = Scheduler::run(&g);
        let op = OperatingPoint::new(OperatingMode::Sw, 0.8);
        let expect = PowerModel::active_mw(Component::Core, op) * 2.0;
        assert!((r.ledger.energy_mj(Category::OtherSw) - expect).abs() < 1e-9);
        // leakage charged over the makespan
        assert!(r.ledger.energy_mj(Category::Idle) > 0.0);
    }
}
