//! The SOC domain and chip-level services (§II, §II-A, §III-A):
//! operating modes and DVFS tables ([`opmodes`]), the power-mode state
//! machine of Table I and per-component power model ([`power`]), and the
//! FLL/uDMA models ([`udma`]).

pub mod opmodes;
pub mod power;
pub mod udma;

pub use opmodes::{OperatingMode, OperatingPoint};
pub use power::{Component, PowerModel};
