//! The SOC domain and chip-level services (§II, §II-A, §III-A):
//! operating modes and DVFS tables ([`opmodes`]), the power-mode state
//! machine of Table I and per-component power model ([`power`]), the
//! FLL/uDMA models ([`udma`]), and the event-driven whole-SoC scheduler
//! ([`sched`]) that the coordinator use cases run on.

pub mod opmodes;
pub mod pm;
pub mod power;
pub mod sched;
pub mod udma;

pub use opmodes::{OperatingMode, OperatingPoint};
pub use power::{Component, PowerModel};
pub use sched::{Engine, Job, JobGraph, JobId, SchedResult, Scheduler};
