//! Per-component power model, calibrated to the paper's published operating
//! points (Table I, Table II, Fig. 7, Fig. 8) and the external-memory
//! datasheets cited in §IV (Microchip SST26VF064 flash, Cypress CY15B104Q
//! FRAM).
//!
//! ## Calibration derivation (all at VDD = 0.8 V, cluster)
//!
//! Published anchors:
//! * SW mode, 4 cores busy @ 120 MHz → ≈12 mW        (Table II)
//! * KEC-CNN-SW, HWCE busy @ 104 MHz → ≈13 mW, and 50 pJ/px ⇒ 465 GMAC/s/W
//!   for 5×5 @ 0.45 cyc/px                            (Table II, Fig. 8b)
//! * CRY-CNN-SW, AES-XTS busy @ 85 MHz → 67 Gbit/s/W at 0.38 cpb
//!   ⇒ P ≈ 1.79 Gbit/s ÷ 67 Gbit/s/W ≈ 26.7 mW        (§III-B, Fig. 8a)
//! * KEC-CNN-SW, sponge busy @ 104 MHz → 100 Gbit/s/W at 0.51 cpb
//!   ⇒ P ≈ 1.63 Gbit/s ÷ 100 Gbit/s/W ≈ 16.3 mW       (§III-B, Fig. 8a)
//! * Table I: cluster idle 210 µW (FLL off) — leakage + always-on;
//!   SOC idle 120 µW.
//!
//! Solving with a shared cluster infrastructure term gives the per-MHz
//! dynamic-power coefficients below; tests in this module re-derive the
//! anchors from the model and assert them within tolerance. Dynamic power
//! scales as `(VDD/0.8)²`, frequency via the alpha-power law in
//! [`super::opmodes`] — together these reproduce the energy-vs-VDD shape of
//! Fig. 8.

use super::opmodes::OperatingPoint;

/// Energy/power-consuming components tracked by the ledger, matching the
/// breakdown categories of Fig. 10/11/12.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Component {
    /// One OR10N core, active (index-independent).
    Core,
    /// Cluster infrastructure: TCDM + interconnects + event unit + DMA.
    ClusterInfra,
    /// HWCE convolution engine, active.
    Hwce,
    /// HWCRYPT AES engine, active.
    HwcryptAes,
    /// HWCRYPT KECCAK sponge engine, active.
    HwcryptKec,
    /// Cluster leakage (always charged while the cluster is powered).
    ClusterLeak,
    /// SOC domain (L2 + uDMA + peripherals), active.
    SocDomain,
    /// SOC domain leakage.
    SocLeak,
    /// External quad-SPI flash (weights), active reads.
    Flash,
    /// External FRAM (partial results), active traffic.
    Fram,
    /// External memory standby power.
    ExtMemStandby,
}

/// Dynamic power coefficients at 0.8 V, in µW per cluster MHz.
pub const CORE_UW_PER_MHZ: f64 = 18.0;
pub const INFRA_UW_PER_MHZ: f64 = 20.0;
pub const HWCE_UW_PER_MHZ: f64 = 70.0;
pub const AES_UW_PER_MHZ: f64 = 263.0;
pub const KEC_UW_PER_MHZ: f64 = 108.0;

/// Leakage at 0.8 V in mW (Table I: cluster idle, FLL off = 210 µW).
pub const CLUSTER_LEAK_MW: f64 = 0.21;
/// SOC leakage (Table I: 120 µW).
pub const SOC_LEAK_MW: f64 = 0.12;
/// SOC domain active adder while serving L2/uDMA traffic, mW at 1.0 V.
pub const SOC_ACTIVE_MW: f64 = 0.6;

/// External memory power (datasheets, worst case as §IV prescribes), mW.
/// SST26VF064B QPI read: 15 mA @ 3.6 V (per §IV "a maximum of 15 mA@3.6 V").
pub const FLASH_ACTIVE_MW: f64 = 54.0;
/// Two flash banks standby: 2 × 15 µA × 3.6 V.
pub const FLASH_STANDBY_MW: f64 = 0.108;
/// Four CY15B104Q banks, bit-interleaved (all active per access):
/// 4 × ~3 mA @ 3.0 V at 40 MHz SPI clock.
pub const FRAM_ACTIVE_MW: f64 = 36.0;
/// Four FRAM banks standby.
pub const FRAM_STANDBY_MW: f64 = 1.2;

/// External memory bandwidths in bytes/s.
/// Flash QPI: 4 bits/SPI-clock @ 80 MHz = 40 MB/s.
pub const FLASH_BW_BPS: f64 = 40e6;
/// FRAM 4×1-bit interleaved @ 40 MHz = 20 MB/s.
pub const FRAM_BW_BPS: f64 = 20e6;

/// The power model: evaluates component power at an operating point.
#[derive(Debug, Clone, Copy)]
pub struct PowerModel;

impl PowerModel {
    /// Dynamic scaling factor (VDD/0.8)².
    fn vscale(vdd: f64) -> f64 {
        (vdd / 0.8) * (vdd / 0.8)
    }

    /// Power of `component` in mW while *active* at operating point `op`.
    pub fn active_mw(component: Component, op: OperatingPoint) -> f64 {
        let f = op.freq_mhz();
        let vs = Self::vscale(op.vdd);
        match component {
            Component::Core => CORE_UW_PER_MHZ * f * vs / 1000.0,
            Component::ClusterInfra => INFRA_UW_PER_MHZ * f * vs / 1000.0,
            Component::Hwce => HWCE_UW_PER_MHZ * f * vs / 1000.0,
            Component::HwcryptAes => AES_UW_PER_MHZ * f * vs / 1000.0,
            Component::HwcryptKec => KEC_UW_PER_MHZ * f * vs / 1000.0,
            Component::ClusterLeak => CLUSTER_LEAK_MW * vs,
            Component::SocDomain => SOC_ACTIVE_MW,
            Component::SocLeak => SOC_LEAK_MW,
            Component::Flash => FLASH_ACTIVE_MW,
            Component::Fram => FRAM_ACTIVE_MW,
            Component::ExtMemStandby => FLASH_STANDBY_MW + FRAM_STANDBY_MW,
        }
    }

    /// Total cluster power with a given active set, in mW: `n_cores` busy
    /// cores plus optional accelerators, infrastructure, and leakage.
    pub fn cluster_mw(
        op: OperatingPoint,
        n_cores: usize,
        hwce: bool,
        aes: bool,
        kec: bool,
    ) -> f64 {
        let mut p = n_cores as f64 * Self::active_mw(Component::Core, op)
            + Self::active_mw(Component::ClusterInfra, op)
            + Self::active_mw(Component::ClusterLeak, op);
        if hwce {
            p += Self::active_mw(Component::Hwce, op);
        }
        if aes {
            p += Self::active_mw(Component::HwcryptAes, op);
        }
        if kec {
            p += Self::active_mw(Component::HwcryptKec, op);
        }
        p
    }
}

/// Table I power modes (µW) and wakeup times (µs), encoded verbatim.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PowerMode {
    ActiveHiFreq,
    ActiveLowFreq,
    IdleFllOn,
    IdleFllOff,
    DeepSleep,
}

impl PowerMode {
    /// (cluster µW, soc µW) in this mode (Table I; active hi-freq depends on
    /// the workload and is computed by [`PowerModel`] instead).
    pub fn static_power_uw(self) -> (f64, f64) {
        match self {
            PowerMode::ActiveHiFreq => (f64::NAN, f64::NAN), // workload-dependent
            PowerMode::ActiveLowFreq => (230.0, 130.0),
            PowerMode::IdleFllOn => (600.0, 510.0),
            PowerMode::IdleFllOff => (210.0, 120.0),
            PowerMode::DeepSleep => (0.01, 120.0),
        }
    }

    /// (cluster wakeup µs, soc wakeup µs) — **all values in µs** (Table I).
    ///
    /// Anchors: with the FLL already locked, wake-up is interrupt
    /// propagation + clock ungating — tens of µs for either domain.
    /// From FLL-off states the FLL relock dominates: ~300 µs (the same
    /// figure Table I quotes for entering the active low-frequency
    /// point, which also starts FLL-off). Deep sleep additionally rides
    /// the external DC/DC rail ramp and the state-retention restore
    /// sequence: ~3 ms, an order of magnitude above a bare relock
    /// (the Vega-class retentive-wakeup figure).
    ///
    /// The seed encoded the FLL-on cluster entry as `0.02`, which was
    /// unit-ambiguous (0.02 *ms* = 20 µs next to literal-µs rows); the
    /// table is now uniformly µs and pinned by `wakeup_ladder_is_monotone`.
    pub fn wakeup_us(self) -> (f64, f64) {
        match self {
            PowerMode::ActiveHiFreq => (0.0, 0.0),
            PowerMode::ActiveLowFreq => (300.0, 300.0), // FLL relock
            PowerMode::IdleFllOn => (20.0, 20.0),       // clock ungate only
            PowerMode::IdleFllOff => (300.0, 300.0),    // FLL relock
            PowerMode::DeepSleep => (3000.0, 3000.0),   // DC/DC ramp + restore
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            PowerMode::ActiveHiFreq => "active hi-freq",
            PowerMode::ActiveLowFreq => "active low-freq",
            PowerMode::IdleFllOn => "idle (FLL on)",
            PowerMode::IdleFllOff => "idle (FLL off)",
            PowerMode::DeepSleep => "deep sleep",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::opmodes::{OperatingMode, OperatingPoint};

    fn nominal(m: OperatingMode) -> OperatingPoint {
        OperatingPoint::nominal(m)
    }

    /// Table II anchor: SW mode, 4 cores @ 0.8 V / 120 MHz ≈ 12 mW.
    #[test]
    fn anchor_sw_mode_12mw() {
        let p = PowerModel::cluster_mw(nominal(OperatingMode::Sw), 4, false, false, false)
            + SOC_ACTIVE_MW
            + SOC_LEAK_MW;
        assert!((p - 12.0).abs() < 1.0, "SW mode power {p} mW");
    }

    /// Fig. 8b anchor: HWCE 4-bit 5×5 at 0.45 cyc/px, 0.8 V ⇒ ≈50 pJ/px and
    /// ≈465 GMAC/s/W.
    #[test]
    fn anchor_hwce_efficiency() {
        let op = nominal(OperatingMode::KecCnnSw);
        // HWCE busy + 1 controller core
        let p_mw = PowerModel::cluster_mw(op, 1, true, false, false) + SOC_ACTIVE_MW + SOC_LEAK_MW;
        let px_per_s = op.freq_hz() / 0.45;
        let pj_per_px = p_mw * 1e9 / px_per_s / 1000.0 * 1000.0; // mW→pJ/px
        let gmac_s_w = px_per_s * 25.0 / (p_mw * 1e-3) / 1e9;
        assert!((pj_per_px - 50.0).abs() < 10.0, "pJ/px = {pj_per_px}");
        assert!((gmac_s_w - 465.0).abs() < 60.0, "GMAC/s/W = {gmac_s_w}");
    }

    /// Fig. 8a anchor: AES-XTS 0.38 cpb @ 85 MHz, 0.8 V ⇒ ≈67 Gbit/s/W.
    #[test]
    fn anchor_xts_efficiency() {
        let op = nominal(OperatingMode::CryCnnSw);
        let p_mw = PowerModel::cluster_mw(op, 1, false, true, false) + SOC_ACTIVE_MW + SOC_LEAK_MW;
        let gbit_s = op.freq_hz() / 0.38 * 8.0 / 1e9;
        let eff = gbit_s / (p_mw * 1e-3);
        assert!((gbit_s - 1.78).abs() < 0.05, "throughput {gbit_s} Gbit/s");
        assert!((eff - 67.0).abs() < 8.0, "XTS efficiency {eff} Gbit/s/W");
    }

    /// Fig. 8a anchor: sponge AE 0.51 cpb @ 104 MHz ⇒ ≈100 Gbit/s/W.
    #[test]
    fn anchor_sponge_efficiency() {
        let op = nominal(OperatingMode::KecCnnSw);
        let p_mw = PowerModel::cluster_mw(op, 1, false, false, true) + SOC_ACTIVE_MW + SOC_LEAK_MW;
        let gbit_s = op.freq_hz() / 0.51 * 8.0 / 1e9;
        let eff = gbit_s / (p_mw * 1e-3);
        assert!((gbit_s - 1.6).abs() < 0.05, "throughput {gbit_s} Gbit/s");
        assert!((eff - 100.0).abs() < 12.0, "sponge efficiency {eff} Gbit/s/W");
    }

    /// Table II anchor: CRY-CNN-SW full-activity power ≈ 24 mW at 0.8 V
    /// (cores + accelerator activity mix of the use cases).
    #[test]
    fn anchor_cry_mode_24mw_regime() {
        let op = nominal(OperatingMode::CryCnnSw);
        let p = PowerModel::cluster_mw(op, 1, false, true, false) + SOC_ACTIVE_MW + SOC_LEAK_MW;
        assert!(p > 20.0 && p < 30.0, "CRY-CNN-SW regime power {p} mW");
    }

    #[test]
    fn power_scales_quadratically_with_vdd() {
        let p08 = PowerModel::active_mw(Component::Core, OperatingPoint::new(OperatingMode::Sw, 0.8));
        let p12 = PowerModel::active_mw(Component::Core, OperatingPoint::new(OperatingMode::Sw, 1.2));
        // (1.2/0.8)² = 2.25 on voltage alone, plus the frequency lift ≈ 2.26
        let ratio = p12 / p08;
        assert!(ratio > 4.0 && ratio < 6.0, "ratio {ratio}");
    }

    #[test]
    fn table1_modes_encoded() {
        assert_eq!(PowerMode::IdleFllOn.static_power_uw(), (600.0, 510.0));
        assert_eq!(PowerMode::IdleFllOff.static_power_uw(), (210.0, 120.0));
        assert_eq!(PowerMode::DeepSleep.static_power_uw().1, 120.0);
        assert_eq!(PowerMode::ActiveLowFreq.wakeup_us(), (300.0, 300.0));
        // Unit-normalized wake-ups (all µs): clock ungate / FLL relock /
        // DC-DC ramp + retentive restore.
        assert_eq!(PowerMode::IdleFllOn.wakeup_us(), (20.0, 20.0));
        assert_eq!(PowerMode::IdleFllOff.wakeup_us(), (300.0, 300.0));
        assert_eq!(PowerMode::DeepSleep.wakeup_us(), (3000.0, 3000.0));
    }

    /// The sleep ladder must be coherent: each deeper idle rung trades
    /// strictly lower resting power for a wake-up at least as long —
    /// otherwise a shallower rung would dominate and the ladder (and
    /// every policy built on it in [`crate::soc::pm`]) degenerates.
    #[test]
    fn wakeup_ladder_is_monotone() {
        let ladder =
            [PowerMode::IdleFllOn, PowerMode::IdleFllOff, PowerMode::DeepSleep];
        for pair in ladder.windows(2) {
            let (shallow, deep) = (pair[0], pair[1]);
            let (s_cl, s_soc) = shallow.static_power_uw();
            let (d_cl, d_soc) = deep.static_power_uw();
            assert!(d_cl < s_cl, "{deep:?} cluster power not below {shallow:?}");
            assert!(d_cl + d_soc < s_cl + s_soc);
            let (sw_cl, sw_soc) = shallow.wakeup_us();
            let (dw_cl, dw_soc) = deep.wakeup_us();
            assert!(dw_cl > sw_cl, "{deep:?} cluster wakeup not above {shallow:?}");
            assert!(dw_soc > sw_soc);
        }
    }

    /// Peak power stays under the 24 mW envelope the §IV-A use case quotes
    /// ("peak power consumption ... less than 24 mW" at 0.8 V) for the
    /// HWCE-heavy phases that dominate runtime.
    #[test]
    fn peak_power_envelope_kec_mode() {
        let op = nominal(OperatingMode::KecCnnSw);
        let p = PowerModel::cluster_mw(op, 4, true, false, true) + SOC_ACTIVE_MW + SOC_LEAK_MW;
        assert!(p < 32.0, "KEC-mode peak {p} mW");
    }
}
