//! The I/O DMA subsystem (uDMA) of the SOC domain (§II): autonomously copies
//! data between L2 and the external interfaces (quad-SPI flash/FRAM, camera,
//! ADC) "even when the cluster is in sleep mode", enabling full overlap of
//! I/O transfers, L2↔TCDM transfers and computation (double buffering).

use crate::soc::power::{FLASH_BW_BPS, FRAM_BW_BPS};

/// External interfaces served by the uDMA.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Interface {
    /// Quad-SPI flash (weight storage), QPI mode.
    FlashQpi,
    /// 4× bit-interleaved FRAM (partial results).
    Fram,
    /// Camera parallel interface (input frames).
    Camera,
    /// ADC via I2S/SPI (EEG and other biosignals).
    Adc,
}

impl Interface {
    /// Sustained bandwidth in bytes/s (datasheet-derived; see
    /// [`crate::soc::power`] for flash/FRAM, camera/ADC are not the
    /// bottleneck in any use case and get nominal rates).
    pub fn bandwidth_bps(self) -> f64 {
        match self {
            Interface::FlashQpi => FLASH_BW_BPS,
            Interface::Fram => FRAM_BW_BPS,
            Interface::Camera => 10e6,
            Interface::Adc => 1e6,
        }
    }
}

/// A uDMA channel transfer: seconds to move `bytes` over `iface`.
pub fn transfer_s(iface: Interface, bytes: usize) -> f64 {
    bytes as f64 / iface.bandwidth_bps()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flash_40mbps() {
        // 4 MB in ~0.1 s
        let t = transfer_s(Interface::FlashQpi, 4 << 20);
        assert!((t - 0.1049).abs() < 0.01, "t={t}");
    }

    #[test]
    fn fram_half_flash_bandwidth() {
        let tf = transfer_s(Interface::Fram, 1 << 20);
        let tq = transfer_s(Interface::FlashQpi, 1 << 20);
        assert!((tf / tq - 2.0).abs() < 1e-9);
    }
}
