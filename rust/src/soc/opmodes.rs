//! Operating modes and voltage/frequency scaling (§III-A, Fig. 7).
//!
//! Fulmine defines three multi-corner operating modes:
//!
//! * **CRY-CNN-SW** — everything available; the HWCRYPT AES datapath (two
//!   unpipelined AES rounds per cycle) limits the clock.
//! * **KEC-CNN-SW** — cores + HWCE + KECCAK-f[400] primitives only; the
//!   relaxed AES path allows a higher clock.
//! * **SW** — cores only; maximum frequency.
//!
//! ## Calibration
//!
//! The anchor points published in the paper (Table II and §IV) are:
//!
//! | mode       | VDD   | fmax    |
//! |------------|-------|---------|
//! | CRY-CNN-SW | 0.8 V | 85 MHz  |
//! | KEC-CNN-SW | 0.8 V | 104 MHz |
//! | SW         | 0.8 V | 120 MHz |
//!
//! and Fig. 7 shows that at 1.2 V all modes draw ≈120 mW under full load
//! (≈100 mA design target). Frequency over VDD follows the alpha-power law
//! `f ∝ (VDD − VTH)^α / VDD` with VTH = 0.45 V, α = 1.6 — which reproduces
//! both the 0.8 V anchors and a ≈2.25× frequency lift at 1.2 V, consistent
//! with the shape of Fig. 7a. A test asserts the anchors exactly and the
//! 1.2 V full-load power within tolerance (see [`super::power`]).

/// Threshold voltage used by the alpha-power frequency law (65 nm LL).
pub const VTH: f64 = 0.45;
/// Alpha-power exponent (velocity-saturated short-channel 65 nm).
pub const ALPHA: f64 = 1.6;
/// Calibration voltage for all anchors.
pub const V_NOM: f64 = 0.8;

/// The three multi-corner operating modes of §III-A.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OperatingMode {
    /// All accelerators and cores available @ 85 MHz (0.8 V).
    CryCnnSw,
    /// Cores + HWCE + KECCAK primitives @ 104 MHz (0.8 V).
    KecCnnSw,
    /// Cores only @ 120 MHz (0.8 V).
    Sw,
}

impl OperatingMode {
    /// Maximum cluster frequency at the nominal 0.8 V point, in MHz
    /// (paper Table II / §IV).
    pub fn fmax_nominal_mhz(self) -> f64 {
        match self {
            OperatingMode::CryCnnSw => 85.0,
            OperatingMode::KecCnnSw => 104.0,
            OperatingMode::Sw => 120.0,
        }
    }

    /// Maximum cluster frequency at `vdd` volts, in MHz (alpha-power law
    /// anchored at 0.8 V — Fig. 7a).
    pub fn fmax_mhz(self, vdd: f64) -> f64 {
        assert!((0.6..=1.3).contains(&vdd), "VDD {vdd} outside modelled range");
        let scale = |v: f64| (v - VTH).powf(ALPHA) / v;
        self.fmax_nominal_mhz() * scale(vdd) / scale(V_NOM)
    }

    /// Whether the HWCRYPT AES datapath is usable in this mode.
    pub fn aes_available(self) -> bool {
        matches!(self, OperatingMode::CryCnnSw)
    }

    /// Capability subsumption: can a cluster clocked at `self` execute a
    /// job that was emitted for `other`? The three modes are totally
    /// ordered by their engine capability sets — CRY-CNN-SW (everything) ⊇
    /// KEC-CNN-SW (cores + HWCE + KECCAK) ⊇ SW (cores only) — so a
    /// higher-capability point can host any lower-capability job, at its
    /// own (lower) clock. This is the scheduler's co-residency rule: jobs
    /// whose modes are compatible under the current point run concurrently
    /// instead of serializing on a mode lock (§II-D overlap discipline).
    pub fn supports(self, other: OperatingMode) -> bool {
        self.capability_rank() >= other.capability_rank()
    }

    /// Position in the capability order (SW ⊂ KEC-CNN-SW ⊂ CRY-CNN-SW).
    /// Note the *frequency* order is the reverse: more capability, lower
    /// fmax (Table II).
    fn capability_rank(self) -> u8 {
        match self {
            OperatingMode::Sw => 0,
            OperatingMode::KecCnnSw => 1,
            OperatingMode::CryCnnSw => 2,
        }
    }

    /// Whether the HWCRYPT KECCAK sponge engine is usable in this mode.
    pub fn keccak_available(self) -> bool {
        matches!(self, OperatingMode::CryCnnSw | OperatingMode::KecCnnSw)
    }

    /// Whether the HWCE is usable in this mode.
    pub fn hwce_available(self) -> bool {
        matches!(self, OperatingMode::CryCnnSw | OperatingMode::KecCnnSw)
    }

    pub fn name(self) -> &'static str {
        match self {
            OperatingMode::CryCnnSw => "CRY-CNN-SW",
            OperatingMode::KecCnnSw => "KEC-CNN-SW",
            OperatingMode::Sw => "SW",
        }
    }
}

/// A concrete cluster operating point: mode + supply voltage, running at the
/// mode's fmax for that voltage (the paper always benchmarks at fmax).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OperatingPoint {
    pub mode: OperatingMode,
    pub vdd: f64,
}

impl OperatingPoint {
    pub fn new(mode: OperatingMode, vdd: f64) -> Self {
        OperatingPoint { mode, vdd }
    }

    /// The paper's headline 0.8 V points.
    pub fn nominal(mode: OperatingMode) -> Self {
        OperatingPoint { mode, vdd: V_NOM }
    }

    pub fn freq_mhz(&self) -> f64 {
        self.mode.fmax_mhz(self.vdd)
    }

    pub fn freq_hz(&self) -> f64 {
        self.freq_mhz() * 1e6
    }

    /// Convert cycles to seconds at this operating point.
    pub fn cycles_to_s(&self, cycles: u64) -> f64 {
        cycles as f64 / self.freq_hz()
    }
}

/// FLL mode-switch latency (§II-A): the cluster sleeps while the FLL locks;
/// "the frequency switch can be performed in as little as 10 µs". Used when
/// use cases alternate CRY-CNN-SW and KEC-CNN-SW phases (§IV-A).
pub const MODE_SWITCH_S: f64 = 10e-6;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nominal_anchors_exact() {
        assert_eq!(OperatingMode::CryCnnSw.fmax_mhz(0.8).round(), 85.0);
        assert_eq!(OperatingMode::KecCnnSw.fmax_mhz(0.8).round(), 104.0);
        assert_eq!(OperatingMode::Sw.fmax_mhz(0.8).round(), 120.0);
    }

    #[test]
    fn frequency_monotone_in_vdd() {
        for mode in [OperatingMode::CryCnnSw, OperatingMode::KecCnnSw, OperatingMode::Sw] {
            let mut prev = 0.0;
            for i in 0..=8 {
                let v = 0.8 + 0.05 * i as f64;
                let f = mode.fmax_mhz(v);
                assert!(f > prev, "f not monotone at {v}");
                prev = f;
            }
        }
    }

    #[test]
    fn lift_at_1v2_is_about_2x25() {
        let r = OperatingMode::Sw.fmax_mhz(1.2) / OperatingMode::Sw.fmax_mhz(0.8);
        assert!(r > 2.0 && r < 2.5, "lift {r}");
    }

    #[test]
    fn mode_capabilities() {
        assert!(OperatingMode::CryCnnSw.aes_available());
        assert!(!OperatingMode::KecCnnSw.aes_available());
        assert!(OperatingMode::KecCnnSw.keccak_available());
        assert!(OperatingMode::KecCnnSw.hwce_available());
        assert!(!OperatingMode::Sw.hwce_available());
        assert!(!OperatingMode::Sw.keccak_available());
    }

    /// The subsumption order must agree with the per-engine capability
    /// flags: `a.supports(b)` iff every engine usable at `b` is usable
    /// at `a`.
    #[test]
    fn supports_is_capability_subsumption() {
        let modes = [OperatingMode::CryCnnSw, OperatingMode::KecCnnSw, OperatingMode::Sw];
        for a in modes {
            assert!(a.supports(a), "{a:?} must support itself");
            for b in modes {
                let flagwise = (!b.aes_available() || a.aes_available())
                    && (!b.keccak_available() || a.keccak_available())
                    && (!b.hwce_available() || a.hwce_available());
                assert_eq!(a.supports(b), flagwise, "{a:?} supports {b:?}");
            }
        }
        // the all-capable point hosts everything; SW hosts only SW
        assert!(OperatingMode::CryCnnSw.supports(OperatingMode::Sw));
        assert!(OperatingMode::CryCnnSw.supports(OperatingMode::KecCnnSw));
        assert!(!OperatingMode::Sw.supports(OperatingMode::KecCnnSw));
        assert!(!OperatingMode::KecCnnSw.supports(OperatingMode::CryCnnSw));
    }

    #[test]
    fn mode_frequency_ordering_preserved_across_vdd() {
        for i in 0..=8 {
            let v = 0.8 + 0.05 * i as f64;
            assert!(
                OperatingMode::Sw.fmax_mhz(v) > OperatingMode::KecCnnSw.fmax_mhz(v)
                    && OperatingMode::KecCnnSw.fmax_mhz(v) > OperatingMode::CryCnnSw.fmax_mhz(v)
            );
        }
    }

    #[test]
    fn cycles_to_time() {
        let op = OperatingPoint::nominal(OperatingMode::Sw);
        let t = op.cycles_to_s(120_000_000);
        assert!((t - 1.0).abs() < 1e-9);
    }
}
