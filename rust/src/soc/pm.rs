//! Power-state management: duty-cycled sleep across inter-frame gaps and
//! cluster stalls, driven by a pluggable DVFS/sleep policy (§II-A,
//! Table I; the exemplar end-node behaviour is Vega's state-retentive
//! sleep + cognitive wake-up).
//!
//! ## The problem this subsystem owns
//!
//! Traffic models ([`crate::traffic::Traffic`]) gate frame admission, so
//! a `Periodic{1 Hz}` seizure chip is ~99 % idle — yet without
//! management the scheduler bills that idle time at the *active-idle*
//! leakage floor (cluster + SOC leak, exactly Table I's "idle, FLL off"
//! rung) for the whole makespan. This module wires the
//! [`PowerMode`] ladder into the scheduler: every span in which the
//! chip (or just the cluster) has nothing running is re-billed at the
//! power state a [`PolicyKind`] chooses, with the wake-up transition
//! charged on re-entry.
//!
//! ## Billing model
//!
//! A managed span of length `T` seconds resting in rung `m` (power
//! `p_m` mW, wake-up time `w_m` s) costs
//!
//! ```text
//! E_m(T) = p_m · (T − w_m) + p_burn · w_m        [mJ]
//! ```
//!
//! — the chip sleeps at `p_m` and spends the final `w_m` of the span
//! waking back up at the *burn* power `p_burn`, which we pin to the
//! "idle, FLL on" rung (600 + 510 µW): during wake-up the FLL is
//! relocking and both domains are clock-gated but powered, which is
//! precisely what that Table I row describes. Descending into a rung is
//! free (clock/power gating is a write to the PMU); waking is not.
//!
//! **Break-even rule.** Against staying in the shallowest rung
//! (`E_on(T) = p_on · T`, since its burn equals its resting power),
//! rung `m` wins exactly when
//!
//! ```text
//! p_m (T − w_m) + p_on w_m < p_on T   ⟺   T > w_m
//! ```
//!
//! — *a sleep rung pays for itself iff the span exceeds its wake-up
//! time.* This collapse (burn = `p_on`) is why the greedy thresholds
//! below are the wake times themselves.
//!
//! ## Policies
//!
//! * **greedy** — no knowledge of the span length (a real PMU without a
//!   timer hint): rest in "idle, FLL on", descend to "idle, FLL off"
//!   after idling `w_off`, to deep sleep after `w_deep` (the ski-rental
//!   heuristic: descend to a rung once you have idled its wake time;
//!   2-competitive against the clairvoyant policy).
//! * **lookahead** — knows the span: full-chip gaps read the *next*
//!   release time from the traffic table, cluster stalls read the
//!   compiled frame's remaining work. Picks the single rung minimizing
//!   `E_m(T)` — by the break-even rule, the deepest rung whose wake
//!   time fits.
//! * **oracle** — whole-table lower bound: every managed span rests at
//!   deep-sleep power with free wake-up. No real PMU achieves it; it
//!   bounds what any policy could save.
//!
//! Per span, `E_oracle ≤ E_lookahead ≤ E_greedy` holds *algebraically*
//! (proved in the tests over both domains): lookahead's chosen rung is
//! one of greedy's stages minus the descent overhead, and the oracle
//! drops both the surcharge and the shallow stages.
//!
//! ## Scheduler contract
//!
//! [`gap_bill`] / [`stall_bill`] are pure functions of (policy, span) —
//! the scheduler calls them with identical float operations at
//! identical structural points in the live loop and in fast-forward
//! replay, so sleep accounting stays inside the cycle proof and replay
//! remains bitwise identical to live execution (the fleet dedup parity
//! guarantee).
//!
//! Full-chip gaps gate both domains and (in deep sleep) the external-
//! memory rails; cluster stalls manage only the cluster domain while
//! the SOC keeps serving uDMA traffic.

use crate::soc::power::PowerMode;
use anyhow::{bail, Result};

/// Reference battery for energy-per-day reporting: a 225 mAh / 3 V
/// lithium coin cell (CR2032 class) = 675 mWh.
pub const BATTERY_MWH: f64 = 675.0;

/// `BATTERY_MWH` in millijoules (1 mWh = 3.6 J = 3600 mJ).
pub const BATTERY_MJ: f64 = BATTERY_MWH * 3600.0;

/// Seconds per day, for energy-per-day extrapolation.
pub const SECONDS_PER_DAY: f64 = 86400.0;

/// Average-power → deployment-lifetime reporting: extrapolate a run's
/// mean power to a day, and that to days on [`BATTERY_MWH`].
pub fn energy_per_day_mj(energy_mj: f64, makespan_s: f64) -> f64 {
    energy_mj / makespan_s * SECONDS_PER_DAY
}

pub fn battery_days(energy_mj: f64, makespan_s: f64) -> f64 {
    BATTERY_MJ / energy_per_day_mj(energy_mj, makespan_s)
}

/// Dead time of a brown-out recovery (s): the supply collapse drops the
/// whole chip to the deep-sleep rung, and the watchdog restart pays the
/// full deep-sleep wake-up transition before the flushed frame can
/// re-execute ([`crate::fault`] bills this per reset event).
pub fn brownout_dead_s() -> f64 {
    Domain::Chip.ladder().wake_s[2]
}

/// Energy of that restart transition (mJ): the deep-sleep wake interval
/// billed at the burn power (the FLL-on idle rung) — the same wake-tail
/// arithmetic a managed span's bill charges.
pub fn brownout_wake_mj() -> f64 {
    let l = Domain::Chip.ladder();
    l.p_mw[0] * l.wake_s[2]
}

/// Which DVFS/sleep policy manages idle spans. Selected with
/// `stream`/`fleet --policy`; `None` at the scheduler level means
/// unmanaged (the pre-PM billing: active-idle leakage throughout).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum PolicyKind {
    /// Staged descent without span knowledge (ski-rental thresholds).
    Greedy,
    /// Span-aware single-rung choice (next release / remaining work).
    Lookahead,
    /// Whole-table lower bound: deep-sleep power, free wake-up.
    Oracle,
}

impl PolicyKind {
    /// Parse a CLI `--policy` name.
    pub fn parse(s: &str) -> Result<PolicyKind> {
        match s {
            "greedy" => Ok(PolicyKind::Greedy),
            "lookahead" => Ok(PolicyKind::Lookahead),
            "oracle" => Ok(PolicyKind::Oracle),
            _ => bail!("unknown policy {s:?} (expected greedy|lookahead|oracle)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            PolicyKind::Greedy => "greedy",
            PolicyKind::Lookahead => "lookahead",
            PolicyKind::Oracle => "oracle",
        }
    }

    fn policy(self) -> &'static dyn Policy {
        match self {
            PolicyKind::Greedy => &Greedy,
            PolicyKind::Lookahead => &Lookahead,
            PolicyKind::Oracle => &Oracle,
        }
    }
}

/// Which power domain a managed span covers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Domain {
    /// Full-chip inter-frame gap: cluster + SOC rest together, deep
    /// sleep additionally gates the external-memory standby rails.
    Chip,
    /// In-frame cluster stall (uDMA/ext-mem still busy): only the
    /// cluster side of the ladder applies.
    Cluster,
}

/// What a policy charges for one managed span.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Bill {
    /// Energy across the span, wake-up transition included (mJ).
    pub energy_mj: f64,
    /// Seconds of the span spent in deep sleep — the portion for which
    /// a full-chip gap also gates the external-memory standby rails.
    pub deep_s: f64,
    /// Whether a wake-up transition was paid (the span descended below
    /// the shallowest rung).
    pub woke: bool,
}

/// The sleep ladder a domain exposes: three rungs, shallow → deep.
/// Powers and wake times come from the Table I encoding in
/// [`PowerMode`] — this module adds no constants of its own.
#[derive(Debug, Clone, Copy)]
struct Ladder {
    /// Resting power per rung, mW: [FLL-on idle, FLL-off idle, deep].
    p_mw: [f64; 3],
    /// Wake-up time per rung, s.
    wake_s: [f64; 3],
}

const RUNGS: [PowerMode; 3] =
    [PowerMode::IdleFllOn, PowerMode::IdleFllOff, PowerMode::DeepSleep];

impl Domain {
    fn ladder(self) -> Ladder {
        let mut p_mw = [0.0; 3];
        let mut wake_s = [0.0; 3];
        for (i, m) in RUNGS.into_iter().enumerate() {
            let (cl_uw, soc_uw) = m.static_power_uw();
            let (cl_us, soc_us) = m.wakeup_us();
            match self {
                Domain::Chip => {
                    p_mw[i] = (cl_uw + soc_uw) * 1e-3;
                    wake_s[i] = cl_us.max(soc_us) * 1e-6;
                }
                Domain::Cluster => {
                    p_mw[i] = cl_uw * 1e-3;
                    wake_s[i] = cl_us * 1e-6;
                }
            }
        }
        Ladder { p_mw, wake_s }
    }

    /// The power the *unmanaged* scheduler bills across this domain's
    /// idle spans (the leakage floor `charge_overheads` charges over
    /// the whole makespan) — what a policy's bill replaces.
    pub fn baseline_mw(self, cluster_leak_mw: f64, soc_leak_mw: f64) -> f64 {
        match self {
            Domain::Chip => cluster_leak_mw + soc_leak_mw,
            Domain::Cluster => cluster_leak_mw,
        }
    }
}

/// One rung's span cost: rest at `p_mw`, spend the final `wake_s`
/// relocking at the burn power (the FLL-on idle rung).
fn rung_mj(l: &Ladder, rung: usize, span_s: f64) -> f64 {
    l.p_mw[rung] * (span_s - l.wake_s[rung]) + l.p_mw[0] * l.wake_s[rung]
}

/// A sleep policy: bills one managed idle span of a domain. The
/// implementations are stateless — all state a policy may consult
/// (span length, domain) is in the call, which is what lets the
/// scheduler re-issue the exact computation during fast-forward replay.
pub trait Policy {
    fn name(&self) -> &'static str;
    /// Cost of an idle span of `span_s` seconds in `domain`.
    fn bill(&self, domain: Domain, span_s: f64) -> Bill;
}

/// Staged descent: FLL-on for the first `w_off`, FLL-off until
/// `w_deep`, deep sleep beyond — thresholds are the rungs' own wake
/// times (see the break-even rule in the module docs).
pub struct Greedy;

impl Policy for Greedy {
    fn name(&self) -> &'static str {
        "greedy"
    }

    fn bill(&self, domain: Domain, span_s: f64) -> Bill {
        let l = domain.ladder();
        let (t1, t2) = (l.wake_s[1], l.wake_s[2]);
        if span_s <= t1 {
            Bill { energy_mj: l.p_mw[0] * span_s, deep_s: 0.0, woke: false }
        } else if span_s <= t2 {
            Bill {
                energy_mj: l.p_mw[0] * t1
                    + l.p_mw[1] * (span_s - t1)
                    + (l.p_mw[0] - l.p_mw[1]) * l.wake_s[1],
                deep_s: 0.0,
                woke: true,
            }
        } else {
            Bill {
                energy_mj: l.p_mw[0] * t1
                    + l.p_mw[1] * (t2 - t1)
                    + l.p_mw[2] * (span_s - t2)
                    + (l.p_mw[0] - l.p_mw[2]) * l.wake_s[2],
                deep_s: span_s - t2,
                woke: true,
            }
        }
    }
}

/// Span-aware: the deepest rung whose wake time fits (equivalently,
/// the rung minimizing `E_m(T)` — the break-even rule makes the two
/// statements the same).
pub struct Lookahead;

impl Policy for Lookahead {
    fn name(&self) -> &'static str {
        "lookahead"
    }

    fn bill(&self, domain: Domain, span_s: f64) -> Bill {
        let l = domain.ladder();
        let mut best = Bill { energy_mj: l.p_mw[0] * span_s, deep_s: 0.0, woke: false };
        if span_s > l.wake_s[1] {
            let e = rung_mj(&l, 1, span_s);
            if e < best.energy_mj {
                best = Bill { energy_mj: e, deep_s: 0.0, woke: true };
            }
        }
        if span_s > l.wake_s[2] {
            let e = rung_mj(&l, 2, span_s);
            if e < best.energy_mj {
                best = Bill { energy_mj: e, deep_s: span_s - l.wake_s[2], woke: true };
            }
        }
        best
    }
}

/// The lower bound: deep-sleep power over the whole span, free wake.
pub struct Oracle;

impl Policy for Oracle {
    fn name(&self) -> &'static str {
        "oracle"
    }

    fn bill(&self, domain: Domain, span_s: f64) -> Bill {
        let l = domain.ladder();
        Bill { energy_mj: l.p_mw[2] * span_s, deep_s: span_s, woke: false }
    }
}

/// Bill a full-chip inter-frame gap (both domains managed).
pub fn gap_bill(kind: PolicyKind, span_s: f64) -> Bill {
    kind.policy().bill(Domain::Chip, span_s)
}

/// Bill an in-frame cluster stall (cluster domain only).
pub fn stall_bill(kind: PolicyKind, span_s: f64) -> Bill {
    kind.policy().bill(Domain::Cluster, span_s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_policy_names() {
        assert_eq!(PolicyKind::parse("greedy").unwrap(), PolicyKind::Greedy);
        assert_eq!(PolicyKind::parse("lookahead").unwrap(), PolicyKind::Lookahead);
        assert_eq!(PolicyKind::parse("oracle").unwrap(), PolicyKind::Oracle);
        assert!(PolicyKind::parse("eager").is_err());
        for k in [PolicyKind::Greedy, PolicyKind::Lookahead, PolicyKind::Oracle] {
            assert_eq!(PolicyKind::parse(k.name()).unwrap(), k);
        }
    }

    #[test]
    fn ladder_matches_table1() {
        let chip = Domain::Chip.ladder();
        // Table I totals: 600+510, 210+120, 0.01+120 µW.
        assert!((chip.p_mw[0] - 1.11).abs() < 1e-12);
        assert!((chip.p_mw[1] - 0.33).abs() < 1e-12);
        assert!((chip.p_mw[2] - 0.12001).abs() < 1e-12);
        // Wake times: 20 µs / 300 µs / 3 ms after unit normalization.
        for (got, want) in chip.wake_s.into_iter().zip([20e-6, 300e-6, 3000e-6]) {
            assert!((got - want).abs() < 1e-12, "wake {got} != {want}");
        }
        let cl = Domain::Cluster.ladder();
        assert!((cl.p_mw[0] - 0.6).abs() < 1e-12);
        assert!((cl.p_mw[1] - 0.21).abs() < 1e-12);
        assert!(cl.p_mw[2] < 1e-4);
    }

    /// The break-even rule: a rung beats staying FLL-on exactly when the
    /// span exceeds its wake time.
    #[test]
    fn break_even_is_the_wake_time() {
        for domain in [Domain::Chip, Domain::Cluster] {
            let l = domain.ladder();
            for rung in 1..3 {
                let w = l.wake_s[rung];
                assert!(rung_mj(&l, rung, w * 0.999) > l.p_mw[0] * (w * 0.999));
                assert!(rung_mj(&l, rung, w * 1.001) < l.p_mw[0] * (w * 1.001));
                // At exactly the wake time the two are equal by algebra.
                assert!((rung_mj(&l, rung, w) - l.p_mw[0] * w).abs() < 1e-15);
            }
        }
    }

    /// Per-span policy ordering, the acceptance invariant: for every
    /// span length and both domains, oracle ≤ lookahead ≤ greedy.
    #[test]
    fn per_span_ordering_oracle_lookahead_greedy() {
        // Sweep spans from sub-wake to multi-second, log-spaced, plus
        // the exact thresholds where the piecewise forms meet.
        let mut spans: Vec<f64> = (0..200).map(|i| 1e-6 * 1.12f64.powi(i)).collect();
        spans.extend([20e-6, 300e-6, 3000e-6, 1.0, 86400.0]);
        for domain in [Domain::Chip, Domain::Cluster] {
            for &t in &spans {
                let g = Greedy.bill(domain, t).energy_mj;
                let la = Lookahead.bill(domain, t).energy_mj;
                let o = Oracle.bill(domain, t).energy_mj;
                assert!(
                    o <= la + 1e-15 && la <= g + 1e-12,
                    "{domain:?} span {t}: oracle {o} lookahead {la} greedy {g}"
                );
            }
        }
    }

    /// Long gaps converge: all policies approach deep-sleep power, and
    /// all beat the unmanaged active-idle baseline.
    #[test]
    fn long_gaps_sleep_below_baseline() {
        let t = 1.0; // a 1 Hz sensor's inter-frame gap
        let base = 0.33 * t; // cluster+soc leak floor, mJ
        for k in [PolicyKind::Greedy, PolicyKind::Lookahead, PolicyKind::Oracle] {
            let b = gap_bill(k, t);
            assert!(b.energy_mj < base, "{k:?} {b:?}");
            assert!(b.energy_mj > 0.0);
            assert!(b.deep_s > 0.9 * t, "{k:?} should rest deep: {b:?}");
        }
        assert!(gap_bill(PolicyKind::Greedy, t).woke);
        assert!(gap_bill(PolicyKind::Lookahead, t).woke);
        assert!(!gap_bill(PolicyKind::Oracle, t).woke);
    }

    /// Short spans: nobody can beat FLL-on, greedy and lookahead agree,
    /// and no wake-up is charged.
    #[test]
    fn short_spans_rest_shallow() {
        let t = 10e-6;
        let g = gap_bill(PolicyKind::Greedy, t);
        let la = gap_bill(PolicyKind::Lookahead, t);
        assert_eq!(g, la);
        assert!(!g.woke);
        assert_eq!(g.deep_s, 0.0);
        assert!((g.energy_mj - 1.11 * t).abs() < 1e-15);
    }

    #[test]
    fn battery_reporting_roundtrips() {
        // A chip averaging exactly 1 mW: 86.4 J/day, 675 mWh / 86.4 J.
        let epd = energy_per_day_mj(1.0, 1.0);
        assert!((epd - 86400.0).abs() < 1e-9);
        let days = battery_days(1.0, 1.0);
        assert!((days - BATTERY_MJ / 86400.0).abs() < 1e-9);
        assert!((days - 28.125).abs() < 1e-9);
    }
}
