//! A micro-ISA virtual machine standing in for the OR10N cores (§II).
//!
//! The paper's software baselines run on in-order, single-issue, 4-stage
//! OpenRISC cores extended with (a) zero-overhead hardware loops, (b) load
//! and store with embedded pointer arithmetic (post-increment), (c) 8/16-bit
//! SIMD instructions over the 32-bit registers including a single-cycle
//! dot-product (`pv.sdotsp.h/.b`), and (d) single-cycle fixed-point ops
//! (rounded normalization, clipping) [15].
//!
//! This VM executes real kernels written against that ISA and *counts
//! cycles structurally*: 1 cycle per issued instruction, +1 bubble on taken
//! branches (4-stage pipeline), zero overhead for hardware loops, and memory
//! stalls from per-cycle TCDM bank arbitration shared with the accelerator
//! and DMA masters ([`crate::cluster::tcdm`]). The paper's §III-C software
//! numbers (94 / 24 / 13 cycles/px) are *reproduced by execution*, not
//! asserted — see [`crate::kernels_sw`].

pub mod asm;
pub mod vm;

pub use asm::{Asm, Cond, Op, Reg};
pub use vm::{Machine, RunResult};
