//! The multi-core VM: executes up to four programs in cycle lockstep against
//! the shared TCDM, reproducing bank-conflict stalls between cores (and, when
//! combined with accelerator traffic generators, between cores and
//! accelerators).

use super::asm::{Cond, Op};
use crate::cluster::tcdm::Tcdm;
use crate::cluster::N_CORES;

/// Maximum hardware-loop nesting (two levels, as in the RI5CY/OR10N design).
const MAX_LOOP_NEST: usize = 2;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum CoreState {
    Running,
    /// Waiting for a TCDM grant for the current memory op.
    MemStall,
    Halted,
}

#[derive(Debug, Clone, Copy)]
struct LoopFrame {
    start: usize,
    end: usize, // exclusive: index one past the last body instruction
    remaining: u32,
}

/// One OR10N-like core.
pub struct CoreVm {
    pub regs: [i32; 32],
    pc: usize,
    prog: Vec<Op>,
    state: CoreState,
    loops: Vec<LoopFrame>,
    /// Extra cycles to burn (branch bubbles).
    bubble: u32,
    /// Statistics.
    pub instructions: u64,
    pub mem_stalls: u64,
}

impl CoreVm {
    fn new() -> Self {
        CoreVm {
            regs: [0; 32],
            pc: 0,
            prog: vec![Op::Halt],
            state: CoreState::Halted,
            loops: Vec::new(),
            bubble: 0,
            instructions: 0,
            mem_stalls: 0,
        }
    }

    fn load(&mut self, prog: Vec<Op>, args: &[(u8, i32)]) {
        self.prog = prog;
        self.pc = 0;
        self.regs = [0; 32];
        for &(r, v) in args {
            self.regs[r as usize] = v;
        }
        self.loops.clear();
        self.bubble = 0;
        self.state = CoreState::Running;
    }

    pub fn halted(&self) -> bool {
        self.state == CoreState::Halted
    }

    /// Advance pc honouring hardware loops (zero overhead: the loop-back
    /// happens in the same cycle as the last body instruction).
    fn advance_pc(&mut self) {
        self.pc += 1;
        while let Some(top) = self.loops.last_mut() {
            if self.pc == top.end {
                if top.remaining > 1 {
                    top.remaining -= 1;
                    self.pc = top.start;
                } else {
                    self.loops.pop();
                }
                continue;
            }
            break;
        }
    }
}

/// Result of a multi-core run.
#[derive(Debug, Clone, Copy)]
pub struct RunResult {
    /// Total cycles until all cores halted.
    pub cycles: u64,
    /// Sum of instructions issued across cores.
    pub instructions: u64,
    /// Total memory stall cycles across cores.
    pub mem_stalls: u64,
}

/// The cluster-side machine: 4 cores + shared TCDM.
pub struct Machine {
    pub tcdm: Tcdm,
    cores: Vec<CoreVm>,
    pub cycle: u64,
}

impl Default for Machine {
    fn default() -> Self {
        Self::new()
    }
}

impl Machine {
    pub fn new() -> Self {
        Machine {
            tcdm: Tcdm::new(),
            cores: (0..N_CORES).map(|_| CoreVm::new()).collect(),
            cycle: 0,
        }
    }

    /// Load `prog` onto core `c` with initial register values `args`.
    pub fn load_program(&mut self, c: usize, prog: Vec<Op>, args: &[(u8, i32)]) {
        self.cores[c].load(prog, args);
    }

    pub fn core(&self, c: usize) -> &CoreVm {
        &self.cores[c]
    }

    /// Run until all cores halt; returns cycle/instruction statistics.
    /// `max_cycles` guards against runaway programs.
    pub fn run(&mut self, max_cycles: u64) -> RunResult {
        let start_cycle = self.cycle;
        while self.cores.iter().any(|c| !c.halted()) {
            assert!(
                self.cycle - start_cycle < max_cycles,
                "VM exceeded {max_cycles} cycles"
            );
            self.step();
        }
        RunResult {
            cycles: self.cycle - start_cycle,
            instructions: self.cores.iter().map(|c| c.instructions).sum(),
            mem_stalls: self.cores.iter().map(|c| c.mem_stalls).sum(),
        }
    }

    /// One cluster cycle: all running cores issue; memory ops arbitrate on
    /// the TCDM; losers stall and retry next cycle.
    pub fn step(&mut self) {
        // Phase 1: collect memory requests from cores whose current op is a
        // memory access (or which are retrying after a stall).
        let mut wants_mem: [Option<u32>; N_CORES] = [None; N_CORES];
        for (i, core) in self.cores.iter_mut().enumerate() {
            if core.halted() {
                continue;
            }
            if core.bubble > 0 {
                continue;
            }
            if let Some(addr) = Self::mem_addr(core) {
                wants_mem[i] = Some(addr);
            }
        }
        for (i, addr) in wants_mem.iter().enumerate() {
            if let Some(a) = addr {
                self.tcdm.request(i, *a);
            }
        }
        let granted = self.tcdm.arbitrate();

        // Phase 2: execute.
        for i in 0..self.cores.len() {
            let core = &mut self.cores[i];
            if core.halted() {
                continue;
            }
            if core.bubble > 0 {
                core.bubble -= 1;
                continue;
            }
            if wants_mem[i].is_some() && !granted[i] {
                core.state = CoreState::MemStall;
                core.mem_stalls += 1;
                continue;
            }
            core.state = CoreState::Running;
            Self::execute(core, &mut self.tcdm);
        }
        self.cycle += 1;
    }

    /// Effective address of the current instruction if it is a memory op.
    fn mem_addr(core: &CoreVm) -> Option<u32> {
        let op = core.prog.get(core.pc)?;
        let ea = |ra: u8, off: i32| (core.regs[ra as usize].wrapping_add(off)) as u32;
        match *op {
            Op::Lw { ra, off, .. }
            | Op::Sw { ra, off, .. }
            | Op::Lh { ra, off, .. }
            | Op::Sh { ra, off, .. }
            | Op::Lb { ra, off, .. }
            | Op::Sb { ra, off, .. } => Some(ea(ra, off)),
            _ => None,
        }
    }

    fn execute(core: &mut CoreVm, tcdm: &mut Tcdm) {
        let op = core.prog[core.pc];
        core.instructions += 1;
        let r = &mut core.regs;
        let mut next_is_jump: Option<usize> = None;
        match op {
            Op::Add(d, a, b) => r[d as usize] = r[a as usize].wrapping_add(r[b as usize]),
            Op::Sub(d, a, b) => r[d as usize] = r[a as usize].wrapping_sub(r[b as usize]),
            Op::Mul(d, a, b) => r[d as usize] = r[a as usize].wrapping_mul(r[b as usize]),
            Op::Mac(d, a, b) => {
                r[d as usize] =
                    r[d as usize].wrapping_add(r[a as usize].wrapping_mul(r[b as usize]))
            }
            Op::And(d, a, b) => r[d as usize] = r[a as usize] & r[b as usize],
            Op::Or(d, a, b) => r[d as usize] = r[a as usize] | r[b as usize],
            Op::Xor(d, a, b) => r[d as usize] = r[a as usize] ^ r[b as usize],
            Op::Sll(d, a, b) => r[d as usize] = r[a as usize].wrapping_shl(r[b as usize] as u32 & 31),
            Op::Srl(d, a, b) => {
                r[d as usize] = ((r[a as usize] as u32) >> (r[b as usize] as u32 & 31)) as i32
            }
            Op::Sra(d, a, b) => r[d as usize] = r[a as usize] >> (r[b as usize] as u32 & 31),
            Op::Addi(d, a, imm) => r[d as usize] = r[a as usize].wrapping_add(imm),
            Op::Li(d, imm) => r[d as usize] = imm,
            Op::Mv(d, a) => r[d as usize] = r[a as usize],

            Op::SdotpH(d, a, b) => {
                let (x, y) = (r[a as usize], r[b as usize]);
                let dot = (x as i16 as i32) * (y as i16 as i32)
                    + ((x >> 16) as i16 as i32) * ((y >> 16) as i16 as i32);
                r[d as usize] = r[d as usize].wrapping_add(dot);
            }
            Op::SdotpB(d, a, b) => {
                let (x, y) = (r[a as usize], r[b as usize]);
                let mut dot = 0i32;
                for lane in 0..4 {
                    let xa = (x >> (8 * lane)) as i8 as i32;
                    let yb = (y >> (8 * lane)) as i8 as i32;
                    dot += xa * yb;
                }
                r[d as usize] = r[d as usize].wrapping_add(dot);
            }
            Op::AddNr(d, a, n) => {
                let v = r[a as usize] as i64;
                r[d as usize] = crate::fixedpoint::norm_round(v, n) as i32;
            }
            Op::Clip(d, a, bits) => r[d as usize] = crate::fixedpoint::clip(r[a as usize], bits),
            Op::Relu(d, a) => r[d as usize] = r[a as usize].max(0),
            Op::Max(d, a, b) => r[d as usize] = r[a as usize].max(r[b as usize]),
            Op::PackH(d, a, b) => {
                let hi_a = (r[a as usize] >> 16) & 0xffff;
                let lo_b = r[b as usize] & 0xffff;
                r[d as usize] = hi_a | (lo_b << 16);
            }

            Op::Lw { rd, ra, off, post } => {
                let ea = (r[ra as usize].wrapping_add(off)) as u32;
                r[rd as usize] = tcdm.read_u32(ea) as i32;
                r[ra as usize] = r[ra as usize].wrapping_add(post);
            }
            Op::Sw { rs, ra, off, post } => {
                let ea = (r[ra as usize].wrapping_add(off)) as u32;
                tcdm.write_u32(ea, r[rs as usize] as u32);
                r[ra as usize] = r[ra as usize].wrapping_add(post);
            }
            Op::Lh { rd, ra, off, post } => {
                let ea = (r[ra as usize].wrapping_add(off)) as u32;
                r[rd as usize] = tcdm.read_u16(ea) as i16 as i32;
                r[ra as usize] = r[ra as usize].wrapping_add(post);
            }
            Op::Sh { rs, ra, off, post } => {
                let ea = (r[ra as usize].wrapping_add(off)) as u32;
                tcdm.write_u16(ea, r[rs as usize] as u16);
                r[ra as usize] = r[ra as usize].wrapping_add(post);
            }
            Op::Lb { rd, ra, off, post } => {
                let ea = (r[ra as usize].wrapping_add(off)) as u32;
                r[rd as usize] = tcdm.read_u8(ea) as i8 as i32;
                r[ra as usize] = r[ra as usize].wrapping_add(post);
            }
            Op::Sb { rs, ra, off, post } => {
                let ea = (r[ra as usize].wrapping_add(off)) as u32;
                tcdm.write_u8(ea, r[rs as usize] as u8);
                r[ra as usize] = r[ra as usize].wrapping_add(post);
            }

            Op::Branch(cond, a, b, target) => {
                let (x, y) = (r[a as usize], r[b as usize]);
                let taken = match cond {
                    Cond::Eq => x == y,
                    Cond::Ne => x != y,
                    Cond::Lt => x < y,
                    Cond::Ge => x >= y,
                };
                if taken {
                    next_is_jump = Some(target);
                    core.bubble = 1; // pipeline bubble on taken branch
                }
            }
            Op::Jump(target) => {
                next_is_jump = Some(target);
                core.bubble = 1;
            }
            Op::HwLoop { count, body } => {
                let n = r[count as usize].max(0) as u32;
                Self::push_loop(core, n, body);
            }
            Op::HwLoopI { count, body } => {
                Self::push_loop(core, count, body);
            }
            Op::Halt => {
                core.state = CoreState::Halted;
                return;
            }
            Op::Nop => {}
        }
        match next_is_jump {
            Some(t) => core.pc = t,
            None => core.advance_pc(),
        }
    }

    fn push_loop(core: &mut CoreVm, n: u32, body: usize) {
        assert!(core.loops.len() < MAX_LOOP_NEST, "hardware loop nesting > 2");
        if n == 0 {
            // skip the body entirely
            core.pc += body; // advance_pc will +1 past the setup op
            return;
        }
        let start = core.pc + 1;
        core.loops.push(LoopFrame { start, end: start + body, remaining: n });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::asm::{Asm, Cond, Op};

    fn run_single(prog: Vec<Op>, args: &[(u8, i32)]) -> (Machine, RunResult) {
        let mut m = Machine::new();
        m.load_program(0, prog, args);
        let r = m.run(1_000_000);
        (m, r)
    }

    #[test]
    fn arithmetic_and_halt() {
        let mut a = Asm::new();
        a.op(Op::Li(1, 21));
        a.op(Op::Li(2, 2));
        a.op(Op::Mul(3, 1, 2));
        a.op(Op::Halt);
        let (m, r) = run_single(a.finish(), &[]);
        assert_eq!(m.core(0).regs[3], 42);
        assert_eq!(r.instructions, 4);
        assert_eq!(r.cycles, 4);
    }

    #[test]
    fn branch_loop_counts_bubbles() {
        // decrement r1 from 3 to 0 with a conditional branch: the taken
        // branch costs an extra bubble cycle each iteration.
        let mut a = Asm::new();
        a.op(Op::Li(1, 3));
        a.op(Op::Li(2, 0));
        a.label("top");
        a.op(Op::Addi(1, 1, -1));
        a.branch(Cond::Ne, 1, 2, "top");
        a.op(Op::Halt);
        let (m, r) = run_single(a.finish(), &[]);
        assert_eq!(m.core(0).regs[1], 0);
        // 2 li + 3×(addi+bne) + 2 bubbles (taken twice) + halt = 11
        assert_eq!(r.cycles, 11);
    }

    #[test]
    fn hw_loop_is_zero_overhead() {
        // same loop with the hardware loop: no branch, no bubble.
        let mut a = Asm::new();
        a.op(Op::Li(1, 0));
        a.hw_loop_i(10);
        a.op(Op::Addi(1, 1, 1));
        a.end_loop();
        a.op(Op::Halt);
        let (m, r) = run_single(a.finish(), &[]);
        assert_eq!(m.core(0).regs[1], 10);
        // li + setup + 10×addi + halt
        assert_eq!(r.cycles, 13);
    }

    #[test]
    fn nested_hw_loops() {
        let mut a = Asm::new();
        a.op(Op::Li(1, 0));
        a.hw_loop_i(4);
        a.hw_loop_i(5);
        a.op(Op::Addi(1, 1, 1));
        a.end_loop();
        a.op(Op::Nop);
        a.end_loop();
        a.op(Op::Halt);
        let (m, _) = run_single(a.finish(), &[]);
        assert_eq!(m.core(0).regs[1], 20);
    }

    #[test]
    fn zero_trip_hw_loop_skips_body() {
        let mut a = Asm::new();
        a.op(Op::Li(1, 7));
        a.op(Op::Li(2, 0));
        a.hw_loop(2);
        a.op(Op::Li(1, 99));
        a.end_loop();
        a.op(Op::Halt);
        let (m, _) = run_single(a.finish(), &[]);
        assert_eq!(m.core(0).regs[1], 7, "body must be skipped");
    }

    #[test]
    fn post_increment_load_store() {
        let mut m = Machine::new();
        m.tcdm.write_u32(0x100, 11);
        m.tcdm.write_u32(0x104, 22);
        let mut a = Asm::new();
        a.op(Op::Lw { rd: 2, ra: 1, off: 0, post: 4 });
        a.op(Op::Lw { rd: 3, ra: 1, off: 0, post: 4 });
        a.op(Op::Add(4, 2, 3));
        a.op(Op::Sw { rs: 4, ra: 1, off: 0, post: 0 });
        a.op(Op::Halt);
        m.load_program(0, a.finish(), &[(1, 0x100)]);
        m.run(1000);
        assert_eq!(m.tcdm.read_u32(0x108), 33);
    }

    #[test]
    fn sdotp_h_two_lanes() {
        let mut a = Asm::new();
        // x = [3, -2] packed, y = [10, 100] packed → dot = 30 - 200 = -170
        let x = (3i32 & 0xffff) | ((-2i32) << 16);
        let y = (10i32 & 0xffff) | (100i32 << 16);
        a.op(Op::Li(1, x));
        a.op(Op::Li(2, y));
        a.op(Op::Li(3, 5));
        a.op(Op::SdotpH(3, 1, 2));
        a.op(Op::Halt);
        let (m, _) = run_single(a.finish(), &[]);
        assert_eq!(m.core(0).regs[3], 5 - 170);
    }

    #[test]
    fn sdotp_b_four_lanes() {
        let mut a = Asm::new();
        let pack =
            |v: [i8; 4]| (v[0] as u8 as i32) | ((v[1] as u8 as i32) << 8) | ((v[2] as u8 as i32) << 16) | ((v[3] as u8 as i32) << 24);
        a.op(Op::Li(1, pack([1, -2, 3, -4])));
        a.op(Op::Li(2, pack([5, 6, 7, 8])));
        a.op(Op::Li(3, 0));
        a.op(Op::SdotpB(3, 1, 2));
        a.op(Op::Halt);
        let (m, _) = run_single(a.finish(), &[]);
        assert_eq!(m.core(0).regs[3], 5 - 12 + 21 - 32);
    }

    #[test]
    fn fixed_point_ops() {
        let mut a = Asm::new();
        a.op(Op::Li(1, 300));
        a.op(Op::AddNr(2, 1, 4)); // (300+8)>>4 = 19
        a.op(Op::Li(3, 40000));
        a.op(Op::Clip(4, 3, 16)); // clip to i16 → 32767
        a.op(Op::Li(5, -7));
        a.op(Op::Relu(6, 5));
        a.op(Op::Halt);
        let (m, _) = run_single(a.finish(), &[]);
        assert_eq!(m.core(0).regs[2], 19);
        assert_eq!(m.core(0).regs[4], 32767);
        assert_eq!(m.core(0).regs[6], 0);
    }

    #[test]
    fn two_cores_conflict_on_same_bank() {
        // Both cores hammer bank 0; each access pays ~1 stall every other
        // cycle, so 2-core runtime ≈ 2× the no-conflict time for the memory
        // portion.
        let prog = |_base: i32| {
            let mut a = Asm::new();
            a.hw_loop_i(100);
            a.op(Op::Lw { rd: 2, ra: 1, off: 0, post: 0 });
            a.end_loop();
            a.op(Op::Halt);
            a.finish()
        };
        let mut m = Machine::new();
        m.load_program(0, prog(0), &[(1, 0x0)]);
        m.load_program(1, prog(0), &[(1, 0x20)]); // same bank 0
        let r = m.run(100_000);
        assert!(r.mem_stalls > 80, "expected heavy conflict, got {}", r.mem_stalls);

        // different banks: no stalls
        let mut m2 = Machine::new();
        m2.load_program(0, prog(0), &[(1, 0x0)]);
        m2.load_program(1, prog(0), &[(1, 0x4)]); // bank 1
        let r2 = m2.run(100_000);
        assert_eq!(r2.mem_stalls, 0);
        assert!(r2.cycles < r.cycles);
    }

    #[test]
    fn four_cores_independent_banks_run_parallel() {
        let mut m = Machine::new();
        for c in 0..4 {
            let mut a = Asm::new();
            a.hw_loop_i(50);
            a.op(Op::Lw { rd: 2, ra: 1, off: 0, post: 0 });
            a.end_loop();
            a.op(Op::Halt);
            m.load_program(c, a.finish(), &[(1, (c * 4) as i32)]);
        }
        let r = m.run(100_000);
        assert_eq!(r.mem_stalls, 0);
        assert!(r.cycles <= 60);
    }
}
