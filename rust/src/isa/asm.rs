//! Instruction set and assembler DSL for the OR10N-like micro-ISA.

/// A register index r0..r31. r0 is a normal register (no hardwired zero —
/// OpenRISC convention differs from RISC-V; kernels simply avoid assuming 0).
pub type Reg = u8;

/// Branch conditions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Cond {
    Eq,
    Ne,
    Lt,
    Ge,
}

/// The instruction set. Arithmetic is 32-bit two's complement, wrapping
/// (as the hardware ALU); explicit saturation goes through `Clip`/`AddNr`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Op {
    // --- ALU ---
    /// rd = ra + rb
    Add(Reg, Reg, Reg),
    /// rd = ra - rb
    Sub(Reg, Reg, Reg),
    /// rd = ra * rb (low 32 bits)
    Mul(Reg, Reg, Reg),
    /// rd += ra * rb (multiply-accumulate, single cycle)
    Mac(Reg, Reg, Reg),
    And(Reg, Reg, Reg),
    Or(Reg, Reg, Reg),
    Xor(Reg, Reg, Reg),
    /// rd = ra << (rb & 31)
    Sll(Reg, Reg, Reg),
    /// rd = (ra as u32) >> (rb & 31)
    Srl(Reg, Reg, Reg),
    /// rd = ra >> (rb & 31) arithmetic
    Sra(Reg, Reg, Reg),
    /// rd = ra + imm
    Addi(Reg, Reg, i32),
    /// rd = imm
    Li(Reg, i32),
    /// rd = ra (register move)
    Mv(Reg, Reg),

    // --- DSP extensions (§II: SIMD over 32-bit registers) ---
    /// rd += dot(ra, rb) over 2 × 16-bit signed lanes (pv.sdotsp.h)
    SdotpH(Reg, Reg, Reg),
    /// rd += dot(ra, rb) over 4 × 8-bit signed lanes (pv.sdotsp.b)
    SdotpB(Reg, Reg, Reg),
    /// rd = (ra + 2^(n-1)) >> n — rounded normalization (p.addN-style)
    AddNr(Reg, Reg, u8),
    /// rd = clip(ra) to signed `bits` range (p.clip)
    Clip(Reg, Reg, u8),
    /// rd = max(ra, 0) — single-cycle ReLU via p.max with zero operand
    Relu(Reg, Reg),
    /// rd = max(ra, rb) (p.max)
    Max(Reg, Reg, Reg),
    /// rd = [ra.lane1, rb.lane0] — 16-bit lane pack (pv.pack.h), used to
    /// realign SIMD windows when convolving at odd offsets
    PackH(Reg, Reg, Reg),

    // --- memory (TCDM), with embedded pointer arithmetic ---
    /// rd = mem32[ra + off]; then ra += post (post-increment addressing)
    Lw { rd: Reg, ra: Reg, off: i32, post: i32 },
    /// mem32[ra + off] = rs; then ra += post
    Sw { rs: Reg, ra: Reg, off: i32, post: i32 },
    /// rd = sign-extended mem16[ra + off]; then ra += post
    Lh { rd: Reg, ra: Reg, off: i32, post: i32 },
    /// mem16[ra + off] = rs; then ra += post
    Sh { rs: Reg, ra: Reg, off: i32, post: i32 },
    /// rd = sign-extended mem8[ra + off]; then ra += post
    Lb { rd: Reg, ra: Reg, off: i32, post: i32 },
    /// mem8[ra + off] = rs; then ra += post
    Sb { rs: Reg, ra: Reg, off: i32, post: i32 },

    // --- control ---
    /// branch to absolute instruction index if cond(ra, rb)
    Branch(Cond, Reg, Reg, usize),
    /// unconditional jump to absolute instruction index
    Jump(usize),
    /// Zero-overhead hardware loop: repeat the next `body` instructions
    /// `count` times (lp.setup). Nesting up to 2 levels as in the hardware.
    HwLoop { count: Reg, body: usize },
    /// Hardware loop with immediate trip count.
    HwLoopI { count: u32, body: usize },
    /// Stop this core.
    Halt,
    Nop,
}

/// Two-pass assembler with string labels for branch targets.
pub struct Asm {
    ops: Vec<Op>,
    labels: std::collections::HashMap<String, usize>,
    fixups: Vec<(usize, String)>,
    /// Open hardware-loop bodies: (index of HwLoop op awaiting body length).
    open_loops: Vec<usize>,
}

impl Default for Asm {
    fn default() -> Self {
        Self::new()
    }
}

impl Asm {
    pub fn new() -> Self {
        Asm {
            ops: Vec::new(),
            labels: Default::default(),
            fixups: Vec::new(),
            open_loops: Vec::new(),
        }
    }

    pub fn op(&mut self, op: Op) -> &mut Self {
        self.ops.push(op);
        self
    }

    pub fn label(&mut self, name: &str) -> &mut Self {
        self.labels.insert(name.to_string(), self.ops.len());
        self
    }

    pub fn branch(&mut self, cond: Cond, ra: Reg, rb: Reg, label: &str) -> &mut Self {
        self.fixups.push((self.ops.len(), label.to_string()));
        self.ops.push(Op::Branch(cond, ra, rb, usize::MAX));
        self
    }

    pub fn jump(&mut self, label: &str) -> &mut Self {
        self.fixups.push((self.ops.len(), label.to_string()));
        self.ops.push(Op::Jump(usize::MAX));
        self
    }

    /// Open a hardware loop with immediate trip count; close with
    /// [`Asm::end_loop`]. The body length is patched automatically.
    pub fn hw_loop_i(&mut self, count: u32) -> &mut Self {
        self.open_loops.push(self.ops.len());
        self.ops.push(Op::HwLoopI { count, body: 0 });
        self
    }

    /// Open a register-count hardware loop.
    pub fn hw_loop(&mut self, count: Reg) -> &mut Self {
        self.open_loops.push(self.ops.len());
        self.ops.push(Op::HwLoop { count, body: 0 });
        self
    }

    pub fn end_loop(&mut self) -> &mut Self {
        let start = self.open_loops.pop().expect("end_loop without open loop");
        let body = self.ops.len() - start - 1;
        assert!(body > 0, "empty hardware loop");
        match &mut self.ops[start] {
            Op::HwLoop { body: b, .. } | Op::HwLoopI { body: b, .. } => *b = body,
            _ => unreachable!(),
        }
        self
    }

    pub fn finish(mut self) -> Vec<Op> {
        assert!(self.open_loops.is_empty(), "unclosed hardware loop");
        for (idx, label) in self.fixups {
            let target = *self
                .labels
                .get(&label)
                .unwrap_or_else(|| panic!("undefined label {label}"));
            match &mut self.ops[idx] {
                Op::Branch(_, _, _, t) | Op::Jump(t) => *t = target,
                _ => unreachable!(),
            }
        }
        self.ops
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_resolve() {
        let mut a = Asm::new();
        a.label("top");
        a.op(Op::Addi(1, 1, -1));
        a.branch(Cond::Ne, 1, 0, "top");
        a.op(Op::Halt);
        let prog = a.finish();
        assert_eq!(prog[1], Op::Branch(Cond::Ne, 1, 0, 0));
    }

    #[test]
    fn hw_loop_body_patched() {
        let mut a = Asm::new();
        a.hw_loop_i(10);
        a.op(Op::Nop);
        a.op(Op::Nop);
        a.end_loop();
        a.op(Op::Halt);
        let prog = a.finish();
        assert_eq!(prog[0], Op::HwLoopI { count: 10, body: 2 });
    }

    #[test]
    #[should_panic]
    fn undefined_label_panics() {
        let mut a = Asm::new();
        a.jump("nowhere");
        a.finish();
    }

    #[test]
    #[should_panic]
    fn unclosed_loop_panics() {
        let mut a = Asm::new();
        a.hw_loop_i(3);
        a.op(Op::Nop);
        a.finish();
    }
}
