//! Paper-artifact regeneration: one function per table/figure of the
//! evaluation (§III, §IV, Table I/II). Each returns a printable table;
//! the `fulmine` CLI and the bench harness print them, and integration
//! tests assert the comparative shape (who wins, by roughly what factor).
//!
//! The §IV figures (10/11/12) and the streaming/ablation reports resolve
//! their use cases through the [`crate::system::SocSystem`] façade — the
//! paper presentation (titles, published-number notes, feasibility
//! footers) is this module's only remaining job.

use crate::coordinator::{facedet, seizure, surveillance, UseCaseResult};
use crate::energy::EnergyLedger;
use crate::soc::sched::{SchedResult, N_ENGINES};
use crate::crypto::sponge::SpongeConfig;
use crate::hwce::golden::WeightPrec;
use crate::hwce::timing::{analytic_cycles_per_px, simulate_tile_cycles};
use crate::hwce::HwceJob;
use crate::hwcrypt::CipherOp;
use crate::isa::vm::Machine;
use crate::kernels_sw::conv::{run_conv, stage_tile, ConvImpl, ConvJob};
use crate::kernels_sw::crypto_cost;
use crate::soc::opmodes::{OperatingMode, OperatingPoint};
use crate::soc::power::{PowerMode, PowerModel, SOC_ACTIVE_MW, SOC_LEAK_MW};
use crate::system::{LadderReport, RunSpec, RungSel, SocSystem};
use anyhow::Result;
use std::fmt::Write as _;

const MODES: [OperatingMode; 3] =
    [OperatingMode::CryCnnSw, OperatingMode::KecCnnSw, OperatingMode::Sw];

/// Roll-up of scheduler results across concurrently running chips — the
/// one merge rule shared by [`crate::system::ShardedStream`] (S shards of
/// one stream) and the [`crate::system::Fleet`] aggregator (C chips per
/// dedup class, each weighted by its class population). Energy, busy
/// time, overlap, co-residency and relocks *sum* across chips; elapsed
/// time is the slowest chip's makespan (chips run concurrently); peak
/// residency is the per-chip maximum (each chip bounds its own memory).
/// Idle/standby energy accrues per chip over *its own* makespan — a chip
/// that drains early deep-sleeps (§II power modes) rather than leaking
/// until the slowest chip finishes — so merged energy is exactly the sum
/// of the member energies.
#[derive(Debug, Clone)]
pub struct Merged {
    /// Summed energy; `elapsed_s` pinned to [`Merged::time_s`].
    pub ledger: EnergyLedger,
    pub busy_s: [f64; N_ENGINES],
    pub overlap_s: f64,
    pub coresidency_s: f64,
    pub mode_switches: u64,
    pub peak_resident_jobs: usize,
    pub total_jobs: usize,
    pub fast_forwarded_frames: usize,
    /// Summed managed (sleep/retention) residency across chips (s) — see
    /// [`crate::soc::pm`]. Zero when no power policy ran.
    pub sleep_s: f64,
    /// Summed deep-sleep residency across chips (s).
    pub deep_sleep_s: f64,
    /// Summed wake transitions across chips.
    pub wake_transitions: u64,
    /// Summed frames dropped to faults across chips — see [`crate::fault`].
    pub frames_dropped: u64,
    /// Summed fault-recovery retry attempts across chips.
    pub fault_retries: u64,
    /// Summed brown-out / policy-forced chip resets across chips.
    pub chip_resets: u64,
    /// Summed in-flight frames lost to resets across chips.
    pub state_loss_frames: u64,
    /// Summed extra energy spent recovering from faults (mJ) across chips.
    pub recovery_energy_mj: f64,
    /// Slowest member's makespan.
    pub time_s: f64,
    /// Total chips absorbed (populations included).
    pub chips: usize,
}

impl Merged {
    /// The identity element: absorbing into an empty merge copies the
    /// member (merging S=1 is identity — property-tested).
    pub fn empty() -> Self {
        Merged {
            ledger: EnergyLedger::new(),
            busy_s: [0.0; N_ENGINES],
            overlap_s: 0.0,
            coresidency_s: 0.0,
            mode_switches: 0,
            peak_resident_jobs: 0,
            total_jobs: 0,
            fast_forwarded_frames: 0,
            sleep_s: 0.0,
            deep_sleep_s: 0.0,
            wake_transitions: 0,
            frames_dropped: 0,
            fault_retries: 0,
            chip_resets: 0,
            state_loss_frames: 0,
            recovery_energy_mj: 0.0,
            time_s: 0.0,
            chips: 0,
        }
    }

    /// Fold one scheduler result in, weighted by `chips` identical chips
    /// running it concurrently (`chips == 1` is the plain shard merge;
    /// the fleet path scales a class representative to its population).
    pub fn absorb(&mut self, r: &SchedResult, chips: usize) {
        let w = chips as f64;
        if chips == 1 {
            self.ledger.merge(&r.ledger);
        } else {
            self.ledger.merge(&r.ledger.scaled(w));
        }
        for e in 0..N_ENGINES {
            self.busy_s[e] += r.busy_s[e] * w;
        }
        self.overlap_s += r.overlap_s * w;
        self.coresidency_s += r.coresidency_s * w;
        self.mode_switches += r.mode_switches * chips as u64;
        self.peak_resident_jobs = self.peak_resident_jobs.max(r.peak_resident_jobs);
        self.total_jobs += r.n_jobs * chips;
        self.fast_forwarded_frames += r.fast_forwarded_frames * chips;
        self.sleep_s += r.sleep_s * w;
        self.deep_sleep_s += r.deep_sleep_s * w;
        self.wake_transitions += r.wake_transitions * chips as u64;
        self.frames_dropped += r.frames_dropped * chips as u64;
        self.fault_retries += r.fault_retries * chips as u64;
        self.chip_resets += r.chip_resets * chips as u64;
        self.state_loss_frames += r.state_loss_frames * chips as u64;
        self.recovery_energy_mj += r.recovery_energy_mj * w;
        self.time_s = self.time_s.max(r.makespan_s);
        self.chips += chips;
        // chips run concurrently: elapsed time is the slowest member, not
        // the sum `EnergyLedger::merge` accumulated
        self.ledger.elapsed_s = self.time_s;
    }

    /// Fold one scheduler result in after rescaling its time base by
    /// `scale` — the parametric-fleet seam: a drift-α class member is
    /// its representative with every duration (and therefore every
    /// energy integral) multiplied by α. Defined as *exactly*
    /// `absorb(&r.rescaled(scale), chips)` so the property tests can
    /// pin the equivalence bitwise; `scale == 1.0` degenerates to the
    /// plain absorb (multiplying by 1.0 is a float identity).
    pub fn absorb_scaled(&mut self, r: &SchedResult, chips: usize, scale: f64) {
        self.absorb(&r.rescaled(scale), chips);
    }

    /// Fold another roll-up in (the associativity seam: merging partial
    /// merges equals one flat merge on every summed field).
    pub fn combine(&mut self, other: &Merged) {
        self.ledger.merge(&other.ledger);
        for e in 0..N_ENGINES {
            self.busy_s[e] += other.busy_s[e];
        }
        self.overlap_s += other.overlap_s;
        self.coresidency_s += other.coresidency_s;
        self.mode_switches += other.mode_switches;
        self.peak_resident_jobs = self.peak_resident_jobs.max(other.peak_resident_jobs);
        self.total_jobs += other.total_jobs;
        self.fast_forwarded_frames += other.fast_forwarded_frames;
        self.sleep_s += other.sleep_s;
        self.deep_sleep_s += other.deep_sleep_s;
        self.wake_transitions += other.wake_transitions;
        self.frames_dropped += other.frames_dropped;
        self.fault_retries += other.fault_retries;
        self.chip_resets += other.chip_resets;
        self.state_loss_frames += other.state_loss_frames;
        self.recovery_energy_mj += other.recovery_energy_mj;
        self.time_s = self.time_s.max(other.time_s);
        self.chips += other.chips;
        self.ledger.elapsed_s = self.time_s;
    }
}

/// Merge `(result, chips)` pairs into one fleet-level roll-up.
pub fn merge<'a>(parts: impl IntoIterator<Item = (&'a SchedResult, usize)>) -> Merged {
    let mut m = Merged::empty();
    for (r, chips) in parts {
        m.absorb(r, chips);
    }
    m
}

/// Table I: power modes (encoded constants, printed verbatim).
pub fn table1() -> String {
    let mut s = String::new();
    writeln!(s, "== Table I: Fulmine power modes ==").unwrap();
    writeln!(s, "{:<18} {:>14} {:>12} {:>14} {:>12}", "mode", "CLUSTER µW", "wake µs", "SOC µW", "wake µs").unwrap();
    for m in [
        PowerMode::ActiveLowFreq,
        PowerMode::IdleFllOn,
        PowerMode::IdleFllOff,
        PowerMode::DeepSleep,
    ] {
        let (pc, ps) = m.static_power_uw();
        let (wc, ws) = m.wakeup_us();
        writeln!(s, "{:<18} {:>14.2} {:>12.2} {:>14.1} {:>12.1}", m.name(), pc, wc, ps, ws).unwrap();
    }
    s
}

/// Fig. 7: cluster fmax (a) and power (b) vs VDD in the three operating
/// modes.
pub fn fig7() -> String {
    let mut s = String::new();
    writeln!(s, "== Fig. 7a: cluster fmax [MHz] vs VDD ==").unwrap();
    writeln!(s, "{:>6} {:>12} {:>12} {:>8}", "VDD", "CRY-CNN-SW", "KEC-CNN-SW", "SW").unwrap();
    for i in 0..=8 {
        let v = 0.8 + 0.05 * i as f64;
        writeln!(
            s,
            "{v:>6.2} {:>12.1} {:>12.1} {:>8.1}",
            MODES[0].fmax_mhz(v),
            MODES[1].fmax_mhz(v),
            MODES[2].fmax_mhz(v)
        )
        .unwrap();
    }
    writeln!(s, "\n== Fig. 7b: cluster power [mW] at fmax, full activity ==").unwrap();
    writeln!(s, "{:>6} {:>12} {:>12} {:>8}", "VDD", "CRY(AES)", "KEC(HWCE)", "SW(4c)").unwrap();
    for i in 0..=8 {
        let v = 0.8 + 0.05 * i as f64;
        let cry = PowerModel::cluster_mw(OperatingPoint::new(MODES[0], v), 4, true, true, false);
        let kec = PowerModel::cluster_mw(OperatingPoint::new(MODES[1], v), 4, true, false, true);
        let sw = PowerModel::cluster_mw(OperatingPoint::new(MODES[2], v), 4, false, false, false);
        writeln!(s, "{v:>6.2} {cry:>12.1} {kec:>12.1} {sw:>8.1}").unwrap();
    }
    s
}

/// §III-B synthetic crypto benchmarks: cycles, cpb, speedups vs software.
pub fn sec3b() -> String {
    let mut s = String::new();
    writeln!(s, "== §III-B: HWCRYPT synthetic benchmarks (8 kB blocks) ==").unwrap();
    let bytes = 8192;
    let rows: [(&str, f64, f64, f64); 3] = [
        (
            "AES-128-ECB",
            CipherOp::AesEcb.cycles(bytes) as f64 + crate::hwcrypt::JOB_CONFIG_CYCLES as f64,
            crypto_cost::sw_ecb_cpb(1),
            crypto_cost::sw_ecb_cpb(4),
        ),
        (
            "AES-128-XTS",
            CipherOp::AesXts.cycles(bytes) as f64 + crate::hwcrypt::JOB_CONFIG_CYCLES as f64,
            crypto_cost::sw_xts_cpb(1),
            crypto_cost::sw_xts_cpb(4),
        ),
        (
            "KECCAK-f[400] AE",
            CipherOp::SpongeAe(SpongeConfig::MAX_RATE).cycles(bytes) as f64,
            crypto_cost::SW_KECCAK_CPB_1CORE,
            crypto_cost::SW_KECCAK_CPB_1CORE / 3.7,
        ),
    ];
    writeln!(
        s,
        "{:<18} {:>10} {:>8} {:>12} {:>12}",
        "cipher", "HW cycles", "HW cpb", "vs SW 1c", "vs SW 4c"
    )
    .unwrap();
    for (name, hw_cycles, sw1, sw4) in rows {
        let cpb = hw_cycles / bytes as f64;
        writeln!(
            s,
            "{name:<18} {hw_cycles:>10.0} {cpb:>8.3} {:>11.0}x {:>11.0}x",
            sw1 / cpb,
            sw4 / cpb
        )
        .unwrap();
    }
    writeln!(s, "(paper: ECB ~3100 cycles, 0.38 cpb, 450x/120x; XTS 495x/287x; AE 0.51 cpb)").unwrap();
    s
}

/// Fig. 8a: HWCRYPT time and energy per byte vs VDD.
pub fn fig8a() -> String {
    let mut s = String::new();
    writeln!(s, "== Fig. 8a: HWCRYPT time/energy per byte vs VDD ==").unwrap();
    writeln!(
        s,
        "{:>6} {:>11} {:>11} {:>11} {:>11} {:>12} {:>12}",
        "VDD", "XTS ns/B", "XTS pJ/B", "AE ns/B", "AE pJ/B", "XTS Gb/s/W", "AE Gb/s/W"
    )
    .unwrap();
    for i in 0..=8 {
        let v = 0.8 + 0.05 * i as f64;
        let cry = OperatingPoint::new(OperatingMode::CryCnnSw, v);
        let kec = OperatingPoint::new(OperatingMode::KecCnnSw, v);
        let p_cry = PowerModel::cluster_mw(cry, 1, false, true, false) + SOC_ACTIVE_MW + SOC_LEAK_MW;
        let p_kec = PowerModel::cluster_mw(kec, 1, false, false, true) + SOC_ACTIVE_MW + SOC_LEAK_MW;
        let t_xts = 0.38 / cry.freq_hz();
        let t_ae = 0.51 / kec.freq_hz();
        let e_xts = t_xts * p_cry * 1e9; // mW × s → pJ… (mW*ns = pJ)
        let e_ae = t_ae * p_kec * 1e9;
        writeln!(
            s,
            "{v:>6.2} {:>11.2} {e_xts:>11.1} {:>11.2} {e_ae:>11.1} {:>12.1} {:>12.1}",
            t_xts * 1e9,
            t_ae * 1e9,
            8.0 / (e_xts * 1e-3),
            8.0 / (e_ae * 1e-3),
        )
        .unwrap();
    }
    writeln!(s, "(paper @0.8V: 67 Gbit/s/W XTS, 100 Gbit/s/W sponge AE)").unwrap();
    s
}

/// §III-C: the convolution ladder — software numbers *measured on the VM*,
/// HWCE numbers from the detailed streamer simulation.
pub fn sec3c() -> String {
    let mut s = String::new();
    writeln!(s, "== §III-C: 2D convolution ladder (5x5, 32x32 tile) ==").unwrap();
    let job = ConvJob { w: 36, h: 36, k: 5, qf: 8, x_base: 0, w_base: 0x8000, y_base: 0x9000 };
    let x: Vec<i16> = (0..job.w * job.h).map(|i| (i % 251) as i16 - 125).collect();
    let wts: Vec<i16> = (0..25).map(|i| (i as i16) - 12).collect();

    let measure = |imp: ConvImpl, cores: usize| -> f64 {
        let mut m = Machine::new();
        stage_tile(&mut m, job, &x, &wts, imp);
        run_conv(&mut m, job, imp, cores).1
    };
    let naive1 = measure(ConvImpl::Naive, 1);
    let naive4 = measure(ConvImpl::Naive, 4);
    let simd4 = measure(ConvImpl::Simd, 4);

    writeln!(s, "{:<26} {:>12} {:>10}", "implementation", "cycles/px", "paper").unwrap();
    writeln!(s, "{:<26} {naive1:>12.2} {:>10}", "SW naive 1 core (VM)", "94").unwrap();
    writeln!(s, "{:<26} {naive4:>12.2} {:>10}", "SW naive 4 cores (VM)", "24").unwrap();
    writeln!(s, "{:<26} {simd4:>12.2} {:>10}", "SW SIMD 4 cores (VM)", "13").unwrap();
    for (prec, label, paper) in [
        (WeightPrec::W16, "HWCE 16b (detailed sim)", 1.14),
        (WeightPrec::W8, "HWCE 8b  (detailed sim)", 0.61),
        (WeightPrec::W4, "HWCE 4b  (detailed sim)", 0.45),
    ] {
        let j = HwceJob { w: 32, h: 32, k: 5, prec, qf: 8 };
        let cpp = simulate_tile_cycles(j) as f64 / (j.positions() * prec.simd()) as f64;
        writeln!(s, "{label:<26} {cpp:>12.2} {paper:>10}").unwrap();
    }
    let j16 = HwceJob { w: 32, h: 32, k: 5, prec: WeightPrec::W16, qf: 8 };
    let hw16 = simulate_tile_cycles(j16) as f64 / j16.positions() as f64;
    writeln!(
        s,
        "speedups: HWCE16 vs naive-1c = {:.0}x (paper 82x); vs SIMD-4c = {:.1}x (paper 11x)",
        naive1 / hw16,
        simd4 / hw16
    )
    .unwrap();
    s
}

/// Fig. 8b: HWCE time and energy per pixel vs VDD, per precision.
pub fn fig8b() -> String {
    let mut s = String::new();
    writeln!(s, "== Fig. 8b: HWCE time/energy per pixel vs VDD (5x5) ==").unwrap();
    writeln!(
        s,
        "{:>6} {:>10} {:>10} {:>10} {:>10} {:>10} {:>10}",
        "VDD", "16b ns/px", "16b pJ/px", "8b ns/px", "8b pJ/px", "4b ns/px", "4b pJ/px"
    )
    .unwrap();
    for i in 0..=8 {
        let v = 0.8 + 0.05 * i as f64;
        let op = OperatingPoint::new(OperatingMode::KecCnnSw, v);
        let p_mw = PowerModel::cluster_mw(op, 1, true, false, false) + SOC_ACTIVE_MW + SOC_LEAK_MW;
        let mut cells = Vec::new();
        for prec in [WeightPrec::W16, WeightPrec::W8, WeightPrec::W4] {
            let cyc = analytic_cycles_per_px(5, prec);
            let t_ns = cyc / op.freq_hz() * 1e9;
            cells.push((t_ns, t_ns * p_mw * 1e-3 * 1e3)); // ns × mW = pJ
        }
        writeln!(
            s,
            "{v:>6.2} {:>10.2} {:>10.1} {:>10.2} {:>10.1} {:>10.2} {:>10.1}",
            cells[0].0, cells[0].1, cells[1].0, cells[1].1, cells[2].0, cells[2].1
        )
        .unwrap();
    }
    writeln!(s, "(paper @0.8V 4b: ~50 pJ/px, 465 GMAC/s/W)").unwrap();
    s
}

/// Run a workload's ladder through the façade (the registry is the single
/// resolution point for every report).
fn system_ladder(workload: &str) -> LadderReport {
    SocSystem::new().ladder(workload).expect("built-in workload")
}

/// Fig. 10: secure autonomous aerial surveillance ladder.
pub fn fig10() -> String {
    let ladder = system_ladder("surveillance");
    let mut s = ladder.render_table(
        "Fig. 10: ResNet-20 secure surveillance (224x224, XTS on all ext. data)",
        Some("(paper: 114x time, 45x energy vs SW-1c; best 27 mJ, 3.16 pJ/op)"),
    );
    let best = ladder.rows.last().unwrap();
    let (iters, frac) = surveillance::flight_feasibility(best);
    writeln!(
        s,
        "feasibility: {iters} iterations in a 7-min flight, {:.3}% of the 2590 J battery (paper: 235 iters, <0.25%)",
        frac * 100.0
    )
    .unwrap();
    s
}

/// Fig. 11: face-detection ladder.
pub fn fig11() -> String {
    let ladder = system_ladder("facedet");
    let mut s = ladder.render_table(
        "Fig. 11: local face detection + secured remote recognition (224x224)",
        Some("(paper: 24x speedup, 13x energy; best 0.57 mJ, 5.74 pJ/op)"),
    );
    writeln!(
        s,
        "battery: {:.2} days continuous on 4 V 150 mAh (paper: ~1.6 days)",
        facedet::battery_days(ladder.rows.last().unwrap())
    )
    .unwrap();
    s
}

/// Fig. 12: seizure-detection ladder.
pub fn fig12() -> String {
    let ladder = system_ladder("seizure");
    let mut s = ladder.render_table(
        "Fig. 12: EEG seizure detection + secure collection (23ch x 256)",
        Some("(paper: 4.3x speedup, 2.1x energy; best 0.18 mJ, 12.7 pJ/op)"),
    );
    let (iters, days) = seizure::pacemaker_endurance(ladder.rows.last().unwrap());
    writeln!(
        s,
        "endurance: {:.1e} iterations, {days:.0} days continuous on a 2 Ah@3.3V battery (paper: >130e6, >750 days)",
        iters
    )
    .unwrap();
    s
}

/// Table II: state-of-the-art comparison. Fulmine rows are computed from
/// the model; literature rows are the published constants.
pub fn table2() -> String {
    let mut s = String::new();
    writeln!(s, "== Table II: state-of-the-art comparison ==").unwrap();
    writeln!(
        s,
        "{:<34} {:>10} {:>12} {:>11} {:>12} {:>9} {:>10} {:>9}",
        "platform", "P [mW]", "conv GMAC/s", "GMAC/s/W", "enc Gbit/s", "Gb/s/W", "SW MIPS", "MIPS/mW"
    )
    .unwrap();
    // literature rows (published values)
    let lit: [(&str, f64, f64, f64, f64, f64, f64, f64); 8] = [
        ("AES: Mathew et al. [36]", 0.43, 0.0, 0.0, 0.124, 289.0, 0.0, 0.0),
        ("AES: Zhao et al. [38]", 0.05, 0.0, 0.0, 0.027, 574.0, 0.0, 0.0),
        ("CNN: Origami [40]", 93.0, 37.0, 402.0, 0.0, 0.0, 0.0, 0.0),
        ("CNN: ShiDianNao [41]", 320.0, 64.0, 200.0, 0.0, 0.0, 0.0, 0.0),
        ("CNN: Eyeriss [42]", 278.0, 23.0, 83.0, 0.0, 0.0, 0.0, 0.0),
        ("IoT: SleepWalker [45]", 0.175, 0.0, 0.0, 0.0, 0.0, 25.0, 143.0),
        ("IoT: Konijnenburg [47]", 0.52, 0.0, 0.0, 0.0, 0.0, 10.4, 20.0),
        ("IoT: Mia Wallace [48]", 9.2, 2.41, 261.0, 0.0, 0.0, 270.0, 29.0),
    ];
    for (n, p, cp, ce, ep, ee, sp, se) in lit {
        writeln!(
            s,
            "{n:<34} {p:>10.3} {cp:>12.2} {ce:>11.0} {ep:>12.3} {ee:>9.0} {sp:>10.1} {se:>9.0}"
        )
        .unwrap();
    }
    // Fulmine rows from the model
    for (mode, label) in [
        (OperatingMode::CryCnnSw, "Fulmine CRY-CNN-SW @0.8V (model)"),
        (OperatingMode::KecCnnSw, "Fulmine KEC-CNN-SW @0.8V (model)"),
        (OperatingMode::Sw, "Fulmine SW @0.8V (model)"),
    ] {
        let op = OperatingPoint::nominal(mode);
        let f = op.freq_hz();
        let (conv_perf, conv_eff) = if mode.hwce_available() {
            let px = f / analytic_cycles_per_px(5, WeightPrec::W4);
            let gmacs = px * 25.0 / 1e9;
            let p = PowerModel::cluster_mw(op, 1, true, false, false) + SOC_ACTIVE_MW + SOC_LEAK_MW;
            (gmacs, gmacs / (p * 1e-3))
        } else {
            (0.0, 0.0)
        };
        let (enc_perf, enc_eff) = match mode {
            OperatingMode::CryCnnSw => {
                let gbit = f / 0.38 * 8.0 / 1e9;
                let p = PowerModel::cluster_mw(op, 1, false, true, false) + SOC_ACTIVE_MW + SOC_LEAK_MW;
                (gbit, gbit / (p * 1e-3))
            }
            OperatingMode::KecCnnSw => {
                let gbit = f / 0.51 * 8.0 / 1e9;
                let p = PowerModel::cluster_mw(op, 1, false, false, true) + SOC_ACTIVE_MW + SOC_LEAK_MW;
                (gbit, gbit / (p * 1e-3))
            }
            OperatingMode::Sw => (0.0, 0.0),
        };
        let mips = 4.0 * op.freq_mhz();
        let p_sw = PowerModel::cluster_mw(op, 4, false, false, false) + SOC_ACTIVE_MW + SOC_LEAK_MW;
        let total_p = PowerModel::cluster_mw(
            op,
            1,
            mode.hwce_available(),
            mode == OperatingMode::CryCnnSw,
            mode == OperatingMode::KecCnnSw,
        ) + SOC_ACTIVE_MW
            + SOC_LEAK_MW;
        writeln!(
            s,
            "{label:<34} {total_p:>10.1} {conv_perf:>12.2} {conv_eff:>11.0} {enc_perf:>12.3} {enc_eff:>9.0} {mips:>10.1} {:>9.0}",
            mips / p_sw
        )
        .unwrap();
    }
    // equivalent-efficiency comparison on the §IV-B workload
    let fd = system_ladder("facedet").rows;
    let best = fd.last().unwrap();
    let eq_ops = best.eq_ops as f64;
    let sleepwalker_time = eq_ops / 25e6; // 25 MIPS
    writeln!(s, "\nEquivalent efficiency (§IV-B mixed workload, {:.2e} eq-ops):", eq_ops).unwrap();
    writeln!(
        s,
        "  Fulmine: {:.2} pJ/op in {:.4} s   (paper: 5.74 pJ/op)",
        best.pj_per_op, best.time_s
    )
    .unwrap();
    writeln!(
        s,
        "  SleepWalker: 6.99 pJ/op in {sleepwalker_time:.2} s = {:.0}x slower (paper: 89x)",
        sleepwalker_time / best.time_s
    )
    .unwrap();
    s
}

/// The `fulmine stream` report: pipeline `frames` frames of a registered
/// workload through the event-driven scheduler and compare against
/// back-to-back single-frame runs. Thin wrapper over the
/// [`SocSystem`] façade, kept for callers that want the text in one call.
pub fn stream_report(usecase: &str, frames: usize, rung: Option<&str>) -> Result<String> {
    let spec = RunSpec::new(usecase).frames(frames).rung(RungSel::parse(rung));
    Ok(SocSystem::new().run(&spec)?.render_text())
}

/// Everything, in paper order.
pub fn all_reports() -> String {
    [
        table1(),
        fig7(),
        sec3b(),
        fig8a(),
        sec3c(),
        fig8b(),
        fig10(),
        fig11(),
        fig12(),
        table2(),
    ]
    .join("\n")
}

/// The artifact names [`paper_artifact`] resolves, in paper order — the
/// single list the CLI parser admits.
pub const PAPER_ARTIFACTS: [&str; 11] = [
    "table1", "fig7", "sec3b", "fig8a", "sec3c", "fig8b", "fig10", "fig11", "fig12", "table2",
    "all",
];

/// Regenerate one named paper artifact (`fulmine <name>`); `None` if the
/// name is not a paper table/figure.
pub fn paper_artifact(name: &str) -> Option<String> {
    Some(match name {
        "table1" => table1(),
        "fig7" => fig7(),
        "sec3b" => sec3b(),
        "fig8a" => fig8a(),
        "sec3c" => sec3c(),
        "fig8b" => fig8b(),
        "fig10" => fig10(),
        "fig11" => fig11(),
        "fig12" => fig12(),
        "table2" => table2(),
        "all" => all_reports(),
        _ => return None,
    })
}

/// The Fig. 10 ladder but sweeping ablations (used by `bench_usecases` and
/// the ablation study): returns (label, result) including intermediate
/// configurations not in the main ladder. Runs as [`RunSpec`] mode
/// overrides on the best rung via the façade.
pub fn surveillance_ablations() -> Vec<(String, UseCaseResult)> {
    SocSystem::new()
        .surveillance_ablations()
        .expect("surveillance is a built-in workload")
        .rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_reports_nonempty_and_mention_anchors() {
        let r = all_reports();
        for needle in [
            "Table I",
            "Fig. 7a",
            "§III-B",
            "Fig. 8a",
            "§III-C",
            "Fig. 8b",
            "Fig. 10",
            "Fig. 11",
            "Fig. 12",
            "Table II",
            "SleepWalker",
        ] {
            assert!(r.contains(needle), "missing {needle}");
        }
    }

    #[test]
    fn table2_fulmine_rows_match_paper_capabilities() {
        let t = table2();
        // the model-derived Fulmine rows must be present
        assert!(t.contains("Fulmine CRY-CNN-SW"));
        assert!(t.contains("Fulmine SW"));
    }

    #[test]
    fn stream_report_renders_and_selects_rungs() {
        // default rung (best)
        let s = stream_report("seizure", 2, None).unwrap();
        assert!(s.contains("2 frames"));
        // by index and by label substring
        assert!(stream_report("surveillance", 1, Some("0")).is_ok());
        assert!(stream_report("facedet", 1, Some("hwcrypt")).is_ok());
        // errors
        assert!(stream_report("surveillance", 1, Some("99")).is_err());
        assert!(stream_report("surveillance", 1, Some("nope")).is_err());
        assert!(stream_report("surveillance", 0, None).is_err());
        assert!(stream_report("bogus", 1, None).is_err());
    }

    /// The advertised name list and the dispatch match must not drift.
    #[test]
    fn paper_artifact_resolves_every_name() {
        for name in PAPER_ARTIFACTS {
            assert!(paper_artifact(name).is_some(), "{name}");
        }
        assert!(paper_artifact("fig99").is_none());
    }

    use crate::energy::Category;

    /// Synthetic scheduler result with dyadic (k/8) field values: float
    /// sums of dyadics this small are exact, so the merge identity and
    /// associativity properties below hold *bitwise*, not approximately.
    fn synth_result(i: usize) -> SchedResult {
        let d = |k: usize| (((i * 7 + k * 3) % 32) as f64) * 0.125;
        let mut ledger = EnergyLedger::new();
        for (k, cat) in Category::all().into_iter().enumerate() {
            ledger.charge_mj(cat, d(k));
        }
        let makespan = d(1) + 4.0;
        ledger.elapsed_s = makespan;
        let mut busy_s = [0.0f64; N_ENGINES];
        for (e, b) in busy_s.iter_mut().enumerate() {
            *b = d(e + 7);
        }
        SchedResult {
            ledger,
            makespan_s: makespan,
            mode_switches: (i % 5) as u64,
            busy_s,
            n_jobs: 10 + i,
            overlap_s: d(2),
            coresidency_s: d(3),
            peak_resident_jobs: 3 + (i % 4),
            fast_forwarded_frames: i % 9,
            sleep_s: d(4),
            deep_sleep_s: d(5),
            wake_transitions: (i % 7) as u64,
            frames_dropped: (i % 3) as u64,
            fault_retries: (i % 6) as u64,
            chip_resets: (i % 2) as u64,
            state_loss_frames: (i % 4) as u64,
            recovery_energy_mj: d(6),
        }
    }

    fn assert_merged_bitwise_eq(a: &crate::report::Merged, b: &crate::report::Merged) {
        for cat in Category::all() {
            assert_eq!(
                a.ledger.energy_mj(cat).to_bits(),
                b.ledger.energy_mj(cat).to_bits(),
                "{cat:?}"
            );
        }
        assert_eq!(a.ledger.elapsed_s.to_bits(), b.ledger.elapsed_s.to_bits());
        for e in 0..N_ENGINES {
            assert_eq!(a.busy_s[e].to_bits(), b.busy_s[e].to_bits(), "engine {e}");
        }
        assert_eq!(a.overlap_s.to_bits(), b.overlap_s.to_bits());
        assert_eq!(a.coresidency_s.to_bits(), b.coresidency_s.to_bits());
        assert_eq!(a.mode_switches, b.mode_switches);
        assert_eq!(a.peak_resident_jobs, b.peak_resident_jobs);
        assert_eq!(a.total_jobs, b.total_jobs);
        assert_eq!(a.fast_forwarded_frames, b.fast_forwarded_frames);
        assert_eq!(a.sleep_s.to_bits(), b.sleep_s.to_bits());
        assert_eq!(a.deep_sleep_s.to_bits(), b.deep_sleep_s.to_bits());
        assert_eq!(a.wake_transitions, b.wake_transitions);
        assert_eq!(a.frames_dropped, b.frames_dropped);
        assert_eq!(a.fault_retries, b.fault_retries);
        assert_eq!(a.chip_resets, b.chip_resets);
        assert_eq!(a.state_loss_frames, b.state_loss_frames);
        assert_eq!(a.recovery_energy_mj.to_bits(), b.recovery_energy_mj.to_bits());
        assert_eq!(a.time_s.to_bits(), b.time_s.to_bits());
        assert_eq!(a.chips, b.chips);
    }

    /// Property: merging a single result is the identity (every field
    /// survives bitwise, elapsed pinned to the makespan).
    #[test]
    fn merge_of_one_is_identity() {
        for i in 0..24 {
            let r = synth_result(i);
            let m = crate::report::merge([(&r, 1usize)]);
            for cat in Category::all() {
                assert_eq!(
                    m.ledger.energy_mj(cat).to_bits(),
                    r.ledger.energy_mj(cat).to_bits()
                );
            }
            assert_eq!(m.ledger.elapsed_s.to_bits(), r.makespan_s.to_bits());
            for e in 0..N_ENGINES {
                assert_eq!(m.busy_s[e].to_bits(), r.busy_s[e].to_bits());
            }
            assert_eq!(m.overlap_s.to_bits(), r.overlap_s.to_bits());
            assert_eq!(m.coresidency_s.to_bits(), r.coresidency_s.to_bits());
            assert_eq!(m.mode_switches, r.mode_switches);
            assert_eq!(m.peak_resident_jobs, r.peak_resident_jobs);
            assert_eq!(m.total_jobs, r.n_jobs);
            assert_eq!(m.fast_forwarded_frames, r.fast_forwarded_frames);
            assert_eq!(m.sleep_s.to_bits(), r.sleep_s.to_bits());
            assert_eq!(m.deep_sleep_s.to_bits(), r.deep_sleep_s.to_bits());
            assert_eq!(m.wake_transitions, r.wake_transitions);
            assert_eq!(m.frames_dropped, r.frames_dropped);
            assert_eq!(m.fault_retries, r.fault_retries);
            assert_eq!(m.chip_resets, r.chip_resets);
            assert_eq!(m.state_loss_frames, r.state_loss_frames);
            assert_eq!(m.recovery_energy_mj.to_bits(), r.recovery_energy_mj.to_bits());
            assert_eq!(m.time_s.to_bits(), r.makespan_s.to_bits());
            assert_eq!(m.chips, 1);
        }
    }

    /// Property: the merge is associative on the energy/busy/relock sums —
    /// combining partial merges in any grouping equals one flat merge.
    #[test]
    fn merge_is_associative() {
        for base in 0..8 {
            let (a, b, c) =
                (synth_result(base), synth_result(base + 11), synth_result(base + 23));
            let flat = crate::report::merge([(&a, 1usize), (&b, 1), (&c, 1)]);
            let sa = crate::report::merge([(&a, 1usize)]);
            let sb = crate::report::merge([(&b, 1usize)]);
            let sc = crate::report::merge([(&c, 1usize)]);
            // (a ⊕ b) ⊕ c
            let mut left = sa.clone();
            left.combine(&sb);
            left.combine(&sc);
            // a ⊕ (b ⊕ c)
            let mut bc = sb.clone();
            bc.combine(&sc);
            let mut right = sa.clone();
            right.combine(&bc);
            assert_merged_bitwise_eq(&left, &right);
            assert_merged_bitwise_eq(&left, &flat);
        }
    }

    /// Property: a population of C identical chips absorbed at once equals
    /// C separate absorbs (the fleet's analytic scaling is exactly the
    /// naive per-chip merge, bitwise on dyadic inputs).
    #[test]
    fn merge_population_scaling_matches_repeated_absorb() {
        let r = synth_result(5);
        let scaled = crate::report::merge([(&r, 3usize)]);
        let repeated = crate::report::merge([(&r, 1usize), (&r, 1), (&r, 1)]);
        assert_merged_bitwise_eq(&scaled, &repeated);
        assert_eq!(scaled.chips, 3);
        assert_eq!(scaled.total_jobs, 3 * r.n_jobs);
        assert_eq!(scaled.mode_switches, 3 * r.mode_switches);
        assert_eq!(scaled.wake_transitions, 3 * r.wake_transitions);
        assert_eq!(scaled.fault_retries, 3 * r.fault_retries);
        assert_eq!(scaled.frames_dropped, 3 * r.frames_dropped);
    }

    /// Property: `absorb_scaled` at scale 1.0 is bitwise the plain
    /// absorb (x × 1.0 is a float identity), and at any scale it equals
    /// absorbing a pre-rescaled result — the two ways a parametric
    /// member can reach the roll-up must agree exactly.
    #[test]
    fn absorb_scaled_matches_absorb_of_rescaled() {
        for i in 0..16 {
            let r = synth_result(i);
            // scale 1.0 degenerates to plain absorb
            let mut plain = Merged::empty();
            plain.absorb(&r, 4);
            let mut unit = Merged::empty();
            unit.absorb_scaled(&r, 4, 1.0);
            assert_merged_bitwise_eq(&plain, &unit);
            // general scales: absorb_scaled == absorb ∘ rescaled
            for scale in [0.5, 2.0, 1.25, 0.875] {
                let mut via_scaled = Merged::empty();
                via_scaled.absorb_scaled(&r, 3, scale);
                let mut via_rescale = Merged::empty();
                via_rescale.absorb(&r.rescaled(scale), 3);
                assert_merged_bitwise_eq(&via_scaled, &via_rescale);
            }
        }
    }

    /// Property: a population of C members at one power-of-two scale
    /// absorbed at once equals C separate scaled absorbs — population
    /// scaling and time-base scaling commute bitwise on dyadic inputs
    /// (×2⁻¹ and ×2 are exact, so the sums stay exact).
    #[test]
    fn absorb_scaled_population_matches_repeated_members() {
        let r = synth_result(9);
        for scale in [0.5, 2.0] {
            let mut pop = Merged::empty();
            pop.absorb_scaled(&r, 3, scale);
            let mut reps = Merged::empty();
            for _ in 0..3 {
                reps.absorb_scaled(&r, 1, scale);
            }
            assert_merged_bitwise_eq(&pop, &reps);
            assert_eq!(pop.chips, 3);
            assert_eq!(pop.time_s.to_bits(), (r.makespan_s * scale).to_bits());
        }
    }

    /// Scaling stretches every time-integrated field linearly and leaves
    /// counts alone (a drifted chip does the same *work* slower).
    #[test]
    fn rescaled_scales_times_and_energies_but_not_counts() {
        let r = synth_result(3);
        let s = r.rescaled(2.0);
        assert_eq!(s.makespan_s.to_bits(), (r.makespan_s * 2.0).to_bits());
        for cat in Category::all() {
            assert_eq!(
                s.ledger.energy_mj(cat).to_bits(),
                (r.ledger.energy_mj(cat) * 2.0).to_bits(),
                "{cat:?}"
            );
        }
        for e in 0..N_ENGINES {
            assert_eq!(s.busy_s[e].to_bits(), (r.busy_s[e] * 2.0).to_bits());
        }
        assert_eq!(s.sleep_s.to_bits(), (r.sleep_s * 2.0).to_bits());
        assert_eq!(s.deep_sleep_s.to_bits(), (r.deep_sleep_s * 2.0).to_bits());
        assert_eq!(s.n_jobs, r.n_jobs);
        assert_eq!(s.mode_switches, r.mode_switches);
        assert_eq!(s.wake_transitions, r.wake_transitions);
        assert_eq!(s.peak_resident_jobs, r.peak_resident_jobs);
        assert_eq!(s.fast_forwarded_frames, r.fast_forwarded_frames);
        // fault counters are events, not time: counts survive, the extra
        // recovery energy stretches with the time base
        assert_eq!(s.frames_dropped, r.frames_dropped);
        assert_eq!(s.fault_retries, r.fault_retries);
        assert_eq!(s.chip_resets, r.chip_resets);
        assert_eq!(s.state_loss_frames, r.state_loss_frames);
        assert_eq!(s.recovery_energy_mj.to_bits(), (r.recovery_energy_mj * 2.0).to_bits());
    }

    #[test]
    fn ablations_produce_distinct_results() {
        let ab = surveillance_ablations();
        assert_eq!(ab.len(), 5);
        assert!(ab.iter().any(|(l, _)| l == "hwce4 layer-gran"));
        // higher voltage: faster but less efficient
        let base = ab.iter().find(|(l, _)| l == "hwce8+hwcrypt").unwrap();
        let v12 = ab.iter().find(|(l, _)| l == "hwce4@1.2V").unwrap();
        assert!(v12.1.time_s < base.1.time_s);
    }
}
