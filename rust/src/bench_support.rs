//! Minimal benchmarking support for the `rust/benches/*` harnesses (the
//! offline crate set has no criterion): warmup + median-of-N wall-clock
//! measurement with spread, printed in a uniform format.

use std::time::Instant;

/// Measure `f`'s wall time: `warmup` unmeasured runs, then `n` measured
/// runs; returns (median_s, min_s, max_s).
pub fn measure<F: FnMut()>(warmup: usize, n: usize, mut f: F) -> (f64, f64, f64) {
    for _ in 0..warmup {
        f();
    }
    let mut times: Vec<f64> = (0..n)
        .map(|_| {
            let t = Instant::now();
            f();
            t.elapsed().as_secs_f64()
        })
        .collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (times[n / 2], times[0], times[n - 1])
}

/// Print one bench row: name, median, spread and an optional throughput.
pub fn report_row(name: &str, median_s: f64, min_s: f64, max_s: f64, throughput: Option<(f64, &str)>) {
    let tp = throughput
        .map(|(v, unit)| format!("  {v:>10.2} {unit}"))
        .unwrap_or_default();
    println!(
        "{name:<44} {:>10.3} ms  [{:>8.3} .. {:>8.3}]{tp}",
        median_s * 1e3,
        min_s * 1e3,
        max_s * 1e3
    );
}

/// Optimization barrier (re-export of std's black_box).
#[inline]
pub fn blackbox<T>(x: T) -> T {
    std::hint::black_box(x)
}
