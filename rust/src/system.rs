//! The `SocSystem` façade: typed run specifications in, structured —
//! machine-readable — reports out.
//!
//! Everything the CLI and benches used to do through stringly-typed free
//! functions (`stream_report(&str, usize, Option<&str>)`, ladder tuples,
//! inline `println!` rows) goes through three types here:
//!
//! * [`RunSpec`] — which [`crate::workload::Workload`], how many frames,
//!   which ladder [`Rung`] (by index, label substring, or best), and
//!   optional [`ModeOverrides`] on top (the ablation mechanism);
//! * [`SocSystem`] — resolves the spec against its workload [`Registry`],
//!   builds the frame graph, schedules it, and attributes the result
//!   (including per-tenant rows for multi-tenant workloads);
//! * [`RunReport`] / [`LadderReport`] / [`AblationReport`] — structured
//!   values that render to the exact text tables the CLI always printed
//!   *and* to JSON ([`crate::json`], hand-rolled — the crate stays
//!   anyhow-only).
//!
//! Multi-SoC scale-out lives here too: [`ShardedStream`] splits a frame
//! stream across S simulated Fulmine chips on `std::thread` workers (the
//! job-graph seam is the natural sharding boundary — frames are
//! independent, chips share nothing), and a [`RunSpec`] with
//! `shards > 1` returns the same [`RunReport`] with per-shard statistics
//! (simulated makespan, energy, and the `serialized_bound`/`analytic`
//! admission estimates) merged in: energy sums across chips, the
//! makespan is the slowest shard's, and throughput scales near-linearly.

use crate::coordinator::{
    share, stream_graph_faulted_pm, stream_graph_session_pm, ExecConfig, ModeOverrides, Rung,
    StreamResult, Tiling, UseCaseResult,
};
use crate::energy::{Category, EnergyLedger};
use crate::fault::{FaultModel, FaultPlan, Recovery};
use crate::hwce::golden::WeightPrec;
use crate::json::Json;
use crate::session::{BackendKind, SessionModel, SessionPlan, SessionRecovery, SessionStats};
use crate::soc::pm::{self, PolicyKind};
use crate::soc::sched::{
    exact_pow2, CompiledFrame, Engine, JobGraph, SchedResult, Scheduler, StreamScheduler,
    N_ENGINES,
};
use crate::traffic::{Perturb, Traffic};
use crate::workload::{frame_graph, frame_graph_with, Registry, Workload};
use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicUsize, Ordering as AtomicOrdering};
use std::sync::Mutex;
use std::time::Instant;

/// How a [`RunSpec`] selects a ladder rung.
#[derive(Debug, Clone, PartialEq)]
pub enum RungSel {
    /// The last (most accelerated) rung — the default.
    Best,
    /// By position on the workload's ladder.
    Index(usize),
    /// By case-insensitive label substring.
    Label(String),
}

impl RungSel {
    /// Parse a CLI `--config` selector: absent → best, an integer → index,
    /// anything else → label substring.
    pub fn parse(selector: Option<&str>) -> RungSel {
        match selector {
            None => RungSel::Best,
            Some(s) => match s.parse::<usize>() {
                Ok(i) => RungSel::Index(i),
                Err(_) => RungSel::Label(s.to_string()),
            },
        }
    }
}

/// A typed run request: the replacement for the stringly-typed
/// `stream_report` arguments.
#[derive(Debug, Clone)]
pub struct RunSpec {
    /// Registry name of the workload.
    pub workload: String,
    /// Frames to stream (1 = a single-frame run).
    pub frames: usize,
    pub rung: RungSel,
    /// Applied on top of the selected rung's configuration.
    pub overrides: ModeOverrides,
    /// In-flight frame window of the streaming scheduler
    /// ([`crate::soc::sched::DEFAULT_STREAM_WINDOW`] when `None`; clamped
    /// to the stream length). Live scheduler state is
    /// O(window × frame jobs) whatever `frames` is.
    pub window: Option<usize>,
    /// Simulated Fulmine chips to split the stream across (1 = one SoC,
    /// the default). With S > 1 the frames are sharded over S chips
    /// simulated on parallel host threads ([`ShardedStream`]) and the
    /// report carries per-shard statistics.
    pub shards: usize,
    /// Frame-arrival model gating the stream ([`Traffic::BackToBack`] by
    /// default — the PR 5 semantics). Sharded runs regenerate the model
    /// per chip: every chip is an independent sensor starting at `t = 0`.
    pub traffic: Traffic,
    /// Power-state policy managing idle spans ([`crate::soc::pm`]).
    /// `None` (the default) bills gaps at the historical FLL-on idle
    /// floor — bitwise identical to pre-policy runs.
    pub policy: Option<PolicyKind>,
    /// Deterministic fault-injection model ([`crate::fault`]). `None`
    /// (the default) never touches the fault machinery and is bitwise
    /// identical to the pre-fault simulator.
    pub faults: Option<FaultModel>,
    /// Recovery policy answering injected faults (3-attempt retry by
    /// default; ignored when `faults` is `None`).
    pub recovery: Recovery,
    /// Deterministic lossy secure-link channel ([`crate::session`]).
    /// `None` (the default) never touches the session machinery; session
    /// workloads then stream pure record frames with their handshake
    /// placeholders at zero cost. Mutually exclusive with `faults`.
    pub loss: Option<SessionModel>,
    /// How the secure link re-establishes its session after an outage
    /// (resumption by default; ignored when `loss` is `None`).
    pub session_recovery: SessionRecovery,
    /// Crypto cost backend for the workload's cipher phases
    /// ([`crate::session::CryptoBackend`]). `None` follows the rung's
    /// native configuration (HWCRYPT when the engine is enabled, SW
    /// otherwise) — bitwise the historical emission.
    pub crypto_backend: Option<BackendKind>,
}

impl RunSpec {
    pub fn new(workload: &str) -> Self {
        RunSpec {
            workload: workload.to_string(),
            frames: 1,
            rung: RungSel::Best,
            overrides: ModeOverrides::default(),
            window: None,
            shards: 1,
            traffic: Traffic::BackToBack,
            policy: None,
            faults: None,
            recovery: Recovery::default(),
            loss: None,
            session_recovery: SessionRecovery::default(),
            crypto_backend: None,
        }
    }

    pub fn frames(mut self, frames: usize) -> Self {
        self.frames = frames;
        self
    }

    pub fn rung(mut self, rung: RungSel) -> Self {
        self.rung = rung;
        self
    }

    pub fn overrides(mut self, overrides: ModeOverrides) -> Self {
        self.overrides = overrides;
        self
    }

    pub fn window(mut self, window: usize) -> Self {
        self.window = Some(window);
        self
    }

    pub fn shards(mut self, shards: usize) -> Self {
        self.shards = shards;
        self
    }

    pub fn traffic(mut self, traffic: Traffic) -> Self {
        self.traffic = traffic;
        self
    }

    pub fn policy(mut self, policy: Option<PolicyKind>) -> Self {
        self.policy = policy;
        self
    }

    pub fn faults(mut self, faults: Option<FaultModel>) -> Self {
        self.faults = faults;
        self
    }

    pub fn recovery(mut self, recovery: Recovery) -> Self {
        self.recovery = recovery;
        self
    }

    pub fn loss(mut self, loss: Option<SessionModel>) -> Self {
        self.loss = loss;
        self
    }

    pub fn session_recovery(mut self, session_recovery: SessionRecovery) -> Self {
        self.session_recovery = session_recovery;
        self
    }

    pub fn crypto_backend(mut self, crypto_backend: Option<BackendKind>) -> Self {
        self.crypto_backend = crypto_backend;
        self
    }
}

/// Per-chip statistics of a sharded stream run ([`ShardedStream`]).
#[derive(Debug, Clone)]
pub struct ShardStat {
    /// Shard index (0..S).
    pub shard: usize,
    /// Frames this chip streamed (near-equal [`share`] split).
    pub frames: usize,
    /// Simulated makespan of this chip's stream (s).
    pub time_s: f64,
    /// Total energy this chip consumed (mJ).
    pub energy_mj: f64,
    pub mode_switches: u64,
    pub peak_resident_jobs: usize,
    /// Frames this chip's scheduler replayed through the steady-state
    /// fast-forward path.
    pub fast_forwarded_frames: usize,
    /// Host wall-clock spent simulating this shard (s) — the simulator's
    /// own cost, not simulated time.
    pub wall_s: f64,
    /// Admission estimate for this shard's share: the analytic
    /// (serialized-cluster) single-frame replay × frames.
    pub analytic_est_s: f64,
    /// Worst-case admission bound: [`JobGraph::serialized_bound`] × frames
    /// — no schedule of this shard can exceed it.
    pub serialized_bound_s: f64,
}

/// Frame-parallel multi-SoC scale-out: split a stream of identical frames
/// across S simulated Fulmine chips, one `std::thread` worker per chip.
/// The frame template is compiled once ([`CompiledFrame`]) and shared
/// read-only by every worker; each chip streams its [`share`] of the
/// frames through the bounded-window scheduler independently (chips share
/// nothing — the job-graph seam makes frames embarrassingly parallel, the
/// scaling axis multi-cluster endpoint SoCs like Vega take in hardware).
pub struct ShardedStream;

impl ShardedStream {
    /// Run `frames` split across `shards` chips (each chip streams its
    /// share with in-flight window `window`, clamped per shard). Returns
    /// per-shard scheduler results and statistics in shard order; shards
    /// is clamped to `frames` so no chip receives an empty stream.
    pub fn run(
        graph: &JobGraph,
        frames: usize,
        window: usize,
        shards: usize,
    ) -> Vec<(SchedResult, ShardStat)> {
        Self::run_traffic(graph, frames, window, shards, &Traffic::BackToBack)
    }

    /// [`ShardedStream::run`] under a traffic model: each chip regenerates
    /// the arrival schedule for *its own* share (chips are independent
    /// sensors, each starting at `t = 0`), so an S-way split of a seeded
    /// model is reproducible whatever S is. Back-to-back traffic is
    /// bitwise identical to [`ShardedStream::run`].
    pub fn run_traffic(
        graph: &JobGraph,
        frames: usize,
        window: usize,
        shards: usize,
        traffic: &Traffic,
    ) -> Vec<(SchedResult, ShardStat)> {
        Self::run_traffic_pm(graph, frames, window, shards, traffic, None)
    }

    /// [`ShardedStream::run_traffic`] with an optional power-state policy
    /// ([`crate::soc::pm`]) applied identically on every chip. `None` is
    /// bitwise identical to [`ShardedStream::run_traffic`].
    pub fn run_traffic_pm(
        graph: &JobGraph,
        frames: usize,
        window: usize,
        shards: usize,
        traffic: &Traffic,
        policy: Option<PolicyKind>,
    ) -> Vec<(SchedResult, ShardStat)> {
        Self::run_faulted(graph, frames, window, shards, traffic, policy, None)
    }

    /// [`ShardedStream::run_traffic_pm`] under a fault model: each shard
    /// consumes the *global* fault table for its frame range (offset by
    /// the preceding shards' shares — [`FaultModel::table`] partitions
    /// exactly), so the union of shard faults equals the unsharded table
    /// whatever S is; release times stay per-chip local as always.
    /// `faults: None` is bitwise identical to
    /// [`ShardedStream::run_traffic_pm`].
    pub fn run_faulted(
        graph: &JobGraph,
        frames: usize,
        window: usize,
        shards: usize,
        traffic: &Traffic,
        policy: Option<PolicyKind>,
        faults: Option<(&FaultModel, Recovery)>,
    ) -> Vec<(SchedResult, ShardStat)> {
        assert!(frames >= 1, "sharded streaming needs at least one frame");
        assert!(window >= 1, "sharded streaming needs at least one in-flight frame of window");
        assert!(shards >= 1, "sharded streaming needs at least one chip");
        traffic.validate().expect("invalid traffic model");
        let shards = shards.min(frames);
        let template = CompiledFrame::compile(graph);
        let analytic_s = graph.analytic().makespan_s;
        let bound_s = graph.serialized_bound();
        let shares: Vec<usize> = (0..shards).map(|s| share(frames, shards, s)).collect();
        let releases: Vec<Vec<f64>> = shares.iter().map(|&f| traffic.release_times(f)).collect();
        // Per-shard recovery plans over the shard's global frame range:
        // pure in (model, range), so the same spec faults the same frames
        // however it is sharded or threaded.
        let mut offset = 0usize;
        let plans: Vec<Option<FaultPlan>> = shares
            .iter()
            .map(|&f| {
                let start = offset;
                offset += f;
                faults.map(|(m, rec)| FaultPlan::build(m, rec, graph, start, f, window.min(f)))
            })
            .collect();
        let results: Vec<(SchedResult, f64)> = std::thread::scope(|scope| {
            let template = &template;
            let handles: Vec<_> = shares
                .iter()
                .zip(&releases)
                .zip(&plans)
                .map(|((&f, rel), plan)| {
                    scope.spawn(move || {
                        let t0 = Instant::now();
                        let mut r = match plan {
                            None => StreamScheduler::run_compiled_traffic_pm(
                                template,
                                f,
                                window.min(f),
                                rel,
                                policy,
                            ),
                            Some(p) => StreamScheduler::run_with_variants_traffic_pm(
                                graph,
                                f,
                                window.min(f),
                                &p.variant_refs(),
                                rel,
                                policy,
                            ),
                        };
                        if let Some(p) = plan {
                            crate::fault::apply_stats(&mut r, &p.stats, 1.0);
                        }
                        (r, t0.elapsed().as_secs_f64())
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("shard worker panicked"))
                .collect()
        });
        results
            .into_iter()
            .enumerate()
            .map(|(i, (r, wall_s))| {
                // Gaps push the bound out: every frame has arrived by the
                // last release, after which serial execution is the worst
                // case (back-to-back's last release is 0 — unchanged).
                let last_rel = releases[i].last().copied().unwrap_or(0.0);
                let stat = ShardStat {
                    shard: i,
                    frames: shares[i],
                    time_s: r.makespan_s,
                    energy_mj: r.ledger.total_mj(),
                    mode_switches: r.mode_switches,
                    peak_resident_jobs: r.peak_resident_jobs,
                    fast_forwarded_frames: r.fast_forwarded_frames,
                    wall_s,
                    analytic_est_s: analytic_s * shares[i] as f64,
                    serialized_bound_s: last_rel + bound_s * shares[i] as f64,
                };
                (r, stat)
            })
            .collect()
    }

    /// [`ShardedStream::run_traffic_pm`] under a secure-link channel:
    /// each shard builds its [`SessionPlan`] over its *global* frame
    /// range (offset by the preceding shards' shares), so the union of
    /// shard plans equals the unsharded plan whatever S is — handshakes,
    /// retransmissions and outage skips land on the same global frames.
    /// Release times stay per-chip local as always. `session: None` is
    /// bitwise identical to [`ShardedStream::run_traffic_pm`].
    pub fn run_session(
        graph: &JobGraph,
        frames: usize,
        window: usize,
        shards: usize,
        traffic: &Traffic,
        policy: Option<PolicyKind>,
        session: Option<(&SessionModel, SessionRecovery)>,
    ) -> Result<Vec<(SchedResult, ShardStat)>> {
        assert!(frames >= 1, "sharded streaming needs at least one frame");
        assert!(window >= 1, "sharded streaming needs at least one in-flight frame of window");
        assert!(shards >= 1, "sharded streaming needs at least one chip");
        traffic.validate().expect("invalid traffic model");
        let shards = shards.min(frames);
        let template = CompiledFrame::compile(graph);
        let analytic_s = graph.analytic().makespan_s;
        let bound_s = graph.serialized_bound();
        let shares: Vec<usize> = (0..shards).map(|s| share(frames, shards, s)).collect();
        let releases: Vec<Vec<f64>> = shares.iter().map(|&f| traffic.release_times(f)).collect();
        // Per-shard session plans over the shard's global frame range:
        // pure in (model, recovery, range), so the same spec answers the
        // same outages however it is sharded or threaded.
        let mut offset = 0usize;
        let mut plans: Vec<Option<SessionPlan>> = Vec::with_capacity(shards);
        for &f in &shares {
            let start = offset;
            offset += f;
            plans.push(match session {
                None => None,
                Some((m, rec)) => Some(SessionPlan::build(m, rec, graph, start, f)?),
            });
        }
        let results: Vec<(SchedResult, f64)> = std::thread::scope(|scope| {
            let template = &template;
            let handles: Vec<_> = shares
                .iter()
                .zip(&releases)
                .zip(&plans)
                .map(|((&f, rel), plan)| {
                    scope.spawn(move || {
                        let t0 = Instant::now();
                        let mut r = match plan {
                            None => StreamScheduler::run_compiled_traffic_pm(
                                template,
                                f,
                                window.min(f),
                                rel,
                                policy,
                            ),
                            Some(p) => StreamScheduler::run_with_variants_traffic_pm(
                                graph,
                                f,
                                window.min(f),
                                &p.variant_refs(),
                                rel,
                                policy,
                            ),
                        };
                        if let Some(p) = plan {
                            crate::session::apply_stats(&mut r, &p.stats, 1.0);
                        }
                        (r, t0.elapsed().as_secs_f64())
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("shard worker panicked"))
                .collect()
        });
        Ok(results
            .into_iter()
            .enumerate()
            .map(|(i, (r, wall_s))| {
                let last_rel = releases[i].last().copied().unwrap_or(0.0);
                let stat = ShardStat {
                    shard: i,
                    frames: shares[i],
                    time_s: r.makespan_s,
                    energy_mj: r.ledger.total_mj(),
                    mode_switches: r.mode_switches,
                    peak_resident_jobs: r.peak_resident_jobs,
                    fast_forwarded_frames: r.fast_forwarded_frames,
                    wall_s,
                    analytic_est_s: analytic_s * shares[i] as f64,
                    serialized_bound_s: last_rel + bound_s * shares[i] as f64,
                };
                (r, stat)
            })
            .collect())
    }
}

/// Merge per-shard scheduler results into one [`StreamResult`] via the
/// shared [`crate::report::merge`] rule (energy/busy/overlap/relocks sum,
/// makespan = slowest shard, peak residency = per-chip max, per-chip
/// idle/standby — see [`crate::report::Merged`]), then package the stream
/// presentation around it.
fn merge_sharded(
    label: &str,
    graph: &JobGraph,
    frames: usize,
    window: usize,
    eq_ops_per_frame: u64,
    parts: &[(SchedResult, ShardStat)],
    policy: Option<PolicyKind>,
) -> StreamResult {
    let single = Scheduler::run(graph);
    let analytic = graph.analytic();
    let max_share = parts.iter().map(|(_, st)| st.frames).max().unwrap_or(0);
    let m = crate::report::merge(parts.iter().map(|(r, _)| (r, 1usize)));
    let energy_mj = m.ledger.total_mj();
    StreamResult {
        label: label.to_string(),
        frames,
        time_s: m.time_s,
        fps: frames as f64 / m.time_s,
        energy_mj,
        pj_per_op: energy_mj * 1e9 / (eq_ops_per_frame as f64 * frames as f64),
        single_frame_s: single.makespan_s,
        single_frame_analytic_s: analytic.makespan_s,
        speedup: single.makespan_s * frames as f64 / m.time_s,
        mode_switches: m.mode_switches,
        busy_s: m.busy_s,
        overlap_s: m.overlap_s,
        coresidency_s: m.coresidency_s,
        // each chip clamps to its own share; report the widest window any
        // shard actually ran with
        window: window.min(max_share),
        peak_resident_jobs: m.peak_resident_jobs,
        total_jobs: m.total_jobs,
        fast_forwarded_frames: m.fast_forwarded_frames,
        policy,
        sleep_s: m.sleep_s,
        deep_sleep_s: m.deep_sleep_s,
        wake_transitions: m.wake_transitions,
        frames_dropped: m.frames_dropped,
        fault_retries: m.fault_retries,
        chip_resets: m.chip_resets,
        state_loss_frames: m.state_loss_frames,
        recovery_energy_mj: m.recovery_energy_mj,
        ledger: m.ledger,
    }
}

// ---- fleet-scale simulation -------------------------------------------

/// One homogeneous population of a [`Fleet`]: `chips` endpoints all
/// running the same [`RunSpec`] (workload, rung, frames, window, traffic
/// phase). Chips of one group are simulation-identical by construction —
/// the dedup layer simulates the whole group once.
#[derive(Debug, Clone)]
pub struct FleetGroup {
    pub spec: RunSpec,
    pub chips: usize,
}

/// A fleet request: chip populations plus the dedup-validation knobs.
#[derive(Debug, Clone)]
pub struct FleetSpec {
    pub groups: Vec<FleetGroup>,
    /// Live simulations per class, the class representative included: the
    /// remaining `sample_k − 1` randomly sampled members re-run through
    /// the fast-forward-disabled live path and must match the scaled
    /// representative *bitwise*. Total live chips ≤ classes × sample_k.
    pub sample_k: usize,
    /// Host worker threads over classes (0 = available parallelism).
    pub threads: usize,
    /// Power-state policy applied fleet-wide ([`crate::soc::pm`]): every
    /// chip manages its idle gaps under the same policy, and the report
    /// gains battery-life percentiles. `None` = the historical always-on
    /// idle floor.
    pub policy: Option<PolicyKind>,
    /// Per-chip process/temperature service-time drift amplitude, in
    /// percent: chip `i` draws a deterministic scale factor
    /// `α ∈ [1 − drift/100, 1 + drift/100]` ([`Perturb::derive`]) that
    /// multiplies every service time (and the FLL relock). `0.0` =
    /// homogeneous fleet (the historical behaviour).
    pub drift_pct: f64,
    /// Per-chip traffic phase offset amplitude, in seconds: chip `i`
    /// draws a deterministic offset `φ ∈ [0, phase_jitter_s]` added to
    /// every release time before the drift scale. `0.0` = all chips
    /// phase-aligned.
    pub phase_jitter_s: f64,
    /// Seed for the per-chip perturbation derivation (chips keep their
    /// α/φ across runs and shardings).
    pub seed: u64,
    /// Deterministic fault-injection model applied fleet-wide
    /// ([`crate::fault`]): every chip of a class draws the same
    /// per-frame fault table. Joins the class dedup key; `None` is
    /// bitwise the historical fault-free fleet.
    pub faults: Option<FaultModel>,
    /// Recovery policy answering injected faults (ignored when `faults`
    /// is `None`).
    pub recovery: Recovery,
    /// Deterministic lossy secure-link channel applied fleet-wide
    /// ([`crate::session`]): every chip of a class draws the same
    /// per-frame delivery table. Joins the class dedup key; requires
    /// every group workload to be a session workload. Mutually exclusive
    /// with `faults`.
    pub loss: Option<SessionModel>,
    /// Session re-establishment policy after outages (ignored when
    /// `loss` is `None`).
    pub session_recovery: SessionRecovery,
    /// Crypto cost backend override for every chip's cipher phases
    /// (`None` follows each rung's native configuration). Joins the
    /// class dedup key.
    pub crypto_backend: Option<BackendKind>,
    /// Test-only: flip the low mantissa bit of every sampled parity
    /// run's makespan, forcing the structured parity-mismatch error so
    /// its reporting path can be exercised end to end.
    #[doc(hidden)]
    pub corrupt_parity: bool,
}

impl FleetSpec {
    pub fn new(groups: Vec<FleetGroup>) -> Self {
        FleetSpec {
            groups,
            sample_k: 3,
            threads: 0,
            policy: None,
            drift_pct: 0.0,
            phase_jitter_s: 0.0,
            seed: 0xF1EE7,
            faults: None,
            recovery: Recovery::default(),
            loss: None,
            session_recovery: SessionRecovery::default(),
            crypto_backend: None,
            corrupt_parity: false,
        }
    }

    pub fn sample_k(mut self, sample_k: usize) -> Self {
        self.sample_k = sample_k;
        self
    }

    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    pub fn policy(mut self, policy: Option<PolicyKind>) -> Self {
        self.policy = policy;
        self
    }

    pub fn drift(mut self, drift_pct: f64) -> Self {
        self.drift_pct = drift_pct;
        self
    }

    pub fn phase_jitter(mut self, phase_jitter_s: f64) -> Self {
        self.phase_jitter_s = phase_jitter_s;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn faults(mut self, faults: Option<FaultModel>) -> Self {
        self.faults = faults;
        self
    }

    pub fn recovery(mut self, recovery: Recovery) -> Self {
        self.recovery = recovery;
        self
    }

    pub fn loss(mut self, loss: Option<SessionModel>) -> Self {
        self.loss = loss;
        self
    }

    pub fn session_recovery(mut self, session_recovery: SessionRecovery) -> Self {
        self.session_recovery = session_recovery;
        self
    }

    pub fn crypto_backend(mut self, crypto_backend: Option<BackendKind>) -> Self {
        self.crypto_backend = crypto_backend;
        self
    }

    /// The standard heterogeneous mix `fulmine fleet` runs: `chips`
    /// endpoints spread near-evenly over every built-in workload × two
    /// rungs (worst, best) × four traffic models (back-to-back, periodic
    /// at the workload's native sensor rate, 4-frame bursts, Poisson
    /// triggers). Poisson chips draw their seed from a bounded per-chip
    /// pool rather than one pooled seed per template: sub-populations of
    /// one template get genuinely distinct release tables (so the mixed
    /// fleet exercises class sampling and the parametric path), while the
    /// class count stays O(templates × pool) — the dedup invariant the
    /// whole fleet runner rests on. The pool scales with per-template
    /// population (1 for small fleets, the historical behaviour, up to 8).
    pub fn mixed(chips: usize, frames: usize) -> FleetSpec {
        assert!(chips >= 1, "a fleet needs at least one chip");
        assert!(frames >= 1, "fleet chips need at least one frame");
        let registry = Registry::builtin();
        // Template list: `None` is a fully specified deterministic traffic
        // model; `Some(rate)` is a Poisson template whose seed is spread
        // over the pool below.
        let mut templates: Vec<(RunSpec, Option<f64>)> = Vec::new();
        for w in registry.iter() {
            let rate = w.native_rate_hz();
            for rung in [RungSel::Best, RungSel::Index(0)] {
                for t in [
                    Traffic::BackToBack,
                    Traffic::Periodic { rate_hz: rate },
                    Traffic::Bursty { burst: 4, rate_hz: rate / 4.0 },
                ] {
                    templates.push((
                        RunSpec::new(w.name()).frames(frames).rung(rung.clone()).traffic(t),
                        None,
                    ));
                }
                templates
                    .push((RunSpec::new(w.name()).frames(frames).rung(rung.clone()), Some(rate)));
            }
        }
        let n = templates.len();
        let pool = (chips / (4 * n)).clamp(1, 8);
        let mut seed = 0u64;
        let mut groups: Vec<FleetGroup> = Vec::new();
        for (i, (spec, poisson_rate)) in templates.into_iter().enumerate() {
            let t_chips = share(chips, n, i);
            match poisson_rate {
                None => groups.push(FleetGroup { spec, chips: t_chips }),
                Some(rate_hz) => {
                    for k in 0..pool {
                        seed += 1;
                        groups.push(FleetGroup {
                            spec: spec.clone().traffic(Traffic::Poisson { rate_hz, seed }),
                            chips: share(t_chips, pool, k),
                        });
                    }
                }
            }
        }
        groups.retain(|g| g.chips > 0);
        FleetSpec::new(groups)
    }

    /// The secure-link fleet `fulmine fleet --loss` runs: `chips`
    /// endpoints spread near-evenly over the `secure_link` workload's
    /// rungs (worst, best) × the four traffic models, mirroring
    /// [`FleetSpec::mixed`] but session-only — every class can carry the
    /// channel plan, where `mixed`'s non-session workloads could not.
    pub fn secure_link(chips: usize, frames: usize) -> FleetSpec {
        assert!(chips >= 1, "a fleet needs at least one chip");
        assert!(frames >= 1, "fleet chips need at least one frame");
        let registry = Registry::builtin();
        let w = registry.resolve("secure_link").expect("secure_link is built in");
        let rate = w.native_rate_hz();
        let mut templates: Vec<RunSpec> = Vec::new();
        for rung in [RungSel::Best, RungSel::Index(0)] {
            for t in [
                Traffic::BackToBack,
                Traffic::Periodic { rate_hz: rate },
                Traffic::Bursty { burst: 4, rate_hz: rate / 4.0 },
                Traffic::Poisson { rate_hz: rate, seed: 1 },
            ] {
                templates
                    .push(RunSpec::new(w.name()).frames(frames).rung(rung.clone()).traffic(t));
            }
        }
        let n = templates.len();
        let mut groups: Vec<FleetGroup> = templates
            .into_iter()
            .enumerate()
            .map(|(i, spec)| FleetGroup { spec, chips: share(chips, n, i) })
            .collect();
        groups.retain(|g| g.chips > 0);
        FleetSpec::new(groups)
    }
}

/// Aggregate statistics of one simulated chip class. Per-chip values are
/// the *representative's* (the unperturbed α = 1, φ = 0 chip) — exact
/// classes reproduce them bitwise on every member; parametric members
/// spread around them, and that spread surfaces in the fleet-wide
/// percentiles (which weight every distinct member).
#[derive(Debug, Clone)]
pub struct ClassStat {
    /// The dedup key: workload | resolved config | frames | window |
    /// traffic phase.
    pub key: String,
    pub workload: String,
    pub rung: String,
    /// Human description of the traffic model.
    pub traffic: String,
    /// Population this class was scaled to.
    pub chips: usize,
    pub frames: usize,
    /// Per-chip stream makespan (s).
    pub makespan_s: f64,
    /// Per-chip energy (mJ).
    pub energy_mj: f64,
    pub fps: f64,
    /// Mean engine utilization of one chip (Σ busy / (makespan × engines)).
    pub utilization: f64,
    /// Power-state policy this class ran under (`"none"` when unmanaged).
    pub policy: String,
    /// Per-chip managed (sleep/retention) residency (s).
    pub sleep_s: f64,
    /// Per-chip deep-sleep residency (s).
    pub deep_sleep_s: f64,
    /// Per-chip duty-cycled energy draw extrapolated to a day (mJ/day).
    pub epd_mj_per_day: f64,
    /// Days a [`pm::BATTERY_MWH`] coin cell sustains this class's chips.
    pub battery_days: f64,
    /// Fraction of this class's frames whose output survived faults
    /// (1.0 for a fault-free fleet).
    pub availability: f64,
    /// Delivered frames per second of one chip's stream (= fps ×
    /// availability; equal to `fps` for a loss-free, fault-free class).
    pub goodput_fps: f64,
    /// Per-chip frames dropped to faults.
    pub frames_dropped: u64,
    /// Per-chip retry executions beyond first attempts.
    pub fault_retries: u64,
    /// Per-chip full resets (brown-outs plus watchdog resets).
    pub chip_resets: u64,
    /// Per-chip energy overhead of fault recovery (mJ).
    pub recovery_energy_mj: f64,
    pub fast_forwarded_frames: usize,
    /// Distinct parametric members (quantized α/φ buckets) this class
    /// split into — 1 for a homogeneous fleet.
    pub members: usize,
    /// Members whose schedule-invariance certificate refused the
    /// closed-form derivation and were re-simulated on the rescaled
    /// template instead (exact, just not O(1)).
    pub live_fallbacks: usize,
    /// Live simulations charged to this class (representative + parity
    /// samples + certificate fallbacks).
    pub live_runs: usize,
    /// Member-bucket indices sampled for the live parity check.
    pub sampled_members: Vec<usize>,
    /// Host wall-clock of the class representative's simulation (s).
    pub wall_s: f64,
}

/// p50/p95/p99 of a per-chip metric across the whole fleet (weighted
/// nearest-rank over classes — every chip of a class contributes its
/// class's value).
#[derive(Debug, Clone, Copy)]
pub struct Pct {
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

/// Outcome of a [`Fleet::run`]: the roll-up (total energy, fleet
/// makespan), per-chip percentiles, per-class statistics, and the dedup
/// accounting (live chips vs population, parity checks, estimated naive
/// cost).
#[derive(Debug, Clone)]
pub struct FleetReport {
    /// Total chip population simulated (by class scaling).
    pub chips: usize,
    pub sample_k: usize,
    /// Per-chip drift amplitude the fleet ran with (percent).
    pub drift_pct: f64,
    /// Per-chip traffic phase jitter the fleet ran with (seconds).
    pub phase_jitter_s: f64,
    /// Distinct parametric members across all classes (== class count for
    /// a homogeneous fleet).
    pub members: usize,
    /// Members re-simulated live because the schedule-invariance
    /// certificate refused their closed-form derivation.
    pub live_fallbacks: usize,
    /// Chips actually simulated live (representatives + parity samples +
    /// certificate fallbacks).
    pub live_chips: usize,
    /// Sampled live-vs-derived comparisons performed (bitwise for exact
    /// scales, tolerance-checked otherwise — counts always exact).
    pub parity_checked: usize,
    /// Comparisons that failed (a successful run reports 0 — failures
    /// abort with an error instead).
    pub parity_failures: usize,
    pub classes: Vec<ClassStat>,
    pub total_frames: u64,
    /// Fleet-total energy (J).
    pub energy_j: f64,
    /// Slowest chip's makespan (chips run concurrently).
    pub makespan_s: f64,
    /// Power-state policy the fleet ran under (`"none"` when unmanaged).
    pub policy: String,
    /// Fault model the fleet ran under (`"none"` when fault-free).
    pub faults: String,
    /// Recovery policy answering faults (`"none"` when fault-free).
    pub recovery: String,
    /// Secure-link channel the fleet ran under (`"none"` when no
    /// channel was modeled).
    pub channel: String,
    /// Session re-establishment policy (`"none"` without a channel).
    pub session_recovery: String,
    /// Crypto cost backend (`"native"` when each rung follows its own
    /// configuration).
    pub crypto_backend: String,
    /// Fleet-total full handshakes over a secure link (0 without one).
    pub full_handshakes: u64,
    /// Fleet-total abbreviated resumption handshakes.
    pub resumptions: u64,
    /// Fleet-total flight/record retransmissions.
    pub retransmissions: u64,
    /// Fleet-total records dropped by the channel.
    pub records_dropped: u64,
    /// Fleet-total handshake-side active energy (J).
    pub handshake_j: f64,
    /// Fleet-total record-side active energy (J).
    pub record_j: f64,
    /// Fleet-total frames dropped to faults.
    pub frames_dropped: u64,
    /// Fleet-total retry executions.
    pub fault_retries: u64,
    /// Fleet-total full-chip resets.
    pub chip_resets: u64,
    /// Fleet-total in-flight frames lost to resets.
    pub state_loss_frames: u64,
    /// Fleet-total energy overhead of fault recovery (J).
    pub recovery_energy_j: f64,
    pub energy_mj_per_chip: Pct,
    pub latency_s: Pct,
    pub utilization: Pct,
    /// Days a [`pm::BATTERY_MWH`] coin cell sustains a chip at its class's
    /// duty-cycled draw (weighted percentiles across the population).
    pub battery_days: Pct,
    /// Per-chip fraction of frames delivered despite faults (weighted
    /// percentiles; all 1.0 for a fault-free fleet).
    pub availability: Pct,
    /// Per-chip fault-recovery energy overhead (mJ, weighted
    /// percentiles).
    pub recovery_mj_per_chip: Pct,
    /// Per-chip delivered-record throughput (weighted percentiles;
    /// equal to raw fps for a loss-free, fault-free fleet).
    pub goodput_fps: Pct,
    /// Host wall-clock of the whole fleet run (s).
    pub wall_s: f64,
    pub chips_per_s: f64,
    /// Estimated cost of simulating every chip individually: Σ class
    /// representative wall × population.
    pub naive_est_wall_s: f64,
    /// `naive_est_wall_s / wall_s` — the class-dedup win.
    pub dedup_speedup: f64,
}

/// Weighted nearest-rank percentile: the smallest value whose cumulative
/// chip population reaches `⌈q × total⌉`.
fn weighted_percentile(vals: &mut [(f64, usize)], q: f64, total: usize) -> f64 {
    vals.sort_by(|a, b| a.0.total_cmp(&b.0));
    let rank = ((q * total as f64).ceil() as usize).max(1);
    let mut cum = 0usize;
    for &(v, w) in vals.iter() {
        cum += w;
        if cum >= rank {
            return v;
        }
    }
    vals.last().map_or(f64::NAN, |&(v, _)| v)
}

fn pct(vals: &mut [(f64, usize)], total: usize) -> Pct {
    Pct {
        p50: weighted_percentile(vals, 0.50, total),
        p95: weighted_percentile(vals, 0.95, total),
        p99: weighted_percentile(vals, 0.99, total),
    }
}

/// Bitwise comparison of two scheduler results (everything except the
/// fast-forward counter, which legitimately differs between the replay
/// and live paths). Returns the first mismatching field as
/// `(field, expected_bits, got_bits)` — `None` means bitwise equal — so
/// a fleet parity failure names exactly what diverged instead of a bare
/// boolean.
fn sched_bitwise_mismatch(
    a: &SchedResult,
    b: &SchedResult,
) -> Option<(&'static str, u64, u64)> {
    let floats = [
        ("makespan_s", a.makespan_s, b.makespan_s),
        ("overlap_s", a.overlap_s, b.overlap_s),
        ("coresidency_s", a.coresidency_s, b.coresidency_s),
        ("sleep_s", a.sleep_s, b.sleep_s),
        ("deep_sleep_s", a.deep_sleep_s, b.deep_sleep_s),
        ("recovery_energy_mj", a.recovery_energy_mj, b.recovery_energy_mj),
    ];
    for (name, x, y) in floats {
        if x.to_bits() != y.to_bits() {
            return Some((name, x.to_bits(), y.to_bits()));
        }
    }
    let counts = [
        ("mode_switches", a.mode_switches, b.mode_switches),
        ("n_jobs", a.n_jobs as u64, b.n_jobs as u64),
        ("peak_resident_jobs", a.peak_resident_jobs as u64, b.peak_resident_jobs as u64),
        ("wake_transitions", a.wake_transitions, b.wake_transitions),
        ("frames_dropped", a.frames_dropped, b.frames_dropped),
        ("fault_retries", a.fault_retries, b.fault_retries),
        ("chip_resets", a.chip_resets, b.chip_resets),
        ("state_loss_frames", a.state_loss_frames, b.state_loss_frames),
    ];
    for (name, x, y) in counts {
        if x != y {
            return Some((name, x, y));
        }
    }
    for e in 0..N_ENGINES {
        if a.busy_s[e].to_bits() != b.busy_s[e].to_bits() {
            return Some(("busy_s", a.busy_s[e].to_bits(), b.busy_s[e].to_bits()));
        }
    }
    for c in Category::all() {
        let (x, y) = (a.ledger.energy_mj(c), b.ledger.energy_mj(c));
        if x.to_bits() != y.to_bits() {
            return Some(("ledger_energy_mj", x.to_bits(), y.to_bits()));
        }
    }
    None
}

/// Relative tolerance for live-vs-derived parity on non-exact scales: a
/// closed-form member and its live re-simulation compute the same real
/// numbers through differently ordered f64 operations, so float fields
/// agree to rounding (~1e-12 over these event counts; 1e-9 leaves three
/// orders of slack) while every *decision* count must stay exact.
const PARAM_TOL: f64 = 1e-9;

/// Live-vs-derived parity for a non-exactly-representable scale: all
/// decision-schedule counts bitwise (dispatch order, mode switches, wake
/// transitions, fault counters), all time/energy floats within `tol`
/// relative. Same `(field, expected_bits, got_bits)` reporting shape as
/// [`sched_bitwise_mismatch`].
fn sched_close_mismatch(
    a: &SchedResult,
    b: &SchedResult,
    tol: f64,
) -> Option<(&'static str, u64, u64)> {
    let close =
        |x: f64, y: f64| (x - y).abs() <= tol * x.abs().max(y.abs()).max(1e-12);
    let counts = [
        ("mode_switches", a.mode_switches, b.mode_switches),
        ("n_jobs", a.n_jobs as u64, b.n_jobs as u64),
        ("peak_resident_jobs", a.peak_resident_jobs as u64, b.peak_resident_jobs as u64),
        ("wake_transitions", a.wake_transitions, b.wake_transitions),
        ("frames_dropped", a.frames_dropped, b.frames_dropped),
        ("fault_retries", a.fault_retries, b.fault_retries),
        ("chip_resets", a.chip_resets, b.chip_resets),
        ("state_loss_frames", a.state_loss_frames, b.state_loss_frames),
    ];
    for (name, x, y) in counts {
        if x != y {
            return Some((name, x, y));
        }
    }
    let floats = [
        ("makespan_s", a.makespan_s, b.makespan_s),
        ("overlap_s", a.overlap_s, b.overlap_s),
        ("coresidency_s", a.coresidency_s, b.coresidency_s),
        ("sleep_s", a.sleep_s, b.sleep_s),
        ("deep_sleep_s", a.deep_sleep_s, b.deep_sleep_s),
        ("recovery_energy_mj", a.recovery_energy_mj, b.recovery_energy_mj),
    ];
    for (name, x, y) in floats {
        if !close(x, y) {
            return Some((name, x.to_bits(), y.to_bits()));
        }
    }
    for e in 0..N_ENGINES {
        if !close(a.busy_s[e], b.busy_s[e]) {
            return Some(("busy_s", a.busy_s[e].to_bits(), b.busy_s[e].to_bits()));
        }
    }
    for c in Category::all() {
        let (x, y) = (a.ledger.energy_mj(c), b.ledger.energy_mj(c));
        if !close(x, y) {
            return Some(("ledger_energy_mj", x.to_bits(), y.to_bits()));
        }
    }
    None
}

/// The per-chip metrics the fleet percentiles aggregate: (energy [mJ],
/// makespan [s], mean engine utilization, battery days).
fn chip_metrics(r: &SchedResult) -> (f64, f64, f64, f64) {
    let energy_mj = r.ledger.total_mj();
    let busy: f64 = r.busy_s.iter().sum();
    let utilization = busy / (r.makespan_s * N_ENGINES as f64);
    let battery = pm::battery_days(energy_mj, r.makespan_s);
    (energy_mj, r.makespan_s, utilization, battery)
}

/// The fleet runner: simulates a heterogeneous population of Fulmine
/// endpoints in O(distinct chip classes) instead of O(chips).
///
/// The dedup key is **two-level**. The *family* level groups chips by
/// (workload, resolved configuration, frame count, window, traffic
/// phase, policy) — exactly the PR 6 class key — and each family is
/// simulated **once** as a representative via
/// [`StreamScheduler::run_param_rep`] (families sharded across host
/// threads). The *member* level then splits a family's population by the
/// deterministic per-chip perturbation ([`Perturb::derive`] from the
/// fleet seed and global chip index): chips sharing one quantized
/// (drift α, phase φ) bucket are one member. An exact class is the
/// degenerate single-member (identity) family. Members are **derived,
/// not simulated**: the representative's
/// [`crate::soc::sched::ParamRep`] certificate
/// ([`crate::soc::sched::ParamRep::certify`]) proves the member makes
/// bit-for-bit the same dispatch/pop/retire/admit decisions on an
/// α-scaled time base, and [`crate::soc::sched::ParamRep::member`] (or,
/// for pure drift, the property-tested
/// [`crate::report::Merged::absorb_scaled`] seam) produces its
/// makespan/energy/busy/sleep in closed form. A member the certificate
/// refuses is re-simulated live on the rescaled template — exact, just
/// not O(1) — and counted in [`FleetReport::live_fallbacks`].
///
/// The scaling claim is *checked, not assumed*: per family, `sample_k −
/// 1` randomly sampled member buckets re-run through the
/// fast-forward-disabled live scheduler on the rescaled template and
/// must match their derivation — bitwise where the scale is exactly
/// representable (identity, power-of-two α with φ = 0, and fallbacks),
/// decision counts bitwise plus floats within [`PARAM_TOL`] otherwise
/// ([`FleetReport::parity_checked`] / [`FleetReport::parity_failures`]);
/// a mismatch aborts the run. That keeps `fulmine fleet --chips 1000000
/// --drift 1 --phase-jitter 0.02` — *every* chip perturbed — a
/// seconds-scale operation: O(families) simulations plus O(members)
/// closed-form derivations, never O(chips) scheduler runs.
pub struct Fleet;

/// A deduplicated chip family, resolved and ready to simulate: the shared
/// decision-schedule template plus its parametric member buckets.
struct FleetClass {
    key: String,
    workload: String,
    rung: String,
    traffic: Traffic,
    graph: JobGraph,
    frames: usize,
    window: usize,
    release: Vec<f64>,
    chips: usize,
    /// Parametric members, keyed by [`Perturb::key`] (deterministic
    /// order): quantized perturbation → population.
    members: BTreeMap<String, (Perturb, usize)>,
}

/// Per-class simulation outcome (filled by the worker pool).
struct ClassOutcome {
    /// The representative's (unperturbed) result.
    result: SchedResult,
    /// Population roll-up over all derived members.
    merged: crate::report::Merged,
    /// Per distinct member: (metric value, member population) — the
    /// fleet percentile inputs.
    e_vals: Vec<(f64, usize)>,
    l_vals: Vec<(f64, usize)>,
    u_vals: Vec<(f64, usize)>,
    b_vals: Vec<(f64, usize)>,
    /// Per-member availability and recovery-energy percentile inputs.
    a_vals: Vec<(f64, usize)>,
    r_vals: Vec<(f64, usize)>,
    /// Per-member goodput (delivered records / makespan) percentile
    /// inputs.
    g_vals: Vec<(f64, usize)>,
    /// Per-chip session counters of a secure-link class (`None` without
    /// a channel).
    session: Option<SessionStats>,
    /// Σ member α × population — the exact scale of the class's session
    /// energies across its drifted members.
    session_alpha_pop: f64,
    members: usize,
    live_fallbacks: usize,
    wall_s: f64,
    live_runs: usize,
    parity_runs: usize,
    /// First live-vs-derived mismatch: (field, expected bits, got bits).
    parity_fail: Option<(&'static str, u64, u64)>,
    sampled: Vec<usize>,
}

impl Fleet {
    /// Execute `fleet` against `sys`'s registry. See the type docs for the
    /// dedup/parity contract.
    pub fn run(sys: &SocSystem, fleet: &FleetSpec) -> Result<FleetReport> {
        if fleet.groups.iter().all(|g| g.chips == 0) {
            bail!("fleet needs at least one chip");
        }
        if fleet.sample_k == 0 {
            bail!("--sample must be at least 1 (the class representative)");
        }
        if !(fleet.drift_pct.is_finite() && (0.0..100.0).contains(&fleet.drift_pct)) {
            bail!("--drift must be a percentage in [0, 100)");
        }
        if !(fleet.phase_jitter_s.is_finite() && fleet.phase_jitter_s >= 0.0) {
            bail!("--phase-jitter must be a non-negative seconds value");
        }
        if let Some(m) = &fleet.faults {
            m.validate()?;
            fleet.recovery.validate()?;
        }
        if let Some(m) = &fleet.loss {
            if fleet.faults.is_some() {
                bail!("--loss and --faults are mutually exclusive (one failure model per run)");
            }
            m.validate()?;
        }
        let hetero = fleet.drift_pct > 0.0 || fleet.phase_jitter_s > 0.0;
        let t_fleet = Instant::now();
        // The fault model and recovery policy join the dedup key: chips
        // under different fault regimes must never merge into one class.
        // The secure-link channel, session recovery and crypto backend
        // join it the same way.
        let fault_frag = match &fleet.faults {
            None => "flt:none".to_string(),
            Some(m) => format!("{}|r:{}", m.key(), fleet.recovery.key()),
        };
        let ses_frag = match &fleet.loss {
            None => "ses:none".to_string(),
            Some(m) => format!("{}|sr:{}", m.key(), fleet.session_recovery.key()),
        };
        let backend_frag =
            format!("cb:{}", fleet.crypto_backend.map_or("native", |b| b.name()));

        // Family dedup: resolve each group and merge identical classes,
        // then split each family's population into parametric members by
        // the chips' deterministic perturbations (global chip index →
        // quantized α/φ bucket). A homogeneous fleet skips the derivation
        // and keeps the single identity member per family.
        let mut index: BTreeMap<String, usize> = BTreeMap::new();
        let mut classes: Vec<FleetClass> = Vec::new();
        let mut next_chip = 0u64;
        for g in &fleet.groups {
            if g.chips == 0 {
                continue;
            }
            if g.spec.shards != 1 {
                bail!("fleet chips are single SoCs — use more chips, not shards");
            }
            if g.spec.window == Some(0) {
                bail!("--window must be at least 1");
            }
            g.spec.traffic.validate()?;
            let (w, rung) = sys.resolve(&g.spec)?;
            let window = g
                .spec
                .window
                .unwrap_or(crate::soc::sched::DEFAULT_STREAM_WINDOW)
                .min(g.spec.frames);
            // The fleet-wide policy is part of the key: a future mixed-
            // policy fleet must not merge chips across policies.
            let key = format!(
                "{}|{:?}|f{}|w{}|{}|p:{}|{}|{}|{}",
                w.name(),
                rung.cfg,
                g.spec.frames,
                window,
                g.spec.traffic.key(),
                fleet.policy.map_or("none", |p| p.name()),
                fault_frag,
                ses_frag,
                backend_frag,
            );
            let ci = match index.get(&key) {
                Some(&ci) => ci,
                None => {
                    let graph = frame_graph_with(w, rung.cfg, fleet.crypto_backend)?;
                    if fleet.loss.is_some() && !crate::session::has_session_jobs(&graph) {
                        bail!(
                            "--loss requires session workloads; '{}' emits no handshake jobs \
                             (a secure-link fleet wants [`FleetSpec::secure_link`])",
                            w.name()
                        );
                    }
                    let release = g.spec.traffic.release_times(g.spec.frames);
                    index.insert(key.clone(), classes.len());
                    classes.push(FleetClass {
                        key,
                        workload: w.name().to_string(),
                        rung: rung.label.to_string(),
                        traffic: g.spec.traffic.clone(),
                        graph,
                        frames: g.spec.frames,
                        window,
                        release,
                        chips: 0,
                        members: BTreeMap::new(),
                    });
                    classes.len() - 1
                }
            };
            let c = &mut classes[ci];
            c.chips += g.chips;
            if hetero {
                for j in 0..g.chips as u64 {
                    let p = Perturb::derive(
                        fleet.seed,
                        next_chip + j,
                        fleet.drift_pct,
                        fleet.phase_jitter_s,
                    );
                    c.members.entry(p.key()).or_insert((p, 0)).1 += 1;
                }
            } else {
                c.members.entry(Perturb::IDENTITY.key()).or_insert((Perturb::IDENTITY, 0)).1 +=
                    g.chips;
            }
            next_chip += g.chips as u64;
        }
        let total_chips: usize = classes.iter().map(|c| c.chips).sum();

        // Simulate each class once (plus parity samples), classes sharded
        // across host worker threads as in `ShardedStream`.
        let threads = if fleet.threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            fleet.threads
        }
        .min(classes.len())
        .max(1);
        let next = AtomicUsize::new(0);
        let slots: Vec<Mutex<Option<ClassOutcome>>> =
            classes.iter().map(|_| Mutex::new(None)).collect();
        std::thread::scope(|scope| {
            for _ in 0..threads {
                scope.spawn(|| loop {
                    let ci = next.fetch_add(1, AtomicOrdering::Relaxed);
                    if ci >= classes.len() {
                        break;
                    }
                    let c = &classes[ci];
                    let cf = CompiledFrame::compile(&c.graph);
                    // A faulted class compiles its recovery plan once:
                    // per-frame variant templates plus the closed-form
                    // reliability counters, pure in (model, frames,
                    // window). Fault-free classes skip the machinery
                    // entirely (the bitwise-identity property).
                    let plan = fleet.faults.as_ref().map(|m| {
                        FaultPlan::build(m, fleet.recovery, &c.graph, 0, c.frames, c.window)
                    });
                    // A lossy-channel class compiles its session plan the
                    // same way (mutually exclusive with faults; session
                    // templates were validated at class construction).
                    let splan = fleet.loss.as_ref().map(|m| {
                        SessionPlan::build(m, fleet.session_recovery, &c.graph, 0, c.frames)
                            .expect("session templates validated at class construction")
                    });
                    let cvars: Vec<(usize, CompiledFrame)> = plan
                        .as_ref()
                        .map(|p| p.variants.as_slice())
                        .or_else(|| splan.as_ref().map(|p| p.variants.as_slice()))
                        .map(|vs| {
                            vs.iter().map(|(f, g)| (*f, CompiledFrame::compile(g))).collect()
                        })
                        .unwrap_or_default();
                    let planned = plan.is_some() || splan.is_some();
                    let t0 = Instant::now();
                    let rep = if planned {
                        StreamScheduler::run_param_rep_variants(
                            &cf, &cvars, c.frames, c.window, &c.release, fleet.policy,
                        )
                    } else {
                        StreamScheduler::run_param_rep(
                            &cf, c.frames, c.window, &c.release, fleet.policy,
                        )
                    };
                    let wall_s = t0.elapsed().as_secs_f64();
                    // The fault counters attach *after* every derivation
                    // with one shared arithmetic (f64 addition does not
                    // distribute over the α scaling, so both sides of a
                    // parity comparison must add the same numbers in the
                    // same order). The representative's own result gets
                    // them at scale 1.
                    let mut rep_res = rep.result().clone();
                    if let Some(pl) = &plan {
                        crate::fault::apply_stats(&mut rep_res, &pl.stats, 1.0);
                    }
                    if let Some(pl) = &splan {
                        crate::session::apply_stats(&mut rep_res, &pl.stats, 1.0);
                    }
                    // A member's live reference: the α-rescaled template
                    // (and α-rescaled fault variants) with the
                    // (φ-shifted, α-scaled) release table — fast-forward
                    // enabled for certificate fallbacks (exact either
                    // way), disabled for parity samples (the independent
                    // reference path).
                    let live_member = |p: &Perturb, ff: bool| -> SchedResult {
                        let mut rel = c.release.clone();
                        p.apply(&mut rel);
                        let scaled = cf.rescaled(p.alpha);
                        let mut r = if planned {
                            let svars: Vec<(usize, CompiledFrame)> = cvars
                                .iter()
                                .map(|(f, v)| (*f, v.rescaled(p.alpha)))
                                .collect();
                            StreamScheduler::run_compiled_variants_traffic_pm(
                                &scaled, &svars, c.frames, c.window, &rel, fleet.policy, ff,
                            )
                        } else if ff {
                            StreamScheduler::run_compiled_traffic_pm(
                                &scaled, c.frames, c.window, &rel, fleet.policy,
                            )
                        } else {
                            StreamScheduler::run_compiled_traffic_live_pm(
                                &scaled, c.frames, c.window, &rel, fleet.policy,
                            )
                        };
                        if let Some(pl) = &plan {
                            crate::fault::apply_stats(&mut r, &pl.stats, p.alpha);
                        }
                        if let Some(pl) = &splan {
                            crate::session::apply_stats(&mut r, &pl.stats, p.alpha);
                        }
                        r
                    };
                    // Sampled live-vs-derived parity targets: random
                    // member buckets, deterministically seeded per class.
                    let live_n = fleet.sample_k.min(c.chips);
                    let n_buckets = c.members.len() as u64;
                    let mut rng = crate::traffic::Xorshift64Star::new(
                        0x5EED ^ ((ci as u64) << 20) ^ c.chips as u64,
                    );
                    let sampled: Vec<usize> =
                        (1..live_n).map(|_| (rng.next_u64() % n_buckets) as usize).collect();
                    let mut merged = crate::report::Merged::empty();
                    let (mut e_vals, mut l_vals, mut u_vals, mut b_vals) =
                        (Vec::new(), Vec::new(), Vec::new(), Vec::new());
                    let (mut a_vals, mut r_vals, mut g_vals) =
                        (Vec::new(), Vec::new(), Vec::new());
                    let mut session_alpha_pop = 0.0f64;
                    let mut live_fallbacks = 0usize;
                    let mut parity_runs = 0usize;
                    let mut parity_fail: Option<(&'static str, u64, u64)> = None;
                    for (bi, (p, pop)) in c.members.values().enumerate() {
                        let mut fallback = false;
                        let pure_drift = fleet.policy.is_none() && p.phase_s == 0.0;
                        let res = if p.is_identity() {
                            rep_res.clone()
                        } else if !rep.certify(p) {
                            fallback = true;
                            live_fallbacks += 1;
                            live_member(p, true)
                        } else {
                            let mut r = if pure_drift {
                                // pure drift with no billing is exactly the
                                // representative on a rescaled time base
                                rep.result().rescaled(p.alpha)
                            } else {
                                rep.member(p).expect("certified member derives")
                            };
                            if let Some(pl) = &plan {
                                crate::fault::apply_stats(&mut r, &pl.stats, p.alpha);
                            }
                            if let Some(pl) = &splan {
                                crate::session::apply_stats(&mut r, &pl.stats, p.alpha);
                            }
                            r
                        };
                        for _ in sampled.iter().filter(|&&s| s == bi) {
                            parity_runs += 1;
                            let mut live = live_member(p, false);
                            if fleet.corrupt_parity {
                                live.makespan_s =
                                    f64::from_bits(live.makespan_s.to_bits() ^ 1);
                            }
                            let exact = fallback
                                || (exact_pow2(p.alpha) && p.phase_s == 0.0);
                            let mismatch = if exact {
                                sched_bitwise_mismatch(&res, &live)
                            } else {
                                sched_close_mismatch(&res, &live, PARAM_TOL)
                            };
                            if parity_fail.is_none() {
                                parity_fail = mismatch;
                            }
                        }
                        if pure_drift && !fallback && !p.is_identity() && !planned {
                            // through the extended report seam
                            // (absorb_scaled ≡ absorb ∘ rescaled,
                            // property-tested bitwise); a faulted class
                            // must absorb the post-`apply_stats` result
                            // instead, or the counters and wake energy
                            // would never reach the roll-up
                            merged.absorb_scaled(rep.result(), *pop, p.alpha);
                        } else {
                            merged.absorb(&res, *pop);
                        }
                        let (e, l, u, b) = chip_metrics(&res);
                        e_vals.push((e, *pop));
                        l_vals.push((l, *pop));
                        u_vals.push((u, *pop));
                        b_vals.push((b, *pop));
                        a_vals.push((
                            (c.frames as f64 - res.frames_dropped as f64) / c.frames as f64,
                            *pop,
                        ));
                        r_vals.push((res.recovery_energy_mj, *pop));
                        g_vals.push((
                            (c.frames as f64 - res.frames_dropped as f64) / res.makespan_s,
                            *pop,
                        ));
                        if splan.is_some() {
                            // Session energies scale with the member's
                            // time base: aggregate the α-weighted
                            // population so the fleet split stays exact.
                            session_alpha_pop += p.alpha * *pop as f64;
                        }
                    }
                    *slots[ci].lock().expect("class slot poisoned") = Some(ClassOutcome {
                        result: rep_res,
                        merged,
                        e_vals,
                        l_vals,
                        u_vals,
                        b_vals,
                        a_vals,
                        r_vals,
                        g_vals,
                        session: splan.as_ref().map(|p| p.stats),
                        session_alpha_pop,
                        members: c.members.len(),
                        live_fallbacks,
                        wall_s,
                        live_runs: 1 + parity_runs + live_fallbacks,
                        parity_runs,
                        parity_fail,
                        sampled,
                    });
                });
            }
        });
        let outcomes: Vec<ClassOutcome> = slots
            .into_iter()
            .map(|m| m.into_inner().expect("class slot poisoned").expect("class simulated"))
            .collect();

        // Roll up: combine the per-class population merges + per-member
        // percentiles (every distinct parametric member contributes its
        // own value, weighted by its bucket population).
        let mut merged = crate::report::Merged::empty();
        let mut stats: Vec<ClassStat> = Vec::new();
        let (mut live_chips, mut parity_checked, mut parity_failures) = (0usize, 0usize, 0usize);
        let (mut members_total, mut fallbacks_total) = (0usize, 0usize);
        let mut naive_est_wall_s = 0.0f64;
        let mut total_frames = 0u64;
        let (mut e_vals, mut l_vals, mut u_vals, mut b_vals) =
            (Vec::new(), Vec::new(), Vec::new(), Vec::new());
        let (mut a_vals, mut r_vals, mut g_vals) = (Vec::new(), Vec::new(), Vec::new());
        let (mut full_handshakes, mut resumptions) = (0u64, 0u64);
        let (mut retransmissions, mut records_dropped) = (0u64, 0u64);
        let (mut handshake_j, mut record_j) = (0.0f64, 0.0f64);
        let mut first_fail: Option<(String, &'static str, u64, u64)> = None;
        let policy_name = fleet.policy.map_or("none", |p| p.name()).to_string();
        for (c, o) in classes.iter().zip(outcomes) {
            merged.combine(&o.merged);
            live_chips += o.live_runs;
            parity_checked += o.parity_runs;
            if let Some((field, expected, got)) = o.parity_fail {
                parity_failures += 1;
                if first_fail.is_none() {
                    first_fail = Some((c.key.clone(), field, expected, got));
                }
            }
            members_total += o.members;
            fallbacks_total += o.live_fallbacks;
            naive_est_wall_s += o.wall_s * c.chips as f64;
            total_frames += (c.frames * c.chips) as u64;
            let (energy_mj, _, utilization, battery) = chip_metrics(&o.result);
            let epd = pm::energy_per_day_mj(energy_mj, o.result.makespan_s);
            e_vals.extend(o.e_vals);
            l_vals.extend(o.l_vals);
            u_vals.extend(o.u_vals);
            b_vals.extend(o.b_vals);
            a_vals.extend(o.a_vals);
            r_vals.extend(o.r_vals);
            g_vals.extend(o.g_vals);
            if let Some(ss) = &o.session {
                // Counters are per chip and exact under drift; energies
                // scale with each member's time base (Σ α × population).
                full_handshakes += ss.full_handshakes * c.chips as u64;
                resumptions += ss.resumptions * c.chips as u64;
                retransmissions += ss.retransmissions * c.chips as u64;
                records_dropped += ss.records_dropped * c.chips as u64;
                handshake_j += ss.handshake_mj * o.session_alpha_pop / 1e3;
                record_j += ss.record_mj * o.session_alpha_pop / 1e3;
            }
            stats.push(ClassStat {
                key: c.key.clone(),
                workload: c.workload.clone(),
                rung: c.rung.clone(),
                traffic: c.traffic.describe(),
                chips: c.chips,
                frames: c.frames,
                makespan_s: o.result.makespan_s,
                energy_mj,
                fps: c.frames as f64 / o.result.makespan_s,
                utilization,
                policy: policy_name.clone(),
                sleep_s: o.result.sleep_s,
                deep_sleep_s: o.result.deep_sleep_s,
                epd_mj_per_day: epd,
                battery_days: battery,
                availability: (c.frames as f64 - o.result.frames_dropped as f64)
                    / c.frames as f64,
                goodput_fps: (c.frames as f64 - o.result.frames_dropped as f64)
                    / o.result.makespan_s,
                frames_dropped: o.result.frames_dropped,
                fault_retries: o.result.fault_retries,
                chip_resets: o.result.chip_resets,
                recovery_energy_mj: o.result.recovery_energy_mj,
                fast_forwarded_frames: o.result.fast_forwarded_frames,
                members: o.members,
                live_fallbacks: o.live_fallbacks,
                live_runs: o.live_runs,
                sampled_members: o.sampled,
                wall_s: o.wall_s,
            });
        }
        if let Some((key, field, expected, got)) = first_fail {
            bail!(
                "sampled live-vs-scaled parity failed for {parity_failures} of {} classes — \
                 first mismatch in class '{key}': field `{field}` expected {expected:#018x}, \
                 live run produced {got:#018x} — class scaling would have misreported the fleet",
                classes.len()
            );
        }
        let wall_s = t_fleet.elapsed().as_secs_f64().max(1e-9);
        Ok(FleetReport {
            chips: total_chips,
            sample_k: fleet.sample_k,
            drift_pct: fleet.drift_pct,
            phase_jitter_s: fleet.phase_jitter_s,
            members: members_total,
            live_fallbacks: fallbacks_total,
            live_chips,
            parity_checked,
            parity_failures,
            total_frames,
            energy_j: merged.ledger.total_mj() / 1e3,
            makespan_s: merged.time_s,
            policy: policy_name,
            faults: fleet
                .faults
                .as_ref()
                .map_or_else(|| "none".to_string(), |m| m.describe()),
            recovery: fleet
                .faults
                .as_ref()
                .map_or_else(|| "none".to_string(), |_| fleet.recovery.describe()),
            channel: fleet
                .loss
                .as_ref()
                .map_or_else(|| "none".to_string(), |m| m.describe()),
            session_recovery: fleet
                .loss
                .as_ref()
                .map_or_else(|| "none".to_string(), |_| {
                    fleet.session_recovery.describe().to_string()
                }),
            crypto_backend: fleet.crypto_backend.map_or("native", |b| b.name()).to_string(),
            full_handshakes,
            resumptions,
            retransmissions,
            records_dropped,
            handshake_j,
            record_j,
            frames_dropped: merged.frames_dropped,
            fault_retries: merged.fault_retries,
            chip_resets: merged.chip_resets,
            state_loss_frames: merged.state_loss_frames,
            recovery_energy_j: merged.recovery_energy_mj / 1e3,
            energy_mj_per_chip: pct(&mut e_vals, total_chips),
            latency_s: pct(&mut l_vals, total_chips),
            utilization: pct(&mut u_vals, total_chips),
            battery_days: pct(&mut b_vals, total_chips),
            availability: pct(&mut a_vals, total_chips),
            recovery_mj_per_chip: pct(&mut r_vals, total_chips),
            goodput_fps: pct(&mut g_vals, total_chips),
            wall_s,
            chips_per_s: total_chips as f64 / wall_s,
            naive_est_wall_s,
            dedup_speedup: naive_est_wall_s / wall_s,
            classes: stats,
        })
    }
}

impl FleetReport {
    /// The `fulmine fleet` text report.
    pub fn render_text(&self) -> String {
        let mut s = String::new();
        writeln!(
            s,
            "== fleet: {} chips in {} classes ==",
            self.chips,
            self.classes.len()
        )
        .unwrap();
        writeln!(
            s,
            "simulated live: {} chips ({} classes, sample-K {}) | parity checks {} | failures {}",
            self.live_chips,
            self.classes.len(),
            self.sample_k,
            self.parity_checked,
            self.parity_failures
        )
        .unwrap();
        if self.drift_pct > 0.0 || self.phase_jitter_s > 0.0 {
            writeln!(
                s,
                "parametric: drift ±{}% phase jitter {} s | {} members over {} families | {} live fallbacks",
                self.drift_pct,
                self.phase_jitter_s,
                self.members,
                self.classes.len(),
                self.live_fallbacks
            )
            .unwrap();
        }
        writeln!(
            s,
            "fleet energy {:.3} J over {} frames | slowest chip {:.4} s | policy {}",
            self.energy_j, self.total_frames, self.makespan_s, self.policy
        )
        .unwrap();
        if self.faults != "none" {
            writeln!(s, "faults: {} | recovery: {}", self.faults, self.recovery).unwrap();
            writeln!(
                s,
                "reliability: {} frames dropped | {} retries | {} chip resets \
                 ({} in-flight frames lost) | recovery energy {:.3} J",
                self.frames_dropped,
                self.fault_retries,
                self.chip_resets,
                self.state_loss_frames,
                self.recovery_energy_j
            )
            .unwrap();
        }
        if self.channel != "none" {
            writeln!(
                s,
                "secure link: {} | session recovery: {} | crypto backend: {}",
                self.channel, self.session_recovery, self.crypto_backend
            )
            .unwrap();
            writeln!(
                s,
                "sessions: {} full + {} resumed | {} retransmissions | {} records dropped \
                 | handshake {:.3} J vs record {:.3} J",
                self.full_handshakes,
                self.resumptions,
                self.retransmissions,
                self.records_dropped,
                self.handshake_j,
                self.record_j
            )
            .unwrap();
        }
        writeln!(
            s,
            "host: {:.3} s wall ({:.3e} chips/s) | naive per-chip est {:.1} s | dedup speedup {:.0}x",
            self.wall_s, self.chips_per_s, self.naive_est_wall_s, self.dedup_speedup
        )
        .unwrap();
        writeln!(s, "{:<14} {:>9} {:>9} {:>9}", "per chip", "p50", "p95", "p99").unwrap();
        for (name, p) in [
            ("energy [mJ]", self.energy_mj_per_chip),
            ("latency [s]", self.latency_s),
            ("utilization", self.utilization),
            ("battery [d]", self.battery_days),
        ] {
            writeln!(s, "{name:<14} {:>9.4} {:>9.4} {:>9.4}", p.p50, p.p95, p.p99).unwrap();
        }
        if self.faults != "none" || self.channel != "none" {
            for (name, p) in [
                ("availability", self.availability),
                ("goodput [fps]", self.goodput_fps),
                ("recovery [mJ]", self.recovery_mj_per_chip),
            ] {
                writeln!(s, "{name:<14} {:>9.4} {:>9.4} {:>9.4}", p.p50, p.p95, p.p99).unwrap();
            }
        }
        writeln!(
            s,
            "{:<14} {:<10} {:<22} {:>9} {:>8} {:>9} {:>10} {:>10} {:>6}",
            "workload", "rung", "traffic", "chips", "fps", "mJ/chip", "util", "batt [d]", "ff"
        )
        .unwrap();
        for c in &self.classes {
            writeln!(
                s,
                "{:<14} {:<10} {:<22} {:>9} {:>8.3} {:>9.4} {:>9.1}% {:>10.2} {:>6}",
                c.workload,
                c.rung,
                c.traffic,
                c.chips,
                c.fps,
                c.energy_mj,
                c.utilization * 100.0,
                c.battery_days,
                c.fast_forwarded_frames
            )
            .unwrap();
        }
        s
    }

    pub fn to_json(&self) -> Json {
        let pct_json = |p: &Pct| {
            Json::obj(vec![
                ("p50", Json::num(p.p50)),
                ("p95", Json::num(p.p95)),
                ("p99", Json::num(p.p99)),
            ])
        };
        Json::obj(vec![
            ("chips", Json::num(self.chips as f64)),
            ("class_count", Json::num(self.classes.len() as f64)),
            ("sample_k", Json::num(self.sample_k as f64)),
            ("drift_pct", Json::num(self.drift_pct)),
            ("phase_jitter_s", Json::num(self.phase_jitter_s)),
            ("members", Json::num(self.members as f64)),
            ("live_fallbacks", Json::num(self.live_fallbacks as f64)),
            ("live_chips", Json::num(self.live_chips as f64)),
            ("parity_checked", Json::num(self.parity_checked as f64)),
            ("parity_failures", Json::num(self.parity_failures as f64)),
            ("total_frames", Json::num(self.total_frames as f64)),
            ("energy_j", Json::num(self.energy_j)),
            ("makespan_s", Json::num(self.makespan_s)),
            ("wall_s", Json::num(self.wall_s)),
            ("chips_per_s", Json::num(self.chips_per_s)),
            ("naive_est_wall_s", Json::num(self.naive_est_wall_s)),
            ("dedup_speedup", Json::num(self.dedup_speedup)),
            ("policy", Json::string(&self.policy)),
            ("faults", Json::string(&self.faults)),
            ("recovery", Json::string(&self.recovery)),
            ("channel", Json::string(&self.channel)),
            ("session_recovery", Json::string(&self.session_recovery)),
            ("crypto_backend", Json::string(&self.crypto_backend)),
            ("full_handshakes", Json::num(self.full_handshakes as f64)),
            ("resumptions", Json::num(self.resumptions as f64)),
            ("retransmissions", Json::num(self.retransmissions as f64)),
            ("records_dropped", Json::num(self.records_dropped as f64)),
            ("handshake_j", Json::num(self.handshake_j)),
            ("record_j", Json::num(self.record_j)),
            ("frames_dropped", Json::num(self.frames_dropped as f64)),
            ("fault_retries", Json::num(self.fault_retries as f64)),
            ("chip_resets", Json::num(self.chip_resets as f64)),
            ("state_loss_frames", Json::num(self.state_loss_frames as f64)),
            ("recovery_energy_j", Json::num(self.recovery_energy_j)),
            ("energy_mj_per_chip", pct_json(&self.energy_mj_per_chip)),
            ("latency_s", pct_json(&self.latency_s)),
            ("utilization", pct_json(&self.utilization)),
            ("battery_days", pct_json(&self.battery_days)),
            ("availability", pct_json(&self.availability)),
            ("recovery_mj_per_chip", pct_json(&self.recovery_mj_per_chip)),
            ("goodput_fps", pct_json(&self.goodput_fps)),
            (
                "classes",
                Json::Arr(
                    self.classes
                        .iter()
                        .map(|c| {
                            Json::obj(vec![
                                ("key", Json::string(&c.key)),
                                ("workload", Json::string(&c.workload)),
                                ("rung", Json::string(&c.rung)),
                                ("traffic", Json::string(&c.traffic)),
                                ("chips", Json::num(c.chips as f64)),
                                ("frames", Json::num(c.frames as f64)),
                                ("makespan_s", Json::num(c.makespan_s)),
                                ("energy_mj", Json::num(c.energy_mj)),
                                ("fps", Json::num(c.fps)),
                                ("utilization", Json::num(c.utilization)),
                                ("policy", Json::string(&c.policy)),
                                ("sleep_s", Json::num(c.sleep_s)),
                                ("deep_sleep_s", Json::num(c.deep_sleep_s)),
                                ("epd_mj_per_day", Json::num(c.epd_mj_per_day)),
                                ("battery_days", Json::num(c.battery_days)),
                                ("availability", Json::num(c.availability)),
                                ("goodput_fps", Json::num(c.goodput_fps)),
                                ("frames_dropped", Json::num(c.frames_dropped as f64)),
                                ("fault_retries", Json::num(c.fault_retries as f64)),
                                ("chip_resets", Json::num(c.chip_resets as f64)),
                                ("recovery_energy_mj", Json::num(c.recovery_energy_mj)),
                                (
                                    "fast_forwarded_frames",
                                    Json::num(c.fast_forwarded_frames as f64),
                                ),
                                ("members", Json::num(c.members as f64)),
                                ("live_fallbacks", Json::num(c.live_fallbacks as f64)),
                                ("live_runs", Json::num(c.live_runs as f64)),
                                ("wall_s", Json::num(c.wall_s)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Resolve a rung selector against a workload's ladder.
fn select_rung(rungs: &[Rung], sel: &RungSel) -> Result<Rung> {
    if rungs.is_empty() {
        bail!("workload declares no ladder rungs");
    }
    match sel {
        RungSel::Best => Ok(*rungs.last().expect("checked non-empty above")),
        RungSel::Index(i) => rungs
            .get(*i)
            .copied()
            .ok_or_else(|| anyhow!("rung index {i} out of range (0..{})", rungs.len())),
        RungSel::Label(sel) => {
            let needle = sel.to_lowercase();
            rungs
                .iter()
                .find(|r| r.label.to_lowercase().contains(&needle))
                .copied()
                .ok_or_else(|| {
                    let names: Vec<&str> = rungs.iter().map(|r| r.label).collect();
                    anyhow!("no rung matches {sel:?}; available: {names:?} or an index")
                })
        }
    }
}

/// Per-tenant attribution row of a [`RunReport`] (one row for ordinary
/// workloads; one per tenant for multi-tenant streams).
#[derive(Debug, Clone)]
pub struct TenantRow {
    pub name: String,
    /// OR1200-equivalent ops per frame of this tenant.
    pub eq_ops: u64,
    /// Active energy of this tenant's jobs over all frames (mJ).
    pub active_mj: f64,
    /// Active energy plus this tenant's proportional share of the
    /// schedule-wide idle/standby energy (mJ).
    pub energy_mj: f64,
    pub pj_per_op: f64,
}

/// Structured outcome of one [`SocSystem::run`]: everything the text
/// report shows, as data.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub workload: String,
    /// Label of the rung the run executed at.
    pub rung: String,
    /// The rung's configuration after overrides.
    pub cfg: ExecConfig,
    pub frames: usize,
    /// Fault model the run was subjected to (`"none"` for clean runs).
    pub faults: String,
    /// Recovery policy in force (`"none"` when no faults were injected).
    pub recovery: String,
    /// Secure-link channel the stream ran over (`"none"` when no
    /// channel was modeled).
    pub channel: String,
    /// Session re-establishment policy (`"none"` without a channel).
    pub session_recovery: String,
    /// Crypto cost backend of the cipher phases (`"native"` when the
    /// rung's own configuration decided).
    pub crypto_backend: String,
    /// Session counters of a secure-link run (`None` without a
    /// channel). Sharded runs carry the union over all shards.
    pub session: Option<SessionStats>,
    pub result: StreamResult,
    pub tenants: Vec<TenantRow>,
    /// Per-chip statistics of a sharded run (empty for a single SoC —
    /// the single-chip report is byte-identical to the unsharded one).
    pub shards: Vec<ShardStat>,
}

impl RunReport {
    /// The `fulmine stream` text report: throughput and energy as always,
    /// plus the per-engine utilization table (busy_s / makespan) and the
    /// overlap statistics of the schedule; multi-tenant runs add one
    /// attribution line per tenant.
    pub fn render_text(&self) -> String {
        let r = &self.result;
        let frames = self.frames;
        let mut s = String::new();
        writeln!(s, "== stream: {} @ {}, {frames} frames ==", self.workload, self.rung).unwrap();
        writeln!(
            s,
            "single frame {:>9.4} s | {frames} streamed {:>9.4} s  ({:.3} frames/s, {:.2}x vs back-to-back)",
            r.single_frame_s, r.time_s, r.fps, r.speedup
        )
        .unwrap();
        writeln!(
            s,
            "single-frame analytic bound {:>9.4} s (scheduled/analytic {:.3}x)",
            r.single_frame_analytic_s,
            r.single_frame_s / r.single_frame_analytic_s
        )
        .unwrap();
        writeln!(
            s,
            "energy {:>9.4} mJ total, {:>8.4} mJ/frame, {:>7.2} pJ/op | {} mode switches",
            r.energy_mj,
            r.energy_mj / frames as f64,
            r.pj_per_op,
            r.mode_switches
        )
        .unwrap();
        if let Some(p) = r.policy {
            writeln!(
                s,
                "policy {}: slept {:>9.4} s ({:.1}% of makespan, {:.4} s deep, {} wakes)",
                p.name(),
                r.sleep_s,
                r.sleep_s / r.time_s * 100.0,
                r.deep_sleep_s,
                r.wake_transitions
            )
            .unwrap();
            writeln!(
                s,
                "duty-cycled draw {:>9.3} mJ/day -> {:.2} days on a {} mWh cell",
                pm::energy_per_day_mj(r.energy_mj, r.time_s),
                pm::battery_days(r.energy_mj, r.time_s),
                pm::BATTERY_MWH
            )
            .unwrap();
        }
        if self.faults != "none" {
            writeln!(s, "faults {} | recovery {}", self.faults, self.recovery).unwrap();
            writeln!(
                s,
                "reliability: availability {:.4} | {} dropped | {} retries | {} resets \
                 ({} in-flight lost) | recovery energy {:>8.4} mJ",
                r.availability(),
                r.frames_dropped,
                r.fault_retries,
                r.chip_resets,
                r.state_loss_frames,
                r.recovery_energy_mj
            )
            .unwrap();
        }
        if let Some(ss) = &self.session {
            writeln!(
                s,
                "secure link: {} | session recovery {} | crypto backend {}",
                self.channel, self.session_recovery, self.crypto_backend
            )
            .unwrap();
            writeln!(
                s,
                "sessions: {} full + {} resumed | {} retransmissions | {} records dropped \
                 | backoff dead {:>8.4} s",
                ss.full_handshakes,
                ss.resumptions,
                ss.retransmissions,
                ss.records_dropped,
                ss.backoff_dead_s
            )
            .unwrap();
            writeln!(
                s,
                "link: availability {:.4} | goodput {:.3} records/s (of {:.3} fps) \
                 | handshake {:>8.4} mJ vs record {:>8.4} mJ | overhead {:>8.4} mJ",
                ss.availability(frames),
                ss.goodput_fps(frames, r.time_s),
                r.fps,
                ss.handshake_mj,
                ss.record_mj,
                ss.overhead_mj
            )
            .unwrap();
        }
        if self.tenants.len() > 1 {
            for t in &self.tenants {
                writeln!(
                    s,
                    "  tenant {:<14} {:>9.4} mJ  {:>7.2} pJ/op  ({:.3e} eq-ops/frame)",
                    t.name, t.energy_mj, t.pj_per_op, t.eq_ops as f64
                )
                .unwrap();
            }
        }
        // busy time sums across chips in a sharded run: normalize
        // utilization by chip-time (makespan × chips) so it stays ≤ 100 %
        // — a fleet average per engine type. S = 1 reduces to the
        // historical single-chip rendering unchanged.
        let chips = self.shards.len().max(1) as f64;
        writeln!(s, "{:<14} {:>10} {:>7}", "engine", "busy [s]", "util").unwrap();
        for e in Engine::ALL {
            let busy = r.busy_s[e.index()];
            if busy > 0.0 {
                writeln!(
                    s,
                    "{:<14} {:>10.4} {:>6.1}%",
                    e.name(),
                    busy,
                    busy / (r.time_s * chips) * 100.0
                )
                .unwrap();
            }
        }
        writeln!(
            s,
            "overlap {:>9.4} s (>=2 jobs in flight) | cluster co-residency {:>9.4} s",
            r.overlap_s, r.coresidency_s
        )
        .unwrap();
        writeln!(
            s,
            "window {} in-flight frames | peak resident jobs {} (of {} scheduled)",
            r.window, r.peak_resident_jobs, r.total_jobs
        )
        .unwrap();
        if !self.shards.is_empty() {
            writeln!(
                s,
                "sharded across {} SoCs (frame-parallel chips: energy/busy/overlap summed, makespan = slowest shard, util = fleet average)",
                self.shards.len()
            )
            .unwrap();
            for st in &self.shards {
                writeln!(
                    s,
                    "  shard {} {:>6} frames  {:>9.4} s  {:>9.4} mJ  analytic est {:>9.4} s  bound {:>9.4} s",
                    st.shard, st.frames, st.time_s, st.energy_mj, st.analytic_est_s, st.serialized_bound_s
                )
                .unwrap();
            }
        }
        writeln!(s, "{}", r.ledger.report(&format!("{} x{frames}", self.workload))).unwrap();
        s
    }

    pub fn to_json(&self) -> Json {
        let r = &self.result;
        // same chip-time normalization as the text report: per-chip
        // utilization for S = 1, fleet average per engine type otherwise
        let chips = self.shards.len().max(1) as f64;
        let mut engines = Vec::new();
        for e in Engine::ALL {
            let busy = r.busy_s[e.index()];
            if busy > 0.0 {
                engines.push(Json::obj(vec![
                    ("name", Json::string(e.name())),
                    ("busy_s", Json::num(busy)),
                    ("utilization", Json::num(busy / (r.time_s * chips))),
                ]));
            }
        }
        Json::obj(vec![
            ("workload", Json::string(&self.workload)),
            ("rung", Json::string(&self.rung)),
            ("frames", Json::num(self.frames as f64)),
            ("single_frame_s", Json::num(r.single_frame_s)),
            ("single_frame_analytic_s", Json::num(r.single_frame_analytic_s)),
            ("time_s", Json::num(r.time_s)),
            ("fps", Json::num(r.fps)),
            ("speedup_vs_serial", Json::num(r.speedup)),
            ("energy_mj", Json::num(r.energy_mj)),
            ("pj_per_op", Json::num(r.pj_per_op)),
            ("mode_switches", Json::num(r.mode_switches as f64)),
            ("overlap_s", Json::num(r.overlap_s)),
            ("coresidency_s", Json::num(r.coresidency_s)),
            ("window", Json::num(r.window as f64)),
            ("peak_resident_jobs", Json::num(r.peak_resident_jobs as f64)),
            ("total_jobs", Json::num(r.total_jobs as f64)),
            ("fast_forwarded_frames", Json::num(r.fast_forwarded_frames as f64)),
            (
                "policy",
                r.policy.map_or(Json::Null, |p| Json::string(p.name())),
            ),
            ("sleep_s", Json::num(r.sleep_s)),
            ("deep_sleep_s", Json::num(r.deep_sleep_s)),
            ("wake_transitions", Json::num(r.wake_transitions as f64)),
            ("faults", Json::string(&self.faults)),
            ("recovery", Json::string(&self.recovery)),
            ("channel", Json::string(&self.channel)),
            ("session_recovery", Json::string(&self.session_recovery)),
            ("crypto_backend", Json::string(&self.crypto_backend)),
            (
                "session",
                self.session.as_ref().map_or(Json::Null, |ss| {
                    Json::obj(vec![
                        ("full_handshakes", Json::num(ss.full_handshakes as f64)),
                        ("resumptions", Json::num(ss.resumptions as f64)),
                        ("retransmissions", Json::num(ss.retransmissions as f64)),
                        ("records_dropped", Json::num(ss.records_dropped as f64)),
                        ("handshake_mj", Json::num(ss.handshake_mj)),
                        ("record_mj", Json::num(ss.record_mj)),
                        ("overhead_mj", Json::num(ss.overhead_mj)),
                        ("backoff_dead_s", Json::num(ss.backoff_dead_s)),
                        ("availability", Json::num(ss.availability(self.frames))),
                        (
                            "goodput_fps",
                            Json::num(ss.goodput_fps(self.frames, r.time_s)),
                        ),
                    ])
                }),
            ),
            ("availability", Json::num(r.availability())),
            ("frames_dropped", Json::num(r.frames_dropped as f64)),
            ("fault_retries", Json::num(r.fault_retries as f64)),
            ("chip_resets", Json::num(r.chip_resets as f64)),
            ("state_loss_frames", Json::num(r.state_loss_frames as f64)),
            ("recovery_energy_mj", Json::num(r.recovery_energy_mj)),
            ("epd_mj_per_day", Json::num(pm::energy_per_day_mj(r.energy_mj, r.time_s))),
            ("battery_days", Json::num(pm::battery_days(r.energy_mj, r.time_s))),
            ("shard_count", Json::num(self.shards.len().max(1) as f64)),
            (
                "shards",
                Json::Arr(
                    self.shards
                        .iter()
                        .map(|st| {
                            Json::obj(vec![
                                ("shard", Json::num(st.shard as f64)),
                                ("frames", Json::num(st.frames as f64)),
                                ("time_s", Json::num(st.time_s)),
                                ("energy_mj", Json::num(st.energy_mj)),
                                ("mode_switches", Json::num(st.mode_switches as f64)),
                                (
                                    "peak_resident_jobs",
                                    Json::num(st.peak_resident_jobs as f64),
                                ),
                                (
                                    "fast_forwarded_frames",
                                    Json::num(st.fast_forwarded_frames as f64),
                                ),
                                ("wall_s", Json::num(st.wall_s)),
                                ("analytic_est_s", Json::num(st.analytic_est_s)),
                                ("serialized_bound_s", Json::num(st.serialized_bound_s)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("engines", Json::Arr(engines)),
            ("energy_breakdown_mj", breakdown_json(&r.ledger)),
            (
                "tenants",
                Json::Arr(
                    self.tenants
                        .iter()
                        .map(|t| {
                            Json::obj(vec![
                                ("name", Json::string(&t.name)),
                                ("eq_ops_per_frame", Json::num(t.eq_ops as f64)),
                                ("active_mj", Json::num(t.active_mj)),
                                ("energy_mj", Json::num(t.energy_mj)),
                                ("pj_per_op", Json::num(t.pj_per_op)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

fn breakdown_json(ledger: &EnergyLedger) -> Json {
    Json::Obj(
        Category::all()
            .iter()
            .map(|&c| (c.name().to_string(), Json::num(ledger.energy_mj(c))))
            .collect(),
    )
}

/// One single-frame run per ladder rung of a workload.
#[derive(Debug, Clone)]
pub struct LadderReport {
    pub workload: String,
    pub rows: Vec<UseCaseResult>,
}

impl LadderReport {
    /// The Fig. 10/11/12-style table (the historical `ladder_table`
    /// rendering; `paper_note` appends the figure's comparison line).
    pub fn render_table(&self, title: &str, paper_note: Option<&str>) -> String {
        let mut s = String::new();
        writeln!(s, "== {title} ==").unwrap();
        writeln!(
            s,
            "{:<16} {:>9} {:>10} {:>8} | {:>8} {:>8} {:>8} {:>8} {:>8} {:>8}",
            "config", "time [s]", "E [mJ]", "pJ/op", "conv", "crypto", "o-sw", "dma", "extmem", "idle"
        )
        .unwrap();
        for r in &self.rows {
            write!(
                s,
                "{:<16} {:>9.4} {:>10.4} {:>8.2} |",
                r.label, r.time_s, r.energy_mj, r.pj_per_op
            )
            .unwrap();
            for c in Category::all() {
                write!(s, " {:>8.3}", r.ledger.energy_mj(c)).unwrap();
            }
            writeln!(s).unwrap();
        }
        if let Some(note) = paper_note {
            writeln!(s, "{note}").unwrap();
        }
        s
    }

    /// Generic rendering for `fulmine ladder <workload>`.
    pub fn render_text(&self) -> String {
        self.render_table(&format!("ladder: {}", self.workload), None)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("workload", Json::string(&self.workload)),
            (
                "rungs",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("label", Json::string(&r.label)),
                                ("time_s", Json::num(r.time_s)),
                                ("energy_mj", Json::num(r.energy_mj)),
                                ("eq_ops", Json::num(r.eq_ops as f64)),
                                ("pj_per_op", Json::num(r.pj_per_op)),
                                ("energy_breakdown_mj", breakdown_json(&r.ledger)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// The surveillance design-choice sweep (ablation labels + results).
#[derive(Debug, Clone)]
pub struct AblationReport {
    pub rows: Vec<(String, UseCaseResult)>,
}

impl AblationReport {
    /// The historical `fulmine ablations` rows, one line each.
    pub fn render_text(&self) -> String {
        let mut s = String::new();
        for (label, r) in &self.rows {
            writeln!(
                s,
                "{label:<18} time {:>8.4} s  energy {:>8.3} mJ  {:>6.2} pJ/op",
                r.time_s, r.energy_mj, r.pj_per_op
            )
            .unwrap();
        }
        s
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![(
            "ablations",
            Json::Arr(
                self.rows
                    .iter()
                    .map(|(label, r)| {
                        Json::obj(vec![
                            ("label", Json::string(label)),
                            ("time_s", Json::num(r.time_s)),
                            ("energy_mj", Json::num(r.energy_mj)),
                            ("pj_per_op", Json::num(r.pj_per_op)),
                        ])
                    })
                    .collect(),
            ),
        )])
    }
}

/// One grid point of the `fulmine faultsweep` reliability table.
#[derive(Debug, Clone)]
pub struct FaultSweepRow {
    pub faults: String,
    pub recovery: String,
    pub availability: f64,
    pub frames_dropped: u64,
    pub fault_retries: u64,
    pub chip_resets: u64,
    pub recovery_energy_mj: f64,
    pub energy_mj: f64,
    pub time_s: f64,
}

/// The fault-rate × recovery-policy sweep of one workload stream.
#[derive(Debug, Clone)]
pub struct FaultSweepReport {
    pub workload: String,
    pub frames: usize,
    pub rows: Vec<FaultSweepRow>,
}

impl FaultSweepReport {
    pub fn render_text(&self) -> String {
        let mut s = String::new();
        writeln!(
            s,
            "== faultsweep: {} over {} frames (rate x policy grid, shared fault seed) ==",
            self.workload, self.frames
        )
        .unwrap();
        writeln!(
            s,
            "{:<26} {:<26} {:>7} {:>7} {:>7} {:>7} {:>10} {:>10}",
            "faults", "recovery", "avail", "drop", "retry", "reset", "rec [mJ]", "E [mJ]"
        )
        .unwrap();
        for r in &self.rows {
            writeln!(
                s,
                "{:<26} {:<26} {:>7.4} {:>7} {:>7} {:>7} {:>10.4} {:>10.3}",
                r.faults,
                r.recovery,
                r.availability,
                r.frames_dropped,
                r.fault_retries,
                r.chip_resets,
                r.recovery_energy_mj,
                r.energy_mj
            )
            .unwrap();
        }
        s
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("workload", Json::string(&self.workload)),
            ("frames", Json::num(self.frames as f64)),
            (
                "rows",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("faults", Json::string(&r.faults)),
                                ("recovery", Json::string(&r.recovery)),
                                ("availability", Json::num(r.availability)),
                                ("frames_dropped", Json::num(r.frames_dropped as f64)),
                                ("fault_retries", Json::num(r.fault_retries as f64)),
                                ("chip_resets", Json::num(r.chip_resets as f64)),
                                ("recovery_energy_mj", Json::num(r.recovery_energy_mj)),
                                ("energy_mj", Json::num(r.energy_mj)),
                                ("time_s", Json::num(r.time_s)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// One grid point of the `fulmine sessionsweep` secure-link ablation.
#[derive(Debug, Clone)]
pub struct SessionSweepRow {
    pub backend: String,
    pub channel: String,
    pub recovery: String,
    pub availability: f64,
    /// Delivered records per second of stream time.
    pub goodput_fps: f64,
    pub retransmissions: u64,
    pub resumptions: u64,
    pub full_handshakes: u64,
    pub records_dropped: u64,
    pub handshake_mj: f64,
    pub record_mj: f64,
    pub energy_mj: f64,
    pub time_s: f64,
}

/// The crypto-backend × loss-rate × recovery-policy ablation of the
/// `secure_link` stream: every point shares one channel seed, so within
/// a loss rate the *same frames* suffer outages under every backend and
/// policy and the rows differ only in how the session answers.
#[derive(Debug, Clone)]
pub struct SessionSweepReport {
    pub workload: String,
    pub frames: usize,
    pub rows: Vec<SessionSweepRow>,
}

impl SessionSweepReport {
    pub fn render_text(&self) -> String {
        let mut s = String::new();
        writeln!(
            s,
            "== sessionsweep: {} over {} frames (backend x loss x recovery grid, shared channel seed) ==",
            self.workload, self.frames
        )
        .unwrap();
        writeln!(
            s,
            "{:<8} {:<22} {:<24} {:>7} {:>9} {:>6} {:>6} {:>6} {:>9} {:>9} {:>10}",
            "backend",
            "channel",
            "recovery",
            "avail",
            "goodput",
            "retx",
            "resume",
            "drop",
            "hs [mJ]",
            "rec [mJ]",
            "E [mJ]"
        )
        .unwrap();
        for r in &self.rows {
            writeln!(
                s,
                "{:<8} {:<22} {:<24} {:>7.4} {:>9.3} {:>6} {:>6} {:>6} {:>9.4} {:>9.4} {:>10.3}",
                r.backend,
                r.channel,
                r.recovery,
                r.availability,
                r.goodput_fps,
                r.retransmissions,
                r.resumptions,
                r.records_dropped,
                r.handshake_mj,
                r.record_mj,
                r.energy_mj
            )
            .unwrap();
        }
        s
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("workload", Json::string(&self.workload)),
            ("frames", Json::num(self.frames as f64)),
            (
                "rows",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("backend", Json::string(&r.backend)),
                                ("channel", Json::string(&r.channel)),
                                ("recovery", Json::string(&r.recovery)),
                                ("availability", Json::num(r.availability)),
                                ("goodput_fps", Json::num(r.goodput_fps)),
                                ("retransmissions", Json::num(r.retransmissions as f64)),
                                ("resumptions", Json::num(r.resumptions as f64)),
                                ("full_handshakes", Json::num(r.full_handshakes as f64)),
                                ("records_dropped", Json::num(r.records_dropped as f64)),
                                ("handshake_mj", Json::num(r.handshake_mj)),
                                ("record_mj", Json::num(r.record_mj)),
                                ("energy_mj", Json::num(r.energy_mj)),
                                ("time_s", Json::num(r.time_s)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// The façade over one simulated Fulmine SoC: a workload [`Registry`] plus
/// the scheduling/attribution machinery to execute a [`RunSpec`].
pub struct SocSystem {
    registry: Registry,
}

impl Default for SocSystem {
    fn default() -> Self {
        Self::new()
    }
}

impl SocSystem {
    /// A system with the built-in workload set registered.
    pub fn new() -> Self {
        SocSystem { registry: Registry::builtin() }
    }

    /// A system over a caller-composed registry.
    pub fn with_registry(registry: Registry) -> Self {
        SocSystem { registry }
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    pub fn registry_mut(&mut self) -> &mut Registry {
        &mut self.registry
    }

    fn resolve(&self, spec: &RunSpec) -> Result<(&dyn Workload, Rung)> {
        let w = self.registry.resolve(&spec.workload)?;
        if spec.frames == 0 {
            bail!("--frames must be at least 1");
        }
        let mut rung = select_rung(&w.rungs(), &spec.rung)?;
        rung.cfg = spec.overrides.apply(rung.cfg);
        Ok((w, rung))
    }

    /// Run a chip fleet with class deduplication — see [`Fleet::run`].
    pub fn fleet(&self, spec: &FleetSpec) -> Result<FleetReport> {
        Fleet::run(self, spec)
    }

    /// Schedule one frame of the spec's workload and return the Fig.
    /// 10/11/12-style result (the spec's `frames` is ignored here).
    pub fn run_frame(&self, spec: &RunSpec) -> Result<UseCaseResult> {
        let (w, rung) = self.resolve(spec)?;
        let g = frame_graph_with(w, rung.cfg, spec.crypto_backend)?;
        let res = Scheduler::run(&g);
        Ok(UseCaseResult::from_ledger(w.name(), res.ledger, w.eq_ops()))
    }

    /// Stream `spec.frames` frames of the workload through the scheduler
    /// (across `spec.shards` simulated chips when sharded) and return the
    /// structured report, with per-tenant attribution for multi-tenant
    /// workloads.
    pub fn run(&self, spec: &RunSpec) -> Result<RunReport> {
        let (w, rung) = self.resolve(spec)?;
        if spec.window == Some(0) {
            bail!("--window must be at least 1 (zero in-flight frames schedule nothing)");
        }
        if spec.shards == 0 {
            bail!("--shards must be at least 1 (no chips schedule no frames)");
        }
        spec.traffic.validate()?;
        if let Some(m) = &spec.faults {
            m.validate()?;
            spec.recovery.validate()?;
        }
        if let Some(m) = &spec.loss {
            if spec.faults.is_some() {
                bail!("--loss and --faults are mutually exclusive (one failure model per run)");
            }
            m.validate()?;
        }
        let g = frame_graph_with(w, rung.cfg, spec.crypto_backend)?;
        let window = spec.window.unwrap_or(crate::soc::sched::DEFAULT_STREAM_WINDOW);
        // The global session plan: one closed-form pass over the channel
        // draws. Sharded runs rebuild the same plan per shard range (pure,
        // so the union equals this one) — the report carries the global
        // counters either way.
        let session = spec
            .loss
            .as_ref()
            .map(|m| SessionPlan::build(m, spec.session_recovery, &g, 0, spec.frames))
            .transpose()?;
        let (result, shards) = if spec.shards > 1 {
            let parts = match &spec.loss {
                None => ShardedStream::run_faulted(
                    &g,
                    spec.frames,
                    window,
                    spec.shards,
                    &spec.traffic,
                    spec.policy,
                    spec.faults.as_ref().map(|m| (m, spec.recovery)),
                ),
                Some(m) => ShardedStream::run_session(
                    &g,
                    spec.frames,
                    window,
                    spec.shards,
                    &spec.traffic,
                    spec.policy,
                    Some((m, spec.session_recovery)),
                )?,
            };
            let result = merge_sharded(
                w.name(), &g, spec.frames, window, w.eq_ops(), &parts, spec.policy,
            );
            (result, parts.into_iter().map(|(_, st)| st).collect())
        } else {
            let release = spec.traffic.release_times(spec.frames);
            let result = match &session {
                Some(plan) => stream_graph_session_pm(
                    w.name(),
                    &g,
                    spec.frames,
                    window,
                    w.eq_ops(),
                    &release,
                    spec.policy,
                    Some(plan),
                ),
                None => {
                    let plan = spec.faults.as_ref().map(|m| {
                        FaultPlan::build(
                            m, spec.recovery, &g, 0, spec.frames, window.min(spec.frames),
                        )
                    });
                    stream_graph_faulted_pm(
                        w.name(),
                        &g,
                        spec.frames,
                        window,
                        w.eq_ops(),
                        &release,
                        spec.policy,
                        plan.as_ref(),
                    )
                }
            };
            (result, Vec::new())
        };
        let frames = spec.frames as f64;

        // Per-tenant attribution. Rows follow the workload's *declared*
        // tenants (a tenant whose frame emitted no jobs still gets a row);
        // active energy is schedule-independent, so per-frame segment
        // totals — matched to tenants by name — scale by the frame count,
        // and the leftover (idle, leakage, ext-mem standby, plus any
        // segment matching no declared tenant) is shared out proportionally
        // to each tenant's active energy. Single-tenant workloads are one
        // row covering the whole schedule, whatever segments they marked.
        let seg = g.segment_active_mj();
        let tenant_info = w.tenants();
        let tenants = if seg.is_empty() || tenant_info.len() <= 1 {
            vec![TenantRow {
                name: w.name().to_string(),
                eq_ops: w.eq_ops(),
                active_mj: g.active_mj() * frames,
                energy_mj: result.energy_mj,
                pj_per_op: result.pj_per_op,
            }]
        } else {
            let active: Vec<f64> = tenant_info
                .iter()
                .map(|(name, _)| {
                    seg.iter().find(|(l, _)| l == name).map_or(0.0, |(_, mj)| mj * frames)
                })
                .collect();
            let active_total: f64 = active.iter().sum();
            let overhead = (result.energy_mj - active_total).max(0.0);
            tenant_info
                .iter()
                .zip(&active)
                .map(|((name, eq_ops), &active_mj)| {
                    let share = if active_total > 0.0 {
                        active_mj / active_total
                    } else {
                        1.0 / tenant_info.len() as f64
                    };
                    let energy_mj = active_mj + overhead * share;
                    // undefined rather than garbage when a tenant declares
                    // no equivalent ops (JSON renders NaN as null)
                    let pj_per_op = if *eq_ops > 0 {
                        energy_mj * 1e9 / (*eq_ops as f64 * frames)
                    } else {
                        f64::NAN
                    };
                    TenantRow {
                        name: name.clone(),
                        eq_ops: *eq_ops,
                        active_mj,
                        energy_mj,
                        pj_per_op,
                    }
                })
                .collect()
        };

        Ok(RunReport {
            workload: w.name().to_string(),
            rung: rung.label.to_string(),
            cfg: rung.cfg,
            frames: spec.frames,
            faults: spec
                .faults
                .as_ref()
                .map_or_else(|| "none".to_string(), |m| m.describe()),
            recovery: spec
                .faults
                .as_ref()
                .map_or_else(|| "none".to_string(), |_| spec.recovery.describe()),
            channel: spec
                .loss
                .as_ref()
                .map_or_else(|| "none".to_string(), |m| m.describe()),
            session_recovery: spec.loss.as_ref().map_or_else(
                || "none".to_string(),
                |_| spec.session_recovery.describe().to_string(),
            ),
            crypto_backend: spec.crypto_backend.map_or("native", |b| b.name()).to_string(),
            session: session.map(|p| p.stats),
            result,
            tenants,
            shards,
        })
    }

    /// One single-frame run per rung of the workload's ladder.
    pub fn ladder(&self, workload: &str) -> Result<LadderReport> {
        let w = self.registry.resolve(workload)?;
        let rows = w
            .rungs()
            .into_iter()
            .map(|rung| {
                let g = frame_graph(w, rung.cfg)?;
                let res = Scheduler::run(&g);
                let mut r = UseCaseResult::from_ledger(w.name(), res.ledger, w.eq_ops());
                r.label = rung.label.to_string();
                Ok(r)
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(LadderReport { workload: workload.to_string(), rows })
    }

    /// The Fig. 10 design-choice sweep, expressed as [`RunSpec`]s with
    /// [`ModeOverrides`] on the best surveillance rung — intermediate
    /// configurations not on the main ladder.
    pub fn surveillance_ablations(&self) -> Result<AblationReport> {
        let sweeps: [(&str, ModeOverrides); 5] = [
            (
                "hwce4+swcrypto",
                ModeOverrides { hwcrypt: Some(false), ..Default::default() },
            ),
            (
                "hwce8+hwcrypt",
                ModeOverrides { hwce: Some(Some(WeightPrec::W8)), ..Default::default() },
            ),
            ("hwce4@1.0V", ModeOverrides { vdd: Some(1.0), ..Default::default() }),
            ("hwce4@1.2V", ModeOverrides { vdd: Some(1.2), ..Default::default() }),
            (
                "hwce4 layer-gran",
                ModeOverrides { tiling: Some(Tiling::Layer), ..Default::default() },
            ),
        ];
        let mut rows = Vec::new();
        for (label, overrides) in sweeps {
            let spec = RunSpec::new("surveillance").overrides(overrides);
            rows.push((label.to_string(), self.run_frame(&spec)?));
        }
        Ok(AblationReport { rows })
    }

    /// The `fulmine faultsweep` grid: stream `frames` frames of the
    /// workload once per fault-rate × recovery-policy point (plus a
    /// fault-free baseline) and tabulate availability, drop/retry/reset
    /// counts and recovery energy. All points share one fault seed, so
    /// within a rate the *same frames* fault under every policy and the
    /// rows differ only in how the chip answers.
    pub fn fault_sweep(&self, workload: &str, frames: usize) -> Result<FaultSweepReport> {
        const SEED: u64 = 9;
        let rates = [0.01f64, 0.05];
        let policies = [Recovery::default(), Recovery::Degrade, Recovery::Reset];
        let mut points = vec![(FaultModel::none(), Recovery::default())];
        for &r in &rates {
            let model = FaultModel {
                drop_rate: r,
                transient_rate: r,
                brownout_rate: r / 10.0,
                link_rate: r,
                seed: SEED,
            };
            for &p in &policies {
                points.push((model.clone(), p));
            }
        }
        let mut rows = Vec::new();
        for (model, recovery) in points {
            let spec = RunSpec::new(workload)
                .frames(frames)
                .faults((!model.is_none()).then(|| model.clone()))
                .recovery(recovery);
            let run = self.run(&spec)?;
            let r = &run.result;
            rows.push(FaultSweepRow {
                faults: if model.is_none() {
                    "none".to_string()
                } else {
                    format!("mixed @ {} (seed {})", model.drop_rate, model.seed)
                },
                recovery: if model.is_none() { "—".to_string() } else { recovery.describe() },
                availability: r.availability(),
                frames_dropped: r.frames_dropped,
                fault_retries: r.fault_retries,
                chip_resets: r.chip_resets,
                recovery_energy_mj: r.recovery_energy_mj,
                energy_mj: r.energy_mj,
                time_s: r.time_s,
            });
        }
        Ok(FaultSweepReport { workload: workload.to_string(), frames, rows })
    }

    /// The `fulmine sessionsweep` grid: stream `frames` frames of the
    /// `secure_link` workload once per crypto backend × channel point —
    /// a lossless baseline plus two loss rates × three recovery policies
    /// per backend, all sharing one channel seed so the same frames
    /// suffer the same outages across the grid.
    pub fn session_sweep(&self, frames: usize) -> Result<SessionSweepReport> {
        const SEED: u64 = 11;
        // 0.2 is the retransmission regime (every loss recovered within
        // the timer budget); 0.6 is the outage regime (frames exhaust
        // the 8-send budget, so the recovery policies actually diverge).
        let rates = [0.2f64, 0.6];
        let mut rows = Vec::new();
        for backend in BackendKind::all() {
            let mut points = vec![(SessionModel { loss_rate: 0.0, seed: SEED }, None)];
            for &rate in &rates {
                for rec in SessionRecovery::all() {
                    points.push((SessionModel { loss_rate: rate, seed: SEED }, Some(rec)));
                }
            }
            for (model, rec) in points {
                let recovery = rec.unwrap_or_default();
                let spec = RunSpec::new("secure_link")
                    .frames(frames)
                    .loss(Some(model.clone()))
                    .session_recovery(recovery)
                    .crypto_backend(Some(backend));
                let run = self.run(&spec)?;
                let ss = run.session.expect("secure_link with --loss carries session stats");
                rows.push(SessionSweepRow {
                    backend: backend.name().to_string(),
                    channel: model.describe(),
                    recovery: if rec.is_none() {
                        "—".to_string()
                    } else {
                        recovery.describe().to_string()
                    },
                    availability: ss.availability(frames),
                    goodput_fps: ss.goodput_fps(frames, run.result.time_s),
                    retransmissions: ss.retransmissions,
                    resumptions: ss.resumptions,
                    full_handshakes: ss.full_handshakes,
                    records_dropped: ss.records_dropped,
                    handshake_mj: ss.handshake_mj,
                    record_mj: ss.record_mj,
                    energy_mj: run.result.energy_mj,
                    time_s: run.result.time_s,
                });
            }
        }
        Ok(SessionSweepReport {
            workload: "secure_link".to_string(),
            frames,
            rows,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rung_selection_modes() {
        let rungs = ExecConfig::ladder();
        assert_eq!(select_rung(&rungs, &RungSel::Best).unwrap().label, "+HWCE 4b");
        assert_eq!(select_rung(&rungs, &RungSel::Index(0)).unwrap().label, "SW 1-core");
        assert_eq!(
            select_rung(&rungs, &RungSel::Label("hwcrypt".into())).unwrap().label,
            "+HWCRYPT"
        );
        let e = select_rung(&rungs, &RungSel::Index(99)).unwrap_err().to_string();
        assert!(e.contains("out of range"), "{e}");
        let e = select_rung(&rungs, &RungSel::Label("nope".into())).unwrap_err().to_string();
        assert!(e.contains("available"), "{e}");
    }

    #[test]
    fn rungsel_parse_matches_cli_convention() {
        assert_eq!(RungSel::parse(None), RungSel::Best);
        assert_eq!(RungSel::parse(Some("2")), RungSel::Index(2));
        assert_eq!(RungSel::parse(Some("hwce")), RungSel::Label("hwce".into()));
    }

    #[test]
    fn zero_frames_rejected() {
        let sys = SocSystem::new();
        let e = sys.run(&RunSpec::new("surveillance").frames(0)).unwrap_err().to_string();
        assert!(e.contains("at least 1"), "{e}");
    }

    #[test]
    fn single_tenant_report_has_one_row() {
        let sys = SocSystem::new();
        let r = sys.run(&RunSpec::new("seizure").frames(2)).unwrap();
        assert_eq!(r.tenants.len(), 1);
        assert_eq!(r.tenants[0].name, "seizure");
        assert!((r.tenants[0].energy_mj - r.result.energy_mj).abs() < 1e-12);
        assert!(r.tenants[0].active_mj <= r.result.energy_mj + 1e-12);
    }

    #[test]
    fn zero_window_rejected() {
        let sys = SocSystem::new();
        let e = sys.run(&RunSpec::new("seizure").window(0)).unwrap_err().to_string();
        assert!(e.contains("--window must be at least 1"), "{e}");
    }

    #[test]
    fn zero_shards_rejected() {
        let sys = SocSystem::new();
        let e = sys.run(&RunSpec::new("seizure").shards(0)).unwrap_err().to_string();
        assert!(e.contains("--shards must be at least 1"), "{e}");
    }

    /// Satellite (window clamp): a window wider than the stream reports —
    /// and schedules — exactly as the clamped window does.
    #[test]
    fn oversized_window_clamps_and_matches() {
        let sys = SocSystem::new();
        let wide = sys.run(&RunSpec::new("seizure").frames(3).window(4096)).unwrap();
        let exact = sys.run(&RunSpec::new("seizure").frames(3).window(3)).unwrap();
        assert_eq!(wide.result.window, 3);
        assert_eq!(wide.result.time_s.to_bits(), exact.result.time_s.to_bits());
        assert_eq!(wide.result.energy_mj.to_bits(), exact.result.energy_mj.to_bits());
        assert_eq!(wide.result.peak_resident_jobs, exact.result.peak_resident_jobs);
    }

    /// Tentpole (multi-SoC sharding): splitting a stream across simulated
    /// chips sums energy, takes the slowest shard as the makespan, scales
    /// throughput near-linearly, and surfaces per-shard admission
    /// estimates that bound the scheduled makespans.
    #[test]
    fn sharded_stream_consistency() {
        let sys = SocSystem::new();
        let frames = 8usize;
        let base = sys.run(&RunSpec::new("seizure").frames(frames)).unwrap();
        let sharded = sys.run(&RunSpec::new("seizure").frames(frames).shards(2)).unwrap();
        assert_eq!(sharded.frames, frames);
        assert_eq!(sharded.shards.len(), 2);
        let f_sum: usize = sharded.shards.iter().map(|s| s.frames).sum();
        assert_eq!(f_sum, frames, "shard shares must partition the stream");
        let e_sum: f64 = sharded.shards.iter().map(|s| s.energy_mj).sum();
        assert!(
            (e_sum - sharded.result.energy_mj).abs() < 1e-9 * (1.0 + e_sum),
            "shard energies {e_sum} vs merged {}",
            sharded.result.energy_mj
        );
        assert!(
            sharded.result.time_s <= base.result.time_s + 1e-12,
            "sharding must not slow the stream"
        );
        assert!(
            sharded.result.fps >= base.result.fps * 1.5,
            "2 chips should approach 2x throughput: {} vs {}",
            sharded.result.fps,
            base.result.fps
        );
        for st in &sharded.shards {
            assert!(st.time_s <= st.serialized_bound_s + 1e-9, "shard {} bound", st.shard);
            assert!(st.analytic_est_s > 0.0 && st.frames > 0);
        }
        let text = sharded.render_text();
        assert!(text.contains("sharded across 2 SoCs"), "{text}");
        assert!(text.contains("shard 0") && text.contains("shard 1"), "{text}");
        let json = sharded.to_json().render();
        assert!(json.contains("\"shard_count\":2"), "{json}");
        assert!(json.contains("\"serialized_bound_s\""), "{json}");
        // a single-SoC report carries no shard section (byte-stable text)
        assert!(!base.render_text().contains("sharded across"), "S=1 text must be unchanged");
        assert_eq!(base.shards.len(), 0);
        // more chips than frames clamps to one frame per chip
        let over = sys.run(&RunSpec::new("seizure").frames(2).shards(16)).unwrap();
        assert_eq!(over.shards.len(), 2);
    }

    /// Satellite: per-tenant attribution is window-invariant — the active
    /// rows are identical for any window, and the attributed total always
    /// re-sums to the schedule's energy even though tighter windows may
    /// change the makespan (and with it the shared idle overhead).
    #[test]
    fn tenant_attribution_sums_are_window_invariant() {
        let sys = SocSystem::new();
        let frames = 6usize;
        let mut reference: Option<Vec<(String, f64)>> = None;
        for window in [1usize, 2, frames, 32] {
            let r = sys.run(&RunSpec::new("mixed").frames(frames).window(window)).unwrap();
            // oversized windows clamp to the stream length
            assert_eq!(r.result.window, window.min(frames));
            let attributed: f64 = r.tenants.iter().map(|t| t.energy_mj).sum();
            assert!(
                (attributed - r.result.energy_mj).abs() < 1e-6 * r.result.energy_mj,
                "window {window}: attributed {attributed} vs {}",
                r.result.energy_mj
            );
            let active: Vec<(String, f64)> =
                r.tenants.iter().map(|t| (t.name.clone(), t.active_mj)).collect();
            match &reference {
                None => reference = Some(active),
                Some(base) => {
                    for ((n0, a0), (n1, a1)) in base.iter().zip(&active) {
                        assert_eq!(n0, n1);
                        assert_eq!(a0.to_bits(), a1.to_bits(), "{n0} active energy vs window");
                    }
                }
            }
        }
    }

    /// Satellite (traffic tests): a seeded Poisson run replays bitwise
    /// across invocations, and — since every chip regenerates its model
    /// from t = 0 — an equal S-way split makes all shards bitwise equal
    /// to each other and to the single-chip run of one share.
    #[test]
    fn poisson_traffic_reproducible_across_runs_and_shards() {
        let sys = SocSystem::new();
        let poisson = Traffic::Poisson { rate_hz: 2.0, seed: 9 };
        let spec = RunSpec::new("seizure").frames(12).traffic(poisson.clone());
        let a = sys.run(&spec).unwrap();
        let b = sys.run(&spec).unwrap();
        assert_eq!(a.result.time_s.to_bits(), b.result.time_s.to_bits());
        assert_eq!(a.result.energy_mj.to_bits(), b.result.energy_mj.to_bits());
        let sharded = sys.run(&spec.clone().shards(3)).unwrap();
        let again = sys.run(&spec.clone().shards(3)).unwrap();
        assert_eq!(
            sharded.result.energy_mj.to_bits(),
            again.result.energy_mj.to_bits(),
            "sharded Poisson must replay bitwise"
        );
        assert_eq!(sharded.shards.len(), 3);
        // 12 frames over 3 chips: identical 4-frame shares, identical chips
        let single_share =
            sys.run(&RunSpec::new("seizure").frames(4).traffic(poisson)).unwrap();
        for st in &sharded.shards {
            assert_eq!(st.frames, 4);
            assert_eq!(st.time_s.to_bits(), sharded.shards[0].time_s.to_bits());
            assert_eq!(st.energy_mj.to_bits(), sharded.shards[0].energy_mj.to_bits());
            assert_eq!(
                st.time_s.to_bits(),
                single_share.result.time_s.to_bits(),
                "a shard is exactly the single-chip run of its share"
            );
        }
    }

    /// Satellite (traffic tests): traffic gaps change the schedule but
    /// not the work — per-tenant active rows stay bitwise
    /// window-invariant and the attributed total still re-sums to the
    /// schedule's energy on gap-inserted streams.
    #[test]
    fn gap_inserted_streams_keep_attribution_window_invariant() {
        let sys = SocSystem::new();
        let frames = 6usize;
        let mut reference: Option<Vec<(String, f64)>> = None;
        for window in [1usize, 2, frames] {
            let r = sys
                .run(
                    &RunSpec::new("mixed")
                        .frames(frames)
                        .window(window)
                        .traffic(Traffic::Periodic { rate_hz: 0.5 }),
                )
                .unwrap();
            let attributed: f64 = r.tenants.iter().map(|t| t.energy_mj).sum();
            assert!(
                (attributed - r.result.energy_mj).abs() < 1e-6 * r.result.energy_mj,
                "window {window}: attributed {attributed} vs {}",
                r.result.energy_mj
            );
            let active: Vec<(String, f64)> =
                r.tenants.iter().map(|t| (t.name.clone(), t.active_mj)).collect();
            match &reference {
                None => reference = Some(active),
                Some(base) => {
                    for ((n0, a0), (n1, a1)) in base.iter().zip(&active) {
                        assert_eq!(n0, n1);
                        assert_eq!(a0.to_bits(), a1.to_bits(), "{n0} active vs window");
                    }
                }
            }
        }
        // gap-dominated single-tenant stream: makespan is release-driven
        let gapped = sys
            .run(
                &RunSpec::new("seizure")
                    .frames(4)
                    .traffic(Traffic::Periodic { rate_hz: 0.25 }),
            )
            .unwrap();
        assert!(
            gapped.result.time_s >= 3.0 / 0.25,
            "4 frames at 0.25 Hz must span at least the last release: {}",
            gapped.result.time_s
        );
    }

    #[test]
    fn weighted_percentile_nearest_rank() {
        let total = 4usize;
        let mut v = vec![(3.0, 1usize), (1.0, 1), (4.0, 1), (2.0, 1)];
        assert_eq!(weighted_percentile(&mut v, 0.50, total), 2.0);
        assert_eq!(weighted_percentile(&mut v, 0.95, total), 4.0);
        assert_eq!(weighted_percentile(&mut v, 0.25, total), 1.0);
        // population weighting: 97 cheap chips, 3 expensive ones
        let mut w = vec![(1.0, 97usize), (10.0, 3)];
        assert_eq!(weighted_percentile(&mut w, 0.50, 100), 1.0);
        assert_eq!(weighted_percentile(&mut w, 0.95, 100), 1.0);
        assert_eq!(weighted_percentile(&mut w, 0.99, 100), 10.0);
    }

    /// Tentpole: the fleet runner dedups chips into classes (live work
    /// tracks the class count, not the population), every class passes its
    /// sampled live-vs-scaled parity check, and the roll-up is coherent.
    #[test]
    fn fleet_dedups_classes_and_passes_parity() {
        let sys = SocSystem::new();
        let fleet = FleetSpec::mixed(64, 4);
        let n_groups = fleet.groups.len();
        let report = sys.fleet(&fleet).unwrap();
        assert_eq!(report.chips, 64);
        assert_eq!(report.classes.len(), n_groups, "mixed templates are all distinct");
        assert!(report.classes.len() < report.chips, "dedup must beat per-chip simulation");
        assert!(report.live_chips <= report.classes.len() * report.sample_k);
        assert!(report.parity_checked >= report.classes.len(), "every class sampled");
        assert_eq!(report.parity_failures, 0);
        let pop: usize = report.classes.iter().map(|c| c.chips).sum();
        assert_eq!(pop, 64, "class populations partition the fleet");
        assert_eq!(report.total_frames, 64 * 4);
        assert!(report.energy_j > 0.0);
        for p in [report.energy_mj_per_chip, report.latency_s, report.utilization] {
            assert!(p.p50 <= p.p95 && p.p95 <= p.p99, "percentiles must be ordered");
        }
        assert!(report.makespan_s >= report.latency_s.p99, "fleet makespan is the slowest chip");
        let text = report.render_text();
        assert!(text.contains("64 chips"), "{text}");
        assert!(text.contains("dedup speedup"), "{text}");
        let json = report.to_json().render();
        for key in [
            "\"chips\"",
            "\"class_count\"",
            "\"live_chips\"",
            "\"parity_checked\"",
            "\"parity_failures\"",
            "\"dedup_speedup\"",
            "\"chips_per_s\"",
            "\"energy_mj_per_chip\"",
        ] {
            assert!(json.contains(key), "missing {key} in {json}");
        }
    }

    /// Class scaling is honest: a 5-chip single-class fleet reports 5× the
    /// single-chip energy, every member simulates live (sample_k ≥
    /// population), and duplicate groups merge into one class.
    #[test]
    fn fleet_population_scaling_matches_single_runs() {
        let sys = SocSystem::new();
        let spec = RunSpec::new("seizure")
            .frames(3)
            .traffic(Traffic::Periodic { rate_hz: 2.0 });
        let single = sys.run(&spec).unwrap();
        let fleet = FleetSpec::new(vec![
            FleetGroup { spec: spec.clone(), chips: 2 },
            FleetGroup { spec: spec.clone(), chips: 3 },
        ])
        .sample_k(5);
        let report = sys.fleet(&fleet).unwrap();
        assert_eq!(report.classes.len(), 1, "identical groups merge into one class");
        assert_eq!(report.chips, 5);
        assert_eq!(report.classes[0].chips, 5);
        assert_eq!(report.live_chips, 5, "sample_k covers the whole population");
        assert_eq!(report.parity_failures, 0);
        let expect_j = 5.0 * single.result.energy_mj / 1e3;
        assert!(
            (report.energy_j - expect_j).abs() < 1e-12 * (1.0 + expect_j),
            "scaled fleet energy {} vs 5x single {}",
            report.energy_j,
            expect_j
        );
        assert_eq!(report.makespan_s.to_bits(), single.result.time_s.to_bits());
        assert_eq!(report.latency_s.p50.to_bits(), single.result.time_s.to_bits());
        assert_eq!(report.latency_s.p99.to_bits(), single.result.time_s.to_bits());
    }

    /// Tentpole (power policy): a managed gapped stream keeps the exact
    /// unmanaged schedule (timing is bitwise identical — the policy only
    /// re-bills idle spans), spends most of the makespan asleep, saves
    /// energy, and surfaces the battery extrapolation in text and JSON.
    #[test]
    fn policy_rebills_gaps_without_touching_the_schedule() {
        let sys = SocSystem::new();
        let spec = RunSpec::new("seizure")
            .frames(8)
            .traffic(Traffic::Periodic { rate_hz: 2.0 });
        let base = sys.run(&spec).unwrap();
        let managed = sys.run(&spec.clone().policy(Some(PolicyKind::Lookahead))).unwrap();
        assert_eq!(base.result.time_s.to_bits(), managed.result.time_s.to_bits());
        assert_eq!(base.result.mode_switches, managed.result.mode_switches);
        assert_eq!(base.result.sleep_s, 0.0, "unmanaged runs report no sleep");
        assert!(managed.result.sleep_s > 0.9 * managed.result.time_s, "gap-dominated");
        assert!(managed.result.deep_sleep_s > 0.0);
        assert!(managed.result.energy_mj < base.result.energy_mj, "sleep must save energy");
        let text = managed.render_text();
        assert!(text.contains("policy lookahead"), "{text}");
        assert!(text.contains("days on a"), "{text}");
        assert!(!base.render_text().contains("policy"), "unmanaged text unchanged");
        let json = managed.to_json().render();
        assert!(json.contains("\"policy\":\"lookahead\""), "{json}");
        assert!(json.contains("\"battery_days\""), "{json}");
        // sharded managed run: chip-local gaps re-bill per chip and sum
        let sharded = sys
            .run(&spec.clone().frames(8).shards(2).policy(Some(PolicyKind::Lookahead)))
            .unwrap();
        assert!(sharded.result.sleep_s > 0.0);
        let e_sum: f64 = sharded.shards.iter().map(|s| s.energy_mj).sum();
        assert!((e_sum - sharded.result.energy_mj).abs() < 1e-9 * (1.0 + e_sum));
    }

    /// Tentpole (fleet policy): a managed fleet passes the sampled
    /// live-vs-scaled bitwise parity (sleep accounting included via
    /// `sched_bitwise_mismatch`), reports battery-life percentiles, and orders
    /// oracle ≤ lookahead ≤ greedy ≤ unmanaged on total energy.
    #[test]
    fn fleet_policy_parity_and_energy_ordering() {
        let sys = SocSystem::new();
        let groups = || {
            vec![
                FleetGroup {
                    spec: RunSpec::new("seizure")
                        .frames(4)
                        .traffic(Traffic::Periodic { rate_hz: 2.0 }),
                    chips: 5,
                },
                FleetGroup {
                    spec: RunSpec::new("facedet")
                        .frames(3)
                        .traffic(Traffic::Poisson { rate_hz: 1.0, seed: 7 }),
                    chips: 4,
                },
            ]
        };
        let run = |policy: Option<PolicyKind>| {
            sys.fleet(&FleetSpec::new(groups()).sample_k(3).policy(policy)).unwrap()
        };
        let base = run(None);
        let greedy = run(Some(PolicyKind::Greedy));
        let lookahead = run(Some(PolicyKind::Lookahead));
        let oracle = run(Some(PolicyKind::Oracle));
        for (r, name) in
            [(&base, "none"), (&greedy, "greedy"), (&lookahead, "lookahead"), (&oracle, "oracle")]
        {
            assert_eq!(r.parity_failures, 0, "{name} parity");
            assert_eq!(r.policy, name);
            assert!(r.classes.iter().all(|c| c.policy == name && c.key.contains(name)));
        }
        assert!(oracle.energy_j <= lookahead.energy_j);
        assert!(lookahead.energy_j <= greedy.energy_j);
        assert!(greedy.energy_j < base.energy_j, "gapped chips must save under management");
        // battery life moves the other way: deeper sleep → more days
        assert!(lookahead.battery_days.p50 >= greedy.battery_days.p50);
        for c in &lookahead.classes {
            assert!(c.sleep_s > 0.0 && c.battery_days > 0.0 && c.epd_mj_per_day > 0.0);
        }
        let text = lookahead.render_text();
        assert!(text.contains("policy lookahead"), "{text}");
        assert!(text.contains("battery [d]"), "{text}");
        let json = lookahead.to_json().render();
        assert!(json.contains("\"policy\":\"lookahead\""), "{json}");
        assert!(json.contains("\"battery_days\""), "{json}");
        assert!(json.contains("\"epd_mj_per_day\""), "{json}");
    }

    #[test]
    fn fleet_rejects_bad_specs() {
        let sys = SocSystem::new();
        let e = sys
            .fleet(&FleetSpec::new(vec![FleetGroup {
                spec: RunSpec::new("seizure").shards(2),
                chips: 4,
            }]))
            .unwrap_err()
            .to_string();
        assert!(e.contains("more chips"), "{e}");
        let e = sys
            .fleet(&FleetSpec::new(vec![FleetGroup {
                spec: RunSpec::new("seizure"),
                chips: 0,
            }]))
            .unwrap_err()
            .to_string();
        assert!(e.contains("at least one chip"), "{e}");
        let e = sys
            .fleet(
                &FleetSpec::new(vec![FleetGroup {
                    spec: RunSpec::new("seizure"),
                    chips: 1,
                }])
                .sample_k(0),
            )
            .unwrap_err()
            .to_string();
        assert!(e.contains("--sample"), "{e}");
        let one_chip =
            || FleetSpec::new(vec![FleetGroup { spec: RunSpec::new("seizure"), chips: 1 }]);
        let e = sys.fleet(&one_chip().drift(-1.0)).unwrap_err().to_string();
        assert!(e.contains("--drift"), "{e}");
        let e = sys.fleet(&one_chip().drift(100.0)).unwrap_err().to_string();
        assert!(e.contains("--drift"), "{e}");
        let e = sys.fleet(&one_chip().phase_jitter(-0.5)).unwrap_err().to_string();
        assert!(e.contains("--phase-jitter"), "{e}");
    }

    /// Satellite: the mixed fleet spreads Poisson seeds over a bounded
    /// per-chip pool once a template holds enough population — chips of
    /// one Poisson template genuinely differ (exercising class sampling)
    /// while the class count stays O(templates × pool).
    #[test]
    fn mixed_fleet_spreads_poisson_seeds() {
        // small fleets keep the historical single seed per template
        let small = FleetSpec::mixed(64, 4);
        let small_poisson: Vec<_> = small
            .groups
            .iter()
            .filter(|g| matches!(g.spec.traffic, Traffic::Poisson { .. }))
            .collect();
        assert!(!small_poisson.is_empty());
        // large fleets spread each Poisson template over an 8-seed pool
        let big = FleetSpec::mixed(1_000_000, 4);
        let big_poisson: Vec<_> = big
            .groups
            .iter()
            .filter(|g| matches!(g.spec.traffic, Traffic::Poisson { .. }))
            .collect();
        assert_eq!(big_poisson.len(), 8 * small_poisson.len(), "8-seed pool per template");
        let seeds: std::collections::BTreeSet<u64> = big_poisson
            .iter()
            .map(|g| match g.spec.traffic {
                Traffic::Poisson { seed, .. } => seed,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(seeds.len(), big_poisson.len(), "seeds are distinct per sub-population");
        let total: usize = big.groups.iter().map(|g| g.chips).sum();
        assert_eq!(total, 1_000_000, "populations still partition the fleet");
        assert!(big.groups.len() <= 4 * small.groups.len(), "class count stays bounded");
    }

    /// Tentpole (parametric classes): a fully perturbed fleet — every
    /// chip drifted and phase-shifted — derives its members in closed
    /// form, and the fleet percentiles match a per-chip live
    /// materialization of the whole population.
    #[test]
    fn fleet_parametric_members_match_materialized_chips() {
        let sys = SocSystem::new();
        let spec =
            RunSpec::new("seizure").frames(3).traffic(Traffic::Periodic { rate_hz: 2.0 });
        let fleet = FleetSpec::new(vec![FleetGroup { spec: spec.clone(), chips: 12 }])
            .sample_k(4)
            .drift(2.0)
            .phase_jitter(0.01);
        let report = sys.fleet(&fleet).unwrap();
        assert_eq!(report.chips, 12);
        assert_eq!(report.classes.len(), 1, "one family");
        assert!(report.members > 1, "perturbed chips split into parametric members");
        assert_eq!(report.classes[0].members, report.members);
        assert_eq!(report.parity_failures, 0);
        assert!(report.classes[0].live_fallbacks <= report.members);
        // Materialize every chip live on its rescaled template and compare
        // the (per-member weighted) fleet percentiles against the per-chip
        // ground truth.
        let (w, rung) = sys.resolve(&spec).unwrap();
        let graph = frame_graph(w, rung.cfg).unwrap();
        let cf = CompiledFrame::compile(&graph);
        let release = spec.traffic.release_times(3);
        let window = crate::soc::sched::DEFAULT_STREAM_WINDOW.min(3);
        let (mut e, mut l, mut u, mut b) = (Vec::new(), Vec::new(), Vec::new(), Vec::new());
        for chip in 0..12u64 {
            let p = Perturb::derive(fleet.seed, chip, fleet.drift_pct, fleet.phase_jitter_s);
            let mut rel = release.clone();
            p.apply(&mut rel);
            let live = StreamScheduler::run_compiled_traffic_live_pm(
                &cf.rescaled(p.alpha),
                3,
                window,
                &rel,
                None,
            );
            let (ce, cl, cu, cb) = chip_metrics(&live);
            e.push((ce, 1usize));
            l.push((cl, 1));
            u.push((cu, 1));
            b.push((cb, 1));
        }
        let close = |x: f64, y: f64, what: &str| {
            assert!(
                (x - y).abs() <= 1e-9 * x.abs().max(y.abs()).max(1e-12),
                "{what}: {x} vs {y}"
            );
        };
        for (got, vals, what) in [
            (report.energy_mj_per_chip, &mut e, "energy"),
            (report.latency_s, &mut l, "latency"),
            (report.utilization, &mut u, "utilization"),
            (report.battery_days, &mut b, "battery"),
        ] {
            let want = pct(vals, 12);
            close(got.p50, want.p50, what);
            close(got.p95, want.p95, what);
            close(got.p99, want.p99, what);
        }
        // heterogeneity is real: the spread survives into the percentiles
        assert!(report.latency_s.p99 > report.latency_s.p50, "drift+jitter spread the fleet");
        let text = report.render_text();
        assert!(text.contains("parametric: drift"), "{text}");
        let json = report.to_json().render();
        for key in ["\"drift_pct\"", "\"phase_jitter_s\"", "\"members\"", "\"live_fallbacks\""] {
            assert!(json.contains(key), "missing {key} in {json}");
        }
    }

    /// Parametric members under a power policy: the span re-billing
    /// closed form survives the sampled live parity (sleep statistics
    /// included), and battery percentiles stay meaningful.
    #[test]
    fn fleet_parametric_with_policy_keeps_parity() {
        let sys = SocSystem::new();
        let spec =
            RunSpec::new("seizure").frames(4).traffic(Traffic::Periodic { rate_hz: 2.0 });
        let fleet = FleetSpec::new(vec![FleetGroup { spec, chips: 9 }])
            .sample_k(5)
            .policy(Some(PolicyKind::Lookahead))
            .drift(1.0)
            .phase_jitter(0.05)
            .seed(7);
        let report = sys.fleet(&fleet).unwrap();
        assert_eq!(report.parity_failures, 0, "billed members must match live re-runs");
        assert!(report.members > 1);
        assert_eq!(report.policy, "lookahead");
        assert!(report.classes[0].sleep_s > 0.0, "gap-dominated class sleeps");
        assert!(report.battery_days.p50 > 0.0);
    }

    /// Certificate fallback at fleet level: a phase jitter so large it
    /// dwarfs the representative's absolute event margins (Δ/φ under the
    /// bar) refuses the φ closed form, and the jittered members
    /// re-simulate live — exact, counted, and still parity-clean.
    #[test]
    fn fleet_phase_fallback_when_certificate_refuses() {
        let sys = SocSystem::new();
        let spec =
            RunSpec::new("seizure").frames(4).traffic(Traffic::Periodic { rate_hz: 2.0 });
        let fleet = FleetSpec::new(vec![FleetGroup { spec, chips: 6 }])
            .sample_k(3)
            .phase_jitter(1e9)
            .seed(3);
        let report = sys.fleet(&fleet).unwrap();
        assert!(
            report.live_fallbacks > 0,
            "a margin-dwarfing phase offset must refuse the closed form"
        );
        assert_eq!(report.parity_failures, 0, "fallback members are exact");
        assert_eq!(report.chips, 6);
        assert!(report.live_chips > 3, "fallbacks count as live work");
    }

    fn lossy(rate: f64) -> SessionModel {
        SessionModel { loss_rate: rate, seed: 7 }
    }

    /// Tentpole (secure link): channel faults and chip faults are
    /// distinct failure models — one per run, on stream and fleet alike
    /// — and a channel on a workload without handshake jobs is a spec
    /// error, not a silent no-op.
    #[test]
    fn loss_validation_and_exclusivity() {
        let sys = SocSystem::new();
        let faults = FaultModel {
            drop_rate: 0.1,
            transient_rate: 0.0,
            brownout_rate: 0.0,
            link_rate: 0.0,
            seed: 1,
        };
        let e = sys
            .run(
                &RunSpec::new("secure_link")
                    .frames(4)
                    .loss(Some(lossy(0.1)))
                    .faults(Some(faults.clone())),
            )
            .unwrap_err()
            .to_string();
        assert!(e.contains("mutually exclusive"), "{e}");
        let e = sys
            .fleet(
                &FleetSpec::secure_link(8, 4)
                    .loss(Some(lossy(0.1)))
                    .faults(Some(faults)),
            )
            .unwrap_err()
            .to_string();
        assert!(e.contains("mutually exclusive"), "{e}");
        let e = sys
            .run(&RunSpec::new("seizure").frames(2).loss(Some(SessionModel::lossless())))
            .unwrap_err()
            .to_string();
        assert!(e.contains("handshake"), "{e}");
        let e = sys
            .fleet(
                &FleetSpec::new(vec![FleetGroup {
                    spec: RunSpec::new("seizure").frames(2),
                    chips: 2,
                }])
                .loss(Some(SessionModel::lossless())),
            )
            .unwrap_err()
            .to_string();
        assert!(e.contains("handshake"), "{e}");
    }

    /// Tentpole (secure link): the retransmission/resumption schedule is
    /// pure in (model, recovery, global frame), so sharding the stream
    /// preserves every session counter exactly and the re-sent energy to
    /// float reordering.
    #[test]
    fn secure_link_counters_are_shard_invariant() {
        let sys = SocSystem::new();
        let spec = RunSpec::new("secure_link").frames(64).loss(Some(lossy(0.35)));
        let base = sys.run(&spec).unwrap();
        let ss = base.session.expect("lossy run carries session stats");
        assert!(ss.retransmissions > 0, "35% loss over 64 frames must retransmit");
        assert!(base.result.fault_retries == ss.retransmissions);
        assert!(base.result.frames_dropped == ss.records_dropped);
        for shards in [2usize, 4] {
            let sharded = sys.run(&spec.clone().shards(shards)).unwrap();
            assert_eq!(
                sharded.result.frames_dropped, base.result.frames_dropped,
                "{shards}-way sharding must not move drops"
            );
            assert_eq!(
                sharded.result.fault_retries, base.result.fault_retries,
                "{shards}-way sharding must not move retransmissions"
            );
            assert!(
                (sharded.result.recovery_energy_mj - base.result.recovery_energy_mj).abs()
                    <= 1e-9 * (1.0 + base.result.recovery_energy_mj),
                "re-sent energy union: {} vs {}",
                sharded.result.recovery_energy_mj,
                base.result.recovery_energy_mj
            );
            assert_eq!(sharded.session, base.session, "global counters are shard-blind");
        }
        let text = base.render_text();
        assert!(text.contains("secure link:"), "{text}");
        assert!(text.contains("goodput"), "{text}");
        let json = base.to_json().render();
        for key in ["\"session\"", "\"retransmissions\"", "\"goodput_fps\"", "\"channel\""] {
            assert!(json.contains(key), "missing {key}");
        }
    }

    /// Tentpole (secure-link fleet): the channel, session recovery and
    /// crypto backend join the class dedup key, and the fleet report
    /// carries the handshake/record split plus goodput percentiles.
    #[test]
    fn secure_link_fleet_keys_and_session_columns() {
        let sys = SocSystem::new();
        let spec = FleetSpec::secure_link(40, 6)
            .loss(Some(lossy(0.3)))
            .session_recovery(SessionRecovery::Resume)
            .crypto_backend(Some(BackendKind::Hwcrypt))
            .sample_k(2);
        let report = sys.fleet(&spec).unwrap();
        assert_eq!(report.chips, 40);
        assert_eq!(report.parity_failures, 0);
        for c in &report.classes {
            assert!(c.key.contains("ses:"), "{}", c.key);
            assert!(c.key.contains("sr:resume"), "{}", c.key);
            assert!(c.key.contains("cb:hwcrypt"), "{}", c.key);
            assert!(c.goodput_fps <= c.fps + 1e-12, "goodput never exceeds raw fps");
        }
        // every chip performs its frame-0 negotiation; under resumption
        // the outage handshakes are all abbreviated
        assert_eq!(report.full_handshakes, 40);
        assert!(report.retransmissions > 0, "30% loss must retransmit somewhere");
        assert!(report.handshake_j > 0.0 && report.record_j > 0.0);
        assert!(report.availability.p50 <= 1.0);
        let text = report.render_text();
        assert!(text.contains("secure link:"), "{text}");
        assert!(text.contains("goodput [fps]"), "{text}");
        let json = report.to_json().render();
        for key in ["\"channel\"", "\"resumptions\"", "\"handshake_j\"", "\"goodput_fps\""] {
            assert!(json.contains(key), "missing {key}");
        }
        // a session-free fleet stays on the historical columns
        let clean = sys.fleet(&FleetSpec::secure_link(8, 2).sample_k(1)).unwrap();
        assert_eq!(clean.channel, "none");
        assert_eq!(clean.retransmissions, 0);
        assert!(!clean.render_text().contains("secure link:"));
    }

    /// Satellite (ablation): the sessionsweep grid covers backend ×
    /// loss × recovery with a lossless baseline per backend, and the
    /// baseline rows deliver everything.
    #[test]
    fn session_sweep_grid_shape_and_baselines() {
        let sys = SocSystem::new();
        let report = sys.session_sweep(16).unwrap();
        assert_eq!(report.rows.len(), 3 * (1 + 2 * 3), "3 backends x (baseline + 2x3)");
        for row in report.rows.iter().filter(|r| r.recovery == "—") {
            assert_eq!(row.availability, 1.0, "lossless baseline delivers everything");
            assert_eq!(row.retransmissions, 0);
            assert_eq!(row.full_handshakes, 1, "exactly the frame-0 negotiation");
            assert_eq!(row.records_dropped, 0);
        }
        for row in &report.rows {
            assert!(row.goodput_fps > 0.0);
            assert!(row.energy_mj > 0.0);
        }
        // seed 11 over frames 0..16: 7 retransmissions at loss 0.2, 35 at
        // 0.6 — the lossy rows really exercise the timers
        for row in report.rows.iter().filter(|r| r.recovery != "—") {
            assert!(row.retransmissions > 0, "{}/{}", row.backend, row.channel);
        }
        // the channel is shared across the grid: within one (loss,
        // recovery) point every backend sees the same outages, so the
        // counters are backend-invariant and only the energies move
        let reference: Vec<_> = report.rows[..7]
            .iter()
            .map(|r| (r.retransmissions, r.resumptions, r.records_dropped))
            .collect();
        for backend_rows in report.rows.chunks(7).skip(1) {
            for (r, want) in backend_rows.iter().zip(&reference) {
                assert_eq!(
                    (r.retransmissions, r.resumptions, r.records_dropped),
                    *want,
                    "{}/{}: counters must not depend on the backend",
                    r.backend,
                    r.channel
                );
            }
        }
        let text = report.render_text();
        assert!(text.contains("sessionsweep"), "{text}");
        for b in ["hwcrypt", "sw", "insram"] {
            assert!(text.contains(b), "backend {b} missing from {text}");
        }
        let json = report.to_json().render();
        for key in ["\"backend\"", "\"goodput_fps\"", "\"handshake_mj\""] {
            assert!(json.contains(key), "missing {key}");
        }
    }
}
